"""L1 correctness: Pallas block-quantization kernels vs the pure-jnp oracle.

This is the core correctness signal for the paper's communication
compression. hypothesis sweeps shapes and value distributions; exact
integer-output equality is required (the Rust port is held to the same
contract, cross-checked in rust/tests/).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import quant as Q
from compile.kernels import ref as R

BLOCKS = [32, 64, 256]


def _rand(n, seed=0, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(seed), (n,), jnp.float32) * scale


# ------------------------------------------------------------------ INT8


@pytest.mark.parametrize("block", BLOCKS)
@pytest.mark.parametrize("nblocks", [1, 2, 7, 64, 130])
def test_int8_matches_ref(block, nblocks):
    x = _rand(block * nblocks, seed=block + nblocks)
    q, s = Q.quantize_int8(x, block)
    qr, sr = R.quantize_int8_ref(x, block)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(Q.dequantize_int8(q, s, block)),
        np.asarray(R.dequantize_int8_ref(qr, sr, block)),
        rtol=1e-6, atol=1e-7,
    )


@settings(max_examples=25, deadline=None)
@given(
    nblocks=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
    scale=st.sampled_from([1e-6, 1e-2, 1.0, 1e3]),
)
def test_int8_error_bound(nblocks, seed, scale):
    """|x - dq(q(x))| <= scale/2 per block (half a quantization step)."""
    block = 64
    x = _rand(block * nblocks, seed=seed, scale=scale)
    q, s = Q.quantize_int8(x, block)
    xd = Q.dequantize_int8(q, s, block)
    err = np.abs(np.asarray(x - xd)).reshape(nblocks, block)
    bound = np.asarray(s)[:, None] * 0.5 + 1e-12
    assert (err <= bound).all()


def test_int8_zero_block_exact():
    x = jnp.zeros((512,), jnp.float32)
    q, s = Q.quantize_int8(x, 256)
    assert (np.asarray(q) == 0).all()
    np.testing.assert_array_equal(np.asarray(s), np.ones(2, np.float32))
    np.testing.assert_array_equal(np.asarray(Q.dequantize_int8(q, s, 256)), np.zeros(512))


def test_int8_idempotent():
    """Quantization is a projection: q(dq(q(x))) == q(x)."""
    x = _rand(1024, seed=7)
    q1, s1 = Q.quantize_int8(x, 256)
    xd = Q.dequantize_int8(q1, s1, 256)
    q2, s2 = Q.quantize_int8(xd, 256)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)


def test_int8_extremes_hit_limits():
    x = jnp.concatenate([jnp.full((128,), 5.0), jnp.full((128,), -5.0)])
    q, s = Q.quantize_int8(x, 256)
    assert np.asarray(q).max() == 127 and np.asarray(q).min() == -127


def test_int8_rejects_misaligned():
    with pytest.raises(ValueError):
        Q.quantize_int8(_rand(100), 256)


# ------------------------------------------------------------------ INT4


@pytest.mark.parametrize("block", BLOCKS)
@pytest.mark.parametrize("nblocks", [1, 3, 64])
def test_int4_matches_ref(block, nblocks):
    x = _rand(block * nblocks, seed=block * 31 + nblocks)
    p, s = Q.quantize_int4(x, block)
    pr, sr = R.quantize_int4_ref(x, block)
    np.testing.assert_array_equal(np.asarray(p), np.asarray(pr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(Q.dequantize_int4(p, s, block)),
        np.asarray(R.dequantize_int4_ref(pr, sr, block)),
        rtol=1e-6, atol=1e-7,
    )


@settings(max_examples=25, deadline=None)
@given(nblocks=st.integers(1, 12), seed=st.integers(0, 2**31 - 1))
def test_int4_error_bound(nblocks, seed):
    block = 64
    x = _rand(block * nblocks, seed=seed)
    p, s = Q.quantize_int4(x, block)
    xd = Q.dequantize_int4(p, s, block)
    err = np.abs(np.asarray(x - xd)).reshape(nblocks, block)
    bound = np.asarray(s)[:, None] * 0.5 + 1e-12
    assert (err <= bound).all()


def test_int4_nibble_layout():
    """Element 2i in low nibble, 2i+1 in high nibble, offset-8 encoding."""
    # block of 4: values scaled so q = [7, -7, 0, 1] exactly (amax 7 -> scale 1)
    x = jnp.array([7.0, -7.0, 0.0, 1.0], jnp.float32)
    p, s = Q.quantize_int4(x, 4)
    assert float(s[0]) == 1.0
    b0, b1 = int(np.asarray(p)[0]), int(np.asarray(p)[1])
    assert b0 == (7 + 8) + 16 * (-7 + 8)
    assert b1 == (0 + 8) + 16 * (1 + 8)


def test_int4_worse_than_int8():
    x = _rand(4096, seed=3)
    e8 = np.abs(np.asarray(x - Q.dequantize_int8(*Q.quantize_int8(x, 256), 256))).mean()
    e4 = np.abs(np.asarray(x - Q.dequantize_int4(*Q.quantize_int4(x, 256), 256))).mean()
    assert e4 > e8 > 0


def test_int4_odd_block_rejected():
    with pytest.raises(ValueError):
        Q.quantize_int4(_rand(99 * 2), 99)


# ------------------------------------------------------------------ roundtrip jits


def test_roundtrips_match_ref():
    x = _rand(8192, seed=11)
    np.testing.assert_allclose(
        np.asarray(Q.roundtrip_int8(x, 256)), np.asarray(R.roundtrip_int8_ref(x, 256)), rtol=1e-6, atol=1e-7
    )
    np.testing.assert_allclose(
        np.asarray(Q.roundtrip_int4(x, 256)), np.asarray(R.roundtrip_int4_ref(x, 256)), rtol=1e-6, atol=1e-7
    )
