"""L2 correctness: flat-parameter GPT model — shapes, init statistics,
gradients, and trainability (loss decreases when overfitting one batch)."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.PRESETS["tiny"]


def _batch(cfg, seed=0):
    key = jax.random.PRNGKey(seed)
    toks = jax.random.randint(key, (cfg.mbs, cfg.seq + 1), 0, cfg.vocab)
    return toks[:, :-1].astype(jnp.int32), toks[:, 1:].astype(jnp.int32)


def test_param_specs_layout_is_dense():
    """Flat layout covers [0, n_params) with no gaps or overlaps."""
    off = 0
    for name, shape in M.param_specs(CFG):
        size = math.prod(shape)
        assert size > 0, name
        off += size
    assert off == M.n_params(CFG)


def test_param_count_formula():
    """n_params == vocab*d + seq*d + L*(12d^2 + 4d) + 2d (tied head)."""
    d, L = CFG.d_model, CFG.n_layers
    expected = CFG.vocab * d + CFG.seq * d + L * (12 * d * d + 4 * d) + 2 * d
    assert M.n_params(CFG) == expected


def test_init_statistics():
    flat = M.init_params(jnp.int32(0), CFG)
    assert flat.shape == (M.n_params(CFG),)
    p = M.unflatten(flat, CFG)
    np.testing.assert_array_equal(np.asarray(p["final_ln.scale"]), np.ones(CFG.d_model))
    np.testing.assert_array_equal(np.asarray(p["final_ln.bias"]), np.zeros(CFG.d_model))
    emb_std = float(jnp.std(p["embed.weight"]))
    assert 0.015 < emb_std < 0.025
    # residual projections scaled down by 1/sqrt(2L)
    out_std = float(jnp.std(p["layers.0.attn.out"]))
    assert out_std < emb_std


def test_init_seed_determinism():
    a = M.init_params(jnp.int32(42), CFG)
    b = M.init_params(jnp.int32(42), CFG)
    c = M.init_params(jnp.int32(43), CFG)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_forward_shapes_and_loss_at_init():
    flat = M.init_params(jnp.int32(0), CFG)
    toks, tgts = _batch(CFG)
    logits = M.forward(flat, toks, CFG)
    assert logits.shape == (CFG.mbs, CFG.seq, CFG.vocab)
    loss = float(M.loss_fn(flat, toks, tgts, CFG))
    # near-uniform prediction at init => CE ~ ln(vocab)
    assert abs(loss - math.log(CFG.vocab)) < 0.5


def test_train_step_grad_shapes_finite():
    flat = M.init_params(jnp.int32(0), CFG)
    toks, tgts = _batch(CFG)
    loss, grads = M.train_step(flat, toks, tgts, CFG)
    assert grads.shape == flat.shape
    assert np.isfinite(float(loss))
    assert np.isfinite(np.asarray(grads)).all()
    assert float(jnp.linalg.norm(grads)) > 0


def test_grads_match_finite_difference():
    """Directional derivative vs finite difference on the flat vector."""
    cfg = M.ModelConfig("fd", 32, 1, 2, 64, 16, 1)
    flat = M.init_params(jnp.int32(1), cfg)
    toks, tgts = _batch(cfg, seed=2)
    loss, grads = M.train_step(flat, toks, tgts, cfg)
    key = jax.random.PRNGKey(3)
    d = jax.random.normal(key, flat.shape)
    d = d / jnp.linalg.norm(d)
    eps = 1e-3
    lp = float(M.loss_fn(flat + eps * d, toks, tgts, cfg))
    lm = float(M.loss_fn(flat - eps * d, toks, tgts, cfg))
    fd = (lp - lm) / (2 * eps)
    an = float(jnp.dot(grads, d))
    assert abs(fd - an) < 5e-3 * max(1.0, abs(an)), (fd, an)


def test_sgd_overfits_single_batch():
    flat = M.init_params(jnp.int32(0), CFG)
    toks, tgts = _batch(CFG, seed=7)
    step = jax.jit(lambda f: M.train_step(f, toks, tgts, CFG))
    losses = []
    for _ in range(30):
        loss, g = step(flat)
        losses.append(float(loss))
        flat = flat - 0.5 * g
    assert losses[-1] < losses[0] - 0.5, losses[:3] + losses[-3:]


def test_causal_masking_in_model():
    """Changing future tokens must not affect earlier logits."""
    flat = M.init_params(jnp.int32(0), CFG)
    toks, _ = _batch(CFG, seed=1)
    logits1 = M.forward(flat, toks, CFG)
    toks2 = toks.at[:, -1].set((toks[:, -1] + 1) % CFG.vocab)
    logits2 = M.forward(flat, toks2, CFG)
    np.testing.assert_allclose(
        np.asarray(logits1[:, :-1]), np.asarray(logits2[:, :-1]), atol=1e-5
    )


@pytest.mark.parametrize("name", list(M.PRESETS))
def test_all_presets_have_valid_geometry(name):
    cfg = M.PRESETS[name]
    assert cfg.d_model % cfg.n_heads == 0
    assert M.n_params(cfg) > 0
    assert M.flops_per_token(cfg) == 3 * M.flops_per_token(cfg, fwd_only=True)
