"""L1 correctness: fused attention + tiled matmul kernels vs jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import attention as A
from compile.kernels import matmul as MM
from compile.kernels import ref as R


def _qkv(heads, seq, hd, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    shape = (heads, seq, hd)
    return tuple(jax.random.normal(k, shape, jnp.float32) for k in ks)


@pytest.mark.parametrize(
    "heads,seq,hd",
    [(1, 64, 16), (2, 128, 32), (4, 128, 64), (3, 256, 32)],
)
def test_attention_causal_matches_ref(heads, seq, hd):
    q, k, v = _qkv(heads, seq, hd, seed=heads * seq)
    out = A.attention(q, k, v, causal=True)
    exp = R.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=3e-5)


def test_attention_noncausal_matches_ref():
    q, k, v = _qkv(2, 128, 32, seed=9)
    out = A.attention(q, k, v, causal=False)
    exp = R.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=3e-5)


def test_attention_block_size_invariance():
    """Online-softmax result must not depend on the KV tiling."""
    q, k, v = _qkv(2, 128, 32, seed=4)
    a = A.attention(q, k, v, q_block=32, kv_block=32)
    b = A.attention(q, k, v, q_block=64, kv_block=128)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5)


def test_attention_causality():
    """Perturbing future tokens must not change past outputs."""
    q, k, v = _qkv(1, 64, 16, seed=5)
    out1 = A.attention(q, k, v)
    k2 = k.at[:, 48:].set(k[:, 48:] + 10.0)
    v2 = v.at[:, 48:].set(v[:, 48:] - 3.0)
    out2 = A.attention(q, k2, v2)
    np.testing.assert_allclose(np.asarray(out1[:, :48]), np.asarray(out2[:, :48]), atol=3e-5)
    assert not np.allclose(np.asarray(out1[:, 48:]), np.asarray(out2[:, 48:]), atol=1e-3)


def test_attention_rejects_misaligned_seq():
    q, k, v = _qkv(1, 96, 16)
    with pytest.raises(ValueError):
        A.attention(q, k, v, q_block=64, kv_block=64)


@pytest.mark.parametrize(
    "m,k,n,bm,bn,bk",
    [(64, 64, 64, 64, 64, 64), (128, 64, 192, 64, 64, 32), (256, 128, 128, 128, 128, 128)],
)
def test_matmul_matches_ref(m, k, n, bm, bn, bk):
    a = jax.random.normal(jax.random.PRNGKey(m + n), (m, k), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(k), (k, n), jnp.float32)
    c = MM.matmul(a, b, bm, bn, bk)
    np.testing.assert_allclose(np.asarray(c), np.asarray(R.matmul_ref(a, b)), atol=1e-3)


def test_matmul_identity():
    a = jax.random.normal(jax.random.PRNGKey(0), (64, 64), jnp.float32)
    eye = jnp.eye(64, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(MM.matmul(a, eye, 32, 32, 32)), np.asarray(a), atol=1e-5)


def test_matmul_rejects_mismatch():
    a = jnp.zeros((64, 32))
    b = jnp.zeros((64, 64))
    with pytest.raises(ValueError):
        MM.matmul(a, b)
