"""L2: GPT-NeoX-style transformer in JAX — the compute graph the Rust
coordinator executes via AOT-compiled HLO.

The paper trains GPT-NeoX-10B/20B; this module defines the same
architecture family (pre-LN decoder, learned positions, GELU MLP, causal
attention, tied LM head) parameterized so the reproduction can instantiate
laptop-scale proxies (DESIGN.md §1, substitution table).

Everything works on ONE FLAT f32 PARAMETER VECTOR. This mirrors how ZeRO
implementations flatten model state into contiguous partitions: the Rust
engine shards, gathers, quantizes and updates the flat vector, and the HLO
entry points take/return the flat vector so host<->device marshalling is a
single buffer. `param_specs` fixes the layout; the AOT manifest exports it.

Exported entry points (lowered by aot.py):
  init_params(seed)                     -> flat f32[n_params]
  train_step(flat, tokens, targets)     -> (loss, flat_grads)
  eval_loss(flat, tokens, targets)      -> loss
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture + batch geometry (static for AOT lowering)."""

    name: str
    d_model: int
    n_layers: int
    n_heads: int
    vocab: int
    seq: int
    mbs: int  # micro-batch size baked into the lowered train_step

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def __post_init__(self):
        if self.d_model % self.n_heads != 0:
            raise ValueError("d_model must divide n_heads")


# Laptop-scale proxies for the paper's models (see DESIGN.md §8: 1 CPU core).
# "neox10b"/"neox20b" carry the real paper geometries for the analytical
# simulator; the *_proxy configs are what the PJRT-CPU numerics path runs.
PRESETS: Dict[str, ModelConfig] = {
    "tiny": ModelConfig("tiny", 64, 2, 2, 256, 64, 2),
    "mini": ModelConfig("mini", 128, 3, 4, 512, 128, 2),
    "loss10b_proxy": ModelConfig("loss10b_proxy", 256, 4, 4, 512, 128, 1),
    "loss20b_proxy": ModelConfig("loss20b_proxy", 320, 6, 5, 512, 128, 1),
    "e2e": ModelConfig("e2e", 512, 8, 8, 2048, 256, 1),
}


# --------------------------------------------------------------------------
# Parameter layout
# --------------------------------------------------------------------------


def param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Deterministic (name, shape) list defining the flat-vector layout."""
    d = cfg.d_model
    specs: List[Tuple[str, Tuple[int, ...]]] = [
        ("embed.weight", (cfg.vocab, d)),
        ("pos.weight", (cfg.seq, d)),
    ]
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        specs += [
            (p + "ln1.scale", (d,)),
            (p + "ln1.bias", (d,)),
            (p + "attn.qkv", (d, 3 * d)),
            (p + "attn.out", (d, d)),
            (p + "ln2.scale", (d,)),
            (p + "ln2.bias", (d,)),
            (p + "mlp.fc", (d, 4 * d)),
            (p + "mlp.proj", (4 * d, d)),
        ]
    specs += [("final_ln.scale", (d,)), ("final_ln.bias", (d,))]
    # LM head is tied to embed.weight (GPT-NeoX offers both; tied keeps the
    # proxy models small — recorded in the manifest).
    return specs


def n_params(cfg: ModelConfig) -> int:
    return sum(math.prod(s) for _, s in param_specs(cfg))


def unflatten(flat: jax.Array, cfg: ModelConfig) -> Dict[str, jax.Array]:
    params = {}
    off = 0
    for name, shape in param_specs(cfg):
        size = math.prod(shape)
        params[name] = flat[off : off + size].reshape(shape)
        off += size
    return params


def init_params(seed: jax.Array, cfg: ModelConfig) -> jax.Array:
    """GPT-NeoX init: N(0, 0.02), residual projections scaled by 1/sqrt(2L)."""
    key = jax.random.PRNGKey(seed)
    chunks = []
    resid_scale = 0.02 / math.sqrt(2.0 * cfg.n_layers)
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        size = math.prod(shape)
        if name.endswith("ln1.scale") or name.endswith("ln2.scale") or name == "final_ln.scale":
            chunks.append(jnp.ones((size,), jnp.float32))
        elif name.endswith(".bias"):
            chunks.append(jnp.zeros((size,), jnp.float32))
        elif name.endswith("attn.out") or name.endswith("mlp.proj"):
            chunks.append(jax.random.normal(sub, (size,), jnp.float32) * resid_scale)
        else:
            chunks.append(jax.random.normal(sub, (size,), jnp.float32) * 0.02)
    return jnp.concatenate(chunks)


# --------------------------------------------------------------------------
# Forward pass
# --------------------------------------------------------------------------


def _layer_norm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias


def _causal_attention(x, qkv_w, out_w, cfg: ModelConfig):
    b, s, d = x.shape
    qkv = x @ qkv_w  # (b, s, 3d)
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(t):  # (b, s, d) -> (b, h, s, hd)
        return t.reshape(b, s, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)

    q, k, v = heads(q), heads(k), heads(v)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(cfg.head_dim)
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    logits = jnp.where(mask[None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", w, v)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, d)
    return o @ out_w


def forward(flat: jax.Array, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    """tokens: int32 (mbs, seq) -> logits f32 (mbs, seq, vocab)."""
    p = unflatten(flat, cfg)
    x = p["embed.weight"][tokens] + p["pos.weight"][None, : tokens.shape[1]]
    for i in range(cfg.n_layers):
        pre = f"layers.{i}."
        h = _layer_norm(x, p[pre + "ln1.scale"], p[pre + "ln1.bias"])
        x = x + _causal_attention(h, p[pre + "attn.qkv"], p[pre + "attn.out"], cfg)
        h = _layer_norm(x, p[pre + "ln2.scale"], p[pre + "ln2.bias"])
        h = jax.nn.gelu(h @ p[pre + "mlp.fc"]) @ p[pre + "mlp.proj"]
        x = x + h
    x = _layer_norm(x, p["final_ln.scale"], p["final_ln.bias"])
    return x @ p["embed.weight"].T  # tied head


def loss_fn(flat: jax.Array, tokens: jax.Array, targets: jax.Array, cfg: ModelConfig):
    """Mean next-token cross-entropy."""
    logits = forward(flat, tokens, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def train_step(flat: jax.Array, tokens: jax.Array, targets: jax.Array, cfg: ModelConfig):
    """One microbatch fwd+bwd: returns (loss, flat grads)."""
    loss, grads = jax.value_and_grad(loss_fn)(flat, tokens, targets, cfg)
    return loss, grads


# --------------------------------------------------------------------------
# FLOPs accounting (used to cross-check the Rust model:: calculator)
# --------------------------------------------------------------------------


def flops_per_token(cfg: ModelConfig, fwd_only: bool = False) -> float:
    """Dense matmul FLOPs per token (fwd = 2*mac; bwd = 2x fwd)."""
    d, s = cfg.d_model, cfg.seq
    per_layer = (
        2 * d * 3 * d  # qkv proj
        + 2 * 2 * s * d  # QK^T and AV (per token: 2 * seq * d each)
        + 2 * d * d  # out proj
        + 2 * d * 4 * d * 2  # mlp fc + proj
    )
    total = cfg.n_layers * per_layer + 2 * d * cfg.vocab  # lm head
    return total if fwd_only else 3 * total
