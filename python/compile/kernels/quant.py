"""L1 Pallas kernels: ZeRO++-style block-based quantization.

The paper (ZeRO-topo) adopts ZeRO++'s block-based quantization [Dettmers et
al.] for *all* collectives: INT8 symmetric quantization for weight
all-gather and the secondary weight partition, INT4 (packed, two nibbles
per byte) for the all-to-all gradient reduce-scatter.

Hardware adaptation (see DESIGN.md §6): ZeRO++ ships CUDA kernels where one
thread-block reduces max-abs over a quantization block via warp shuffles.
On TPU/Pallas the quantization block maps to a grid cell whose tile is
staged HBM->VMEM by the BlockSpec; the max-abs reduction runs on the VPU
over the VMEM-resident tile (VMEM *is* the scratchpad, no shuffle needed),
and nibble packing is arithmetic (`lo + hi*16`), which vectorizes cleanly.

All kernels are lowered with interpret=True: the CPU PJRT plugin cannot run
Mosaic custom-calls; numerics are identical, and the real-TPU efficiency is
estimated from the BlockSpec footprint in DESIGN.md §7.

Quantization contract (mirrored bit-for-bit by the Rust port in
rust/src/quant/):
  - symmetric, per-block scale:  s = max|x| / Q   (Q = 127 for INT8, 7 for INT4)
  - s == 0 (all-zero block) is replaced by 1.0 so dequantization is exact
  - q = clip(round_half_to_even(x / s), -Q, Q)
  - dequant: x' = q * s
  - INT4 packing: nibble n = q + 8 in [1, 15]; byte = n_even + 16 * n_odd
    (element 2i in the low nibble, element 2i+1 in the high nibble)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default quantization block (elements per scale). ZeRO++ uses fine-grained
# blocks for accuracy; 256 keeps the VMEM tile tiny and the scale overhead
# at 1/64 (f32 scale per 256 elements).
DEFAULT_BLOCK = 256

# VMEM budget reasoning (DESIGN.md §7): a (BLOCKS_PER_TILE, BLOCK) f32 tile
# plus its int8 output and f32 scales must fit comfortably in 16 MiB VMEM
# with double buffering. 64 * 256 * 4B = 64 KiB per input tile -- far under
# budget, so the grid is bandwidth-bound (as on the GPU original).
BLOCKS_PER_TILE = 64


def _check(n: int, block: int) -> int:
    if n % block != 0:
        raise ValueError(f"size {n} not a multiple of block {block}")
    return n // block


# ---------------------------------------------------------------------------
# INT8
# ---------------------------------------------------------------------------


def _quant_int8_kernel(x_ref, q_ref, s_ref):
    """One grid cell quantizes a (rows, block) tile, one scale per row."""
    x = x_ref[...]
    amax = jnp.max(jnp.abs(x), axis=-1)
    scale = jnp.where(amax > 0.0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale[:, None]), -127.0, 127.0)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale.astype(jnp.float32)


def quantize_int8(x: jax.Array, block: int = DEFAULT_BLOCK):
    """Blockwise symmetric INT8 quantization.

    Args:
      x: flat f32 array, length divisible by `block`.
    Returns:
      (q, scales): int8[n], f32[n//block].
    """
    n = x.shape[0]
    nblocks = _check(n, block)
    rows = min(BLOCKS_PER_TILE, nblocks)
    while nblocks % rows != 0:
        rows -= 1
    grid = (nblocks // rows,)
    xb = x.reshape(nblocks, block)
    q, s = pl.pallas_call(
        _quant_int8_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, block), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((rows, block), lambda i: (i, 0)),
            pl.BlockSpec((rows,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblocks, block), jnp.int8),
            jax.ShapeDtypeStruct((nblocks,), jnp.float32),
        ],
        interpret=True,
    )(xb)
    return q.reshape(n), s


def _dequant_int8_kernel(q_ref, s_ref, x_ref):
    q = q_ref[...].astype(jnp.float32)
    s = s_ref[...]
    x_ref[...] = q * s[:, None]


def dequantize_int8(q: jax.Array, scales: jax.Array, block: int = DEFAULT_BLOCK):
    """Inverse of quantize_int8: x' = q * scale(block)."""
    n = q.shape[0]
    nblocks = _check(n, block)
    if scales.shape != (nblocks,):
        raise ValueError(f"scales shape {scales.shape} != ({nblocks},)")
    rows = min(BLOCKS_PER_TILE, nblocks)
    while nblocks % rows != 0:
        rows -= 1
    grid = (nblocks // rows,)
    x = pl.pallas_call(
        _dequant_int8_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, block), lambda i: (i, 0)),
            pl.BlockSpec((rows,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((rows, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblocks, block), jnp.float32),
        interpret=True,
    )(q.reshape(nblocks, block), scales)
    return x.reshape(n)


# ---------------------------------------------------------------------------
# INT4 (packed two-per-byte)
# ---------------------------------------------------------------------------


def _quant_int4_kernel(x_ref, p_ref, s_ref):
    x = x_ref[...]
    amax = jnp.max(jnp.abs(x), axis=-1)
    scale = jnp.where(amax > 0.0, amax / 7.0, 1.0)
    q = jnp.clip(jnp.round(x / scale[:, None]), -7.0, 7.0).astype(jnp.int32)
    n = q + 8  # nibbles in [1, 15]
    lo = n[:, 0::2]
    hi = n[:, 1::2]
    p_ref[...] = (lo + hi * 16).astype(jnp.uint8)
    s_ref[...] = scale.astype(jnp.float32)


def quantize_int4(x: jax.Array, block: int = DEFAULT_BLOCK):
    """Blockwise symmetric INT4 quantization with nibble packing.

    Returns:
      (packed, scales): uint8[n//2], f32[n//block]. Element 2i sits in the
      low nibble of byte i, element 2i+1 in the high nibble.
    """
    n = x.shape[0]
    if block % 2 != 0:
        raise ValueError("int4 block must be even")
    nblocks = _check(n, block)
    rows = min(BLOCKS_PER_TILE, nblocks)
    while nblocks % rows != 0:
        rows -= 1
    grid = (nblocks // rows,)
    p, s = pl.pallas_call(
        _quant_int4_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, block), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((rows, block // 2), lambda i: (i, 0)),
            pl.BlockSpec((rows,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblocks, block // 2), jnp.uint8),
            jax.ShapeDtypeStruct((nblocks,), jnp.float32),
        ],
        interpret=True,
    )(x.reshape(nblocks, block))
    return p.reshape(n // 2), s


def _dequant_int4_kernel(p_ref, s_ref, x_ref):
    p = p_ref[...].astype(jnp.int32)
    lo = (p % 16) - 8
    hi = (p // 16) - 8
    rows, half = p.shape
    q = jnp.stack([lo, hi], axis=-1).reshape(rows, half * 2)
    x_ref[...] = q.astype(jnp.float32) * s_ref[...][:, None]


def dequantize_int4(packed: jax.Array, scales: jax.Array, block: int = DEFAULT_BLOCK):
    """Inverse of quantize_int4."""
    half = packed.shape[0]
    n = half * 2
    nblocks = _check(n, block)
    if scales.shape != (nblocks,):
        raise ValueError(f"scales shape {scales.shape} != ({nblocks},)")
    rows = min(BLOCKS_PER_TILE, nblocks)
    while nblocks % rows != 0:
        rows -= 1
    grid = (nblocks // rows,)
    x = pl.pallas_call(
        _dequant_int4_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, block // 2), lambda i: (i, 0)),
            pl.BlockSpec((rows,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((rows, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblocks, block), jnp.float32),
        interpret=True,
    )(packed.reshape(nblocks, block // 2), scales)
    return x.reshape(n)


# ---------------------------------------------------------------------------
# Fused round-trips (the shapes the AOT path exports; ZeRO++'s quantized
# all-to-all reduce-scatter does exactly one quant->wire->dequant per hop)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnums=(1,))
def roundtrip_int8(x: jax.Array, block: int = DEFAULT_BLOCK) -> jax.Array:
    q, s = quantize_int8(x, block)
    return dequantize_int8(q, s, block)


@functools.partial(jax.jit, static_argnums=(1,))
def roundtrip_int4(x: jax.Array, block: int = DEFAULT_BLOCK) -> jax.Array:
    p, s = quantize_int4(x, block)
    return dequantize_int4(p, s, block)
