"""L1 Pallas kernel: tiled matmul (MXU-shaped building block).

The classic (i, j, k) grid: each cell multiplies an (bm, bk) A-tile by a
(bk, bn) B-tile and accumulates into the (bm, bn) output tile, relying on
Pallas's revisiting semantics over the k axis. Tiles are sized for the MXU
(128-aligned) and comfortably fit VMEM (see DESIGN.md §7).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += a_ref[...] @ b_ref[...]


@functools.partial(jax.jit, static_argnums=(2, 3, 4))
def matmul(
    a: jax.Array,
    b: jax.Array,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
) -> jax.Array:
    """C = A @ B with explicit (bm, bn, bk) tiling."""
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"inner dims mismatch: {k} vs {k2}")
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    if m % bm or n % bn or k % bk:
        raise ValueError(f"shape ({m},{k})x({k},{n}) not divisible by tiles {bm},{bn},{bk}")
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)
