"""Pure-jnp correctness oracles for every Pallas kernel in this package.

These are the ground truth the kernels (and the Rust ports) are tested
against: straight-line jnp with no tiling, no pallas, no cleverness.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

DEFAULT_BLOCK = 256


# -- block quantization ------------------------------------------------------


def quantize_int8_ref(x: jax.Array, block: int = DEFAULT_BLOCK):
    n = x.shape[0]
    xb = x.reshape(n // block, block)
    amax = jnp.max(jnp.abs(xb), axis=-1)
    scale = jnp.where(amax > 0.0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xb / scale[:, None]), -127.0, 127.0).astype(jnp.int8)
    return q.reshape(n), scale.astype(jnp.float32)


def dequantize_int8_ref(q: jax.Array, scales: jax.Array, block: int = DEFAULT_BLOCK):
    n = q.shape[0]
    qb = q.reshape(n // block, block).astype(jnp.float32)
    return (qb * scales[:, None]).reshape(n)


def quantize_int4_ref(x: jax.Array, block: int = DEFAULT_BLOCK):
    n = x.shape[0]
    xb = x.reshape(n // block, block)
    amax = jnp.max(jnp.abs(xb), axis=-1)
    scale = jnp.where(amax > 0.0, amax / 7.0, 1.0)
    q = jnp.clip(jnp.round(xb / scale[:, None]), -7.0, 7.0).astype(jnp.int32)
    nib = q + 8
    packed = (nib[:, 0::2] + nib[:, 1::2] * 16).astype(jnp.uint8)
    return packed.reshape(n // 2), scale.astype(jnp.float32)


def dequantize_int4_ref(packed: jax.Array, scales: jax.Array, block: int = DEFAULT_BLOCK):
    half = packed.shape[0]
    n = half * 2
    pb = packed.reshape(n // block, block // 2).astype(jnp.int32)
    lo = (pb % 16) - 8
    hi = (pb // 16) - 8
    q = jnp.stack([lo, hi], axis=-1).reshape(n // block, block)
    return (q.astype(jnp.float32) * scales[:, None]).reshape(n)


def roundtrip_int8_ref(x: jax.Array, block: int = DEFAULT_BLOCK):
    q, s = quantize_int8_ref(x, block)
    return dequantize_int8_ref(q, s, block)


def roundtrip_int4_ref(x: jax.Array, block: int = DEFAULT_BLOCK):
    p, s = quantize_int4_ref(x, block)
    return dequantize_int4_ref(p, s, block)


# -- attention ---------------------------------------------------------------


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True):
    """Plain softmax attention. q,k,v: (heads, seq, head_dim)."""
    _, s, hd = q.shape
    logits = jnp.einsum("hqd,hkd->hqk", q, k) / jnp.sqrt(jnp.float32(hd))
    if causal:
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        logits = jnp.where(mask[None, :, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", w, v)


# -- matmul ------------------------------------------------------------------


def matmul_ref(a: jax.Array, b: jax.Array):
    return a @ b
