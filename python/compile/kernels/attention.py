"""L1 Pallas kernel: fused causal attention (flash-attention style).

The paper enables flash attention in its training stack (Section VI). The
CUDA flash-attention kernel keeps the running softmax statistics in
registers/shared memory and streams KV through threadblocks; the TPU/Pallas
adaptation (DESIGN.md §6) makes each grid cell own one (head, q-block) and
streams KV *tiles* through VMEM with the online-softmax recurrence:

    m_new = max(m, rowmax(S))            # S = q_tile @ k_tile^T / sqrt(d)
    l_new = exp(m - m_new) * l + rowsum(exp(S - m_new))
    acc   = exp(m - m_new) * acc + exp(S - m_new) @ v_tile

Both matmuls are MXU-shaped (q_block x head_dim @ head_dim x kv_block).
interpret=True for CPU-PJRT execution; see quant.py docstring.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_Q_BLOCK = 64
DEFAULT_KV_BLOCK = 64

_NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, kv_block: int, causal: bool):
    """Grid cell: one (head, q-block). KV streamed in `kv_block` tiles."""
    q = q_ref[0]  # (q_block, head_dim)
    q_block, head_dim = q.shape
    seq = k_ref.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.float32(head_dim))
    qi = pl.program_id(1)
    q_start = qi * q_block

    nkv = seq // kv_block

    def body(j, carry):
        acc, m, l = carry
        k = jax.lax.dynamic_slice(k_ref[0], (j * kv_block, 0), (kv_block, head_dim))
        v = jax.lax.dynamic_slice(v_ref[0], (j * kv_block, 0), (kv_block, head_dim))
        s = (q @ k.T) * scale  # (q_block, kv_block)
        if causal:
            q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 0)
            k_pos = j * kv_block + jax.lax.broadcasted_iota(
                jnp.int32, (q_block, kv_block), 1
            )
            s = jnp.where(q_pos >= k_pos, s, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + p @ v
        return acc_new, m_new, l_new

    acc0 = jnp.zeros((q_block, head_dim), jnp.float32)
    m0 = jnp.full((q_block,), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((q_block,), jnp.float32)
    acc, _, l = jax.lax.fori_loop(0, nkv, body, (acc0, m0, l0))
    # Fully-masked rows cannot occur for causal (diagonal always visible),
    # but guard the division anyway.
    o_ref[0] = acc / jnp.maximum(l, 1e-30)[:, None]


@functools.partial(jax.jit, static_argnums=(3, 4, 5))
def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    q_block: int = DEFAULT_Q_BLOCK,
    kv_block: int = DEFAULT_KV_BLOCK,
) -> jax.Array:
    """Fused attention over (heads, seq, head_dim) tensors."""
    heads, seq, head_dim = q.shape
    q_block = min(q_block, seq)
    kv_block = min(kv_block, seq)
    if seq % q_block or seq % kv_block:
        raise ValueError(f"seq {seq} not divisible by blocks {q_block}/{kv_block}")
    grid = (heads, seq // q_block)
    kernel = functools.partial(_attn_kernel, kv_block=kv_block, causal=causal)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q_block, head_dim), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, seq, head_dim), lambda h, i: (h, 0, 0)),
            pl.BlockSpec((1, seq, head_dim), lambda h, i: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, head_dim), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((heads, seq, head_dim), jnp.float32),
        interpret=True,
    )(q, k, v)
