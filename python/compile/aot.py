"""AOT lowering: JAX (L2) + Pallas (L1) -> HLO *text* artifacts for the
Rust runtime.

HLO text (NOT `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProtos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published `xla` crate) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Python runs ONLY here (`make artifacts`); the Rust binary is self-contained
afterwards.

Usage:
    python -m compile.aot --out-dir ../artifacts [--configs tiny,mini,...]
"""

from __future__ import annotations

import argparse
import functools
import hashlib
import json
import math
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M
from compile.kernels import attention as A
from compile.kernels import quant as Q

QUANT_N = 65536  # element count baked into the exported quant graphs
QUANT_BLOCK = Q.DEFAULT_BLOCK
ATTN_SHAPE = (4, 128, 32)  # (heads, seq, head_dim) for the fused-attn artifact


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _write(out_dir: str, fname: str, text: str) -> str:
    path = os.path.join(out_dir, fname)
    with open(path, "w") as f:
        f.write(text)
    return fname


def lower_model(cfg: M.ModelConfig, out_dir: str) -> dict:
    n = M.n_params(cfg)
    tok = jax.ShapeDtypeStruct((cfg.mbs, cfg.seq), jnp.int32)
    flat = jax.ShapeDtypeStruct((n,), jnp.float32)
    seed = jax.ShapeDtypeStruct((), jnp.int32)

    init = jax.jit(functools.partial(M.init_params, cfg=cfg)).lower(seed)
    train = jax.jit(functools.partial(M.train_step, cfg=cfg)).lower(flat, tok, tok)
    evalf = jax.jit(functools.partial(M.loss_fn, cfg=cfg)).lower(flat, tok, tok)

    artifacts = {
        "init": _write(out_dir, f"init_{cfg.name}.hlo.txt", to_hlo_text(init)),
        "train_step": _write(out_dir, f"train_{cfg.name}.hlo.txt", to_hlo_text(train)),
        "eval_loss": _write(out_dir, f"eval_{cfg.name}.hlo.txt", to_hlo_text(evalf)),
    }

    params, off = [], 0
    for name, shape in M.param_specs(cfg):
        size = math.prod(shape)
        params.append({"name": name, "shape": list(shape), "offset": off, "size": size})
        off += size

    return {
        "name": cfg.name,
        "d_model": cfg.d_model,
        "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads,
        "vocab": cfg.vocab,
        "seq": cfg.seq,
        "mbs": cfg.mbs,
        "n_params": n,
        "tied_lm_head": True,
        "flops_per_token_fwd": M.flops_per_token(cfg, fwd_only=True),
        "flops_per_token": M.flops_per_token(cfg),
        "params": params,
        "artifacts": artifacts,
    }


def lower_quant(out_dir: str) -> dict:
    """Export the L1 Pallas quantizers as standalone graphs.

    The Rust comm path uses a native bit-exact port for speed; these
    artifacts exist so integration tests can assert native == Pallas via
    PJRT (rust/tests/pjrt_quant.rs).
    """
    x = jax.ShapeDtypeStruct((QUANT_N,), jnp.float32)
    q8 = jax.ShapeDtypeStruct((QUANT_N,), jnp.int8)
    p4 = jax.ShapeDtypeStruct((QUANT_N // 2,), jnp.uint8)
    s = jax.ShapeDtypeStruct((QUANT_N // QUANT_BLOCK,), jnp.float32)

    arts = {
        "quant_int8": _write(
            out_dir,
            "quant_int8.hlo.txt",
            to_hlo_text(jax.jit(lambda v: Q.quantize_int8(v, QUANT_BLOCK)).lower(x)),
        ),
        "dequant_int8": _write(
            out_dir,
            "dequant_int8.hlo.txt",
            to_hlo_text(
                jax.jit(lambda q, sc: Q.dequantize_int8(q, sc, QUANT_BLOCK)).lower(q8, s)
            ),
        ),
        "quant_int4": _write(
            out_dir,
            "quant_int4.hlo.txt",
            to_hlo_text(jax.jit(lambda v: Q.quantize_int4(v, QUANT_BLOCK)).lower(x)),
        ),
        "dequant_int4": _write(
            out_dir,
            "dequant_int4.hlo.txt",
            to_hlo_text(
                jax.jit(lambda p, sc: Q.dequantize_int4(p, sc, QUANT_BLOCK)).lower(p4, s)
            ),
        ),
        "roundtrip_int8": _write(
            out_dir,
            "roundtrip_int8.hlo.txt",
            to_hlo_text(jax.jit(lambda v: Q.roundtrip_int8(v, QUANT_BLOCK)).lower(x)),
        ),
        "roundtrip_int4": _write(
            out_dir,
            "roundtrip_int4.hlo.txt",
            to_hlo_text(jax.jit(lambda v: Q.roundtrip_int4(v, QUANT_BLOCK)).lower(x)),
        ),
    }
    return {"n": QUANT_N, "block": QUANT_BLOCK, "artifacts": arts}


def lower_attention(out_dir: str) -> dict:
    h, s, hd = ATTN_SHAPE
    t = jax.ShapeDtypeStruct(ATTN_SHAPE, jnp.float32)
    art = _write(
        out_dir,
        "attn_fused.hlo.txt",
        to_hlo_text(jax.jit(lambda q, k, v: A.attention(q, k, v)).lower(t, t, t)),
    )
    return {"heads": h, "seq": s, "head_dim": hd, "artifacts": {"attn_fused": art}}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--configs",
        default="tiny,mini,loss10b_proxy,loss20b_proxy,e2e",
        help="comma-separated preset names from model.PRESETS",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"quant": lower_quant(args.out_dir), "attention": lower_attention(args.out_dir), "models": {}}
    for name in args.configs.split(","):
        cfg = M.PRESETS[name.strip()]
        print(f"lowering {cfg.name}: n_params={M.n_params(cfg):,}")
        manifest["models"][cfg.name] = lower_model(cfg, args.out_dir)

    blob = json.dumps(manifest, indent=1, sort_keys=True)
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        f.write(blob)
    print(
        f"wrote {len(manifest['models'])} model configs + quant/attn artifacts; "
        f"manifest sha256={hashlib.sha256(blob.encode()).hexdigest()[:12]}"
    )


if __name__ == "__main__":
    main()
