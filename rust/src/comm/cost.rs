//! α–β (latency–bandwidth) cost model for collectives over the simulated
//! cluster, with a per-link-class time/byte ledger.
//!
//! Collective timing formulas (Thakur et al.; Chan et al.) at the
//! bottleneck link class of the participating group:
//!
//! * ring all-gather / reduce-scatter over d ranks, V wire bytes total:
//!   `T = (d-1)·α + ((d-1)/d)·V / B_eff`
//! * 1-hop all-to-all (ZeRO++ quantized reduce-scatter):
//!   `T = α + ((d-1)/d)·V / B_eff`
//! * ring all-reduce: `T = 2(d-1)·α + 2((d-1)/d)·V / B_eff`
//! * tree broadcast: `T = ⌈log2 d⌉·α + V / B_eff`
//!
//! `B_eff` accounts for NIC sharing: when the group crosses nodes, every
//! rank of the same node funnels through the node's Slingshot ports, so
//! the per-rank bandwidth is `B_node / ranks_per_node_in_group`
//! (DESIGN.md §4).

use std::collections::BTreeMap;

use crate::topology::{Cluster, LinkClass};

/// Collective kinds for the ledger.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Coll {
    AllGather,
    ReduceScatter,
    AllToAll,
    AllReduce,
    Broadcast,
}

impl Coll {
    pub fn name(&self) -> &'static str {
        match self {
            Coll::AllGather => "all-gather",
            Coll::ReduceScatter => "reduce-scatter",
            Coll::AllToAll => "all-to-all",
            Coll::AllReduce => "all-reduce",
            Coll::Broadcast => "broadcast",
        }
    }
}

/// Accumulated traffic/time per (collective, link class).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LedgerEntry {
    pub calls: u64,
    pub wire_bytes: u64,
    pub seconds: f64,
}

/// Collective-library efficiency model layered on the raw link specs.
///
/// The α–β model with nominal link bandwidths is the *optimistic* bound; a
/// real collective library (RCCL on Slingshot — the paper's own Discussion
/// blames "expensive inter-node collective communication via RCCL") adds:
///
/// * `inter_efficiency` — achievable fraction of nominal NIC bandwidth,
/// * `group_penalty_beta` — algorithmic degradation with group size,
///   `B /= (1 + β·log2(d))` (ring pipelining, tree imbalance, dragonfly
///   congestion all grow with participant count),
/// * `a2a_inter_efficiency` — extra derate for inter-node all-to-all
///   (bisection-heavy; the worst pattern on a dragonfly).
///
/// Defaults are the *ideal* model (1, 0, 1). [`CommEfficiency::rccl_frontier`]
/// carries the values calibrated against the paper's own measured ratios
/// (EXPERIMENTS.md §Calibration).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CommEfficiency {
    pub inter_efficiency: f64,
    pub group_penalty_beta: f64,
    pub a2a_inter_efficiency: f64,
}

impl Default for CommEfficiency {
    fn default() -> Self {
        CommEfficiency { inter_efficiency: 1.0, group_penalty_beta: 0.0, a2a_inter_efficiency: 1.0 }
    }
}

impl CommEfficiency {
    /// Calibrated against the paper's measured 20B/384-GCD ratios
    /// (+40.5% ZeRO++ vs ZeRO-3, +70.7% topo vs ZeRO++, 0.94 scaling
    /// efficiency) under the event-driven step scheduler
    /// ([`crate::sched`]) — see EXPERIMENTS.md §Calibration for the fit.
    pub fn rccl_frontier() -> Self {
        CommEfficiency {
            inter_efficiency: 1.0,
            group_penalty_beta: 0.04,
            a2a_inter_efficiency: 0.13,
        }
    }
}

/// The cost model: resolves groups to link classes, computes simulated
/// time, and records everything in a ledger.
#[derive(Debug, Clone)]
pub struct CostModel {
    pub cluster: Cluster,
    pub efficiency: CommEfficiency,
    ledger: BTreeMap<(Coll, LinkClass), LedgerEntry>,
    total_seconds: f64,
}

impl CostModel {
    pub fn new(cluster: Cluster) -> Self {
        CostModel {
            cluster,
            efficiency: CommEfficiency::default(),
            ledger: BTreeMap::new(),
            total_seconds: 0.0,
        }
    }

    pub fn with_efficiency(cluster: Cluster, efficiency: CommEfficiency) -> Self {
        CostModel { cluster, efficiency, ledger: BTreeMap::new(), total_seconds: 0.0 }
    }

    /// Effective per-rank bandwidth for a group at its bottleneck class.
    pub fn effective_bandwidth(&self, group: &[usize]) -> (LinkClass, f64) {
        let class = self.cluster.bottleneck_class(group);
        let spec = self.cluster.link_spec(class);
        let b = if class == LinkClass::InterNode {
            // NIC sharing: B_node split across this group's ranks per node.
            let mut per_node: BTreeMap<usize, usize> = BTreeMap::new();
            for &r in group {
                *per_node.entry(self.cluster.node_of(r)).or_default() += 1;
            }
            let max_per_node = per_node.values().copied().max().unwrap_or(1) as f64;
            let penalty =
                1.0 + self.efficiency.group_penalty_beta * (group.len().max(2) as f64).log2();
            spec.bandwidth * self.efficiency.inter_efficiency / max_per_node / penalty
        } else {
            spec.bandwidth
        };
        (class, b)
    }

    fn charge(&mut self, coll: Coll, class: LinkClass, wire_bytes: u64, seconds: f64) -> f64 {
        let e = self.ledger.entry((coll, class)).or_default();
        e.calls += 1;
        e.wire_bytes += wire_bytes;
        e.seconds += seconds;
        self.total_seconds += seconds;
        seconds
    }

    // -- pure time queries (no ledger mutation) --------------------------
    //
    // The step scheduler (`sched::plan::StepPlan`) derives task durations
    // from these, so simulator and engine price a collective identically
    // whether or not it is charged to the ledger. Each `priced_*` helper
    // resolves the group's bottleneck class exactly once (the O(d²)
    // pairwise scan) and returns it alongside the time.

    /// Ring all-gather time + the link class it occupies (one scan).
    pub fn priced_all_gather(&self, group: &[usize], wire_bytes: u64) -> (f64, LinkClass) {
        let d = group.len() as f64;
        if d <= 1.0 {
            return (0.0, LinkClass::Local);
        }
        let (class, b) = self.effective_bandwidth(group);
        let alpha = self.cluster.link_spec(class).latency;
        ((d - 1.0) * alpha + ((d - 1.0) / d) * wire_bytes as f64 / b, class)
    }

    /// 1-hop all-to-all time + link class (one scan).
    pub fn priced_all_to_all(&self, group: &[usize], wire_bytes: u64) -> (f64, LinkClass) {
        let d = group.len() as f64;
        if d <= 1.0 {
            return (0.0, LinkClass::Local);
        }
        let (class, mut b) = self.effective_bandwidth(group);
        if class == LinkClass::InterNode {
            b *= self.efficiency.a2a_inter_efficiency;
        }
        let alpha = self.cluster.link_spec(class).latency;
        (alpha + ((d - 1.0) / d) * wire_bytes as f64 / b, class)
    }

    /// Ring all-reduce time + link class (one scan).
    pub fn priced_all_reduce(&self, group: &[usize], wire_bytes: u64) -> (f64, LinkClass) {
        let d = group.len() as f64;
        if d <= 1.0 {
            return (0.0, LinkClass::Local);
        }
        let (class, b) = self.effective_bandwidth(group);
        let alpha = self.cluster.link_spec(class).latency;
        (2.0 * (d - 1.0) * alpha + 2.0 * ((d - 1.0) / d) * wire_bytes as f64 / b, class)
    }

    /// Point-to-point transfer time between two ranks + the link class it
    /// crosses — pipeline stage-boundary activation/gradient shipments
    /// (`sched::pipeline`). Inter-node transfers share the node's NIC with
    /// the `workers_per_node - 1` peers shipping their own boundary
    /// traffic concurrently (every DP rank of a stage sends at once) and
    /// pay the library `inter_efficiency`; intra-node links are dedicated.
    pub fn priced_p2p(&self, a: usize, b: usize, wire_bytes: u64) -> (f64, LinkClass) {
        let class = self.cluster.link_between(a, b);
        if class == LinkClass::Local {
            return (0.0, LinkClass::Local);
        }
        let spec = self.cluster.link_spec(class);
        let bw = if class == LinkClass::InterNode {
            spec.bandwidth * self.efficiency.inter_efficiency
                / self.cluster.workers_per_node() as f64
        } else {
            spec.bandwidth
        };
        (spec.latency + wire_bytes as f64 / bw, class)
    }

    /// Tree-broadcast time + link class (one scan).
    pub fn priced_broadcast(&self, group: &[usize], wire_bytes: u64) -> (f64, LinkClass) {
        let d = group.len() as f64;
        if d <= 1.0 {
            return (0.0, LinkClass::Local);
        }
        let (class, b) = self.effective_bandwidth(group);
        let alpha = self.cluster.link_spec(class).latency;
        ((d.log2().ceil()) * alpha + wire_bytes as f64 / b, class)
    }

    /// Ring all-gather time: `V` is the full (post-gather) wire-payload size.
    pub fn all_gather_time(&self, group: &[usize], wire_bytes: u64) -> f64 {
        self.priced_all_gather(group, wire_bytes).0
    }

    /// Ring reduce-scatter time: `V` = full contribution size per rank
    /// (same ring pattern as the all-gather, reversed).
    pub fn reduce_scatter_time(&self, group: &[usize], wire_bytes: u64) -> f64 {
        self.priced_all_gather(group, wire_bytes).0
    }

    /// 1-hop all-to-all time. Inter-node all-to-all additionally pays
    /// `a2a_inter_efficiency` (bisection-heavy — see [`CommEfficiency`]).
    pub fn all_to_all_time(&self, group: &[usize], wire_bytes: u64) -> f64 {
        self.priced_all_to_all(group, wire_bytes).0
    }

    /// Ring all-reduce time.
    pub fn all_reduce_time(&self, group: &[usize], wire_bytes: u64) -> f64 {
        self.priced_all_reduce(group, wire_bytes).0
    }

    /// Tree broadcast time.
    pub fn broadcast_time(&self, group: &[usize], wire_bytes: u64) -> f64 {
        self.priced_broadcast(group, wire_bytes).0
    }

    // -- charging variants (time query + ledger entry) -------------------

    /// Ring all-gather: `V` is the full (post-gather) wire-payload size.
    pub fn all_gather(&mut self, group: &[usize], wire_bytes: u64) -> f64 {
        if group.len() <= 1 {
            return 0.0;
        }
        let (t, class) = self.priced_all_gather(group, wire_bytes);
        self.charge(Coll::AllGather, class, wire_bytes, t)
    }

    /// Ring reduce-scatter: `V` = full contribution size per rank (wire).
    pub fn reduce_scatter(&mut self, group: &[usize], wire_bytes: u64) -> f64 {
        if group.len() <= 1 {
            return 0.0;
        }
        let (t, class) = self.priced_all_gather(group, wire_bytes);
        self.charge(Coll::ReduceScatter, class, wire_bytes, t)
    }

    /// 1-hop all-to-all (the ZeRO++ quantized reduce-scatter transport).
    pub fn all_to_all(&mut self, group: &[usize], wire_bytes: u64) -> f64 {
        if group.len() <= 1 {
            return 0.0;
        }
        let (t, class) = self.priced_all_to_all(group, wire_bytes);
        self.charge(Coll::AllToAll, class, wire_bytes, t)
    }

    /// Ring all-reduce.
    pub fn all_reduce(&mut self, group: &[usize], wire_bytes: u64) -> f64 {
        if group.len() <= 1 {
            return 0.0;
        }
        let (t, class) = self.priced_all_reduce(group, wire_bytes);
        self.charge(Coll::AllReduce, class, wire_bytes, t)
    }

    /// Tree broadcast.
    pub fn broadcast(&mut self, group: &[usize], wire_bytes: u64) -> f64 {
        if group.len() <= 1 {
            return 0.0;
        }
        let (t, class) = self.priced_broadcast(group, wire_bytes);
        self.charge(Coll::Broadcast, class, wire_bytes, t)
    }

    pub fn total_seconds(&self) -> f64 {
        self.total_seconds
    }

    pub fn entries(&self) -> impl Iterator<Item = (&(Coll, LinkClass), &LedgerEntry)> {
        self.ledger.iter()
    }

    pub fn entry(&self, coll: Coll, class: LinkClass) -> LedgerEntry {
        self.ledger.get(&(coll, class)).copied().unwrap_or_default()
    }

    /// Total wire bytes that crossed node boundaries (the paper's key
    /// optimization target).
    pub fn inter_node_bytes(&self) -> u64 {
        self.ledger
            .iter()
            .filter(|((_, c), _)| *c == LinkClass::InterNode)
            .map(|(_, e)| e.wire_bytes)
            .sum()
    }

    pub fn reset(&mut self) {
        self.ledger.clear();
        self.total_seconds = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cm(nodes: usize) -> CostModel {
        CostModel::new(Cluster::frontier(nodes))
    }

    #[test]
    fn gcd_pair_is_fastest_path() {
        let mut m = cm(2);
        let v = 1_000_000_000u64; // 1 GB wire
        let t_pair = m.all_gather(&[0, 1], v);
        let t_node = m.all_gather(&[0, 1, 2, 3, 4, 5, 6, 7], v);
        let t_world = m.all_gather(&(0..16).collect::<Vec<_>>(), v);
        assert!(t_pair < t_node && t_node < t_world, "{t_pair} {t_node} {t_world}");
    }

    #[test]
    fn inter_node_shares_nic() {
        let mut m = cm(2);
        // only 1 rank per node participating -> full 100 GB/s
        let (_, b1) = m.effective_bandwidth(&[0, 8]);
        assert_eq!(b1, 100e9);
        // all 8 ranks of each node participating -> 12.5 GB/s per rank
        let (_, b8) = m.effective_bandwidth(&(0..16).collect::<Vec<_>>());
        assert_eq!(b8, 100e9 / 8.0);
        let _ = m.all_gather(&[0, 8], 1000);
    }

    #[test]
    fn ring_formula_exact() {
        let mut m = cm(1);
        // group = one node (8 ranks), bottleneck = IntraCross (50 GB/s, 3 µs)
        let v = 800_000_000u64;
        let t = m.all_gather(&(0..8).collect::<Vec<_>>(), v);
        let expect = 7.0 * 3e-6 + (7.0 / 8.0) * 8e8 / 50e9;
        assert!((t - expect).abs() < 1e-12, "{t} vs {expect}");
    }

    #[test]
    fn alltoall_has_single_alpha() {
        let mut m = cm(1);
        let group: Vec<usize> = (0..8).collect();
        let t_a2a = m.all_to_all(&group, 1000);
        let t_ring = m.reduce_scatter(&group, 1000);
        assert!(t_a2a < t_ring); // fewer latency terms
    }

    #[test]
    fn allreduce_is_two_phases() {
        let mut m = cm(1);
        let group: Vec<usize> = (0..8).collect();
        let v = 1_000_000u64;
        let t_ar = m.all_reduce(&group, v);
        let t_rs = m.reduce_scatter(&group, v);
        let t_ag = m.all_gather(&group, v);
        assert!((t_ar - (t_rs + t_ag)).abs() < 1e-12);
    }

    #[test]
    fn singleton_groups_are_free() {
        let mut m = cm(1);
        assert_eq!(m.all_gather(&[3], 1_000_000), 0.0);
        assert_eq!(m.all_reduce(&[3], 1_000_000), 0.0);
        assert_eq!(m.total_seconds(), 0.0);
    }

    #[test]
    fn ledger_accumulates() {
        let mut m = cm(2);
        m.all_gather(&[0, 1], 100);
        m.all_gather(&[0, 1], 200);
        m.all_reduce(&(0..16).collect::<Vec<_>>(), 500);
        let e = m.entry(Coll::AllGather, LinkClass::Intra(0));
        assert_eq!(e.calls, 2);
        assert_eq!(e.wire_bytes, 300);
        assert_eq!(m.inter_node_bytes(), 500);
        assert!(m.total_seconds() > 0.0);
        m.reset();
        assert_eq!(m.total_seconds(), 0.0);
        assert_eq!(m.inter_node_bytes(), 0);
    }

    #[test]
    fn pure_time_queries_match_charged_times() {
        let mut m = CostModel::with_efficiency(Cluster::frontier(2), CommEfficiency::rccl_frontier());
        let g: Vec<usize> = (0..16).collect();
        let v = 123_456_789u64;
        assert_eq!(m.all_gather_time(&g, v), m.all_gather(&g, v));
        assert_eq!(m.reduce_scatter_time(&g, v), m.reduce_scatter(&g, v));
        assert_eq!(m.all_to_all_time(&g, v), m.all_to_all(&g, v));
        assert_eq!(m.all_reduce_time(&g, v), m.all_reduce(&g, v));
        assert_eq!(m.broadcast_time(&g, v), m.broadcast(&g, v));
        // queries never touch the ledger
        let before = m.total_seconds();
        let _ = m.all_gather_time(&g, v);
        assert_eq!(m.total_seconds(), before);
    }

    #[test]
    fn p2p_prices_the_crossed_link() {
        let m = cm(2);
        // GCD pair: dedicated 200 GB/s intra link
        let (t, class) = m.priced_p2p(0, 1, 200_000_000);
        assert_eq!(class, LinkClass::Intra(0));
        assert!((t - (2e-6 + 0.2e9 / 200e9)).abs() < 1e-15, "{t}");
        // cross-node: NIC shared by the node's 8 concurrent senders
        let (t, class) = m.priced_p2p(0, 8, 100_000_000);
        assert_eq!(class, LinkClass::InterNode);
        assert!((t - (10e-6 + 0.1e9 / (100e9 / 8.0))).abs() < 1e-15, "{t}");
        // same rank: free
        assert_eq!(m.priced_p2p(3, 3, 1_000_000), (0.0, LinkClass::Local));
    }

    #[test]
    fn quantization_halves_wire_time() {
        // Same collective, half the wire bytes -> strictly less time, and
        // the bandwidth term exactly halves.
        let mut m = cm(2);
        let g: Vec<usize> = (0..16).collect();
        let t_full = m.all_gather(&g, 2_000_000_000);
        let t_half = m.all_gather(&g, 1_000_000_000);
        let d = 16.0;
        let alpha_terms = (d - 1.0) * 10e-6;
        assert!(
            ((t_full - alpha_terms) / (t_half - alpha_terms) - 2.0).abs() < 1e-9,
            "{t_full} {t_half}"
        );
    }
}
