//! The collective-communication engine over the simulated cluster.
//!
//! Collectives here do BOTH jobs the reproduction needs (DESIGN.md §1):
//!
//! 1. **Real data movement** between per-rank host buffers, with the exact
//!    wire transformation the paper's stack applies (fp16 rounding, INT8 /
//!    INT4 block quantization) — so the training loss carries genuine
//!    quantization error (Figs 9/10).
//! 2. **Simulated time** via the α–β [`cost::CostModel`] at the bottleneck
//!    link class of the group — so throughput scaling is faithful
//!    (Figs 7/8, Tables VII/VIII).
//!
//! Two reduce-scatter transports are provided: the conventional **ring**
//! (wire-rounds on every hop — quantization error accumulates (d-1) times)
//! and the ZeRO++ **1-hop all-to-all** (exactly one quantize→dequantize per
//! payload; the design the paper adopts to bound error).

pub mod cost;

use crate::dtype::round_f16_slice;
use crate::quant::{self, padded_len};
use crate::topology::Cluster;
pub use cost::{Coll, CostModel, LedgerEntry};

/// Wire format of a collective payload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Wire {
    F32,
    F16,
    Int8 { block: usize },
    Int4 { block: usize },
}

impl Wire {
    /// Apply the wire transformation in place (what one hop does to the
    /// payload) and return the wire size in bytes.
    pub fn apply(&self, data: &mut Vec<f32>) -> usize {
        let n = data.len();
        match *self {
            Wire::F32 => 4 * n,
            Wire::F16 => {
                round_f16_slice(data);
                2 * n
            }
            Wire::Int8 { block } => {
                let padded = padded_len(n, block);
                data.resize(padded, 0.0);
                let q = quant::quantize_int8(data, block);
                quant::dequantize_int8_into(&q, data);
                data.truncate(n);
                n + 4 * n.div_ceil(block)
            }
            Wire::Int4 { block } => {
                let padded = padded_len(n, block);
                data.resize(padded, 0.0);
                let q = quant::quantize_int4(data, block);
                quant::dequantize_int4_into(&q, data);
                data.truncate(n);
                n.div_ceil(2) + 4 * n.div_ceil(block)
            }
        }
    }

    /// Wire bytes for `n` elements without touching data.
    pub fn wire_bytes(&self, n: usize) -> usize {
        match *self {
            Wire::F32 => 4 * n,
            Wire::F16 => 2 * n,
            Wire::Int8 { block } => n + 4 * n.div_ceil(block),
            Wire::Int4 { block } => n.div_ceil(2) + 4 * n.div_ceil(block),
        }
    }
}

/// The communication world: one per training run.
#[derive(Debug, Clone)]
pub struct CommWorld {
    pub cost: CostModel,
}

impl CommWorld {
    pub fn new(cluster: Cluster) -> Self {
        CommWorld { cost: CostModel::new(cluster) }
    }

    pub fn cluster(&self) -> &Cluster {
        &self.cost.cluster
    }

    /// All-gather: every rank in `group` contributes one equal-length
    /// shard (in group order); all ranks receive the concatenation.
    ///
    /// Each shard crosses the wire once (quantized by the sender,
    /// dequantized by receivers), so the result is identical on every rank
    /// and returned as a single buffer.
    pub fn all_gather(&mut self, group: &[usize], shards: &[&[f32]], wire: Wire) -> Vec<f32> {
        assert_eq!(group.len(), shards.len(), "one shard per group rank");
        let shard_len = shards.first().map_or(0, |s| s.len());
        assert!(shards.iter().all(|s| s.len() == shard_len), "equal shard lengths");
        let mut out = Vec::with_capacity(shard_len * shards.len());
        let mut total_wire = 0usize;
        for s in shards {
            if group.len() == 1 {
                out.extend_from_slice(s);
                continue;
            }
            let mut payload = s.to_vec();
            total_wire += wire.apply(&mut payload);
            out.extend_from_slice(&payload);
        }
        self.cost.all_gather(group, total_wire as u64);
        out
    }

    /// Ring reduce-scatter: rank `j` of the group receives the sum of all
    /// ranks' `j`-th shard. Contributions must have equal lengths divisible
    /// by the group size.
    ///
    /// The ring accumulates hop by hop, applying the wire transformation
    /// after EVERY partial sum — the (d-1)-fold error accumulation that
    /// motivates ZeRO++'s all-to-all variant.
    pub fn reduce_scatter_ring(
        &mut self,
        group: &[usize],
        contributions: &[&[f32]],
        wire: Wire,
    ) -> Vec<Vec<f32>> {
        let d = group.len();
        assert_eq!(d, contributions.len());
        let n = contributions[0].len();
        assert!(contributions.iter().all(|c| c.len() == n));
        assert!(n % d == 0, "contribution length {n} not divisible by group {d}");
        let shard = n / d;
        let mut out = Vec::with_capacity(d);
        for j in 0..d {
            // shard j starts at rank (j+1) mod d and travels the ring,
            // ending at rank j: acc = c_{j+1}; then +c_{j+2} ... +c_j, with
            // a wire round after each transfer.
            let mut acc = contributions[(j + 1) % d][j * shard..(j + 1) * shard].to_vec();
            for hop in 2..=d {
                wire.apply(&mut acc);
                let src = contributions[(j + hop) % d];
                for (a, &b) in acc.iter_mut().zip(&src[j * shard..(j + 1) * shard]) {
                    *a += b;
                }
            }
            out.push(acc);
        }
        if d > 1 {
            self.cost.reduce_scatter(group, wire.wire_bytes(n) as u64);
        }
        out
    }

    /// ZeRO++ 1-hop all-to-all reduce-scatter: each rank quantizes its d
    /// sub-shards once, sends sub-shard j to rank j, receivers dequantize
    /// and reduce. Exactly ONE wire round per payload.
    pub fn reduce_scatter_a2a(
        &mut self,
        group: &[usize],
        contributions: &[&[f32]],
        wire: Wire,
    ) -> Vec<Vec<f32>> {
        let d = group.len();
        assert_eq!(d, contributions.len());
        let n = contributions[0].len();
        assert!(contributions.iter().all(|c| c.len() == n));
        assert!(n % d == 0, "contribution length {n} not divisible by group {d}");
        let shard = n / d;
        let mut out = vec![vec![0f32; shard]; d];
        for (i, c) in contributions.iter().enumerate() {
            for j in 0..d {
                let mut payload = c[j * shard..(j + 1) * shard].to_vec();
                if i != j {
                    // local contribution needs no wire
                    wire.apply(&mut payload);
                }
                for (o, &v) in out[j].iter_mut().zip(&payload) {
                    *o += v;
                }
            }
        }
        if d > 1 {
            self.cost.all_to_all(group, wire.wire_bytes(n) as u64);
        }
        out
    }

    /// All-reduce = ring reduce-scatter + ring all-gather (both charged).
    /// Every rank receives the identical reduced buffer.
    pub fn all_reduce(&mut self, group: &[usize], contributions: &[&[f32]], wire: Wire) -> Vec<f32> {
        let d = group.len();
        if d == 1 {
            return contributions[0].to_vec();
        }
        let n = contributions[0].len();
        // pad to a multiple of d for the scatter phase
        let padded = n.div_ceil(d) * d;
        let owned: Vec<Vec<f32>> = contributions
            .iter()
            .map(|c| {
                let mut v = c.to_vec();
                v.resize(padded, 0.0);
                v
            })
            .collect();
        let views: Vec<&[f32]> = owned.iter().map(|v| v.as_slice()).collect();
        let shards = self.reduce_scatter_ring(group, &views, wire);
        let shard_views: Vec<&[f32]> = shards.iter().map(|s| s.as_slice()).collect();
        let mut full = self.all_gather(group, &shard_views, wire);
        full.truncate(n);
        full
    }

    /// Broadcast `buf` from the group's first rank to all (tree).
    pub fn broadcast(&mut self, group: &[usize], buf: &[f32], wire: Wire) -> Vec<f32> {
        let mut payload = buf.to_vec();
        if group.len() > 1 {
            let bytes = wire.apply(&mut payload);
            self.cost.broadcast(group, bytes as u64);
        }
        payload
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::check;
    use crate::util::rng::Rng;

    fn world(nodes: usize) -> CommWorld {
        CommWorld::new(Cluster::frontier(nodes))
    }

    fn randv(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        let mut v = vec![0.0; n];
        r.fill_normal(&mut v, 1.0);
        v
    }

    #[test]
    fn all_gather_f32_is_exact_concat() {
        let mut w = world(1);
        let a = randv(64, 1);
        let b = randv(64, 2);
        let out = w.all_gather(&[0, 1], &[&a, &b], Wire::F32);
        assert_eq!(out[..64], a[..]);
        assert_eq!(out[64..], b[..]);
    }

    #[test]
    fn all_gather_int8_error_bounded() {
        let mut w = world(1);
        let a = randv(512, 3);
        let b = randv(512, 4);
        let out = w.all_gather(&[0, 1], &[&a, &b], Wire::Int8 { block: 256 });
        let full: Vec<f32> = a.iter().chain(&b).copied().collect();
        let err = crate::util::stats::max_abs_err(&full, &out);
        assert!(err > 0.0 && err < 0.05, "{err}");
    }

    #[test]
    fn reduce_scatter_ring_f32_sums_exactly_for_integers() {
        let mut w = world(1);
        // integer-valued contributions: f32 sums are exact
        let c0: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let c1: Vec<f32> = (0..8).map(|i| (10 * i) as f32).collect();
        let out = w.reduce_scatter_ring(&[0, 1], &[&c0, &c1], Wire::F32);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0], vec![0.0, 11.0, 22.0, 33.0]);
        assert_eq!(out[1], vec![44.0, 55.0, 66.0, 77.0]);
    }

    #[test]
    fn reduce_scatter_a2a_matches_ring_on_f32() {
        check("a2a == ring on f32", 30, |g| {
            let d = *g.pick(&[2usize, 4, 8]);
            let shard = g.usize_in(1, 64);
            let contributions: Vec<Vec<f32>> =
                (0..d).map(|i| g.vec_f32_exact(d * shard, 1.0 + i as f32 * 0.0)).collect();
            let views: Vec<&[f32]> = contributions.iter().map(|v| v.as_slice()).collect();
            let mut w1 = world(1);
            let mut w2 = world(1);
            let group: Vec<usize> = (0..d).collect();
            let ring = w1.reduce_scatter_ring(&group, &views, Wire::F32);
            let a2a = w2.reduce_scatter_a2a(&group, &views, Wire::F32);
            for (r, a) in ring.iter().zip(&a2a) {
                for (x, y) in r.iter().zip(a) {
                    assert!((x - y).abs() <= 1e-4 * x.abs().max(1.0), "{x} vs {y}");
                }
            }
        });
    }

    #[test]
    fn a2a_quantized_beats_ring_quantized_on_error() {
        // The ZeRO++ design point: 1-hop a2a accumulates ~1 quant error,
        // the ring accumulates (d-1).
        let d = 8;
        let n = 2048;
        let contributions: Vec<Vec<f32>> = (0..d).map(|i| randv(n, 100 + i as u64)).collect();
        let views: Vec<&[f32]> = contributions.iter().map(|v| v.as_slice()).collect();
        let group: Vec<usize> = (0..d).collect();
        // exact reference
        let mut exact = vec![0f32; n];
        for c in &contributions {
            for (e, &v) in exact.iter_mut().zip(c) {
                *e += v;
            }
        }
        let wire = Wire::Int4 { block: 64 };
        let ring = world(1).reduce_scatter_ring(&group, &views, wire);
        let a2a = world(1).reduce_scatter_a2a(&group, &views, wire);
        let flat = |shards: Vec<Vec<f32>>| shards.concat();
        let e_ring = crate::util::stats::mae(&exact, &flat(ring));
        let e_a2a = crate::util::stats::mae(&exact, &flat(a2a));
        assert!(e_a2a < e_ring, "a2a {e_a2a} vs ring {e_ring}");
    }

    #[test]
    fn all_reduce_f32_close_to_exact_sum() {
        let mut w = world(1);
        let a = randv(100, 5);
        let b = randv(100, 6);
        let out = w.all_reduce(&[0, 1], &[&a, &b], Wire::F32);
        assert_eq!(out.len(), 100);
        for i in 0..100 {
            assert!((out[i] - (a[i] + b[i])).abs() < 1e-5);
        }
    }

    #[test]
    fn all_reduce_identical_across_conceptual_ranks() {
        // out is shared; property: it equals rs+ag of the same inputs
        let mut w = world(1);
        let a = randv(64, 7);
        let out1 = w.all_reduce(&[0, 1], &[&a, &a], Wire::F16);
        for (o, &x) in out1.iter().zip(&a) {
            assert!((o - 2.0 * x).abs() <= 2.0 * x.abs() * 0.01 + 1e-3);
        }
    }

    #[test]
    fn wire_f16_rounds() {
        let mut v = vec![1.0 + 2f32.powi(-13)];
        let bytes = Wire::F16.apply(&mut v);
        assert_eq!(bytes, 2);
        assert_eq!(v[0], 1.0);
    }

    #[test]
    fn wire_handles_unaligned_quant_lengths() {
        let mut v = randv(100, 8); // 100 not divisible by block
        let before = v.clone();
        let bytes = Wire::Int8 { block: 64 }.apply(&mut v);
        assert_eq!(v.len(), 100);
        assert_eq!(bytes, 100 + 4 * 2);
        assert!(crate::util::stats::max_abs_err(&before, &v) < 0.05);
    }

    #[test]
    fn cost_ledger_records_collectives() {
        let mut w = world(2);
        let a = randv(256, 9);
        let shards: Vec<&[f32]> = vec![&a; 16];
        let group: Vec<usize> = (0..16).collect();
        let _ = w.all_gather(&group, &shards, Wire::Int8 { block: 256 });
        assert!(w.cost.inter_node_bytes() > 0);
        let e = w.cost.entry(Coll::AllGather, crate::topology::LinkClass::InterNode);
        assert_eq!(e.calls, 1);
    }

    #[test]
    fn broadcast_roundtrip() {
        let mut w = world(1);
        let a = randv(128, 10);
        let out = w.broadcast(&[0, 1, 2, 3], &a, Wire::F32);
        assert_eq!(out, a);
    }

    #[test]
    fn prop_wire_bytes_agrees_with_apply() {
        // `wire_bytes(n)` must equal the byte count `apply` reports for
        // every wire format, including odd / block-unaligned lengths
        check("wire_bytes == apply bytes", 60, |g| {
            let n = g.usize_in(1, 3000);
            // blocks stay even (INT4 packs nibble pairs); lengths don't
            let block = *g.pick(&[8usize, 64, 100, 256]);
            for wire in [Wire::F32, Wire::F16, Wire::Int8 { block }, Wire::Int4 { block }] {
                let mut v = g.vec_f32_exact(n, 1.0);
                let applied = wire.apply(&mut v);
                assert_eq!(applied, wire.wire_bytes(n), "{wire:?} n={n}");
                assert_eq!(v.len(), n, "{wire:?} must preserve length");
                assert!(v.iter().all(|x| x.is_finite()));
            }
        });
    }

    #[test]
    fn prop_quantized_wire_is_idempotent() {
        // quantize -> dequantize -> quantize is a fixed point: the max-abs
        // element of each block maps to exactly ±Q, so the second pass
        // recovers the same scales and codes (and a second `apply` is a
        // bit-exact no-op)
        check("int8/int4 wire idempotent", 40, |g| {
            let n = g.usize_in(1, 2048);
            let block = *g.pick(&[32usize, 100, 256]);
            let v = g.vec_f32_exact(n, 2.0);
            let padded = crate::quant::padded_len(n, block);
            let mut x = v.clone();
            x.resize(padded, 0.0);

            let q1 = crate::quant::quantize_int8(&x, block);
            let d1 = crate::quant::dequantize_int8(&q1);
            let q2 = crate::quant::quantize_int8(&d1, block);
            assert_eq!(q1, q2, "INT8 requantization must be a fixed point");

            let p1 = crate::quant::quantize_int4(&x, block);
            let e1 = crate::quant::dequantize_int4(&p1);
            let p2 = crate::quant::quantize_int4(&e1, block);
            assert_eq!(p1, p2, "INT4 requantization must be a fixed point");

            // the same property through the Wire interface
            for wire in [Wire::Int8 { block }, Wire::Int4 { block }] {
                let mut once = v.clone();
                wire.apply(&mut once);
                let mut twice = once.clone();
                wire.apply(&mut twice);
                assert_eq!(once, twice, "{wire:?} second apply must be a no-op");
            }
        });
    }

    #[test]
    fn prop_all_gather_preserves_order_and_length() {
        check("all-gather layout", 40, |g| {
            let d = *g.pick(&[2usize, 4, 8]);
            let shard = g.usize_in(1, 128);
            let shards: Vec<Vec<f32>> = (0..d).map(|_| g.vec_f32_exact(shard, 1.0)).collect();
            let views: Vec<&[f32]> = shards.iter().map(|v| v.as_slice()).collect();
            let mut w = world(1);
            let group: Vec<usize> = (0..d).collect();
            let out = w.all_gather(&group, &views, Wire::F32);
            assert_eq!(out.len(), d * shard);
            for (i, s) in shards.iter().enumerate() {
                assert_eq!(&out[i * shard..(i + 1) * shard], s.as_slice());
            }
        });
    }
}
