//! # ZeRO-Topo
//!
//! Reproduction of *"Scaling Large Language Model Training on Frontier with
//! Low-Bandwidth Partitioning"* (CS.DC 2025): a 3-level topology-aware
//! hierarchical partitioning strategy (ZeRO-topo) on top of ZeRO++/ZeRO-3,
//! implemented as a Rust training coordinator over AOT-compiled JAX/Pallas
//! compute (PJRT CPU).
//!
//! The three levels map training state onto Frontier's bandwidth hierarchy:
//!
//! | state            | sharding factor           | bandwidth level        |
//! |------------------|---------------------------|------------------------|
//! | model weights    | 2 (GCD pair in a MI250X)  | `B_GCD` = 200 GB/s     |
//! | gradients        | 8 (GCDs of one node)      | `B_intra` 50–100 GB/s  |
//! | optimizer states | all GCDs (like ZeRO-3)    | `B_inter` = 100 GB/s   |
//!
//! plus ZeRO++-style block quantization on every collective (INT8 weight
//! all-gather, INT4 all-to-all gradient reduce-scatter) and INT8-quantized
//! secondary weight partitions.
//!
//! Layer map (see `DESIGN.md`; a module-by-module crate map with CLI
//! quickstarts lives in `rust/README.md`):
//! * L3 (this crate): coordinator, simulated Frontier cluster, collective
//!   engine with an α–β cost model, sharding planners, training engine,
//!   analytical performance simulator, and the discrete-event multi-stream
//!   step scheduler ([`sched`]) both clocks run on — including the
//!   pipeline-parallel 1F1B/interleaved schedules ([`sched::pipeline`]).
//! * L2 (`python/compile/model.py`): GPT-NeoX-style flat-parameter model,
//!   AOT-lowered to HLO text under `artifacts/`.
//! * L1 (`python/compile/kernels/`): Pallas block-quantization + fused
//!   attention kernels (interpret mode), bit-exact with [`quant`].

pub mod comm;
pub mod config;
pub mod data;
pub mod dtype;
// the documented public surface (ISSUEs 4 and 10): every public item in
// the engine, memory, metrics, scheduler, simulator, and topology-spec
// modules must carry rustdoc — `cargo doc` runs with
// RUSTDOCFLAGS="-D warnings" in CI, so a missing doc or broken
// intra-doc link fails the build
#[warn(missing_docs)]
pub mod engine;
#[warn(missing_docs)]
pub mod memory;
#[warn(missing_docs)]
pub mod metrics;
pub mod model;
pub mod optimizer;
pub mod quant;
pub mod report;
pub mod runtime;
#[warn(missing_docs)]
pub mod sched;
pub mod sharding;
#[warn(missing_docs)]
pub mod sim;
pub mod testing;
#[warn(missing_docs)]
pub mod topology;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
