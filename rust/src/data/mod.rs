//! Synthetic token corpus — the substitution for the paper's Pile (web
//! subset) stream (DESIGN.md §1).
//!
//! The generator produces a *learnable* sequence: a Zipfian unigram prior
//! blended with a first-order Markov structure (each token prefers a few
//! deterministic successors) plus noise. A model that learns the bigram
//! table drops well below the unigram entropy floor, so loss curves have
//! the familiar decaying shape and quantization-induced differences are
//! visible (Figs 9/10).

use crate::util::rng::Rng;

/// Deterministic synthetic corpus over `vocab` tokens.
#[derive(Debug, Clone)]
pub struct SyntheticCorpus {
    pub vocab: usize,
    zipf_cdf: Vec<f64>,
    /// `successor[t]` = preferred next tokens for t
    successor: Vec<[u32; 4]>,
    /// probability of following the Markov edge vs drawing from the prior
    pub markov_p: f64,
}

impl SyntheticCorpus {
    pub fn new(vocab: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0x5EED_C0DE);
        let zipf_cdf = Rng::zipf_table(vocab, 1.1);
        let successor = (0..vocab)
            .map(|_| {
                [
                    rng.below(vocab as u64) as u32,
                    rng.below(vocab as u64) as u32,
                    rng.below(vocab as u64) as u32,
                    rng.below(vocab as u64) as u32,
                ]
            })
            .collect();
        SyntheticCorpus { vocab, zipf_cdf, successor, markov_p: 0.75 }
    }

    /// Sample one document (token stream) of length `len`.
    pub fn document(&self, len: usize, rng: &mut Rng) -> Vec<i32> {
        let mut out = Vec::with_capacity(len);
        let mut prev = rng.zipf(&self.zipf_cdf) as u32;
        out.push(prev as i32);
        for _ in 1..len {
            let next = if rng.f64() < self.markov_p {
                self.successor[prev as usize][rng.below(4) as usize]
            } else {
                rng.zipf(&self.zipf_cdf) as u32
            };
            out.push(next as i32);
            prev = next;
        }
        out
    }
}

/// One microbatch: `tokens[i]` predicts `targets[i]`.
#[derive(Debug, Clone, PartialEq)]
pub struct Batch {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub mbs: usize,
    pub seq: usize,
}

/// Deterministic batch stream: each (rank, step, microbatch) triple maps to
/// an independent RNG stream, so data-parallel ranks see disjoint data and
/// any scheme comparison sees IDENTICAL data per step (critical for the
/// loss-curve comparison: only the wire format differs).
#[derive(Debug, Clone)]
pub struct BatchStream {
    corpus: SyntheticCorpus,
    pub mbs: usize,
    pub seq: usize,
    seed: u64,
}

impl BatchStream {
    pub fn new(corpus: SyntheticCorpus, mbs: usize, seq: usize, seed: u64) -> Self {
        BatchStream { corpus, mbs, seq, seed }
    }

    pub fn batch(&self, replica: usize, step: usize, micro: usize) -> Batch {
        let mut rng = Rng::new(
            self.seed
                ^ (replica as u64).wrapping_mul(0x9E3779B97F4A7C15)
                ^ (step as u64).wrapping_mul(0xBF58476D1CE4E5B9)
                ^ (micro as u64).wrapping_mul(0x94D049BB133111EB),
        );
        let mut tokens = Vec::with_capacity(self.mbs * self.seq);
        let mut targets = Vec::with_capacity(self.mbs * self.seq);
        for _ in 0..self.mbs {
            let doc = self.corpus.document(self.seq + 1, &mut rng);
            tokens.extend_from_slice(&doc[..self.seq]);
            targets.extend_from_slice(&doc[1..]);
        }
        Batch { tokens, targets, mbs: self.mbs, seq: self.seq }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_vocab_range() {
        let c = SyntheticCorpus::new(512, 1);
        let mut rng = Rng::new(2);
        let doc = c.document(4096, &mut rng);
        assert_eq!(doc.len(), 4096);
        assert!(doc.iter().all(|&t| (0..512).contains(&t)));
    }

    #[test]
    fn corpus_is_deterministic() {
        let c = SyntheticCorpus::new(256, 7);
        let a = c.document(100, &mut Rng::new(3));
        let b = c.document(100, &mut Rng::new(3));
        assert_eq!(a, b);
    }

    #[test]
    fn markov_structure_is_learnable() {
        // successors of a token should be concentrated: the empirical
        // bigram entropy must be far below the unigram entropy.
        let c = SyntheticCorpus::new(128, 9);
        let mut rng = Rng::new(11);
        let doc = c.document(200_000, &mut rng);
        let mut uni = vec![0f64; 128];
        let mut big = std::collections::HashMap::new();
        for w in doc.windows(2) {
            uni[w[0] as usize] += 1.0;
            *big.entry((w[0], w[1])).or_insert(0f64) += 1.0;
        }
        let n = (doc.len() - 1) as f64;
        let h_uni: f64 = uni.iter().filter(|&&c| c > 0.0).map(|&c| -(c / n) * (c / n).ln()).sum();
        let h_joint: f64 = big.values().map(|&c| -(c / n) * (c / n).ln()).sum();
        let h_cond = h_joint - h_uni;
        assert!(h_cond < 0.75 * h_uni, "H(next|prev)={h_cond:.3} H(uni)={h_uni:.3}");
    }

    #[test]
    fn batch_shapes_and_shift() {
        let s = BatchStream::new(SyntheticCorpus::new(256, 1), 2, 32, 5);
        let b = s.batch(0, 0, 0);
        assert_eq!(b.tokens.len(), 64);
        assert_eq!(b.targets.len(), 64);
        // within each row, targets are tokens shifted by one
        for row in 0..2 {
            let t = &b.tokens[row * 32..(row + 1) * 32];
            let y = &b.targets[row * 32..(row + 1) * 32];
            assert_eq!(&t[1..], &y[..31]);
        }
    }

    #[test]
    fn streams_disjoint_across_replicas_and_steps() {
        let s = BatchStream::new(SyntheticCorpus::new(256, 1), 1, 64, 5);
        let b00 = s.batch(0, 0, 0);
        let b10 = s.batch(1, 0, 0);
        let b01 = s.batch(0, 1, 0);
        assert_ne!(b00.tokens, b10.tokens);
        assert_ne!(b00.tokens, b01.tokens);
        // but deterministic
        assert_eq!(b00, s.batch(0, 0, 0));
    }
}
