//! Half-precision (IEEE f16 and bfloat16) conversions, bit-exact.
//!
//! The paper trains in fp16 mixed precision: primary weight partitions and
//! un-quantized wire payloads are fp16. The engine emulates this regime by
//! rounding f32 buffers through f16 at the same points the real stack
//! would (`round_f16_slice` on comm payloads and primary partitions).

/// f32 -> IEEE binary16 bits, round-to-nearest-even, with overflow to inf
/// and subnormal handling.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x7F_FFFF;

    if exp == 0xFF {
        // inf / nan
        return sign | 0x7C00 | if mant != 0 { 0x0200 } else { 0 };
    }
    // unbiased exponent
    let e = exp - 127 + 15;
    if e >= 0x1F {
        return sign | 0x7C00; // overflow -> inf
    }
    if e <= 0 {
        // subnormal or zero
        if e < -10 {
            return sign; // underflow to zero
        }
        // add implicit leading 1, shift right by (1 - e) + 13
        let m = mant | 0x80_0000;
        let shift = 14 - e; // bits to drop from 24-bit mantissa down to 10
        let half = 1u32 << (shift - 1);
        let rounded = m + half - 1 + ((m >> shift) & 1); // round-half-even
        return sign | (rounded >> shift) as u16;
    }
    // normal: round 23-bit mantissa to 10 bits, half-to-even
    let half = 0x0FFF + ((mant >> 13) & 1);
    let mant_r = mant + half;
    if mant_r & 0x80_0000 != 0 {
        // mantissa overflow -> bump exponent
        let e2 = e + 1;
        if e2 >= 0x1F {
            return sign | 0x7C00;
        }
        return sign | ((e2 as u16) << 10);
    }
    sign | ((e as u16) << 10) | (mant_r >> 13) as u16
}

/// IEEE binary16 bits -> f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = (h >> 10) & 0x1F;
    let mant = (h & 0x3FF) as u32;
    let bits = match (exp, mant) {
        (0, 0) => sign,
        (0, m) => {
            // subnormal: value = m * 2^-24; normalize m to set bit 10
            let mut e = 0i32; // shifts applied
            let mut m = m;
            while m & 0x400 == 0 {
                m <<= 1;
                e += 1;
            }
            m &= 0x3FF;
            // exponent: 2^(-15) * (m_norm/2^10) * 2^(1-e) ... net E = 113 - e
            sign | (((113 - e) as u32) << 23) | (m << 13)
        }
        (0x1F, 0) => sign | 0x7F80_0000,
        (0x1F, m) => sign | 0x7F80_0000 | (m << 13),
        (e, m) => sign | (((e as u32) + 127 - 15) << 23) | (m << 13),
    };
    f32::from_bits(bits)
}

/// Round an f32 through f16 precision (the mixed-precision emulation).
#[inline]
pub fn round_f16(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

/// f32 -> bfloat16 bits (round-to-nearest-even).
pub fn f32_to_bf16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040; // quiet
    }
    let round = 0x7FFF + ((bits >> 16) & 1);
    ((bits + round) >> 16) as u16
}

/// bfloat16 bits -> f32 (exact).
pub fn bf16_bits_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// Round an f32 through bf16 precision.
#[inline]
pub fn round_bf16(x: f32) -> f32 {
    bf16_bits_to_f32(f32_to_bf16_bits(x))
}

/// In-place f16 rounding of a slice (hot path: called on every fp16 wire
/// payload — kept branch-light; see EXPERIMENTS.md §Perf).
pub fn round_f16_slice(xs: &mut [f32]) {
    for x in xs.iter_mut() {
        *x = round_f16(*x);
    }
}

/// Wire sizes in bytes-per-element for the formats the engine ships.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireDtype {
    F32,
    F16,
    Bf16,
    Int8Block,
    Int4Block,
}

impl WireDtype {
    /// Payload bytes for `n` elements with quantization block `block`
    /// (scales are f32-per-block for the block formats).
    pub fn wire_bytes(&self, n: usize, block: usize) -> usize {
        match self {
            WireDtype::F32 => 4 * n,
            WireDtype::F16 | WireDtype::Bf16 => 2 * n,
            WireDtype::Int8Block => n + 4 * n.div_ceil(block),
            WireDtype::Int4Block => n.div_ceil(2) + 4 * n.div_ceil(block),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_small_integers_roundtrip() {
        for v in [-4.0f32, -1.0, -0.5, 0.0, 0.5, 1.0, 2.0, 1024.0] {
            assert_eq!(round_f16(v), v, "{v}");
            assert_eq!(round_bf16(v), v, "{v}");
        }
    }

    #[test]
    fn f16_limits() {
        assert_eq!(round_f16(65504.0), 65504.0); // max finite f16
        assert!(round_f16(65520.0).is_infinite()); // rounds over
        assert_eq!(round_f16(1e-8), 0.0); // underflow
        assert!(round_f16(f32::NAN).is_nan());
        assert_eq!(round_f16(f32::INFINITY), f32::INFINITY);
        assert_eq!(round_f16(f32::NEG_INFINITY), f32::NEG_INFINITY);
    }

    #[test]
    fn f16_subnormals() {
        let tiny = 5.96e-8f32; // smallest positive f16 subnormal ~5.96e-8
        let r = round_f16(tiny);
        assert!(r > 0.0 && r < 1e-7);
        // known subnormal: 2^-24
        assert_eq!(round_f16(2f32.powi(-24)), 2f32.powi(-24));
    }

    #[test]
    fn f16_rounding_is_half_even() {
        // 1 + 2^-11 is exactly between 1.0 and 1+2^-10 -> rounds to even (1.0)
        let x = 1.0 + 2f32.powi(-11);
        assert_eq!(round_f16(x), 1.0);
        // 1 + 3*2^-11 is between 1+2^-10 and 1+2^-9 -> rounds to even (1+2^-9)
        let y = 1.0 + 3.0 * 2f32.powi(-11);
        assert_eq!(round_f16(y), 1.0 + 2.0 * 2f32.powi(-10));
    }

    #[test]
    fn f16_error_bound_against_native_cast() {
        // relative error of rounding must be <= 2^-11 for normal range
        let mut rng = crate::util::rng::Rng::new(9);
        for _ in 0..10_000 {
            let v = rng.normal_f32(0.0, 10.0);
            let r = round_f16(v);
            assert!((r - v).abs() <= v.abs() * 2f32.powi(-11) + 1e-7, "{v} -> {r}");
        }
    }

    #[test]
    fn bf16_truncates_mantissa() {
        let v = 1.0000001f32;
        assert_eq!(round_bf16(v), 1.0);
        assert!(round_bf16(f32::NAN).is_nan());
        assert_eq!(round_bf16(3.399e38), f32::INFINITY); // > bf16 max finite
        assert!((round_bf16(3.0e38) - 3.0e38).abs() < 3.0e38 * 0.01); // representable
    }

    #[test]
    fn wire_bytes() {
        assert_eq!(WireDtype::F32.wire_bytes(1024, 256), 4096);
        assert_eq!(WireDtype::F16.wire_bytes(1024, 256), 2048);
        assert_eq!(WireDtype::Int8Block.wire_bytes(1024, 256), 1024 + 16);
        assert_eq!(WireDtype::Int4Block.wire_bytes(1024, 256), 512 + 16);
    }
}
