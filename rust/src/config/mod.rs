//! Experiment configuration: JSON-loadable run descriptions plus the
//! presets behind every figure/table reproduction (DESIGN.md §3).

use std::path::Path;

use crate::sched::scenario::{RankCount, Scenario};
use crate::sched::Depth;
use crate::sharding::Scheme;
use crate::util::json::Json;

/// Configuration of a training / simulation run.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Model preset name: an AOT manifest config (numerics path) or a
    /// `TransformerSpec` name (simulator path).
    pub model: String,
    pub scheme: Scheme,
    /// Machine spec: a builtin name (`frontier`, `dgx`, `aurora`, ...) or
    /// a path to a topology JSON (`topology::MachineSpec::resolve`).
    pub machine: String,
    pub nodes: usize,
    /// Micro-batch size per GCD.
    pub micro_batch: usize,
    /// Gradient-accumulation steps per optimizer step.
    pub grad_accum: usize,
    pub steps: usize,
    pub seed: u64,
    /// Quantization block size for wire formats + secondary partitions.
    pub quant_block: usize,
    /// Learning rate for the numerics path.
    pub lr: f32,
    /// MFU anchor for the simulated compute term of the step clock.
    pub mfu: f64,
    /// Prefetch depth for the step scheduler's gather stream: gather
    /// *units* ahead of the compute cursor — whole microbatch gathers
    /// when `layer_blocks == 1`, layer blocks when `layer_blocks > 1`.
    pub prefetch_depth: Depth,
    /// Layer blocks the step clock splits each microbatch gather into
    /// (layer-granular prefetch; 1 = monolithic, today's clock
    /// bit-for-bit). The engine splits its proxy manifest's flat
    /// parameter count near-evenly (manifests carry no layer map).
    pub layer_blocks: usize,
    /// How many ranks the step clock models explicitly (`auto` collapses
    /// congruent groups — with no asymmetry below, a single rank).
    pub ranks: RankCount,
    /// Per-node lognormal compute-jitter sigma for the step clock (0 off).
    pub jitter_sigma: f64,
    /// `(rank, compute multiplier)` stragglers for the step clock.
    pub stragglers: Vec<(usize, f64)>,
    /// `(rank, grad_accum)` imbalance overrides for the step clock.
    pub imbalance: Vec<(usize, usize)>,
    /// Pipeline stages `P` for the step clock (1 = pure data-parallel;
    /// stages are whole node groups, so `P` must divide the node count).
    pub pipeline_stages: usize,
    /// Pipeline microbatches `M` per step for the step clock
    /// (0 = use `grad_accum`).
    pub microbatches: usize,
    /// Virtual chunks per stage `V` (1 = plain 1F1B, >1 = interleaved).
    pub interleave: usize,
    /// Per-step telemetry JSONL sink (`None` = off). One self-describing
    /// JSON object per optimizer step (DESIGN.md §13).
    pub telemetry: Option<String>,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            model: "tiny".into(),
            // auto secondary (machine's innermost span) — valid on every
            // machine; on Frontier it resolves to the paper's sec=2
            scheme: Scheme::ZeroTopo { sec_degree: 0 },
            machine: "frontier".into(),
            nodes: 1,
            micro_batch: 1,
            grad_accum: 1,
            steps: 10,
            seed: 42,
            quant_block: crate::quant::DEFAULT_BLOCK,
            lr: 1e-3,
            mfu: 0.35,
            prefetch_depth: Depth::Infinite,
            layer_blocks: 1,
            ranks: RankCount::Auto,
            jitter_sigma: 0.0,
            stragglers: Vec::new(),
            imbalance: Vec::new(),
            pipeline_stages: 1,
            microbatches: 0,
            interleave: 1,
            telemetry: None,
        }
    }
}

#[derive(Debug, thiserror::Error)]
pub enum ConfigError {
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("parse: {0}")]
    Parse(#[from] crate::util::json::JsonError),
    #[error("bad field {0}: {1}")]
    Bad(&'static str, String),
}

impl RunConfig {
    pub fn from_json(j: &Json) -> Result<Self, ConfigError> {
        let mut c = RunConfig::default();
        let get_usize = |j: &Json, k: &'static str, d: usize| -> Result<usize, ConfigError> {
            match j.get(k) {
                None => Ok(d),
                Some(v) => v.as_usize().ok_or_else(|| ConfigError::Bad(k, v.to_string())),
            }
        };
        if let Some(v) = j.get("model") {
            c.model = v.as_str().ok_or_else(|| ConfigError::Bad("model", v.to_string()))?.into();
        }
        if let Some(v) = j.get("scheme") {
            let s = v.as_str().ok_or_else(|| ConfigError::Bad("scheme", v.to_string()))?;
            c.scheme =
                Scheme::parse(s).ok_or_else(|| ConfigError::Bad("scheme", s.to_string()))?;
        }
        if let Some(v) = j.get("machine") {
            c.machine =
                v.as_str().ok_or_else(|| ConfigError::Bad("machine", v.to_string()))?.into();
        }
        c.nodes = get_usize(j, "nodes", c.nodes)?;
        c.micro_batch = get_usize(j, "micro_batch", c.micro_batch)?;
        c.grad_accum = get_usize(j, "grad_accum", c.grad_accum)?;
        c.steps = get_usize(j, "steps", c.steps)?;
        c.quant_block = get_usize(j, "quant_block", c.quant_block)?;
        if let Some(v) = j.get("seed") {
            c.seed = v.as_i64().ok_or_else(|| ConfigError::Bad("seed", v.to_string()))? as u64;
        }
        if let Some(v) = j.get("lr") {
            c.lr = v.as_f64().ok_or_else(|| ConfigError::Bad("lr", v.to_string()))? as f32;
        }
        if let Some(v) = j.get("mfu") {
            c.mfu = v.as_f64().ok_or_else(|| ConfigError::Bad("mfu", v.to_string()))?;
        }
        if let Some(v) = j.get("prefetch_depth") {
            // accept both a number (like every other numeric field) and
            // the string forms "2" / "inf"
            c.prefetch_depth = match (v.as_usize(), v.as_str()) {
                (Some(d), _) => Depth::Bounded(d),
                (None, Some(s)) => Depth::parse(s)
                    .ok_or_else(|| ConfigError::Bad("prefetch_depth", s.to_string()))?,
                _ => return Err(ConfigError::Bad("prefetch_depth", v.to_string())),
            };
        }
        c.layer_blocks = get_usize(j, "layer_blocks", c.layer_blocks)?;
        if c.layer_blocks == 0 {
            return Err(ConfigError::Bad("layer_blocks", "0".into()));
        }
        if let Some(v) = j.get("ranks") {
            // like prefetch_depth: a number or the string "auto"
            c.ranks = match (v.as_usize(), v.as_str()) {
                (Some(n), _) if n > 0 => RankCount::Count(n),
                (None, Some(s)) => RankCount::parse(s)
                    .ok_or_else(|| ConfigError::Bad("ranks", s.to_string()))?,
                _ => return Err(ConfigError::Bad("ranks", v.to_string())),
            };
        }
        if let Some(v) = j.get("jitter_sigma") {
            c.jitter_sigma =
                v.as_f64().ok_or_else(|| ConfigError::Bad("jitter_sigma", v.to_string()))?;
        }
        if let Some(v) = j.get("stragglers") {
            c.stragglers = parse_rank_pairs(v, "stragglers", |e| {
                e.as_f64().filter(|&m| m > 0.0 && m.is_finite())
            })?;
        }
        if let Some(v) = j.get("imbalance") {
            c.imbalance =
                parse_rank_pairs(v, "imbalance", |e| e.as_usize().filter(|&g| g >= 1))?;
        }
        c.pipeline_stages = get_usize(j, "pipeline_stages", c.pipeline_stages)?;
        if c.pipeline_stages == 0 {
            return Err(ConfigError::Bad("pipeline_stages", "0".into()));
        }
        c.microbatches = get_usize(j, "microbatches", c.microbatches)?;
        c.interleave = get_usize(j, "interleave", c.interleave)?;
        if c.interleave == 0 {
            return Err(ConfigError::Bad("interleave", "0".into()));
        }
        match j.get("telemetry") {
            None | Some(Json::Null) => {}
            Some(v) => {
                let s = v
                    .as_str()
                    .ok_or_else(|| ConfigError::Bad("telemetry", v.to_string()))?;
                c.telemetry = Some(s.to_string());
            }
        }
        Ok(c)
    }

    /// The step-clock scenario this config describes (seeded by the run
    /// seed, so two runs of the same config see identical jitter).
    pub fn scenario(&self) -> Scenario {
        Scenario {
            ranks: self.ranks,
            stragglers: self.stragglers.clone(),
            jitter_sigma: self.jitter_sigma,
            seed: self.seed,
            imbalance: self.imbalance.clone(),
            // run-level fault events are CLI/goodput concerns, not part
            // of the per-step clock a RunConfig describes
            faults: Vec::new(),
        }
    }

    pub fn load(path: &Path) -> Result<Self, ConfigError> {
        let text = std::fs::read_to_string(path)?;
        Ok(Self::from_json(&Json::parse(&text)?)?)
    }

    /// Write the config as JSON — the exact format [`RunConfig::load`]
    /// reads back, so `plan --emit-config out.json` followed by
    /// `train --config out.json` runs the planner's winner verbatim.
    pub fn save(&self, path: &Path) -> Result<(), ConfigError> {
        let mut text = self.to_json().to_string();
        text.push('\n');
        std::fs::write(path, text)?;
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::str(self.model.clone())),
            ("scheme", Json::str(self.scheme.name())),
            ("machine", Json::str(self.machine.clone())),
            ("nodes", Json::from(self.nodes)),
            ("micro_batch", Json::from(self.micro_batch)),
            ("grad_accum", Json::from(self.grad_accum)),
            ("steps", Json::from(self.steps)),
            ("seed", Json::num(self.seed as f64)),
            ("quant_block", Json::from(self.quant_block)),
            ("lr", Json::num(self.lr as f64)),
            ("mfu", Json::num(self.mfu)),
            ("prefetch_depth", Json::str(self.prefetch_depth.to_string())),
            ("layer_blocks", Json::from(self.layer_blocks)),
            ("ranks", Json::str(self.ranks.to_string())),
            ("jitter_sigma", Json::num(self.jitter_sigma)),
            (
                "stragglers",
                Json::arr(self.stragglers.iter().map(|&(r, m)| {
                    Json::arr([Json::from(r), Json::num(m)])
                })),
            ),
            (
                "imbalance",
                Json::arr(self.imbalance.iter().map(|&(r, g)| {
                    Json::arr([Json::from(r), Json::from(g)])
                })),
            ),
            ("pipeline_stages", Json::from(self.pipeline_stages)),
            ("microbatches", Json::from(self.microbatches)),
            ("interleave", Json::from(self.interleave)),
            (
                "telemetry",
                match &self.telemetry {
                    Some(p) => Json::str(p.clone()),
                    None => Json::Null,
                },
            ),
        ])
    }
}

/// Parse a `[[rank, value], ...]` JSON list.
fn parse_rank_pairs<T>(
    v: &Json,
    what: &'static str,
    elem: impl Fn(&Json) -> Option<T>,
) -> Result<Vec<(usize, T)>, ConfigError> {
    let arr = v.as_arr().ok_or_else(|| ConfigError::Bad(what, v.to_string()))?;
    let mut out = Vec::with_capacity(arr.len());
    for pair in arr {
        let p = pair.as_arr().filter(|p| p.len() == 2);
        let parsed = p.and_then(|p| Some((p[0].as_usize()?, elem(&p[1])?)));
        match parsed {
            Some(rv) => out.push(rv),
            None => return Err(ConfigError::Bad(what, pair.to_string())),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_through_json() {
        let c = RunConfig {
            model: "mini".into(),
            scheme: Scheme::Zero3,
            machine: "dgx".into(),
            nodes: 4,
            micro_batch: 2,
            grad_accum: 8,
            steps: 100,
            seed: 7,
            quant_block: 128,
            lr: 3e-4,
            mfu: 0.4,
            prefetch_depth: Depth::Bounded(2),
            layer_blocks: 8,
            ranks: RankCount::Count(4),
            jitter_sigma: 0.05,
            stragglers: vec![(3, 1.25)],
            imbalance: vec![(1, 6)],
            pipeline_stages: 4,
            microbatches: 16,
            interleave: 2,
            telemetry: Some("steps.jsonl".into()),
        };
        let j = c.to_json();
        let c2 = RunConfig::from_json(&j).unwrap();
        assert_eq!(c2.model, "mini");
        assert_eq!(c2.scheme, Scheme::Zero3);
        assert_eq!(c2.machine, "dgx");
        assert_eq!(c2.nodes, 4);
        assert_eq!(c2.grad_accum, 8);
        assert_eq!(c2.quant_block, 128);
        assert!((c2.lr - 3e-4).abs() < 1e-9);
        assert!((c2.mfu - 0.4).abs() < 1e-12);
        assert_eq!(c2.prefetch_depth, Depth::Bounded(2));
        assert_eq!(c2.layer_blocks, 8);
        assert_eq!(c2.ranks, RankCount::Count(4));
        assert!((c2.jitter_sigma - 0.05).abs() < 1e-12);
        assert_eq!(c2.stragglers, vec![(3, 1.25)]);
        assert_eq!(c2.imbalance, vec![(1, 6)]);
        assert_eq!(c2.pipeline_stages, 4);
        assert_eq!(c2.microbatches, 16);
        assert_eq!(c2.interleave, 2);
        assert_eq!(c2.telemetry.as_deref(), Some("steps.jsonl"));
        let sc = c2.scenario();
        assert_eq!(sc.seed, 7);
        assert!(!sc.is_trivial());
    }

    #[test]
    fn save_load_roundtrip() {
        let c = RunConfig {
            model: "20b".into(),
            scheme: Scheme::ZeroTopo { sec_degree: 2 },
            nodes: 48,
            layer_blocks: 44,
            prefetch_depth: Depth::Bounded(2),
            ..RunConfig::default()
        };
        let dir = std::env::temp_dir().join("zero_topo_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("emitted.json");
        c.save(&path).unwrap();
        let c2 = RunConfig::load(&path).unwrap();
        assert_eq!(c2.model, "20b");
        assert_eq!(c2.scheme, Scheme::ZeroTopo { sec_degree: 2 });
        assert_eq!(c2.nodes, 48);
        assert_eq!(c2.layer_blocks, 44);
        assert_eq!(c2.prefetch_depth, Depth::Bounded(2));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn scenario_fields_parse_and_validate() {
        let j = Json::parse(r#"{"ranks":"auto","stragglers":[[5,1.2]],"imbalance":[[2,4]]}"#)
            .unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert_eq!(c.ranks, RankCount::Auto);
        assert_eq!(c.stragglers, vec![(5, 1.2)]);
        assert_eq!(c.imbalance, vec![(2, 4)]);
        let j = Json::parse(r#"{"ranks":8}"#).unwrap();
        assert_eq!(RunConfig::from_json(&j).unwrap().ranks, RankCount::Count(8));
        for bad in [
            r#"{"ranks":0}"#,
            r#"{"ranks":"sometimes"}"#,
            r#"{"stragglers":[[5,-1.0]]}"#,
            r#"{"stragglers":[[5]]}"#,
            r#"{"imbalance":[[2,0]]}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(RunConfig::from_json(&j).is_err(), "{bad}");
        }
    }

    #[test]
    fn default_config_roundtrips_including_scheme_name() {
        // `scheme` is serialized as `name()` — parse must read every
        // name() form back (sharding::scheme_names_roundtrip test)
        let c = RunConfig::default();
        let c2 = RunConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.scheme, c.scheme);
        assert_eq!(c2.machine, c.machine);
        assert_eq!(c2.prefetch_depth, c.prefetch_depth);
    }

    #[test]
    fn defaults_for_missing_fields() {
        let j = Json::parse(r#"{"model":"e2e"}"#).unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert_eq!(c.model, "e2e");
        assert_eq!(c.machine, "frontier");
        assert_eq!(c.nodes, 1);
        assert_eq!(c.scheme, Scheme::ZeroTopo { sec_degree: 0 });
        assert_eq!(c.prefetch_depth, Depth::Infinite);
    }

    #[test]
    fn prefetch_depth_accepts_number_and_string() {
        let j = Json::parse(r#"{"prefetch_depth":2}"#).unwrap();
        assert_eq!(RunConfig::from_json(&j).unwrap().prefetch_depth, Depth::Bounded(2));
        let j = Json::parse(r#"{"prefetch_depth":"inf"}"#).unwrap();
        assert_eq!(RunConfig::from_json(&j).unwrap().prefetch_depth, Depth::Infinite);
        let j = Json::parse(r#"{"prefetch_depth":"nope"}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
    }

    #[test]
    fn rejects_bad_values() {
        let j = Json::parse(r#"{"scheme":"zero9"}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"nodes":-1}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"pipeline_stages":0}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"interleave":0}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"layer_blocks":0}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
    }

    #[test]
    fn layer_blocks_default_monolithic() {
        let c = RunConfig::from_json(&Json::parse(r#"{"model":"e2e"}"#).unwrap()).unwrap();
        assert_eq!(c.layer_blocks, 1);
        let j = Json::parse(r#"{"layer_blocks":16}"#).unwrap();
        assert_eq!(RunConfig::from_json(&j).unwrap().layer_blocks, 16);
    }

    #[test]
    fn telemetry_defaults_off_and_null_roundtrips() {
        let c = RunConfig::from_json(&Json::parse(r#"{"model":"e2e"}"#).unwrap()).unwrap();
        assert_eq!(c.telemetry, None);
        // to_json writes an explicit null — from_json must read it back
        let c2 = RunConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.telemetry, None);
        let j = Json::parse(r#"{"telemetry":"out/steps.jsonl"}"#).unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert_eq!(c.telemetry.as_deref(), Some("out/steps.jsonl"));
        let j = Json::parse(r#"{"telemetry":7}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
    }

    #[test]
    fn pipeline_fields_default_off() {
        let c = RunConfig::from_json(&Json::parse(r#"{"model":"e2e"}"#).unwrap()).unwrap();
        assert_eq!(c.pipeline_stages, 1);
        assert_eq!(c.microbatches, 0);
        assert_eq!(c.interleave, 1);
        let j = Json::parse(r#"{"pipeline_stages":4,"microbatches":8,"interleave":2}"#).unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert_eq!((c.pipeline_stages, c.microbatches, c.interleave), (4, 8, 2));
    }
}
