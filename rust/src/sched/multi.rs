//! Multi-rank step graphs: instantiate one compute/prefetch/grad-sync
//! stream triple per *modeled* rank over a [`StepPlan`], with shared
//! collective tasks and cross-rank barrier dependencies, so asymmetric
//! schedules — stragglers, per-node jitter, imbalanced grad-accum groups —
//! show real cross-rank coupling instead of the congruent-group shortcut.
//!
//! Semantics, chosen so the congruent case stays *bit-for-bit* the
//! single-rank calibrated model:
//!
//! * **Shared collectives.** A collective over group `G` is ONE wire
//!   operation, so the graph holds one task per (group, phase, microbatch,
//!   layer block) — layered plans split each microbatch gather into its
//!   per-block chain, monolithic plans keep one — priced exactly as
//!   [`StepPlan`] prices it, with the full congruent world's contention
//!   (NIC sharing, group penalties) baked into the duration. Every modeled member's consumer depends on it, and it
//!   depends on every modeled member's readiness: a straggler anywhere in
//!   the group delays the collective for everyone — the synchronization
//!   physics Dash et al. blame for Frontier's scaling-efficiency loss.
//! * **Link-instance contention.** Tasks carry a contention `instance`
//!   keying the *physical* link they occupy: the level-`k` block index for
//!   `Intra(k)` (two GCD pairs' gathers ride different IF links and do not
//!   contend), the shared fabric for `InterNode`. Distinct collectives
//!   crossing the same instance genuinely compete via the event loop's
//!   processor sharing — e.g. a late prefetch gather overlapping the
//!   grad-sync all-to-all on the same node.
//! * **Congruence collapsing.** Modeling all W ranks of a Frontier-scale
//!   world is wasteful when most are congruent: [`RankCount::Auto`] keeps
//!   one representative node per distinct node signature and one rank per
//!   distinct (multiplier, grad-accum) signature within it. A trivial
//!   scenario therefore collapses to exactly `StepPlan::build(0)`.
//!
//! # Example
//!
//! A straggler delays its whole synchronization group:
//!
//! ```no_run
//! // (no_run: doctest binaries miss the libxla rpath in this offline env)
//! use zero_topo::comm::cost::{CommEfficiency, CostModel};
//! use zero_topo::sched::multi::MultiRankPlan;
//! use zero_topo::sched::plan::StepPlan;
//! use zero_topo::sched::scenario::Scenario;
//! use zero_topo::sched::Depth;
//! use zero_topo::sharding::{Scheme, ShardingSpec};
//! use zero_topo::topology::Cluster;
//!
//! let cluster = Cluster::frontier(2);
//! let cost = CostModel::with_efficiency(cluster.clone(), CommEfficiency::rccl_frontier());
//! let spec = ShardingSpec::resolve(Scheme::Zero3, &cluster).unwrap();
//! let plan = StepPlan::from_protocol(
//!     &cost, Scheme::Zero3, &spec, 1_000_000, 256, 2, 1.0, Depth::Infinite,
//! );
//! let base = MultiRankPlan::new(&plan, &cluster, &Scenario::default());
//! let slow_scenario = Scenario { stragglers: vec![(5, 1.5)], ..Default::default() };
//! let slow = MultiRankPlan::new(&plan, &cluster, &slow_scenario);
//! assert!(slow.simulate().makespan() > base.simulate().makespan());
//! ```

use std::collections::BTreeMap;

use crate::sched::plan::StepPlan;
use crate::sched::scenario::{RankCount, Scenario};
use crate::sched::{self, Schedule, StreamKind, Task, TaskGraph, TaskId};
use crate::topology::{Cluster, LinkClass};

/// A step plan expanded over explicitly modeled ranks.
#[derive(Debug, Clone)]
pub struct MultiRankPlan {
    plan: StepPlan,
    cluster: Cluster,
    /// Sorted modeled rank ids (world rank space).
    modeled: Vec<usize>,
    /// Per-world-rank compute multipliers (jitter x stragglers).
    mult: Vec<f64>,
    /// Per-world-rank grad-accum counts.
    ga: Vec<usize>,
}

/// Contention instance of a link class for a group starting at `group_min`:
/// the aligned block index for intra-node levels, the shared fabric (0) for
/// inter-node, the rank itself for `Local` (never contends). Shared with
/// the pipeline builder so stage collectives key the same physical links.
pub(crate) fn instance_of(cluster: &Cluster, class: LinkClass, group_min: usize) -> usize {
    match class {
        LinkClass::Local => group_min,
        LinkClass::Intra(k) => {
            let k = (k as usize).min(cluster.spec.levels.len() - 1);
            group_min / cluster.spec.levels[k].span
        }
        LinkClass::InterNode => 0,
    }
}

/// The synchronization group a sync phase of link class `class` spans for
/// `rank`: its aligned level-`k` block for `Intra(k)` (ZeRO-topo's per-node
/// all-to-all), the world for `InterNode`, just the rank for `Local`.
fn sync_group(cluster: &Cluster, rank: usize, class: LinkClass) -> Vec<usize> {
    match class {
        LinkClass::Local => vec![rank],
        LinkClass::Intra(k) => {
            let k = (k as usize).min(cluster.spec.levels.len() - 1);
            cluster.level_group(rank, k)
        }
        LinkClass::InterNode => (0..cluster.world_size()).collect(),
    }
}

impl MultiRankPlan {
    /// Expand `plan` over the ranks `scenario` asks for. The plan's
    /// durations are reused as-is (congruent pricing); the scenario only
    /// perturbs compute multipliers and per-rank grad-accum counts.
    pub fn new(plan: &StepPlan, cluster: &Cluster, scenario: &Scenario) -> MultiRankPlan {
        let world = cluster.world_size();
        let mult = scenario.compute_multipliers(cluster);
        let ga = scenario.grad_accums(world, plan.grad_accum);
        let mut modeled = match scenario.ranks {
            RankCount::Auto => auto_ranks(cluster, &mult, &ga),
            RankCount::Count(n) => {
                let mut m: Vec<usize> = (0..n.min(world)).collect();
                // scenario-named ranks are always modeled explicitly
                m.extend(scenario.stragglers.iter().map(|&(r, _)| r).filter(|&r| r < world));
                m.extend(scenario.imbalance.iter().map(|&(r, _)| r).filter(|&r| r < world));
                m
            }
        };
        modeled.sort_unstable();
        modeled.dedup();
        assert!(!modeled.is_empty());
        MultiRankPlan { plan: plan.clone(), cluster: cluster.clone(), modeled, mult, ga }
    }

    /// The explicitly modeled world-rank ids (sorted).
    pub fn modeled_ranks(&self) -> &[usize] {
        &self.modeled
    }

    /// Build the multi-rank step DAG.
    ///
    /// Bookkeeping is index-based (DESIGN.md §16): rank→position is a
    /// dense vector over the world-rank space, per-phase gather/sync
    /// groups come from a single linear grouping pass (`self.modeled` is
    /// sorted and every group key is non-decreasing in the rank, so this
    /// reproduces the ascending-key map order bit-for-bit), and the
    /// phase chain is a position-indexed vector. Task insertion order —
    /// and therefore every simulated span — is unchanged.
    pub fn build(&self) -> TaskGraph {
        let p = &self.plan;
        let mut g = TaskGraph::with_rank_ids(self.modeled.clone());
        let mut mpos = vec![usize::MAX; self.cluster.world_size()];
        for (i, &r) in self.modeled.iter().enumerate() {
            mpos[r] = i;
        }
        // per modeled rank, its compute tasks in consumption order
        let mut consumers: Vec<Vec<TaskId>> = vec![Vec::new(); self.modeled.len()];

        // previous step's §V.D refresh: one world-spanning collective
        if p.t_update > 0.0 {
            g.add(Task {
                label: "update-gather".into(),
                rank: self.modeled[0],
                stream: StreamKind::GradSync,
                work: p.t_update,
                class: Some(p.class_update),
                instance: instance_of(&self.cluster, p.class_update, 0),
                deps: vec![],
            });
        }

        // prefetch gate: the next gather of rank (position i) may start
        // once consumer j-1-depth of that rank has finished, where j is
        // the rank's consumer count so far — with a layered plan `depth`
        // counts *layer blocks* ahead of the compute cursor (§12)
        let fwd_blocks = p.fwd_blocks();
        let bwd_blocks = p.bwd_blocks();
        let layered = p.blocks.len() > 1;
        let per_micro = fwd_blocks.len() + bwd_blocks.len();
        let gate = |consumers: &[Vec<TaskId>], i: usize, ga_r: usize| -> Vec<TaskId> {
            match p.depth {
                sched::Depth::Bounded(d) if d < per_micro * ga_r => {
                    let k = consumers[i].len() as i64 - 1 - d as i64;
                    if k >= 0 {
                        vec![consumers[i][k as usize]]
                    } else {
                        vec![]
                    }
                }
                _ => vec![],
            }
        };

        let max_ga = self.modeled.iter().map(|&r| self.ga[r]).max().expect("non-empty");
        // pre-size the arena: every (microbatch, block, group) yields one
        // gather plus a compute per member, plus the sync chain + update
        g.reserve(
            max_ga * per_micro * (self.modeled.len() + 1)
                + p.sync.len() * self.modeled.len()
                + 1,
        );
        for m in 0..max_ga {
            for (deg, class, name, blocks) in [
                (p.d_fwd, p.class_fwd, "fwd", &fwd_blocks),
                (p.d_bwd, p.class_bwd, "bwd", &bwd_blocks),
            ] {
                // modeled members still running microbatch m, by gather
                // group — `r / deg` is non-decreasing over sorted ranks,
                // so consecutive-key grouping matches the old map order
                let mut groups: Vec<(usize, Vec<usize>)> = Vec::new();
                for &r in &self.modeled {
                    if m < self.ga[r] {
                        let key = r / deg.max(1);
                        match groups.last_mut() {
                            Some((k, members)) if *k == key => members.push(r),
                            _ => {
                                debug_assert!(groups.last().is_none_or(|(k, _)| *k < key));
                                groups.push((key, vec![r]));
                            }
                        }
                    }
                }
                for &(bid, t_gather, t_compute) in blocks.iter() {
                    let suffix = if layered { format!("b{bid}") } else { String::new() };
                    for (gi, members) in &groups {
                        let gi = *gi;
                        let mut deps: Vec<TaskId> = Vec::new();
                        for &r in members {
                            for d in gate(&consumers, mpos[r], self.ga[r]) {
                                if !deps.contains(&d) {
                                    deps.push(d);
                                }
                            }
                        }
                        let gather = g.add(Task {
                            label: format!("gather.{name}[{m}]{suffix}@g{gi}"),
                            rank: members[0],
                            stream: StreamKind::Prefetch,
                            work: t_gather,
                            class: Some(class),
                            instance: instance_of(&self.cluster, class, gi * deg.max(1)),
                            deps,
                        });
                        for &r in members {
                            let c = g.add(Task {
                                label: format!("compute.{name}[{m}]{suffix}@r{r}"),
                                rank: r,
                                stream: StreamKind::Compute,
                                work: t_compute * self.mult[r],
                                class: None,
                                instance: 0,
                                deps: vec![gather],
                            });
                            consumers[mpos[r]].push(c);
                        }
                    }
                }
            }
        }

        // gradient-sync phases: one task per synchronization group, gated
        // by every modeled member's readiness (phase 0: its last compute;
        // later phases: its previous phase's task). The chain is indexed
        // by modeled-rank position; group mins are non-decreasing over
        // sorted ranks, so linear grouping again matches the map order.
        let mut prev_phase: Vec<TaskId> = vec![TaskId(usize::MAX); self.modeled.len()];
        for (k, phase) in p.sync.iter().enumerate() {
            let mut groups: Vec<(usize, Vec<usize>)> = Vec::new();
            for &r in &self.modeled {
                let grp = sync_group(&self.cluster, r, phase.class);
                let gmin = *grp.iter().min().expect("non-empty group");
                match groups.last_mut() {
                    Some((key, members)) if *key == gmin => members.push(r),
                    _ => {
                        debug_assert!(groups.last().is_none_or(|(key, _)| *key < gmin));
                        groups.push((gmin, vec![r]));
                    }
                }
            }
            let mut next_phase: Vec<TaskId> = vec![TaskId(usize::MAX); self.modeled.len()];
            for (gmin, members) in groups {
                let mut deps: Vec<TaskId> = Vec::new();
                for &r in &members {
                    let d = if k == 0 {
                        *consumers[mpos[r]].last().expect("grad_accum >= 1")
                    } else {
                        prev_phase[mpos[r]]
                    };
                    if !deps.contains(&d) {
                        deps.push(d);
                    }
                }
                let t = g.add(Task {
                    label: format!("grad-sync[{k}]@g{gmin}"),
                    rank: members[0],
                    stream: StreamKind::GradSync,
                    work: phase.seconds,
                    class: Some(phase.class),
                    instance: instance_of(&self.cluster, phase.class, gmin),
                    deps,
                });
                for &r in &members {
                    next_phase[mpos[r]] = t;
                }
            }
            prev_phase = next_phase;
        }
        g
    }

    /// Build and run the event loop.
    pub fn simulate(&self) -> Schedule {
        sched::simulate(self.build())
    }
}

/// Congruence collapsing: keep one representative node per distinct node
/// signature (the ordered tuple of its ranks' signatures), and within each
/// kept node one rank per distinct (multiplier, grad-accum) signature.
fn auto_ranks(cluster: &Cluster, mult: &[f64], ga: &[usize]) -> Vec<usize> {
    let wpn = cluster.workers_per_node();
    let sig = |r: usize| (mult[r].to_bits(), ga[r]);
    let mut kept_nodes: BTreeMap<Vec<(u64, usize)>, usize> = BTreeMap::new();
    for node in 0..cluster.nodes {
        let nsig: Vec<(u64, usize)> = (node * wpn..(node + 1) * wpn).map(sig).collect();
        kept_nodes.entry(nsig).or_insert(node);
    }
    let mut nodes: Vec<usize> = kept_nodes.into_values().collect();
    nodes.sort_unstable();
    let mut modeled = Vec::new();
    for node in nodes {
        let mut seen: Vec<(u64, usize)> = Vec::new();
        for r in node * wpn..(node + 1) * wpn {
            if !seen.contains(&sig(r)) {
                seen.push(sig(r));
                modeled.push(r);
            }
        }
    }
    modeled.sort_unstable();
    modeled
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::cost::{CommEfficiency, CostModel};
    use crate::sched::Depth;
    use crate::sharding::{Scheme, ShardingSpec};

    fn plan(scheme: Scheme, nodes: usize, depth: Depth) -> (StepPlan, Cluster) {
        let cluster = Cluster::frontier(nodes);
        let cost = CostModel::with_efficiency(cluster.clone(), CommEfficiency::rccl_frontier());
        let spec = ShardingSpec::resolve(scheme, &cluster).unwrap();
        let p = StepPlan::from_protocol(
            &cost,
            scheme,
            &spec,
            1_000_000_000,
            256,
            4,
            2.0,
            depth,
        );
        (p, cluster)
    }

    #[test]
    fn multi_rank_schedules_conserve_the_makespan_ledger() {
        // straggler + jitter break the congruence collapse, so the walk
        // crosses ranks; the ledger must still tile the makespan exactly
        let (p, cluster) = plan(Scheme::ZeroTopo { sec_degree: 2 }, 4, Depth::Bounded(1));
        let sc = Scenario {
            ranks: RankCount::Count(8),
            stragglers: vec![(3, 1.7)],
            jitter_sigma: 0.05,
            seed: 7,
            ..Default::default()
        };
        let sched = MultiRankPlan::new(&p, &cluster, &sc).simulate();
        let d = crate::sched::critical::decompose(&sched);
        assert!(
            d.conservation_error() <= 1e-12,
            "conservation error {:.3e}",
            d.conservation_error()
        );
        assert_eq!(d.makespan(), sched.makespan());
    }

    #[test]
    fn trivial_scenario_collapses_to_one_rank() {
        let (p, cluster) = plan(Scheme::ZeroTopo { sec_degree: 2 }, 4, Depth::Infinite);
        let mr = MultiRankPlan::new(&p, &cluster, &Scenario::default());
        assert_eq!(mr.modeled_ranks(), &[0]);
        // bit-for-bit the single-rank plan
        assert_eq!(mr.simulate().makespan(), p.simulate().makespan());
    }

    #[test]
    fn congruent_explicit_ranks_match_single_rank() {
        for scheme in [Scheme::Zero3, Scheme::ZeroPP, Scheme::ZeroTopo { sec_degree: 2 }] {
            let (p, cluster) = plan(scheme, 2, Depth::Infinite);
            let single = p.simulate().makespan();
            for n in [1, 2, 8, 16] {
                let sc = Scenario { ranks: RankCount::Count(n), ..Default::default() };
                let mk = MultiRankPlan::new(&p, &cluster, &sc).simulate().makespan();
                assert!(
                    (mk - single).abs() <= 1e-12 * single.max(1.0),
                    "{scheme:?} ranks={n}: {mk} vs {single}"
                );
            }
        }
    }

    #[test]
    fn straggler_delays_the_whole_step() {
        let (p, cluster) = plan(Scheme::ZeroTopo { sec_degree: 2 }, 4, Depth::Infinite);
        let base = p.simulate().makespan();
        let sc = Scenario { stragglers: vec![(5, 1.5)], ..Default::default() };
        let mr = MultiRankPlan::new(&p, &cluster, &sc);
        assert!(mr.modeled_ranks().contains(&5));
        let sched = mr.simulate();
        assert!(sched.makespan() > base * 1.01, "{} vs {base}", sched.makespan());
        assert_eq!(sched.slowest_rank(), 5);
        // a non-straggler rank spends the gap waiting on its peer
        let peer = *mr.modeled_ranks().iter().find(|&&r| r != 5).unwrap();
        assert!(sched.skew_wait(peer) > 0.0);
        assert!(sched.skew_wait(5) < sched.skew_wait(peer));
    }

    #[test]
    fn auto_collapse_keeps_straggler_node_plus_exemplar() {
        let (p, cluster) = plan(Scheme::Zero3, 4, Depth::Infinite);
        let sc = Scenario { stragglers: vec![(5, 1.3)], ..Default::default() };
        let mr = MultiRankPlan::new(&p, &cluster, &sc);
        // node 0 (rep + straggler signatures) + one exemplar node rank
        assert_eq!(mr.modeled_ranks(), &[0, 5, 8]);
    }

    #[test]
    fn imbalanced_grad_accum_stretches_makespan() {
        let (p, cluster) = plan(Scheme::ZeroPP, 2, Depth::Infinite);
        let base = p.simulate().makespan();
        let sc = Scenario { imbalance: vec![(3, 6)], ..Default::default() };
        let sched = MultiRankPlan::new(&p, &cluster, &sc).simulate();
        assert!(sched.makespan() > base, "{} vs {base}", sched.makespan());
        assert_eq!(sched.slowest_rank(), 3);
    }

    #[test]
    fn jitter_is_deterministic_and_spreads_nodes() {
        let (p, cluster) = plan(Scheme::ZeroTopo { sec_degree: 2 }, 4, Depth::Infinite);
        let sc = Scenario { jitter_sigma: 0.1, seed: 7, ..Default::default() };
        let a = MultiRankPlan::new(&p, &cluster, &sc);
        let b = MultiRankPlan::new(&p, &cluster, &sc);
        // per-node jitter collapses to one rank per node
        assert_eq!(a.modeled_ranks().len(), 4);
        let sa = a.simulate();
        let sb = b.simulate();
        assert_eq!(sa.makespan(), sb.makespan());
        for (x, y) in sa.spans().iter().zip(sb.spans()) {
            assert_eq!(x.start, y.start);
            assert_eq!(x.end, y.end);
        }
        // a different seed moves the makespan (a.s.)
        let sc2 = Scenario { seed: 8, ..sc };
        assert_ne!(MultiRankPlan::new(&p, &cluster, &sc2).simulate().makespan(), sa.makespan());
    }

    #[test]
    fn layered_plan_threads_through_multi_rank() {
        let cluster = Cluster::frontier(2);
        let cost =
            CostModel::with_efficiency(cluster.clone(), CommEfficiency::rccl_frontier());
        let spec = ShardingSpec::resolve(Scheme::Zero3, &cluster).unwrap();
        let elems = crate::sched::pipeline::even_chunk_params(1_000_000_000, 4);
        let p = StepPlan::from_protocol_layered(
            &cost,
            Scheme::Zero3,
            &spec,
            &elems,
            256,
            2,
            2.0,
            crate::sched::Depth::Bounded(2),
        );
        // 1-rank multi reproduces the layered single-rank schedule bit-for-bit
        let single = p.simulate();
        let sc = Scenario { ranks: RankCount::Count(1), ..Default::default() };
        let multi = MultiRankPlan::new(&p, &cluster, &sc).simulate();
        assert_eq!(single.makespan(), multi.makespan());
        assert_eq!(single.spans().len(), multi.spans().len());
        for (a, b) in single.spans().iter().zip(multi.spans()) {
            assert_eq!((a.start, a.end), (b.start, b.end));
        }
        // a straggler still stretches the step, and the shared gathers
        // carry block labels
        let sc = Scenario { stragglers: vec![(5, 1.5)], ..Default::default() };
        let sched = MultiRankPlan::new(&p, &cluster, &sc).simulate();
        assert!(sched.makespan() > single.makespan());
        assert!(sched
            .graph()
            .tasks()
            .iter()
            .any(|t| t.label.starts_with("gather.bwd[0]b3@")));
    }

    #[test]
    fn gather_instances_separate_physical_links() {
        // two modeled GCD pairs: their pair gathers ride different IF links
        let (p, cluster) = plan(Scheme::ZeroTopo { sec_degree: 2 }, 2, Depth::Infinite);
        let sc = Scenario { ranks: RankCount::Count(4), ..Default::default() };
        let g = MultiRankPlan::new(&p, &cluster, &sc).build();
        let gathers: Vec<&Task> = g
            .tasks()
            .iter()
            .filter(|t| t.label.starts_with("gather.fwd[0]"))
            .collect();
        assert_eq!(gathers.len(), 2);
        assert_eq!(gathers[0].class, gathers[1].class);
        assert_ne!(gathers[0].instance, gathers[1].instance);
    }
}
