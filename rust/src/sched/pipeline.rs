//! Pipeline-parallel schedules on the event scheduler (DESIGN.md §11):
//! 1F1B and interleaved-virtual-stage task graphs with bubble-fraction
//! prediction, composed with the per-stage ZeRO gather/sync tasks.
//!
//! [`PipelinePlan::from_protocol`] partitions the model's layer chunks
//! into `P` stages placed on contiguous node groups (each stage keeps a
//! `W/P`-rank data-parallel group running the ZeRO scheme *within* the
//! stage), prices stage-to-stage activation/gradient transfers through
//! the same α–β [`CostModel`] every collective uses, and emits the step
//! as a task graph for [`crate::sched::simulate`]:
//!
//! * per (stage, chunk, microbatch): forward/backward compute units on
//!   the stage's compute stream, in **1F1B order** (warmup forwards,
//!   steady one-forward-one-backward, cooldown backwards) — or, with
//!   `interleave = V > 1`, the Megatron-style interleaved order over
//!   `P·V` virtual stages (each physical stage owns every `P`-th chunk);
//! * per stage boundary crossed by a chunk edge: a `p2p` transfer task on
//!   the receiver's [`StreamKind::PipeTransfer`] stream, contending for
//!   the inter-node fabric with every collective that crosses it;
//! * per (stage, microbatch): the stage's ZeRO weight gathers on the
//!   prefetch stream, bounded by [`Depth`] exactly as in [`StepPlan`];
//! * per stage: the §V.D updated-weight refresh at the grad-stream head
//!   and the gradient-sync phases after the stage's last backward.
//!
//! **Degeneracy contract**: `P = 1` builds a graph whose simulation is
//! bit-for-bit the single-axis [`StepPlan`] step (same durations, same
//! spans), so the pipeline path cannot drift from the calibrated clock.
//! With equal stages and zero communication the simulated
//! [`PipelinePlan::bubble_fraction`] reproduces the closed-form 1F1B
//! bound `(P-1)/(M+P-1)` exactly (property-tested in
//! `tests/pipeline.rs`), and interleaving tightens it to
//! `(P-1)/(V·M+P-1)`.
//!
//! # Example
//!
//! A communication-free 2-stage, 4-microbatch 1F1B plan hits the
//! closed-form bubble bound:
//!
//! ```no_run
//! // (no_run: doctest binaries miss the libxla rpath in this offline env)
//! use zero_topo::sched::pipeline::PipelinePlan;
//! use zero_topo::sched::Depth;
//!
//! let plan = PipelinePlan::synthetic(2, 4, 1, 1.0, 2.0, Depth::Infinite);
//! let sched = plan.simulate();
//! let bubble = plan.bubble_fraction(&sched);
//! let bound = PipelinePlan::ideal_bubble(2, 4, 1); // (P-1)/(M+P-1) = 0.2
//! assert!((bubble - bound).abs() < 1e-9);
//! ```

use crate::comm::cost::CostModel;
use crate::sched::multi::instance_of;
use crate::sched::plan::StepPlan;
use crate::sched::{self, Depth, Schedule, StreamKind, Task, TaskGraph, TaskId};
use crate::sharding::{Scheme, ShardingError, ShardingSpec};
use crate::topology::{Cluster, LinkClass};

/// Shape of a pipeline-parallel execution: `stages` pipeline stages ×
/// a data-parallel group per stage, `microbatches` in flight per
/// optimizer step, `interleave` virtual chunks per stage.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PipeConfig {
    /// Number of pipeline stages `P` (1 = no pipeline axis).
    pub stages: usize,
    /// Microbatches `M` per optimizer step (the 1F1B "M"). In the sim /
    /// engine wrappers `0` means "derive from the global batch /
    /// grad-accum"; [`PipelinePlan::from_protocol`] requires `>= 1`.
    pub microbatches: usize,
    /// Virtual chunks per stage `V` (1 = plain 1F1B, `> 1` = the
    /// interleaved schedule; requires `M % P == 0` like Megatron's).
    pub interleave: usize,
}

impl Default for PipeConfig {
    fn default() -> Self {
        PipeConfig { stages: 1, microbatches: 0, interleave: 1 }
    }
}

impl PipeConfig {
    /// The interleave factor actually applied: chunking is meaningless
    /// without a pipeline axis, so `P = 1` always runs `V = 1`.
    pub fn effective_interleave(&self) -> usize {
        if self.stages <= 1 {
            1
        } else {
            self.interleave.max(1)
        }
    }

    /// Total virtual chunks `P × V` the layer blocks are partitioned into.
    pub fn chunks(&self) -> usize {
        self.stages.max(1) * self.effective_interleave()
    }
}

/// Why a pipeline plan could not be constructed.
#[derive(Debug, thiserror::Error)]
pub enum PipelineError {
    /// `stages` was 0.
    #[error("pipeline stages must be >= 1, got {0}")]
    BadStages(usize),
    /// `microbatches` was 0 at plan-construction time.
    #[error("pipeline microbatches must be >= 1, got {0}")]
    BadMicrobatches(usize),
    /// Stages are whole node groups; `P` must divide the node count.
    #[error("{stages} pipeline stages do not divide {nodes} nodes (each stage is a contiguous node group)")]
    StagesDontDivideNodes {
        /// Requested stage count `P`.
        stages: usize,
        /// Cluster node count.
        nodes: usize,
    },
    /// The interleaved schedule issues microbatches in groups of `P`.
    #[error("interleaved schedule needs microbatches ({microbatches}) divisible by stages ({stages})")]
    InterleaveNeedsDivisibleMicrobatches {
        /// Requested microbatch count `M`.
        microbatches: usize,
        /// Requested stage count `P`.
        stages: usize,
    },
    /// `chunk_params` length disagreed with `P × V`.
    #[error("chunk_params has {got} entries, want stages x interleave = {want}")]
    ChunkCount {
        /// Entries received.
        got: usize,
        /// Entries required.
        want: usize,
    },
    /// The ZeRO scheme could not resolve on the per-stage DP group.
    #[error(transparent)]
    Sharding(#[from] ShardingError),
}

/// A pipeline-parallel step plan: per-stage ZeRO [`StepPlan`]s plus the
/// stage-boundary transfer pricing and the schedule shape, ready to
/// [`PipelinePlan::build`] into a task graph.
///
/// All fields are public (like [`StepPlan`]) so tests and ablations can
/// construct synthetic plans — e.g. equal stages with zero communication
/// to check the closed-form bubble bound.
#[derive(Debug, Clone)]
pub struct PipelinePlan {
    /// Per-stage ZeRO plan (`grad_accum` holds `M`), priced over the
    /// stage's data-parallel sub-cluster; compute terms hold the stage's
    /// per-microbatch totals across its `V` chunks.
    pub stages: Vec<StepPlan>,
    /// `chunk_frac[s][c]`: chunk `c`'s fraction of stage `s`'s
    /// per-microbatch compute (sums to 1 per stage).
    pub chunk_frac: Vec<Vec<f64>>,
    /// Virtual chunks per stage `V` (1 = plain 1F1B).
    pub interleave: usize,
    /// Activation transfer seconds per microbatch per stage boundary.
    pub t_act: f64,
    /// Activation-gradient transfer seconds (same payload, same time in
    /// the fp16 wire model, but kept separate for ablations).
    pub t_grad: f64,
    /// Link class every stage boundary crosses (stages are whole node
    /// groups, so `InterNode` whenever `P > 1`).
    pub class_p2p: LinkClass,
    /// Representative world rank per stage (the first rank of each
    /// stage's contiguous DP block).
    pub rep_ranks: Vec<usize>,
    /// Per-stage compute multipliers (scenario stragglers/jitter mapped
    /// onto stages); 1.0 everywhere by default.
    pub stage_mult: Vec<f64>,
    /// The full cluster, kept for link-instance resolution of per-stage
    /// collectives.
    pub cluster: Cluster,
}

impl PipelinePlan {
    /// Derive the pipeline plan for `(scheme, cluster)` from the cost
    /// model. `chunk_params[j]` is the parameter count of virtual chunk
    /// `j` (`j = v·P + s` lives on stage `s` as its chunk `v`; length
    /// must be `pipe.chunks()`), `activation_bytes` the fp16 payload one
    /// microbatch ships across a stage boundary, and `compute_s` the
    /// whole-step **full-model** compute seconds per DP rank (all `M`
    /// microbatches) — split across stages in proportion to their
    /// parameter share.
    ///
    /// Each stage's ZeRO collectives are priced on a sub-cluster of
    /// `nodes / P` nodes: stage DP blocks are node-aligned, so by the
    /// nested-aligned-span property the stage groups price identically
    /// to their congruent stage-0 images.
    ///
    /// With `layered = true` each stage's per-microbatch gathers split
    /// into its per-chunk layer blocks (a stage's blocks are exactly its
    /// slice of `chunk_params` — the layer-granular prefetch axis and the
    /// virtual-chunk axis compose, DESIGN.md §12), so [`Depth`] gates the
    /// stage's prefetch stream in *chunks* ahead of its compute cursor.
    /// `layered = false` (or `V = 1`, where a stage owns a single chunk)
    /// keeps today's one-gather-per-(stage, microbatch) schedule
    /// bit-for-bit.
    #[allow(clippy::too_many_arguments)]
    pub fn from_protocol(
        cost: &CostModel,
        scheme: Scheme,
        pipe: &PipeConfig,
        chunk_params: &[u64],
        quant_block: usize,
        activation_bytes: u64,
        compute_s: f64,
        depth: Depth,
        layered: bool,
    ) -> Result<PipelinePlan, PipelineError> {
        let p = pipe.stages;
        let m = pipe.microbatches;
        let v = pipe.effective_interleave();
        if p == 0 {
            return Err(PipelineError::BadStages(p));
        }
        if m == 0 {
            return Err(PipelineError::BadMicrobatches(m));
        }
        if v > 1 && m % p != 0 {
            return Err(PipelineError::InterleaveNeedsDivisibleMicrobatches {
                microbatches: m,
                stages: p,
            });
        }
        let cluster = &cost.cluster;
        if cluster.nodes % p != 0 {
            return Err(PipelineError::StagesDontDivideNodes { stages: p, nodes: cluster.nodes });
        }
        if chunk_params.len() != p * v {
            return Err(PipelineError::ChunkCount { got: chunk_params.len(), want: p * v });
        }

        let dp = cluster.world_size() / p;
        let sub = Cluster::new(cluster.spec.clone(), cluster.nodes / p);
        let sub_cost = CostModel::with_efficiency(sub.clone(), cost.efficiency);
        let spec = ShardingSpec::resolve(scheme, &sub)?;
        let psi: u64 = chunk_params.iter().sum();

        let mut stages = Vec::with_capacity(p);
        let mut chunk_frac = Vec::with_capacity(p);
        for s in 0..p {
            let stage_chunks: Vec<u64> = (0..v).map(|c| chunk_params[c * p + s]).collect();
            let stage_params: u64 = stage_chunks.iter().sum();
            let frac = if psi > 0 { stage_params as f64 / psi as f64 } else { 1.0 / p as f64 };
            stages.push(if layered {
                StepPlan::from_protocol_layered(
                    &sub_cost,
                    scheme,
                    &spec,
                    &stage_chunks,
                    quant_block,
                    m,
                    compute_s * frac,
                    depth,
                )
            } else {
                StepPlan::from_protocol(
                    &sub_cost,
                    scheme,
                    &spec,
                    stage_params as usize,
                    quant_block,
                    m,
                    compute_s * frac,
                    depth,
                )
            });
            chunk_frac.push(
                (0..v)
                    .map(|c| {
                        if stage_params > 0 {
                            chunk_params[c * p + s] as f64 / stage_params as f64
                        } else {
                            1.0 / v as f64
                        }
                    })
                    .collect(),
            );
        }

        let rep_ranks: Vec<usize> = (0..p).map(|s| s * dp).collect();
        let (t_act, class_p2p) = if p > 1 {
            cost.priced_p2p(rep_ranks[0], rep_ranks[1], activation_bytes)
        } else {
            (0.0, LinkClass::Local)
        };
        Ok(PipelinePlan {
            stages,
            chunk_frac,
            interleave: v,
            t_act,
            t_grad: t_act,
            class_p2p,
            rep_ranks,
            stage_mult: vec![1.0; p],
            cluster: cluster.clone(),
        })
    }

    /// A synthetic plan for tests/ablations: `p` equal stages with zero
    /// communication (no gathers, no sync, free transfers), `m`
    /// microbatches, `v`-way interleave, per-microbatch compute
    /// `t_fwd`/`t_bwd` per stage. Its simulated bubble fraction is the
    /// closed-form [`PipelinePlan::ideal_bubble`] exactly.
    pub fn synthetic(
        p: usize,
        m: usize,
        v: usize,
        t_fwd: f64,
        t_bwd: f64,
        depth: Depth,
    ) -> PipelinePlan {
        assert!(p >= 1 && m >= 1 && v >= 1, "need p, m, v >= 1");
        let v = if p == 1 { 1 } else { v };
        assert!(v == 1 || m % p == 0, "interleave needs m % p == 0");
        let stage = StepPlan {
            scheme: Scheme::Zero3,
            grad_accum: m,
            depth,
            t_gather_fwd: 0.0,
            class_fwd: LinkClass::Local,
            t_gather_bwd: 0.0,
            class_bwd: LinkClass::Local,
            t_update: 0.0,
            class_update: LinkClass::Local,
            t_compute_fwd: t_fwd,
            t_compute_bwd: t_bwd,
            sync: Vec::new(),
            d_fwd: 1,
            d_bwd: 1,
            blocks: Vec::new(),
        };
        let cluster = Cluster::frontier(p);
        let wpn = cluster.workers_per_node();
        PipelinePlan {
            stages: vec![stage; p],
            chunk_frac: vec![vec![1.0 / v as f64; v]; p],
            interleave: v,
            t_act: 0.0,
            t_grad: 0.0,
            class_p2p: if p > 1 { LinkClass::InterNode } else { LinkClass::Local },
            rep_ranks: (0..p).map(|s| s * wpn).collect(),
            stage_mult: vec![1.0; p],
            cluster,
        }
    }

    /// Replace the per-stage compute multipliers (scenario injection —
    /// see `sched::scenario::Scenario::stage_multipliers`).
    pub fn with_stage_multipliers(mut self, mult: Vec<f64>) -> PipelinePlan {
        assert_eq!(mult.len(), self.stages.len(), "one multiplier per stage");
        assert!(mult.iter().all(|&x| x > 0.0 && x.is_finite()), "bad multiplier");
        self.stage_mult = mult;
        self
    }

    /// Number of physical pipeline stages `P`.
    pub fn stage_count(&self) -> usize {
        self.stages.len()
    }

    /// Microbatches `M` per step.
    pub fn microbatches(&self) -> usize {
        self.stages[0].grad_accum
    }

    /// The closed-form pipeline-bubble bound for equal stages and free
    /// communication: `(P-1)/(V·M + P-1)` — the classic `(P-1)/(M+P-1)`
    /// 1F1B bound at `V = 1`, tightened `V`-fold by interleaving.
    pub fn ideal_bubble(p: usize, m: usize, v: usize) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        (p - 1) as f64 / ((v * m) as f64 + (p - 1) as f64)
    }

    /// Fraction of the pipeline's compute window its compute streams sat
    /// idle: `1 - Σ_s busy_s / (P · window)` where the window spans the
    /// first compute start to the last compute end. Includes stalls the
    /// ZeRO gathers and stage transfers induce (this is the *simulated*
    /// bubble); with zero communication and equal stages it equals
    /// [`PipelinePlan::ideal_bubble`].
    pub fn bubble_fraction(&self, sched: &Schedule) -> f64 {
        let mut t0 = f64::INFINITY;
        let mut t1 = f64::NEG_INFINITY;
        let mut busy = 0.0;
        for span in sched.spans() {
            if sched.graph().task(span.task).stream == StreamKind::Compute {
                busy += span.end - span.start;
                t0 = t0.min(span.start);
                t1 = t1.max(span.end);
            }
        }
        if t1 <= t0 {
            return 0.0;
        }
        (1.0 - busy / (self.stage_count() as f64 * (t1 - t0))).max(0.0)
    }

    /// Build the pipeline step DAG over one representative DP rank per
    /// stage, then hand it to [`crate::sched::simulate`].
    pub fn simulate(&self) -> Schedule {
        sched::simulate(self.build())
    }

    /// Build the pipeline step DAG: per-stage compute units in 1F1B (or
    /// interleaved) order, stage-boundary transfers on the pipe streams,
    /// per-(stage, microbatch) ZeRO gathers gated by [`Depth`], and the
    /// per-stage refresh + gradient-sync chain.
    pub fn build(&self) -> TaskGraph {
        let p = self.stage_count();
        let m = self.microbatches();
        let v = self.interleave;
        let nvirt = p * v;
        let mut g = TaskGraph::with_rank_ids(self.rep_ranks.clone());
        // pre-size the arena: each (virtual stage, microbatch, direction)
        // unit adds at most a gather + compute + p2p transfer; per-stage
        // refresh and the sync chains ride on top (DESIGN.md §16)
        g.reserve(nvirt * m * 2 * 3 + p * (2 + self.stages[0].sync.len()));

        // previous step's §V.D refresh occupies each stage's grad head
        for (s, sp) in self.stages.iter().enumerate() {
            if sp.t_update > 0.0 {
                g.add(Task {
                    label: format!("update-gather@s{s}"),
                    rank: self.rep_ranks[s],
                    stream: StreamKind::GradSync,
                    work: sp.t_update,
                    class: Some(sp.class_update),
                    instance: instance_of(&self.cluster, sp.class_update, self.rep_ranks[s]),
                    deps: vec![],
                });
            }
        }

        // prefetch gate: the stage's k-th issued gather may start once
        // the first consumer of gather k-1-depth has finished (the exact
        // StepPlan semantics, generalized to the 1F1B consumption order)
        let gate = |consumers: &[TaskId], k: usize| -> Vec<TaskId> {
            match self.stages[0].depth {
                Depth::Bounded(d) => {
                    let idx = k as i64 - 1 - d as i64;
                    if idx >= 0 {
                        vec![consumers[idx as usize]]
                    } else {
                        vec![]
                    }
                }
                Depth::Infinite => vec![],
            }
        };

        let orders: Vec<Vec<Unit>> = (0..p).map(|s| stage_order(s, p, m, v)).collect();
        let mut next = vec![0usize; p];
        let mut fwd_task: Vec<Vec<Option<TaskId>>> = vec![vec![None; m]; nvirt];
        let mut bwd_task: Vec<Vec<Option<TaskId>>> = vec![vec![None; m]; nvirt];
        let mut fwd_gather: Vec<Vec<Option<TaskId>>> = vec![vec![None; m]; p];
        let mut bwd_gather: Vec<Vec<Option<TaskId>>> = vec![vec![None; m]; p];
        let mut gather_consumers: Vec<Vec<TaskId>> = vec![Vec::new(); p];
        let mut last_compute: Vec<Option<TaskId>> = vec![None; p];

        // merge the per-stage orders into one global insertion order:
        // round-robin over stages, adding each stage's next units while
        // their cross-stage producers are already in the graph
        let total: usize = orders.iter().map(|o| o.len()).sum();
        let mut added = 0usize;
        while added < total {
            let mut progressed = false;
            for s in 0..p {
                while next[s] < orders[s].len() {
                    let unit = orders[s][next[s]];
                    let ready = match unit {
                        Unit::Fwd { v: c, m: mm } => {
                            let j = c * p + s;
                            j == 0 || fwd_task[j - 1][mm].is_some()
                        }
                        Unit::Bwd { v: c, m: mm } => {
                            let j = c * p + s;
                            if j == nvirt - 1 {
                                fwd_task[j][mm].is_some()
                            } else {
                                bwd_task[j + 1][mm].is_some()
                            }
                        }
                    };
                    if !ready {
                        break;
                    }
                    let sp = &self.stages[s];
                    let rep = self.rep_ranks[s];
                    // a layered stage gathers per chunk: its blocks are
                    // exactly its chunk slice, so every (chunk, microbatch)
                    // unit issues its own gather and Depth gates the stage
                    // in chunks ahead of the compute cursor (§12)
                    let layered_stage = sp.blocks.len() > 1;
                    let issue_gather = |g: &mut TaskGraph,
                                        consumers: &[TaskId],
                                        label: String,
                                        work: f64,
                                        class: LinkClass|
                     -> TaskId {
                        g.add(Task {
                            label,
                            rank: rep,
                            stream: StreamKind::Prefetch,
                            work,
                            class: Some(class),
                            instance: instance_of(&self.cluster, class, rep),
                            deps: gate(consumers, consumers.len()),
                        })
                    };
                    match unit {
                        Unit::Fwd { v: c, m: mm } => {
                            let j = c * p + s;
                            let (gid, fresh) = if layered_stage {
                                let t = issue_gather(
                                    &mut g,
                                    &gather_consumers[s],
                                    format!("gather.fwd[{mm}]c{c}@s{s}"),
                                    sp.blocks[c].t_gather_fwd,
                                    sp.class_fwd,
                                );
                                (t, true)
                            } else if let Some(t) = fwd_gather[s][mm] {
                                (t, false)
                            } else {
                                let t = issue_gather(
                                    &mut g,
                                    &gather_consumers[s],
                                    format!("gather.fwd[{mm}]@s{s}"),
                                    sp.t_gather_fwd,
                                    sp.class_fwd,
                                );
                                fwd_gather[s][mm] = Some(t);
                                (t, true)
                            };
                            let mut deps = vec![gid];
                            if j > 0 {
                                let prod = fwd_task[j - 1][mm].expect("producer added");
                                let from = (j - 1) % p;
                                deps.push(g.add(Task {
                                    label: format!("p2p.act[m{mm}c{c}]@s{from}>s{s}"),
                                    rank: rep,
                                    stream: StreamKind::PipeTransfer,
                                    work: self.t_act,
                                    class: Some(self.class_p2p),
                                    instance: 0,
                                    deps: vec![prod],
                                }));
                            }
                            let ct = g.add(Task {
                                label: format!("compute.fwd[{mm}]c{c}@s{s}"),
                                rank: rep,
                                stream: StreamKind::Compute,
                                work: sp.t_compute_fwd
                                    * self.chunk_frac[s][c]
                                    * self.stage_mult[s],
                                class: None,
                                instance: 0,
                                deps,
                            });
                            fwd_task[j][mm] = Some(ct);
                            if fresh {
                                gather_consumers[s].push(ct);
                            }
                            last_compute[s] = Some(ct);
                        }
                        Unit::Bwd { v: c, m: mm } => {
                            let j = c * p + s;
                            let (gid, fresh) = if layered_stage {
                                let t = issue_gather(
                                    &mut g,
                                    &gather_consumers[s],
                                    format!("gather.bwd[{mm}]c{c}@s{s}"),
                                    sp.blocks[c].t_gather_bwd,
                                    sp.class_bwd,
                                );
                                (t, true)
                            } else if let Some(t) = bwd_gather[s][mm] {
                                (t, false)
                            } else {
                                let t = issue_gather(
                                    &mut g,
                                    &gather_consumers[s],
                                    format!("gather.bwd[{mm}]@s{s}"),
                                    sp.t_gather_bwd,
                                    sp.class_bwd,
                                );
                                bwd_gather[s][mm] = Some(t);
                                (t, true)
                            };
                            let mut deps = vec![gid];
                            if j == nvirt - 1 {
                                deps.push(fwd_task[j][mm].expect("own forward added"));
                            } else {
                                let prod = bwd_task[j + 1][mm].expect("producer added");
                                let from = (j + 1) % p;
                                deps.push(g.add(Task {
                                    label: format!("p2p.grad[m{mm}c{c}]@s{from}>s{s}"),
                                    rank: rep,
                                    stream: StreamKind::PipeTransfer,
                                    work: self.t_grad,
                                    class: Some(self.class_p2p),
                                    instance: 0,
                                    deps: vec![prod],
                                }));
                            }
                            let ct = g.add(Task {
                                label: format!("compute.bwd[{mm}]c{c}@s{s}"),
                                rank: rep,
                                stream: StreamKind::Compute,
                                work: sp.t_compute_bwd
                                    * self.chunk_frac[s][c]
                                    * self.stage_mult[s],
                                class: None,
                                instance: 0,
                                deps,
                            });
                            bwd_task[j][mm] = Some(ct);
                            if fresh {
                                gather_consumers[s].push(ct);
                            }
                            last_compute[s] = Some(ct);
                        }
                    }
                    next[s] += 1;
                    added += 1;
                    progressed = true;
                }
            }
            // the 1F1B / interleaved orders are feasible by construction;
            // a stalled merge means a malformed hand-built plan
            assert!(progressed, "infeasible pipeline schedule order (stages {p}, m {m}, v {v})");
        }

        // gradient-sync phases per stage, after the stage's last unit
        for (s, sp) in self.stages.iter().enumerate() {
            let mut prev = last_compute[s].expect("every stage owns compute units");
            for (k, phase) in sp.sync.iter().enumerate() {
                prev = g.add(Task {
                    label: format!("grad-sync[{k}]@s{s}"),
                    rank: self.rep_ranks[s],
                    stream: StreamKind::GradSync,
                    work: phase.seconds,
                    class: Some(phase.class),
                    instance: instance_of(&self.cluster, phase.class, self.rep_ranks[s]),
                    deps: vec![prev],
                });
            }
        }
        g
    }
}

/// One compute unit of a pipeline schedule: chunk `v`'s forward or
/// backward pass of microbatch `m` on some stage.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Unit {
    Fwd { v: usize, m: usize },
    Bwd { v: usize, m: usize },
}

/// Stage `s`'s compute order. `v = 1`: textbook 1F1B — `min(P-1-s, M)`
/// warmup forwards, then one-forward-one-backward, then the cooldown
/// backwards. `v > 1`: Megatron's interleaved order — forwards grouped
/// as (microbatch group of `P`) × (chunk) × (index in group), backwards
/// with the chunk order reversed, warmup `min(2(P-1-s) + (V-1)P, MV)`.
fn stage_order(s: usize, p: usize, m: usize, v: usize) -> Vec<Unit> {
    let (fwd, bwd): (Vec<(usize, usize)>, Vec<(usize, usize)>) = if v == 1 {
        ((0..m).map(|mm| (0, mm)).collect(), (0..m).map(|mm| (0, mm)).collect())
    } else {
        debug_assert!(m % p == 0, "interleave needs m % p == 0");
        let mut f = Vec::with_capacity(m * v);
        let mut b = Vec::with_capacity(m * v);
        for grp in 0..m / p {
            for c in 0..v {
                for i in 0..p {
                    f.push((c, grp * p + i));
                    b.push((v - 1 - c, grp * p + i));
                }
            }
        }
        (f, b)
    };
    let total = m * v;
    let warmup = if v == 1 {
        (p - 1 - s).min(total)
    } else {
        (2 * (p - 1 - s) + (v - 1) * p).min(total)
    };
    let mut order = Vec::with_capacity(2 * total);
    for &(c, mm) in &fwd[..warmup] {
        order.push(Unit::Fwd { v: c, m: mm });
    }
    let mut bi = 0;
    for &(c, mm) in &fwd[warmup..] {
        order.push(Unit::Fwd { v: c, m: mm });
        let (bc, bm) = bwd[bi];
        order.push(Unit::Bwd { v: bc, m: bm });
        bi += 1;
    }
    for &(c, mm) in &bwd[bi..] {
        order.push(Unit::Bwd { v: c, m: mm });
    }
    order
}

/// Near-even contiguous split of `n` items into `chunks` parts: the
/// first `n % chunks` parts get one extra item; parts may be empty when
/// `n < chunks` (layer counts not divisible by `P·V` still partition).
pub fn split_even(n: usize, chunks: usize) -> Vec<usize> {
    assert!(chunks > 0, "need at least one chunk");
    let base = n / chunks;
    let extra = n % chunks;
    (0..chunks).map(|c| base + usize::from(c < extra)).collect()
}

/// Upper bound on the microbatch chunks stage `stage` holds live
/// activations for under the 1F1B / interleaved schedule: warmup depth
/// plus the one chunk in flight (`v = 1`: the textbook `P − s` bound;
/// `v > 1`: `2(P−1−s) + (V−1)P + 1`), capped at the `M·V` chunks the
/// stage runs per step. `microbatches = 0` means "not yet resolved" and
/// keeps the uncapped steady-state bound (`M ≥ P` assumed). `P = 1`
/// degenerates to 1: data-parallel runs one microbatch's forward +
/// backward at a time. This is the activation term of the
/// schedule-aware memory ledger ([`crate::memory::fit_report`],
/// DESIGN.md §15); the warmup formulas mirror `stage_order` exactly.
pub fn in_flight_chunks(
    stages: usize,
    microbatches: usize,
    interleave: usize,
    stage: usize,
) -> usize {
    let p = stages.max(1);
    if p == 1 {
        return 1;
    }
    let s = stage.min(p - 1);
    let v = interleave.max(1);
    let warmup = if v == 1 { p - 1 - s } else { 2 * (p - 1 - s) + (v - 1) * p };
    let in_flight = warmup + 1;
    if microbatches > 0 {
        in_flight.min(microbatches * v).max(1)
    } else {
        in_flight
    }
}

/// Even `u64` parameter split for callers that know only a flat total
/// (the engine's proxy manifests): near-even like [`split_even`], summing
/// exactly to `total`.
pub fn even_chunk_params(total: u64, chunks: usize) -> Vec<u64> {
    assert!(chunks > 0, "need at least one chunk");
    let base = total / chunks as u64;
    let extra = (total % chunks as u64) as usize;
    (0..chunks).map(|c| base + u64::from(c < extra)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::cost::CommEfficiency;

    fn frontier_plan(
        scheme: Scheme,
        nodes: usize,
        pipe: &PipeConfig,
        depth: Depth,
    ) -> Result<PipelinePlan, PipelineError> {
        frontier_plan_opts(scheme, nodes, pipe, depth, false)
    }

    fn frontier_plan_opts(
        scheme: Scheme,
        nodes: usize,
        pipe: &PipeConfig,
        depth: Depth,
        layered: bool,
    ) -> Result<PipelinePlan, PipelineError> {
        let cluster = Cluster::frontier(nodes);
        let cost = CostModel::with_efficiency(cluster, CommEfficiency::rccl_frontier());
        let chunks = even_chunk_params(2_000_000_000, pipe.chunks());
        PipelinePlan::from_protocol(
            &cost,
            scheme,
            pipe,
            &chunks,
            256,
            25_000_000,
            4.0,
            depth,
            layered,
        )
    }

    #[test]
    fn pipeline_schedules_conserve_the_makespan_ledger() {
        // 1F1B and interleaved, monolithic and layered: the critical-path
        // ledger must tile the makespan exactly on every variant
        for (interleave, layered) in [(1, false), (2, false), (1, true), (2, true)] {
            let pipe = PipeConfig { stages: 4, microbatches: 8, interleave };
            let pp = frontier_plan_opts(
                Scheme::ZeroTopo { sec_degree: 2 },
                4,
                &pipe,
                Depth::Bounded(1),
                layered,
            )
            .unwrap();
            let sched = pp.simulate();
            let d = crate::sched::critical::decompose(&sched);
            assert!(
                d.conservation_error() <= 1e-12,
                "V={interleave} layered={layered}: conservation error {:.3e}",
                d.conservation_error()
            );
            assert_eq!(d.makespan(), sched.makespan());
        }
    }

    #[test]
    fn one_stage_matches_step_plan_spans() {
        for scheme in [Scheme::Zero3, Scheme::ZeroPP, Scheme::ZeroTopo { sec_degree: 2 }] {
            for depth in [Depth::Bounded(0), Depth::Bounded(1), Depth::Infinite] {
                let pipe = PipeConfig { stages: 1, microbatches: 4, interleave: 1 };
                let pp = frontier_plan(scheme, 4, &pipe, depth).unwrap();
                let single = pp.stages[0].simulate();
                let sched = pp.simulate();
                assert_eq!(single.makespan(), sched.makespan(), "{scheme:?} {depth:?}");
                assert_eq!(single.spans().len(), sched.spans().len());
                for (a, b) in single.spans().iter().zip(sched.spans()) {
                    assert_eq!(a.start, b.start);
                    assert_eq!(a.end, b.end);
                }
            }
        }
    }

    #[test]
    fn synthetic_1f1b_hits_the_closed_form_bubble() {
        for (p, m) in [(2, 4), (4, 8), (4, 1), (8, 3)] {
            let plan = PipelinePlan::synthetic(p, m, 1, 1.0, 2.0, Depth::Infinite);
            let sched = plan.simulate();
            let bubble = plan.bubble_fraction(&sched);
            let bound = PipelinePlan::ideal_bubble(p, m, 1);
            assert!((bubble - bound).abs() < 1e-9, "p={p} m={m}: {bubble} vs {bound}");
            // and the makespan is exactly (M + P - 1) * (tf + tb)
            let mk = sched.makespan();
            let want = (m + p - 1) as f64 * 3.0;
            assert!((mk - want).abs() < 1e-9, "p={p} m={m}: {mk} vs {want}");
        }
    }

    #[test]
    fn synthetic_interleave_tightens_the_bubble() {
        for (p, m, v) in [(2, 4, 2), (4, 8, 2), (4, 8, 4), (3, 6, 3)] {
            let plain = PipelinePlan::synthetic(p, m, 1, 1.0, 2.0, Depth::Infinite);
            let inter = PipelinePlan::synthetic(p, m, v, 1.0, 2.0, Depth::Infinite);
            let b1 = plain.bubble_fraction(&plain.simulate());
            let bv = inter.bubble_fraction(&inter.simulate());
            let bound = PipelinePlan::ideal_bubble(p, m, v);
            assert!((bv - bound).abs() < 1e-9, "p={p} m={m} v={v}: {bv} vs {bound}");
            assert!(bv < b1, "p={p} m={m} v={v}: {bv} !< {b1}");
        }
    }

    #[test]
    fn worst_case_single_microbatch_bubble() {
        let plan = PipelinePlan::synthetic(4, 1, 1, 1.0, 2.0, Depth::Infinite);
        let bubble = plan.bubble_fraction(&plan.simulate());
        assert!((bubble - 0.75).abs() < 1e-9, "{bubble}"); // (P-1)/P
    }

    #[test]
    fn stage_orders_cover_every_unit_once() {
        for (p, m, v) in [(1, 3, 1), (2, 5, 1), (4, 8, 2), (3, 6, 3), (8, 8, 1)] {
            for s in 0..p {
                let order = stage_order(s, p, m, v);
                assert_eq!(order.len(), 2 * m * v, "p={p} m={m} v={v} s={s}");
                let mut fwd = vec![vec![false; m]; v];
                let mut bwd = vec![vec![false; m]; v];
                for u in order {
                    match u {
                        Unit::Fwd { v: c, m: mm } => {
                            assert!(!fwd[c][mm]);
                            fwd[c][mm] = true;
                        }
                        Unit::Bwd { v: c, m: mm } => {
                            assert!(!bwd[c][mm]);
                            bwd[c][mm] = true;
                        }
                    }
                }
                assert!(fwd.iter().flatten().all(|&x| x));
                assert!(bwd.iter().flatten().all(|&x| x));
            }
        }
    }

    #[test]
    fn stages_must_divide_nodes() {
        let pipe = PipeConfig { stages: 3, microbatches: 4, interleave: 1 };
        assert!(matches!(
            frontier_plan(Scheme::Zero3, 4, &pipe, Depth::Infinite),
            Err(PipelineError::StagesDontDivideNodes { stages: 3, nodes: 4 })
        ));
    }

    #[test]
    fn interleave_requires_divisible_microbatches() {
        let pipe = PipeConfig { stages: 4, microbatches: 6, interleave: 2 };
        assert!(matches!(
            frontier_plan(Scheme::Zero3, 4, &pipe, Depth::Infinite),
            Err(PipelineError::InterleaveNeedsDivisibleMicrobatches { .. })
        ));
    }

    #[test]
    fn uneven_splits_partition_without_panicking() {
        assert_eq!(split_even(44, 8), vec![6, 6, 6, 6, 5, 5, 5, 5]);
        assert_eq!(split_even(3, 8), vec![1, 1, 1, 0, 0, 0, 0, 0]);
        assert_eq!(even_chunk_params(10, 4), vec![3, 3, 2, 2]);
        assert_eq!(even_chunk_params(10, 4).iter().sum::<u64>(), 10);
        // a pipeline over chunks with zero-parameter stages still builds
        let cluster = Cluster::frontier(4);
        let cost = CostModel::with_efficiency(cluster, CommEfficiency::rccl_frontier());
        let pipe = PipeConfig { stages: 4, microbatches: 4, interleave: 1 };
        let chunks = vec![1_000_000, 0, 1_000_000, 0];
        let plan = PipelinePlan::from_protocol(
            &cost,
            Scheme::Zero3,
            &pipe,
            &chunks,
            256,
            1_000_000,
            4.0,
            Depth::Infinite,
            false,
        )
        .unwrap();
        let sched = plan.simulate();
        assert!(sched.makespan().is_finite() && sched.makespan() > 0.0);
    }

    #[test]
    fn layered_stage_gathers_are_the_chunk_slice() {
        // layered + V=2: each stage holds 2 blocks (its chunk slice), and
        // the build issues one gather per (stage, microbatch, chunk)
        let pipe = PipeConfig { stages: 2, microbatches: 4, interleave: 2 };
        let plan = frontier_plan_opts(Scheme::Zero3, 4, &pipe, Depth::Infinite, true).unwrap();
        for sp in &plan.stages {
            assert_eq!(sp.blocks.len(), 2);
            let f: f64 = sp.blocks.iter().map(|b| b.t_gather_fwd).sum();
            assert!((f - sp.t_gather_fwd).abs() <= 1e-12 * sp.t_gather_fwd.max(1.0));
        }
        let g = plan.build();
        let per_chunk_gathers = g
            .tasks()
            .iter()
            .filter(|t| t.label.starts_with("gather.fwd[") && t.label.contains('c'))
            .count();
        // P=2 stages x M=4 microbatches x V=2 chunks
        assert_eq!(per_chunk_gathers, 2 * 4 * 2);
        let mk = plan.simulate().makespan();
        assert!(mk.is_finite() && mk > 0.0);
    }

    #[test]
    fn layered_with_single_chunk_stays_monolithic_bit_for_bit() {
        // V=1: a stage owns one chunk, so layered mode degenerates to the
        // monolithic per-stage gathers — schedules must be identical
        let pipe = PipeConfig { stages: 4, microbatches: 8, interleave: 1 };
        let a = frontier_plan_opts(Scheme::ZeroTopo { sec_degree: 2 }, 4, &pipe,
            Depth::Bounded(1), false)
        .unwrap()
        .simulate();
        let b = frontier_plan_opts(Scheme::ZeroTopo { sec_degree: 2 }, 4, &pipe,
            Depth::Bounded(1), true)
        .unwrap()
        .simulate();
        assert_eq!(a.makespan(), b.makespan());
        assert_eq!(a.spans().len(), b.spans().len());
        for (x, y) in a.spans().iter().zip(b.spans()) {
            assert_eq!((x.start, x.end), (y.start, y.end));
        }
    }

    #[test]
    fn layered_pipeline_depth_is_monotone() {
        let pipe = PipeConfig { stages: 2, microbatches: 8, interleave: 2 };
        let mk = |d: Depth| {
            frontier_plan_opts(Scheme::Zero3, 4, &pipe, d, true)
                .unwrap()
                .simulate()
                .makespan()
        };
        let t: Vec<f64> =
            [Depth::Bounded(0), Depth::Bounded(1), Depth::Bounded(4), Depth::Infinite]
                .iter()
                .map(|&d| mk(d))
                .collect();
        // p2p transfers share the inter-node domain with stage gathers, so
        // allow a hair of processor-sharing noise on top of monotone
        for w in t.windows(2) {
            assert!(w[1] <= w[0] * (1.0 + 1e-6), "{t:?}");
        }
    }

    #[test]
    fn transfers_ride_the_pipe_stream_and_fabric() {
        let pipe = PipeConfig { stages: 2, microbatches: 4, interleave: 1 };
        let plan = frontier_plan(Scheme::ZeroTopo { sec_degree: 2 }, 4, &pipe, Depth::Infinite)
            .unwrap();
        assert!(plan.t_act > 0.0);
        assert_eq!(plan.class_p2p, LinkClass::InterNode);
        let g = plan.build();
        let transfers: Vec<&Task> =
            g.tasks().iter().filter(|t| t.stream == StreamKind::PipeTransfer).collect();
        // (P-1) boundaries x M microbatches x (act + grad)
        assert_eq!(transfers.len(), 2 * 4);
        assert!(transfers.iter().all(|t| t.class == Some(LinkClass::InterNode)));
        // stage reps are the first ranks of each 2-node block
        assert_eq!(plan.rep_ranks, vec![0, 16]);
    }

    #[test]
    fn straggler_stage_stretches_the_step() {
        let pipe = PipeConfig { stages: 4, microbatches: 8, interleave: 1 };
        let base = frontier_plan(Scheme::Zero3, 4, &pipe, Depth::Infinite).unwrap();
        let base_mk = base.simulate().makespan();
        let slow = base.clone().with_stage_multipliers(vec![1.0, 1.5, 1.0, 1.0]);
        let mk = slow.simulate().makespan();
        assert!(mk > base_mk * 1.05, "{mk} vs {base_mk}");
    }

    #[test]
    fn pipe_config_helpers() {
        let pc = PipeConfig { stages: 1, microbatches: 4, interleave: 3 };
        assert_eq!(pc.effective_interleave(), 1);
        assert_eq!(pc.chunks(), 1);
        let pc = PipeConfig { stages: 4, microbatches: 8, interleave: 2 };
        assert_eq!(pc.effective_interleave(), 2);
        assert_eq!(pc.chunks(), 8);
        assert_eq!(PipeConfig::default().stages, 1);
    }

    #[test]
    fn ideal_bubble_closed_forms() {
        assert_eq!(PipelinePlan::ideal_bubble(1, 8, 1), 0.0);
        assert!((PipelinePlan::ideal_bubble(4, 8, 1) - 3.0 / 11.0).abs() < 1e-15);
        assert!((PipelinePlan::ideal_bubble(4, 8, 2) - 3.0 / 19.0).abs() < 1e-15);
        assert!((PipelinePlan::ideal_bubble(4, 1, 1) - 0.75).abs() < 1e-15);
    }

    #[test]
    fn in_flight_chunks_matches_1f1b_bound() {
        // textbook 1F1B: stage s holds min(P - s, M) microbatches
        for s in 0..4 {
            assert_eq!(in_flight_chunks(4, 8, 1, s), 4 - s);
        }
        // M caps the bound (short pipelines can't fill the warmup)
        assert_eq!(in_flight_chunks(4, 2, 1, 0), 2);
        // M = 0 (unresolved) keeps the steady-state bound
        assert_eq!(in_flight_chunks(4, 0, 1, 0), 4);
        // P = 1: one microbatch's activations at a time
        assert_eq!(in_flight_chunks(1, 8, 1, 0), 1);
        assert_eq!(in_flight_chunks(1, 0, 4, 0), 1);
        // interleaved: warmup 2(P-1-s) + (V-1)P, plus the one in flight
        assert_eq!(in_flight_chunks(4, 8, 2, 0), 2 * 3 + 4 + 1);
        assert_eq!(in_flight_chunks(4, 8, 2, 3), 4 + 1);
        // never exceeds the M*V chunks a stage runs
        assert_eq!(in_flight_chunks(4, 2, 2, 0), 4);
    }
}
