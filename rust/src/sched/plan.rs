//! The per-step task graph of the paper's Section V protocol, expressed
//! for the [`crate::sched`] event loop.
//!
//! [`StepPlan::from_protocol`] derives every communication duration from
//! the same α–β [`CostModel`] the collective engine charges (pure
//! `*_time` queries, no ledger mutation), so `sim::simulate_step` and
//! `engine::TrainEngine` price and schedule the comm side of a step
//! identically by construction (their compute anchors differ: the sim
//! uses the detailed FLOPs account, the engine the 6Ψ rule on its proxy
//! manifest). The graph per optimizer step (paper Figs 4–6):
//!
//! * per microbatch: a forward weight gather feeding the forward compute
//!   and a backward (secondary-partition) gather feeding the backward
//!   compute, both on the prefetch stream and bounded by [`Depth`];
//! * ZeRO-topo only: the §V.D updated-weight all-gather on the grad-sync
//!   stream at the step head (the refresh issued after the previous
//!   step's optimizer update, overlapping this step's compute in steady
//!   state);
//! * at the grad-accumulation boundary: the scheme's gradient-sync
//!   phases, sequential on the grad-sync stream, blocking the step end.
//!
//! # Layer-granular prefetch (DESIGN.md §12)
//!
//! [`StepPlan::from_protocol_layered`] splits each per-microbatch gather
//! into a chain of per-layer-block gather tasks — one per entry of the
//! model's contiguous layer-chunk partition
//! (`model::TransformerSpec::chunk_params`: embeddings ride the first
//! block, the LM head the last). Forward compute splits into per-block
//! units consuming their block's gather in layer order; backward consumes
//! the blocks in **reverse** order (the head's gradients flow first), so
//! [`Depth::Bounded`]`(d)` gates the prefetch stream at *`d` layer blocks*
//! ahead of the compute cursor — DeepSpeed's parameter-prefetch window
//! expressed in layers. Per-block gather times are the block's
//! [`CostModel::priced_all_gather`] share of the monolithic gather,
//! rescaled so they sum *exactly* to `t_gather_fwd`/`t_gather_bwd` (one
//! coalesced ring launch per microbatch window — the split never changes
//! the total gather volume or [`StepPlan::prefetchable_s`]). With one
//! block (or none) the plan is bit-for-bit today's monolithic schedule.

use crate::comm::cost::CostModel;
use crate::comm::Wire;
use crate::sched::{self, Depth, Schedule, StreamKind, Task, TaskGraph, TaskId};
use crate::sharding::{shard_groups, Scheme, ShardingSpec};
use crate::topology::LinkClass;

/// One gradient-sync phase: duration + the link class it occupies.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SyncPhase {
    /// Phase duration at unit rate.
    pub seconds: f64,
    /// Link class the phase occupies.
    pub class: LinkClass,
}

/// One layer block of a layer-granular plan: its share of the
/// per-microbatch weight gathers and of the microbatch compute. Blocks
/// are consumed in layer order forward and in reverse order backward;
/// their gather times sum to the plan's monolithic
/// `t_gather_fwd`/`t_gather_bwd` by construction (gather-splitting is
/// conservative — property-tested in `tests/layered_prefetch.rs`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LayerBlock {
    /// Per-microbatch forward gather seconds for this block.
    pub t_gather_fwd: f64,
    /// Per-microbatch backward (secondary) gather seconds for this block.
    pub t_gather_bwd: f64,
    /// This block's fraction of the per-microbatch compute (the block's
    /// parameter share; fractions sum to 1).
    pub compute_frac: f64,
}

/// Durations + structure of one optimizer step, ready to schedule.
#[derive(Debug, Clone)]
pub struct StepPlan {
    /// The ZeRO scheme the plan prices.
    pub scheme: Scheme,
    /// Gradient-accumulation microbatches per step.
    pub grad_accum: usize,
    /// Prefetch depth bounding the gather stream.
    pub depth: Depth,
    /// Per-microbatch forward weight gather.
    pub t_gather_fwd: f64,
    /// Link class of the forward gather.
    pub class_fwd: LinkClass,
    /// Per-microbatch backward (secondary) gather.
    pub t_gather_bwd: f64,
    /// Link class of the backward gather.
    pub class_bwd: LinkClass,
    /// §V.D updated-weight all-gather (0 for schemes without one).
    pub t_update: f64,
    /// Link class of the updated-weight gather.
    pub class_update: LinkClass,
    /// Per-microbatch forward compute.
    pub t_compute_fwd: f64,
    /// Per-microbatch backward compute (≈ 2× forward).
    pub t_compute_bwd: f64,
    /// Sequential gradient-sync phases at the accumulation boundary.
    pub sync: Vec<SyncPhase>,
    /// Forward gather group degree — the congruent-group shape a
    /// multi-rank builder needs to place each rank's gathers
    /// ([`crate::sched::multi::MultiRankPlan`]).
    pub d_fwd: usize,
    /// Backward (secondary) gather group degree.
    pub d_bwd: usize,
    /// Per-layer-block split of the microbatch gathers + compute
    /// (layer-granular prefetch, DESIGN.md §12). Empty (or a single
    /// entry) = monolithic whole-model gathers — today's schedule,
    /// bit-for-bit.
    pub blocks: Vec<LayerBlock>,
}

impl StepPlan {
    /// Derive the plan for `(scheme, cluster)` from the cost model:
    /// `n_elems` = ψ (flat parameter count), `compute_s` = total compute
    /// seconds for the whole step (all `grad_accum` microbatches).
    #[allow(clippy::too_many_arguments)]
    pub fn from_protocol(
        cost: &CostModel,
        scheme: Scheme,
        spec: &ShardingSpec,
        n_elems: usize,
        quant_block: usize,
        grad_accum: usize,
        compute_s: f64,
        depth: Depth,
    ) -> StepPlan {
        let cluster = &cost.cluster;
        let world = cluster.world_size();
        let block = quant_block;
        let (fwd_wire, bwd_wire) = if scheme.quantized() {
            (Wire::Int8 { block }, Wire::Int8 { block })
        } else {
            (Wire::F16, Wire::F16)
        };

        // rank 0's groups; all groups of a degree are congruent, so rank
        // 0's time IS the per-rank step contribution
        let group_time = |degree: usize, wire: Wire| -> (f64, LinkClass) {
            if degree <= 1 {
                return (0.0, LinkClass::Local);
            }
            let g: Vec<usize> = (0..degree).collect();
            cost.priced_all_gather(&g, wire.wire_bytes(n_elems) as u64)
        };
        let (t_gather_fwd, class_fwd) = group_time(spec.weights, fwd_wire);
        let bwd_degree = if spec.secondary > 0 { spec.secondary } else { spec.weights };
        let (t_gather_bwd, class_bwd) = group_time(bwd_degree, bwd_wire);

        // ZeRO-topo's §V.D updated-weight gather spans the optimizer group
        let (t_update, class_update) = if matches!(scheme, Scheme::ZeroTopo { .. }) {
            group_time(world, fwd_wire)
        } else {
            (0.0, LinkClass::Local)
        };

        let full: Vec<usize> = (0..world).collect();
        let mut sync = Vec::new();
        match scheme {
            Scheme::Zero1 | Scheme::Zero2 => {
                let (t, class) =
                    cost.priced_all_reduce(&full, Wire::F16.wire_bytes(n_elems) as u64);
                sync.push(SyncPhase { seconds: t, class });
            }
            Scheme::Zero3 => {
                // ring reduce-scatter: same pattern/pricing as the gather
                let (t, class) =
                    cost.priced_all_gather(&full, Wire::F16.wire_bytes(n_elems) as u64);
                sync.push(SyncPhase { seconds: t, class });
            }
            Scheme::ZeroPP => {
                let (t, class) = cost
                    .priced_all_to_all(&full, Wire::Int4 { block }.wire_bytes(n_elems) as u64);
                sync.push(SyncPhase { seconds: t, class });
            }
            Scheme::ZeroTopo { .. } => {
                let p = cluster.workers_per_node();
                let node0: Vec<usize> = (0..p).collect();
                let (t1, class1) = cost
                    .priced_all_to_all(&node0, Wire::Int4 { block }.wire_bytes(n_elems) as u64);
                sync.push(SyncPhase { seconds: t1, class: class1 });
                if cluster.nodes > 1 {
                    // the P cross-node groups are congruent (one rank per
                    // node each) and funnel through each node's NIC: their
                    // bandwidth terms serialize — one phase, P × one group
                    let shard_bytes = Wire::F16.wire_bytes(n_elems / p) as u64;
                    let group: Vec<usize> = (0..cluster.nodes).map(|m| m * p).collect();
                    let (t, class) = cost.priced_all_reduce(&group, shard_bytes);
                    sync.push(SyncPhase { seconds: p as f64 * t, class });
                }
            }
            Scheme::Mics { .. } | Scheme::FsdpHybrid { .. } => {
                let g = spec.grads;
                let groups = shard_groups(world, g);
                let (t1, class1) =
                    cost.priced_all_gather(&groups[0], Wire::F16.wire_bytes(n_elems) as u64);
                sync.push(SyncPhase { seconds: t1, class: class1 });
                let n_groups = world / g;
                if n_groups > 1 {
                    // g congruent replica groups, serialized like above
                    let shard_bytes = Wire::F16.wire_bytes(n_elems / g) as u64;
                    let group: Vec<usize> = (0..n_groups).map(|m| m * g).collect();
                    let (t, class) = cost.priced_all_reduce(&group, shard_bytes);
                    sync.push(SyncPhase { seconds: g as f64 * t, class });
                }
            }
        }

        let ga = grad_accum.max(1);
        StepPlan {
            scheme,
            grad_accum: ga,
            depth,
            t_gather_fwd,
            class_fwd,
            t_gather_bwd,
            class_bwd,
            t_update,
            class_update,
            t_compute_fwd: compute_s / (3.0 * ga as f64),
            t_compute_bwd: 2.0 * compute_s / (3.0 * ga as f64),
            sync,
            d_fwd: spec.weights,
            d_bwd: bwd_degree,
            blocks: Vec::new(),
        }
    }

    /// [`StepPlan::from_protocol`] with the per-microbatch gathers and
    /// compute split over `block_elems` contiguous layer blocks
    /// (`block_elems[b]` = parameter count of block `b`; the model side
    /// produces these via `TransformerSpec::chunk_params`). Each block's
    /// gather is priced by [`CostModel::priced_all_gather`] on its own
    /// wire bytes, then the per-block times are rescaled to sum exactly
    /// to the monolithic `t_gather_fwd`/`t_gather_bwd` (one coalesced
    /// ring launch per window — the ring setup latency is amortized
    /// across the blocks, and the total gather volume is unchanged).
    /// A single block degenerates to [`StepPlan::from_protocol`]
    /// bit-for-bit.
    #[allow(clippy::too_many_arguments)]
    pub fn from_protocol_layered(
        cost: &CostModel,
        scheme: Scheme,
        spec: &ShardingSpec,
        block_elems: &[u64],
        quant_block: usize,
        grad_accum: usize,
        compute_s: f64,
        depth: Depth,
    ) -> StepPlan {
        assert!(!block_elems.is_empty(), "need at least one layer block");
        let n_elems = block_elems.iter().sum::<u64>() as usize;
        let mut plan = StepPlan::from_protocol(
            cost,
            scheme,
            spec,
            n_elems,
            quant_block,
            grad_accum,
            compute_s,
            depth,
        );
        if block_elems.len() > 1 {
            plan.blocks = layer_blocks_of(cost, scheme, block_elems, quant_block, &plan);
        }
        plan
    }

    /// Number of layer blocks the microbatch gathers are split into
    /// (1 = monolithic).
    pub fn layer_blocks(&self) -> usize {
        self.blocks.len().max(1)
    }

    /// Forward-phase consumption order: `(block id, gather seconds,
    /// compute seconds)` per layer block, layer order. Monolithic plans
    /// return the single whole-model entry. Shared by the single-rank,
    /// multi-rank and pipeline builders so their gather chains can never
    /// disagree.
    pub fn fwd_blocks(&self) -> Vec<(usize, f64, f64)> {
        if self.blocks.len() <= 1 {
            return vec![(0, self.t_gather_fwd, self.t_compute_fwd)];
        }
        self.blocks
            .iter()
            .enumerate()
            .map(|(b, lb)| (b, lb.t_gather_fwd, self.t_compute_fwd * lb.compute_frac))
            .collect()
    }

    /// Backward-phase consumption order: like [`StepPlan::fwd_blocks`]
    /// but blocks in **reverse** layer order (the head's gradients flow
    /// first, so the backward gather chain consumes tail blocks first).
    pub fn bwd_blocks(&self) -> Vec<(usize, f64, f64)> {
        if self.blocks.len() <= 1 {
            return vec![(0, self.t_gather_bwd, self.t_compute_bwd)];
        }
        self.blocks
            .iter()
            .enumerate()
            .rev()
            .map(|(b, lb)| (b, lb.t_gather_bwd, self.t_compute_bwd * lb.compute_frac))
            .collect()
    }

    /// Total prefetchable gather seconds (microbatch gathers + update).
    pub fn prefetchable_s(&self) -> f64 {
        self.grad_accum as f64 * (self.t_gather_fwd + self.t_gather_bwd) + self.t_update
    }

    /// Total blocking gradient-sync seconds.
    pub fn grad_sync_s(&self) -> f64 {
        self.sync.iter().map(|p| p.seconds).sum()
    }

    /// Total compute seconds across all microbatches.
    pub fn compute_s(&self) -> f64 {
        self.grad_accum as f64 * (self.t_compute_fwd + self.t_compute_bwd)
    }

    /// The no-overlap reference: compute + per-microbatch gathers + sync,
    /// all strictly serialized. Depth 0 degenerates to exactly this (the
    /// update gather rides the grad-sync stream and stays overlapped).
    pub fn serialized_s(&self) -> f64 {
        self.compute_s()
            + self.grad_accum as f64 * (self.t_gather_fwd + self.t_gather_bwd)
            + self.grad_sync_s()
    }

    /// Build the step DAG for one rank.
    pub fn build(&self, rank: usize) -> TaskGraph {
        let mut g = TaskGraph::new();
        // previous step's §V.D refresh occupies the grad stream head
        if self.t_update > 0.0 {
            g.add(Task {
                label: "update-gather".into(),
                rank,
                stream: StreamKind::GradSync,
                work: self.t_update,
                class: Some(self.class_update),
                instance: 0,
                deps: vec![],
            });
        }
        // consumer order per microbatch: forward blocks in layer order,
        // then backward blocks in reverse layer order (monolithic: cf_m,
        // cb_m). Gather j (feeding consumer j) may start once consumer
        // j-1-depth has finished — with layer blocks, `depth` counts
        // *blocks* ahead of the compute cursor (DESIGN.md §12).
        let fwd = self.fwd_blocks();
        let bwd = self.bwd_blocks();
        let layered = self.blocks.len() > 1;
        let total = (fwd.len() + bwd.len()) * self.grad_accum;
        // pre-size the arena: gather + compute per consumer slot, plus the
        // sync chain and the refresh task already added (DESIGN.md §16)
        g.reserve(total * 2 + self.sync.len());
        let mut consumers: Vec<TaskId> = Vec::with_capacity(total);
        let gate = |consumers: &[TaskId], j: usize| -> Vec<TaskId> {
            match self.depth {
                // a depth >= the number of consumers never gates anything
                Depth::Bounded(d) if d < total => {
                    let k = j as i64 - 1 - d as i64;
                    if k >= 0 {
                        vec![consumers[k as usize]]
                    } else {
                        vec![]
                    }
                }
                _ => vec![],
            }
        };
        for m in 0..self.grad_accum {
            for (name, class, blocks) in
                [("fwd", self.class_fwd, &fwd), ("bwd", self.class_bwd, &bwd)]
            {
                for &(bid, t_gather, t_compute) in blocks {
                    let suffix =
                        if layered { format!("b{bid}") } else { String::new() };
                    let gt = g.add(Task {
                        label: format!("gather.{name}[{m}]{suffix}"),
                        rank,
                        stream: StreamKind::Prefetch,
                        work: t_gather,
                        class: Some(class),
                        instance: 0,
                        deps: gate(&consumers, consumers.len()),
                    });
                    let ct = g.add(Task {
                        label: format!("compute.{name}[{m}]{suffix}"),
                        rank,
                        stream: StreamKind::Compute,
                        work: t_compute,
                        class: None,
                        instance: 0,
                        deps: vec![gt],
                    });
                    consumers.push(ct);
                }
            }
        }
        let mut prev = *consumers.last().expect("grad_accum >= 1");
        for (k, phase) in self.sync.iter().enumerate() {
            prev = g.add(Task {
                label: format!("grad-sync[{k}]"),
                rank,
                stream: StreamKind::GradSync,
                work: phase.seconds,
                class: Some(phase.class),
                instance: 0,
                deps: vec![prev],
            });
        }
        g
    }

    /// Build for the representative rank and run the event loop. All
    /// ranks' streams are congruent under the symmetric protocol, so rank
    /// 0's makespan is the simulated step time.
    pub fn simulate(&self) -> Schedule {
        sched::simulate(self.build(0))
    }
}

/// Largest parameter count simultaneously live on the gather stream: the
/// maximum sum of `d + 1` consecutive entries of `block_elems` under
/// [`Depth::Bounded`]`(d)` (the block being consumed plus up to `d`
/// prefetched ahead of the compute cursor — the gate in
/// [`StepPlan::build`]), or the whole model under [`Depth::Infinite`].
/// A monolithic split (`block_elems.len() == 1`) returns the full
/// parameter count at any depth: the one gather materializes everything.
/// This is the window term of the schedule-aware memory ledger
/// ([`crate::memory::fit_report`], DESIGN.md §15).
pub fn gather_window_params(block_elems: &[u64], depth: Depth) -> u64 {
    if block_elems.is_empty() {
        return 0;
    }
    let w = match depth {
        Depth::Infinite => block_elems.len(),
        Depth::Bounded(d) => d.saturating_add(1).min(block_elems.len()),
    };
    block_elems
        .windows(w)
        .map(|win| win.iter().sum::<u64>())
        .max()
        .unwrap_or(0)
}

/// Split the plan's per-microbatch gather times over contiguous layer
/// blocks: price each block's all-gather on its own wire bytes via
/// [`CostModel::priced_all_gather`], then rescale so the block times sum
/// exactly to the monolithic `t_gather_fwd`/`t_gather_bwd` (one coalesced
/// ring launch per microbatch window — the per-block pricing only decides
/// how the window divides, never its total). Compute fractions are the
/// blocks' parameter shares.
fn layer_blocks_of(
    cost: &CostModel,
    scheme: Scheme,
    block_elems: &[u64],
    quant_block: usize,
    plan: &StepPlan,
) -> Vec<LayerBlock> {
    let wire =
        if scheme.quantized() { Wire::Int8 { block: quant_block } } else { Wire::F16 };
    let total: u64 = block_elems.iter().sum();
    let raw = |degree: usize| -> Vec<f64> {
        if degree <= 1 {
            return vec![0.0; block_elems.len()];
        }
        let g: Vec<usize> = (0..degree).collect();
        block_elems
            .iter()
            .map(|&e| cost.priced_all_gather(&g, wire.wire_bytes(e as usize) as u64).0)
            .collect()
    };
    let share = |raw: &[f64], total_t: f64| -> Vec<f64> {
        let s: f64 = raw.iter().sum();
        if s > 0.0 {
            raw.iter().map(|&r| total_t * (r / s)).collect()
        } else {
            // zero-time gathers (degree <= 1): nothing to distribute
            vec![total_t / raw.len() as f64; raw.len()]
        }
    };
    // the plan already resolved the gather group degrees in from_protocol
    let fwd = share(&raw(plan.d_fwd), plan.t_gather_fwd);
    let bwd = share(&raw(plan.d_bwd), plan.t_gather_bwd);
    block_elems
        .iter()
        .enumerate()
        .map(|(b, &e)| LayerBlock {
            t_gather_fwd: fwd[b],
            t_gather_bwd: bwd[b],
            compute_frac: if total > 0 {
                e as f64 / total as f64
            } else {
                1.0 / block_elems.len() as f64
            },
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::cost::CommEfficiency;
    use crate::topology::Cluster;

    fn plan(scheme: Scheme, nodes: usize, depth: Depth) -> StepPlan {
        let cluster = Cluster::frontier(nodes);
        let cost = CostModel::with_efficiency(cluster.clone(), CommEfficiency::rccl_frontier());
        let spec = ShardingSpec::resolve(scheme, &cluster).unwrap();
        let psi = 1_000_000_000usize;
        StepPlan::from_protocol(&cost, scheme, &spec, psi, 256, 4, 2.0, depth)
    }

    #[test]
    fn depth_zero_serializes_exactly() {
        // no update gather for ZeRO-3: depth 0 == the serialized reference
        let p = plan(Scheme::Zero3, 4, Depth::Bounded(0));
        let mk = p.simulate().makespan();
        assert!((mk - p.serialized_s()).abs() < 1e-9 * p.serialized_s(), "{mk}");
    }

    #[test]
    fn infinite_depth_hides_gathers_under_compute() {
        // ZeRO-topo gathers are tiny GCD-pair transfers: with unbounded
        // prefetch the step collapses to ~ first gather + compute + sync
        let p = plan(Scheme::ZeroTopo { sec_degree: 2 }, 4, Depth::Infinite);
        let mk = p.simulate().makespan();
        let floor = p.compute_s() + p.grad_sync_s();
        assert!(mk >= floor - 1e-12, "{mk} < {floor}");
        assert!(mk <= floor + 2.0 * (p.t_gather_fwd + p.t_gather_bwd), "{mk} vs {floor}");
    }

    #[test]
    fn depth_monotone() {
        for scheme in [Scheme::Zero3, Scheme::ZeroPP, Scheme::ZeroTopo { sec_degree: 2 }] {
            let t: Vec<f64> = [
                Depth::Bounded(0),
                Depth::Bounded(1),
                Depth::Bounded(2),
                Depth::Infinite,
            ]
            .iter()
            .map(|&d| plan(scheme, 4, d).simulate().makespan())
            .collect();
            for w in t.windows(2) {
                assert!(w[1] <= w[0] + 1e-9, "{scheme:?}: {t:?}");
            }
        }
    }

    #[test]
    fn huge_bounded_depth_equals_infinite() {
        let a = plan(Scheme::ZeroPP, 4, Depth::Bounded(1_000_000)).simulate().makespan();
        let b = plan(Scheme::ZeroPP, 4, Depth::Infinite).simulate().makespan();
        assert!((a - b).abs() < 1e-12);
    }

    #[test]
    fn makespan_bounded_by_serialized_plus_update() {
        for scheme in [Scheme::Zero3, Scheme::ZeroPP, Scheme::ZeroTopo { sec_degree: 2 }] {
            for depth in [Depth::Bounded(0), Depth::Bounded(1), Depth::Infinite] {
                let p = plan(scheme, 2, depth);
                let mk = p.simulate().makespan();
                assert!(mk <= p.serialized_s() + p.t_update + 1e-9, "{scheme:?} {depth:?}");
                assert!(mk >= p.compute_s() + p.grad_sync_s() - 1e-9, "{scheme:?} {depth:?}");
            }
        }
    }

    #[test]
    fn topo_sync_has_two_phases_multi_node() {
        let p = plan(Scheme::ZeroTopo { sec_degree: 2 }, 2, Depth::Infinite);
        assert_eq!(p.sync.len(), 2);
        assert!(p.sync[0].class < LinkClass::InterNode);
        assert_eq!(p.sync[1].class, LinkClass::InterNode);
        let single = plan(Scheme::ZeroTopo { sec_degree: 2 }, 1, Depth::Infinite);
        assert_eq!(single.sync.len(), 1);
    }

    #[test]
    fn graph_shape() {
        let p = plan(Scheme::ZeroTopo { sec_degree: 2 }, 2, Depth::Bounded(1));
        let g = p.build(0);
        // update + 4 * (gather.fwd, compute.fwd, gather.bwd, compute.bwd) + 2 sync
        assert_eq!(g.len(), 1 + 4 * 4 + 2);
        let sched = sched::simulate(g);
        // compute busy == compute_s
        let busy = sched.stream_busy(0, StreamKind::Compute);
        assert!((busy - p.compute_s()).abs() < 1e-9, "{busy}");
    }

    fn layered(scheme: Scheme, nodes: usize, depth: Depth, blocks: usize) -> StepPlan {
        let cluster = Cluster::frontier(nodes);
        let cost = CostModel::with_efficiency(cluster.clone(), CommEfficiency::rccl_frontier());
        let spec = ShardingSpec::resolve(scheme, &cluster).unwrap();
        let elems = crate::sched::pipeline::even_chunk_params(1_000_000_000, blocks);
        StepPlan::from_protocol_layered(&cost, scheme, &spec, &elems, 256, 4, 2.0, depth)
    }

    #[test]
    fn single_block_layered_is_monolithic_bit_for_bit() {
        for scheme in [Scheme::Zero3, Scheme::ZeroPP, Scheme::ZeroTopo { sec_degree: 2 }] {
            let mono = plan(scheme, 4, Depth::Bounded(1));
            let one = layered(scheme, 4, Depth::Bounded(1), 1);
            assert!(one.blocks.is_empty(), "{scheme:?}");
            let (a, b) = (mono.simulate(), one.simulate());
            assert_eq!(a.makespan(), b.makespan(), "{scheme:?}");
            assert_eq!(a.spans().len(), b.spans().len());
            for (x, y) in a.spans().iter().zip(b.spans()) {
                assert_eq!((x.start, x.end), (y.start, y.end), "{scheme:?}");
            }
        }
    }

    #[test]
    fn layered_blocks_sum_to_monolithic_gathers() {
        for blocks in [2usize, 3, 7, 44] {
            let p = layered(Scheme::ZeroTopo { sec_degree: 2 }, 4, Depth::Infinite, blocks);
            assert_eq!(p.blocks.len(), blocks);
            let f: f64 = p.blocks.iter().map(|b| b.t_gather_fwd).sum();
            let b: f64 = p.blocks.iter().map(|b| b.t_gather_bwd).sum();
            let c: f64 = p.blocks.iter().map(|b| b.compute_frac).sum();
            assert!((f - p.t_gather_fwd).abs() <= 1e-12 * p.t_gather_fwd.max(1.0), "{f}");
            assert!((b - p.t_gather_bwd).abs() <= 1e-12 * p.t_gather_bwd.max(1.0), "{b}");
            assert!((c - 1.0).abs() < 1e-12, "{c}");
        }
    }

    #[test]
    fn layered_depth_zero_serializes_exactly() {
        // depth-in-layers 0 still degenerates to the serialized reference:
        // the split conserves gather and compute totals
        let p = layered(Scheme::Zero3, 4, Depth::Bounded(0), 8);
        let mk = p.simulate().makespan();
        assert!((mk - p.serialized_s()).abs() < 1e-9 * p.serialized_s(), "{mk}");
    }

    #[test]
    fn layered_depth_monotone() {
        let steps: Vec<f64> = [
            Depth::Bounded(0),
            Depth::Bounded(1),
            Depth::Bounded(2),
            Depth::Bounded(8),
            Depth::Infinite,
        ]
        .iter()
        .map(|&d| layered(Scheme::Zero3, 4, d, 8).simulate().makespan())
        .collect();
        for w in steps.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "{steps:?}");
        }
        // depth 0 in layers == the monolithic serialized reference (totals
        // are conserved, and fetch-on-demand exposes every gather)
        let mono0 = plan(Scheme::Zero3, 4, Depth::Bounded(0)).simulate().makespan();
        assert!((steps[0] - mono0).abs() <= 1e-9 * mono0, "{} vs {mono0}", steps[0]);
    }

    #[test]
    fn layered_graph_shape_and_reverse_backward_order() {
        let p = layered(Scheme::ZeroTopo { sec_degree: 2 }, 2, Depth::Bounded(1), 3);
        let g = p.build(0);
        // update + 4 microbatches x 3 blocks x (gather+compute) x 2 phases + 2 sync
        assert_eq!(g.len(), 1 + 4 * 3 * 2 * 2 + 2);
        let labels: Vec<&str> = g
            .tasks()
            .iter()
            .map(|t| t.label.as_str())
            .filter(|l| l.contains("[0]") && !l.starts_with("grad-sync"))
            .collect();
        // forward blocks in layer order, backward blocks reversed
        assert_eq!(
            labels,
            vec![
                "gather.fwd[0]b0",
                "compute.fwd[0]b0",
                "gather.fwd[0]b1",
                "compute.fwd[0]b1",
                "gather.fwd[0]b2",
                "compute.fwd[0]b2",
                "gather.bwd[0]b2",
                "compute.bwd[0]b2",
                "gather.bwd[0]b1",
                "compute.bwd[0]b1",
                "gather.bwd[0]b0",
                "compute.bwd[0]b0",
            ]
        );
    }

    #[test]
    fn layered_infinite_depth_bounded_by_monolithic() {
        // at depth=inf the layered step can only be FASTER than the
        // monolithic one, and only by less than one microbatch's compute:
        // the tail after the last gather shrinks from a whole backward
        // unit to one block's share. The compute-bound calibrated scheme
        // (ZeRO-topo) converges within 1%; comm-bound ZeRO-3 keeps the
        // full ~t_compute_bwd head start.
        for scheme in [Scheme::Zero3, Scheme::ZeroPP, Scheme::ZeroTopo { sec_degree: 2 }] {
            let mono = plan(scheme, 4, Depth::Infinite);
            let a = mono.simulate().makespan();
            let b = layered(scheme, 4, Depth::Infinite, 44).simulate().makespan();
            assert!(b <= a + 1e-9 * a, "{scheme:?}: layered {b} slower than mono {a}");
            let micro_compute = mono.t_compute_fwd + mono.t_compute_bwd;
            assert!(b >= a - micro_compute - 1e-9 * a, "{scheme:?}: {b} vs {a}");
        }
        let mono = plan(Scheme::ZeroTopo { sec_degree: 2 }, 4, Depth::Infinite)
            .simulate()
            .makespan();
        let lay = layered(Scheme::ZeroTopo { sec_degree: 2 }, 4, Depth::Infinite, 44)
            .simulate()
            .makespan();
        assert!((lay - mono).abs() <= 0.01 * mono, "{lay} vs {mono}");
    }

    #[test]
    fn gather_window_params_formula() {
        let blocks = [4u64, 1, 3, 2];
        // depth 0: the single largest block
        assert_eq!(gather_window_params(&blocks, Depth::Bounded(0)), 4);
        // depth 1: best 2-window is [4,1] vs [1,3] vs [3,2] -> 5
        assert_eq!(gather_window_params(&blocks, Depth::Bounded(1)), 5);
        // depth >= len-1 and infinite both cover everything
        assert_eq!(gather_window_params(&blocks, Depth::Bounded(3)), 10);
        assert_eq!(gather_window_params(&blocks, Depth::Bounded(99)), 10);
        assert_eq!(gather_window_params(&blocks, Depth::Infinite), 10);
        // monolithic split: full model at any depth
        assert_eq!(gather_window_params(&[10], Depth::Bounded(0)), 10);
        assert_eq!(gather_window_params(&[], Depth::Infinite), 0);
    }

    #[test]
    fn gather_window_monotone_in_depth() {
        let blocks: Vec<u64> = (1..=44).map(|i| 1000 + (i % 7) * 37).collect();
        let mut prev = 0;
        for d in 0..48 {
            let w = gather_window_params(&blocks, Depth::Bounded(d));
            assert!(w >= prev, "depth {d}: {w} < {prev}");
            prev = w;
        }
        assert_eq!(prev, gather_window_params(&blocks, Depth::Infinite));
    }
}
