//! The per-step task graph of the paper's Section V protocol, expressed
//! for the [`crate::sched`] event loop.
//!
//! [`StepPlan::from_protocol`] derives every communication duration from
//! the same α–β [`CostModel`] the collective engine charges (pure
//! `*_time` queries, no ledger mutation), so `sim::simulate_step` and
//! `engine::TrainEngine` price and schedule the comm side of a step
//! identically by construction (their compute anchors differ: the sim
//! uses the detailed FLOPs account, the engine the 6Ψ rule on its proxy
//! manifest). The graph per optimizer step (paper Figs 4–6):
//!
//! * per microbatch: a forward weight gather feeding the forward compute
//!   and a backward (secondary-partition) gather feeding the backward
//!   compute, both on the prefetch stream and bounded by [`Depth`];
//! * ZeRO-topo only: the §V.D updated-weight all-gather on the grad-sync
//!   stream at the step head (the refresh issued after the previous
//!   step's optimizer update, overlapping this step's compute in steady
//!   state);
//! * at the grad-accumulation boundary: the scheme's gradient-sync
//!   phases, sequential on the grad-sync stream, blocking the step end.

use crate::comm::cost::CostModel;
use crate::comm::Wire;
use crate::sched::{self, Depth, Schedule, StreamKind, Task, TaskGraph, TaskId};
use crate::sharding::{shard_groups, Scheme, ShardingSpec};
use crate::topology::LinkClass;

/// One gradient-sync phase: duration + the link class it occupies.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SyncPhase {
    /// Phase duration at unit rate.
    pub seconds: f64,
    /// Link class the phase occupies.
    pub class: LinkClass,
}

/// Durations + structure of one optimizer step, ready to schedule.
#[derive(Debug, Clone)]
pub struct StepPlan {
    /// The ZeRO scheme the plan prices.
    pub scheme: Scheme,
    /// Gradient-accumulation microbatches per step.
    pub grad_accum: usize,
    /// Prefetch depth bounding the gather stream.
    pub depth: Depth,
    /// Per-microbatch forward weight gather.
    pub t_gather_fwd: f64,
    /// Link class of the forward gather.
    pub class_fwd: LinkClass,
    /// Per-microbatch backward (secondary) gather.
    pub t_gather_bwd: f64,
    /// Link class of the backward gather.
    pub class_bwd: LinkClass,
    /// §V.D updated-weight all-gather (0 for schemes without one).
    pub t_update: f64,
    /// Link class of the updated-weight gather.
    pub class_update: LinkClass,
    /// Per-microbatch forward compute.
    pub t_compute_fwd: f64,
    /// Per-microbatch backward compute (≈ 2× forward).
    pub t_compute_bwd: f64,
    /// Sequential gradient-sync phases at the accumulation boundary.
    pub sync: Vec<SyncPhase>,
    /// Forward gather group degree — the congruent-group shape a
    /// multi-rank builder needs to place each rank's gathers
    /// ([`crate::sched::multi::MultiRankPlan`]).
    pub d_fwd: usize,
    /// Backward (secondary) gather group degree.
    pub d_bwd: usize,
}

impl StepPlan {
    /// Derive the plan for `(scheme, cluster)` from the cost model:
    /// `n_elems` = ψ (flat parameter count), `compute_s` = total compute
    /// seconds for the whole step (all `grad_accum` microbatches).
    #[allow(clippy::too_many_arguments)]
    pub fn from_protocol(
        cost: &CostModel,
        scheme: Scheme,
        spec: &ShardingSpec,
        n_elems: usize,
        quant_block: usize,
        grad_accum: usize,
        compute_s: f64,
        depth: Depth,
    ) -> StepPlan {
        let cluster = &cost.cluster;
        let world = cluster.world_size();
        let block = quant_block;
        let (fwd_wire, bwd_wire) = if scheme.quantized() {
            (Wire::Int8 { block }, Wire::Int8 { block })
        } else {
            (Wire::F16, Wire::F16)
        };

        // rank 0's groups; all groups of a degree are congruent, so rank
        // 0's time IS the per-rank step contribution
        let group_time = |degree: usize, wire: Wire| -> (f64, LinkClass) {
            if degree <= 1 {
                return (0.0, LinkClass::Local);
            }
            let g: Vec<usize> = (0..degree).collect();
            cost.priced_all_gather(&g, wire.wire_bytes(n_elems) as u64)
        };
        let (t_gather_fwd, class_fwd) = group_time(spec.weights, fwd_wire);
        let bwd_degree = if spec.secondary > 0 { spec.secondary } else { spec.weights };
        let (t_gather_bwd, class_bwd) = group_time(bwd_degree, bwd_wire);

        // ZeRO-topo's §V.D updated-weight gather spans the optimizer group
        let (t_update, class_update) = if matches!(scheme, Scheme::ZeroTopo { .. }) {
            group_time(world, fwd_wire)
        } else {
            (0.0, LinkClass::Local)
        };

        let full: Vec<usize> = (0..world).collect();
        let mut sync = Vec::new();
        match scheme {
            Scheme::Zero1 | Scheme::Zero2 => {
                let (t, class) =
                    cost.priced_all_reduce(&full, Wire::F16.wire_bytes(n_elems) as u64);
                sync.push(SyncPhase { seconds: t, class });
            }
            Scheme::Zero3 => {
                // ring reduce-scatter: same pattern/pricing as the gather
                let (t, class) =
                    cost.priced_all_gather(&full, Wire::F16.wire_bytes(n_elems) as u64);
                sync.push(SyncPhase { seconds: t, class });
            }
            Scheme::ZeroPP => {
                let (t, class) = cost
                    .priced_all_to_all(&full, Wire::Int4 { block }.wire_bytes(n_elems) as u64);
                sync.push(SyncPhase { seconds: t, class });
            }
            Scheme::ZeroTopo { .. } => {
                let p = cluster.workers_per_node();
                let node0: Vec<usize> = (0..p).collect();
                let (t1, class1) = cost
                    .priced_all_to_all(&node0, Wire::Int4 { block }.wire_bytes(n_elems) as u64);
                sync.push(SyncPhase { seconds: t1, class: class1 });
                if cluster.nodes > 1 {
                    // the P cross-node groups are congruent (one rank per
                    // node each) and funnel through each node's NIC: their
                    // bandwidth terms serialize — one phase, P × one group
                    let shard_bytes = Wire::F16.wire_bytes(n_elems / p) as u64;
                    let group: Vec<usize> = (0..cluster.nodes).map(|m| m * p).collect();
                    let (t, class) = cost.priced_all_reduce(&group, shard_bytes);
                    sync.push(SyncPhase { seconds: p as f64 * t, class });
                }
            }
            Scheme::Mics { .. } | Scheme::FsdpHybrid { .. } => {
                let g = spec.grads;
                let groups = shard_groups(world, g);
                let (t1, class1) =
                    cost.priced_all_gather(&groups[0], Wire::F16.wire_bytes(n_elems) as u64);
                sync.push(SyncPhase { seconds: t1, class: class1 });
                let n_groups = world / g;
                if n_groups > 1 {
                    // g congruent replica groups, serialized like above
                    let shard_bytes = Wire::F16.wire_bytes(n_elems / g) as u64;
                    let group: Vec<usize> = (0..n_groups).map(|m| m * g).collect();
                    let (t, class) = cost.priced_all_reduce(&group, shard_bytes);
                    sync.push(SyncPhase { seconds: g as f64 * t, class });
                }
            }
        }

        let ga = grad_accum.max(1);
        StepPlan {
            scheme,
            grad_accum: ga,
            depth,
            t_gather_fwd,
            class_fwd,
            t_gather_bwd,
            class_bwd,
            t_update,
            class_update,
            t_compute_fwd: compute_s / (3.0 * ga as f64),
            t_compute_bwd: 2.0 * compute_s / (3.0 * ga as f64),
            sync,
            d_fwd: spec.weights,
            d_bwd: bwd_degree,
        }
    }

    /// Total prefetchable gather seconds (microbatch gathers + update).
    pub fn prefetchable_s(&self) -> f64 {
        self.grad_accum as f64 * (self.t_gather_fwd + self.t_gather_bwd) + self.t_update
    }

    /// Total blocking gradient-sync seconds.
    pub fn grad_sync_s(&self) -> f64 {
        self.sync.iter().map(|p| p.seconds).sum()
    }

    /// Total compute seconds across all microbatches.
    pub fn compute_s(&self) -> f64 {
        self.grad_accum as f64 * (self.t_compute_fwd + self.t_compute_bwd)
    }

    /// The no-overlap reference: compute + per-microbatch gathers + sync,
    /// all strictly serialized. Depth 0 degenerates to exactly this (the
    /// update gather rides the grad-sync stream and stays overlapped).
    pub fn serialized_s(&self) -> f64 {
        self.compute_s()
            + self.grad_accum as f64 * (self.t_gather_fwd + self.t_gather_bwd)
            + self.grad_sync_s()
    }

    /// Build the step DAG for one rank.
    pub fn build(&self, rank: usize) -> TaskGraph {
        let mut g = TaskGraph::new();
        // previous step's §V.D refresh occupies the grad stream head
        if self.t_update > 0.0 {
            g.add(Task {
                label: "update-gather".into(),
                rank,
                stream: StreamKind::GradSync,
                work: self.t_update,
                class: Some(self.class_update),
                instance: 0,
                deps: vec![],
            });
        }
        // consumer order: cf_0, cb_0, cf_1, ... — gather j (feeding
        // consumer j) may start once consumer j-1-depth has finished
        let mut consumers: Vec<TaskId> = Vec::with_capacity(2 * self.grad_accum);
        let gate = |consumers: &[TaskId], j: usize| -> Vec<TaskId> {
            match self.depth {
                // a depth >= the number of consumers never gates anything
                Depth::Bounded(d) if d < 2 * self.grad_accum => {
                    let k = j as i64 - 1 - d as i64;
                    if k >= 0 {
                        vec![consumers[k as usize]]
                    } else {
                        vec![]
                    }
                }
                _ => vec![],
            }
        };
        for m in 0..self.grad_accum {
            let f = g.add(Task {
                label: format!("gather.fwd[{m}]"),
                rank,
                stream: StreamKind::Prefetch,
                work: self.t_gather_fwd,
                class: Some(self.class_fwd),
                instance: 0,
                deps: gate(&consumers, 2 * m),
            });
            let cf = g.add(Task {
                label: format!("compute.fwd[{m}]"),
                rank,
                stream: StreamKind::Compute,
                work: self.t_compute_fwd,
                class: None,
                instance: 0,
                deps: vec![f],
            });
            consumers.push(cf);
            let b = g.add(Task {
                label: format!("gather.bwd[{m}]"),
                rank,
                stream: StreamKind::Prefetch,
                work: self.t_gather_bwd,
                class: Some(self.class_bwd),
                instance: 0,
                deps: gate(&consumers, 2 * m + 1),
            });
            let cb = g.add(Task {
                label: format!("compute.bwd[{m}]"),
                rank,
                stream: StreamKind::Compute,
                work: self.t_compute_bwd,
                class: None,
                instance: 0,
                deps: vec![b],
            });
            consumers.push(cb);
        }
        let mut prev = *consumers.last().expect("grad_accum >= 1");
        for (k, phase) in self.sync.iter().enumerate() {
            prev = g.add(Task {
                label: format!("grad-sync[{k}]"),
                rank,
                stream: StreamKind::GradSync,
                work: phase.seconds,
                class: Some(phase.class),
                instance: 0,
                deps: vec![prev],
            });
        }
        g
    }

    /// Build for the representative rank and run the event loop. All
    /// ranks' streams are congruent under the symmetric protocol, so rank
    /// 0's makespan is the simulated step time.
    pub fn simulate(&self) -> Schedule {
        sched::simulate(self.build(0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::cost::CommEfficiency;
    use crate::topology::Cluster;

    fn plan(scheme: Scheme, nodes: usize, depth: Depth) -> StepPlan {
        let cluster = Cluster::frontier(nodes);
        let cost = CostModel::with_efficiency(cluster.clone(), CommEfficiency::rccl_frontier());
        let spec = ShardingSpec::resolve(scheme, &cluster).unwrap();
        let psi = 1_000_000_000usize;
        StepPlan::from_protocol(&cost, scheme, &spec, psi, 256, 4, 2.0, depth)
    }

    #[test]
    fn depth_zero_serializes_exactly() {
        // no update gather for ZeRO-3: depth 0 == the serialized reference
        let p = plan(Scheme::Zero3, 4, Depth::Bounded(0));
        let mk = p.simulate().makespan();
        assert!((mk - p.serialized_s()).abs() < 1e-9 * p.serialized_s(), "{mk}");
    }

    #[test]
    fn infinite_depth_hides_gathers_under_compute() {
        // ZeRO-topo gathers are tiny GCD-pair transfers: with unbounded
        // prefetch the step collapses to ~ first gather + compute + sync
        let p = plan(Scheme::ZeroTopo { sec_degree: 2 }, 4, Depth::Infinite);
        let mk = p.simulate().makespan();
        let floor = p.compute_s() + p.grad_sync_s();
        assert!(mk >= floor - 1e-12, "{mk} < {floor}");
        assert!(mk <= floor + 2.0 * (p.t_gather_fwd + p.t_gather_bwd), "{mk} vs {floor}");
    }

    #[test]
    fn depth_monotone() {
        for scheme in [Scheme::Zero3, Scheme::ZeroPP, Scheme::ZeroTopo { sec_degree: 2 }] {
            let t: Vec<f64> = [
                Depth::Bounded(0),
                Depth::Bounded(1),
                Depth::Bounded(2),
                Depth::Infinite,
            ]
            .iter()
            .map(|&d| plan(scheme, 4, d).simulate().makespan())
            .collect();
            for w in t.windows(2) {
                assert!(w[1] <= w[0] + 1e-9, "{scheme:?}: {t:?}");
            }
        }
    }

    #[test]
    fn huge_bounded_depth_equals_infinite() {
        let a = plan(Scheme::ZeroPP, 4, Depth::Bounded(1_000_000)).simulate().makespan();
        let b = plan(Scheme::ZeroPP, 4, Depth::Infinite).simulate().makespan();
        assert!((a - b).abs() < 1e-12);
    }

    #[test]
    fn makespan_bounded_by_serialized_plus_update() {
        for scheme in [Scheme::Zero3, Scheme::ZeroPP, Scheme::ZeroTopo { sec_degree: 2 }] {
            for depth in [Depth::Bounded(0), Depth::Bounded(1), Depth::Infinite] {
                let p = plan(scheme, 2, depth);
                let mk = p.simulate().makespan();
                assert!(mk <= p.serialized_s() + p.t_update + 1e-9, "{scheme:?} {depth:?}");
                assert!(mk >= p.compute_s() + p.grad_sync_s() - 1e-9, "{scheme:?} {depth:?}");
            }
        }
    }

    #[test]
    fn topo_sync_has_two_phases_multi_node() {
        let p = plan(Scheme::ZeroTopo { sec_degree: 2 }, 2, Depth::Infinite);
        assert_eq!(p.sync.len(), 2);
        assert!(p.sync[0].class < LinkClass::InterNode);
        assert_eq!(p.sync[1].class, LinkClass::InterNode);
        let single = plan(Scheme::ZeroTopo { sec_degree: 2 }, 1, Depth::Infinite);
        assert_eq!(single.sync.len(), 1);
    }

    #[test]
    fn graph_shape() {
        let p = plan(Scheme::ZeroTopo { sec_degree: 2 }, 2, Depth::Bounded(1));
        let g = p.build(0);
        // update + 4 * (gather.fwd, compute.fwd, gather.bwd, compute.bwd) + 2 sync
        assert_eq!(g.len(), 1 + 4 * 4 + 2);
        let sched = sched::simulate(g);
        // compute busy == compute_s
        let busy = sched.stream_busy(0, StreamKind::Compute);
        assert!((busy - p.compute_s()).abs() < 1e-9, "{busy}");
    }
}
