//! Chrome-trace (about://tracing / Perfetto) export of a [`Schedule`]:
//! one process per named schedule, one thread per (rank, stream), one
//! complete ("X") event per task span, plus one counter ("C") track per
//! link class showing its in-flight task count over time — the
//! utilization timeline next to the span lanes. Load the emitted JSON in
//! `chrome://tracing` or <https://ui.perfetto.dev> to see the stream
//! timelines the step scheduler produced. Pipeline schedules get a
//! fourth per-rank lane for their stage-to-stage transfers.
//!
//! Lanes carry `thread_sort_index` metadata so Perfetto renders each
//! rank's streams in Compute / Prefetch / GradSync / PipeTransfer order,
//! and counter tracks are named with the machine's link labels (the same
//! labels the stall table prints) when a [`MachineSpec`] is supplied.

use crate::sched::{Schedule, StreamKind};
use crate::topology::spec::MachineSpec;
use crate::util::json::Json;

/// All stream lanes a rank can own, in lane order.
const STREAMS: [StreamKind; 4] = [
    StreamKind::Compute,
    StreamKind::Prefetch,
    StreamKind::GradSync,
    StreamKind::PipeTransfer,
];

fn tid_of(rank: usize, stream: StreamKind) -> usize {
    let s = match stream {
        StreamKind::Compute => 0,
        StreamKind::Prefetch => 1,
        StreamKind::GradSync => 2,
        StreamKind::PipeTransfer => 3,
    };
    rank * STREAMS.len() + s
}

/// Render one or more named schedules (e.g. one per scheme) as a Chrome
/// trace JSON document. Timestamps are microseconds of simulated time.
/// Counter tracks fall back to the generic [`LinkClass`] display names;
/// pass the machine through [`chrome_trace_labeled`] to use its level
/// names instead.
///
/// [`LinkClass`]: crate::topology::LinkClass
pub fn chrome_trace(named: &[(String, &Schedule)]) -> String {
    chrome_trace_labeled(named, None)
}

/// [`chrome_trace`] with link-utilization counter tracks named after
/// `machine`'s link labels (`MachineSpec::class_label`), so the trace, the
/// stall table, and the utilization table all speak the same names.
pub fn chrome_trace_labeled(
    named: &[(String, &Schedule)],
    machine: Option<&MachineSpec>,
) -> String {
    let mut events: Vec<Json> = Vec::new();
    for (pid, (name, sched)) in named.iter().enumerate() {
        events.push(Json::obj(vec![
            ("name", Json::str("process_name")),
            ("ph", Json::str("M")),
            ("pid", Json::from(pid)),
            ("args", Json::obj(vec![("name", Json::str(name.clone()))])),
        ]));
        // the pipe lane only appears for ranks that use it (one pass)
        let pipe_ranks: std::collections::BTreeSet<usize> = sched
            .graph()
            .tasks()
            .iter()
            .filter(|t| t.stream == StreamKind::PipeTransfer)
            .map(|t| t.rank)
            .collect();
        for rank in sched.ranks() {
            for stream in STREAMS {
                if stream == StreamKind::PipeTransfer && !pipe_ranks.contains(&rank) {
                    continue;
                }
                let tid = tid_of(rank, stream);
                events.push(Json::obj(vec![
                    ("name", Json::str("thread_name")),
                    ("ph", Json::str("M")),
                    ("pid", Json::from(pid)),
                    ("tid", Json::from(tid)),
                    (
                        "args",
                        Json::obj(vec![(
                            "name",
                            Json::str(format!("rank{rank}/{}", stream.name())),
                        )]),
                    ),
                ]));
                // lane order within the rank = stream declaration order
                events.push(Json::obj(vec![
                    ("name", Json::str("thread_sort_index")),
                    ("ph", Json::str("M")),
                    ("pid", Json::from(pid)),
                    ("tid", Json::from(tid)),
                    ("args", Json::obj(vec![("sort_index", Json::from(tid))])),
                ]));
            }
        }
        for span in sched.spans() {
            let task = sched.graph().task(span.task);
            let mut args = vec![
                ("stream", Json::str(task.stream.name())),
                ("rank", Json::from(task.rank)),
            ];
            if let Some(c) = task.class {
                args.push(("link_class", Json::str(c.to_string())));
                args.push(("link_instance", Json::from(task.instance)));
            }
            events.push(Json::obj(vec![
                ("name", Json::str(task.label.clone())),
                ("cat", Json::str(task.stream.name())),
                ("ph", Json::str("X")),
                ("ts", Json::num(span.start * 1e6)),
                ("dur", Json::num((span.end - span.start) * 1e6)),
                ("pid", Json::from(pid)),
                ("tid", Json::from(tid_of(task.rank, task.stream))),
                ("args", Json::obj(args)),
            ]));
        }
        // one counter track per link class: in-flight tasks over time,
        // named consistently with the stall-table link labels
        for class in sched.link_classes() {
            let label = match machine {
                Some(m) => m.class_label(class),
                None => class.to_string(),
            };
            for (t, depth) in sched.class_in_flight(class) {
                events.push(Json::obj(vec![
                    ("name", Json::str(format!("util:{label}"))),
                    ("ph", Json::str("C")),
                    ("pid", Json::from(pid)),
                    ("ts", Json::num(t * 1e6)),
                    ("args", Json::obj(vec![("in_flight", Json::from(depth))])),
                ]));
            }
        }
    }
    let doc = Json::obj(vec![
        ("traceEvents", Json::arr(events)),
        ("displayTimeUnit", Json::str("ms")),
    ]);
    doc.to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{simulate, Task, TaskGraph};

    fn count_ph(events: &[Json], ph: &str) -> usize {
        events
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some(ph))
            .count()
    }

    #[test]
    fn trace_roundtrips_through_json() {
        let mut g = TaskGraph::new();
        let a = g.add(Task {
            label: "gather".into(),
            rank: 0,
            stream: StreamKind::Prefetch,
            work: 1.0,
            class: Some(crate::topology::LinkClass::InterNode),
            instance: 0,
            deps: vec![],
        });
        g.add(Task {
            label: "fwd".into(),
            rank: 0,
            stream: StreamKind::Compute,
            work: 2.0,
            class: None,
            instance: 0,
            deps: vec![a],
        });
        let sched = simulate(g);
        let out = chrome_trace(&[("demo".to_string(), &sched)]);
        let parsed = Json::parse(&out).expect("valid JSON");
        let events = parsed.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
        // 1 process_name + 3 x (thread_name + thread_sort_index) + 2 task
        // events + 2 counter samples (gather in flight over [0, 1))
        assert_eq!(events.len(), 11);
        assert_eq!(count_ph(events, "M"), 7);
        assert_eq!(count_ph(events, "C"), 2);
        let xs: Vec<&Json> = events
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X"))
            .collect();
        assert_eq!(xs.len(), 2);
        // the compute span starts after the 1s gather: ts == 1e6 us
        let fwd = xs
            .iter()
            .find(|e| e.get("name").and_then(|n| n.as_str()) == Some("fwd"))
            .unwrap();
        assert_eq!(fwd.get("ts").and_then(|t| t.as_f64()), Some(1e6));
        assert_eq!(fwd.get("dur").and_then(|t| t.as_f64()), Some(2e6));
        assert_eq!(fwd.at(&["args", "rank"]).and_then(|r| r.as_usize()), Some(0));
    }

    #[test]
    fn multi_rank_trace_gets_one_lane_per_rank_stream() {
        let mut g = TaskGraph::new();
        for rank in [0usize, 3] {
            g.add(Task {
                label: format!("c@r{rank}"),
                rank,
                stream: StreamKind::Compute,
                work: 1.0,
                class: None,
                instance: 0,
                deps: vec![],
            });
        }
        let sched = simulate(g);
        let out = chrome_trace(&[("multi".to_string(), &sched)]);
        let parsed = Json::parse(&out).expect("valid JSON");
        let events = parsed.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
        // 1 process_name + 2 ranks x 3 x (thread_name + sort_index) + 2
        // task events; no link classes, so no counter tracks
        assert_eq!(events.len(), 15);
        assert_eq!(count_ph(events, "C"), 0);
        let tids: Vec<usize> = events
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X"))
            .map(|e| e.get("tid").and_then(|t| t.as_usize()).unwrap())
            .collect();
        assert_eq!(tids, vec![0, 12]); // rank * 4 + stream
    }

    #[test]
    fn pipe_lane_appears_only_when_used() {
        let mut g = TaskGraph::new();
        let c = g.add(Task {
            label: "fwd".into(),
            rank: 0,
            stream: StreamKind::Compute,
            work: 1.0,
            class: None,
            instance: 0,
            deps: vec![],
        });
        g.add(Task {
            label: "p2p.act".into(),
            rank: 0,
            stream: StreamKind::PipeTransfer,
            work: 0.5,
            class: Some(crate::topology::LinkClass::InterNode),
            instance: 0,
            deps: vec![c],
        });
        let sched = simulate(g);
        let out = chrome_trace(&[("pipe".to_string(), &sched)]);
        let parsed = Json::parse(&out).expect("valid JSON");
        let events = parsed.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
        // 1 process_name + 4 x (thread_name + sort_index) + 2 tasks + 3
        // counter samples (seed at 0, rise at 1.0, fall at 1.5)
        assert_eq!(events.len(), 14);
        assert_eq!(count_ph(events, "C"), 3);
        let pipe_tid = events
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X"))
            .find(|e| e.get("name").and_then(|n| n.as_str()) == Some("p2p.act"))
            .and_then(|e| e.get("tid").and_then(|t| t.as_usize()));
        assert_eq!(pipe_tid, Some(3));
    }

    #[test]
    fn sort_index_orders_lanes_and_machine_labels_name_counters() {
        let mut g = TaskGraph::new();
        let a = g.add(Task {
            label: "gather".into(),
            rank: 0,
            stream: StreamKind::Prefetch,
            work: 1.0,
            class: Some(crate::topology::LinkClass::InterNode),
            instance: 0,
            deps: vec![],
        });
        g.add(Task {
            label: "fwd".into(),
            rank: 0,
            stream: StreamKind::Compute,
            work: 1.0,
            class: None,
            instance: 0,
            deps: vec![a],
        });
        let sched = simulate(g);
        let frontier = MachineSpec::frontier_mi250x();
        let out = chrome_trace_labeled(&[("demo".to_string(), &sched)], Some(&frontier));
        let parsed = Json::parse(&out).expect("valid JSON");
        let events = parsed.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
        // every lane carries a sort index equal to its tid
        let sorts: Vec<(usize, usize)> = events
            .iter()
            .filter(|e| e.get("name").and_then(|n| n.as_str()) == Some("thread_sort_index"))
            .map(|e| {
                let tid = e.get("tid").and_then(|t| t.as_usize()).unwrap();
                let idx = e
                    .at(&["args", "sort_index"])
                    .and_then(|s| s.as_usize())
                    .unwrap();
                (tid, idx)
            })
            .collect();
        assert_eq!(sorts, vec![(0, 0), (1, 1), (2, 2)]);
        // counter tracks use the machine's stall-table label
        let counter = events
            .iter()
            .find(|e| e.get("ph").and_then(|p| p.as_str()) == Some("C"))
            .unwrap();
        let name = counter.get("name").and_then(|n| n.as_str()).unwrap();
        let label = frontier.class_label(crate::topology::LinkClass::InterNode);
        assert_eq!(name, format!("util:{label}"));
    }
}
