//! Chrome-trace (about://tracing / Perfetto) export of a [`Schedule`]:
//! one process per named schedule, one thread per (rank, stream), one
//! complete ("X") event per task span. Load the emitted JSON in
//! `chrome://tracing` or <https://ui.perfetto.dev> to see the stream
//! timelines the step scheduler produced. Pipeline schedules get a
//! fourth per-rank lane for their stage-to-stage transfers.

use crate::sched::{Schedule, StreamKind};
use crate::util::json::Json;

/// All stream lanes a rank can own, in lane order.
const STREAMS: [StreamKind; 4] = [
    StreamKind::Compute,
    StreamKind::Prefetch,
    StreamKind::GradSync,
    StreamKind::PipeTransfer,
];

fn tid_of(rank: usize, stream: StreamKind) -> usize {
    let s = match stream {
        StreamKind::Compute => 0,
        StreamKind::Prefetch => 1,
        StreamKind::GradSync => 2,
        StreamKind::PipeTransfer => 3,
    };
    rank * STREAMS.len() + s
}

/// Render one or more named schedules (e.g. one per scheme) as a Chrome
/// trace JSON document. Timestamps are microseconds of simulated time.
pub fn chrome_trace(named: &[(String, &Schedule)]) -> String {
    let mut events: Vec<Json> = Vec::new();
    for (pid, (name, sched)) in named.iter().enumerate() {
        events.push(Json::obj(vec![
            ("name", Json::str("process_name")),
            ("ph", Json::str("M")),
            ("pid", Json::from(pid)),
            ("args", Json::obj(vec![("name", Json::str(name.clone()))])),
        ]));
        // the pipe lane only appears for ranks that use it (one pass)
        let pipe_ranks: std::collections::BTreeSet<usize> = sched
            .graph()
            .tasks()
            .iter()
            .filter(|t| t.stream == StreamKind::PipeTransfer)
            .map(|t| t.rank)
            .collect();
        for rank in sched.ranks() {
            for stream in STREAMS {
                if stream == StreamKind::PipeTransfer && !pipe_ranks.contains(&rank) {
                    continue;
                }
                events.push(Json::obj(vec![
                    ("name", Json::str("thread_name")),
                    ("ph", Json::str("M")),
                    ("pid", Json::from(pid)),
                    ("tid", Json::from(tid_of(rank, stream))),
                    (
                        "args",
                        Json::obj(vec![(
                            "name",
                            Json::str(format!("rank{rank}/{}", stream.name())),
                        )]),
                    ),
                ]));
            }
        }
        for span in sched.spans() {
            let task = sched.graph().task(span.task);
            let mut args = vec![
                ("stream", Json::str(task.stream.name())),
                ("rank", Json::from(task.rank)),
            ];
            if let Some(c) = task.class {
                args.push(("link_class", Json::str(c.to_string())));
                args.push(("link_instance", Json::from(task.instance)));
            }
            events.push(Json::obj(vec![
                ("name", Json::str(task.label.clone())),
                ("cat", Json::str(task.stream.name())),
                ("ph", Json::str("X")),
                ("ts", Json::num(span.start * 1e6)),
                ("dur", Json::num((span.end - span.start) * 1e6)),
                ("pid", Json::from(pid)),
                ("tid", Json::from(tid_of(task.rank, task.stream))),
                ("args", Json::obj(args)),
            ]));
        }
    }
    let doc = Json::obj(vec![
        ("traceEvents", Json::arr(events)),
        ("displayTimeUnit", Json::str("ms")),
    ]);
    doc.to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{simulate, Task, TaskGraph};

    #[test]
    fn trace_roundtrips_through_json() {
        let mut g = TaskGraph::new();
        let a = g.add(Task {
            label: "gather".into(),
            rank: 0,
            stream: StreamKind::Prefetch,
            work: 1.0,
            class: Some(crate::topology::LinkClass::InterNode),
            instance: 0,
            deps: vec![],
        });
        g.add(Task {
            label: "fwd".into(),
            rank: 0,
            stream: StreamKind::Compute,
            work: 2.0,
            class: None,
            instance: 0,
            deps: vec![a],
        });
        let sched = simulate(g);
        let out = chrome_trace(&[("demo".to_string(), &sched)]);
        let parsed = Json::parse(&out).expect("valid JSON");
        let events = parsed.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
        // 1 process_name + 3 thread_name + 2 task events
        assert_eq!(events.len(), 6);
        let xs: Vec<&Json> = events
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X"))
            .collect();
        assert_eq!(xs.len(), 2);
        // the compute span starts after the 1s gather: ts == 1e6 us
        let fwd = xs
            .iter()
            .find(|e| e.get("name").and_then(|n| n.as_str()) == Some("fwd"))
            .unwrap();
        assert_eq!(fwd.get("ts").and_then(|t| t.as_f64()), Some(1e6));
        assert_eq!(fwd.get("dur").and_then(|t| t.as_f64()), Some(2e6));
        assert_eq!(fwd.at(&["args", "rank"]).and_then(|r| r.as_usize()), Some(0));
    }

    #[test]
    fn multi_rank_trace_gets_one_lane_per_rank_stream() {
        let mut g = TaskGraph::new();
        for rank in [0usize, 3] {
            g.add(Task {
                label: format!("c@r{rank}"),
                rank,
                stream: StreamKind::Compute,
                work: 1.0,
                class: None,
                instance: 0,
                deps: vec![],
            });
        }
        let sched = simulate(g);
        let out = chrome_trace(&[("multi".to_string(), &sched)]);
        let parsed = Json::parse(&out).expect("valid JSON");
        let events = parsed.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
        // 1 process_name + 2 ranks x 3 thread_name + 2 task events
        assert_eq!(events.len(), 9);
        let tids: Vec<usize> = events
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X"))
            .map(|e| e.get("tid").and_then(|t| t.as_usize()).unwrap())
            .collect();
        assert_eq!(tids, vec![0, 12]); // rank * 4 + stream
    }

    #[test]
    fn pipe_lane_appears_only_when_used() {
        let mut g = TaskGraph::new();
        let c = g.add(Task {
            label: "fwd".into(),
            rank: 0,
            stream: StreamKind::Compute,
            work: 1.0,
            class: None,
            instance: 0,
            deps: vec![],
        });
        g.add(Task {
            label: "p2p.act".into(),
            rank: 0,
            stream: StreamKind::PipeTransfer,
            work: 0.5,
            class: Some(crate::topology::LinkClass::InterNode),
            instance: 0,
            deps: vec![c],
        });
        let sched = simulate(g);
        let out = chrome_trace(&[("pipe".to_string(), &sched)]);
        let parsed = Json::parse(&out).expect("valid JSON");
        let events = parsed.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
        // 1 process_name + 4 thread_name (pipe lane present) + 2 tasks
        assert_eq!(events.len(), 7);
        let pipe_tid = events
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X"))
            .find(|e| e.get("name").and_then(|n| n.as_str()) == Some("p2p.act"))
            .and_then(|e| e.get("tid").and_then(|t| t.as_usize()));
        assert_eq!(pipe_tid, Some(3));
    }
}
