//! Scenario injectors for multi-rank step graphs: stragglers (per-rank
//! compute multipliers), seeded per-node jitter, and imbalanced
//! grad-accumulation groups — the asymmetries Dash et al. and Wang et al.
//! identify as the real limiters of scaling efficiency, which a
//! single-representative-rank step graph cannot express — plus
//! deterministic **fault events** ([`FaultEvent`]: node failure,
//! spot-style preemption, elastic world-resize) that the goodput layer
//! (`sim::goodput::price_timeline`, DESIGN.md §17) prices over a run.
//!
//! Everything is deterministic: jitter multipliers derive from a seeded
//! [`Rng`] (one lognormal draw per node, in node order), never from wall
//! clocks, so two simulations of the same scenario are bit-identical.
//! Faults fire at fixed step indices, not sampled times, for the same
//! reason.

use std::fmt;
use std::str::FromStr;

use crate::topology::Cluster;
use crate::util::rng::Rng;

/// How many ranks the multi-rank builder models explicitly.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RankCount {
    /// Collapse congruent groups: model one representative node per
    /// distinct node signature, one representative rank per distinct rank
    /// signature within it. A trivial scenario collapses to a single rank;
    /// per-node jitter keeps one rank per node; a straggler keeps its node
    /// plus one exemplar node.
    Auto,
    /// Model the first `n` ranks explicitly (scenario-named ranks are
    /// always added on top).
    Count(usize),
}

impl RankCount {
    /// Parse `"auto"` or a positive rank count.
    pub fn parse(s: &str) -> Option<RankCount> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Some(RankCount::Auto),
            other => other.parse::<usize>().ok().filter(|&n| n > 0).map(RankCount::Count),
        }
    }
}

impl FromStr for RankCount {
    type Err = String;

    fn from_str(s: &str) -> Result<RankCount, String> {
        RankCount::parse(s).ok_or_else(|| format!("bad rank count '{s}' (use N >= 1 or 'auto')"))
    }
}

impl fmt::Display for RankCount {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RankCount::Auto => f.write_str("auto"),
            RankCount::Count(n) => write!(f, "{n}"),
        }
    }
}

/// What kind of fault strikes at a [`FaultEvent`]'s step.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// A node dies: work since the last checkpoint is lost and the run
    /// pays a restore (load + rematerialization).
    NodeFailure,
    /// A spot-style preemption with advance notice: if the grace window
    /// fits a checkpoint save, the run flushes and loses nothing;
    /// otherwise it degenerates to a failure.
    Preemption {
        /// Seconds of notice before the node is reclaimed.
        grace_s: f64,
    },
    /// An elastic world-resize: the run continues on `new_nodes` nodes
    /// after paying a re-shard (an all-to-all of the per-rank optimizer
    /// state over the new world, priced through the collective cost
    /// model). No work is lost.
    Resize {
        /// Node count after the resize (must leave >= 2 workers).
        new_nodes: usize,
    },
}

/// One deterministic fault: `kind` strikes immediately before step
/// `at_step` executes. Priced by `sim::goodput::price_timeline`; events
/// never perturb the per-step clock itself (the step schedule stays
/// bit-identical), only the run-level time accounting.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    /// Zero-based optimizer-step index the fault fires before.
    pub at_step: usize,
    /// What happens.
    pub kind: FaultKind,
}

/// A deterministic asymmetry recipe for one simulated step.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    /// How many ranks the multi-rank builder models explicitly.
    pub ranks: RankCount,
    /// `(rank, compute multiplier)` — e.g. `(5, 1.2)` slows rank 5's
    /// kernels by 20%. Multipliers compose with jitter.
    pub stragglers: Vec<(usize, f64)>,
    /// Lognormal per-node compute jitter: each node draws `exp(sigma * z)`,
    /// `z ~ N(0,1)`, shared by all its ranks. 0 disables.
    pub jitter_sigma: f64,
    /// Seed for the jitter draws.
    pub seed: u64,
    /// `(rank, grad_accum)` overrides — imbalanced accumulation groups
    /// (some ranks run more microbatches before the sync boundary).
    pub imbalance: Vec<(usize, usize)>,
    /// Deterministic fault events, priced at the run level by the
    /// goodput layer. Does **not** affect [`Scenario::is_trivial`]: the
    /// per-step clock is identical with or without faults.
    pub faults: Vec<FaultEvent>,
}

impl Default for Scenario {
    fn default() -> Self {
        Scenario {
            ranks: RankCount::Auto,
            stragglers: Vec::new(),
            jitter_sigma: 0.0,
            seed: 42,
            imbalance: Vec::new(),
            faults: Vec::new(),
        }
    }
}

impl Scenario {
    /// True when no injector is active (every rank congruent).
    pub fn is_trivial(&self) -> bool {
        self.stragglers.is_empty() && self.jitter_sigma == 0.0 && self.imbalance.is_empty()
    }

    /// Per-rank compute multipliers over the whole world: node jitter
    /// (seeded, in node order) composed with explicit stragglers.
    pub fn compute_multipliers(&self, cluster: &Cluster) -> Vec<f64> {
        let world = cluster.world_size();
        let wpn = cluster.workers_per_node();
        let mut mult = vec![1.0; world];
        if self.jitter_sigma > 0.0 {
            let mut rng = Rng::new(self.seed);
            for node in 0..cluster.nodes {
                let m = (self.jitter_sigma * rng.normal()).exp();
                for r in node * wpn..(node + 1) * wpn {
                    mult[r] *= m;
                }
            }
        }
        for &(r, m) in &self.stragglers {
            assert!(m > 0.0 && m.is_finite(), "bad straggler multiplier {m}");
            if r < world {
                mult[r] *= m;
            }
        }
        mult
    }

    /// Per-**stage** compute multipliers for a `stages`-deep pipeline
    /// over `cluster`: the worst (largest) [`Scenario::compute_multipliers`]
    /// entry within each stage's contiguous `W/P`-rank block. The slowest
    /// DP rank of a stage gates the stage's collectives, so it sets the
    /// stage's effective speed — this is how "a straggler on a stage"
    /// composes with the pipeline schedule
    /// (`sched::pipeline::PipelinePlan::with_stage_multipliers`).
    pub fn stage_multipliers(&self, cluster: &Cluster, stages: usize) -> Vec<f64> {
        let world = cluster.world_size();
        assert!(stages >= 1 && world % stages == 0, "stages must divide the world");
        let mult = self.compute_multipliers(cluster);
        let dp = world / stages;
        (0..stages)
            .map(|s| mult[s * dp..(s + 1) * dp].iter().fold(f64::NEG_INFINITY, |a, &b| a.max(b)))
            .collect()
    }

    /// Per-rank grad-accum counts: `base` everywhere, overridden by the
    /// imbalance list (overrides clamp to >= 1).
    pub fn grad_accums(&self, world: usize, base: usize) -> Vec<usize> {
        let mut ga = vec![base.max(1); world];
        for &(r, g) in &self.imbalance {
            if r < world {
                ga[r] = g.max(1);
            }
        }
        ga
    }

    /// Parse a `rank:mult[,rank:mult...]` list (e.g. `5:1.2,17:1.5`).
    pub fn parse_stragglers(s: &str) -> Result<Vec<(usize, f64)>, String> {
        parse_pairs(s, "straggler", |v: f64| v > 0.0 && v.is_finite())
    }

    /// Parse a `rank:grad_accum[,...]` list (e.g. `3:4`).
    pub fn parse_imbalance(s: &str) -> Result<Vec<(usize, usize)>, String> {
        parse_pairs(s, "imbalance", |v: usize| v >= 1)
    }

    /// Parse a comma-separated fault list. Each entry is one of
    ///
    /// * `STEP:fail` — node failure before step `STEP`;
    /// * `STEP:preempt:GRACE` — preemption with `GRACE` seconds notice;
    /// * `STEP:resize:NODES` — elastic resize to `NODES` nodes.
    ///
    /// Example: `"10:fail,25:preempt:30,40:resize:24"`.
    pub fn parse_faults(s: &str) -> Result<Vec<FaultEvent>, String> {
        let mut out = Vec::new();
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let mut fields = part.split(':');
            let step = fields
                .next()
                .and_then(|f| f.trim().parse::<usize>().ok())
                .ok_or_else(|| format!("bad fault '{part}' (want STEP:kind[:arg])"))?;
            let kind = match fields.next().map(|f| f.trim().to_ascii_lowercase()) {
                Some(k) if k == "fail" => {
                    if fields.next().is_some() {
                        return Err(format!("fault '{part}': 'fail' takes no argument"));
                    }
                    FaultKind::NodeFailure
                }
                Some(k) if k == "preempt" => {
                    let grace = fields
                        .next()
                        .and_then(|f| f.trim().parse::<f64>().ok())
                        .filter(|g| g.is_finite() && *g >= 0.0)
                        .ok_or_else(|| {
                            format!("fault '{part}': want STEP:preempt:GRACE_SECONDS (>= 0)")
                        })?;
                    FaultKind::Preemption { grace_s: grace }
                }
                Some(k) if k == "resize" => {
                    let nodes = fields
                        .next()
                        .and_then(|f| f.trim().parse::<usize>().ok())
                        .filter(|n| *n >= 1)
                        .ok_or_else(|| {
                            format!("fault '{part}': want STEP:resize:NODES (>= 1)")
                        })?;
                    FaultKind::Resize { new_nodes: nodes }
                }
                _ => {
                    return Err(format!(
                        "bad fault '{part}' (kinds: fail, preempt:GRACE, resize:NODES)"
                    ))
                }
            };
            if fields.next().is_some() {
                return Err(format!("fault '{part}': trailing fields"));
            }
            out.push(FaultEvent { at_step: step, kind });
        }
        Ok(out)
    }
}

fn parse_pairs<T: FromStr + Copy>(
    s: &str,
    what: &str,
    ok: impl Fn(T) -> bool,
) -> Result<Vec<(usize, T)>, String> {
    let mut out = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (r, v) = part
            .split_once(':')
            .ok_or_else(|| format!("bad {what} '{part}' (want rank:value)"))?;
        let rank: usize =
            r.trim().parse().map_err(|_| format!("bad {what} rank '{r}'"))?;
        let val: T = v.trim().parse().map_err(|_| format!("bad {what} value '{v}'"))?;
        if !ok(val) {
            return Err(format!("out-of-range {what} value '{v}'"));
        }
        out.push((rank, val));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_count_parses() {
        assert_eq!(RankCount::parse("auto"), Some(RankCount::Auto));
        assert_eq!(RankCount::parse("4"), Some(RankCount::Count(4)));
        assert_eq!(RankCount::parse("0"), None);
        assert_eq!(RankCount::parse("x"), None);
        assert_eq!(RankCount::Auto.to_string(), "auto");
        assert_eq!("8".parse::<RankCount>().unwrap(), RankCount::Count(8));
    }

    #[test]
    fn multipliers_compose_jitter_and_stragglers() {
        let cluster = Cluster::frontier(2);
        let mut sc = Scenario { jitter_sigma: 0.1, ..Default::default() };
        sc.stragglers = vec![(3, 2.0)];
        let m = sc.compute_multipliers(&cluster);
        assert_eq!(m.len(), 16);
        // per-node jitter: all ranks of a node share the draw
        for r in 1..8 {
            if r != 3 {
                assert_eq!(m[r], m[0], "rank {r}");
            }
        }
        assert!((m[3] / m[0] - 2.0).abs() < 1e-12);
        // distinct nodes get distinct draws (a.s.)
        assert_ne!(m[0], m[8]);
        // deterministic across calls
        assert_eq!(m, sc.compute_multipliers(&cluster));
    }

    #[test]
    fn trivial_scenario_has_unit_multipliers() {
        let cluster = Cluster::frontier(2);
        let sc = Scenario::default();
        assert!(sc.is_trivial());
        assert!(sc.compute_multipliers(&cluster).iter().all(|&m| m == 1.0));
        assert_eq!(sc.grad_accums(4, 3), vec![3, 3, 3, 3]);
    }

    #[test]
    fn pair_lists_parse() {
        assert_eq!(Scenario::parse_stragglers("5:1.2, 7:2").unwrap(), vec![(5, 1.2), (7, 2.0)]);
        assert_eq!(Scenario::parse_imbalance("3:4").unwrap(), vec![(3, 4)]);
        assert!(Scenario::parse_stragglers("5").is_err());
        assert!(Scenario::parse_stragglers("5:-1").is_err());
        assert!(Scenario::parse_imbalance("3:0").is_err());
        assert_eq!(Scenario::parse_stragglers("").unwrap(), vec![]);
    }

    #[test]
    fn stage_multipliers_take_the_block_max() {
        let cluster = Cluster::frontier(4); // 32 ranks
        let sc = Scenario { stragglers: vec![(5, 1.5), (20, 2.0)], ..Default::default() };
        // 4 stages of 8 ranks: rank 5 -> stage 0, rank 20 -> stage 2
        let m = sc.stage_multipliers(&cluster, 4);
        assert_eq!(m, vec![1.5, 1.0, 2.0, 1.0]);
        // one stage = whole-world max
        assert_eq!(sc.stage_multipliers(&cluster, 1), vec![2.0]);
        // trivial scenario: all ones
        assert!(Scenario::default()
            .stage_multipliers(&cluster, 2)
            .iter()
            .all(|&x| x == 1.0));
    }

    #[test]
    fn fault_lists_parse() {
        let faults = Scenario::parse_faults("10:fail, 25:preempt:30, 40:resize:24").unwrap();
        assert_eq!(
            faults,
            vec![
                FaultEvent { at_step: 10, kind: FaultKind::NodeFailure },
                FaultEvent { at_step: 25, kind: FaultKind::Preemption { grace_s: 30.0 } },
                FaultEvent { at_step: 40, kind: FaultKind::Resize { new_nodes: 24 } },
            ]
        );
        assert_eq!(Scenario::parse_faults("").unwrap(), vec![]);
        assert!(Scenario::parse_faults("10").is_err());
        assert!(Scenario::parse_faults("10:explode").is_err());
        assert!(Scenario::parse_faults("10:fail:3").is_err());
        assert!(Scenario::parse_faults("10:preempt").is_err());
        assert!(Scenario::parse_faults("10:preempt:-5").is_err());
        assert!(Scenario::parse_faults("10:preempt:nan").is_err());
        assert!(Scenario::parse_faults("10:resize:0").is_err());
        assert!(Scenario::parse_faults("x:fail").is_err());
        assert!(Scenario::parse_faults("10:resize:24:7").is_err());
    }

    #[test]
    fn faults_do_not_make_a_scenario_nontrivial() {
        // the per-step clock is unchanged by faults; only the run-level
        // goodput accounting sees them
        let sc = Scenario {
            faults: vec![FaultEvent { at_step: 1, kind: FaultKind::NodeFailure }],
            ..Default::default()
        };
        assert!(sc.is_trivial());
    }

    #[test]
    fn grad_accum_overrides() {
        let sc = Scenario { imbalance: vec![(1, 5), (9, 2)], ..Default::default() };
        let ga = sc.grad_accums(4, 3);
        assert_eq!(ga, vec![3, 5, 3, 3]); // rank 9 out of world: ignored
    }
}
