//! Exact critical-path decomposition: the attribution half of the
//! bottleneck engine (DESIGN.md §14).
//!
//! [`critical_path`] walks any [`Schedule`] — single-rank, multi-rank,
//! pipeline, layered — backwards from the last-finishing task through
//! whichever blocker (dependency or same-stream FIFO predecessor)
//! finished latest. [`decompose`] then partitions the makespan along
//! that path into a **conserved ledger**: compute seconds, per-link-class
//! communication seconds, and idle gaps. The conservation contract is
//! hard: `compute + idle + Σ comm == makespan` to 1e-12 absolute on
//! every graph the simulator can produce (the event loop issues a task
//! at the exact completion instant of its latest blocker, so segment
//! boundaries are bitwise-shared and the gaps are exactly zero;
//! Neumaier-compensated accumulation keeps the per-category sums from
//! drifting on long paths).
//!
//! This module is the one home of the critical-path walk:
//! [`Schedule::critical_path`] and the multi-rank/pipeline report tables
//! delegate here, bit-for-bit unchanged.

use std::collections::BTreeMap;
use std::fmt;

use crate::sched::{Schedule, StreamKind, TaskId};
use crate::topology::LinkClass;

/// What a critical-path segment spent its time on.
///
/// The derived order — `Compute`, then `Comm` fastest link first, then
/// `Idle` — is the ledger's display order, and breaks exact ties in
/// [`Decomposition::dominant`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Category {
    /// A compute task (no link class) on the path.
    Compute,
    /// A communication task on the path, keyed by its link class.
    Comm(LinkClass),
    /// A gap on the path: the next task's start minus the previous
    /// task's end. Structurally zero for simulator-produced schedules
    /// (tasks issue at their latest blocker's completion instant); kept
    /// so the ledger stays conserved on any hand-built span set.
    Idle,
}

impl fmt::Display for Category {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Category::Compute => write!(f, "compute"),
            Category::Comm(c) => write!(f, "comm {c}"),
            Category::Idle => write!(f, "idle"),
        }
    }
}

/// One tile of the critical path: task, category, and the half-open
/// `[start, end)` slice of the makespan it owns (clipped so consecutive
/// segments never overlap), plus the idle gap that preceded it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PathSegment {
    /// The task this segment belongs to.
    pub task: TaskId,
    /// Its ledger category.
    pub category: Category,
    /// Segment start (the later of the task's start and the previous
    /// segment's end).
    pub start: f64,
    /// Segment end (the task's span end).
    pub end: f64,
    /// Gap between the previous segment's end and this task's start
    /// (clamped at zero).
    pub idle_before: f64,
}

/// Neumaier-compensated running sum: exact enough that category totals
/// never drift past the 1e-12 conservation budget, however long the path.
#[derive(Debug, Clone, Copy, Default)]
struct Acc {
    sum: f64,
    comp: f64,
}

impl Acc {
    fn add(&mut self, x: f64) {
        let t = self.sum + x;
        if self.sum.abs() >= x.abs() {
            self.comp += (self.sum - t) + x;
        } else {
            self.comp += (x - t) + self.sum;
        }
        self.sum = t;
    }

    fn total(self) -> f64 {
        self.sum + self.comp
    }
}

/// The conserved makespan ledger of one schedule's critical path.
#[derive(Debug, Clone)]
pub struct Decomposition {
    makespan: f64,
    compute_s: f64,
    idle_s: f64,
    comm_s: BTreeMap<LinkClass, f64>,
    segments: Vec<PathSegment>,
}

impl Decomposition {
    /// The schedule's makespan (the quantity the ledger partitions).
    pub fn makespan(&self) -> f64 {
        self.makespan
    }

    /// Compute seconds on the critical path.
    pub fn compute_s(&self) -> f64 {
        self.compute_s
    }

    /// Idle-gap seconds on the critical path.
    pub fn idle_s(&self) -> f64 {
        self.idle_s
    }

    /// Per-link-class communication seconds on the critical path,
    /// fastest class first.
    pub fn comm_s(&self) -> &BTreeMap<LinkClass, f64> {
        &self.comm_s
    }

    /// Total communication seconds on the critical path.
    pub fn comm_total(&self) -> f64 {
        let mut acc = Acc::default();
        for &v in self.comm_s.values() {
            acc.add(v);
        }
        acc.total()
    }

    /// Sum of every ledger category; equals [`Decomposition::makespan`]
    /// within 1e-12 absolute.
    pub fn total(&self) -> f64 {
        self.compute_s + self.idle_s + self.comm_total()
    }

    /// `|total - makespan|` — the conservation defect this module
    /// guarantees stays under 1e-12 absolute.
    pub fn conservation_error(&self) -> f64 {
        (self.total() - self.makespan).abs()
    }

    /// The ledger rows in display order: compute, per-class comm
    /// (fastest link first), idle.
    pub fn entries(&self) -> Vec<(Category, f64)> {
        let mut rows = vec![(Category::Compute, self.compute_s)];
        rows.extend(self.comm_s.iter().map(|(&c, &v)| (Category::Comm(c), v)));
        rows.push((Category::Idle, self.idle_s));
        rows
    }

    /// The category holding the largest share of the makespan — "what is
    /// this step bound by". Exact ties go to the earlier category in
    /// [`Decomposition::entries`] order (compute outranks comm outranks
    /// idle), so the answer is deterministic.
    pub fn dominant(&self) -> Category {
        let mut best = (Category::Compute, f64::NEG_INFINITY);
        for (cat, v) in self.entries() {
            if v > best.1 {
                best = (cat, v);
            }
        }
        best.0
    }

    /// The path segments in execution order.
    pub fn segments(&self) -> &[PathSegment] {
        &self.segments
    }
}

/// The critical path of `sched`: from the last-finishing task, walk
/// backwards through whichever blocker (dependency or same-stream FIFO
/// predecessor) finished latest. Returned in execution order.
///
/// This is the canonical walk; [`Schedule::critical_path`] is a thin
/// wrapper around it.
pub fn critical_path(sched: &Schedule) -> Vec<TaskId> {
    if sched.spans().is_empty() {
        return Vec::new();
    }
    // same-(rank, stream) FIFO predecessor by insertion order
    let graph = sched.graph();
    let n = graph.len();
    let mut stream_pred: Vec<Option<TaskId>> = vec![None; n];
    let mut last_on: BTreeMap<(usize, StreamKind), TaskId> = BTreeMap::new();
    for (i, t) in graph.tasks().iter().enumerate() {
        let key = (t.rank, t.stream);
        stream_pred[i] = last_on.get(&key).copied();
        last_on.insert(key, TaskId(i));
    }
    let mut cur = TaskId(0);
    let mut best_end = f64::NEG_INFINITY;
    for s in sched.spans() {
        if s.end > best_end {
            best_end = s.end;
            cur = s.task;
        }
    }
    let mut path = vec![cur];
    loop {
        let t = graph.task(cur);
        let mut blocker: Option<TaskId> = None;
        let mut blocker_end = f64::NEG_INFINITY;
        for &d in t.deps.iter().chain(stream_pred[cur.0].iter()) {
            let e = sched.span(d).end;
            if e > blocker_end {
                blocker_end = e;
                blocker = Some(d);
            }
        }
        match blocker {
            // blockers always precede `cur` in insertion order, so the
            // walk strictly decreases and terminates
            Some(b) => {
                path.push(b);
                cur = b;
            }
            None => break,
        }
    }
    path.reverse();
    path
}

/// Partition `sched`'s makespan into the conserved attribution ledger.
///
/// Walks [`critical_path`] front to back with a cursor: any gap before a
/// task is `Idle`, the remainder of the task's span is `Compute` or
/// `Comm(class)` by whether the task holds a link class. An empty
/// schedule decomposes to an all-zero ledger.
pub fn decompose(sched: &Schedule) -> Decomposition {
    let path = critical_path(sched);
    let mut compute = Acc::default();
    let mut idle = Acc::default();
    let mut comm: BTreeMap<LinkClass, Acc> = BTreeMap::new();
    let mut segments = Vec::with_capacity(path.len());
    let mut cursor = 0.0f64;
    for &id in &path {
        let span = sched.span(id);
        let gap = (span.start - cursor).max(0.0);
        if gap > 0.0 {
            idle.add(gap);
        }
        let start = span.start.max(cursor);
        let dur = span.end - start;
        let category = match sched.graph().task(id).class {
            None => Category::Compute,
            Some(c) => Category::Comm(c),
        };
        match category {
            Category::Compute => compute.add(dur),
            Category::Comm(c) => comm.entry(c).or_default().add(dur),
            Category::Idle => unreachable!("segments are never Idle"),
        }
        segments.push(PathSegment { task: id, category, start, end: span.end, idle_before: gap });
        cursor = span.end;
    }
    Decomposition {
        makespan: sched.makespan(),
        compute_s: compute.total(),
        idle_s: idle.total(),
        comm_s: comm.into_iter().map(|(c, a)| (c, a.total())).collect(),
        segments,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{simulate, Task, TaskGraph};

    fn graph_with(specs: &[(&str, StreamKind, f64, Option<LinkClass>, Vec<usize>)]) -> Schedule {
        let mut g = TaskGraph::new();
        for (label, stream, work, class, deps) in specs {
            g.add(Task {
                label: (*label).into(),
                rank: 0,
                stream: *stream,
                work: *work,
                class: *class,
                instance: 0,
                deps: deps.iter().map(|&d| TaskId(d)).collect(),
            });
        }
        simulate(g)
    }

    #[test]
    fn empty_schedule_decomposes_to_zero() {
        let sched = simulate(TaskGraph::new());
        let d = decompose(&sched);
        assert_eq!(d.makespan(), 0.0);
        assert_eq!(d.total(), 0.0);
        assert_eq!(d.conservation_error(), 0.0);
        assert!(d.segments().is_empty());
        assert_eq!(d.dominant(), Category::Compute);
    }

    #[test]
    fn gather_then_compute_splits_exactly() {
        let sched = graph_with(&[
            ("gather", StreamKind::Prefetch, 1.5, Some(LinkClass::InterNode), vec![]),
            ("fwd", StreamKind::Compute, 2.0, None, vec![0]),
        ]);
        let d = decompose(&sched);
        assert_eq!(d.makespan(), 3.5);
        assert_eq!(d.compute_s(), 2.0);
        assert_eq!(d.comm_s()[&LinkClass::InterNode], 1.5);
        assert_eq!(d.idle_s(), 0.0);
        assert_eq!(d.conservation_error(), 0.0);
        assert_eq!(d.dominant(), Category::Compute);
    }

    #[test]
    fn overlapped_gather_attributes_only_exposed_time() {
        // compute a || gather, then compute b needing the gather: the
        // gather's exposed slice on the path is only its tail
        let sched = graph_with(&[
            ("a", StreamKind::Compute, 1.0, None, vec![]),
            ("gather", StreamKind::Prefetch, 3.0, Some(LinkClass::Intra(0)), vec![]),
            ("b", StreamKind::Compute, 1.0, None, vec![1]),
        ]);
        let d = decompose(&sched);
        assert_eq!(d.makespan(), 4.0);
        // path = gather (0..3) -> b (3..4); `a` overlaps inside gather
        assert_eq!(d.comm_s()[&LinkClass::Intra(0)], 3.0);
        assert_eq!(d.compute_s(), 1.0);
        assert_eq!(d.conservation_error(), 0.0);
        assert_eq!(d.dominant(), Category::Comm(LinkClass::Intra(0)));
    }

    #[test]
    fn dominant_breaks_ties_toward_compute() {
        let sched = graph_with(&[
            ("gather", StreamKind::Prefetch, 2.0, Some(LinkClass::InterNode), vec![]),
            ("fwd", StreamKind::Compute, 2.0, None, vec![0]),
        ]);
        let d = decompose(&sched);
        assert_eq!(d.compute_s(), d.comm_s()[&LinkClass::InterNode]);
        assert_eq!(d.dominant(), Category::Compute);
    }

    #[test]
    fn wrapper_matches_canonical_walk() {
        let sched = graph_with(&[
            ("g0", StreamKind::Prefetch, 0.5, Some(LinkClass::InterNode), vec![]),
            ("c0", StreamKind::Compute, 1.0, None, vec![0]),
            ("g1", StreamKind::Prefetch, 2.0, Some(LinkClass::InterNode), vec![]),
            ("c1", StreamKind::Compute, 1.0, None, vec![1, 2]),
            ("sync", StreamKind::GradSync, 0.25, Some(LinkClass::InterNode), vec![3]),
        ]);
        assert_eq!(sched.critical_path(), critical_path(&sched));
    }

    #[test]
    fn segments_tile_the_makespan() {
        let sched = graph_with(&[
            ("g", StreamKind::Prefetch, 0.7, Some(LinkClass::Intra(1)), vec![]),
            ("c", StreamKind::Compute, 1.3, None, vec![0]),
            ("s", StreamKind::GradSync, 0.9, Some(LinkClass::InterNode), vec![1]),
        ]);
        let d = decompose(&sched);
        let mut cursor = 0.0;
        for seg in d.segments() {
            assert_eq!(seg.start, cursor, "gapless tiling");
            assert!(seg.end >= seg.start);
            cursor = seg.end;
        }
        assert_eq!(cursor, d.makespan());
        assert!(d.conservation_error() <= 1e-12);
    }
}
