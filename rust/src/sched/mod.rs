//! Discrete-event multi-stream step scheduler (DESIGN.md §5).
//!
//! Models one optimizer step as a DAG of tasks executed by per-rank
//! *resource streams* — the streams a DeepSpeed/FSDP-style runtime
//! actually runs, plus a pipeline-transfer lane:
//!
//! * **Compute**: forward/backward kernels, one serial queue per rank.
//! * **Prefetch**: the parameter all-gather side stream. Weight gathers
//!   issue here in consumption order — one per microbatch phase, or one
//!   per layer block under layer-granular prefetch — bounded by the
//!   prefetch [`Depth`] (how many gather units may run ahead of the
//!   compute that consumes them).
//! * **GradSync**: the gradient/optimizer path — blocking reduce-scatter /
//!   all-to-all / all-reduce phases at the grad-accumulation boundary,
//!   plus the §V.D updated-weight all-gather (charged at the step head:
//!   in steady state the refresh issued after step `s` overlaps the
//!   compute of step `s+1`).
//! * **PipeTransfer**: stage-to-stage activation/gradient point-to-point
//!   transfers when a pipeline schedule is in play ([`pipeline`]); pure
//!   data-parallel steps leave it empty.
//!
//! The event loop is a fluid-flow simulation: each stream executes its
//! FIFO queue in order, a task starts when its dependencies are done and
//! its stream is free, and concurrent communication tasks that share a
//! [`LinkClass`] split that class's bandwidth evenly (processor sharing —
//! two inter-node collectives in flight each proceed at half rate). Time
//! advances to the earliest completion under the current rates.
//!
//! [`Schedule`] retains every task's `[start, end)` span, from which the
//! makespan (the simulated step time), per-stream busy time, and the
//! *stall breakdown* — compute-idle time attributed to the link class
//! that was busy while compute waited — are derived. `sim::simulate_step`
//! and `engine::TrainEngine` both obtain their step clock from this event
//! loop via [`plan::StepPlan`], so their communication pricing and
//! schedule semantics can never drift.
//!
//! # Example
//!
//! A 1 s gather feeding a 2 s kernel makes a 3 s step whose stall is
//! attributed to the gather's link class:
//!
//! ```no_run
//! // (no_run: doctest binaries miss the libxla rpath in this offline env)
//! use zero_topo::sched::{simulate, StreamKind, Task, TaskGraph};
//! use zero_topo::topology::LinkClass;
//!
//! let mut g = TaskGraph::new();
//! let gather = g.add(Task {
//!     label: "gather".into(),
//!     rank: 0,
//!     stream: StreamKind::Prefetch,
//!     work: 1.0,
//!     class: Some(LinkClass::InterNode),
//!     instance: 0,
//!     deps: vec![],
//! });
//! g.add(Task {
//!     label: "fwd".into(),
//!     rank: 0,
//!     stream: StreamKind::Compute,
//!     work: 2.0,
//!     class: None,
//!     instance: 0,
//!     deps: vec![gather],
//! });
//! let sched = simulate(g);
//! assert!((sched.makespan() - 3.0).abs() < 1e-12);
//! assert!((sched.stall_by_class(0)[&LinkClass::InterNode] - 1.0).abs() < 1e-12);
//! ```

pub mod critical;
pub mod multi;
pub mod pipeline;
pub mod plan;
pub mod reference;
pub mod scenario;
pub mod trace;

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap};
use std::fmt;
use std::str::FromStr;

use crate::metrics::StepUtilization;
use crate::topology::LinkClass;

/// The per-rank resource streams of a training step.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum StreamKind {
    /// Forward/backward kernels, one serial queue per rank.
    Compute,
    /// The parameter all-gather side stream (bounded by [`Depth`]).
    Prefetch,
    /// Gradient-sync phases + the §V.D updated-weight refresh.
    GradSync,
    /// Stage-to-stage activation/gradient transfers of a pipeline
    /// schedule ([`pipeline::PipelinePlan`]); empty for pure-DP steps.
    PipeTransfer,
}

impl StreamKind {
    /// Short display name ("compute", "prefetch", "grad-sync", "pipe").
    pub fn name(&self) -> &'static str {
        match self {
            StreamKind::Compute => "compute",
            StreamKind::Prefetch => "prefetch",
            StreamKind::GradSync => "grad-sync",
            StreamKind::PipeTransfer => "pipe",
        }
    }
}

/// Prefetch depth: how many gather *units* the prefetch stream may run
/// ahead of the compute that consumes them. The unit depends on the plan:
///
/// * **monolithic** plans (the default — [`plan::StepPlan`] with no layer
///   blocks) issue one whole-model gather per microbatch phase, so
///   `Bounded(d)` means *d per-microbatch gathers* ahead;
/// * **layer-granular** plans ([`plan::StepPlan::from_protocol_layered`],
///   CLI `--layer-granular` / `--blocks`) split each microbatch gather
///   into per-layer-block tasks, so `Bounded(d)` means *d layer blocks*
///   ahead of the compute cursor — DeepSpeed's parameter-prefetch window
///   expressed in layers (DESIGN.md §12).
///
/// `Bounded(0)` fetches only when needed (fully serialized) in both
/// modes; `Infinite` lets the gather pipeline run freely (DeepSpeed's
/// free-running side stream).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Depth {
    /// At most this many gather units ahead of their consumers (0 = on
    /// demand). Units are microbatch gathers or layer blocks — see the
    /// enum docs.
    Bounded(usize),
    /// Free-running gather pipeline (DeepSpeed's side stream).
    Infinite,
}

impl Depth {
    /// Parse `"0"`, `"2"`, ... or `"inf"`/`"infinite"`/`"unbounded"`.
    pub fn parse(s: &str) -> Option<Depth> {
        match s.to_ascii_lowercase().as_str() {
            "inf" | "infinite" | "unbounded" => Some(Depth::Infinite),
            other => other.parse::<usize>().ok().map(Depth::Bounded),
        }
    }
}

impl FromStr for Depth {
    type Err = String;

    fn from_str(s: &str) -> Result<Depth, String> {
        Depth::parse(s).ok_or_else(|| format!("bad depth '{s}' (use a number or 'inf')"))
    }
}

impl fmt::Display for Depth {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Depth::Bounded(d) => write!(f, "{d}"),
            Depth::Infinite => f.write_str("inf"),
        }
    }
}

/// Handle into a [`TaskGraph`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct TaskId(pub usize);

/// One node of the step DAG. `rank` is a first-class field (graphs with a
/// declared rank registry reject tasks naming unknown ranks), so per-rank
/// queries on the schedule can never mis-bucket tasks.
#[derive(Debug, Clone)]
pub struct Task {
    /// Display label ("gather.fwd[0]", "compute.bwd[3]@r5", ...).
    pub label: String,
    /// World rank whose streams execute this task.
    pub rank: usize,
    /// Which of the rank's serial streams queues the task.
    pub stream: StreamKind,
    /// Seconds of work at unit rate (a comm task sharing its contention
    /// domain with n-1 concurrent peers proceeds at rate 1/n).
    pub work: f64,
    /// Link class for communication tasks; `None` for compute.
    pub class: Option<LinkClass>,
    /// Contention sub-domain within the class: tasks compete for bandwidth
    /// only when both `class` and `instance` match. Single-rank plans use 0
    /// everywhere (one shared domain per class, the pre-multi-rank
    /// semantics); multi-rank plans key instances off physical links — the
    /// level-`k` block index for `Intra(k)`, one shared fabric for
    /// `InterNode` — so two GCD pairs' gathers ride separate IF links while
    /// collectives crossing the same fabric genuinely compete.
    pub instance: usize,
    /// Tasks that must complete before this one may start (must already
    /// be in the graph).
    pub deps: Vec<TaskId>,
}

/// The step DAG. Acyclic by construction: a task may only depend on
/// tasks added before it, and per-stream FIFO order is insertion order.
#[derive(Debug, Clone, Default)]
pub struct TaskGraph {
    tasks: Vec<Task>,
    /// Declared rank registry (sorted). `None` = infer ranks from tasks
    /// (single-rank plans); multi-rank builders declare their modeled rank
    /// ids up front so `add` can reject mis-bucketed tasks.
    rank_ids: Option<Vec<usize>>,
}

impl TaskGraph {
    /// An empty graph with no declared rank registry (ranks inferred).
    pub fn new() -> TaskGraph {
        TaskGraph::default()
    }

    /// An empty graph whose task arena is pre-sized for `cap` tasks —
    /// builders that know their task count up front avoid re-allocation
    /// on the hot path (DESIGN.md §16).
    pub fn with_capacity(cap: usize) -> TaskGraph {
        TaskGraph { tasks: Vec::with_capacity(cap), rank_ids: None }
    }

    /// Reserve arena capacity for at least `additional` more tasks.
    pub fn reserve(&mut self, additional: usize) {
        self.tasks.reserve(additional);
    }

    /// A graph with an explicit rank registry: every task added must name
    /// one of `ranks`, and [`Schedule::ranks`] reports exactly this set
    /// (even for ranks that end up owning only shared tasks).
    pub fn with_rank_ids(mut ranks: Vec<usize>) -> TaskGraph {
        assert!(!ranks.is_empty(), "rank registry must be non-empty");
        ranks.sort_unstable();
        ranks.dedup();
        TaskGraph { tasks: Vec::new(), rank_ids: Some(ranks) }
    }

    /// The declared rank registry, if one was given at construction.
    pub fn rank_ids(&self) -> Option<&[usize]> {
        self.rank_ids.as_deref()
    }

    /// Add a task; its dependencies must already be in the graph.
    pub fn add(&mut self, task: Task) -> TaskId {
        let id = TaskId(self.tasks.len());
        for d in &task.deps {
            assert!(d.0 < id.0, "dependency {:?} added after dependent {:?}", d, id);
        }
        assert!(task.work >= 0.0 && task.work.is_finite(), "bad work {}", task.work);
        if let Some(ranks) = &self.rank_ids {
            assert!(
                ranks.binary_search(&task.rank).is_ok(),
                "task '{}' names rank {} outside the declared registry {:?}",
                task.label,
                task.rank,
                ranks
            );
        }
        self.tasks.push(task);
        id
    }

    /// The task behind a handle.
    pub fn task(&self, id: TaskId) -> &Task {
        &self.tasks[id.0]
    }

    /// All tasks, in insertion (= per-stream FIFO) order.
    pub fn tasks(&self) -> &[Task] {
        &self.tasks
    }

    /// Number of tasks in the graph.
    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    /// True when the graph holds no tasks.
    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }
}

/// Executed `[start, end)` interval of one task.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Span {
    /// The executed task.
    pub task: TaskId,
    /// Simulated start time (seconds).
    pub start: f64,
    /// Simulated end time (seconds).
    pub end: f64,
}

/// Link-utilization accounting of one `(LinkClass, instance)` contention
/// domain, derived post-hoc from the executed spans — the telemetry layer
/// (DESIGN.md §13) reads the timeline the event loop already produced, so
/// enabling it cannot perturb the event clock.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct LinkUsage {
    /// Seconds at least one task occupied the domain (union of spans).
    pub busy: f64,
    /// Summed task span seconds (`>= busy`; the ratio is the mean
    /// processor-sharing fan-in while the domain was busy).
    pub task_seconds: f64,
    /// Number of tasks that rode the domain.
    pub tasks: usize,
    /// Peak concurrent tasks in flight (the worst fan-in the event loop
    /// arbitrated on the domain).
    pub peak_in_flight: usize,
}

/// The executed timeline of a [`TaskGraph`].
#[derive(Debug, Clone)]
pub struct Schedule {
    graph: TaskGraph,
    spans: Vec<Span>,
    makespan: f64,
}

/// Dense, index-based image of a [`TaskGraph`], built once per
/// simulation (DESIGN.md §16): interned stream and contention-domain
/// ids, per-stream FIFO task lists, CSR dependents adjacency, and the
/// initial unmet-dependency counters. Everything the event loop touches
/// per round is a flat `Vec` indexed by task, stream, or domain id —
/// no map lookups on the hot path.
struct Arena {
    /// Stream id per task; streams are the distinct `(rank, StreamKind)`
    /// keys numbered in sorted order (the reference loop's scan order).
    stream_of: Vec<usize>,
    /// Per-stream task lists in insertion (= FIFO) order.
    stream_tasks: Vec<Vec<usize>>,
    /// Contention-domain id per task (`usize::MAX` = no link class); the
    /// domains are the distinct `(LinkClass, instance)` pairs.
    domain_of: Vec<usize>,
    /// Number of interned contention domains.
    n_domains: usize,
    /// CSR dependents adjacency: `dep_edges[dep_start[i]..dep_start[i+1]]`
    /// are the tasks whose unmet counter drops when task `i` completes.
    dep_start: Vec<usize>,
    dep_edges: Vec<usize>,
    /// Incoming dependency-edge count per task (duplicates counted — a
    /// duplicated dep contributes one initial unit and one decrement).
    unmet_init: Vec<usize>,
}

impl Arena {
    fn build(graph: &TaskGraph) -> Arena {
        let n = graph.tasks.len();
        let mut keys: Vec<(usize, StreamKind)> =
            graph.tasks.iter().map(|t| (t.rank, t.stream)).collect();
        keys.sort_unstable();
        keys.dedup();
        let stream_of: Vec<usize> = graph
            .tasks
            .iter()
            .map(|t| keys.binary_search(&(t.rank, t.stream)).expect("interned stream"))
            .collect();
        let mut stream_tasks: Vec<Vec<usize>> = vec![Vec::new(); keys.len()];
        for (i, &s) in stream_of.iter().enumerate() {
            stream_tasks[s].push(i);
        }

        let mut doms: Vec<(LinkClass, usize)> =
            graph.tasks.iter().filter_map(|t| t.class.map(|c| (c, t.instance))).collect();
        doms.sort_unstable();
        doms.dedup();
        let domain_of: Vec<usize> = graph
            .tasks
            .iter()
            .map(|t| match t.class {
                Some(c) => doms.binary_search(&(c, t.instance)).expect("interned domain"),
                None => usize::MAX,
            })
            .collect();

        let mut unmet_init = vec![0usize; n];
        let mut dep_start = vec![0usize; n + 1];
        for (i, t) in graph.tasks.iter().enumerate() {
            unmet_init[i] = t.deps.len();
            for d in &t.deps {
                dep_start[d.0 + 1] += 1;
            }
        }
        for i in 0..n {
            dep_start[i + 1] += dep_start[i];
        }
        let mut cursor = dep_start.clone();
        let mut dep_edges = vec![0usize; dep_start[n]];
        for (i, t) in graph.tasks.iter().enumerate() {
            for d in &t.deps {
                dep_edges[cursor[d.0]] = i;
                cursor[d.0] += 1;
            }
        }
        Arena {
            stream_of,
            stream_tasks,
            domain_of,
            n_domains: doms.len(),
            dep_start,
            dep_edges,
            unmet_init,
        }
    }
}

/// Run the discrete-event loop over `graph` and return the timeline.
///
/// This is the optimized arena engine (DESIGN.md §16): an [`Arena`] of
/// index-based state built once, a binary-heap worklist of issue-ready
/// streams fed incrementally by completion events, and processor-sharing
/// rates cached per `(LinkClass, instance)` domain and re-priced *only*
/// for the domains whose membership changed since the last round (the
/// lazy contention-share recomputation). It is **bit-identical** to the
/// preserved map-based loop, [`reference::simulate_reference`]: the same
/// set of tasks issues each round (issue order within a round cannot
/// affect the spans — every task issued in a round starts at the same
/// `now`, and readiness depends only on completions), and every
/// floating-point expression — `1.0 / n` shares, the min-fold of
/// `remaining / rate`, the `1e-12`-scaled completion epsilon — is
/// unchanged. `testing::differential` and `tests/differential.rs`
/// enforce the equivalence on randomized and pinned worlds.
pub fn simulate(graph: TaskGraph) -> Schedule {
    let n = graph.len();
    let arena = Arena::build(&graph);
    let n_streams = arena.stream_tasks.len();

    let mut remaining: Vec<f64> = graph.tasks.iter().map(|t| t.work).collect();
    let mut start = vec![f64::NAN; n];
    let mut end = vec![f64::NAN; n];
    let mut unmet = arena.unmet_init.clone();

    let mut stream_head = vec![0usize; n_streams];
    let mut stream_busy = vec![false; n_streams];

    // worklist of streams whose head may be issuable; `queued` dedups.
    // Stale entries are harmless — the pop guard re-checks the state.
    let mut ready: BinaryHeap<Reverse<usize>> = BinaryHeap::with_capacity(n_streams);
    let mut queued = vec![false; n_streams];
    for s in 0..n_streams {
        if arena.stream_tasks[s].first().is_some_and(|&i| unmet[i] == 0) {
            ready.push(Reverse(s));
            queued[s] = true;
        }
    }

    // dense running set (order-independent: swap-removal is fine because
    // the min-fold and the per-task decrement don't depend on order)
    let mut running: Vec<usize> = Vec::with_capacity(n_streams);

    // per-domain processor-sharing state, re-priced only when dirty
    let mut dom_count = vec![0usize; arena.n_domains];
    let mut dom_rate = vec![1.0f64; arena.n_domains];
    let mut dom_dirty = vec![false; arena.n_domains];
    let mut dirty: Vec<usize> = Vec::with_capacity(arena.n_domains);

    let mut now = 0.0f64;
    let mut n_done = 0usize;
    while n_done < n {
        // issue phase: drain the worklist. Each stream issues at most one
        // task (it is busy until that task completes), so one drain reaches
        // the same fixed point as the reference's repeated full scans.
        while let Some(Reverse(s)) = ready.pop() {
            queued[s] = false;
            if stream_busy[s] {
                continue;
            }
            let h = stream_head[s];
            if h >= arena.stream_tasks[s].len() {
                continue;
            }
            let i = arena.stream_tasks[s][h];
            if unmet[i] != 0 {
                continue;
            }
            start[i] = now;
            stream_head[s] = h + 1;
            stream_busy[s] = true;
            running.push(i);
            let d = arena.domain_of[i];
            if d != usize::MAX {
                dom_count[d] += 1;
                if !dom_dirty[d] {
                    dom_dirty[d] = true;
                    dirty.push(d);
                }
            }
        }
        if running.is_empty() {
            // every remaining task waits on a dependency that can never
            // finish — impossible for graphs built through `add`
            panic!("scheduler deadlock: {} of {} tasks unreachable", n - n_done, n);
        }

        // lazy re-pricing: only domains whose membership changed since the
        // last round get a fresh 1/n share (same expression as the
        // reference's full rebuild, so the value is bit-identical)
        for &d in &dirty {
            dom_dirty[d] = false;
            if dom_count[d] > 0 {
                dom_rate[d] = 1.0 / dom_count[d] as f64;
            }
        }
        dirty.clear();

        let rate = |i: usize| -> f64 {
            let d = arena.domain_of[i];
            if d == usize::MAX {
                1.0
            } else {
                dom_rate[d]
            }
        };

        // advance to the earliest completion under current rates
        let dt = running
            .iter()
            .map(|&i| remaining[i] / rate(i))
            .fold(f64::INFINITY, f64::min)
            .max(0.0);
        now += dt;

        // completion sweep: rates stay frozen for the whole sweep (domain
        // membership changes only re-price at the next round's start)
        let mut k = 0;
        while k < running.len() {
            let i = running[k];
            remaining[i] -= rate(i) * dt;
            if remaining[i] <= 1e-12 * graph.tasks[i].work.max(1.0) {
                running.swap_remove(k);
                remaining[i] = 0.0;
                end[i] = now;
                n_done += 1;
                let d = arena.domain_of[i];
                if d != usize::MAX {
                    dom_count[d] -= 1;
                    if !dom_dirty[d] {
                        dom_dirty[d] = true;
                        dirty.push(d);
                    }
                }
                // the stream frees up: re-queue it if its next head is ready
                let s = arena.stream_of[i];
                stream_busy[s] = false;
                let h = stream_head[s];
                if h < arena.stream_tasks[s].len()
                    && unmet[arena.stream_tasks[s][h]] == 0
                    && !queued[s]
                {
                    ready.push(Reverse(s));
                    queued[s] = true;
                }
                // dependents count down; queue their streams when unblocked
                for e in arena.dep_start[i]..arena.dep_start[i + 1] {
                    let j = arena.dep_edges[e];
                    unmet[j] -= 1;
                    if unmet[j] == 0 {
                        let sj = arena.stream_of[j];
                        if !stream_busy[sj] && !queued[sj] {
                            ready.push(Reverse(sj));
                            queued[sj] = true;
                        }
                    }
                }
            } else {
                k += 1;
            }
        }
    }

    let spans: Vec<Span> = (0..n)
        .map(|i| Span { task: TaskId(i), start: start[i], end: end[i] })
        .collect();
    Schedule { graph, makespan: now, spans }
}

impl Schedule {
    /// The graph this schedule executed.
    pub fn graph(&self) -> &TaskGraph {
        &self.graph
    }

    /// Simulated step time: when the last task finished.
    pub fn makespan(&self) -> f64 {
        self.makespan
    }

    /// The executed `[start, end)` interval of one task.
    pub fn span(&self, id: TaskId) -> Span {
        self.spans[id.0]
    }

    /// Every task's executed interval, indexed by [`TaskId`].
    pub fn spans(&self) -> &[Span] {
        &self.spans
    }

    /// The schedule's ranks: the graph's declared registry when present,
    /// otherwise the ranks that own at least one task.
    pub fn ranks(&self) -> Vec<usize> {
        if let Some(ids) = self.graph.rank_ids() {
            return ids.to_vec();
        }
        let mut r: Vec<usize> = self.graph.tasks.iter().map(|t| t.rank).collect();
        r.sort_unstable();
        r.dedup();
        r
    }

    /// Total busy seconds of one stream (streams are serial, so spans on a
    /// stream never overlap).
    pub fn stream_busy(&self, rank: usize, stream: StreamKind) -> f64 {
        self.spans
            .iter()
            .filter(|s| {
                let t = self.graph.task(s.task);
                t.rank == rank && t.stream == stream
            })
            .map(|s| s.end - s.start)
            .sum()
    }

    /// Stall breakdown for one rank: wall time its compute stream sat idle
    /// while at least one communication task of each link class was in
    /// flight — the "where does the step wait" attribution the paper's
    /// bandwidth-level analysis asks for. Overlapping classes are each
    /// charged (the map is attribution, not a partition of idle time).
    pub fn stall_by_class(&self, rank: usize) -> BTreeMap<LinkClass, f64> {
        let mut bounds: Vec<f64> = Vec::with_capacity(2 * self.spans.len());
        for s in &self.spans {
            bounds.push(s.start);
            bounds.push(s.end);
        }
        bounds.sort_by(|a, b| a.partial_cmp(b).expect("finite span bounds"));
        bounds.dedup();

        let mut out: BTreeMap<LinkClass, f64> = BTreeMap::new();
        for w in bounds.windows(2) {
            let (a, b) = (w[0], w[1]);
            if b <= a {
                continue;
            }
            let mid = 0.5 * (a + b);
            let covering = |pred: &dyn Fn(&Task) -> bool| {
                self.spans.iter().any(|s| {
                    s.start < mid && mid < s.end && pred(self.graph.task(s.task))
                })
            };
            let compute_busy =
                covering(&|t: &Task| t.rank == rank && t.stream == StreamKind::Compute);
            if compute_busy {
                continue;
            }
            for s in &self.spans {
                if s.start < mid && mid < s.end {
                    if let Some(c) = self.graph.task(s.task).class {
                        *out.entry(c).or_default() += b - a;
                    }
                }
            }
        }
        out
    }

    /// Busy/idle accounting of one rank's streams.
    pub fn utilization(&self, rank: usize) -> StepUtilization {
        StepUtilization {
            makespan: self.makespan,
            compute_busy: self.stream_busy(rank, StreamKind::Compute),
            prefetch_busy: self.stream_busy(rank, StreamKind::Prefetch),
            grad_sync_busy: self.stream_busy(rank, StreamKind::GradSync),
            pipe_busy: self.stream_busy(rank, StreamKind::PipeTransfer),
        }
    }

    /// Per-`(LinkClass, instance)` link accounting: for every contention
    /// domain the event loop arbitrated, the union-of-spans busy seconds,
    /// summed task seconds, task count, and peak processor-sharing fan-in.
    /// Purely span-derived (post-hoc), so telemetry cannot move the clock.
    pub fn link_usage(&self) -> BTreeMap<(LinkClass, usize), LinkUsage> {
        let mut intervals: BTreeMap<(LinkClass, usize), Vec<(f64, f64)>> = BTreeMap::new();
        for s in &self.spans {
            let t = self.graph.task(s.task);
            if let Some(c) = t.class {
                intervals.entry((c, t.instance)).or_default().push((s.start, s.end));
            }
        }
        intervals.into_iter().map(|(key, iv)| (key, usage_of(&iv))).collect()
    }

    /// Busy seconds per link class: the measure of time at least one task
    /// of the class was in flight on *any* instance (a union, not a sum —
    /// two concurrent gathers on different IF links count once).
    ///
    /// Reconciles with [`Schedule::stall_by_class`]: a stall window is
    /// charged to class `c` only while a class-`c` task is in flight, so
    /// for every rank `stall_by_class(rank)[c] <= class_busy()[c]`
    /// (enforced by `tests/telemetry.rs` on the pinned guardrail configs).
    pub fn class_busy(&self) -> BTreeMap<LinkClass, f64> {
        let mut intervals: BTreeMap<LinkClass, Vec<(f64, f64)>> = BTreeMap::new();
        for s in &self.spans {
            if let Some(c) = self.graph.task(s.task).class {
                intervals.entry(c).or_default().push((s.start, s.end));
            }
        }
        intervals.into_iter().map(|(c, mut iv)| (c, union_seconds(&mut iv))).collect()
    }

    /// Every link class that appears in the schedule, fastest-first.
    pub fn link_classes(&self) -> Vec<LinkClass> {
        let mut out: Vec<LinkClass> = self.graph.tasks.iter().filter_map(|t| t.class).collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Piecewise-constant in-flight task count of link class `class`
    /// across all its instances: `(time, count)` change points starting at
    /// `t = 0` — the series the Chrome-trace counter tracks render.
    pub fn class_in_flight(&self, class: LinkClass) -> Vec<(f64, usize)> {
        let intervals: Vec<(f64, f64)> = self
            .spans
            .iter()
            .filter(|s| self.graph.task(s.task).class == Some(class))
            .map(|s| (s.start, s.end))
            .collect();
        depth_timeline(&intervals)
    }

    /// Piecewise-constant ready-but-unstarted backlog of one stream's FIFO
    /// queue: a task is queued from the moment its last dependency finished
    /// until its span starts (FIFO wait + depth gating). `(time, depth)`
    /// change points starting at `t = 0`.
    pub fn stream_queue(&self, rank: usize, stream: StreamKind) -> Vec<(f64, usize)> {
        let mut intervals = Vec::new();
        for s in &self.spans {
            let t = self.graph.task(s.task);
            if t.rank != rank || t.stream != stream {
                continue;
            }
            let ready = t.deps.iter().map(|d| self.span(*d).end).fold(0.0, f64::max);
            if s.start > ready {
                intervals.push((ready, s.start));
            }
        }
        depth_timeline(&intervals)
    }

    /// Peak of [`Schedule::stream_queue`] — how deep the stream's backlog
    /// ever got.
    pub fn stream_peak_queue(&self, rank: usize, stream: StreamKind) -> usize {
        self.stream_queue(rank, stream).into_iter().map(|(_, d)| d).max().unwrap_or(0)
    }

    /// Straggler-wait: wall time `rank`'s compute stream sat idle while NO
    /// communication task was in flight anywhere — idle that
    /// [`Schedule::stall_by_class`] cannot blame on a link class because the
    /// rank was waiting on *other ranks' compute* (a straggler or jitter
    /// victim holding back a collective). Zero by construction in
    /// single-rank graphs.
    pub fn skew_wait(&self, rank: usize) -> f64 {
        self.skew_waits().get(&rank).copied().unwrap_or(0.0)
    }

    /// [`Schedule::skew_wait`] for every rank of the schedule in one sweep
    /// over the span windows — O(windows x spans) total instead of per
    /// rank, which is what the per-rank scenario tables want.
    pub fn skew_waits(&self) -> BTreeMap<usize, f64> {
        let mut out: BTreeMap<usize, f64> = self.ranks().into_iter().map(|r| (r, 0.0)).collect();
        let mut bounds: Vec<f64> = Vec::with_capacity(2 * self.spans.len());
        for s in &self.spans {
            bounds.push(s.start);
            bounds.push(s.end);
        }
        bounds.sort_by(|a, b| a.partial_cmp(b).expect("finite span bounds"));
        bounds.dedup();
        let mut busy: Vec<usize> = Vec::new();
        for w in bounds.windows(2) {
            let (a, b) = (w[0], w[1]);
            if b <= a {
                continue;
            }
            let mid = 0.5 * (a + b);
            let mut comm_in_flight = false;
            busy.clear();
            for s in &self.spans {
                if s.start < mid && mid < s.end {
                    let t = self.graph.task(s.task);
                    if t.class.is_some() {
                        comm_in_flight = true;
                        break;
                    }
                    if t.stream == StreamKind::Compute {
                        busy.push(t.rank);
                    }
                }
            }
            if comm_in_flight {
                continue;
            }
            busy.sort_unstable();
            for (&r, v) in out.iter_mut() {
                if busy.binary_search(&r).is_err() {
                    *v += b - a;
                }
            }
        }
        out
    }

    /// When `rank`'s compute stream finished its last kernel (0 if the rank
    /// owns no compute tasks).
    pub fn rank_compute_end(&self, rank: usize) -> f64 {
        self.spans
            .iter()
            .filter(|s| {
                let t = self.graph.task(s.task);
                t.rank == rank && t.stream == StreamKind::Compute
            })
            .map(|s| s.end)
            .fold(0.0, f64::max)
    }

    /// The rank whose compute stream finishes last — the straggler under an
    /// asymmetric scenario, arbitrary-but-stable under a symmetric one.
    pub fn slowest_rank(&self) -> usize {
        let mut best = (0usize, f64::NEG_INFINITY);
        for r in self.ranks() {
            let end = self.rank_compute_end(r);
            if end > best.1 {
                best = (r, end);
            }
        }
        best.0
    }

    /// The critical path: from the last-finishing task, walk backwards
    /// through whichever blocker (dependency or same-stream predecessor)
    /// finished latest. Returned in execution order.
    ///
    /// Thin compat wrapper over the canonical walk in
    /// [`critical::critical_path`] (which also owns the conserved makespan
    /// ledger, [`critical::decompose`]); results are bit-for-bit identical
    /// to the pre-`sched::critical` implementation.
    pub fn critical_path(&self) -> Vec<TaskId> {
        critical::critical_path(self)
    }
}

/// Union measure of a set of `[start, end)` intervals (sorts in place).
fn union_seconds(intervals: &mut [(f64, f64)]) -> f64 {
    intervals.sort_by(|a, b| a.partial_cmp(b).expect("finite span bounds"));
    let mut total = 0.0;
    let mut cur: Option<(f64, f64)> = None;
    for &(a, b) in intervals.iter() {
        if b <= a {
            continue;
        }
        match cur {
            Some((s, e)) if a <= e => cur = Some((s, e.max(b))),
            Some((s, e)) => {
                total += e - s;
                cur = Some((a, b));
            }
            None => cur = Some((a, b)),
        }
    }
    if let Some((s, e)) = cur {
        total += e - s;
    }
    total
}

/// Piecewise-constant count of concurrently open intervals: `(time, count)`
/// change points, always seeded at `t = 0`. Events sharing a timestamp are
/// merged, so back-to-back spans never show a spurious dip.
fn depth_timeline(intervals: &[(f64, f64)]) -> Vec<(f64, usize)> {
    let mut events: Vec<(f64, i64)> = Vec::with_capacity(2 * intervals.len());
    for &(a, b) in intervals {
        if b > a {
            events.push((a, 1));
            events.push((b, -1));
        }
    }
    events.sort_by(|a, b| a.partial_cmp(b).expect("finite span bounds"));
    let mut out: Vec<(f64, usize)> = vec![(0.0, 0)];
    let mut cur = 0i64;
    let mut i = 0;
    while i < events.len() {
        let t = events[i].0;
        while i < events.len() && events[i].0 == t {
            cur += events[i].1;
            i += 1;
        }
        let v = usize::try_from(cur.max(0)).expect("balanced events");
        if t == 0.0 {
            out[0].1 = v;
        } else if out.last().expect("seeded at t = 0").1 != v {
            out.push((t, v));
        }
    }
    out
}

/// Fold one domain's task intervals into its [`LinkUsage`].
fn usage_of(intervals: &[(f64, f64)]) -> LinkUsage {
    let mut iv = intervals.to_vec();
    let task_seconds: f64 = iv.iter().map(|&(a, b)| (b - a).max(0.0)).sum();
    let peak = depth_timeline(&iv).into_iter().map(|(_, d)| d).max().unwrap_or(0);
    LinkUsage {
        busy: union_seconds(&mut iv),
        task_seconds,
        tasks: intervals.len(),
        peak_in_flight: peak,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(stream: StreamKind, work: f64, deps: Vec<TaskId>) -> Task {
        Task { label: String::new(), rank: 0, stream, work, class: None, instance: 0, deps }
    }

    fn comm(stream: StreamKind, work: f64, class: LinkClass, deps: Vec<TaskId>) -> Task {
        Task { label: String::new(), rank: 0, stream, work, class: Some(class), instance: 0, deps }
    }

    #[test]
    fn single_task_makespan() {
        let mut g = TaskGraph::new();
        g.add(task(StreamKind::Compute, 2.5, vec![]));
        let s = simulate(g);
        assert!((s.makespan() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn chain_serializes() {
        let mut g = TaskGraph::new();
        let a = g.add(task(StreamKind::Prefetch, 1.0, vec![]));
        let b = g.add(task(StreamKind::Compute, 2.0, vec![a]));
        g.add(task(StreamKind::GradSync, 3.0, vec![b]));
        let s = simulate(g);
        assert!((s.makespan() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn independent_streams_overlap() {
        let mut g = TaskGraph::new();
        g.add(task(StreamKind::Prefetch, 4.0, vec![]));
        g.add(task(StreamKind::Compute, 3.0, vec![]));
        let s = simulate(g);
        assert!((s.makespan() - 4.0).abs() < 1e-12);
        assert!((s.stream_busy(0, StreamKind::Compute) - 3.0).abs() < 1e-12);
        assert!((s.stream_busy(0, StreamKind::Prefetch) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn same_stream_is_serial_fifo() {
        let mut g = TaskGraph::new();
        let a = g.add(task(StreamKind::Prefetch, 1.0, vec![]));
        let b = g.add(task(StreamKind::Prefetch, 1.0, vec![]));
        let s = simulate(g);
        // FIFO: insertion order, back to back
        assert!((s.span(a).end - 1.0).abs() < 1e-12);
        assert!((s.span(b).start - 1.0).abs() < 1e-12);
        assert!((s.makespan() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn blocked_head_stalls_the_stream() {
        // in-order issue: a blocked queue head holds back a ready successor
        let mut g = TaskGraph::new();
        let c = g.add(task(StreamKind::Compute, 2.0, vec![]));
        let blocked = g.add(task(StreamKind::Prefetch, 1.0, vec![c]));
        let free = g.add(task(StreamKind::Prefetch, 1.0, vec![]));
        let s = simulate(g);
        assert!((s.span(blocked).start - 2.0).abs() < 1e-12);
        assert!((s.span(free).start - 3.0).abs() < 1e-12);
    }

    #[test]
    fn same_class_contention_halves_rate() {
        let mut g = TaskGraph::new();
        g.add(comm(StreamKind::Prefetch, 1.0, LinkClass::InterNode, vec![]));
        g.add(comm(StreamKind::GradSync, 1.0, LinkClass::InterNode, vec![]));
        let s = simulate(g);
        // both share the inter-node fabric: 2 units of work at half rate
        assert!((s.makespan() - 2.0).abs() < 1e-12, "{}", s.makespan());
    }

    #[test]
    fn different_classes_do_not_contend() {
        let mut g = TaskGraph::new();
        g.add(comm(StreamKind::Prefetch, 1.0, LinkClass::Intra(0), vec![]));
        g.add(comm(StreamKind::GradSync, 1.0, LinkClass::InterNode, vec![]));
        let s = simulate(g);
        assert!((s.makespan() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn asymmetric_contention_release() {
        // a short and a long transfer share a class: the short one finishes
        // (at 2x its solo time), then the long one speeds back up
        let mut g = TaskGraph::new();
        let short = g.add(comm(StreamKind::Prefetch, 1.0, LinkClass::InterNode, vec![]));
        let long = g.add(comm(StreamKind::GradSync, 3.0, LinkClass::InterNode, vec![]));
        let s = simulate(g);
        assert!((s.span(short).end - 2.0).abs() < 1e-12);
        // long: 2s at 1/2 rate (1 unit done) + 2s at full rate = ends at 4
        assert!((s.span(long).end - 4.0).abs() < 1e-12);
    }

    #[test]
    fn zero_work_tasks_complete() {
        let mut g = TaskGraph::new();
        let a = g.add(task(StreamKind::Compute, 0.0, vec![]));
        let b = g.add(task(StreamKind::Compute, 1.0, vec![a]));
        let s = simulate(g);
        assert!((s.span(b).start).abs() < 1e-12);
        assert!((s.makespan() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn multi_rank_streams_are_independent() {
        let mut g = TaskGraph::new();
        g.add(Task {
            label: "r0".into(),
            rank: 0,
            stream: StreamKind::Compute,
            work: 2.0,
            class: None,
            instance: 0,
            deps: vec![],
        });
        g.add(Task {
            label: "r1".into(),
            rank: 1,
            stream: StreamKind::Compute,
            work: 3.0,
            class: None,
            instance: 0,
            deps: vec![],
        });
        let s = simulate(g);
        assert!((s.makespan() - 3.0).abs() < 1e-12);
        assert_eq!(s.ranks(), vec![0, 1]);
    }

    #[test]
    fn stall_attribution_blames_the_blocking_class() {
        // compute waits 2s on an inter-node gather, then runs 1s
        let mut g = TaskGraph::new();
        let gather = g.add(comm(StreamKind::Prefetch, 2.0, LinkClass::InterNode, vec![]));
        g.add(task(StreamKind::Compute, 1.0, vec![gather]));
        let s = simulate(g);
        let stalls = s.stall_by_class(0);
        assert!((stalls[&LinkClass::InterNode] - 2.0).abs() < 1e-12, "{stalls:?}");
        let u = s.utilization(0);
        assert!((u.makespan - 3.0).abs() < 1e-12);
        assert!((u.compute_busy - 1.0).abs() < 1e-12);
        assert!((u.compute_utilization() - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn depth_parsing_roundtrip() {
        assert_eq!(Depth::parse("inf"), Some(Depth::Infinite));
        assert_eq!(Depth::parse("2"), Some(Depth::Bounded(2)));
        assert_eq!(Depth::parse("x"), None);
        assert_eq!("inf".parse::<Depth>().unwrap(), Depth::Infinite);
        assert_eq!(Depth::Bounded(3).to_string(), "3");
        assert_eq!(Depth::Infinite.to_string(), "inf");
    }

    #[test]
    #[should_panic(expected = "dependency")]
    fn forward_dependencies_rejected() {
        let mut g = TaskGraph::new();
        g.add(task(StreamKind::Compute, 1.0, vec![TaskId(5)]));
    }

    #[test]
    fn distinct_instances_do_not_contend() {
        // same link class on two physical link instances: no sharing
        let mut g = TaskGraph::new();
        let mut t = comm(StreamKind::Prefetch, 1.0, LinkClass::Intra(0), vec![]);
        t.instance = 0;
        g.add(t);
        let mut t = comm(StreamKind::GradSync, 1.0, LinkClass::Intra(0), vec![]);
        t.instance = 1;
        g.add(t);
        let s = simulate(g);
        assert!((s.makespan() - 1.0).abs() < 1e-12, "{}", s.makespan());
    }

    #[test]
    fn same_instance_contends() {
        let mut g = TaskGraph::new();
        g.add(comm(StreamKind::Prefetch, 1.0, LinkClass::Intra(0), vec![]));
        g.add(comm(StreamKind::GradSync, 1.0, LinkClass::Intra(0), vec![]));
        let s = simulate(g);
        assert!((s.makespan() - 2.0).abs() < 1e-12, "{}", s.makespan());
    }

    #[test]
    fn rank_registry_is_authoritative() {
        let mut g = TaskGraph::with_rank_ids(vec![7, 3, 3]);
        assert_eq!(g.rank_ids(), Some(&[3, 7][..]));
        let mut t = task(StreamKind::Compute, 1.0, vec![]);
        t.rank = 3;
        g.add(t);
        let s = simulate(g);
        // rank 7 owns no task but the registry still reports it
        assert_eq!(s.ranks(), vec![3, 7]);
        assert_eq!(s.rank_compute_end(7), 0.0);
    }

    #[test]
    #[should_panic(expected = "outside the declared registry")]
    fn rank_registry_rejects_unknown_ranks() {
        let mut g = TaskGraph::with_rank_ids(vec![0, 1]);
        let mut t = task(StreamKind::Compute, 1.0, vec![]);
        t.rank = 2;
        g.add(t);
    }

    #[test]
    fn skew_wait_blames_peer_compute_not_comm() {
        // rank 0 finishes at t=1 then waits for rank 1's slow compute (no
        // comm in flight): skew, not a class stall
        let mut g = TaskGraph::with_rank_ids(vec![0, 1]);
        let a = g.add(task(StreamKind::Compute, 1.0, vec![]));
        let mut slow = task(StreamKind::Compute, 3.0, vec![]);
        slow.rank = 1;
        let b = g.add(slow);
        let mut sync = comm(StreamKind::GradSync, 1.0, LinkClass::InterNode, vec![a, b]);
        sync.rank = 0;
        g.add(sync);
        let s = simulate(g);
        assert!((s.makespan() - 4.0).abs() < 1e-12);
        // rank 0: idle 1..3 with no comm (skew), idle 3..4 under the sync
        assert!((s.skew_wait(0) - 2.0).abs() < 1e-12, "{}", s.skew_wait(0));
        // rank 1's trailing idle is under the sync -> a class stall, not skew
        assert!(s.skew_wait(1).abs() < 1e-12, "{}", s.skew_wait(1));
        let stalls = s.stall_by_class(0);
        assert!((stalls[&LinkClass::InterNode] - 1.0).abs() < 1e-12, "{stalls:?}");
        assert_eq!(s.slowest_rank(), 1);
        assert!((s.rank_compute_end(1) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn link_usage_unions_overlap_and_tracks_peak() {
        // two 1-unit transfers share the fabric: processor sharing runs
        // both over [0, 2) at half rate
        let mut g = TaskGraph::new();
        g.add(comm(StreamKind::Prefetch, 1.0, LinkClass::InterNode, vec![]));
        g.add(comm(StreamKind::GradSync, 1.0, LinkClass::InterNode, vec![]));
        let s = simulate(g);
        let usage = s.link_usage();
        let u = usage[&(LinkClass::InterNode, 0)];
        assert!((u.busy - 2.0).abs() < 1e-12, "{u:?}");
        assert!((u.task_seconds - 4.0).abs() < 1e-12, "{u:?}");
        assert_eq!(u.tasks, 2);
        assert_eq!(u.peak_in_flight, 2);
        // the in-flight counter series steps 2 -> 0 at the shared finish
        assert_eq!(s.class_in_flight(LinkClass::InterNode), vec![(0.0, 2), (2.0, 0)]);
    }

    #[test]
    fn class_busy_is_a_union_across_instances() {
        // concurrent tasks on two instances of one class: separate usage
        // entries, but the class-level busy union counts the window once
        let mut g = TaskGraph::new();
        g.add(comm(StreamKind::Prefetch, 1.0, LinkClass::Intra(0), vec![]));
        let mut other = comm(StreamKind::GradSync, 1.0, LinkClass::Intra(0), vec![]);
        other.instance = 1;
        g.add(other);
        let s = simulate(g);
        assert!((s.class_busy()[&LinkClass::Intra(0)] - 1.0).abs() < 1e-12);
        let usage = s.link_usage();
        assert_eq!(usage.len(), 2);
        assert!((usage[&(LinkClass::Intra(0), 0)].busy - 1.0).abs() < 1e-12);
        assert!((usage[&(LinkClass::Intra(0), 1)].busy - 1.0).abs() < 1e-12);
        assert_eq!(s.link_classes(), vec![LinkClass::Intra(0)]);
    }

    #[test]
    fn stalls_reconcile_with_class_busy() {
        // a 2s inter-node gather gates 1s of compute; a 1s intra sync
        // follows the compute — stall per class <= class busy seconds
        let mut g = TaskGraph::new();
        let gather = g.add(comm(StreamKind::Prefetch, 2.0, LinkClass::InterNode, vec![]));
        let c = g.add(task(StreamKind::Compute, 1.0, vec![gather]));
        g.add(comm(StreamKind::GradSync, 1.0, LinkClass::Intra(0), vec![c]));
        let s = simulate(g);
        let busy = s.class_busy();
        for rank in s.ranks() {
            for (class, stall) in s.stall_by_class(rank) {
                assert!(stall <= busy[&class] + 1e-9, "{class}: {stall} > {}", busy[&class]);
            }
        }
        assert!((busy[&LinkClass::InterNode] - 2.0).abs() < 1e-12);
        assert!((busy[&LinkClass::Intra(0)] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn stream_queue_counts_ready_but_unstarted_tasks() {
        // two prefetch tasks both ready at t=0: FIFO serializes, so the
        // second sits queued over [0, 1)
        let mut g = TaskGraph::new();
        g.add(task(StreamKind::Prefetch, 1.0, vec![]));
        g.add(task(StreamKind::Prefetch, 1.0, vec![]));
        let s = simulate(g);
        assert_eq!(s.stream_queue(0, StreamKind::Prefetch), vec![(0.0, 1), (1.0, 0)]);
        assert_eq!(s.stream_peak_queue(0, StreamKind::Prefetch), 1);
        // the compute stream never queued anything
        assert_eq!(s.stream_peak_queue(0, StreamKind::Compute), 0);
    }

    #[test]
    fn critical_path_follows_latest_blockers() {
        let mut g = TaskGraph::new();
        let a = g.add(task(StreamKind::Prefetch, 1.0, vec![]));
        let short = g.add(task(StreamKind::Compute, 0.5, vec![]));
        let b = g.add(task(StreamKind::Compute, 2.0, vec![a]));
        let c = g.add(task(StreamKind::GradSync, 1.0, vec![b, short]));
        let s = simulate(g);
        assert_eq!(s.critical_path(), vec![a, b, c]);
    }

    #[test]
    fn lazy_repricing_matches_reference_on_two_instance_overlap() {
        // the O(n^2) re-share fix: two instances of one class overlap, one
        // instance's membership churns (its short task finishes mid-flight)
        // while the other's stays constant — only the dirty instance may be
        // re-priced, and spans must still match the full-rebuild reference
        let build = || {
            let mut g = TaskGraph::with_rank_ids(vec![0, 1]);
            // instance 0: short + long share the link, membership changes
            let mut short = comm(StreamKind::Prefetch, 1.0, LinkClass::Intra(0), vec![]);
            short.instance = 0;
            g.add(short);
            let mut long = comm(StreamKind::GradSync, 3.0, LinkClass::Intra(0), vec![]);
            long.instance = 0;
            g.add(long);
            // instance 1: a steady transfer on a different physical link,
            // spanning both of instance 0's rate changes
            let mut steady = comm(StreamKind::Prefetch, 2.5, LinkClass::Intra(0), vec![]);
            steady.instance = 1;
            steady.rank = 1;
            let st = g.add(steady);
            // and a successor that joins instance 1 after the churn
            let mut late = comm(StreamKind::GradSync, 1.0, LinkClass::Intra(0), vec![st]);
            late.instance = 1;
            late.rank = 1;
            g.add(late);
            g
        };
        let r = reference::simulate_reference(build());
        let o = simulate(build());
        assert_eq!(r.makespan(), o.makespan());
        for (x, y) in r.spans().iter().zip(o.spans()) {
            assert_eq!((x.start, x.end), (y.start, y.end), "{x:?} vs {y:?}");
        }
        // sanity: instance 0 really contended (short took 2x solo time)
        assert!((o.spans()[0].end - 2.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "scheduler deadlock")]
    fn optimized_loop_panics_on_unreachable_task() {
        // parity with the reference loop's deadlock guard (same message)
        let mut g = TaskGraph::new();
        g.add(task(StreamKind::Compute, 1.0, vec![]));
        g.tasks[0].deps = vec![TaskId(0)];
        simulate(g);
    }
}
