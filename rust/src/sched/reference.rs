//! The reference event loop: the original map-based implementation of
//! [`crate::sched::simulate`], preserved verbatim as the correctness
//! oracle for the optimized arena engine (DESIGN.md §16).
//!
//! **Contract.** [`simulate_reference`] and [`crate::sched::simulate`]
//! are *bit-identical*: same makespan, same per-task spans, and
//! therefore the same stall ledgers, link usage, skew waits, and
//! critical-path decompositions (all of which are derived post-hoc from
//! the spans). The optimized engine changes bookkeeping — interned
//! streams and contention domains, index-based dependency counters, a
//! worklist of issue-ready streams, lazily re-priced processor-sharing
//! rates — but never the floating-point expressions: rates are still
//! `1.0 / n`, the time step is still the min-fold of `remaining / rate`,
//! and the completion epsilon is unchanged. The equivalence is enforced
//! by `testing::differential` + `tests/differential.rs` across
//! randomized scheme × machine × ranks × depth × blocks × P/M/V ×
//! scenario graphs and all pinned BENCH_baseline.json worlds.
//!
//! This loop is O(streams) per issue scan and rebuilds every contention
//! domain's share each round — robust, obviously correct, and the thing
//! the fast loop must match. Keep it boring.

use std::collections::BTreeMap;

use crate::sched::{Schedule, Span, StreamKind, TaskGraph, TaskId};
use crate::topology::LinkClass;

/// Run the reference (map-based) discrete-event loop over `graph`.
///
/// Semantics (shared with the optimized loop, see the module docs):
/// per-`(rank, stream)` FIFO in-order issue, processor sharing per
/// `(LinkClass, instance)` domain, time advancing to the earliest
/// completion under the current rates.
pub fn simulate_reference(graph: TaskGraph) -> Schedule {
    let n = graph.len();
    let mut remaining: Vec<f64> = graph.tasks.iter().map(|t| t.work).collect();
    let mut start = vec![f64::NAN; n];
    let mut end = vec![f64::NAN; n];
    let mut done = vec![false; n];

    // per-stream FIFO queues in insertion order
    let mut queues: BTreeMap<(usize, StreamKind), Vec<usize>> = BTreeMap::new();
    for (i, t) in graph.tasks.iter().enumerate() {
        queues.entry((t.rank, t.stream)).or_default().push(i);
    }
    let mut head: BTreeMap<(usize, StreamKind), usize> = BTreeMap::new();
    let mut running: BTreeMap<(usize, StreamKind), usize> = BTreeMap::new();

    let mut now = 0.0f64;
    let mut n_done = 0usize;
    while n_done < n {
        // issue every stream head whose dependencies are satisfied; repeat
        // until a fixed point (a zero-work start may unblock another head)
        loop {
            let mut issued = false;
            for (key, q) in queues.iter() {
                if running.contains_key(key) {
                    continue;
                }
                let h = head.entry(*key).or_insert(0);
                if *h >= q.len() {
                    continue;
                }
                let i = q[*h];
                if graph.tasks[i].deps.iter().all(|d| done[d.0]) {
                    start[i] = now;
                    running.insert(*key, i);
                    *h += 1;
                    issued = true;
                }
            }
            if !issued {
                break;
            }
        }
        if running.is_empty() {
            // every remaining task waits on a dependency that can never
            // finish — impossible for graphs built through `add`
            panic!("scheduler deadlock: {} of {} tasks unreachable", n - n_done, n);
        }

        // processor-sharing rates per (link class, instance) domain
        let mut active: BTreeMap<(LinkClass, usize), usize> = BTreeMap::new();
        for &i in running.values() {
            if let Some(c) = graph.tasks[i].class {
                *active.entry((c, graph.tasks[i].instance)).or_default() += 1;
            }
        }
        let rate = |i: usize| -> f64 {
            match graph.tasks[i].class {
                Some(c) => 1.0 / active[&(c, graph.tasks[i].instance)] as f64,
                None => 1.0,
            }
        };

        // advance to the earliest completion under current rates
        let dt = running
            .values()
            .map(|&i| remaining[i] / rate(i))
            .fold(f64::INFINITY, f64::min)
            .max(0.0);
        now += dt;
        let keys: Vec<(usize, StreamKind)> = running.keys().copied().collect();
        for key in keys {
            let i = running[&key];
            remaining[i] -= rate(i) * dt;
            if remaining[i] <= 1e-12 * graph.tasks[i].work.max(1.0) {
                running.remove(&key);
                remaining[i] = 0.0;
                end[i] = now;
                done[i] = true;
                n_done += 1;
            }
        }
    }

    let spans: Vec<Span> =
        (0..n).map(|i| Span { task: TaskId(i), start: start[i], end: end[i] }).collect();
    Schedule { graph, makespan: now, spans }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{simulate, Task};

    fn comm(work: f64, class: LinkClass, instance: usize, deps: Vec<TaskId>) -> Task {
        Task {
            label: String::new(),
            rank: 0,
            stream: StreamKind::Prefetch,
            work,
            class: Some(class),
            instance,
            deps,
        }
    }

    #[test]
    fn reference_matches_optimized_on_contended_chain() {
        let mut g = TaskGraph::new();
        let a = g.add(comm(1.0, LinkClass::InterNode, 0, vec![]));
        let mut b = comm(3.0, LinkClass::InterNode, 0, vec![]);
        b.stream = StreamKind::GradSync;
        g.add(b);
        let c = g.add(Task {
            label: String::new(),
            rank: 0,
            stream: StreamKind::Compute,
            work: 2.0,
            class: None,
            instance: 0,
            deps: vec![a],
        });
        let mut d = comm(0.5, LinkClass::Intra(0), 1, vec![c]);
        d.stream = StreamKind::Prefetch;
        g.add(d);

        let r = simulate_reference(g.clone());
        let o = simulate(g);
        assert_eq!(r.makespan(), o.makespan());
        assert_eq!(r.spans().len(), o.spans().len());
        for (x, y) in r.spans().iter().zip(o.spans()) {
            assert_eq!((x.start, x.end), (y.start, y.end));
        }
    }

    #[test]
    #[should_panic(expected = "scheduler deadlock")]
    fn reference_panics_on_unreachable_task() {
        // `add` forbids forward/self deps, so corrupt a legal graph into a
        // self-cycle through the module-private field to hit the guard.
        let mut g = TaskGraph::new();
        g.add(Task {
            label: "a".into(),
            rank: 0,
            stream: StreamKind::Compute,
            work: 1.0,
            class: None,
            instance: 0,
            deps: vec![],
        });
        g.tasks[0].deps = vec![TaskId(0)];
        simulate_reference(g);
    }
}
