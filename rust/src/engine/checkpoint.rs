//! Training-state checkpointing: save/restore the engine's canonical
//! weights, sharded optimizer state and step counter.
//!
//! Format: a small self-describing binary — magic, version, JSON header
//! (lengths, scheme, step), then raw little-endian f32 sections, then a
//! Fletcher-64 checksum of everything before it. No external crates
//! (offline build — DESIGN.md §8).

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

const MAGIC: &[u8; 8] = b"ZTCKPT01";

/// A snapshot of engine training state.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    /// Sharding-scheme name the state was trained under
    /// (`Scheme::name()`); restore refuses a mismatch.
    pub scheme: String,
    /// Optimizer step the snapshot was taken after.
    pub step: u64,
    /// Canonical fp32 weights, flat.
    pub weights: Vec<f32>,
    /// Per-rank optimizer shards, flattened per field.
    pub master: Vec<Vec<f32>>,
    /// Per-rank Adam first-moment shards (same geometry as `master`).
    pub m: Vec<Vec<f32>>,
    /// Per-rank Adam second-moment shards (same geometry as `master`).
    pub v: Vec<Vec<f32>>,
}

fn fletcher64(data: &[u8]) -> u64 {
    let (mut a, mut b) = (0u64, 0u64);
    for chunk in data.chunks(4) {
        let mut word = [0u8; 4];
        word[..chunk.len()].copy_from_slice(chunk);
        a = (a + u32::from_le_bytes(word) as u64) % 0xFFFF_FFFF;
        b = (b + a) % 0xFFFF_FFFF;
    }
    (b << 32) | a
}

fn push_f32s(buf: &mut Vec<u8>, xs: &[f32]) {
    buf.reserve(xs.len() * 4);
    for x in xs {
        buf.extend_from_slice(&x.to_le_bits().to_le_bytes());
    }
}

fn read_f32s(data: &[u8], n: usize, off: &mut usize) -> Result<Vec<f32>> {
    let need = n * 4;
    if *off + need > data.len() {
        bail!("checkpoint truncated at offset {}", *off);
    }
    let out = data[*off..*off + need]
        .chunks_exact(4)
        .map(|c| f32::from_bits(u32::from_le_bytes([c[0], c[1], c[2], c[3]])))
        .collect();
    *off += need;
    Ok(out)
}

trait F32Bits {
    fn to_le_bits(&self) -> u32;
}
impl F32Bits for f32 {
    fn to_le_bits(&self) -> u32 {
        self.to_bits()
    }
}

impl Checkpoint {
    /// Total persisted payload bytes (weights + every optimizer shard,
    /// 4 bytes per f32) — what storage-path pricing charges for this
    /// snapshot (`TrainEngine::checkpoint_save_seconds`). Header and
    /// checksum framing are excluded: they are O(ranks), noise next to
    /// the state itself.
    pub fn state_bytes(&self) -> u64 {
        let shard: usize = [&self.master, &self.m, &self.v]
            .iter()
            .flat_map(|g| g.iter())
            .map(|s| s.len())
            .sum();
        4 * (self.weights.len() + shard) as u64
    }

    /// Encode as the self-describing binary format (see module doc).
    pub fn serialize(&self) -> Vec<u8> {
        let header = Json::obj(vec![
            ("scheme", Json::str(self.scheme.clone())),
            ("step", Json::num(self.step as f64)),
            ("n_weights", Json::from(self.weights.len())),
            (
                "shards",
                Json::arr(self.master.iter().map(|s| Json::from(s.len()))),
            ),
        ])
        .to_string();
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&(header.len() as u64).to_le_bytes());
        buf.extend_from_slice(header.as_bytes());
        push_f32s(&mut buf, &self.weights);
        for group in [&self.master, &self.m, &self.v] {
            for shard in group {
                push_f32s(&mut buf, shard);
            }
        }
        let ck = fletcher64(&buf);
        buf.extend_from_slice(&ck.to_le_bytes());
        buf
    }

    /// Decode and verify a [`Checkpoint::serialize`] buffer: magic,
    /// Fletcher-64 checksum, header geometry, and exact payload length
    /// are all checked — truncation, corruption, and geometry mismatches
    /// are errors, never silently misread state.
    pub fn deserialize(data: &[u8]) -> Result<Checkpoint> {
        if data.len() < 24 || &data[..8] != MAGIC {
            bail!("not a zero-topo checkpoint");
        }
        let body = &data[..data.len() - 8];
        let stored = u64::from_le_bytes(data[data.len() - 8..].try_into().unwrap());
        if fletcher64(body) != stored {
            bail!("checkpoint checksum mismatch (corrupt file)");
        }
        let hlen = u64::from_le_bytes(data[8..16].try_into().unwrap()) as usize;
        let header_end = 16 + hlen;
        if header_end > body.len() {
            bail!("bad header length");
        }
        let header = std::str::from_utf8(&data[16..header_end]).context("header utf8")?;
        let j = Json::parse(header).map_err(|e| anyhow::anyhow!("header: {e}"))?;
        let scheme = j.get("scheme").and_then(|v| v.as_str()).context("scheme")?.to_string();
        let step = j.get("step").and_then(|v| v.as_i64()).context("step")? as u64;
        let n_weights = j.get("n_weights").and_then(|v| v.as_usize()).context("n_weights")?;
        let shard_lens: Vec<usize> = j
            .get("shards")
            .and_then(|v| v.as_arr())
            .context("shards")?
            .iter()
            .map(|s| s.as_usize().context("shard len"))
            .collect::<Result<_>>()?;

        let mut off = header_end;
        let weights = read_f32s(body, n_weights, &mut off)?;
        let mut read_group = |off: &mut usize| -> Result<Vec<Vec<f32>>> {
            shard_lens.iter().map(|&n| read_f32s(body, n, off)).collect()
        };
        let master = read_group(&mut off)?;
        let m = read_group(&mut off)?;
        let v = read_group(&mut off)?;
        if off != body.len() {
            bail!("trailing bytes in checkpoint");
        }
        Ok(Checkpoint { scheme, step, weights, master, m, v })
    }

    /// Write the serialized snapshot to a file.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let bytes = self.serialize();
        let mut f = std::fs::File::create(path.as_ref())
            .with_context(|| format!("creating {}", path.as_ref().display()))?;
        f.write_all(&bytes)?;
        Ok(())
    }

    /// Read and verify a snapshot from a file.
    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let mut data = Vec::new();
        std::fs::File::open(path.as_ref())
            .with_context(|| format!("opening {}", path.as_ref().display()))?
            .read_to_end(&mut data)?;
        Self::deserialize(&data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            scheme: "ZeRO-topo(sec=2)".into(),
            step: 42,
            weights: (0..100).map(|i| i as f32 * 0.5 - 3.0).collect(),
            master: vec![vec![1.0, 2.0], vec![3.0, 4.0, 5.0]],
            m: vec![vec![0.1, 0.2], vec![0.3, 0.4, 0.5]],
            v: vec![vec![0.01, 0.02], vec![0.03, 0.04, 0.05]],
        }
    }

    #[test]
    fn roundtrips() {
        let c = sample();
        let bytes = c.serialize();
        let d = Checkpoint::deserialize(&bytes).unwrap();
        assert_eq!(c, d);
    }

    #[test]
    fn detects_corruption() {
        let mut bytes = sample().serialize();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        assert!(Checkpoint::deserialize(&bytes).is_err());
    }

    #[test]
    fn detects_truncation() {
        let bytes = sample().serialize();
        assert!(Checkpoint::deserialize(&bytes[..bytes.len() - 9]).is_err());
        assert!(Checkpoint::deserialize(&bytes[..4]).is_err());
    }

    #[test]
    fn rejects_foreign_files() {
        assert!(Checkpoint::deserialize(b"not a checkpoint at all...").is_err());
    }

    #[test]
    fn detects_geometry_mismatch() {
        // the header records shard lengths from `master`; a snapshot whose
        // moment shards disagree serializes to a payload the header can't
        // account for — deserialize must diagnose it, not misread state
        let mut c = sample();
        c.m[0].push(9.9); // m geometry no longer matches master
        let bytes = c.serialize();
        let err = Checkpoint::deserialize(&bytes).unwrap_err();
        assert!(err.to_string().contains("trailing bytes"), "{err}");
        let mut c = sample();
        c.v[1].pop(); // shorter v: payload runs out before the header says
        let bytes = c.serialize();
        let err = Checkpoint::deserialize(&bytes).unwrap_err();
        assert!(err.to_string().contains("truncated"), "{err}");
    }

    #[test]
    fn corruption_in_every_section_is_detected() {
        // flip one byte at several structurally distinct offsets: magic,
        // header, weights payload, shard payload, checksum itself
        let bytes = sample().serialize();
        for off in [0, 20, 16 + 60, bytes.len() - 12, bytes.len() - 1] {
            let mut bad = bytes.clone();
            bad[off] ^= 0x40;
            assert!(Checkpoint::deserialize(&bad).is_err(), "offset {off} undetected");
        }
    }

    #[test]
    fn state_bytes_counts_weights_and_all_shards() {
        let c = sample();
        // 100 weights + (2+3) master + (2+3) m + (2+3) v = 115 f32s
        assert_eq!(c.state_bytes(), 4 * 115);
    }

    #[test]
    fn file_roundtrip() {
        let c = sample();
        let path = std::env::temp_dir().join("zt_ckpt_test.bin");
        c.save(&path).unwrap();
        let d = Checkpoint::load(&path).unwrap();
        assert_eq!(c, d);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn preserves_nonfinite_and_negative_zero_bits() {
        let mut c = sample();
        c.weights = vec![f32::NEG_INFINITY, -0.0, f32::MIN_POSITIVE];
        let d = Checkpoint::deserialize(&c.serialize()).unwrap();
        assert_eq!(d.weights[0], f32::NEG_INFINITY);
        assert_eq!(d.weights[1].to_bits(), (-0.0f32).to_bits());
        assert_eq!(d.weights[2], f32::MIN_POSITIVE);
    }
}
