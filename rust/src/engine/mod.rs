//! The ZeRO-topo training engine: the paper's Section V protocol running
//! over the simulated Frontier cluster with REAL numerics (PJRT compute +
//! real wire transformations) and a simulated clock (comm cost model).
//!
//! Per optimizer step (paper Figs 4–6):
//!
//! 1. **Forward all-gather** of primary weight shards within each weight
//!    group (ZeRO-topo: the 2 GCDs of an MI250X, INT8 wire; ZeRO-3: all
//!    ranks, fp16 wire).
//! 2. **Backward all-gather** from the *secondary* partition (ZeRO++/topo:
//!    intra-node / intra-GPU, payload already INT8) — for ZeRO-3 a second
//!    global gather.
//! 3. Each rank computes fwd+bwd on ITS microbatch via the AOT `train_step`
//!    HLO, accumulating fp32 gradients locally over `grad_accum`
//!    microbatches.
//! 4. **Gradient sync**: ZeRO-3 rings a fp16 reduce-scatter over all ranks;
//!    ZeRO++ does the 1-hop INT4 all-to-all over all ranks; ZeRO-topo does
//!    the INT4 all-to-all *within the node* then a fp16 all-reduce across
//!    nodes (paper Fig 5).
//! 5. Sharded AdamW (optimizer states split across all ranks), global-norm
//!    clipping via summed shard norms.
//! 6. **Updated-weight all-gather** over the optimizer-shard group
//!    (paper §V.D, volume ψ·(d-1)/d), refreshing primary (and re-quantizing
//!    secondary) partitions.
//!
//! Numerics exploit replication: all weight replicas hold identical values
//! throughout, so one canonical buffer represents every replica while each
//! rank's DATA and gradient contributions stay distinct. The memory story
//! per device is accounted analytically in [`crate::memory`]; the comm
//! ledger charges every group the paper's protocol touches.

pub mod checkpoint;

use anyhow::{bail, Result};

use std::collections::BTreeMap;

use crate::comm::cost::CommEfficiency;
use crate::comm::{CommWorld, Wire};
use crate::config::RunConfig;
use crate::data::{BatchStream, SyntheticCorpus};
use crate::dtype::round_f16_slice;
use crate::metrics::{LossPoint, StepUtilization, TrainLog};
use crate::optimizer::{global_clip_scale, local_sq_norm, AdamWConfig, AdamWShard};
use crate::runtime::ModelRunner;
use crate::sched::multi::MultiRankPlan;
use crate::sched::pipeline::{even_chunk_params, PipeConfig, PipelinePlan};
use crate::sched::plan::StepPlan;
use crate::sched::Schedule;
use crate::sharding::{shard_groups, PartitionMap, Scheme, ShardingSpec};
use crate::topology::{Cluster, LinkClass, MachineSpec};

/// The engine over a PJRT-compiled model.
pub struct TrainEngine<'a> {
    /// The run description this engine was built from.
    pub cfg: RunConfig,
    /// The simulated cluster (machine spec × node count).
    pub cluster: Cluster,
    /// Resolved per-state sharding factors for `cfg.scheme` on `cluster`.
    pub spec: ShardingSpec,
    /// The collective world: moves real data AND charges the cost model.
    pub comm: CommWorld,
    runner: &'a ModelRunner,
    /// Canonical fp16-rounded flat weights (identical on every replica).
    pub weights: Vec<f32>,
    /// Per-rank optimizer shards over `os_pm` ranges.
    opt: Vec<AdamWShard>,
    os_pm: PartitionMap,
    stream: BatchStream,
    step_idx: usize,
    /// Per-rank fp32 gradient accumulators (only alive inside a step).
    grad_accum_bufs: Vec<Vec<f32>>,
    /// Event-clock makespan of one step (constant per run; priced once).
    step_sim_s: f64,
    /// The priced per-step schedule behind `step_sim_s` — kept for the
    /// telemetry views (stall attribution, link utilization, trace).
    step_schedule: Option<Schedule>,
    /// Loss curve + simulated-seconds accumulator for the run.
    pub log: TrainLog,
}

impl<'a> TrainEngine<'a> {
    /// Build an engine for `cfg` over `runner`'s AOT-compiled model:
    /// resolves the machine and sharding, initializes weights and
    /// sharded optimizer state deterministically from the seed, and
    /// prices the per-step event clock once (it is constant per run).
    pub fn new(cfg: RunConfig, runner: &'a ModelRunner) -> Result<TrainEngine<'a>> {
        let cluster = Cluster::new(MachineSpec::resolve(&cfg.machine)?, cfg.nodes);
        let spec = ShardingSpec::resolve(cfg.scheme, &cluster)?;
        let world = cluster.world_size();
        let m = &runner.manifest;
        if cfg.micro_batch != 1 && cfg.micro_batch != m.mbs {
            bail!("micro_batch {} baked into artifact is {}", cfg.micro_batch, m.mbs);
        }
        // init once via the AOT init artifact, fp16-round like a real
        // mixed-precision checkpoint load
        let mut weights = runner.init_params(cfg.seed as i32)?;
        round_f16_slice(&mut weights);
        let os_pm = PartitionMap::new(m.n_params, world);
        let mut padded = weights.clone();
        padded.resize(os_pm.padded_len(), 0.0);
        let opt = (0..world)
            .map(|r| {
                AdamWShard::new(
                    AdamWConfig { lr: cfg.lr, ..Default::default() },
                    &padded[os_pm.range(r)],
                )
            })
            .collect();
        let corpus = SyntheticCorpus::new(m.vocab, cfg.seed ^ 0xDA7A);
        let stream = BatchStream::new(corpus, m.mbs, m.seq, cfg.seed);
        // the engine prices collectives with the SAME calibrated RCCL
        // efficiency the simulator defaults to — without it the two clocks
        // disagree on exactly the inter-node collectives the paper studies
        let mut comm = CommWorld::new(cluster.clone());
        comm.cost.efficiency = CommEfficiency::rccl_frontier();
        let mut engine = TrainEngine {
            comm,
            log: TrainLog { scheme: cfg.scheme.name(), ..Default::default() },
            cluster,
            spec,
            runner,
            weights,
            opt,
            os_pm,
            stream,
            step_idx: 0,
            grad_accum_bufs: Vec::new(),
            step_sim_s: 0.0,
            step_schedule: None,
            cfg,
        };
        // the plan is a pure function of (cfg, spec, cluster, manifest),
        // all fixed for the run: price + schedule it once, accumulate the
        // makespan per step (recompute via `plan_step` if you mutate the
        // engine's cost-model efficiency afterwards). The step clock runs
        // the multi-rank builder: with the default trivial scenario the
        // congruence collapse makes it bit-identical to the single-rank
        // plan; straggler/jitter configs price the slowest-rank makespan.
        // With `pipeline_stages > 1` the clock prices the hybrid
        // PP x ZeRO schedule instead (the numerics stay pure-DP).
        let step_schedule = if engine.cfg.pipeline_stages > 1 {
            engine.pipeline_step_clock()?
        } else {
            let plan = engine.plan_step();
            let scenario = engine.cfg.scenario();
            MultiRankPlan::new(&plan, &engine.cluster, &scenario).simulate()
        };
        engine.step_sim_s = step_schedule.makespan();
        engine.step_schedule = Some(step_schedule);
        Ok(engine)
    }

    fn world(&self) -> usize {
        self.cluster.world_size()
    }

    fn quant_block(&self) -> usize {
        self.cfg.quant_block
    }

    /// Produce the weights every rank computes with this step, applying the
    /// scheme's wire format ONCE (the gathered tensors and the dequantized
    /// secondary partition share the same quantization contract), and
    /// charge the forward + backward all-gathers to the ledger.
    fn gather_weights(&mut self) -> Vec<f32> {
        let mut w_used = self.weights.clone();
        let (fwd_wire, bwd_wire) = match self.cfg.scheme {
            Scheme::ZeroPP | Scheme::ZeroTopo { .. } => (
                Wire::Int8 { block: self.quant_block() },
                Wire::Int8 { block: self.quant_block() },
            ),
            // ZeRO-1/2/3, MiCS, FSDP-hybrid: plain fp16 wire
            _ => (Wire::F16, Wire::F16),
        };
        // numerics: one wire application (fwd gather == secondary dequant;
        // re-gathering identical weights each microbatch reproduces the
        // same bits, so the transform runs once)
        fwd_wire.apply(&mut w_used);

        // ledger: the protocol gathers EVERY microbatch — forward within
        // each weight group, backward from the secondary partitions
        let n = self.weights.len();
        let bwd_degree =
            if self.spec.secondary > 0 { self.spec.secondary } else { self.spec.weights };
        for _ in 0..self.cfg.grad_accum {
            for g in shard_groups(self.world(), self.spec.weights) {
                self.comm.cost.all_gather(&g, fwd_wire.wire_bytes(n) as u64);
            }
            for g in shard_groups(self.world(), bwd_degree) {
                self.comm.cost.all_gather(&g, bwd_wire.wire_bytes(n) as u64);
            }
        }
        w_used
    }

    /// Gradient synchronization per the scheme (paper Fig 5 / Table VIII).
    /// Consumes per-rank fp32 accumulators, returns each rank's averaged
    /// gradient restricted to its optimizer range (padded layout).
    fn sync_gradients(&mut self, per_rank: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let world = self.world();
        let n = self.os_pm.padded_len();
        let inv_world = 1.0 / world as f32;
        let block = self.quant_block();
        let views: Vec<&[f32]> = per_rank.iter().map(|v| v.as_slice()).collect();

        let full_group: Vec<usize> = (0..world).collect();
        let mut per_rank_os: Vec<Vec<f32>> = Vec::with_capacity(world);
        match self.cfg.scheme {
            Scheme::Zero1 | Scheme::Zero2 | Scheme::Zero3 => {
                // fp16 ring reduce-scatter over the whole world
                let shards = self.comm.reduce_scatter_ring(&full_group, &views, Wire::F16);
                for (r, mut s) in shards.into_iter().enumerate() {
                    debug_assert_eq!(self.os_pm.range(r).len(), s.len());
                    for v in s.iter_mut() {
                        *v *= inv_world;
                    }
                    per_rank_os.push(s);
                }
            }
            Scheme::ZeroPP => {
                // INT4 1-hop all-to-all over the whole world (inter-node)
                let shards =
                    self.comm.reduce_scatter_a2a(&full_group, &views, Wire::Int4 { block });
                for (r, mut s) in shards.into_iter().enumerate() {
                    debug_assert_eq!(self.os_pm.range(r).len(), s.len());
                    for v in s.iter_mut() {
                        *v *= inv_world;
                    }
                    per_rank_os.push(s);
                }
            }
            Scheme::ZeroTopo { .. } => {
                // Phase 1: INT4 all-to-all inside each node; phase 2: fp16
                // all-reduce across nodes (paper Fig 5).
                let p = self.cluster.workers_per_node();
                per_rank_os = self.hierarchical_sync(&views, p, Wire::Int4 { block }, true);
                for s in per_rank_os.iter_mut() {
                    for v in s.iter_mut() {
                        *v *= inv_world;
                    }
                }
            }
            Scheme::Mics { .. } | Scheme::FsdpHybrid { .. } => {
                // Related-work baselines: fp16 ring reduce-scatter within
                // the shard group, fp16 all-reduce across replica groups.
                let g = self.spec.grads;
                per_rank_os = self.hierarchical_sync(&views, g, Wire::F16, false);
                for s in per_rank_os.iter_mut() {
                    for v in s.iter_mut() {
                        *v *= inv_world;
                    }
                }
            }
        }
        per_rank_os
    }

    /// Two-phase gradient sync: reduce-scatter within contiguous groups of
    /// `group_size`, then all-reduce across groups per shard index. Each
    /// rank returns the sub-slice matching its flat optimizer shard.
    fn hierarchical_sync(
        &mut self,
        views: &[&[f32]],
        group_size: usize,
        phase1_wire: Wire,
        a2a: bool,
    ) -> Vec<Vec<f32>> {
        let world = self.world();
        assert!(world % group_size == 0);
        let n_groups = world / group_size;
        let n = self.os_pm.padded_len();
        let group_shard = n / group_size;
        // group_sums[grp][local] = group-local sum of shard `local`
        let mut group_sums: Vec<Vec<Vec<f32>>> = Vec::with_capacity(n_groups);
        for grp in 0..n_groups {
            let group: Vec<usize> = (grp * group_size..(grp + 1) * group_size).collect();
            let contrib: Vec<&[f32]> = group.iter().map(|&r| views[r]).collect();
            let shards = if a2a {
                self.comm.reduce_scatter_a2a(&group, &contrib, phase1_wire)
            } else {
                self.comm.reduce_scatter_ring(&group, &contrib, phase1_wire)
            };
            group_sums.push(shards);
        }
        // all-reduce across groups for each local shard index
        let mut global: Vec<Vec<f32>> = Vec::with_capacity(group_size);
        for local in 0..group_size {
            if n_groups == 1 {
                global.push(std::mem::take(&mut group_sums[0][local]));
                continue;
            }
            let group: Vec<usize> = (0..n_groups).map(|m| m * group_size + local).collect();
            let contrib: Vec<&[f32]> =
                (0..n_groups).map(|m| group_sums[m][local].as_slice()).collect();
            global.push(self.comm.all_reduce(&group, &contrib, Wire::F16));
        }
        // each rank keeps the sub-slice matching its optimizer shard and
        // discards the rest (paper §V.C)
        let per_rank_len = group_shard / n_groups;
        (0..world)
            .map(|r| {
                let local = r % group_size;
                let grp = r / group_size;
                global[local][grp * per_rank_len..(grp + 1) * per_rank_len].to_vec()
            })
            .collect()
    }

    /// Run one optimizer step (grad_accum microbatches per rank). Returns
    /// the mean training loss across ranks and microbatches.
    pub fn step(&mut self) -> Result<f64> {
        let world = self.world();
        let n = self.runner.manifest.n_params;
        let w_used = self.gather_weights();

        if self.grad_accum_bufs.len() != world {
            self.grad_accum_bufs = vec![vec![0f32; self.os_pm.padded_len()]; world];
        } else {
            for b in self.grad_accum_bufs.iter_mut() {
                b.iter_mut().for_each(|v| *v = 0.0);
            }
        }
        let mut loss_sum = 0f64;
        for micro in 0..self.cfg.grad_accum {
            for rank in 0..world {
                let b = self.stream.batch(rank, self.step_idx, micro);
                let (loss, grads) = self.runner.train_step(&w_used, &b.tokens, &b.targets)?;
                loss_sum += loss as f64;
                let acc = &mut self.grad_accum_bufs[rank];
                for (a, &g) in acc[..n].iter_mut().zip(&grads) {
                    *a += g;
                }
            }
        }
        let inv_micro = 1.0 / self.cfg.grad_accum as f32;
        for b in self.grad_accum_bufs.iter_mut() {
            b.iter_mut().for_each(|v| *v *= inv_micro);
        }

        // gradient sync per scheme
        let bufs = std::mem::take(&mut self.grad_accum_bufs);
        let per_rank_os = self.sync_gradients(&bufs);
        self.grad_accum_bufs = bufs;

        // ZeRO-topo's paper §V.C: with the os shards now aligned per rank,
        // hierarchical layouts differ from the flat os partition; reorder
        // to flat [0, n) ranges.
        let os_grads = self.reorder_to_flat(per_rank_os);

        // global grad-norm clip (shard norms summed — in the real system a
        // scalar all-reduce, negligible wire cost)
        let sq: f64 = os_grads.iter().map(|g| local_sq_norm(g)).sum();
        let clip = global_clip_scale(sq, self.opt[0].cfg.grad_clip);

        // sharded AdamW + updated-weight all-gather (paper §V.D)
        let mut new_flat = vec![0f32; self.os_pm.padded_len()];
        for (r, g) in os_grads.iter().enumerate() {
            self.opt[r].step(g, clip);
            new_flat[self.os_pm.range(r)].copy_from_slice(&self.opt[r].master);
        }
        new_flat.truncate(n);
        round_f16_slice(&mut new_flat);
        self.weights = new_flat;
        let full_group: Vec<usize> = (0..world).collect();
        self.comm.cost.all_gather(&full_group, Wire::F16.wire_bytes(n) as u64);

        // ---- simulated step clock: the SAME event scheduler + collective
        // pricing the analytic simulator runs (the comm side of a step can
        // never drift between engine and sim; the compute term here uses
        // the 6Ψ rule on the proxy manifest — see `plan_step`) ----
        self.log.sim_seconds += self.step_sim_s;

        self.step_idx += 1;
        let denom = (world * self.cfg.grad_accum) as f64;
        let mean_loss = loss_sum / denom;
        let tokens_per_step =
            (world * self.cfg.grad_accum * self.runner.manifest.mbs * self.runner.manifest.seq)
                as u64;
        self.log.losses.push(LossPoint {
            step: self.step_idx,
            tokens: self.step_idx as u64 * tokens_per_step,
            loss: mean_loss,
        });
        Ok(mean_loss)
    }

    /// Map per-rank sync outputs (whose layout depends on the scheme) onto
    /// flat `os_pm` ranges.
    fn reorder_to_flat(&self, per_rank: Vec<Vec<f32>>) -> Vec<Vec<f32>> {
        let group_size = match self.cfg.scheme {
            // flat already: rank r's RS shard == os_pm.range(r)
            Scheme::Zero1 | Scheme::Zero2 | Scheme::Zero3 | Scheme::ZeroPP => return per_rank,
            Scheme::ZeroTopo { .. } => self.cluster.workers_per_node(),
            Scheme::Mics { .. } | Scheme::FsdpHybrid { .. } => self.spec.grads,
        };
        // rank r holds [group-slice of local shard]: local = r % G,
        // grp = r / G over the padded flat layout — reassemble the full
        // padded vector then re-slice by flat os ranges.
        let n_groups = self.world() / group_size;
        let n_pad = self.os_pm.padded_len();
        let group_shard = n_pad / group_size;
        let per_rank_len = group_shard / n_groups;
        let mut full = vec![0f32; n_pad];
        for (r, s) in per_rank.iter().enumerate() {
            let local = r % group_size;
            let grp = r / group_size;
            let base = local * group_shard + grp * per_rank_len;
            full[base..base + s.len()].copy_from_slice(s);
        }
        (0..self.world()).map(|r| full[self.os_pm.range(r)].to_vec()).collect()
    }

    /// Evaluate current weights on held-out batches (forward only).
    pub fn eval(&self, batches: usize) -> Result<f64> {
        let mut sum = 0.0;
        for i in 0..batches {
            let b = self.stream.batch(usize::MAX / 2, 1_000_000 + i, 0);
            sum += self.runner.eval_loss(&self.weights, &b.tokens, &b.targets)? as f64;
        }
        Ok(sum / batches as f64)
    }

    /// Simulated communication seconds accumulated so far.
    pub fn comm_seconds(&self) -> f64 {
        self.comm.cost.total_seconds()
    }

    /// Simulated wall-clock seconds of training so far: the sum of the
    /// per-step event-clock makespans ([`crate::sched`]).
    pub fn sim_seconds(&self) -> f64 {
        self.log.sim_seconds
    }

    /// Event-clock seconds of ONE optimizer step (constant per run).
    pub fn step_sim_seconds(&self) -> f64 {
        self.step_sim_s
    }

    /// The priced per-step schedule (stall/utilization/trace queries).
    pub fn step_schedule(&self) -> Option<&Schedule> {
        self.step_schedule.as_ref()
    }

    /// Per-stream busy accounting of the priced step (modeled rank 0's
    /// congruence class) — what the train-path telemetry records.
    pub fn step_utilization(&self) -> Option<StepUtilization> {
        let sched = self.step_schedule.as_ref()?;
        let rank = sched.ranks().first().copied().unwrap_or(0);
        Some(sched.utilization(rank))
    }

    /// Compute-stall attribution per link class of the priced step.
    pub fn step_stalls(&self) -> Option<BTreeMap<LinkClass, f64>> {
        let sched = self.step_schedule.as_ref()?;
        let rank = sched.ranks().first().copied().unwrap_or(0);
        Some(sched.stall_by_class(rank))
    }

    /// The step plan priced for this engine's protocol: per-microbatch
    /// gather durations and sync phases from the cost model (identical to
    /// the simulator's pricing by construction). The compute term uses the
    /// 6Ψ FLOPs rule — the proxy manifests carry only a parameter count,
    /// not the layer geometry the simulator's detailed account needs — so
    /// engine and sim step clocks agree on communication and scheduling,
    /// and differ on compute only by 6Ψ-vs-detailed (under ~15% for large
    /// models, more for tiny proxies). With `layer_blocks > 1` the clock
    /// runs the layer-granular prefetch schedule over a near-even split
    /// of the flat parameter count (manifests carry no per-layer map).
    fn plan_step(&self) -> StepPlan {
        let m = &self.runner.manifest;
        let tokens_per_micro = (m.mbs * m.seq) as f64;
        let peak = self.cluster.peak_flops_per_worker();
        let compute_s = 6.0 * m.n_params as f64 * tokens_per_micro * self.cfg.grad_accum as f64
            / (peak * self.cfg.mfu);
        if self.cfg.layer_blocks > 1 {
            let blocks = even_chunk_params(m.n_params as u64, self.cfg.layer_blocks);
            return StepPlan::from_protocol_layered(
                &self.comm.cost,
                self.cfg.scheme,
                &self.spec,
                &blocks,
                self.quant_block(),
                self.cfg.grad_accum,
                compute_s,
                self.cfg.prefetch_depth,
            );
        }
        StepPlan::from_protocol(
            &self.comm.cost,
            self.cfg.scheme,
            &self.spec,
            m.n_params,
            self.quant_block(),
            self.cfg.grad_accum,
            compute_s,
            self.cfg.prefetch_depth,
        )
    }

    /// The step clock for a pipeline-parallel run (`pipeline_stages > 1`):
    /// the numerics keep executing the pure data-parallel protocol at
    /// proxy scale, but the simulated clock prices the hybrid PP × ZeRO
    /// schedule — per-stage ZeRO plans over an even parameter split of
    /// the proxy manifest (the manifests carry no per-layer parameter
    /// map), activation transfers sized from the manifest's
    /// `(mbs, seq, d_model)`, 1F1B or interleaved order, and scenario
    /// stragglers/jitter mapped onto whole stages. Returns the executed
    /// schedule so `new` can keep it for the telemetry views.
    fn pipeline_step_clock(&self) -> Result<Schedule> {
        let m = &self.runner.manifest;
        let p = self.cfg.pipeline_stages;
        // stragglers/jitter map onto stages (the block max), but per-rank
        // grad-accum imbalance has no stage-level analogue yet — refuse
        // rather than silently ignore the injector
        if !self.cfg.imbalance.is_empty() {
            bail!(
                "--imbalance does not compose with pipeline_stages > 1 yet \
                 (per-rank grad-accum overrides have no stage-level mapping)"
            );
        }
        let mb = if self.cfg.microbatches > 0 {
            self.cfg.microbatches
        } else {
            self.cfg.grad_accum.max(1)
        };
        let pipe = PipeConfig { stages: p, microbatches: mb, interleave: self.cfg.interleave };
        let tokens_per_micro = (m.mbs * m.seq) as f64;
        let peak = self.cluster.peak_flops_per_worker();
        let compute_s =
            6.0 * m.n_params as f64 * tokens_per_micro * mb as f64 / (peak * self.cfg.mfu);
        let chunks = even_chunk_params(m.n_params as u64, pipe.chunks());
        let act = 2 * (m.mbs * m.seq * m.d_model) as u64;
        let plan = PipelinePlan::from_protocol(
            &self.comm.cost,
            self.cfg.scheme,
            &pipe,
            &chunks,
            self.quant_block(),
            act,
            compute_s,
            self.cfg.prefetch_depth,
            self.cfg.layer_blocks > 1,
        )?
        .with_stage_multipliers(self.cfg.scenario().stage_multipliers(&self.cluster, p));
        Ok(plan.simulate())
    }

    /// Snapshot the full training state (weights + sharded AdamW + step).
    pub fn checkpoint(&self) -> checkpoint::Checkpoint {
        checkpoint::Checkpoint {
            scheme: self.cfg.scheme.name(),
            step: self.step_idx as u64,
            weights: self.weights.clone(),
            master: self.opt.iter().map(|o| o.master.clone()).collect(),
            m: self.opt.iter().map(|o| o.m.clone()).collect(),
            v: self.opt.iter().map(|o| o.v.clone()).collect(),
        }
    }

    /// Simulated seconds to persist `ck` through the machine's storage
    /// path (DESIGN.md §17): per-rank bytes = the snapshot's real
    /// `state_bytes / world` (dedup-and-rebalance — every rank writes
    /// its shard), funneled through the node-shared write path by all
    /// `workers_per_node` ranks concurrently, plus the path latency.
    pub fn checkpoint_save_seconds(&self, ck: &checkpoint::Checkpoint) -> f64 {
        let storage = self.cluster.spec.storage;
        let bytes_per_rank = ck.state_bytes() as f64 / self.world() as f64;
        storage.latency
            + bytes_per_rank * self.cluster.workers_per_node() as f64 / storage.write_bandwidth
    }

    /// Simulated seconds to restore from `ck`: the storage read mirror
    /// of [`TrainEngine::checkpoint_save_seconds`], plus — for schemes
    /// with a secondary partition (ZeRO++ / ZeRO-topo) — the
    /// rematerialization all-gather that rebuilds the quantized
    /// secondary copies (a full-world INT8 gather of Ψ, the same
    /// collective as the §V.D refresh, priced but not re-executed: the
    /// canonical weights already hold the restored values).
    pub fn checkpoint_restore_seconds(&self, ck: &checkpoint::Checkpoint) -> f64 {
        let storage = self.cluster.spec.storage;
        let bytes_per_rank = ck.state_bytes() as f64 / self.world() as f64;
        let load = storage.latency
            + bytes_per_rank * self.cluster.workers_per_node() as f64 / storage.read_bandwidth;
        let remat = if self.spec.secondary > 0 {
            let full: Vec<usize> = (0..self.world()).collect();
            let wire = Wire::Int8 { block: self.quant_block() }.wire_bytes(self.weights.len());
            self.comm.cost.all_gather_time(&full, wire as u64)
        } else {
            0.0
        };
        load + remat
    }

    /// Snapshot the training state AND advance the simulated clock by
    /// the priced save — the checkpointing tax the goodput layer
    /// (`sim::goodput`) models analytically, paid here on the engine's
    /// own event clock. Returns the snapshot and the charged seconds.
    pub fn checkpoint_priced(&mut self) -> (checkpoint::Checkpoint, f64) {
        let ck = self.checkpoint();
        let save_s = self.checkpoint_save_seconds(&ck);
        self.log.sim_seconds += save_s;
        (ck, save_s)
    }

    /// Restore training state AND advance the simulated clock by the
    /// priced restore (storage read + secondary rematerialization).
    /// Returns the charged seconds; the state restoration itself is
    /// exactly [`TrainEngine::restore`] — bit-identical numerics.
    pub fn restore_priced(&mut self, ck: &checkpoint::Checkpoint) -> Result<f64> {
        self.restore(ck)?;
        let restore_s = self.checkpoint_restore_seconds(ck);
        self.log.sim_seconds += restore_s;
        Ok(restore_s)
    }

    /// Restore training state from a checkpoint (scheme + world must match).
    pub fn restore(&mut self, ck: &checkpoint::Checkpoint) -> Result<()> {
        if ck.scheme != self.cfg.scheme.name() {
            bail!("checkpoint scheme {} != engine scheme {}", ck.scheme, self.cfg.scheme.name());
        }
        if ck.weights.len() != self.weights.len() || ck.master.len() != self.opt.len() {
            bail!("checkpoint geometry mismatch");
        }
        self.weights = ck.weights.clone();
        for (o, ((ms, m), v)) in
            self.opt.iter_mut().zip(ck.master.iter().zip(&ck.m).zip(&ck.v))
        {
            if ms.len() != o.master.len() {
                bail!("shard length mismatch");
            }
            o.master = ms.clone();
            o.m = m.clone();
            o.v = v.clone();
            o.step = ck.step;
        }
        self.step_idx = ck.step as usize;
        Ok(())
    }
}

/// Requirements for the ZeRO-topo layout: padded length divisible by
/// (workers_per_node * nodes) so the hierarchical shards tile evenly.
pub fn check_layout(n_params: usize, cluster: &Cluster) -> PartitionMap {
    PartitionMap::new(n_params, cluster.world_size())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_divisibility() {
        let c = Cluster::frontier(2);
        let pm = check_layout(1_000_003, &c);
        assert_eq!(pm.padded_len() % 16, 0);
    }
}
