//! Mini property-based testing framework (proptest is unavailable offline
//! — DESIGN.md §8).
//!
//! Deterministic: every case derives from a fixed master seed, so failures
//! reproduce exactly. On failure the framework retries with "shrunk"
//! parameters (halved sizes) to report a smaller counterexample when one
//! exists.
//!
//! ```no_run
//! // (no_run: doctest binaries miss the libxla rpath in this offline env)
//! use zero_topo::testing::{Gen, check};
//! check("addition commutes", 100, |g| {
//!     let (a, b) = (g.i64_in(-1000, 1000), g.i64_in(-1000, 1000));
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::util::rng::Rng;

pub mod differential;

/// Random-input generator handed to each property case.
pub struct Gen {
    rng: Rng,
    /// Size budget in [0,1]: cases early in a run are small, later larger.
    pub size: f64,
    pub case: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        if lo == hi {
            return lo;
        }
        // scale the upper bound with the size budget so early cases are small
        let span = ((hi - lo) as f64 * self.size).ceil().max(1.0) as usize;
        self.rng.range_usize(lo, lo + span.min(hi - lo) + 1)
    }

    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.rng.below((hi - lo + 1) as u64) as i64
    }

    pub fn f32_normal(&mut self, std: f32) -> f32 {
        self.rng.normal_f32(0.0, std)
    }

    pub fn f64_unit(&mut self) -> f64 {
        self.rng.f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bool()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.range_usize(0, xs.len())]
    }

    /// Vector of N(0, std) floats whose length scales with the size budget.
    pub fn vec_f32(&mut self, max_len: usize, std: f32) -> Vec<f32> {
        let len = self.usize_in(1, max_len);
        let mut v = vec![0.0; len];
        self.rng.fill_normal(&mut v, std);
        v
    }

    /// Vector with an exact length.
    pub fn vec_f32_exact(&mut self, len: usize, std: f32) -> Vec<f32> {
        let mut v = vec![0.0; len];
        self.rng.fill_normal(&mut v, std);
        v
    }

    /// Occasionally returns edge-case floats instead of normal draws.
    pub fn f32_edgy(&mut self) -> f32 {
        match self.rng.below(8) {
            0 => 0.0,
            1 => -0.0,
            2 => f32::MIN_POSITIVE,
            3 => 65504.0,  // f16 max
            4 => 1e-8,     // f16 underflow
            5 => -3.4e38,  // near f32 min
            _ => self.rng.normal_f32(0.0, 100.0),
        }
    }
}

/// Run `cases` random cases of `prop`. Panics (failing the enclosing test)
/// with the case seed on the first failure.
pub fn check<F: Fn(&mut Gen)>(name: &str, cases: usize, prop: F) {
    let master = 0xC0FFEE_u64 ^ name.bytes().fold(0u64, |h, b| h.wrapping_mul(31).wrapping_add(b as u64));
    for case in 0..cases {
        let seed = master.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let size = ((case + 1) as f64 / cases as f64).min(1.0);
        let run = |sz: f64| {
            let mut g = Gen { rng: Rng::new(seed), size: sz, case };
            prop(&mut g);
        };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| run(size)));
        if let Err(panic) = result {
            // try a "shrunk" (smaller-size) rerun for a friendlier report
            let small = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| run(size * 0.25)));
            let note = if small.is_err() { " (also fails at 1/4 size)" } else { "" };
            let msg = panic
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property '{name}' failed at case {case} (seed {seed:#x}){note}: {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("reverse twice is identity", 50, |g| {
            let v = g.vec_f32(64, 1.0);
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            assert_eq!(v, w);
        });
    }

    #[test]
    fn detects_failures() {
        let r = std::panic::catch_unwind(|| {
            check("always fails", 5, |_g| {
                panic!("boom");
            })
        });
        assert!(r.is_err());
    }

    #[test]
    fn deterministic_cases() {
        use std::cell::RefCell;
        let mut first: Vec<i64> = Vec::new();
        // same name => same seeds => same draws
        for _ in 0..2 {
            let vals = RefCell::new(Vec::new());
            check("collect2", 10, |g| {
                vals.borrow_mut().push(g.i64_in(0, 1_000_000));
            });
            let vals = vals.into_inner();
            if first.is_empty() {
                first = vals;
            } else {
                assert_eq!(first, vals);
            }
        }
    }

    #[test]
    fn size_budget_grows() {
        use std::cell::RefCell;
        let lens = RefCell::new(Vec::new());
        check("sizes", 40, |g| {
            lens.borrow_mut().push(g.usize_in(1, 1000));
        });
        let lens = lens.into_inner();
        let early: f64 = lens[..10].iter().sum::<usize>() as f64 / 10.0;
        let late: f64 = lens[30..].iter().sum::<usize>() as f64 / 10.0;
        assert!(late > early, "{early} vs {late}");
    }
}
