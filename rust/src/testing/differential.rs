//! Differential harness for the event loop: generators for random task
//! graphs — raw DAGs and full plan-level worlds (scheme × machine ×
//! ranks × depth × blocks × P/M/V × scenario) — plus the bit-for-bit
//! comparator that pins the optimized arena engine
//! ([`crate::sched::simulate`]) to the preserved map-based oracle
//! ([`crate::sched::reference::simulate_reference`]). See DESIGN.md §16
//! for the equivalence contract; `tests/differential.rs` drives this
//! module across hundreds of seeded cases and every `BENCH_baseline.json`
//! pin.
//!
//! "Bit-for-bit" means exactly that: makespans and span endpoints are
//! compared via [`f64::to_bits`], and every derived ledger — per-rank
//! stall attribution, link usage, skew waits, the critical-path
//! decomposition — must match on the same terms. Any divergence in
//! issue order, contention re-pricing, or completion sweeps shows up
//! here before it can silently move a calibrated pin.

use crate::comm::cost::{CommEfficiency, CostModel};
use crate::sched::critical;
use crate::sched::multi::MultiRankPlan;
use crate::sched::pipeline::{even_chunk_params, PipeConfig, PipelinePlan};
use crate::sched::plan::StepPlan;
use crate::sched::reference::simulate_reference;
use crate::sched::scenario::{RankCount, Scenario};
use crate::sched::{simulate, Depth, Schedule, StreamKind, Task, TaskGraph, TaskId};
use crate::sharding::{Scheme, ShardingSpec};
use crate::testing::Gen;
use crate::topology::{Cluster, LinkClass};

/// A raw random DAG: arbitrary ranks, all four stream kinds, a mix of
/// zero/tied/fractional works, optional link classes over several
/// contention instances, and random backward dependency edges. This is
/// the adversarial shape the plan builders never produce — simultaneous
/// completions, zero-work cascades, cross-rank dep webs.
pub fn random_graph(g: &mut Gen) -> TaskGraph {
    const STREAMS: [StreamKind; 4] = [
        StreamKind::Compute,
        StreamKind::Prefetch,
        StreamKind::GradSync,
        StreamKind::PipeTransfer,
    ];
    const CLASSES: [LinkClass; 4] =
        [LinkClass::Local, LinkClass::Intra(0), LinkClass::Intra(1), LinkClass::InterNode];
    let n = g.usize_in(1, 120);
    let n_ranks = g.usize_in(1, 6);
    let mut graph = TaskGraph::with_capacity(n);
    for i in 0..n {
        // works with deliberate ties and zeros to stress the completion
        // epsilon and the dt = 0 rounds
        let work = match g.usize_in(0, 4) {
            0 => 0.0,
            1 => 1.0,
            2 => 0.5 + g.f64_unit(),
            _ => (g.usize_in(1, 8) as f64) * 0.25,
        };
        let class = if g.bool() { Some(*g.pick(&CLASSES)) } else { None };
        let mut deps: Vec<TaskId> = Vec::new();
        if i > 0 {
            for _ in 0..g.usize_in(0, 3) {
                let d = TaskId(g.usize_in(0, i - 1));
                if !deps.contains(&d) {
                    deps.push(d);
                }
            }
        }
        graph.add(Task {
            label: format!("t{i}"),
            rank: g.usize_in(0, n_ranks - 1),
            stream: *g.pick(&STREAMS),
            work,
            class,
            instance: g.usize_in(0, 2),
            deps,
        });
    }
    graph
}

/// A random *plan-level* world: a real machine, scheme, and sharding
/// spec expanded through either the multi-rank builder (with a random
/// straggler / jitter / imbalance scenario) or the pipeline builder
/// (random P/M/V, optionally layered). These are the graphs production
/// sweeps actually simulate.
pub fn random_plan_graph(g: &mut Gen) -> TaskGraph {
    let nodes = *g.pick(&[1usize, 2, 4]);
    let cluster = if g.bool() { Cluster::frontier(nodes) } else { Cluster::dgx(nodes) };
    let cost = CostModel::with_efficiency(cluster.clone(), CommEfficiency::rccl_frontier());
    let scheme = *g.pick(&[
        Scheme::Zero1,
        Scheme::Zero2,
        Scheme::Zero3,
        Scheme::ZeroPP,
        Scheme::ZeroTopo { sec_degree: 2 },
    ]);
    let spec = ShardingSpec::resolve(scheme, &cluster).expect("builtin schemes resolve");
    let n_elems = 1_000_000 * g.usize_in(1, 500) as u64;
    let ga = g.usize_in(1, 4);
    let compute_s = 0.5 + g.f64_unit() * 2.0;
    let depth = *g.pick(&[Depth::Infinite, Depth::Bounded(1), Depth::Bounded(2)]);

    if g.bool() {
        // pipeline axis: P/M/V with the interleave constraint m % p == 0
        let p = *g.pick(&[1usize, 2, 4]);
        let v = if p > 1 && g.bool() { 2 } else { 1 };
        let m = p * g.usize_in(1, 3);
        let pipe = PipeConfig { stages: p, microbatches: m, interleave: v };
        let chunks = even_chunk_params(n_elems, p * v);
        let layered = g.bool();
        let plan = PipelinePlan::from_protocol(
            &cost,
            scheme,
            &pipe,
            &chunks,
            256,
            1 << g.usize_in(20, 24),
            compute_s,
            depth,
            layered,
        )
        .expect("generated pipe configs are valid");
        let plan = if g.bool() {
            let mult: Vec<f64> = (0..p).map(|_| 1.0 + g.f64_unit() * 0.5).collect();
            plan.with_stage_multipliers(mult)
        } else {
            plan
        };
        plan.build()
    } else {
        // data-parallel axis: multi-rank expansion under a scenario
        let blocks = *g.pick(&[1usize, 1, 4, 8]);
        let plan = if blocks > 1 {
            let elems = even_chunk_params(n_elems, blocks);
            StepPlan::from_protocol_layered(
                &cost, scheme, &spec, &elems, 256, ga, compute_s, depth,
            )
        } else {
            StepPlan::from_protocol(
                &cost,
                scheme,
                &spec,
                n_elems as usize,
                256,
                ga,
                compute_s,
                depth,
            )
        };
        let world = cluster.world_size();
        let mut scenario = Scenario {
            ranks: if g.bool() {
                RankCount::Auto
            } else {
                RankCount::Count(g.usize_in(1, world.min(8)))
            },
            seed: g.usize_in(0, 1000) as u64,
            ..Default::default()
        };
        if g.bool() {
            scenario.stragglers =
                vec![(g.usize_in(0, world - 1), 1.0 + g.f64_unit())];
        }
        if g.bool() {
            scenario.jitter_sigma = g.f64_unit() * 0.1;
        }
        if g.bool() {
            scenario.imbalance = vec![(g.usize_in(0, world - 1), ga + g.usize_in(1, 3))];
        }
        MultiRankPlan::new(&plan, &cluster, &scenario).build()
    }
}

/// Run `graph` through both event loops and assert bit-identity on
/// every observable (see [`assert_identical`]). Returns the optimized
/// schedule for further inspection.
pub fn simulate_both(graph: TaskGraph) -> Schedule {
    let reference = simulate_reference(graph.clone());
    let optimized = simulate(graph);
    assert_identical(&reference, &optimized);
    optimized
}

/// Exact-bits equality for a pair of floats, with a labeled panic.
fn assert_bits(what: &str, a: f64, b: f64) {
    assert!(
        a.to_bits() == b.to_bits(),
        "{what}: reference {a:?} ({:#x}) != optimized {b:?} ({:#x})",
        a.to_bits(),
        b.to_bits()
    );
}

/// Assert that two schedules of the same graph are bit-identical:
/// makespan, every span, per-rank stall ledgers and skew waits, link
/// usage, and the critical-path decomposition. Panics with the first
/// divergence, labeled by task/rank/link.
pub fn assert_identical(reference: &Schedule, optimized: &Schedule) {
    assert_bits("makespan", reference.makespan(), optimized.makespan());
    assert_eq!(reference.spans().len(), optimized.spans().len(), "span count");
    for (r, o) in reference.spans().iter().zip(optimized.spans()) {
        assert_eq!(r.task, o.task, "span task order");
        assert_bits(&format!("span start of task {}", r.task.0), r.start, o.start);
        assert_bits(&format!("span end of task {}", r.task.0), r.end, o.end);
    }

    // stall + skew ledgers, per rank
    assert_eq!(reference.ranks(), optimized.ranks(), "rank sets");
    for rank in reference.ranks() {
        let rs = reference.stall_by_class(rank);
        let os = optimized.stall_by_class(rank);
        assert_eq!(
            rs.keys().collect::<Vec<_>>(),
            os.keys().collect::<Vec<_>>(),
            "stall classes of rank {rank}"
        );
        for (class, &stall) in &rs {
            assert_bits(&format!("stall[{class}] of rank {rank}"), stall, os[class]);
        }
        assert_bits(
            &format!("skew wait of rank {rank}"),
            reference.skew_wait(rank),
            optimized.skew_wait(rank),
        );
    }

    // link-usage ledger
    let ru = reference.link_usage();
    let ou = optimized.link_usage();
    assert_eq!(ru.keys().collect::<Vec<_>>(), ou.keys().collect::<Vec<_>>(), "link keys");
    for (key, r) in &ru {
        let o = &ou[key];
        assert_bits(&format!("busy of {key:?}"), r.busy, o.busy);
        assert_bits(&format!("task-seconds of {key:?}"), r.task_seconds, o.task_seconds);
        assert_eq!(r.tasks, o.tasks, "task count of {key:?}");
        assert_eq!(r.peak_in_flight, o.peak_in_flight, "peak of {key:?}");
    }

    // critical-path decomposition
    let rd = critical::decompose(reference);
    let od = critical::decompose(optimized);
    assert_bits("decomposition makespan", rd.makespan(), od.makespan());
    assert_bits("decomposition compute", rd.compute_s(), od.compute_s());
    assert_bits("decomposition idle", rd.idle_s(), od.idle_s());
    assert_eq!(
        rd.comm_s().keys().collect::<Vec<_>>(),
        od.comm_s().keys().collect::<Vec<_>>(),
        "decomposition comm classes"
    );
    for (class, &s) in rd.comm_s() {
        assert_bits(&format!("decomposition comm[{class}]"), s, od.comm_s()[class]);
    }
    assert_eq!(rd.segments().len(), od.segments().len(), "segment count");
    for (r, o) in rd.segments().iter().zip(od.segments()) {
        assert_eq!(r.task, o.task, "segment task");
        assert_eq!(r.category, o.category, "segment category of task {}", r.task.0);
        assert_bits(&format!("segment start of task {}", r.task.0), r.start, o.start);
        assert_bits(&format!("segment end of task {}", r.task.0), r.end, o.end);
        assert_bits(
            &format!("segment idle-before of task {}", r.task.0),
            r.idle_before,
            o.idle_before,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::check;

    #[test]
    fn raw_graphs_are_valid_and_loops_agree() {
        check("differential: raw random DAGs", 40, |g| {
            simulate_both(random_graph(g));
        });
    }

    #[test]
    fn plan_graphs_are_valid_and_loops_agree() {
        check("differential: plan-level worlds", 15, |g| {
            simulate_both(random_plan_graph(g));
        });
    }
}
