//! Analytical performance simulator — regenerates the paper's scaling
//! figures (Fig 7: GPT-NeoX-20B, Fig 8: GPT-NeoX-10B) by charging the SAME
//! α–β cost model the engine uses, at the paper's full scale (up to 48
//! nodes / 384 GCDs), with compute anchored to the MI250X peak via an MFU
//! and the RCCL efficiency model calibrated against the paper's own
//! measured ratios (EXPERIMENTS.md §Calibration).
//!
//! Per optimizer step the simulator charges the engine's protocol (same
//! groups, same wire formats):
//!
//! * per microbatch: forward + backward weight all-gathers  (prefetchable)
//! * ZeRO-topo only: the §V.D updated-weight all-gather      (prefetchable)
//! * once per step: gradient sync — ZeRO-3 rings a fp16 reduce-scatter
//!   over the world; ZeRO++ runs the INT4 1-hop all-to-all over the world;
//!   ZeRO-topo runs the INT4 all-to-all inside each node then fp16
//!   all-reduces across nodes                                (blocking)
//!
//! Overlap is *simulated, not averaged*: the step is a task DAG executed
//! by the [`crate::sched`] discrete-event scheduler — per-microbatch
//! gathers pipeline on the prefetch stream up to
//! [`SimConfig::prefetch_depth`] gathers ahead of the compute that
//! consumes them, the §V.D refresh rides the gradient stream, and the
//! gradient sync blocks the step end. `step_s` is the event-clock
//! makespan; stall time per bandwidth level falls out of the schedule
//! ([`simulate_step_schedule`]).
//!
//! Hybrid pipeline-parallel × ZeRO points go through
//! [`simulate_step_pipeline`] (1F1B / interleaved schedules with bubble
//! prediction — DESIGN.md §11).
//!
//! # Example
//!
//! ```no_run
//! // (no_run: doctest binaries miss the libxla rpath in this offline env)
//! use zero_topo::model::TransformerSpec;
//! use zero_topo::sharding::Scheme;
//! use zero_topo::sim::{simulate_step, SimConfig};
//! use zero_topo::topology::Cluster;
//!
//! let b = simulate_step(
//!     &TransformerSpec::gpt125m(),
//!     Scheme::ZeroTopo { sec_degree: 2 },
//!     &Cluster::frontier(1),
//!     &SimConfig::default(),
//! );
//! assert!(b.step_s > 0.0 && b.step_s >= b.compute_s);
//! ```

use crate::comm::cost::{CommEfficiency, CostModel};
use crate::comm::{CommWorld, Wire};
use crate::metrics::sensitivity::{self, Knob, SensitivityReport, ShadowPrice};
use crate::metrics::Throughput;
use crate::model::TransformerSpec;
use crate::sched::multi::MultiRankPlan;
use crate::sched::pipeline::{PipeConfig, PipelineError, PipelinePlan};
use crate::sched::plan::StepPlan;
use crate::sched::scenario::Scenario;
use crate::sched::{Depth, Schedule};
use crate::sharding::{shard_groups, Scheme, ShardingSpec};
use crate::topology::{Cluster, MachineSpec};

pub mod goodput;
pub mod par;
pub mod plan;

/// Simulation parameters. Defaults carry the calibration against the
/// paper's measured 20B @ 384-GCD ratios.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Micro-batch size per GCD.
    pub micro_batch: usize,
    /// Global batch in tokens (grad-accum derived: ga = target/(seq·mbs·W)).
    pub global_batch_tokens: f64,
    /// Model-FLOPs utilization anchor for the compute term.
    pub mfu: f64,
    /// Prefetch depth for the weight-gather stream: how many gather
    /// *units* may run ahead of the compute consuming them — whole
    /// per-microbatch gathers when `layer_blocks == 1`, individual layer
    /// blocks when `layer_blocks > 1` (depth-in-layers, DESIGN.md §12).
    /// `Infinite` models DeepSpeed's free-running side stream;
    /// `Bounded(0)` fetches only on demand (fully serialized).
    pub prefetch_depth: Depth,
    /// Layer blocks the per-microbatch gathers split into (layer-granular
    /// prefetch). `1` = today's monolithic whole-model gathers,
    /// bit-for-bit; `> 1` splits gathers + compute over the model's
    /// contiguous layer chunks (`TransformerSpec::chunk_params`) so
    /// `prefetch_depth` gates in layers. In pipeline runs `> 1` turns on
    /// per-chunk stage gathers instead (a stage's blocks are its chunk
    /// slice).
    pub layer_blocks: usize,
    /// Quantization block for wire sizing.
    pub quant_block: usize,
    /// Collective-library efficiency (RCCL-on-Slingshot calibration).
    pub efficiency: CommEfficiency,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            micro_batch: 1,
            global_batch_tokens: (1u64 << 21) as f64, // ~2.1M tokens
            mfu: 0.35,
            prefetch_depth: Depth::Infinite,
            layer_blocks: 1,
            quant_block: crate::quant::DEFAULT_BLOCK,
            efficiency: CommEfficiency::rccl_frontier(),
        }
    }
}

/// Breakdown of one simulated optimizer step.
#[derive(Debug, Clone, Copy)]
pub struct StepBreakdown {
    /// Per-rank compute seconds (all grad-accum microbatches).
    pub compute_s: f64,
    /// Prefetchable gather time (weight fwd/bwd + topo update gather).
    pub prefetchable_s: f64,
    /// Blocking gradient-sync time.
    pub grad_sync_s: f64,
    /// Event-clock makespan of the scheduled step.
    pub step_s: f64,
    /// Gradient-accumulation microbatches per step.
    pub grad_accum: usize,
    /// Wire bytes the step pushed across node boundaries.
    pub inter_node_bytes: u64,
}

/// Breakdown of one simulated **pipeline-parallel** optimizer step
/// ([`simulate_step_pipeline`]).
#[derive(Debug, Clone, Copy)]
pub struct PipelineBreakdown {
    /// Event-clock makespan of the scheduled pipeline step.
    pub step_s: f64,
    /// Simulated bubble fraction: idle share of the compute window,
    /// including the stalls ZeRO gathers and stage transfers induce.
    pub bubble_fraction: f64,
    /// Closed-form equal-stage free-communication bound
    /// `(P-1)/(V·M + P-1)`.
    pub ideal_bubble: f64,
    /// Pipeline stages `P`.
    pub stages: usize,
    /// Microbatches per step `M` (explicit, or derived from the global
    /// batch over the `W/P`-rank data-parallel width).
    pub microbatches: usize,
    /// Virtual chunks per stage `V` (1 = plain 1F1B).
    pub interleave: usize,
    /// Full-model per-DP-rank compute seconds for the step.
    pub compute_s: f64,
    /// Activation transfer seconds per microbatch per stage boundary.
    pub t_act: f64,
}

/// Wall-clock self-profile of one simulator invocation — REAL time from
/// `std::time::Instant`, kept strictly apart from the simulated event
/// clock (which telemetry must never perturb): how long plan construction
/// and event-loop execution took and how many tasks the loop retired.
/// This is the ROADMAP "Simulator raw speed" number; `calibrate` reports
/// it as tasks/sec and the CI drift table tracks it as a soft (warn-only)
/// gate next to the hard accuracy pins.
#[derive(Debug, Clone, Copy)]
pub struct SimProfile {
    /// Wall seconds spent charging the protocol and building the plan.
    pub plan_build_wall_s: f64,
    /// Wall seconds spent executing the discrete-event loop.
    pub event_loop_wall_s: f64,
    /// Tasks the executed schedule retired.
    pub tasks: usize,
}

impl SimProfile {
    /// Event-loop throughput in tasks per wall second (0.0 when the timer
    /// resolution rounds the loop duration to zero).
    pub fn tasks_per_sec(&self) -> f64 {
        if self.event_loop_wall_s > 0.0 {
            self.tasks as f64 / self.event_loop_wall_s
        } else {
            0.0
        }
    }

    /// Total wall seconds: plan build + event loop.
    pub fn total_wall_s(&self) -> f64 {
        self.plan_build_wall_s + self.event_loop_wall_s
    }
}

/// Price one (model, scheme, cluster) point: charge the full protocol to
/// the byte ledger and derive the step's task-graph durations. Shared by
/// the single-rank and multi-rank simulation entry points.
fn charge_and_plan(
    model: &TransformerSpec,
    scheme: Scheme,
    cluster: &Cluster,
    cfg: &SimConfig,
) -> (StepPlan, f64, CostModel) {
    let spec = ShardingSpec::resolve(scheme, cluster).expect("valid scheme");
    let world = cluster.world_size();
    let psi = model.n_params() as usize;
    let block = cfg.quant_block;

    // grad accumulation to reach the global batch
    let tokens_per_micro = (cfg.micro_batch * model.seq) as f64;
    let ga = (cfg.global_batch_tokens / (tokens_per_micro * world as f64)).round().max(1.0);

    // ---- compute term (per rank; ranks run in parallel) ----
    let flops_per_rank_step = model.flops_per_token() * tokens_per_micro * ga;
    let peak = cluster.peak_flops_per_worker();
    let compute_s = flops_per_rank_step / (peak * cfg.mfu);

    // ---- byte ledger: charge the engine's protocol, every group ----
    let mut world_comm = CommWorld::new(cluster.clone());
    world_comm.cost.efficiency = cfg.efficiency;
    let cost = &mut world_comm.cost;

    let (fwd_wire, bwd_wire) = match scheme {
        Scheme::ZeroPP | Scheme::ZeroTopo { .. } => (Wire::Int8 { block }, Wire::Int8 { block }),
        _ => (Wire::F16, Wire::F16),
    };

    // weight gathers, per microbatch — every group is charged so the byte
    // ledger is complete (congruent groups run in parallel; the step
    // clock below prices rank 0's group only)
    for _ in 0..ga as usize {
        for g in shard_groups(world, spec.weights) {
            cost.all_gather(&g, fwd_wire.wire_bytes(psi) as u64);
        }
        let bwd_degree = if spec.secondary > 0 { spec.secondary } else { spec.weights };
        for g in shard_groups(world, bwd_degree) {
            cost.all_gather(&g, bwd_wire.wire_bytes(psi) as u64);
        }
    }

    let full_group: Vec<usize> = (0..world).collect();

    // ZeRO-topo's §V.D updated-weight all-gather over the optimizer group
    // (stock ZeRO-3/ZeRO++ keep weights sharded; their next fwd gather IS
    // the refresh, so no extra collective for them)
    if matches!(scheme, Scheme::ZeroTopo { .. }) {
        cost.all_gather(&full_group, fwd_wire.wire_bytes(psi) as u64);
    }

    // gradient sync, once per step (blocking at the accumulation boundary)
    match scheme {
        Scheme::Zero1 | Scheme::Zero2 => {
            cost.all_reduce(&full_group, Wire::F16.wire_bytes(psi) as u64);
        }
        Scheme::Zero3 => {
            cost.reduce_scatter(&full_group, Wire::F16.wire_bytes(psi) as u64);
        }
        Scheme::Mics { .. } | Scheme::FsdpHybrid { .. } => {
            let g = spec.grads;
            for grp in shard_groups(world, g) {
                cost.reduce_scatter(&grp, Wire::F16.wire_bytes(psi) as u64);
            }
            let n_groups = world / g;
            if n_groups > 1 {
                let shard_bytes = Wire::F16.wire_bytes(psi / g);
                for local in 0..g {
                    let group: Vec<usize> = (0..n_groups).map(|m| m * g + local).collect();
                    cost.all_reduce(&group, shard_bytes as u64);
                }
            }
        }
        Scheme::ZeroPP => {
            cost.all_to_all(&full_group, Wire::Int4 { block }.wire_bytes(psi) as u64);
        }
        Scheme::ZeroTopo { .. } => {
            let p = cluster.workers_per_node();
            for g in cluster.ranks_by_node() {
                cost.all_to_all(&g, Wire::Int4 { block }.wire_bytes(psi) as u64);
            }
            if cluster.nodes > 1 {
                let shard_bytes = Wire::F16.wire_bytes(psi / p);
                for local in 0..p {
                    let group: Vec<usize> =
                        (0..cluster.nodes).map(|m| m * p + local).collect();
                    cost.all_reduce(&group, shard_bytes as u64);
                }
            }
        }
    }

    // ---- step clock inputs: the task-graph durations ----
    let plan = if cfg.layer_blocks > 1 {
        // layer-granular prefetch: split the microbatch gathers over the
        // model's contiguous layer chunks (embeddings first, head last)
        StepPlan::from_protocol_layered(
            cost,
            scheme,
            &spec,
            &model.chunk_params(cfg.layer_blocks),
            block,
            ga as usize,
            compute_s,
            cfg.prefetch_depth,
        )
    } else {
        StepPlan::from_protocol(
            cost,
            scheme,
            &spec,
            psi,
            block,
            ga as usize,
            compute_s,
            cfg.prefetch_depth,
        )
    };
    (plan, compute_s, world_comm.cost)
}

fn breakdown_of(
    plan: &StepPlan,
    compute_s: f64,
    inter_node_bytes: u64,
    step_s: f64,
) -> StepBreakdown {
    StepBreakdown {
        compute_s,
        prefetchable_s: plan.prefetchable_s(),
        grad_sync_s: plan.grad_sync_s(),
        step_s,
        grad_accum: plan.grad_accum,
        inter_node_bytes,
    }
}

/// Simulate one (model, scheme, cluster) point and keep the schedule —
/// the full stream timeline — for trace export / stall attribution.
pub fn simulate_step_schedule(
    model: &TransformerSpec,
    scheme: Scheme,
    cluster: &Cluster,
    cfg: &SimConfig,
) -> (StepBreakdown, Schedule) {
    let (breakdown, schedule, _) = simulate_step_telemetry(model, scheme, cluster, cfg, None);
    (breakdown, schedule)
}

/// [`simulate_step_schedule`] (or, with a scenario, the multi-rank step
/// clock of [`simulate_step_scenario`]) that additionally keeps the full
/// byte ledger — the per-collective [`CostModel`] the telemetry stream
/// serializes. The simulated numbers are bit-identical to the plain entry
/// points; only what is *returned* differs.
pub fn simulate_step_telemetry(
    model: &TransformerSpec,
    scheme: Scheme,
    cluster: &Cluster,
    cfg: &SimConfig,
    scenario: Option<&Scenario>,
) -> (StepBreakdown, Schedule, CostModel) {
    let (plan, compute_s, cost) = charge_and_plan(model, scheme, cluster, cfg);
    let schedule = match scenario {
        None => plan.simulate(),
        Some(sc) => MultiRankPlan::new(&plan, cluster, sc).simulate(),
    };
    let breakdown =
        breakdown_of(&plan, compute_s, cost.inter_node_bytes(), schedule.makespan());
    (breakdown, schedule, cost)
}

/// Simulate one point under a multi-rank [`Scenario`] (stragglers, jitter,
/// imbalanced grad-accum, explicit `--ranks`). A trivial scenario with
/// auto rank collapsing reproduces [`simulate_step_schedule`] bit-for-bit;
/// asymmetric ones return the cross-rank schedule whose makespan the
/// slowest rank sets.
pub fn simulate_step_scenario(
    model: &TransformerSpec,
    scheme: Scheme,
    cluster: &Cluster,
    cfg: &SimConfig,
    scenario: &Scenario,
) -> (StepBreakdown, Schedule) {
    let (breakdown, schedule, _) =
        simulate_step_telemetry(model, scheme, cluster, cfg, Some(scenario));
    (breakdown, schedule)
}

/// [`simulate_step_schedule`] with wall-clock self-profiling around the
/// plan build and the event loop. The simulated result is identical —
/// the timers only observe; they never feed the event clock.
pub fn profile_step(
    model: &TransformerSpec,
    scheme: Scheme,
    cluster: &Cluster,
    cfg: &SimConfig,
) -> (StepBreakdown, Schedule, SimProfile) {
    let t0 = std::time::Instant::now();
    let (plan, compute_s, cost) = charge_and_plan(model, scheme, cluster, cfg);
    let plan_build_wall_s = t0.elapsed().as_secs_f64();
    let t1 = std::time::Instant::now();
    let schedule = plan.simulate();
    let event_loop_wall_s = t1.elapsed().as_secs_f64();
    let breakdown =
        breakdown_of(&plan, compute_s, cost.inter_node_bytes(), schedule.makespan());
    let profile = SimProfile {
        plan_build_wall_s,
        event_loop_wall_s,
        tasks: schedule.spans().len(),
    };
    (breakdown, schedule, profile)
}

/// Simulate one (model, scheme, cluster) point.
pub fn simulate_step(
    model: &TransformerSpec,
    scheme: Scheme,
    cluster: &Cluster,
    cfg: &SimConfig,
) -> StepBreakdown {
    simulate_step_schedule(model, scheme, cluster, cfg).0
}

fn pipeline_point(
    model: &TransformerSpec,
    scheme: Scheme,
    cluster: &Cluster,
    cfg: &SimConfig,
    pipe: &PipeConfig,
    scenario: Option<&Scenario>,
) -> Result<(PipelineBreakdown, Schedule, PipelinePlan, SimProfile), PipelineError> {
    let t0 = std::time::Instant::now();
    let p = pipe.stages;
    if p == 0 {
        return Err(PipelineError::BadStages(0));
    }
    if cluster.nodes % p != 0 {
        return Err(PipelineError::StagesDontDivideNodes { stages: p, nodes: cluster.nodes });
    }
    let dp = cluster.world_size() / p;
    let tokens_per_micro = (cfg.micro_batch * model.seq) as f64;
    // microbatches: explicit, or the grad-accum needed to reach the global
    // batch over the W/P-wide data-parallel axis (P = 1 reproduces the
    // simulate_step derivation exactly)
    let m = if pipe.microbatches > 0 {
        pipe.microbatches as f64
    } else {
        (cfg.global_batch_tokens / (tokens_per_micro * dp as f64)).round().max(1.0)
    };
    let flops_per_rank_step = model.flops_per_token() * tokens_per_micro * m;
    let peak = cluster.peak_flops_per_worker();
    let compute_s = flops_per_rank_step / (peak * cfg.mfu);

    let resolved =
        PipeConfig { stages: p, microbatches: m as usize, interleave: pipe.interleave };
    let cost = CostModel::with_efficiency(cluster.clone(), cfg.efficiency);
    let chunk_params = model.chunk_params(resolved.chunks());
    let mut plan = PipelinePlan::from_protocol(
        &cost,
        scheme,
        &resolved,
        &chunk_params,
        cfg.quant_block,
        model.activation_bytes(cfg.micro_batch),
        compute_s,
        cfg.prefetch_depth,
        cfg.layer_blocks > 1,
    )?;
    if let Some(sc) = scenario {
        if !sc.is_trivial() {
            plan = plan.with_stage_multipliers(sc.stage_multipliers(cluster, p));
        }
    }
    let plan_build_wall_s = t0.elapsed().as_secs_f64();
    let t1 = std::time::Instant::now();
    let sched = plan.simulate();
    let event_loop_wall_s = t1.elapsed().as_secs_f64();
    let profile = SimProfile {
        plan_build_wall_s,
        event_loop_wall_s,
        tasks: sched.spans().len(),
    };
    let breakdown = PipelineBreakdown {
        step_s: sched.makespan(),
        bubble_fraction: plan.bubble_fraction(&sched),
        ideal_bubble: PipelinePlan::ideal_bubble(p, plan.microbatches(), plan.interleave),
        stages: p,
        microbatches: plan.microbatches(),
        interleave: plan.interleave,
        compute_s,
        t_act: plan.t_act,
    };
    Ok((breakdown, sched, plan, profile))
}

/// Simulate one point under a hybrid pipeline-parallel × ZeRO execution:
/// `P` stages on contiguous node groups, the ZeRO scheme inside each
/// stage's `W/P`-rank group, 1F1B (or interleaved, `pipe.interleave > 1`)
/// microbatch schedule. `pipe.microbatches == 0` derives `M` from the
/// global batch. With `P = 1` the step time is **bit-for-bit**
/// [`simulate_step`]'s (the pipeline path degenerates to the calibrated
/// single-axis plan — gated by `tests/pipeline.rs`). Returns the step
/// breakdown, the executed schedule (trace/stall queries), and the
/// priced plan (per-stage rendering).
pub fn simulate_step_pipeline(
    model: &TransformerSpec,
    scheme: Scheme,
    cluster: &Cluster,
    cfg: &SimConfig,
    pipe: &PipeConfig,
) -> Result<(PipelineBreakdown, Schedule, PipelinePlan), PipelineError> {
    pipeline_point(model, scheme, cluster, cfg, pipe, None).map(|(b, s, p, _)| (b, s, p))
}

/// [`simulate_step_pipeline`] with wall-clock self-profiling around plan
/// build and event loop (same contract as [`profile_step`]).
pub fn profile_step_pipeline(
    model: &TransformerSpec,
    scheme: Scheme,
    cluster: &Cluster,
    cfg: &SimConfig,
    pipe: &PipeConfig,
) -> Result<(PipelineBreakdown, Schedule, PipelinePlan, SimProfile), PipelineError> {
    pipeline_point(model, scheme, cluster, cfg, pipe, None)
}

/// One evaluation of a (possibly perturbed) configuration point: the DP
/// event-clock makespan, or the pipeline makespan when `pipe` is given.
/// `None` when the pipeline point is infeasible under the perturbation.
fn step_seconds(
    model: &TransformerSpec,
    scheme: Scheme,
    cluster: &Cluster,
    cfg: &SimConfig,
    pipe: Option<&PipeConfig>,
) -> Option<f64> {
    match pipe {
        None => Some(simulate_step(model, scheme, cluster, cfg).step_s),
        Some(p) => {
            simulate_step_pipeline(model, scheme, cluster, cfg, p).ok().map(|(b, _, _)| b.step_s)
        }
    }
}

/// Link shadow prices for one configuration point (DESIGN.md §14): the
/// [`crate::metrics::sensitivity`] sweep over every machine knob — peak
/// compute, per-level bandwidths and latencies — re-simulating the step
/// under the one-notch (×2 bandwidth/compute, ÷2 latency) improvement
/// and the ε derivative probe, plus the discrete schedule knobs this
/// module owns: prefetch depth +1 (bounded depths only), layer blocks ×2
/// (layered runs only), and ZeRO-topo's secondary degree bumped to the
/// next level span. `pipe` switches the evaluator to the pipeline
/// makespan. Errors only when the *base* pipeline point is infeasible;
/// infeasible perturbed points silently drop their knob.
pub fn shadow_prices(
    model: &TransformerSpec,
    scheme: Scheme,
    cluster: &Cluster,
    cfg: &SimConfig,
    pipe: Option<&PipeConfig>,
    epsilon: f64,
) -> Result<SensitivityReport, PipelineError> {
    let base_s = match pipe {
        None => simulate_step(model, scheme, cluster, cfg).step_s,
        Some(p) => simulate_step_pipeline(model, scheme, cluster, cfg, p)?.0.step_s,
    };
    let mut report = sensitivity::sweep(&cluster.spec, base_s, epsilon, |spec| {
        let c = Cluster::new(spec.clone(), cluster.nodes);
        step_seconds(model, scheme, &c, cfg, pipe)
    });
    let mut discrete = |knob: Knob, scheme2: Scheme, cfg2: &SimConfig| {
        if let Some(t) = step_seconds(model, scheme2, cluster, cfg2, pipe) {
            report.add(ShadowPrice {
                knob,
                label: knob.label(&cluster.spec),
                improved_s: t,
                saving: base_s - t,
                derivative: None,
            });
        }
    };
    if let Depth::Bounded(d) = cfg.prefetch_depth {
        let mut c2 = cfg.clone();
        c2.prefetch_depth = Depth::Bounded(d + 1);
        discrete(Knob::PrefetchDepth, scheme, &c2);
    }
    if cfg.layer_blocks > 1 {
        let doubled = (cfg.layer_blocks * 2).min(model.n_layers);
        if doubled != cfg.layer_blocks {
            let mut c2 = cfg.clone();
            c2.layer_blocks = doubled;
            discrete(Knob::LayerBlocks, scheme, &c2);
        }
    }
    if matches!(scheme, Scheme::ZeroTopo { .. }) {
        if let Ok(resolved) = ShardingSpec::resolve(scheme, cluster) {
            if let Some(next) =
                cluster.spec.levels.iter().map(|l| l.span).find(|&s| s > resolved.secondary)
            {
                discrete(Knob::SecDegree, Scheme::ZeroTopo { sec_degree: next }, cfg);
            }
        }
    }
    Ok(report)
}

/// [`simulate_step_pipeline`] with a [`Scenario`] mapped onto stages:
/// each stage runs at the *slowest* multiplier among its ranks
/// (stragglers gate their stage's collectives), so "straggler on a
/// stage" studies compose with the pipeline schedule.
pub fn simulate_step_pipeline_scenario(
    model: &TransformerSpec,
    scheme: Scheme,
    cluster: &Cluster,
    cfg: &SimConfig,
    pipe: &PipeConfig,
    scenario: &Scenario,
) -> Result<(PipelineBreakdown, Schedule, PipelinePlan), PipelineError> {
    pipeline_point(model, scheme, cluster, cfg, pipe, Some(scenario)).map(|(b, s, p, _)| (b, s, p))
}

/// [`scaling_series`] under a pipeline-parallel execution: every point's
/// step time is the pipeline makespan over `P × (W/P)` ranks; the global
/// batch per step is `M` microbatches on each of the `W/P` data-parallel
/// pipelines. Errors if any node count is not a multiple of `P`.
pub fn scaling_series_pipeline(
    model: &TransformerSpec,
    scheme: Scheme,
    machine: &MachineSpec,
    node_counts: &[usize],
    cfg: &SimConfig,
    pipe: &PipeConfig,
) -> Result<Vec<Throughput>, PipelineError> {
    scaling_series_pipeline_threaded(model, scheme, machine, node_counts, cfg, pipe, 1)
}

/// [`scaling_series_pipeline`] over up to `threads` worker threads (one
/// pure simulation per point; results in node-count order regardless of
/// the thread count — see [`par::parallel_map`]).
#[allow(clippy::too_many_arguments)]
pub fn scaling_series_pipeline_threaded(
    model: &TransformerSpec,
    scheme: Scheme,
    machine: &MachineSpec,
    node_counts: &[usize],
    cfg: &SimConfig,
    pipe: &PipeConfig,
    threads: usize,
) -> Result<Vec<Throughput>, PipelineError> {
    par::parallel_map(threads, node_counts, |_, &nodes| {
        let cluster = Cluster::new(machine.clone(), nodes);
        let world = cluster.world_size();
        let (b, _, _) = simulate_step_pipeline(model, scheme, &cluster, cfg, pipe)?;
        let dp = world / b.stages;
        let tokens = (b.microbatches * cfg.micro_batch * model.seq * dp) as f64;
        Ok(Throughput {
            gcds: world,
            step_seconds: b.step_s,
            flops_per_step: model.flops_per_token() * tokens,
            sequences_per_step: tokens / model.seq as f64,
        })
    })
    .into_iter()
    .collect()
}

/// Produce the paper's per-scale Throughput series for one scheme on one
/// machine spec (Frontier for the paper's figures; any builtin or
/// JSON-loaded [`MachineSpec`] otherwise).
pub fn scaling_series(
    model: &TransformerSpec,
    scheme: Scheme,
    machine: &MachineSpec,
    node_counts: &[usize],
    cfg: &SimConfig,
) -> Vec<Throughput> {
    scaling_series_threaded(model, scheme, machine, node_counts, cfg, 1)
}

/// [`scaling_series`] over up to `threads` worker threads (one pure
/// simulation per point; deterministic node-count result order).
pub fn scaling_series_threaded(
    model: &TransformerSpec,
    scheme: Scheme,
    machine: &MachineSpec,
    node_counts: &[usize],
    cfg: &SimConfig,
    threads: usize,
) -> Vec<Throughput> {
    par::parallel_map(threads, node_counts, |_, &nodes| {
        let cluster = Cluster::new(machine.clone(), nodes);
        let world = cluster.world_size();
        let b = simulate_step(model, scheme, &cluster, cfg);
        let tokens = (b.grad_accum * cfg.micro_batch * model.seq * world) as f64;
        Throughput {
            gcds: world,
            step_seconds: b.step_s,
            flops_per_step: model.flops_per_token() * tokens,
            sequences_per_step: tokens / model.seq as f64,
        }
    })
}

/// [`scaling_series`] under a multi-rank scenario: every point's step time
/// is the cross-rank makespan. With a trivial scenario this equals the
/// plain series bit-for-bit (congruence collapsing).
pub fn scaling_series_scenario(
    model: &TransformerSpec,
    scheme: Scheme,
    machine: &MachineSpec,
    node_counts: &[usize],
    cfg: &SimConfig,
    scenario: &Scenario,
) -> Vec<Throughput> {
    scaling_series_scenario_threaded(model, scheme, machine, node_counts, cfg, scenario, 1)
}

/// [`scaling_series_scenario`] over up to `threads` worker threads (one
/// pure simulation per point; deterministic node-count result order).
#[allow(clippy::too_many_arguments)]
pub fn scaling_series_scenario_threaded(
    model: &TransformerSpec,
    scheme: Scheme,
    machine: &MachineSpec,
    node_counts: &[usize],
    cfg: &SimConfig,
    scenario: &Scenario,
    threads: usize,
) -> Vec<Throughput> {
    par::parallel_map(threads, node_counts, |_, &nodes| {
        let cluster = Cluster::new(machine.clone(), nodes);
        let world = cluster.world_size();
        let (b, _) = simulate_step_scenario(model, scheme, &cluster, cfg, scenario);
        let tokens = (b.grad_accum * cfg.micro_batch * model.seq * world) as f64;
        Throughput {
            gcds: world,
            step_seconds: b.step_s,
            flops_per_step: model.flops_per_token() * tokens,
            sequences_per_step: tokens / model.seq as f64,
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_point(scheme: Scheme, nodes: usize) -> f64 {
        let model = TransformerSpec::neox20b();
        let cfg = SimConfig::default();
        let cluster = Cluster::frontier(nodes);
        let b = simulate_step(&model, scheme, &cluster, &cfg);
        let world = cluster.world_size() as f64;
        let tokens = (b.grad_accum as f64) * cfg.micro_batch as f64 * model.seq as f64 * world;
        model.flops_per_token() * tokens / b.step_s / world / 1e12
    }

    #[test]
    fn fig7_ordering_at_384_gcds() {
        // the paper's §VI: topo > ZeRO++ > ZeRO-3 at 48 nodes (384 GCDs)
        let z3 = paper_point(Scheme::Zero3, 48);
        let zpp = paper_point(Scheme::ZeroPP, 48);
        let topo = paper_point(Scheme::ZeroTopo { sec_degree: 2 }, 48);
        assert!(topo > zpp && zpp > z3, "topo={topo:.1} zpp={zpp:.1} z3={z3:.1}");
    }

    #[test]
    fn fig7_speedup_magnitudes() {
        // paper: ZeRO++ +40.5% over ZeRO-3; topo +70.7% over ZeRO++;
        // topo +139.8% over ZeRO-3 (20B @ 384 GCDs).
        let z3 = paper_point(Scheme::Zero3, 48);
        let zpp = paper_point(Scheme::ZeroPP, 48);
        let topo = paper_point(Scheme::ZeroTopo { sec_degree: 2 }, 48);
        let r_pp = zpp / z3;
        let r_topo_pp = topo / zpp;
        let r_topo_3 = topo / z3;
        assert!((1.25..1.6).contains(&r_pp), "zpp/z3 = {r_pp:.2} (paper 1.405)");
        assert!((1.45..1.95).contains(&r_topo_pp), "topo/zpp = {r_topo_pp:.2} (paper 1.707)");
        assert!((1.9..2.9).contains(&r_topo_3), "topo/z3 = {r_topo_3:.2} (paper 2.398)");
    }

    #[test]
    fn topo_scaling_efficiency_near_linear() {
        // paper: 0.94 efficiency for up to 384 GCDs
        let model = TransformerSpec::neox20b();
        let cfg = SimConfig::default();
        let frontier = MachineSpec::frontier_mi250x();
        let pts = scaling_series(
            &model,
            Scheme::ZeroTopo { sec_degree: 2 },
            &frontier,
            &[8, 16, 32, 48],
            &cfg,
        );
        let eff = crate::metrics::scaling_efficiency(&pts);
        assert!(
            (0.88..1.0).contains(eff.last().unwrap()),
            "topo eff {eff:?} (paper 0.94)"
        );
        // while ZeRO-3 degrades markedly
        let pts3 = scaling_series(&model, Scheme::Zero3, &frontier, &[8, 16, 32, 48], &cfg);
        let eff3 = crate::metrics::scaling_efficiency(&pts3);
        assert!(eff3.last().unwrap() < &0.88, "z3 eff {eff3:?}");
    }

    #[test]
    fn fig8_10b_same_ordering() {
        let model = TransformerSpec::neox10b();
        let cfg = SimConfig::default();
        let c = Cluster::frontier(48);
        let tf = |scheme| {
            let b = simulate_step(&model, scheme, &c, &cfg);
            let tokens = (b.grad_accum * model.seq * 384) as f64;
            model.flops_per_token() * tokens / b.step_s / 384.0 / 1e12
        };
        let (z3, zpp, topo) = (
            tf(Scheme::Zero3),
            tf(Scheme::ZeroPP),
            tf(Scheme::ZeroTopo { sec_degree: 2 }),
        );
        assert!(topo > zpp && zpp > z3, "{topo:.1} {zpp:.1} {z3:.1}");
    }

    #[test]
    fn topo_cuts_inter_node_traffic() {
        let model = TransformerSpec::neox20b();
        let cfg = SimConfig::default();
        let cluster = Cluster::frontier(8);
        let b3 = simulate_step(&model, Scheme::Zero3, &cluster, &cfg);
        let bt = simulate_step(&model, Scheme::ZeroTopo { sec_degree: 2 }, &cluster, &cfg);
        assert!(
            bt.inter_node_bytes < b3.inter_node_bytes / 2,
            "topo {} vs z3 {}",
            bt.inter_node_bytes,
            b3.inter_node_bytes
        );
    }

    #[test]
    fn scaling_series_runs_on_non_frontier_machines() {
        // the old code hardcoded `Cluster::frontier` here — DGX and
        // data-only machines must sweep end-to-end now
        let model = TransformerSpec::neox10b();
        let cfg = SimConfig::default();
        for m in [MachineSpec::dgx_a100(), MachineSpec::aurora_pvc(), MachineSpec::tpu_pod()] {
            for scheme in [Scheme::Zero3, Scheme::ZeroPP, Scheme::ZeroTopo { sec_degree: 0 }] {
                let pts = scaling_series(&model, scheme, &m, &[1, 2, 4], &cfg);
                assert_eq!(pts.len(), 3);
                assert!(
                    pts.iter().all(|p| p.step_seconds.is_finite() && p.step_seconds > 0.0),
                    "{} {:?}",
                    m.name,
                    scheme
                );
            }
        }
    }

    #[test]
    fn single_node_runs() {
        let model = TransformerSpec::gpt125m();
        let cfg = SimConfig::default();
        let b =
            simulate_step(&model, Scheme::ZeroTopo { sec_degree: 2 }, &Cluster::frontier(1), &cfg);
        assert!(b.step_s > 0.0 && b.grad_sync_s >= 0.0);
    }

    #[test]
    fn compute_term_scales_with_model() {
        let cfg = SimConfig::default();
        let c = Cluster::frontier(8);
        let b10 = simulate_step(&TransformerSpec::neox10b(), Scheme::Zero3, &c, &cfg);
        let b20 = simulate_step(&TransformerSpec::neox20b(), Scheme::Zero3, &c, &cfg);
        assert!(b20.compute_s > 1.5 * b10.compute_s);
    }

    #[test]
    fn ideal_network_compresses_the_gap() {
        // with a perfect interconnect the schemes converge — the paper's
        // point is that the gap is a *low-bandwidth* phenomenon
        let model = TransformerSpec::neox20b();
        let mut cfg = SimConfig::default();
        cfg.efficiency = CommEfficiency::default();
        let c = Cluster::frontier(48);
        let tf = |s, cfg: &SimConfig| {
            let b = simulate_step(&model, s, &c, cfg);
            let tokens = (b.grad_accum * model.seq * 384) as f64;
            model.flops_per_token() * tokens / b.step_s / 384.0 / 1e12
        };
        let gap_ideal = tf(Scheme::ZeroTopo { sec_degree: 2 }, &cfg) / tf(Scheme::Zero3, &cfg);
        let gap_real =
            paper_point(Scheme::ZeroTopo { sec_degree: 2 }, 48) / paper_point(Scheme::Zero3, 48);
        assert!(gap_ideal < gap_real, "ideal {gap_ideal:.2} vs real {gap_real:.2}");
    }

    #[test]
    fn depth_zero_degenerates_to_serialized_time() {
        // with no prefetch ahead, the step is exactly compute +
        // per-microbatch gathers + grad sync (ZeRO-3: no update gather)
        let model = TransformerSpec::neox20b();
        let mut cfg = SimConfig::default();
        cfg.prefetch_depth = Depth::Bounded(0);
        let c = Cluster::frontier(48);
        let b = simulate_step(&model, Scheme::Zero3, &c, &cfg);
        let serial = b.compute_s + b.prefetchable_s + b.grad_sync_s;
        assert!((b.step_s - serial).abs() < 1e-9 * serial, "{} vs {serial}", b.step_s);
    }

    #[test]
    fn deeper_prefetch_is_never_slower() {
        let model = TransformerSpec::neox20b();
        let c = Cluster::frontier(48);
        for scheme in [Scheme::Zero3, Scheme::ZeroPP, Scheme::ZeroTopo { sec_degree: 2 }] {
            let mut last = f64::INFINITY;
            for depth in [Depth::Bounded(0), Depth::Bounded(1), Depth::Bounded(2), Depth::Infinite]
            {
                let mut cfg = SimConfig::default();
                cfg.prefetch_depth = depth;
                let b = simulate_step(&model, scheme, &c, &cfg);
                assert!(b.step_s <= last + 1e-9, "{scheme:?} {depth:?}: {} > {last}", b.step_s);
                last = b.step_s;
            }
        }
    }

    #[test]
    fn layer_blocks_one_is_bitwise_the_default_path() {
        let model = TransformerSpec::neox20b();
        let c = Cluster::frontier(48);
        for scheme in [Scheme::Zero3, Scheme::ZeroPP, Scheme::ZeroTopo { sec_degree: 2 }] {
            let base = simulate_step(&model, scheme, &c, &SimConfig::default());
            let mut cfg = SimConfig::default();
            cfg.layer_blocks = 1;
            let one = simulate_step(&model, scheme, &c, &cfg);
            assert_eq!(base.step_s, one.step_s, "{scheme:?}");
        }
    }

    #[test]
    fn layered_depth_in_layers_is_monotone_and_converges() {
        let model = TransformerSpec::neox20b();
        let c = Cluster::frontier(48);
        for scheme in [Scheme::Zero3, Scheme::ZeroTopo { sec_degree: 2 }] {
            let mono = simulate_step(&model, scheme, &c, &SimConfig::default());
            let mut last = f64::INFINITY;
            for depth in
                [Depth::Bounded(0), Depth::Bounded(1), Depth::Bounded(4), Depth::Infinite]
            {
                let mut cfg = SimConfig::default();
                cfg.layer_blocks = model.n_layers;
                cfg.prefetch_depth = depth;
                let b = simulate_step(&model, scheme, &c, &cfg);
                // relative slack absorbs update-gather processor-sharing
                // noise (the rigorous monotone property lives in
                // tests/layered_prefetch.rs over update-free schemes)
                assert!(
                    b.step_s <= last * (1.0 + 1e-6),
                    "{scheme:?} {depth:?}: {} > {last}",
                    b.step_s
                );
                last = b.step_s;
                // the split conserves totals, so the breakdown is unchanged
                assert!((b.prefetchable_s - mono.prefetchable_s).abs() < 1e-6);
            }
            // depth=inf in layers: never slower than monolithic inf, gains
            // at most one microbatch's compute (the shrunken step tail);
            // the compute-bound ZeRO-topo point converges within 1%
            assert!(last <= mono.step_s + 1e-9, "{scheme:?}: {last} vs {}", mono.step_s);
            let micro_compute = mono.compute_s / mono.grad_accum as f64;
            assert!(
                last >= mono.step_s - micro_compute - 1e-9,
                "{scheme:?}: {last} vs {}",
                mono.step_s
            );
            if matches!(scheme, Scheme::ZeroTopo { .. }) {
                assert!(
                    (last - mono.step_s).abs() <= 0.01 * mono.step_s,
                    "{last} vs {}",
                    mono.step_s
                );
            }
        }
    }

    #[test]
    fn layered_pipeline_point_prices_and_stays_monotone() {
        let model = TransformerSpec::neox20b();
        let c = Cluster::frontier(48);
        let scheme = Scheme::ZeroTopo { sec_degree: 2 };
        let pipe = PipeConfig { stages: 4, microbatches: 8, interleave: 2 };
        let mut last = f64::INFINITY;
        for depth in [Depth::Bounded(0), Depth::Bounded(2), Depth::Infinite] {
            let mut cfg = SimConfig::default();
            cfg.layer_blocks = model.n_layers;
            cfg.prefetch_depth = depth;
            let (b, _, plan) = simulate_step_pipeline(&model, scheme, &c, &cfg, &pipe).unwrap();
            assert!(b.step_s.is_finite() && b.step_s > 0.0);
            // p2p transfers share the fabric with stage gathers: monotone
            // up to processor-sharing noise
            assert!(b.step_s <= last * (1.0 + 1e-6), "{depth:?}: {} > {last}", b.step_s);
            last = b.step_s;
            // a stage's blocks are exactly its chunk slice (V per stage)
            assert!(plan.stages.iter().all(|sp| sp.blocks.len() == 2));
        }
    }

    #[test]
    fn profiling_observes_without_perturbing_the_event_clock() {
        let model = TransformerSpec::neox20b();
        let cfg = SimConfig::default();
        let c = Cluster::frontier(48);
        for scheme in [Scheme::Zero3, Scheme::ZeroPP, Scheme::ZeroTopo { sec_degree: 2 }] {
            let plain = simulate_step(&model, scheme, &c, &cfg);
            let (b, sched, prof) = profile_step(&model, scheme, &c, &cfg);
            assert_eq!(plain.step_s, b.step_s, "{scheme:?}");
            assert_eq!(prof.tasks, sched.spans().len());
            assert!(prof.tasks > 0);
            assert!(prof.plan_build_wall_s >= 0.0 && prof.event_loop_wall_s >= 0.0);
            assert!(prof.total_wall_s() >= prof.event_loop_wall_s);
        }
        let pipe = PipeConfig { stages: 4, microbatches: 8, interleave: 1 };
        let scheme = Scheme::ZeroTopo { sec_degree: 2 };
        let (plain, _, _) = simulate_step_pipeline(&model, scheme, &c, &cfg, &pipe).unwrap();
        let (b, sched, _, prof) =
            profile_step_pipeline(&model, scheme, &c, &cfg, &pipe).unwrap();
        assert_eq!(plain.step_s, b.step_s);
        assert_eq!(prof.tasks, sched.spans().len());
    }

    #[test]
    fn tasks_per_sec_guards_zero_wall_time() {
        let z = SimProfile { plan_build_wall_s: 0.0, event_loop_wall_s: 0.0, tasks: 100 };
        assert_eq!(z.tasks_per_sec(), 0.0);
        let p = SimProfile { plan_build_wall_s: 0.1, event_loop_wall_s: 0.5, tasks: 100 };
        assert!((p.tasks_per_sec() - 200.0).abs() < 1e-9);
        assert!((p.total_wall_s() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn trivial_scenario_reproduces_single_rank_step() {
        let model = TransformerSpec::neox20b();
        let cfg = SimConfig::default();
        let c = Cluster::frontier(48);
        for scheme in [Scheme::Zero3, Scheme::ZeroPP, Scheme::ZeroTopo { sec_degree: 2 }] {
            let a = simulate_step(&model, scheme, &c, &cfg);
            let (b, sched) =
                simulate_step_scenario(&model, scheme, &c, &cfg, &Scenario::default());
            assert_eq!(a.step_s, b.step_s, "{scheme:?}");
            assert_eq!(sched.ranks(), vec![0]);
        }
    }

    #[test]
    fn straggler_scenario_stretches_step_and_attributes_skew() {
        // acceptance: one rank at 1.2x compute measurably stretches the
        // 20B/384-GCD step and shows up in the per-rank attribution
        let model = TransformerSpec::neox20b();
        let cfg = SimConfig::default();
        let c = Cluster::frontier(48);
        for scheme in [Scheme::Zero3, Scheme::ZeroPP, Scheme::ZeroTopo { sec_degree: 2 }] {
            let base = simulate_step(&model, scheme, &c, &cfg);
            let sc = Scenario { stragglers: vec![(5, 1.2)], ..Default::default() };
            let (b, sched) = simulate_step_scenario(&model, scheme, &c, &cfg, &sc);
            assert!(
                b.step_s > base.step_s * 1.005,
                "{scheme:?}: {} vs {}",
                b.step_s,
                base.step_s
            );
            assert_eq!(sched.slowest_rank(), 5, "{scheme:?}");
            // the victims' wait is visible: either pure skew (compute-bound
            // schemes) or extra class-attributed stall (comm-bound ones)
            let victim = *sched.ranks().iter().find(|&&r| r != 5).unwrap();
            let victim_stall = sched.skew_wait(victim)
                + sched.stall_by_class(victim).values().sum::<f64>();
            let straggler_stall = sched.skew_wait(5)
                + sched.stall_by_class(5).values().sum::<f64>();
            assert!(
                victim_stall > straggler_stall,
                "{scheme:?}: victim {victim_stall} vs straggler {straggler_stall}"
            );
        }
    }

    #[test]
    fn scenario_scaling_series_matches_plain_when_trivial() {
        let model = TransformerSpec::neox10b();
        let cfg = SimConfig::default();
        let frontier = MachineSpec::frontier_mi250x();
        let scheme = Scheme::ZeroTopo { sec_degree: 2 };
        let plain = scaling_series(&model, scheme, &frontier, &[2, 4], &cfg);
        let sc = scaling_series_scenario(
            &model,
            scheme,
            &frontier,
            &[2, 4],
            &cfg,
            &Scenario::default(),
        );
        for (a, b) in plain.iter().zip(&sc) {
            assert_eq!(a.step_seconds, b.step_seconds);
        }
    }

    #[test]
    fn pipeline_p1_is_bitwise_simulate_step() {
        let model = TransformerSpec::neox20b();
        let cfg = SimConfig::default();
        let c = Cluster::frontier(48);
        let pipe = PipeConfig { stages: 1, microbatches: 0, interleave: 1 };
        for scheme in [Scheme::Zero3, Scheme::ZeroPP, Scheme::ZeroTopo { sec_degree: 2 }] {
            let a = simulate_step(&model, scheme, &c, &cfg);
            let (b, _, _) = simulate_step_pipeline(&model, scheme, &c, &cfg, &pipe).unwrap();
            assert_eq!(a.step_s, b.step_s, "{scheme:?}");
            assert_eq!(a.grad_accum, b.microbatches, "{scheme:?}");
            // no pipeline axis: the closed-form bubble bound is zero (the
            // simulated fraction still reports the comm-stall share)
            assert_eq!(b.ideal_bubble, 0.0, "{scheme:?}");
        }
    }

    #[test]
    fn pipeline_bubble_shrinks_with_microbatches_and_interleave() {
        let model = TransformerSpec::neox20b();
        let cfg = SimConfig::default();
        let c = Cluster::frontier(48);
        let scheme = Scheme::ZeroTopo { sec_degree: 2 };
        let at = |mb: usize, v: usize| {
            let pipe = PipeConfig { stages: 4, microbatches: mb, interleave: v };
            simulate_step_pipeline(&model, scheme, &c, &cfg, &pipe).unwrap().0
        };
        let m8 = at(8, 1);
        let m32 = at(32, 1);
        assert!(m32.bubble_fraction < m8.bubble_fraction, "{m32:?} vs {m8:?}");
        assert!(m8.ideal_bubble > 0.0 && m8.bubble_fraction >= m8.ideal_bubble - 1e-9);
        let inter = at(8, 2);
        assert!(inter.ideal_bubble < m8.ideal_bubble);
        // per-microbatch work is fixed, so more microbatches = longer step
        assert!(m32.step_s > m8.step_s);
    }

    #[test]
    fn pipeline_rejects_bad_stage_counts() {
        let model = TransformerSpec::neox10b();
        let cfg = SimConfig::default();
        let c = Cluster::frontier(6);
        let pipe = PipeConfig { stages: 4, microbatches: 8, interleave: 1 };
        assert!(simulate_step_pipeline(&model, Scheme::Zero3, &c, &cfg, &pipe).is_err());
    }

    #[test]
    fn pipeline_scaling_series_runs_cross_machine() {
        let model = TransformerSpec::neox10b();
        let cfg = SimConfig::default();
        let pipe = PipeConfig { stages: 2, microbatches: 8, interleave: 1 };
        for m in [MachineSpec::frontier_mi250x(), MachineSpec::dgx_a100()] {
            let pts = scaling_series_pipeline(
                &model,
                Scheme::ZeroTopo { sec_degree: 0 },
                &m,
                &[2, 4, 8],
                &cfg,
                &pipe,
            )
            .unwrap();
            assert_eq!(pts.len(), 3);
            assert!(pts.iter().all(|p| p.step_seconds.is_finite() && p.step_seconds > 0.0));
        }
    }

    #[test]
    fn pipeline_straggler_stage_stretches_step() {
        let model = TransformerSpec::neox20b();
        let cfg = SimConfig::default();
        let c = Cluster::frontier(48);
        let pipe = PipeConfig { stages: 4, microbatches: 8, interleave: 1 };
        let scheme = Scheme::ZeroTopo { sec_degree: 2 };
        let (base, _, _) = simulate_step_pipeline(&model, scheme, &c, &cfg, &pipe).unwrap();
        // rank 100 lives in stage 1 (ranks 96..192 at 48 nodes / P=4)
        let sc = Scenario { stragglers: vec![(100, 1.3)], ..Default::default() };
        let (slow, _, _) =
            simulate_step_pipeline_scenario(&model, scheme, &c, &cfg, &pipe, &sc).unwrap();
        assert!(slow.step_s > base.step_s * 1.01, "{} vs {}", slow.step_s, base.step_s);
    }

    #[test]
    fn schedule_attributes_stalls_to_link_classes() {
        // ZeRO-3 at depth 0 exposes its inter-node gathers: the compute
        // stream's stall time is attributed to the inter-node class
        let model = TransformerSpec::neox20b();
        let mut cfg = SimConfig::default();
        cfg.prefetch_depth = Depth::Bounded(0);
        let c = Cluster::frontier(48);
        let (b, sched) = simulate_step_schedule(&model, Scheme::Zero3, &c, &cfg);
        let stalls = sched.stall_by_class(0);
        let inter = stalls.get(&crate::topology::LinkClass::InterNode).copied().unwrap_or(0.0);
        // all gathers + the grad sync are inter-node and fully exposed
        let expect = b.prefetchable_s + b.grad_sync_s;
        assert!((inter - expect).abs() < 1e-6 * expect, "{inter} vs {expect}");
    }
}
