//! Deterministic parallel sweep driver (DESIGN.md §16).
//!
//! Every simulation in this crate is a pure function of its inputs, so a
//! sweep over N points is embarrassingly parallel — the only thing that
//! could break determinism is *result order*. [`parallel_map`] therefore
//! dispatches points to a fixed pool of scoped workers via an atomic
//! work index (no per-thread chunking, so stragglers can't skew the
//! split), tags every result with its input index, and reassembles the
//! output in input order. `threads == 1` degenerates to a plain serial
//! map over the same closure — byte-identical output by construction,
//! which is what the `plan`/`scale`/`scenario` determinism tests pin.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Map `f` over `items` on up to `threads` OS threads, returning results
/// in input order. `f` receives `(index, &item)` and must be pure with
/// respect to ordering: the call schedule across threads is
/// nondeterministic, but since each result is keyed by its index the
/// returned vector never is. A panic in any worker propagates.
pub fn parallel_map<T, U, F>(threads: usize, items: &[T], f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(usize, &T) -> U + Sync,
{
    let threads = threads.max(1).min(items.len().max(1));
    if threads <= 1 {
        return items.iter().enumerate().map(|(i, x)| f(i, x)).collect();
    }
    let next = AtomicUsize::new(0);
    let mut tagged: Vec<(usize, U)> = std::thread::scope(|scope| {
        let workers: Vec<_> = (0..threads)
            .map(|_| {
                scope.spawn(|| {
                    let mut local: Vec<(usize, U)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= items.len() {
                            break;
                        }
                        local.push((i, f(i, &items[i])));
                    }
                    local
                })
            })
            .collect();
        workers
            .into_iter()
            .flat_map(|w| w.join().expect("sweep worker panicked"))
            .collect()
    });
    tagged.sort_by_key(|&(i, _)| i);
    tagged.into_iter().map(|(_, u)| u).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_input_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = parallel_map(8, &items, |i, &x| {
            assert_eq!(i, x);
            x * 2
        });
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn serial_and_parallel_agree() {
        let items: Vec<f64> = (0..50).map(|i| i as f64 * 0.1).collect();
        let f = |_: usize, &x: &f64| (x.sin() * 1e6).to_bits();
        let serial = parallel_map(1, &items, f);
        for threads in [2, 4, 16] {
            assert_eq!(parallel_map(threads, &items, f), serial, "threads={threads}");
        }
    }

    #[test]
    fn empty_and_single_inputs() {
        let none: Vec<usize> = Vec::new();
        assert!(parallel_map(4, &none, |_, &x| x).is_empty());
        assert_eq!(parallel_map(4, &[7usize], |_, &x| x), vec![7]);
    }

    #[test]
    fn worker_panics_propagate() {
        let r = std::panic::catch_unwind(|| {
            parallel_map(2, &[1usize, 2, 3], |_, &x| {
                if x == 2 {
                    panic!("boom");
                }
                x
            })
        });
        assert!(r.is_err());
    }
}
