//! The feasibility-aware auto-planner (DESIGN.md §15): sweep the joint
//! schedule space (scheme × prefetch depth × layer blocks × P × M × V),
//! prune every point whose [`crate::memory::fit_report`] ledger exceeds
//! the device HBM **before** pricing anything, then price the survivors
//! through the exact simulation entry points the CLI uses
//! ([`super::simulate_step`] / [`simulate_step_pipeline`]) and rank them by
//! token-normalized throughput (TFLOPS/GCD — raw step seconds would
//! falsely favor small-`M` pipelines that run fewer tokens per step).
//!
//! The sweep is deliberately exhaustive over the user's bounds rather
//! than heuristic: at the default bounds it is a few hundred cheap
//! simulations, and every pruned point carries its full byte ledger so
//! "why not X?" is always answerable.

use crate::memory::{fit_report, FitConfig, MemoryFit};
use crate::model::TransformerSpec;
use crate::sched::pipeline::PipeConfig;
use crate::sched::plan::StepPlan;
use crate::sched::Depth;
use crate::sharding::Scheme;
use crate::topology::Cluster;

use super::par::parallel_map;
use super::{simulate_step_pipeline, SimConfig};

/// Bounds of the planner's sweep: the cartesian product of these axes is
/// enumerated (pipeline axes only combine with `stages > 1`; the
/// data-parallel axis `stages == 1` combines with `depths × blocks`).
#[derive(Debug, Clone)]
pub struct PlanSpace {
    /// Candidate schemes (expand `ZeroTopo { sec_degree: 0 }` yourself
    /// if you want one candidate per machine level — the CLI does).
    pub schemes: Vec<Scheme>,
    /// Prefetch depths to try (gather units / layer blocks ahead).
    pub depths: Vec<Depth>,
    /// Layer-block splits to try at `P = 1` (1 = monolithic).
    pub blocks: Vec<usize>,
    /// Pipeline stage counts to try (1 = pure data-parallel).
    pub stages: Vec<usize>,
    /// Microbatch counts `M` to try at `P > 1` (0 = derive from the
    /// global batch, exactly like `pipeline --microbatches 0`).
    pub microbatches: Vec<usize>,
    /// Interleave factors `V` to try at `P > 1`.
    pub interleaves: Vec<usize>,
}

impl PlanSpace {
    /// The default bounds for `schemes` on `model`: depths {1, 2, ∞},
    /// blocks {1, one-per-layer}, P {1, 2, 4, 8}, M {derived, 8, 16,
    /// 32}, V {1, 2}.
    pub fn default_for(schemes: Vec<Scheme>, model: &TransformerSpec) -> PlanSpace {
        PlanSpace {
            schemes,
            depths: vec![Depth::Bounded(1), Depth::Bounded(2), Depth::Infinite],
            blocks: vec![1, model.n_layers.max(1)],
            stages: vec![1, 2, 4, 8],
            microbatches: vec![0, 8, 16, 32],
            interleaves: vec![1, 2],
        }
    }
}

/// One feasible, priced point of the sweep.
#[derive(Debug, Clone)]
pub struct PlanPoint {
    /// The scheme at this point.
    pub scheme: Scheme,
    /// Prefetch depth used.
    pub depth: Depth,
    /// Layer blocks per microbatch gather (1 = monolithic; always 1
    /// when `stages > 1`).
    pub blocks: usize,
    /// Pipeline stages `P`.
    pub stages: usize,
    /// Resolved microbatches per step: `M` for pipelines, the derived
    /// grad-accum for `P = 1`.
    pub microbatches: usize,
    /// Interleave factor `V`.
    pub interleave: usize,
    /// The schedule-aware memory ledger that admitted the point.
    pub fit: MemoryFit,
    /// Simulated step seconds (event-clock makespan).
    pub step_s: f64,
    /// Global tokens processed per optimizer step.
    pub tokens_per_step: f64,
    /// Token-normalized model throughput per GCD — the ranking
    /// objective.
    pub tflops_per_gcd: f64,
}

impl PlanPoint {
    /// Global tokens per second per GCD (an alternative normalization;
    /// proportional to [`PlanPoint::tflops_per_gcd`] for a fixed model).
    pub fn tokens_per_s_per_gcd(&self, world: usize) -> f64 {
        self.tokens_per_step / self.step_s / world.max(1) as f64
    }
}

/// A point the planner refused to price: its ledger exceeds HBM. The
/// full [`MemoryFit`] is kept so the overage is provable per component.
#[derive(Debug, Clone)]
pub struct PrunedPoint {
    /// The scheme at this point.
    pub scheme: Scheme,
    /// Prefetch depth requested.
    pub depth: Depth,
    /// Layer blocks requested.
    pub blocks: usize,
    /// Pipeline stages `P`.
    pub stages: usize,
    /// Resolved microbatches per step.
    pub microbatches: usize,
    /// Interleave factor `V`.
    pub interleave: usize,
    /// The over-budget ledger (its `overage()` is `> 0` by
    /// construction).
    pub fit: MemoryFit,
}

/// Result of a [`plan_search`] sweep.
#[derive(Debug, Clone)]
pub struct PlanOutcome {
    /// Feasible points, best first (see [`plan_search`] for the exact
    /// tie-break order).
    pub ranked: Vec<PlanPoint>,
    /// Infeasible points, pruned before pricing, smallest overage first.
    pub pruned: Vec<PrunedPoint>,
    /// Combinations rejected as illegal before the memory ledger was
    /// even consulted (`P` not dividing the nodes, `M % P != 0` under
    /// interleaving, a scheme that cannot resolve on the stage group).
    pub skipped: usize,
    /// Capacity frontier: for each scheme, the largest model (total
    /// parameters Ψ) any swept schedule admits on this machine at this
    /// world size, per the ledger's closed form
    /// ([`MemoryFit::max_model_params`]).
    pub frontier: Vec<(Scheme, f64)>,
}

impl PlanOutcome {
    /// The fastest feasible point, if anything fit.
    pub fn winner(&self) -> Option<&PlanPoint> {
        self.ranked.first()
    }

    /// Points evaluated through the memory ledger (feasible + pruned).
    pub fn evaluated(&self) -> usize {
        self.ranked.len() + self.pruned.len()
    }

    /// When nothing fits: the pruned point closest to fitting, so the
    /// "nothing fits, smallest overage X GiB" message can name it.
    pub fn smallest_overage(&self) -> Option<&PrunedPoint> {
        self.pruned.first()
    }
}

fn depth_key(d: Depth) -> usize {
    match d {
        Depth::Bounded(x) => x,
        Depth::Infinite => usize::MAX,
    }
}

/// Sweep `space` for `(model, cluster)` under the simulation parameters
/// in `cfg` (`cfg.prefetch_depth` / `cfg.layer_blocks` are overridden
/// per point; everything else — micro-batch, global batch, MFU,
/// efficiency, quant block — is held fixed).
///
/// Every combination is first run through [`fit_report`]; only points
/// whose ledger fits the per-device HBM are simulated. Feasible points
/// are ranked by `tflops_per_gcd` descending, ties broken by: smaller
/// memory high-water mark, fewer pipeline stages, fewer layer blocks,
/// shallower prefetch depth, scheme name — i.e. among equally fast
/// points the planner prefers the simplest, most frugal schedule
/// (DESIGN.md §15).
pub fn plan_search(
    model: &TransformerSpec,
    cluster: &Cluster,
    cfg: &SimConfig,
    space: &PlanSpace,
) -> PlanOutcome {
    plan_search_threaded(model, cluster, cfg, space, 1)
}

/// A feasible combination awaiting pricing: everything the simulation
/// stage needs, captured during the (serial) enumeration pass.
struct Candidate {
    scheme: Scheme,
    depth: Depth,
    blocks: usize,
    stages: usize,
    m: usize,
    v: usize,
    fit: MemoryFit,
    dp: usize,
}

/// [`plan_search`] with the pricing stage fanned out over up to
/// `threads` worker threads (DESIGN.md §16). The sweep runs in three
/// phases: a serial enumeration pass (memory-ledger gating, skip
/// accounting, frontier bookkeeping — cheap), a serial plan-cache build
/// (one [`StepPlan`] per distinct `(scheme, blocks)` among the feasible
/// `P = 1` candidates; pricing is depth-independent, so each depth point
/// reuses the cached plan with only its `depth` field overridden —
/// bit-identical to rebuilding, gated by a test below), and a parallel
/// pricing pass over the candidates in deterministic enumeration order.
/// `threads == 1` is the plain serial sweep; any thread count produces
/// byte-identical outcomes.
pub fn plan_search_threaded(
    model: &TransformerSpec,
    cluster: &Cluster,
    cfg: &SimConfig,
    space: &PlanSpace,
    threads: usize,
) -> PlanOutcome {
    let world = cluster.world_size();
    let tokens_per_micro = (cfg.micro_batch * model.seq) as f64;
    let total_psi = model.n_params() as f64;

    let mut candidates: Vec<Candidate> = Vec::new();
    let mut pruned: Vec<PrunedPoint> = Vec::new();
    let mut skipped = 0usize;
    let mut frontier: Vec<(Scheme, f64)> = Vec::new();

    let mut note_frontier = |scheme: Scheme, cap: f64| match frontier
        .iter_mut()
        .find(|(s, _)| *s == scheme)
    {
        Some((_, best)) => *best = best.max(cap),
        None => frontier.push((scheme, cap)),
    };

    // phase 1: enumerate + gate on the memory ledger (serial — cheap)
    for &scheme in &space.schemes {
        for &p in &space.stages {
            let p = p.max(1);
            if cluster.nodes % p != 0 {
                // stages are whole node groups: every sub-combo is illegal
                skipped += if p == 1 {
                    space.depths.len() * space.blocks.len()
                } else {
                    space.depths.len() * space.microbatches.len() * space.interleaves.len()
                };
                continue;
            }
            let dp = world / p;
            let derived_m =
                (cfg.global_batch_tokens / (tokens_per_micro * dp as f64)).round().max(1.0)
                    as usize;

            // (blocks, m, v) sub-axes: DP sweeps blocks, pipelines sweep M × V
            let combos: Vec<(usize, usize, usize)> = if p == 1 {
                space.blocks.iter().map(|&b| (b.max(1), derived_m, 1)).collect()
            } else {
                let mut c = Vec::new();
                for &m in &space.microbatches {
                    for &v in &space.interleaves {
                        c.push((1, if m > 0 { m } else { derived_m }, v.max(1)));
                    }
                }
                c
            };

            for &depth in &space.depths {
                for &(blocks, m, v) in &combos {
                    if p > 1 && v > 1 && m % p != 0 {
                        // the interleaved schedule issues microbatches in
                        // groups of P
                        skipped += 1;
                        continue;
                    }
                    let fit_cfg = FitConfig {
                        micro_batch: cfg.micro_batch,
                        quant_block: cfg.quant_block,
                        prefetch_depth: depth,
                        layer_blocks: blocks,
                        stages: p,
                        microbatches: m,
                        interleave: v,
                    };
                    let fit = match fit_report(model, scheme, cluster, &fit_cfg) {
                        Ok(f) => f,
                        Err(_) => {
                            skipped += 1;
                            continue;
                        }
                    };
                    note_frontier(scheme, fit.max_model_params(total_psi));
                    if !fit.fits() {
                        pruned.push(PrunedPoint {
                            scheme,
                            depth,
                            blocks,
                            stages: p,
                            microbatches: m,
                            interleave: v,
                            fit,
                        });
                        continue;
                    }
                    candidates.push(Candidate {
                        scheme,
                        depth,
                        blocks,
                        stages: p,
                        m,
                        v,
                        fit,
                        dp,
                    });
                }
            }
        }
    }

    // phase 2: plan cache — one priced StepPlan per distinct (scheme,
    // blocks) among the P = 1 candidates. `charge_and_plan` only stores
    // the prefetch depth on the plan (every priced duration is
    // depth-independent), so the depth axis reuses the cached plan.
    let mut cache: Vec<(Scheme, usize, StepPlan)> = Vec::new();
    for c in candidates.iter().filter(|c| c.stages == 1) {
        if !cache.iter().any(|(s, b, _)| *s == c.scheme && *b == c.blocks) {
            let mut point_cfg = cfg.clone();
            point_cfg.prefetch_depth = c.depth;
            point_cfg.layer_blocks = c.blocks;
            let (plan, _, _) = super::charge_and_plan(model, c.scheme, cluster, &point_cfg);
            cache.push((c.scheme, c.blocks, plan));
        }
    }

    // phase 3: price the survivors — one pure simulation per candidate,
    // results in enumeration order regardless of the thread count
    let priced: Vec<Option<PlanPoint>> = parallel_map(threads, &candidates, |_, c| {
        let (step_s, tokens) = if c.stages == 1 {
            let (_, _, base) = cache
                .iter()
                .find(|(s, b, _)| *s == c.scheme && *b == c.blocks)
                .expect("every P=1 candidate has a cached plan");
            let mut plan = base.clone();
            plan.depth = c.depth;
            let step_s = plan.simulate().makespan();
            let tokens = plan.grad_accum as f64 * tokens_per_micro * world as f64;
            (step_s, tokens)
        } else {
            let mut point_cfg = cfg.clone();
            point_cfg.prefetch_depth = c.depth;
            point_cfg.layer_blocks = 1;
            let pipe = PipeConfig { stages: c.stages, microbatches: c.m, interleave: c.v };
            match simulate_step_pipeline(model, c.scheme, cluster, &point_cfg, &pipe) {
                Ok((b, _, _)) => (b.step_s, c.m as f64 * tokens_per_micro * c.dp as f64),
                Err(_) => return None,
            }
        };
        if !(step_s.is_finite() && step_s > 0.0) {
            // a degenerate simulation must not poison the ranking (PR-6
            // zero-division satellite, planner edition)
            return None;
        }
        let tflops_per_gcd = model.flops_per_token() * tokens / step_s / world as f64 / 1e12;
        Some(PlanPoint {
            scheme: c.scheme,
            depth: c.depth,
            blocks: c.blocks,
            stages: c.stages,
            microbatches: c.m,
            interleave: c.v,
            fit: c.fit.clone(),
            step_s,
            tokens_per_step: tokens,
            tflops_per_gcd,
        })
    });
    let mut ranked: Vec<PlanPoint> = Vec::with_capacity(candidates.len());
    for point in priced {
        match point {
            Some(pt) => ranked.push(pt),
            None => skipped += 1,
        }
    }

    ranked.sort_by(|a, b| {
        b.tflops_per_gcd
            .partial_cmp(&a.tflops_per_gcd)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| {
                a.fit
                    .total()
                    .partial_cmp(&b.fit.total())
                    .unwrap_or(std::cmp::Ordering::Equal)
            })
            .then_with(|| a.stages.cmp(&b.stages))
            .then_with(|| a.blocks.cmp(&b.blocks))
            .then_with(|| depth_key(a.depth).cmp(&depth_key(b.depth)))
            .then_with(|| a.scheme.name().cmp(&b.scheme.name()))
    });
    pruned.sort_by(|a, b| {
        a.fit
            .overage()
            .partial_cmp(&b.fit.overage())
            .unwrap_or(std::cmp::Ordering::Equal)
    });

    PlanOutcome { ranked, pruned, skipped, frontier }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> SimConfig {
        // tiny global batch: derived grad-accum stays small, the sweep
        // runs in milliseconds
        SimConfig { global_batch_tokens: (1u64 << 15) as f64, ..SimConfig::default() }
    }

    fn small_space(schemes: Vec<Scheme>) -> PlanSpace {
        PlanSpace {
            schemes,
            depths: vec![Depth::Bounded(1), Depth::Infinite],
            blocks: vec![1, 12],
            stages: vec![1, 2],
            microbatches: vec![0, 4],
            interleaves: vec![1, 2],
        }
    }

    #[test]
    fn winner_is_feasible_and_fastest() {
        let model = TransformerSpec::gpt125m();
        let cluster = Cluster::frontier(2);
        let schemes =
            vec![Scheme::Zero3, Scheme::ZeroPP, Scheme::ZeroTopo { sec_degree: 2 }];
        let out = plan_search(&model, &cluster, &small_cfg(), &small_space(schemes));
        let w = out.winner().expect("125m fits everywhere");
        assert!(w.fit.fits());
        for pt in &out.ranked {
            assert!(pt.fit.fits());
            assert!(pt.tflops_per_gcd <= w.tflops_per_gcd + 1e-12);
            assert!(pt.step_s.is_finite() && pt.step_s > 0.0);
        }
        for pr in &out.pruned {
            assert!(pr.fit.overage() > 0.0);
        }
        // every scheme earned a frontier entry
        assert_eq!(out.frontier.len(), 3);
        for &(_, cap) in &out.frontier {
            assert!(cap > 0.0);
        }
    }

    #[test]
    fn bookkeeping_covers_the_whole_grid() {
        let model = TransformerSpec::gpt125m();
        let cluster = Cluster::frontier(2);
        let space = small_space(vec![Scheme::Zero3]);
        let out = plan_search(&model, &cluster, &small_cfg(), &space);
        // P=1: depths×blocks; P=2: depths×M×V; all accounted for
        let grid = 2 * 2 + 2 * 2 * 2;
        assert_eq!(out.evaluated() + out.skipped, grid);
    }

    #[test]
    fn interleave_requires_divisible_microbatches() {
        let model = TransformerSpec::gpt125m();
        let cluster = Cluster::frontier(3);
        let space = PlanSpace {
            schemes: vec![Scheme::Zero3],
            depths: vec![Depth::Infinite],
            blocks: vec![1],
            stages: vec![3],
            microbatches: vec![5],
            interleaves: vec![2],
        };
        let out = plan_search(&model, &cluster, &small_cfg(), &space);
        assert_eq!(out.skipped, 1);
        assert_eq!(out.evaluated(), 0);
    }

    #[test]
    fn depth_override_matches_rebuild_bit_for_bit() {
        // the plan cache's contract: charge_and_plan only *stores* the
        // prefetch depth, so cached-plan-with-depth-overridden must equal
        // a from-scratch rebuild at that depth, monolithic and layered
        let model = TransformerSpec::gpt125m();
        let cluster = Cluster::frontier(2);
        let scheme = Scheme::ZeroTopo { sec_degree: 2 };
        for blocks in [1usize, 12] {
            let mut cfg = small_cfg();
            cfg.layer_blocks = blocks;
            cfg.prefetch_depth = Depth::Infinite;
            let (base, _, _) = super::super::charge_and_plan(&model, scheme, &cluster, &cfg);
            for depth in [Depth::Bounded(0), Depth::Bounded(1), Depth::Bounded(2)] {
                let mut cfg2 = cfg.clone();
                cfg2.prefetch_depth = depth;
                let (rebuilt, _, _) =
                    super::super::charge_and_plan(&model, scheme, &cluster, &cfg2);
                let mut overridden = base.clone();
                overridden.depth = depth;
                let a = rebuilt.simulate();
                let b = overridden.simulate();
                assert_eq!(
                    a.makespan().to_bits(),
                    b.makespan().to_bits(),
                    "blocks={blocks} depth={depth}"
                );
                for (x, y) in a.spans().iter().zip(b.spans()) {
                    assert_eq!((x.start, x.end), (y.start, y.end));
                }
            }
        }
    }

    #[test]
    fn threaded_sweep_is_deterministic() {
        let model = TransformerSpec::gpt125m();
        let cluster = Cluster::frontier(2);
        let schemes =
            vec![Scheme::Zero3, Scheme::ZeroPP, Scheme::ZeroTopo { sec_degree: 2 }];
        let space = small_space(schemes);
        let cfg = small_cfg();
        let serial = plan_search_threaded(&model, &cluster, &cfg, &space, 1);
        for threads in [2, 8] {
            let par = plan_search_threaded(&model, &cluster, &cfg, &space, threads);
            assert_eq!(serial.skipped, par.skipped, "threads={threads}");
            assert_eq!(serial.pruned.len(), par.pruned.len());
            assert_eq!(serial.ranked.len(), par.ranked.len());
            for (a, b) in serial.ranked.iter().zip(&par.ranked) {
                assert_eq!(a.scheme, b.scheme);
                assert_eq!(
                    (a.stages, a.microbatches, a.interleave, a.blocks),
                    (b.stages, b.microbatches, b.interleave, b.blocks)
                );
                assert_eq!(a.step_s.to_bits(), b.step_s.to_bits());
                assert_eq!(a.tflops_per_gcd.to_bits(), b.tflops_per_gcd.to_bits());
            }
            for ((s1, c1), (s2, c2)) in serial.frontier.iter().zip(&par.frontier) {
                assert_eq!(s1, s2);
                assert_eq!(c1.to_bits(), c2.to_bits());
            }
        }
    }

    #[test]
    fn stages_must_divide_nodes() {
        let model = TransformerSpec::gpt125m();
        let cluster = Cluster::frontier(2);
        let space = PlanSpace {
            schemes: vec![Scheme::Zero3],
            depths: vec![Depth::Infinite],
            blocks: vec![1],
            stages: vec![3],
            microbatches: vec![0, 4],
            interleaves: vec![1],
        };
        let out = plan_search(&model, &cluster, &small_cfg(), &space);
        assert_eq!(out.evaluated(), 0);
        assert_eq!(out.skipped, 2);
    }
}
