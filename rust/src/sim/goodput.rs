//! Goodput under failure (DESIGN.md §17): expected tokens/s **net of**
//! checkpoint saves, failure-lost work, and restart recovery.
//!
//! At production scale the dominant "scenario" is failure, not bubbles:
//! across thousands of GCDs the cluster-level MTBF shrinks until the
//! reliability tax — periodic checkpoint saves, work lost since the last
//! checkpoint, and restore/rematerialization on restart — rivals the
//! communication stalls the paper optimizes. This module prices that tax
//! on the same machine specs and cost model as everything else:
//!
//! * [`checkpoint_cost`] derives save/load time from the Tables V/VI
//!   sharded-state bytes per rank
//!   ([`state_bytes_per_rank`]: `(2+K)Ψ/W = 14Ψ/W`) against the
//!   machine's [`crate::topology::StorageSpec`] storage path, plus the
//!   secondary-partition **rematerialization** collective schemes with a
//!   secondary copy (ZeRO++ / ZeRO-topo) replay on restore (a §V.D-style
//!   full-world INT8 all-gather, priced through the α–β
//!   [`CostModel`]);
//! * [`goodput`] converts an MTBF + checkpoint interval into the
//!   first-order Young/Daly availability
//!   `A(τ) = (1 − δ/τ)(1 − (τ/2 + R)/M)` and the resulting goodput
//!   `A · tokens_per_step / step_s`;
//! * [`optimal_interval`] is the exact stationary point of that model,
//!   `τ* = sqrt(2δ(M − R))` (Daly's correction of Young's
//!   `sqrt(2δM)`, [`young_interval`]);
//! * [`price_timeline`] walks a run of `steps` optimizer steps under the
//!   deterministic fault injectors of [`crate::sched::scenario`]
//!   (node failure, spot preemption, elastic resize) and accounts every
//!   simulated second — useful work, saves, lost work, recovery,
//!   re-shard — composing with stragglers/jitter and pipeline schedules
//!   exactly like the scenario paths do today.
//!
//! All quantities are **simulated event-clock seconds**; nothing here
//! touches the wall-clock `SimProfile` time base (DESIGN.md §13/§16).
//! The failure-free path is pure post-processing over the existing step
//! clock: no `simulate_step` pin moves.
//!
//! # Example
//!
//! ```no_run
//! // (no_run: doctest binaries miss the libxla rpath in this offline env)
//! use zero_topo::model::TransformerSpec;
//! use zero_topo::sharding::Scheme;
//! use zero_topo::sim::goodput::{checkpoint_cost, goodput, optimal_interval};
//! use zero_topo::sim::{simulate_step, SimConfig};
//! use zero_topo::topology::Cluster;
//!
//! let model = TransformerSpec::neox20b();
//! let cluster = Cluster::frontier(48);
//! let cfg = SimConfig::default();
//! let scheme = Scheme::ZeroTopo { sec_degree: 2 };
//! let b = simulate_step(&model, scheme, &cluster, &cfg);
//! let ck = checkpoint_cost(&model, scheme, &cluster, &cfg).unwrap();
//! let tau = optimal_interval(21_600.0, &ck).unwrap();
//! let tokens = (b.grad_accum * model.seq * cluster.world_size()) as f64;
//! let g = goodput(b.step_s, tokens, &ck, 21_600.0, tau).unwrap();
//! assert!(g.goodput_tokens_per_s < tokens / b.step_s); // the tax is real
//! ```

use crate::comm::cost::CostModel;
use crate::comm::Wire;
use crate::memory::{OPTIM_BYTES, WEIGHT_BYTES};
use crate::model::TransformerSpec;
use crate::sched::pipeline::{PipeConfig, PipelineError};
use crate::sched::scenario::{FaultEvent, FaultKind, Scenario};
use crate::sharding::{Scheme, ShardingError, ShardingSpec};
use crate::topology::{Cluster, MachineSpec};

use super::{
    simulate_step, simulate_step_pipeline, simulate_step_pipeline_scenario,
    simulate_step_scenario, SimConfig,
};

/// Why a goodput query could not be evaluated. Degenerate inputs
/// (`mtbf = 0`, `interval >= mtbf`, a resize to a single-worker world)
/// are **diagnosed errors**, never NaN tables or panics.
#[derive(Debug, thiserror::Error)]
pub enum GoodputError {
    /// MTBF must be a positive finite number of seconds.
    #[error("MTBF must be positive and finite, got {0}s")]
    BadMtbf(f64),
    /// The checkpoint interval must be positive, finite, and strictly
    /// below the MTBF — at `interval >= mtbf` the Young/Daly first-order
    /// model has no useful-work regime.
    #[error("checkpoint interval {interval}s must be positive, finite, and below the MTBF {mtbf}s")]
    BadInterval {
        /// Requested checkpoint interval (seconds of useful work).
        interval: f64,
        /// Mean time between failures.
        mtbf: f64,
    },
    /// The interval must exceed the save cost, or the run checkpoints
    /// faster than it computes.
    #[error("checkpoint interval {interval}s does not exceed the save cost {save_s}s")]
    IntervalBelowSave {
        /// Requested checkpoint interval.
        interval: f64,
        /// Checkpoint save seconds.
        save_s: f64,
    },
    /// Expected lost work plus recovery fills the whole MTBF window:
    /// the machine fails faster than it can recover.
    #[error("recovery {restore_s}s plus expected lost work {lost_s}s does not fit the MTBF {mtbf}s")]
    RecoveryExceedsMtbf {
        /// Restore (load + rematerialization) seconds.
        restore_s: f64,
        /// Expected lost work (`interval / 2`) seconds.
        lost_s: f64,
        /// Mean time between failures.
        mtbf: f64,
    },
    /// The step clock fed to the model must be positive and finite.
    #[error("step time must be positive and finite, got {0}s")]
    BadStep(f64),
    /// Tokens per step must be positive and finite.
    #[error("tokens per step must be positive and finite, got {0}")]
    BadTokens(f64),
    /// An elastic resize must leave at least two workers to re-shard
    /// onto (`W = 1` has no peers to exchange shards with).
    #[error("elastic resize to {nodes} nodes leaves {workers} worker(s); need at least 2")]
    BadResize {
        /// Requested node count.
        nodes: usize,
        /// Resulting worker count.
        workers: usize,
    },
    /// A timeline walk needs at least one step and a positive
    /// checkpoint cadence.
    #[error("timeline needs steps >= 1 and interval_steps >= 1 (got steps={steps}, interval_steps={interval_steps})")]
    BadTimeline {
        /// Requested optimizer-step count.
        steps: usize,
        /// Requested checkpoint cadence in steps.
        interval_steps: usize,
    },
    /// The scheme could not resolve on the (possibly resized) cluster.
    #[error(transparent)]
    Sharding(#[from] ShardingError),
    /// The pipeline point could not be priced on the (possibly resized)
    /// cluster.
    #[error(transparent)]
    Pipeline(#[from] PipelineError),
}

/// Analytic checkpoint state bytes **per rank**: the Tables V/VI model
/// states that must be persisted — fp16 weights (2Ψ) and Adam optimizer
/// states (KΨ = 12Ψ) — deduplicated and rebalanced across the `W` ranks:
/// `(2 + 12)Ψ / W`. Gradients are transient (recomputed next step) and
/// secondary partitions are *derived* (rebuilt on restore, see
/// [`CheckpointCost::remat_s`]), so neither is persisted. The persisted
/// footprint is scheme-independent; schemes differ in what they must
/// rematerialize.
pub fn state_bytes_per_rank(psi: f64, world: usize) -> f64 {
    (WEIGHT_BYTES + OPTIM_BYTES) * psi / world.max(1) as f64
}

/// The priced checkpoint path for one `(model, scheme, cluster)` point:
/// save/load against the machine's node-shared storage path plus the
/// scheme's restore-time rematerialization collective. Produced by
/// [`checkpoint_cost`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CheckpointCost {
    /// Persisted bytes per rank ([`state_bytes_per_rank`]).
    pub bytes_per_rank: f64,
    /// Seconds to persist one checkpoint: storage latency + per-rank
    /// bytes through the node's write path, shared by its
    /// `workers_per_node` concurrent writers.
    pub save_s: f64,
    /// Seconds to read the persisted state back on restart (same NIC
    /// sharing, read bandwidth).
    pub load_s: f64,
    /// Seconds to rematerialize derived state after a load: schemes with
    /// a secondary weight partition (ZeRO++ / ZeRO-topo) replay a
    /// full-world INT8 all-gather of Ψ (the §V.D refresh) to rebuild
    /// their quantized copies; ZeRO-3 restores exactly what it persisted
    /// and pays 0.
    pub remat_s: f64,
}

impl CheckpointCost {
    /// Total restart seconds: load + rematerialization. This is the `R`
    /// of the Young/Daly model.
    pub fn restore_s(&self) -> f64 {
        self.load_s + self.remat_s
    }
}

/// Price the checkpoint save/load path for `(model, scheme, cluster)`
/// against the cluster machine's [`crate::topology::StorageSpec`]:
///
/// * per-rank persisted bytes from Tables V/VI
///   ([`state_bytes_per_rank`]);
/// * save = `latency + bytes_per_rank · workers_per_node / write_bw`
///   (every worker of a node funnels through the node's storage path
///   concurrently — the same NIC-sharing argument as DESIGN.md §4);
/// * load mirrors save at the read bandwidth;
/// * rematerialization for secondary-partition schemes through the same
///   α–β collective cost model (`cfg.efficiency` calibration included).
///
/// Fails with [`GoodputError::Sharding`] when the scheme does not
/// resolve on the cluster — before any pricing.
pub fn checkpoint_cost(
    model: &TransformerSpec,
    scheme: Scheme,
    cluster: &Cluster,
    cfg: &SimConfig,
) -> Result<CheckpointCost, GoodputError> {
    let spec = ShardingSpec::resolve(scheme, cluster)?;
    let world = cluster.world_size();
    let psi = model.n_params() as f64;
    let storage = cluster.spec.storage;
    let wpn = cluster.workers_per_node() as f64;
    let bytes_per_rank = state_bytes_per_rank(psi, world);
    let save_s = storage.latency + bytes_per_rank * wpn / storage.write_bandwidth;
    let load_s = storage.latency + bytes_per_rank * wpn / storage.read_bandwidth;
    let remat_s = if spec.secondary > 0 {
        let cost = CostModel::with_efficiency(cluster.clone(), cfg.efficiency);
        let group: Vec<usize> = (0..world).collect();
        let wire = Wire::Int8 { block: cfg.quant_block }.wire_bytes(model.n_params() as usize);
        cost.all_gather_time(&group, wire as u64)
    } else {
        0.0
    };
    Ok(CheckpointCost { bytes_per_rank, save_s, load_s, remat_s })
}

/// One evaluated goodput point: the Young/Daly availability at a given
/// MTBF and checkpoint interval, and the tokens/s it nets out to.
/// Produced by [`goodput`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GoodputReport {
    /// Mean time between failures (seconds).
    pub mtbf_s: f64,
    /// Checkpoint interval τ (seconds of useful work between saves).
    pub interval_s: f64,
    /// Event-clock seconds per optimizer step.
    pub step_s: f64,
    /// Tokens per optimizer step.
    pub tokens_per_step: f64,
    /// Checkpoint save seconds δ.
    pub save_s: f64,
    /// Restart seconds R (load + rematerialization).
    pub restore_s: f64,
    /// First-order availability `A(τ) = (1 − δ/τ)(1 − (τ/2 + R)/M)`:
    /// the fraction of wall time spent on useful forward progress.
    pub availability: f64,
    /// Failure-free throughput `tokens_per_step / step_s`.
    pub tokens_per_s: f64,
    /// Goodput: `availability × tokens_per_s`.
    pub goodput_tokens_per_s: f64,
}

/// Evaluate the Young/Daly goodput model at one `(mtbf, interval)`
/// point. The first factor of the availability charges the periodic
/// save tax (`δ/τ` of the time is spent writing checkpoints); the
/// second charges failures (each failure costs the expected `τ/2` of
/// lost work plus `R` of recovery, once per MTBF window).
///
/// Degenerate inputs return diagnosed [`GoodputError`]s: non-positive
/// or non-finite MTBF/interval/step/tokens, `interval >= mtbf`,
/// `interval <= save`, and recovery that cannot fit the MTBF window.
/// Valid inputs always yield a finite `availability` in `(0, 1]`.
pub fn goodput(
    step_s: f64,
    tokens_per_step: f64,
    ckpt: &CheckpointCost,
    mtbf_s: f64,
    interval_s: f64,
) -> Result<GoodputReport, GoodputError> {
    if !(step_s.is_finite() && step_s > 0.0) {
        return Err(GoodputError::BadStep(step_s));
    }
    if !(tokens_per_step.is_finite() && tokens_per_step > 0.0) {
        return Err(GoodputError::BadTokens(tokens_per_step));
    }
    if !(mtbf_s.is_finite() && mtbf_s > 0.0) {
        return Err(GoodputError::BadMtbf(mtbf_s));
    }
    if !(interval_s.is_finite() && interval_s > 0.0) || interval_s >= mtbf_s {
        return Err(GoodputError::BadInterval { interval: interval_s, mtbf: mtbf_s });
    }
    if interval_s <= ckpt.save_s {
        return Err(GoodputError::IntervalBelowSave {
            interval: interval_s,
            save_s: ckpt.save_s,
        });
    }
    let restore_s = ckpt.restore_s();
    let lost_s = interval_s / 2.0;
    if lost_s + restore_s >= mtbf_s {
        return Err(GoodputError::RecoveryExceedsMtbf { restore_s, lost_s, mtbf: mtbf_s });
    }
    let availability =
        (1.0 - ckpt.save_s / interval_s) * (1.0 - (interval_s / 2.0 + restore_s) / mtbf_s);
    let tokens_per_s = tokens_per_step / step_s;
    Ok(GoodputReport {
        mtbf_s,
        interval_s,
        step_s,
        tokens_per_step,
        save_s: ckpt.save_s,
        restore_s,
        availability,
        tokens_per_s,
        goodput_tokens_per_s: availability * tokens_per_s,
    })
}

/// The exact stationary point of the first-order availability model:
/// `τ* = sqrt(2δ(M − R))` — Daly's correction of Young's approximation.
/// Requires `M > R` (a machine that fails faster than it restores has
/// no optimum) and a positive save cost.
pub fn optimal_interval(mtbf_s: f64, ckpt: &CheckpointCost) -> Result<f64, GoodputError> {
    if !(mtbf_s.is_finite() && mtbf_s > 0.0) {
        return Err(GoodputError::BadMtbf(mtbf_s));
    }
    let restore_s = ckpt.restore_s();
    if restore_s >= mtbf_s {
        return Err(GoodputError::RecoveryExceedsMtbf {
            restore_s,
            lost_s: 0.0,
            mtbf: mtbf_s,
        });
    }
    Ok((2.0 * ckpt.save_s * (mtbf_s - restore_s)).sqrt())
}

/// Young's original closed-form approximation `sqrt(2δM)` — the
/// cross-check oracle [`optimal_interval`] must agree with to within 5%
/// whenever `R ≪ M` (the acceptance criterion; gated by
/// `tests/goodput.rs`).
pub fn young_interval(mtbf_s: f64, save_s: f64) -> f64 {
    (2.0 * save_s * mtbf_s).sqrt()
}

/// The geometric interval grid a sweep evaluates: `τ*` scaled by
/// `{1/8, 1/4, 1/2, 1, 2, 4, 8}`, centered so the optimum sits mid-grid
/// and the curvature on both sides is visible.
pub const SWEEP_FACTORS: [f64; 7] = [0.125, 0.25, 0.5, 1.0, 2.0, 4.0, 8.0];

/// Sweep the goodput model over an interval grid around the optimum
/// ([`SWEEP_FACTORS`] × `τ*`). Each point carries its own
/// `Result` — grid edges can legitimately be degenerate (e.g.
/// `8τ* >= M` at short MTBFs) and are reported as diagnosed errors
/// rather than dropped, so tables always show the full grid.
pub fn sweep(
    step_s: f64,
    tokens_per_step: f64,
    ckpt: &CheckpointCost,
    mtbf_s: f64,
) -> Result<Vec<(f64, Result<GoodputReport, GoodputError>)>, GoodputError> {
    let tau = optimal_interval(mtbf_s, ckpt)?;
    Ok(SWEEP_FACTORS
        .iter()
        .map(|f| {
            let interval = f * tau;
            (interval, goodput(step_s, tokens_per_step, ckpt, mtbf_s, interval))
        })
        .collect())
}

/// One priced fault in a [`TimelineReport`]: what the event cost in
/// overhead (recovery, re-shard, emergency save) and in lost work.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultImpact {
    /// Step index the fault struck at.
    pub at_step: usize,
    /// Human label (`node-failure`, `preemption(grace=30s)`,
    /// `resize(48->24 nodes)`).
    pub label: String,
    /// Non-productive seconds the event added (restore, re-shard,
    /// flush).
    pub overhead_s: f64,
    /// Useful seconds destroyed (work since the last checkpoint that
    /// must be re-run).
    pub lost_work_s: f64,
}

/// The fully-accounted timeline of a run under deterministic fault
/// injection: every simulated second is attributed to useful work,
/// checkpoint saves, lost work, or fault overhead, and the goodput is
/// the token total over the wall total. Produced by
/// [`price_timeline`].
#[derive(Debug, Clone, PartialEq)]
pub struct TimelineReport {
    /// Optimizer steps of useful forward progress.
    pub steps: usize,
    /// Checkpoint cadence in steps.
    pub interval_steps: usize,
    /// Step seconds at the end of the run (elastic resizes re-price it).
    pub final_step_s: f64,
    /// Node count at the end of the run.
    pub final_nodes: usize,
    /// Seconds of useful forward progress.
    pub useful_s: f64,
    /// Seconds spent writing periodic checkpoints.
    pub save_s_total: f64,
    /// Seconds of destroyed work re-run after failures.
    pub lost_work_s_total: f64,
    /// Seconds of fault overhead (restores, re-shards, flushes).
    pub overhead_s_total: f64,
    /// Total simulated wall seconds
    /// (`useful + saves + lost + overhead`).
    pub total_s: f64,
    /// Tokens of net forward progress.
    pub tokens: f64,
    /// `tokens / total_s`.
    pub goodput_tokens_per_s: f64,
    /// Failure-free throughput of the same run
    /// (`tokens / useful_s`), for the tax comparison.
    pub tokens_per_s: f64,
    /// Each fault's priced impact, in timeline order.
    pub events: Vec<FaultImpact>,
}

/// Price one `(step_s, tokens_per_step)` point for the timeline walk,
/// composing with the scenario's stragglers/jitter/imbalance and the
/// optional pipeline shape exactly like the `scenario` CLI does.
fn timeline_point(
    model: &TransformerSpec,
    scheme: Scheme,
    cluster: &Cluster,
    cfg: &SimConfig,
    scenario: &Scenario,
    pipe: Option<&PipeConfig>,
) -> Result<(f64, f64), GoodputError> {
    // resolve first: a diagnosed ShardingError, not simulate_step's panic
    ShardingSpec::resolve(scheme, cluster)?;
    let world = cluster.world_size();
    match pipe {
        None => {
            let b = if scenario.is_trivial() {
                simulate_step(model, scheme, cluster, cfg)
            } else {
                simulate_step_scenario(model, scheme, cluster, cfg, scenario).0
            };
            let tokens = (b.grad_accum * cfg.micro_batch * model.seq * world) as f64;
            Ok((b.step_s, tokens))
        }
        Some(p) => {
            let b = if scenario.is_trivial() {
                simulate_step_pipeline(model, scheme, cluster, cfg, p)?.0
            } else {
                simulate_step_pipeline_scenario(model, scheme, cluster, cfg, p, scenario)?.0
            };
            let dp = world / b.stages;
            let tokens = (b.microbatches * cfg.micro_batch * model.seq * dp) as f64;
            Ok((b.step_s, tokens))
        }
    }
}

/// Walk `steps` optimizer steps with a checkpoint every
/// `interval_steps` steps, applying the scenario's deterministic
/// [`FaultEvent`]s as they strike (a fault at step `i` fires before
/// step `i` executes; events past the end of the run are ignored):
///
/// * **node failure** — work since the last checkpoint is destroyed
///   and re-run; the run pays one restore (load + remat);
/// * **preemption** — with `grace_s >= save_s` the run flushes a
///   checkpoint inside the grace window (no lost work, pays
///   `save + restore`); a shorter grace degenerates to a failure;
/// * **elastic resize** — no work is lost; the run pays a re-shard
///   (all-to-all of the per-rank state bytes over the **new** world,
///   priced through the collective cost model) and subsequent steps
///   re-price on the resized cluster (re-resolving the scheme; a
///   resize to fewer than 2 workers is a diagnosed error).
///
/// Returns the conserving ledger: `total_s` is exactly
/// `useful + saves + lost + overhead`, and the goodput is
/// `tokens / total_s`.
#[allow(clippy::too_many_arguments)]
pub fn price_timeline(
    model: &TransformerSpec,
    scheme: Scheme,
    machine: &MachineSpec,
    nodes: usize,
    cfg: &SimConfig,
    scenario: &Scenario,
    pipe: Option<&PipeConfig>,
    steps: usize,
    interval_steps: usize,
) -> Result<TimelineReport, GoodputError> {
    if steps == 0 || interval_steps == 0 {
        return Err(GoodputError::BadTimeline { steps, interval_steps });
    }
    let mut cluster = Cluster::new(machine.clone(), nodes);
    let (mut step_s, mut tokens_per_step) =
        timeline_point(model, scheme, &cluster, cfg, scenario, pipe)?;
    let mut ckpt = checkpoint_cost(model, scheme, &cluster, cfg)?;

    let mut faults: Vec<&FaultEvent> =
        scenario.faults.iter().filter(|f| f.at_step < steps).collect();
    faults.sort_by_key(|f| f.at_step);

    let mut events = Vec::new();
    let (mut useful_s, mut saves_s, mut lost_s, mut overhead_s, mut tokens) =
        (0.0f64, 0.0f64, 0.0f64, 0.0f64, 0.0f64);
    let mut since_ckpt = 0usize; // steps of work not yet persisted
    let mut fi = 0usize;

    for i in 0..steps {
        while fi < faults.len() && faults[fi].at_step == i {
            let f = faults[fi];
            fi += 1;
            match f.kind {
                FaultKind::NodeFailure => {
                    let lost = since_ckpt as f64 * step_s;
                    lost_s += lost;
                    overhead_s += ckpt.restore_s();
                    since_ckpt = 0;
                    events.push(FaultImpact {
                        at_step: i,
                        label: "node-failure".into(),
                        overhead_s: ckpt.restore_s(),
                        lost_work_s: lost,
                    });
                }
                FaultKind::Preemption { grace_s } => {
                    let (over, lost) = if grace_s >= ckpt.save_s {
                        // the grace window fits a flush: nothing is lost
                        (ckpt.save_s + ckpt.restore_s(), 0.0)
                    } else {
                        (ckpt.restore_s(), since_ckpt as f64 * step_s)
                    };
                    lost_s += lost;
                    overhead_s += over;
                    since_ckpt = 0;
                    events.push(FaultImpact {
                        at_step: i,
                        label: format!("preemption(grace={grace_s}s)"),
                        overhead_s: over,
                        lost_work_s: lost,
                    });
                }
                FaultKind::Resize { new_nodes } => {
                    let workers = new_nodes * machine.workers_per_node;
                    if workers < 2 {
                        return Err(GoodputError::BadResize { nodes: new_nodes, workers });
                    }
                    let old_nodes = cluster.nodes;
                    cluster = Cluster::new(machine.clone(), new_nodes);
                    // re-shard: every rank exchanges its state shard over
                    // the new world (one all-to-all of the per-rank bytes)
                    let cost = CostModel::with_efficiency(cluster.clone(), cfg.efficiency);
                    let group: Vec<usize> = (0..workers).collect();
                    let bytes = state_bytes_per_rank(model.n_params() as f64, workers);
                    let reshard = cost.all_to_all_time(&group, bytes as u64);
                    overhead_s += reshard;
                    (step_s, tokens_per_step) =
                        timeline_point(model, scheme, &cluster, cfg, scenario, pipe)?;
                    ckpt = checkpoint_cost(model, scheme, &cluster, cfg)?;
                    events.push(FaultImpact {
                        at_step: i,
                        label: format!("resize({old_nodes}->{new_nodes} nodes)"),
                        overhead_s: reshard,
                        lost_work_s: 0.0,
                    });
                }
            }
        }
        useful_s += step_s;
        tokens += tokens_per_step;
        since_ckpt += 1;
        if since_ckpt == interval_steps {
            saves_s += ckpt.save_s;
            since_ckpt = 0;
        }
    }

    let total_s = useful_s + saves_s + lost_s + overhead_s;
    Ok(TimelineReport {
        steps,
        interval_steps,
        final_step_s: step_s,
        final_nodes: cluster.nodes,
        useful_s,
        save_s_total: saves_s,
        lost_work_s_total: lost_s,
        overhead_s_total: overhead_s,
        total_s,
        tokens,
        goodput_tokens_per_s: tokens / total_s,
        tokens_per_s: tokens / useful_s,
        events,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ck(save_s: f64, load_s: f64, remat_s: f64) -> CheckpointCost {
        CheckpointCost { bytes_per_rank: 1e9, save_s, load_s, remat_s }
    }

    #[test]
    fn degenerate_inputs_are_diagnosed_not_nan() {
        let c = ck(1.0, 0.5, 0.0);
        assert!(matches!(goodput(1.0, 1e6, &c, 0.0, 10.0), Err(GoodputError::BadMtbf(_))));
        assert!(matches!(
            goodput(1.0, 1e6, &c, f64::NAN, 10.0),
            Err(GoodputError::BadMtbf(_))
        ));
        // interval >= mtbf
        assert!(matches!(
            goodput(1.0, 1e6, &c, 100.0, 100.0),
            Err(GoodputError::BadInterval { .. })
        ));
        assert!(matches!(
            goodput(1.0, 1e6, &c, 100.0, -5.0),
            Err(GoodputError::BadInterval { .. })
        ));
        // interval <= save
        assert!(matches!(
            goodput(1.0, 1e6, &c, 100.0, 0.5),
            Err(GoodputError::IntervalBelowSave { .. })
        ));
        // degenerate step/tokens
        assert!(matches!(goodput(0.0, 1e6, &c, 100.0, 10.0), Err(GoodputError::BadStep(_))));
        assert!(matches!(goodput(1.0, 0.0, &c, 100.0, 10.0), Err(GoodputError::BadTokens(_))));
        // recovery cannot fit the window
        let slow = ck(1.0, 80.0, 30.0);
        assert!(matches!(
            goodput(1.0, 1e6, &slow, 100.0, 50.0),
            Err(GoodputError::RecoveryExceedsMtbf { .. })
        ));
        assert!(matches!(optimal_interval(50.0, &slow), Err(GoodputError::RecoveryExceedsMtbf { .. })));
        assert!(matches!(optimal_interval(f64::INFINITY, &c), Err(GoodputError::BadMtbf(_))));
    }

    #[test]
    fn availability_is_finite_and_bounded() {
        let c = ck(2.0, 1.0, 1.0);
        let g = goodput(1.0, 1e6, &c, 10_000.0, 200.0).unwrap();
        assert!(g.availability > 0.0 && g.availability < 1.0);
        assert!(g.goodput_tokens_per_s < g.tokens_per_s);
        assert!(g.goodput_tokens_per_s.is_finite());
        // availability -> 1 as the machine becomes reliable and saves cheap
        let cheap = ck(1e-6, 1e-6, 0.0);
        let g2 = goodput(1.0, 1e6, &cheap, 1e12, 1.0).unwrap();
        assert!(g2.availability > 0.999999);
    }

    #[test]
    fn optimal_interval_is_the_argmax_of_the_model() {
        // dense numeric argmax must agree with the closed form within 5%
        let c = ck(30.0, 60.0, 40.0);
        let mtbf = 86_400.0;
        let tau = optimal_interval(mtbf, &c).unwrap();
        let (mut best_tau, mut best) = (0.0, 0.0);
        let mut t = c.save_s * 1.01;
        while t < mtbf * 0.5 {
            if let Ok(g) = goodput(1.0, 1e6, &c, mtbf, t) {
                if g.goodput_tokens_per_s > best {
                    best = g.goodput_tokens_per_s;
                    best_tau = t;
                }
            }
            t *= 1.001;
        }
        assert!((best_tau - tau).abs() / tau < 0.05, "argmax {best_tau} vs closed form {tau}");
        // exact stationary point: tau^2 = 2*save*(M - R)
        assert!((tau * tau - 2.0 * c.save_s * (mtbf - c.restore_s())).abs() < 1e-6);
    }

    #[test]
    fn daly_matches_young_when_restart_is_small() {
        let c = ck(10.0, 1.0, 0.0);
        let mtbf = 100_000.0;
        let tau = optimal_interval(mtbf, &c).unwrap();
        let young = young_interval(mtbf, c.save_s);
        assert!((tau - young).abs() / young < 0.05, "{tau} vs {young}");
    }

    #[test]
    fn sweep_reports_the_full_grid() {
        let c = ck(5.0, 2.0, 1.0);
        let grid = sweep(1.0, 1e6, &c, 3600.0).unwrap();
        assert_eq!(grid.len(), SWEEP_FACTORS.len());
        // mid-grid (the optimum) must evaluate; it beats its neighbors
        let at = |i: usize| grid[i].1.as_ref().unwrap().goodput_tokens_per_s;
        assert!(at(3) >= at(2) && at(3) >= at(4));
        // the grid is geometric around tau*
        let tau = optimal_interval(3600.0, &c).unwrap();
        assert!((grid[3].0 - tau).abs() < 1e-9);
        assert!((grid[4].0 - 2.0 * tau).abs() < 1e-9);
    }

    #[test]
    fn timeline_ledger_conserves() {
        use crate::sched::scenario::{FaultEvent, FaultKind};
        let model = TransformerSpec::gpt125m();
        let machine = MachineSpec::frontier_mi250x();
        let cfg = SimConfig::default();
        let scheme = Scheme::ZeroTopo { sec_degree: 2 };
        let sc = Scenario {
            faults: vec![
                FaultEvent { at_step: 3, kind: FaultKind::NodeFailure },
                FaultEvent { at_step: 7, kind: FaultKind::Preemption { grace_s: 1e9 } },
            ],
            ..Scenario::default()
        };
        let t =
            price_timeline(&model, scheme, &machine, 2, &cfg, &sc, None, 10, 4).unwrap();
        assert_eq!(t.steps, 10);
        assert_eq!(t.events.len(), 2);
        let sum = t.useful_s + t.save_s_total + t.lost_work_s_total + t.overhead_s_total;
        assert!((t.total_s - sum).abs() < 1e-9);
        // failure at step 3 with cadence 4: 3 unsaved steps destroyed
        assert!((t.events[0].lost_work_s - 3.0 * t.final_step_s).abs() < 1e-9);
        // long-grace preemption flushes: no lost work, pays save+restore
        assert_eq!(t.events[1].lost_work_s, 0.0);
        assert!(t.events[1].overhead_s > t.events[0].overhead_s);
        assert!(t.goodput_tokens_per_s < t.tokens_per_s);
    }

    #[test]
    fn failure_free_timeline_is_pure_step_clock_plus_saves() {
        let model = TransformerSpec::gpt125m();
        let machine = MachineSpec::frontier_mi250x();
        let cfg = SimConfig::default();
        let scheme = Scheme::Zero3;
        let sc = Scenario::default();
        let t = price_timeline(&model, scheme, &machine, 1, &cfg, &sc, None, 8, 4).unwrap();
        let b = simulate_step(&model, scheme, &Cluster::new(machine.clone(), 1), &cfg);
        assert_eq!(t.final_step_s.to_bits(), b.step_s.to_bits(), "step clock must not move");
        assert!((t.useful_s - 8.0 * b.step_s).abs() < 1e-9);
        let ck = checkpoint_cost(&model, scheme, &Cluster::new(machine, 1), &cfg).unwrap();
        assert!((t.save_s_total - 2.0 * ck.save_s).abs() < 1e-12);
        assert_eq!(t.lost_work_s_total, 0.0);
        assert_eq!(t.overhead_s_total, 0.0);
    }

    #[test]
    fn resize_reprices_and_rejects_single_worker_worlds() {
        use crate::sched::scenario::{FaultEvent, FaultKind};
        let model = TransformerSpec::gpt125m();
        let machine = MachineSpec::frontier_mi250x();
        let cfg = SimConfig::default();
        let scheme = Scheme::Zero3;
        let mut sc = Scenario {
            faults: vec![FaultEvent { at_step: 2, kind: FaultKind::Resize { new_nodes: 1 } }],
            ..Scenario::default()
        };
        let t = price_timeline(&model, scheme, &machine, 2, &cfg, &sc, None, 4, 2).unwrap();
        assert_eq!(t.final_nodes, 1);
        assert!(t.events[0].label.contains("2->1"));
        assert!(t.events[0].overhead_s > 0.0);
        assert_eq!(t.events[0].lost_work_s, 0.0);
        // shrinking the world slows the step (fewer workers, same batch)
        // and the re-priced clock is the 1-node clock exactly
        let b1 = simulate_step(&model, scheme, &Cluster::new(machine.clone(), 1), &cfg);
        assert_eq!(t.final_step_s.to_bits(), b1.step_s.to_bits());
        // resize to a single-worker world is a diagnosed error
        sc.faults = vec![FaultEvent { at_step: 2, kind: FaultKind::Resize { new_nodes: 0 } }];
        assert!(matches!(
            price_timeline(&model, scheme, &machine, 2, &cfg, &sc, None, 4, 2),
            Err(GoodputError::BadResize { .. })
        ));
    }

    #[test]
    fn timeline_rejects_empty_runs() {
        let model = TransformerSpec::gpt125m();
        let machine = MachineSpec::frontier_mi250x();
        let cfg = SimConfig::default();
        let sc = Scenario::default();
        assert!(matches!(
            price_timeline(&model, Scheme::Zero3, &machine, 1, &cfg, &sc, None, 0, 4),
            Err(GoodputError::BadTimeline { .. })
        ));
        assert!(matches!(
            price_timeline(&model, Scheme::Zero3, &machine, 1, &cfg, &sc, None, 4, 0),
            Err(GoodputError::BadTimeline { .. })
        ));
    }

    #[test]
    fn secondary_schemes_pay_remat_zero3_does_not() {
        let model = TransformerSpec::neox20b();
        let cluster = Cluster::frontier(48);
        let cfg = SimConfig::default();
        let z3 = checkpoint_cost(&model, Scheme::Zero3, &cluster, &cfg).unwrap();
        let zpp = checkpoint_cost(&model, Scheme::ZeroPP, &cluster, &cfg).unwrap();
        let topo =
            checkpoint_cost(&model, Scheme::ZeroTopo { sec_degree: 2 }, &cluster, &cfg).unwrap();
        assert_eq!(z3.remat_s, 0.0);
        assert!(zpp.remat_s > 0.0 && topo.remat_s > 0.0);
        // persisted bytes are dedup-and-rebalance: scheme-independent
        assert_eq!(z3.bytes_per_rank.to_bits(), topo.bytes_per_rank.to_bits());
        assert_eq!(z3.save_s.to_bits(), topo.save_s.to_bits());
        // restore therefore ranks ZeRO-3 cheapest
        assert!(z3.restore_s() < topo.restore_s());
    }
}
