//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `manifest.json`) produced by `python/compile/aot.py` and executes them
//! on the CPU PJRT client.
//!
//! This is the ONLY place the coordinator touches compiled compute.
//! Python never runs at training time: the artifacts are a build product
//! (`make artifacts`), and HLO *text* is the interchange format (see
//! aot.py's docstring for why not serialized protos).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// One parameter tensor's slot in the flat parameter vector.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
}

/// Manifest entry for one lowered model configuration.
#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub vocab: usize,
    pub seq: usize,
    pub mbs: usize,
    pub n_params: usize,
    pub flops_per_token: f64,
    pub params: Vec<ParamEntry>,
    pub artifacts: BTreeMap<String, String>,
}

/// Parsed `manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub models: BTreeMap<String, ModelManifest>,
    pub quant_n: usize,
    pub quant_block: usize,
    pub quant_artifacts: BTreeMap<String, String>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let mut models = BTreeMap::new();
        let jmodels = j.get("models").and_then(|m| m.as_obj()).context("manifest.models")?;
        for (name, jm) in jmodels {
            let geti = |k: &str| -> Result<usize> {
                jm.get(k).and_then(|v| v.as_usize()).with_context(|| format!("models.{name}.{k}"))
            };
            let mut params = Vec::new();
            for p in jm.get("params").and_then(|v| v.as_arr()).context("params")? {
                params.push(ParamEntry {
                    name: p.get("name").and_then(|v| v.as_str()).context("param name")?.into(),
                    shape: p
                        .get("shape")
                        .and_then(|v| v.as_arr())
                        .context("param shape")?
                        .iter()
                        .map(|d| d.as_usize().context("dim"))
                        .collect::<Result<_>>()?,
                    offset: p.get("offset").and_then(|v| v.as_usize()).context("offset")?,
                    size: p.get("size").and_then(|v| v.as_usize()).context("size")?,
                });
            }
            let mut artifacts = BTreeMap::new();
            for (k, v) in jm.get("artifacts").and_then(|v| v.as_obj()).context("artifacts")? {
                artifacts.insert(k.clone(), v.as_str().context("artifact path")?.to_string());
            }
            models.insert(
                name.clone(),
                ModelManifest {
                    name: name.clone(),
                    d_model: geti("d_model")?,
                    n_layers: geti("n_layers")?,
                    n_heads: geti("n_heads")?,
                    vocab: geti("vocab")?,
                    seq: geti("seq")?,
                    mbs: geti("mbs")?,
                    n_params: geti("n_params")?,
                    flops_per_token: jm
                        .get("flops_per_token")
                        .and_then(|v| v.as_f64())
                        .context("flops_per_token")?,
                    params,
                    artifacts,
                },
            );
        }
        let quant = j.get("quant").context("manifest.quant")?;
        let mut quant_artifacts = BTreeMap::new();
        for (k, v) in quant.get("artifacts").and_then(|v| v.as_obj()).context("quant artifacts")? {
            quant_artifacts.insert(k.clone(), v.as_str().context("path")?.to_string());
        }
        Ok(Manifest {
            models,
            quant_n: quant.get("n").and_then(|v| v.as_usize()).context("quant.n")?,
            quant_block: quant.get("block").and_then(|v| v.as_usize()).context("quant.block")?,
            quant_artifacts,
        })
    }

    /// Validate internal consistency: param table must tile [0, n_params).
    pub fn validate(&self) -> Result<()> {
        for (name, m) in &self.models {
            let mut off = 0;
            for p in &m.params {
                if p.offset != off {
                    bail!("{name}: param {} offset {} != {}", p.name, p.offset, off);
                }
                let numel: usize = p.shape.iter().product();
                if numel != p.size {
                    bail!("{name}: param {} size {} != shape prod {}", p.name, p.size, numel);
                }
                off += p.size;
            }
            if off != m.n_params {
                bail!("{name}: params cover {off} != n_params {}", m.n_params);
            }
        }
        Ok(())
    }
}

/// The PJRT runtime: one CPU client + the artifact directory.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
}

impl Runtime {
    /// Load `manifest.json` from `dir` and start a CPU PJRT client.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json")).with_context(|| {
            format!("reading {}/manifest.json (run `make artifacts`)", dir.display())
        })?;
        let manifest = Manifest::parse(&text)?;
        manifest.validate()?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime { client, dir, manifest })
    }

    /// Default artifact directory: `$ZERO_TOPO_ARTIFACTS` or `artifacts/`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("ZERO_TOPO_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    fn compile_file(&self, fname: &str) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.dir.join(fname);
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client.compile(&comp).map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))
    }

    /// Compile the three entry points of a model config.
    pub fn model(&self, name: &str) -> Result<ModelRunner> {
        let m = self
            .manifest
            .models
            .get(name)
            .with_context(|| {
                format!(
                    "model '{name}' not in manifest (have: {:?})",
                    self.manifest.models.keys().collect::<Vec<_>>()
                )
            })?
            .clone();
        let art = |k: &str| -> Result<&str> {
            m.artifacts.get(k).map(|s| s.as_str()).with_context(|| format!("artifact {k}"))
        };
        Ok(ModelRunner {
            init: self.compile_file(art("init")?)?,
            train: self.compile_file(art("train_step")?)?,
            eval: self.compile_file(art("eval_loss")?)?,
            manifest: m,
        })
    }

    /// Compile a standalone quant artifact by manifest key
    /// (e.g. "roundtrip_int8") — used by the L1↔L3 cross-check tests.
    pub fn quant_executable(&self, key: &str) -> Result<xla::PjRtLoadedExecutable> {
        let f = self
            .manifest
            .quant_artifacts
            .get(key)
            .with_context(|| format!("quant artifact {key}"))?
            .clone();
        self.compile_file(&f)
    }
}

/// Compiled executables for one model config.
pub struct ModelRunner {
    init: xla::PjRtLoadedExecutable,
    train: xla::PjRtLoadedExecutable,
    eval: xla::PjRtLoadedExecutable,
    pub manifest: ModelManifest,
}

fn run1(exe: &xla::PjRtLoadedExecutable, args: &[xla::Literal]) -> Result<xla::Literal> {
    let out = exe.execute::<xla::Literal>(args).map_err(|e| anyhow!("execute: {e:?}"))?;
    out[0][0].to_literal_sync().map_err(|e| anyhow!("to_literal: {e:?}"))
}

impl ModelRunner {
    fn tokens_literal(&self, tokens: &[i32]) -> Result<xla::Literal> {
        let m = &self.manifest;
        if tokens.len() != m.mbs * m.seq {
            bail!("tokens len {} != mbs*seq {}", tokens.len(), m.mbs * m.seq);
        }
        xla::Literal::vec1(tokens)
            .reshape(&[m.mbs as i64, m.seq as i64])
            .map_err(|e| anyhow!("reshape: {e:?}"))
    }

    /// Run the init artifact: standard GPT-NeoX init for `seed`.
    pub fn init_params(&self, seed: i32) -> Result<Vec<f32>> {
        let out = run1(&self.init, &[xla::Literal::scalar(seed)])?;
        let flat = out.to_tuple1().map_err(|e| anyhow!("tuple: {e:?}"))?;
        let v = flat.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        if v.len() != self.manifest.n_params {
            bail!("init returned {} params, manifest says {}", v.len(), self.manifest.n_params);
        }
        Ok(v)
    }

    /// One microbatch fwd+bwd: returns (loss, flat gradient).
    pub fn train_step(
        &self,
        flat: &[f32],
        tokens: &[i32],
        targets: &[i32],
    ) -> Result<(f32, Vec<f32>)> {
        if flat.len() != self.manifest.n_params {
            bail!("flat len {} != n_params {}", flat.len(), self.manifest.n_params);
        }
        let args = [
            xla::Literal::vec1(flat),
            self.tokens_literal(tokens)?,
            self.tokens_literal(targets)?,
        ];
        let out = run1(&self.train, &args)?;
        let (loss, grads) = out.to_tuple2().map_err(|e| anyhow!("tuple2: {e:?}"))?;
        let loss = loss.to_vec::<f32>().map_err(|e| anyhow!("loss: {e:?}"))?[0];
        let grads = grads.to_vec::<f32>().map_err(|e| anyhow!("grads: {e:?}"))?;
        Ok((loss, grads))
    }

    /// Forward-only loss.
    pub fn eval_loss(&self, flat: &[f32], tokens: &[i32], targets: &[i32]) -> Result<f32> {
        let args = [
            xla::Literal::vec1(flat),
            self.tokens_literal(tokens)?,
            self.tokens_literal(targets)?,
        ];
        let out = run1(&self.eval, &args)?;
        let loss = out.to_tuple1().map_err(|e| anyhow!("tuple: {e:?}"))?;
        Ok(loss.to_vec::<f32>().map_err(|e| anyhow!("loss: {e:?}"))?[0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MANIFEST: &str = r#"{
      "quant": {"n": 1024, "block": 256, "artifacts": {"quant_int8": "q8.hlo.txt"}},
      "attention": {"heads": 4, "seq": 128, "head_dim": 32, "artifacts": {}},
      "models": {
        "t": {
          "name": "t", "d_model": 8, "n_layers": 1, "n_heads": 2, "vocab": 16,
          "seq": 4, "mbs": 1, "n_params": 20, "tied_lm_head": true,
          "flops_per_token": 100.0, "flops_per_token_fwd": 33.3,
          "params": [
            {"name": "a", "shape": [2, 5], "offset": 0, "size": 10},
            {"name": "b", "shape": [10], "offset": 10, "size": 10}
          ],
          "artifacts": {"init": "i.hlo.txt", "train_step": "t.hlo.txt", "eval_loss": "e.hlo.txt"}
        }
      }
    }"#;

    #[test]
    fn parses_manifest() {
        let m = Manifest::parse(MANIFEST).unwrap();
        assert_eq!(m.quant_n, 1024);
        assert_eq!(m.quant_block, 256);
        let t = &m.models["t"];
        assert_eq!(t.n_params, 20);
        assert_eq!(t.params.len(), 2);
        assert_eq!(t.params[1].offset, 10);
        m.validate().unwrap();
    }

    #[test]
    fn validate_catches_gaps() {
        let bad = MANIFEST.replace("\"offset\": 10", "\"offset\": 11");
        let m = Manifest::parse(&bad).unwrap();
        assert!(m.validate().is_err());
    }

    #[test]
    fn validate_catches_shape_mismatch() {
        let bad = MANIFEST.replace("[2, 5]", "[2, 6]");
        let m = Manifest::parse(&bad).unwrap();
        assert!(m.validate().is_err());
    }

    #[test]
    fn missing_model_is_reported() {
        let m = Manifest::parse(MANIFEST).unwrap();
        assert!(m.models.get("nope").is_none());
    }
}
