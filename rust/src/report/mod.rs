//! Report rendering: turns measured/simulated results into the paper's
//! tables and figure-series, as aligned text and CSV.

use std::collections::BTreeMap;

use crate::metrics::sensitivity::SensitivityReport;
use crate::metrics::{StepUtilization, Throughput};
use crate::sched::critical::{Category, Decomposition};
use crate::sched::pipeline::PipelinePlan;
use crate::sched::Schedule;
use crate::sharding::Scheme;
use crate::topology::{LinkClass, MachineSpec};
use crate::util::table::{fnum, Table};

/// One scheme's scaling series (a line of Fig 7/8).
#[derive(Debug, Clone)]
pub struct ScalingSeries {
    pub scheme: Scheme,
    pub points: Vec<Throughput>,
}

/// Render a Fig 7/8-style comparison: TFLOPS/GPU per scale per scheme,
/// plus scaling efficiency and the headline speedup ratios.
pub fn render_scaling_figure(title: &str, series: &[ScalingSeries]) -> String {
    assert!(!series.is_empty());
    let mut header = vec!["GCDs".to_string()];
    for s in series {
        header.push(format!("{} TFLOPS/GPU", s.scheme.name()));
        header.push(format!("{} eff", s.scheme.name()));
    }
    let hdr_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr_refs).title(title.to_string());
    let npts = series[0].points.len();
    for s in series {
        assert_eq!(s.points.len(), npts, "series lengths must match");
    }
    for i in 0..npts {
        let mut row = vec![series[0].points[i].gcds.to_string()];
        for s in series {
            let base = s.points[0].tflops_per_gpu();
            let tf = s.points[i].tflops_per_gpu();
            row.push(fnum(tf, 2));
            row.push(fnum(tf / base, 3));
        }
        t.row(row);
    }
    let mut out = t.render();
    // headline ratios at the largest scale (the paper's §VI claims)
    if series.len() >= 2 {
        let last = npts - 1;
        out.push_str("speedups at largest scale:\n");
        for i in 1..series.len() {
            for j in 0..i {
                let a = series[i].points[last].tflops_per_gpu();
                let b = series[j].points[last].tflops_per_gpu();
                out.push_str(&format!(
                    "  {} vs {}: {:.2}x ({:+.1}%)\n",
                    series[i].scheme.name(),
                    series[j].scheme.name(),
                    a / b,
                    (a / b - 1.0) * 100.0
                ));
            }
        }
    }
    out
}

/// Render the scheduler's stall attribution for one scheme's step: where
/// the compute stream waited, per bandwidth level, plus stream busy times
/// — the "which link class stalls the step" table behind the paper's
/// Discussion of expensive inter-node collectives. Level labels come from
/// the machine spec ("B_GCD (GCD-GCD)" on Frontier, "Xe-Link" on Aurora).
pub fn render_stall_table(
    title: &str,
    stalls: &BTreeMap<LinkClass, f64>,
    util: &StepUtilization,
    machine: &MachineSpec,
) -> String {
    let mut t = Table::new(&["bandwidth level", "compute stall (s)", "% of step"])
        .title(title.to_string())
        .left_first();
    for (class, secs) in stalls {
        t.row(vec![
            machine.class_label(*class),
            fnum(*secs, 3),
            fnum(100.0 * secs / util.makespan.max(f64::MIN_POSITIVE), 1),
        ]);
    }
    let mut out = t.render();
    out.push_str(&format!(
        "step {:.3}s: compute busy {:.3}s ({:.1}% util), prefetch busy {:.3}s, grad-sync busy {:.3}s\n",
        util.makespan,
        util.compute_busy,
        100.0 * util.compute_utilization(),
        util.prefetch_busy,
        util.grad_sync_busy,
    ));
    if util.pipe_busy > 0.0 {
        out.push_str(&format!("pipe-transfer busy {:.3}s\n", util.pipe_busy));
    }
    out
}

/// Render the per-stage accounting of a pipeline schedule: one row per
/// stage — its representative rank, compute/pipe/grad-sync busy time,
/// and the worst link-class stall — plus the step time, the *simulated*
/// bubble fraction, and the closed-form equal-stage bound it is
/// predicted against (`(P-1)/(V·M+P-1)`).
pub fn render_pipeline_table(
    title: &str,
    plan: &PipelinePlan,
    sched: &Schedule,
    machine: &MachineSpec,
) -> String {
    let mut t = Table::new(&[
        "stage",
        "rep rank",
        "compute busy (s)",
        "pipe busy (s)",
        "grad-sync busy (s)",
        "worst stall (s)",
        "on level",
    ])
    .title(title.to_string())
    .left_first();
    for (s, &rep) in plan.rep_ranks.iter().enumerate() {
        let u = sched.utilization(rep);
        let stalls = sched.stall_by_class(rep);
        let worst = stalls
            .iter()
            .max_by(|a, b| a.1.partial_cmp(b.1).expect("finite stalls"))
            .map(|(c, v)| (*c, *v));
        t.row(vec![
            format!("s{s}"),
            format!("r{rep}"),
            fnum(u.compute_busy, 3),
            fnum(u.pipe_busy, 3),
            fnum(u.grad_sync_busy, 3),
            worst.map(|(_, v)| fnum(v, 3)).unwrap_or_else(|| "-".into()),
            worst.map(|(c, _)| machine.class_label(c)).unwrap_or_else(|| "-".into()),
        ]);
    }
    let mut out = t.render();
    let (p, m, v) = (plan.stage_count(), plan.microbatches(), plan.interleave);
    out.push_str(&format!(
        "step {:.3}s; bubble fraction {:.4} (closed-form equal-stage bound {:.4}); P={p} M={m} V={v}\n",
        sched.makespan(),
        plan.bubble_fraction(sched),
        PipelinePlan::ideal_bubble(p, m, v),
    ));
    out
}

/// Render the per-rank attribution of a (multi-rank) schedule: one row per
/// modeled rank — compute busy/end, straggler skew-wait, and the worst
/// link-class stall — slowest ranks first, capped at `max_rows`. This is
/// the table the straggler/jitter scenarios surface: which rank sets the
/// makespan and what everyone else was waiting on.
pub fn render_rank_table(
    title: &str,
    sched: &Schedule,
    machine: &MachineSpec,
    max_rows: usize,
) -> String {
    let mut ranks = sched.ranks();
    let ends: BTreeMap<usize, f64> =
        ranks.iter().map(|&r| (r, sched.rank_compute_end(r))).collect();
    let skews = sched.skew_waits();
    ranks.sort_by(|a, b| ends[b].partial_cmp(&ends[a]).expect("finite ends"));
    let shown = ranks.len().min(max_rows.max(1));
    let mut t = Table::new(&[
        "rank",
        "node",
        "compute busy (s)",
        "compute end (s)",
        "skew wait (s)",
        "worst stall (s)",
        "on level",
    ])
    .title(title.to_string())
    .left_first();
    let wpn = machine.workers_per_node.max(1);
    for &r in &ranks[..shown] {
        let u = sched.utilization(r);
        let stalls = sched.stall_by_class(r);
        let worst = stalls
            .iter()
            .max_by(|a, b| a.1.partial_cmp(b.1).expect("finite stalls"))
            .map(|(c, s)| (*c, *s));
        t.row(vec![
            format!("r{r}"),
            (r / wpn).to_string(),
            fnum(u.compute_busy, 3),
            fnum(ends[&r], 3),
            fnum(skews.get(&r).copied().unwrap_or(0.0), 3),
            worst.map(|(_, s)| fnum(s, 3)).unwrap_or_else(|| "-".into()),
            worst.map(|(c, _)| machine.class_label(c)).unwrap_or_else(|| "-".into()),
        ]);
    }
    let mut out = t.render();
    if ranks.len() > shown {
        out.push_str(&format!("  ({} congruent ranks not shown)\n", ranks.len() - shown));
    }
    out.push_str(&format!(
        "makespan {:.3}s; slowest rank r{} (compute ends {:.3}s)\n",
        sched.makespan(),
        sched.slowest_rank(),
        sched.rank_compute_end(sched.slowest_rank()),
    ));
    out
}

/// Render the link-utilization accounting of a scheduled step: one row per
/// link class that carried traffic — contended links, union busy seconds,
/// busy share of the step, summed task seconds, peak concurrent transfers,
/// and the compute stall `rank` attributes to the class. Busy time is a
/// union of transfer spans, so each class's attributed stall can never
/// exceed its busy cell (reconciliation enforced by `tests/telemetry.rs`);
/// level labels match the stall table and the Chrome-trace counter tracks.
pub fn render_utilization_table(
    title: &str,
    sched: &Schedule,
    machine: &MachineSpec,
    rank: usize,
) -> String {
    if sched.graph().is_empty() {
        return format!("{title}\n(empty schedule: no tasks)\n");
    }
    let usage = sched.link_usage();
    let busy = sched.class_busy();
    let stalls = sched.stall_by_class(rank);
    let makespan = sched.makespan();
    let mut t = Table::new(&[
        "bandwidth level",
        "links",
        "busy (s)",
        "% of step",
        "task seconds",
        "peak in-flight",
        "stall (s)",
    ])
    .title(title.to_string())
    .left_first();
    for class in sched.link_classes() {
        let mut links = 0usize;
        let mut task_seconds = 0.0;
        let mut peak = 0usize;
        for ((c, _), u) in &usage {
            if *c == class {
                links += 1;
                task_seconds += u.task_seconds;
                peak = peak.max(u.peak_in_flight);
            }
        }
        let b = busy.get(&class).copied().unwrap_or(0.0);
        t.row(vec![
            machine.class_label(class),
            links.to_string(),
            fnum(b, 3),
            fnum(100.0 * b / makespan.max(f64::MIN_POSITIVE), 1),
            fnum(task_seconds, 3),
            peak.to_string(),
            fnum(stalls.get(&class).copied().unwrap_or(0.0), 3),
        ]);
    }
    let mut out = t.render();
    out.push_str(&format!(
        "step {makespan:.3}s; busy = union of concurrent transfers per level\n"
    ));
    out
}

/// Human label of a ledger category, with comm rows resolved against the
/// machine's level names (so decomposition, stall, and utilization tables
/// name links identically).
pub fn category_label(cat: Category, machine: &MachineSpec) -> String {
    match cat {
        Category::Compute => "compute".to_string(),
        Category::Comm(c) => format!("comm {}", machine.class_label(c)),
        Category::Idle => "idle".to_string(),
    }
}

/// Render the conserved critical-path decomposition of a step
/// (`sched::critical::decompose`, DESIGN.md §14): one row per ledger
/// category — compute, per-link comm (fastest class first), idle — with
/// its share of the makespan, plus the conservation defect and the
/// binding category. Comm rows carry the machine's level labels so they
/// line up with the stall and utilization tables.
pub fn render_decomposition_table(
    title: &str,
    decomp: &Decomposition,
    machine: &MachineSpec,
) -> String {
    if decomp.segments().is_empty() {
        return format!("{title}\n(empty schedule: no tasks)\n");
    }
    let label = |cat: Category| category_label(cat, machine);
    let makespan = decomp.makespan();
    let mut t = Table::new(&["category", "seconds", "% of step"])
        .title(title.to_string())
        .left_first();
    for (cat, secs) in decomp.entries() {
        t.row(vec![
            label(cat),
            fnum(secs, 3),
            fnum(100.0 * secs / makespan.max(f64::MIN_POSITIVE), 1),
        ]);
    }
    let mut out = t.render();
    out.push_str(&format!(
        "step {:.3}s over {} critical tasks; bound by {}; conservation error {:.1e}\n",
        makespan,
        decomp.segments().len(),
        label(decomp.dominant()),
        decomp.conservation_error(),
    ));
    out
}

/// Render the ranked link shadow-price table (`sim::shadow_prices`,
/// DESIGN.md §14): per knob, the step-time saving of a one-notch
/// improvement (bandwidth/compute x2, latency /2, or the discrete
/// schedule knobs), the resulting step time, and — for the continuous
/// machine knobs — the eps-probe derivative.
pub fn render_shadow_price_table(title: &str, report: &SensitivityReport) -> String {
    if report.prices.is_empty() {
        return format!("{title}\n(no evaluable knobs)\n");
    }
    let mut t = Table::new(&["rank", "knob", "saves (s)", "new step (s)", "d(step)/d(knob)"])
        .title(title.to_string())
        .left_first();
    for (i, p) in report.prices.iter().enumerate() {
        t.row(vec![
            format!("#{}", i + 1),
            p.label.clone(),
            fnum(p.saving, 3),
            fnum(p.improved_s, 3),
            p.derivative.map(|d| fnum(d, 3)).unwrap_or_else(|| "-".into()),
        ]);
    }
    let mut out = t.render();
    out.push_str(&format!(
        "base step {:.3}s; one notch = x2 bandwidth/compute or /2 latency; derivative probed at eps={}\n",
        report.base_s, report.epsilon,
    ));
    out
}

/// Render the slowest-rank critical path: the chain of tasks (dependency or
/// stream-FIFO blockers) ending at the last-finishing task, capped to the
/// final `max_items` entries.
pub fn render_critical_path(sched: &Schedule, max_items: usize) -> String {
    let path = sched.critical_path();
    let skip = path.len().saturating_sub(max_items.max(1));
    let mut out = String::from("critical path (slowest chain):\n");
    if skip > 0 {
        out.push_str(&format!("  ... {skip} earlier tasks elided ...\n"));
    }
    for &id in &path[skip..] {
        let t = sched.graph().task(id);
        let s = sched.span(id);
        out.push_str(&format!(
            "  r{:<4} {:9} {:24} [{:9.3}s .. {:9.3}s]\n",
            t.rank,
            t.stream.name(),
            t.label,
            s.start,
            s.end
        ));
    }
    out
}

const GIB: f64 = 1024.0 * 1024.0 * 1024.0;

/// Render the auto-planner's ranked table (DESIGN.md §15): the top-`top`
/// feasible points with their schedule knobs, step time, token-normalized
/// throughput, and memory ledger totals. An empty feasible set renders
/// the "nothing fits" diagnosis (smallest overage and the point that
/// achieved it) instead of an empty table.
pub fn render_plan_table(
    title: &str,
    outcome: &crate::sim::plan::PlanOutcome,
    top: usize,
) -> String {
    if outcome.ranked.is_empty() {
        let mut s = format!("{title}\n");
        match outcome.smallest_overage() {
            Some(p) => s.push_str(&format!(
                "nothing fits: every evaluated point exceeds the {:.1} GiB HBM budget; \
                 smallest overage {:.2} GiB at {} P={} M={} V={} depth={} blocks={} \
                 (high-water mark {:.2} GiB)\n",
                p.fit.hbm / GIB,
                p.fit.overage() / GIB,
                p.scheme.name(),
                p.stages,
                p.microbatches,
                p.interleave,
                p.depth,
                p.blocks,
                p.fit.total() / GIB,
            )),
            None => {
                s.push_str("nothing fits: the search space was empty (every combination was illegal)\n")
            }
        }
        return s;
    }
    let mut t = Table::new(&[
        "rank",
        "scheme",
        "P",
        "M",
        "V",
        "depth",
        "blocks",
        "step (s)",
        "TFLOPS/GCD",
        "mem (GiB)",
        "headroom (GiB)",
    ])
    .title(title.to_string())
    .left_first();
    for (i, p) in outcome.ranked.iter().take(top.max(1)).enumerate() {
        t.row(vec![
            format!("#{}", i + 1),
            p.scheme.name(),
            p.stages.to_string(),
            p.microbatches.to_string(),
            p.interleave.to_string(),
            p.depth.to_string(),
            p.blocks.to_string(),
            fnum(p.step_s, 3),
            fnum(p.tflops_per_gcd, 2),
            fnum(p.fit.total() / GIB, 2),
            fnum(p.fit.headroom() / GIB, 2),
        ]);
    }
    let mut out = t.render();
    out.push_str(&format!(
        "searched {} points: {} feasible, {} infeasible (pruned before pricing), {} skipped (illegal)\n",
        outcome.evaluated() + outcome.skipped,
        outcome.ranked.len(),
        outcome.pruned.len(),
        outcome.skipped,
    ));
    out
}

/// Render the capacity frontier: per scheme, the largest model the swept
/// schedules admit on this machine at this world size
/// (`MemoryFit::max_model_params` maximized over the sweep).
pub fn render_capacity_frontier(
    title: &str,
    outcome: &crate::sim::plan::PlanOutcome,
) -> String {
    let mut t = Table::new(&["scheme", "max model (B params)"])
        .title(title.to_string())
        .left_first();
    for (scheme, cap) in &outcome.frontier {
        t.row(vec![scheme.name(), fnum(cap / 1e9, 1)]);
    }
    let mut out = t.render();
    out.push_str(
        "capacity = largest Ψ whose states + gather window + in-flight activations fit HBM, \
         maximized over the swept schedules\n",
    );
    out
}

/// Markdown twin of [`render_capacity_frontier`] for CI step summaries
/// (same append-only contract as `calibrate --md`).
pub fn capacity_frontier_markdown(
    title: &str,
    outcome: &crate::sim::plan::PlanOutcome,
) -> String {
    let mut s = format!("### {title}\n\n| scheme | max model (B params) |\n|---|---|\n");
    for (scheme, cap) in &outcome.frontier {
        s.push_str(&format!("| {} | {:.1} |\n", scheme.name(), cap / 1e9));
    }
    s.push('\n');
    s
}

/// One scheme's goodput summary line for [`render_goodput_table`]:
/// checkpoint-path costs, the optimal interval, and the resulting
/// net tokens/s at that interval.
#[derive(Debug, Clone)]
pub struct GoodputRow {
    /// Scheme name.
    pub scheme: String,
    /// Event-clock seconds per optimizer step.
    pub step_s: f64,
    /// Failure-free throughput (tokens/s).
    pub tokens_per_s: f64,
    /// Checkpoint save seconds δ.
    pub save_s: f64,
    /// Restart seconds R (load + rematerialization).
    pub restore_s: f64,
    /// Optimal checkpoint interval τ* = sqrt(2δ(M−R)).
    pub tau_opt_s: f64,
    /// Availability A(τ*) in (0, 1].
    pub availability: f64,
    /// Net tokens/s at τ*.
    pub goodput_tokens_per_s: f64,
}

/// Render the per-scheme goodput comparison at one MTBF: checkpoint
/// costs, the Young/Daly optimal interval, and the net tokens/s.
pub fn render_goodput_table(title: &str, mtbf_s: f64, rows: &[GoodputRow]) -> String {
    let mut t = Table::new(&[
        "scheme",
        "step (s)",
        "save (s)",
        "restore (s)",
        "tau* (s)",
        "avail",
        "goodput (tok/s)",
    ])
    .title(title.to_string())
    .left_first();
    for r in rows {
        t.row(vec![
            r.scheme.clone(),
            fnum(r.step_s, 3),
            fnum(r.save_s, 3),
            fnum(r.restore_s, 3),
            fnum(r.tau_opt_s, 1),
            fnum(r.availability, 4),
            fnum(r.goodput_tokens_per_s, 0),
        ]);
    }
    let mut out = t.render();
    out.push_str(&format!(
        "MTBF {mtbf_s:.0}s; tau* = sqrt(2*save*(MTBF - restore)) (Young/Daly); \
         goodput = availability x tokens/s (DESIGN.md Sec 17)\n"
    ));
    out
}

/// Markdown twin of [`render_goodput_table`] for CI step summaries
/// (same append-only contract as `calibrate --md`).
pub fn goodput_markdown(title: &str, mtbf_s: f64, rows: &[GoodputRow]) -> String {
    let mut s = format!(
        "### {title}\n\n| scheme | step (s) | save (s) | restore (s) | tau* (s) | avail | goodput (tok/s) |\n\
         |---|---|---|---|---|---|---|\n"
    );
    for r in rows {
        s.push_str(&format!(
            "| {} | {:.3} | {:.3} | {:.3} | {:.1} | {:.4} | {:.0} |\n",
            r.scheme,
            r.step_s,
            r.save_s,
            r.restore_s,
            r.tau_opt_s,
            r.availability,
            r.goodput_tokens_per_s
        ));
    }
    s.push_str(&format!("\nMTBF {mtbf_s:.0}s; tau\\* per Young/Daly.\n\n"));
    s
}

/// Render an MTBF×interval sweep grid ([`crate::sim::goodput::sweep`]):
/// one row per interval, with grid edges that degenerate (e.g.
/// `8τ* >= MTBF`) shown as diagnosed notes rather than dropped.
pub fn render_goodput_sweep(
    title: &str,
    tau_opt_s: f64,
    grid: &[(f64, Result<crate::sim::goodput::GoodputReport, crate::sim::goodput::GoodputError>)],
) -> String {
    let mut t = Table::new(&["interval (s)", "avail", "goodput (tok/s)", "note"])
        .title(title.to_string());
    for (interval, res) in grid {
        let star = if (interval - tau_opt_s).abs() < 1e-9 { " *" } else { "" };
        match res {
            Ok(g) => t.row(vec![
                format!("{}{star}", fnum(*interval, 1)),
                fnum(g.availability, 4),
                fnum(g.goodput_tokens_per_s, 0),
                "".into(),
            ]),
            Err(e) => t.row(vec![
                format!("{}{star}", fnum(*interval, 1)),
                "—".into(),
                "—".into(),
                format!("{e}"),
            ]),
        }
    }
    let mut out = t.render();
    out.push_str("* = tau* (closed-form optimum); grid is tau* x {1/8 .. 8}\n");
    out
}

/// CSV with one row per (scheme, scale) for plotting.
pub fn scaling_csv(series: &[ScalingSeries]) -> String {
    let mut out = String::from("scheme,gcds,tflops_per_gpu,samples_per_sec,efficiency\n");
    for s in series {
        let base = s.points[0].tflops_per_gpu();
        for p in &s.points {
            out.push_str(&format!(
                "{},{},{:.4},{:.4},{:.4}\n",
                s.scheme.name(),
                p.gcds,
                p.tflops_per_gpu(),
                p.samples_per_second(),
                p.tflops_per_gpu() / base
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(gcds: usize, tf: f64) -> Throughput {
        Throughput {
            gcds,
            step_seconds: 1.0,
            flops_per_step: tf * 1e12 * gcds as f64,
            sequences_per_step: 1.0,
        }
    }

    #[test]
    fn renders_stall_table() {
        let mut stalls = BTreeMap::new();
        stalls.insert(LinkClass::InterNode, 2.0);
        stalls.insert(LinkClass::Intra(0), 0.5);
        let util = StepUtilization {
            makespan: 10.0,
            compute_busy: 7.0,
            prefetch_busy: 2.5,
            grad_sync_busy: 2.0,
            pipe_busy: 0.0,
        };
        let out =
            render_stall_table("stalls", &stalls, &util, &MachineSpec::frontier_mi250x());
        assert!(out.contains("B_inter"), "{out}");
        assert!(out.contains("B_GCD"), "{out}");
        assert!(out.contains("20.0"), "{out}");
        assert!(out.contains("70.0% util"), "{out}");
        assert!(!out.contains("pipe-transfer"), "{out}");
        let piped = StepUtilization { pipe_busy: 0.5, ..util };
        let out = render_stall_table("stalls", &stalls, &piped, &MachineSpec::frontier_mi250x());
        assert!(out.contains("pipe-transfer busy 0.500s"), "{out}");
    }

    #[test]
    fn renders_goodput_table_and_markdown_twin() {
        let rows = vec![
            GoodputRow {
                scheme: "ZeRO-3".into(),
                step_s: 33.501,
                tokens_per_s: 70425.0,
                save_s: 0.961,
                restore_s: 0.481,
                tau_opt_s: 203.8,
                availability: 0.9905,
                goodput_tokens_per_s: 69756.0,
            },
            GoodputRow {
                scheme: "ZeRO-topo".into(),
                step_s: 12.973,
                tokens_per_s: 181869.0,
                save_s: 0.961,
                restore_s: 2.724,
                tau_opt_s: 203.8,
                availability: 0.9904,
                goodput_tokens_per_s: 180123.0,
            },
        ];
        let out = render_goodput_table("goodput @ frontier", 21_600.0, &rows);
        assert!(out.contains("goodput @ frontier"), "{out}");
        assert!(out.contains("ZeRO-topo"), "{out}");
        assert!(out.contains("180123"), "{out}");
        assert!(out.contains("MTBF 21600s"), "{out}");
        let md = goodput_markdown("goodput @ frontier", 21_600.0, &rows);
        assert!(md.starts_with("### goodput @ frontier"), "{md}");
        assert!(md.contains("| ZeRO-3 | 33.501 |"), "{md}");
        assert!(md.contains("| ZeRO-topo |"), "{md}");
        // same append-only contract as the other markdown twins
        assert!(md.ends_with("\n\n"), "{md:?}");
    }

    #[test]
    fn renders_goodput_sweep_with_diagnosed_edges() {
        use crate::sim::goodput::{goodput, optimal_interval, sweep, CheckpointCost};
        let ck = CheckpointCost { bytes_per_rank: 1e9, save_s: 5.0, load_s: 2.0, remat_s: 1.0 };
        let tau = optimal_interval(3600.0, &ck).unwrap();
        let grid = sweep(1.0, 1e6, &ck, 3600.0).unwrap();
        let out = render_goodput_sweep("sweep", tau, &grid);
        // the optimum row is starred and every grid point prints a row
        assert!(out.contains('*'), "{out}");
        assert_eq!(out.matches('\n').count() >= grid.len() + 2, true, "{out}");
        // a degenerate edge shows its diagnosis, not a blank or NaN
        let bad = vec![(10_000.0, goodput(1.0, 1e6, &ck, 3600.0, 10_000.0))];
        let out = render_goodput_sweep("edge", tau, &bad);
        assert!(out.contains("below the MTBF"), "{out}");
        assert!(!out.contains("NaN"), "{out}");
    }

    #[test]
    fn renders_pipeline_table() {
        use crate::sched::Depth;
        let plan = PipelinePlan::synthetic(4, 8, 1, 1.0, 2.0, Depth::Infinite);
        let sched = plan.simulate();
        let out = render_pipeline_table(
            "pipeline",
            &plan,
            &sched,
            &MachineSpec::frontier_mi250x(),
        );
        assert!(out.contains("pipeline"), "{out}");
        assert!(out.contains("s0") && out.contains("s3"), "{out}");
        assert!(out.contains("P=4 M=8 V=1"), "{out}");
        // synthetic zero-comm plan: simulated bubble == closed-form bound
        assert!(out.contains("bubble fraction 0.2727"), "{out}");
        assert!(out.contains("bound 0.2727"), "{out}");
    }

    #[test]
    fn renders_rank_table_and_critical_path() {
        use crate::sched::{simulate, StreamKind, Task, TaskGraph};
        let mut g = TaskGraph::with_rank_ids(vec![0, 9]);
        let a = g.add(Task {
            label: "compute@r0".into(),
            rank: 0,
            stream: StreamKind::Compute,
            work: 1.0,
            class: None,
            instance: 0,
            deps: vec![],
        });
        let b = g.add(Task {
            label: "compute@r9".into(),
            rank: 9,
            stream: StreamKind::Compute,
            work: 3.0,
            class: None,
            instance: 0,
            deps: vec![],
        });
        g.add(Task {
            label: "grad-sync".into(),
            rank: 0,
            stream: StreamKind::GradSync,
            work: 1.0,
            class: Some(LinkClass::InterNode),
            instance: 0,
            deps: vec![a, b],
        });
        let sched = simulate(g);
        let m = MachineSpec::frontier_mi250x();
        let out = render_rank_table("ranks", &sched, &m, 8);
        assert!(out.contains("slowest rank r9"), "{out}");
        assert!(out.contains("r0"), "{out}");
        // r9 is on node 1 of an 8-wide machine
        assert!(out.lines().any(|l| l.contains("r9") && l.contains(" 1 ")), "{out}");
        let capped = render_rank_table("ranks", &sched, &m, 1);
        assert!(capped.contains("congruent ranks not shown"), "{capped}");
        let cp = render_critical_path(&sched, 8);
        assert!(cp.contains("compute@r9") && cp.contains("grad-sync"), "{cp}");
        let short = render_critical_path(&sched, 1);
        assert!(short.contains("elided"), "{short}");
    }

    // -- golden-string renderer tests: pinned small configs, exact match --

    #[test]
    fn stall_table_golden() {
        let mut stalls = BTreeMap::new();
        stalls.insert(LinkClass::InterNode, 2.0);
        stalls.insert(LinkClass::Intra(0), 0.5);
        let util = StepUtilization {
            makespan: 10.0,
            compute_busy: 7.0,
            prefetch_busy: 2.5,
            grad_sync_busy: 2.0,
            pipe_busy: 0.0,
        };
        let out =
            render_stall_table("stalls", &stalls, &util, &MachineSpec::frontier_mi250x());
        let expected = "\
stalls
+---------------------+-------------------+-----------+
| bandwidth level     | compute stall (s) | % of step |
+---------------------+-------------------+-----------+
| B_GCD (GCD-GCD)     |             0.500 |       5.0 |
| B_inter (node-node) |             2.000 |      20.0 |
+---------------------+-------------------+-----------+
step 10.000s: compute busy 7.000s (70.0% util), prefetch busy 2.500s, grad-sync busy 2.000s
";
        assert_eq!(out, expected);
    }

    #[test]
    fn rank_table_golden() {
        use crate::sched::{simulate, StreamKind, Task, TaskGraph};
        let mut g = TaskGraph::with_rank_ids(vec![0, 9]);
        g.add(Task {
            label: "compute@r0".into(),
            rank: 0,
            stream: StreamKind::Compute,
            work: 1.0,
            class: None,
            instance: 0,
            deps: vec![],
        });
        g.add(Task {
            label: "compute@r9".into(),
            rank: 9,
            stream: StreamKind::Compute,
            work: 3.0,
            class: None,
            instance: 0,
            deps: vec![],
        });
        let sched = simulate(g);
        let out = render_rank_table("ranks", &sched, &MachineSpec::frontier_mi250x(), 8);
        let expected = "\
ranks
+------+------+------------------+-----------------+---------------+-----------------+----------+
| rank | node | compute busy (s) | compute end (s) | skew wait (s) | worst stall (s) | on level |
+------+------+------------------+-----------------+---------------+-----------------+----------+
| r9   |    1 |            3.000 |           3.000 |         0.000 |               - |        - |
| r0   |    0 |            1.000 |           1.000 |         2.000 |               - |        - |
+------+------+------------------+-----------------+---------------+-----------------+----------+
makespan 3.000s; slowest rank r9 (compute ends 3.000s)
";
        assert_eq!(out, expected);
    }

    #[test]
    fn pipeline_table_golden() {
        use crate::sched::Depth;
        let plan = PipelinePlan::synthetic(2, 2, 1, 1.0, 2.0, Depth::Infinite);
        let sched = plan.simulate();
        let out = render_pipeline_table(
            "pipeline",
            &plan,
            &sched,
            &MachineSpec::frontier_mi250x(),
        );
        let expected = "\
pipeline
+-------+----------+------------------+---------------+--------------------+-----------------+----------+
| stage | rep rank | compute busy (s) | pipe busy (s) | grad-sync busy (s) | worst stall (s) | on level |
+-------+----------+------------------+---------------+--------------------+-----------------+----------+
| s0    |       r0 |            6.000 |         0.000 |              0.000 |               - |        - |
| s1    |       r8 |            6.000 |         0.000 |              0.000 |               - |        - |
+-------+----------+------------------+---------------+--------------------+-----------------+----------+
step 9.000s; bubble fraction 0.3333 (closed-form equal-stage bound 0.3333); P=2 M=2 V=1
";
        assert_eq!(out, expected);
    }

    #[test]
    fn utilization_table_golden() {
        use crate::sched::{simulate, StreamKind, Task, TaskGraph};
        let mut g = TaskGraph::new();
        let gather = g.add(Task {
            label: "gather".into(),
            rank: 0,
            stream: StreamKind::Prefetch,
            work: 2.0,
            class: Some(LinkClass::InterNode),
            instance: 0,
            deps: vec![],
        });
        let fwd = g.add(Task {
            label: "fwd".into(),
            rank: 0,
            stream: StreamKind::Compute,
            work: 1.0,
            class: None,
            instance: 0,
            deps: vec![gather],
        });
        g.add(Task {
            label: "sync".into(),
            rank: 0,
            stream: StreamKind::GradSync,
            work: 1.0,
            class: Some(LinkClass::Intra(0)),
            instance: 0,
            deps: vec![fwd],
        });
        let sched = simulate(g);
        let out = render_utilization_table(
            "utilization",
            &sched,
            &MachineSpec::frontier_mi250x(),
            0,
        );
        let expected = "\
utilization
+---------------------+-------+----------+-----------+--------------+----------------+-----------+
| bandwidth level     | links | busy (s) | % of step | task seconds | peak in-flight | stall (s) |
+---------------------+-------+----------+-----------+--------------+----------------+-----------+
| B_GCD (GCD-GCD)     |     1 |    1.000 |      25.0 |        1.000 |              1 |     1.000 |
| B_inter (node-node) |     1 |    2.000 |      50.0 |        2.000 |              1 |     2.000 |
+---------------------+-------+----------+-----------+--------------+----------------+-----------+
step 4.000s; busy = union of concurrent transfers per level
";
        assert_eq!(out, expected);
    }

    #[test]
    fn decomposition_table_golden() {
        use crate::sched::critical::decompose;
        use crate::sched::{simulate, StreamKind, Task, TaskGraph};
        let mut g = TaskGraph::new();
        let gather = g.add(Task {
            label: "gather".into(),
            rank: 0,
            stream: StreamKind::Prefetch,
            work: 3.0,
            class: Some(LinkClass::InterNode),
            instance: 0,
            deps: vec![],
        });
        g.add(Task {
            label: "fwd".into(),
            rank: 0,
            stream: StreamKind::Compute,
            work: 1.0,
            class: None,
            instance: 0,
            deps: vec![gather],
        });
        let d = decompose(&simulate(g));
        let out = render_decomposition_table("decomposition", &d, &MachineSpec::frontier_mi250x());
        let expected = "\
decomposition
+--------------------------+---------+-----------+
| category                 | seconds | % of step |
+--------------------------+---------+-----------+
| compute                  |   1.000 |      25.0 |
| comm B_inter (node-node) |   3.000 |      75.0 |
| idle                     |   0.000 |       0.0 |
+--------------------------+---------+-----------+
step 4.000s over 2 critical tasks; bound by comm B_inter (node-node); conservation error 0.0e0
";
        assert_eq!(out, expected);
    }

    #[test]
    fn empty_schedules_render_guards_not_panics() {
        use crate::sched::critical::decompose;
        use crate::sched::{simulate, TaskGraph};
        let sched = simulate(TaskGraph::new());
        let m = MachineSpec::frontier_mi250x();
        let util = render_utilization_table("utilization", &sched, &m, 0);
        assert_eq!(util, "utilization\n(empty schedule: no tasks)\n");
        let d = decompose(&sched);
        let dec = render_decomposition_table("decomposition", &d, &m);
        assert_eq!(dec, "decomposition\n(empty schedule: no tasks)\n");
    }

    #[test]
    fn renders_shadow_price_table() {
        use crate::metrics::sensitivity::{Knob, SensitivityReport, ShadowPrice};
        let m = MachineSpec::frontier_mi250x();
        let empty = SensitivityReport { base_s: 1.0, epsilon: 0.05, prices: vec![] };
        assert_eq!(
            render_shadow_price_table("prices", &empty),
            "prices\n(no evaluable knobs)\n"
        );
        let report = SensitivityReport {
            base_s: 33.501,
            epsilon: 0.05,
            prices: vec![
                ShadowPrice {
                    knob: Knob::LinkBandwidth(LinkClass::InterNode),
                    label: Knob::LinkBandwidth(LinkClass::InterNode).label(&m),
                    improved_s: 18.069,
                    saving: 15.432,
                    derivative: Some(29.395),
                },
                ShadowPrice {
                    knob: Knob::SecDegree,
                    label: Knob::SecDegree.label(&m),
                    improved_s: 33.0,
                    saving: 0.501,
                    derivative: None,
                },
            ],
        };
        let out = render_shadow_price_table("prices", &report);
        assert!(out.contains("#1"), "{out}");
        assert!(out.contains("BW B_inter (node-node)"), "{out}");
        assert!(out.contains("15.432"), "{out}");
        // discrete knobs have no derivative cell
        assert!(out.lines().any(|l| l.contains("secondary degree") && l.ends_with("- |")), "{out}");
        assert!(out.contains("base step 33.501s"), "{out}");
        assert!(out.contains("eps=0.05"), "{out}");
    }

    #[test]
    fn renders_plan_tables_and_empty_guard() {
        use crate::memory::MemoryFit;
        use crate::sched::Depth;
        use crate::sim::plan::{PlanOutcome, PlanPoint, PrunedPoint};
        let fit = MemoryFit {
            scheme: Scheme::Zero3,
            psi: 1e9,
            stage: 0,
            weights: 1e9,
            secondary: 0.0,
            grads: 1e9,
            optim: 2e9,
            gather_window: 2e9,
            activations: 1e8,
            hbm: 64e9,
        };
        let point = PlanPoint {
            scheme: Scheme::Zero3,
            depth: Depth::Bounded(2),
            blocks: 44,
            stages: 1,
            microbatches: 3,
            interleave: 1,
            fit,
            step_s: 12.97,
            tokens_per_step: 2.4e6,
            tflops_per_gcd: 61.0,
        };
        let outcome = PlanOutcome {
            ranked: vec![point],
            pruned: vec![],
            skipped: 2,
            frontier: vec![(Scheme::Zero3, 55e9)],
        };
        let out = render_plan_table("plan", &outcome, 5);
        assert!(out.contains("#1") && out.contains("ZeRO-3"), "{out}");
        assert!(out.contains("1 feasible") && out.contains("2 skipped"), "{out}");
        let cf = render_capacity_frontier("frontier", &outcome);
        assert!(cf.contains("55.0"), "{cf}");
        let md = capacity_frontier_markdown("frontier", &outcome);
        assert!(md.starts_with("### frontier"), "{md}");
        assert!(md.contains("| ZeRO-3 | 55.0 |"), "{md}");
        // empty feasible set: the "nothing fits" diagnosis, not a panic
        let over = MemoryFit { gather_window: 80e9, ..fit };
        let empty = PlanOutcome {
            ranked: vec![],
            pruned: vec![PrunedPoint {
                scheme: Scheme::Zero3,
                depth: Depth::Infinite,
                blocks: 1,
                stages: 1,
                microbatches: 3,
                interleave: 1,
                fit: over,
            }],
            skipped: 0,
            frontier: vec![],
        };
        let out = render_plan_table("plan", &empty, 5);
        assert!(out.contains("nothing fits"), "{out}");
        assert!(out.contains("smallest overage"), "{out}");
        // fully illegal space: still a message, never an empty table
        let none =
            PlanOutcome { ranked: vec![], pruned: vec![], skipped: 4, frontier: vec![] };
        let out = render_plan_table("plan", &none, 5);
        assert!(out.contains("search space was empty"), "{out}");
    }

    #[test]
    fn renders_figure_with_speedups() {
        let series = vec![
            ScalingSeries { scheme: Scheme::Zero3, points: vec![pt(64, 30.0), pt(384, 12.0)] },
            ScalingSeries {
                scheme: Scheme::ZeroTopo { sec_degree: 2 },
                points: vec![pt(64, 32.0), pt(384, 29.0)],
            },
        ];
        let out = render_scaling_figure("Fig 7", &series);
        assert!(out.contains("Fig 7"));
        assert!(out.contains("2.42x"), "{out}");
        let csv = scaling_csv(&series);
        assert_eq!(csv.lines().count(), 5);
        assert!(csv.contains("ZeRO-3,384,12.0000"));
    }
}
