//! Data-driven machine topology specs — the paper's Tables I & II as
//! *data* instead of enum variants.
//!
//! A [`MachineSpec`] describes one node flavor as an ordered hierarchy of
//! nested intra-node levels (innermost first). Level `k` partitions the
//! node's workers into consecutive blocks of `span` ranks that share one
//! link class; two ranks on the same node communicate over the innermost
//! level whose block contains both, and ranks on different nodes cross the
//! `inter_node` fabric. Because the levels are nested and aligned, every
//! rank→link question (`Cluster::link_between`, `bottleneck_class`,
//! secondary-partition peer groups) is computed from the spans — no
//! per-machine match arms anywhere.
//!
//! Specs round-trip through JSON (`util::json`), so new machines — Aurora,
//! El Capitan, TPU pods, hypothetical fabrics — are config files, not code
//! (ROADMAP "Generalized non-Frontier topologies"). Schema (see
//! DESIGN.md §9):
//!
//! ```json
//! {
//!   "name": "frontier-mi250x",
//!   "workers_per_node": 8,
//!   "peak_flops_per_worker": 191.5e12,
//!   "hbm_per_worker": 64e9,
//!   "levels": [
//!     {"name": "B_GCD (GCD-GCD)", "span": 2, "bandwidth": 200e9, "latency": 2e-6},
//!     {"name": "B_intra (adjacent MI250X)", "span": 4, "bandwidth": 100e9, "latency": 3e-6},
//!     {"name": "B_intra (cross MI250X)", "span": 8, "bandwidth": 50e9, "latency": 3e-6}
//!   ],
//!   "inter_node": {"bandwidth": 100e9, "latency": 10e-6},
//!   "storage": {"write_bandwidth": 5e9, "read_bandwidth": 10e9, "latency": 1e-3}
//! }
//! ```
//!
//! `storage` is the node's checkpoint I/O path (DESIGN.md §17) and is
//! **optional** in JSON: specs written before it existed parse with
//! [`StorageSpec::default`] (a generic parallel-filesystem estimate) and
//! re-emit it explicitly on save, keeping JSON specs pure data with no
//! code-side special cases.

use std::path::Path;

use crate::util::json::{Json, JsonError};

use super::LinkClass;

/// Link parameters for the α–β model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkSpec {
    /// Bandwidth in bytes/second.
    pub bandwidth: f64,
    /// Latency (α) in seconds per message.
    pub latency: f64,
}

/// The node's checkpoint storage path: what save/restore pricing
/// (DESIGN.md §17, `sim::goodput`) charges per byte of persisted state.
/// Bandwidths are **per node** — all `workers_per_node` ranks of a node
/// funnel through it concurrently, the same sharing rule as the NIC.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StorageSpec {
    /// Sustained write bandwidth per node, bytes/second.
    pub write_bandwidth: f64,
    /// Sustained read bandwidth per node, bytes/second.
    pub read_bandwidth: f64,
    /// Fixed per-operation latency (metadata + open/close), seconds.
    pub latency: f64,
}

impl Default for StorageSpec {
    /// A conservative generic parallel-filesystem estimate (2 GB/s
    /// write, 4 GB/s read, 1 ms latency per node) — what specs that
    /// predate the storage field get.
    fn default() -> Self {
        StorageSpec { write_bandwidth: 2e9, read_bandwidth: 4e9, latency: 1e-3 }
    }
}

/// One intra-node hierarchy level: `span` consecutive workers share this
/// link class. Levels are nested — each level's span divides the next —
/// and ordered fastest (innermost) to slowest (outermost).
#[derive(Debug, Clone, PartialEq)]
pub struct MachineLevel {
    /// Display name ("B_GCD (GCD-GCD)", "NVLink", "Xe-Link", ...).
    pub name: String,
    /// Workers per group at this level.
    pub span: usize,
    /// α–β parameters of this level's link.
    pub link: LinkSpec,
}

/// A machine (node flavor) as data: worker compute/memory plus the ordered
/// intra-node bandwidth hierarchy and the inter-node fabric.
#[derive(Debug, Clone, PartialEq)]
pub struct MachineSpec {
    /// Display name; builtin lookup is by CLI name, files by path.
    pub name: String,
    /// Workers (GCDs / GPUs / tiles) per node; equals the outermost span.
    pub workers_per_node: usize,
    /// Peak dense fp16 FLOP/s per worker.
    pub peak_flops_per_worker: f64,
    /// HBM per worker in bytes.
    pub hbm_per_worker: f64,
    /// Intra-node levels, innermost (fastest, smallest span) first.
    pub levels: Vec<MachineLevel>,
    /// Inter-node fabric (the node's aggregate NIC bandwidth).
    pub inter_node: LinkSpec,
    /// Checkpoint storage path (optional in JSON; defaults when absent).
    pub storage: StorageSpec,
}

/// Why a machine spec failed to load, parse, or validate.
#[derive(Debug, thiserror::Error)]
pub enum SpecError {
    /// The spec file could not be read.
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    /// The spec file is not valid JSON.
    #[error("json: {0}")]
    Json(#[from] JsonError),
    /// The spec parsed but violates the structural rules.
    #[error("machine spec '{name}': {why}")]
    Invalid {
        /// The offending spec's name.
        name: String,
        /// What rule it broke.
        why: String,
    },
    /// Not a builtin name and not a readable file path.
    #[error("unknown machine '{name}': not a builtin (try {builtins}) and no such file")]
    Unknown {
        /// The unresolvable machine string.
        name: String,
        /// Comma-separated builtin names for the error message.
        builtins: String,
    },
}

impl MachineSpec {
    /// Innermost-level group size — the primary weight-partition degree of
    /// a ZeRO-topo placement on this machine (2 on Frontier's GCD pairs).
    pub fn innermost_span(&self) -> usize {
        self.levels[0].span
    }

    /// The spans of every intra-node level, innermost first.
    pub fn level_spans(&self) -> Vec<usize> {
        self.levels.iter().map(|l| l.span).collect()
    }

    /// Every link class this machine can resolve, fastest→slowest.
    pub fn classes(&self) -> Vec<LinkClass> {
        (0..self.levels.len() as u8)
            .map(LinkClass::Intra)
            .chain(std::iter::once(LinkClass::InterNode))
            .collect()
    }

    /// α–β parameters of a link class on this machine. `Intra` indices
    /// beyond the hierarchy clamp to the outermost level (a class minted
    /// by a deeper machine resolves to this machine's slowest intra link).
    pub fn link_spec(&self, class: LinkClass) -> LinkSpec {
        match class {
            LinkClass::Local => LinkSpec { bandwidth: f64::INFINITY, latency: 0.0 },
            LinkClass::Intra(k) => self
                .levels
                .get(k as usize)
                .unwrap_or_else(|| self.levels.last().expect("validated: levels non-empty"))
                .link,
            LinkClass::InterNode => self.inter_node,
        }
    }

    /// Human label for a link class, using this machine's level names.
    pub fn class_label(&self, class: LinkClass) -> String {
        match class {
            LinkClass::Local => "local".into(),
            LinkClass::Intra(k) => self
                .levels
                .get(k as usize)
                .map(|l| l.name.clone())
                .unwrap_or_else(|| format!("B_intra[{k}]")),
            LinkClass::InterNode => "B_inter (node-node)".into(),
        }
    }

    /// Structural validation: nested spans, sane numbers.
    pub fn validate(&self) -> Result<(), SpecError> {
        let fail = |why: String| {
            Err(SpecError::Invalid { name: self.name.clone(), why })
        };
        if self.name.is_empty() {
            return fail("empty name".into());
        }
        if self.levels.is_empty() {
            return fail("at least one intra-node level required".into());
        }
        if self.levels.len() > u8::MAX as usize {
            return fail(format!("{} levels exceed the 255-level cap", self.levels.len()));
        }
        let mut prev_span = 1usize;
        let mut prev_bw = f64::INFINITY;
        for (k, l) in self.levels.iter().enumerate() {
            if l.span < 2 || l.span <= prev_span {
                return fail(format!(
                    "level {k} ('{}') span {} must be >= 2 and exceed the previous span {prev_span}",
                    l.name, l.span
                ));
            }
            if l.span % prev_span != 0 {
                return fail(format!(
                    "level {k} ('{}') span {} is not a multiple of the previous span {prev_span}",
                    l.name, l.span
                ));
            }
            if !(l.link.bandwidth > 0.0 && l.link.bandwidth.is_finite()) {
                return fail(format!("level {k} ('{}') bandwidth must be finite and > 0", l.name));
            }
            if l.link.bandwidth > prev_bw {
                return fail(format!(
                    "level {k} ('{}') bandwidth {} exceeds the inner level's {prev_bw} \
                     (levels must be ordered fastest to slowest)",
                    l.name, l.link.bandwidth
                ));
            }
            if !(l.link.latency >= 0.0 && l.link.latency.is_finite()) {
                return fail(format!("level {k} ('{}') latency must be finite and >= 0", l.name));
            }
            prev_span = l.span;
            prev_bw = l.link.bandwidth;
        }
        if prev_span != self.workers_per_node {
            return fail(format!(
                "outermost span {prev_span} must equal workers_per_node {}",
                self.workers_per_node
            ));
        }
        if !(self.inter_node.bandwidth > 0.0 && self.inter_node.bandwidth.is_finite()) {
            return fail("inter_node bandwidth must be finite and > 0".into());
        }
        if !(self.inter_node.latency >= 0.0 && self.inter_node.latency.is_finite()) {
            return fail("inter_node latency must be finite and >= 0".into());
        }
        if !(self.peak_flops_per_worker > 0.0 && self.peak_flops_per_worker.is_finite()) {
            return fail("peak_flops_per_worker must be finite and > 0".into());
        }
        if !(self.hbm_per_worker > 0.0 && self.hbm_per_worker.is_finite()) {
            return fail("hbm_per_worker must be finite and > 0".into());
        }
        if !(self.storage.write_bandwidth > 0.0 && self.storage.write_bandwidth.is_finite()) {
            return fail("storage write_bandwidth must be finite and > 0".into());
        }
        if !(self.storage.read_bandwidth > 0.0 && self.storage.read_bandwidth.is_finite()) {
            return fail("storage read_bandwidth must be finite and > 0".into());
        }
        if !(self.storage.latency >= 0.0 && self.storage.latency.is_finite()) {
            return fail("storage latency must be finite and >= 0".into());
        }
        Ok(())
    }

    // -- JSON ------------------------------------------------------------

    /// Parse + validate a spec from its JSON object form (see the module
    /// doc for the schema and a worked example).
    ///
    /// ```no_run
    /// // (no_run: doctest binaries miss the libxla rpath in this offline env)
    /// use zero_topo::topology::MachineSpec;
    /// use zero_topo::util::json::Json;
    ///
    /// let j = Json::parse(
    ///     r#"{"name": "two-tier", "workers_per_node": 4,
    ///         "peak_flops_per_worker": 100e12, "hbm_per_worker": 32e9,
    ///         "levels": [
    ///           {"name": "fast", "span": 2, "bandwidth": 300e9, "latency": 1e-6},
    ///           {"name": "slow", "span": 4, "bandwidth": 100e9, "latency": 2e-6}],
    ///         "inter_node": {"bandwidth": 50e9, "latency": 9e-6}}"#,
    /// )
    /// .unwrap();
    /// let spec = MachineSpec::from_json(&j).unwrap();
    /// assert_eq!(spec.innermost_span(), 2);
    /// assert_eq!(spec.level_spans(), vec![2, 4]);
    /// ```
    pub fn from_json(j: &Json) -> Result<MachineSpec, SpecError> {
        let name = j
            .get("name")
            .and_then(|v| v.as_str())
            .unwrap_or("<unnamed>")
            .to_string();
        // owns its copy of the name so the original can move into the spec
        let err_name = name.clone();
        let invalid =
            move |why: String| SpecError::Invalid { name: err_name.clone(), why };
        let num = |j: &Json, key: &str| -> Result<f64, String> {
            j.get(key)
                .and_then(|v| v.as_f64())
                .ok_or_else(|| format!("missing numeric field '{key}'"))
        };
        let link = |j: &Json, ctx: &str| -> Result<LinkSpec, String> {
            Ok(LinkSpec {
                bandwidth: j
                    .get("bandwidth")
                    .and_then(|v| v.as_f64())
                    .ok_or_else(|| format!("{ctx}: missing numeric 'bandwidth'"))?,
                latency: j
                    .get("latency")
                    .and_then(|v| v.as_f64())
                    .ok_or_else(|| format!("{ctx}: missing numeric 'latency'"))?,
            })
        };

        if j.get("name").and_then(|v| v.as_str()).is_none() {
            return Err(invalid("missing string field 'name'".into()));
        }
        let workers_per_node = j
            .get("workers_per_node")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| invalid("missing positive integer 'workers_per_node'".into()))?;
        let raw_levels = j
            .get("levels")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| invalid("missing array 'levels'".into()))?;
        let mut levels = Vec::with_capacity(raw_levels.len());
        for (k, lj) in raw_levels.iter().enumerate() {
            levels.push(MachineLevel {
                name: lj
                    .get("name")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| invalid(format!("levels[{k}]: missing string 'name'")))?
                    .to_string(),
                span: lj
                    .get("span")
                    .and_then(|v| v.as_usize())
                    .ok_or_else(|| invalid(format!("levels[{k}]: missing integer 'span'")))?,
                link: link(lj, &format!("levels[{k}]")).map_err(&invalid)?,
            });
        }
        let inter = j
            .get("inter_node")
            .ok_or_else(|| invalid("missing object 'inter_node'".into()))?;
        let peak_flops_per_worker = num(j, "peak_flops_per_worker").map_err(&invalid)?;
        let hbm_per_worker = num(j, "hbm_per_worker").map_err(&invalid)?;
        let inter_node = link(inter, "inter_node").map_err(&invalid)?;
        let storage = match j.get("storage") {
            None => StorageSpec::default(),
            Some(sj) => StorageSpec {
                write_bandwidth: num(sj, "write_bandwidth")
                    .map_err(|e| invalid(format!("storage: {e}")))?,
                read_bandwidth: num(sj, "read_bandwidth")
                    .map_err(|e| invalid(format!("storage: {e}")))?,
                latency: num(sj, "latency").map_err(|e| invalid(format!("storage: {e}")))?,
            },
        };
        let spec = MachineSpec {
            name,
            workers_per_node,
            peak_flops_per_worker,
            hbm_per_worker,
            levels,
            inter_node,
            storage,
        };
        spec.validate()?;
        Ok(spec)
    }

    /// The JSON object form ([`MachineSpec::from_json`] round-trips it).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("workers_per_node", Json::from(self.workers_per_node)),
            ("peak_flops_per_worker", Json::num(self.peak_flops_per_worker)),
            ("hbm_per_worker", Json::num(self.hbm_per_worker)),
            (
                "levels",
                Json::arr(self.levels.iter().map(|l| {
                    Json::obj(vec![
                        ("name", Json::str(l.name.clone())),
                        ("span", Json::from(l.span)),
                        ("bandwidth", Json::num(l.link.bandwidth)),
                        ("latency", Json::num(l.link.latency)),
                    ])
                })),
            ),
            (
                "inter_node",
                Json::obj(vec![
                    ("bandwidth", Json::num(self.inter_node.bandwidth)),
                    ("latency", Json::num(self.inter_node.latency)),
                ]),
            ),
            (
                "storage",
                Json::obj(vec![
                    ("write_bandwidth", Json::num(self.storage.write_bandwidth)),
                    ("read_bandwidth", Json::num(self.storage.read_bandwidth)),
                    ("latency", Json::num(self.storage.latency)),
                ]),
            ),
        ])
    }

    /// Load + validate a spec from a JSON file.
    pub fn load(path: impl AsRef<Path>) -> Result<MachineSpec, SpecError> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text)?)
    }

    /// Write the spec's JSON form to a file.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), SpecError> {
        std::fs::write(path, format!("{}\n", self.to_json()))?;
        Ok(())
    }

    /// Resolve a CLI/config machine string: a builtin name
    /// ([`super::machines`]) or a path to a spec JSON.
    pub fn resolve(s: &str) -> Result<MachineSpec, SpecError> {
        if let Some(m) = Self::builtin(s) {
            return Ok(m);
        }
        if Path::new(s).exists() {
            return Self::load(s);
        }
        Err(SpecError::Unknown {
            name: s.to_string(),
            builtins: super::machines::BUILTIN_NAMES.join(", "),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> MachineSpec {
        MachineSpec {
            name: "sample".into(),
            workers_per_node: 8,
            peak_flops_per_worker: 100e12,
            hbm_per_worker: 32e9,
            levels: vec![
                MachineLevel {
                    name: "inner".into(),
                    span: 2,
                    link: LinkSpec { bandwidth: 300e9, latency: 1e-6 },
                },
                MachineLevel {
                    name: "outer".into(),
                    span: 8,
                    link: LinkSpec { bandwidth: 100e9, latency: 2e-6 },
                },
            ],
            inter_node: LinkSpec { bandwidth: 50e9, latency: 9e-6 },
            storage: StorageSpec { write_bandwidth: 3e9, read_bandwidth: 6e9, latency: 5e-4 },
        }
    }

    #[test]
    fn json_roundtrip_is_identity() {
        let s = sample();
        let j = s.to_json().to_string();
        let re = MachineSpec::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(s, re);
    }

    #[test]
    fn link_spec_and_labels() {
        let s = sample();
        assert_eq!(s.link_spec(LinkClass::Intra(0)).bandwidth, 300e9);
        assert_eq!(s.link_spec(LinkClass::Intra(1)).bandwidth, 100e9);
        // out-of-range intra levels clamp to the outermost intra link
        assert_eq!(s.link_spec(LinkClass::Intra(7)).bandwidth, 100e9);
        assert_eq!(s.link_spec(LinkClass::InterNode).bandwidth, 50e9);
        assert_eq!(s.link_spec(LinkClass::Local).latency, 0.0);
        assert_eq!(s.class_label(LinkClass::Intra(0)), "inner");
        assert_eq!(s.class_label(LinkClass::InterNode), "B_inter (node-node)");
        assert_eq!(
            s.classes(),
            vec![LinkClass::Intra(0), LinkClass::Intra(1), LinkClass::InterNode]
        );
        assert_eq!(s.innermost_span(), 2);
        assert_eq!(s.level_spans(), vec![2, 8]);
    }

    #[test]
    fn validation_rejects_bad_specs() {
        let mut s = sample();
        s.levels[1].span = 6; // not a multiple of 2... and != workers_per_node
        assert!(s.validate().is_err());

        let mut s = sample();
        s.levels[0].span = 1; // spans must be >= 2
        assert!(s.validate().is_err());

        let mut s = sample();
        s.workers_per_node = 16; // outermost span must equal workers/node
        assert!(s.validate().is_err());

        let mut s = sample();
        s.levels[1].link.bandwidth = 400e9; // outer faster than inner
        assert!(s.validate().is_err());

        let mut s = sample();
        s.levels.clear();
        assert!(s.validate().is_err());

        let mut s = sample();
        s.inter_node.bandwidth = 0.0;
        assert!(s.validate().is_err());

        let mut s = sample();
        s.hbm_per_worker = f64::NAN;
        assert!(s.validate().is_err());

        let mut s = sample();
        s.storage.write_bandwidth = 0.0;
        assert!(s.validate().is_err());

        let mut s = sample();
        s.storage.read_bandwidth = f64::INFINITY;
        assert!(s.validate().is_err());

        let mut s = sample();
        s.storage.latency = -1.0;
        assert!(s.validate().is_err());
    }

    #[test]
    fn storage_defaults_when_absent_and_always_emits() {
        // a pre-storage spec parses with the default path...
        let j = Json::parse(
            r#"{"name": "legacy", "workers_per_node": 2,
                "peak_flops_per_worker": 1e12, "hbm_per_worker": 1e9,
                "levels": [{"name": "l", "span": 2, "bandwidth": 1e9, "latency": 1e-6}],
                "inter_node": {"bandwidth": 1e9, "latency": 1e-6}}"#,
        )
        .unwrap();
        let spec = MachineSpec::from_json(&j).unwrap();
        assert_eq!(spec.storage, StorageSpec::default());
        // ...and re-emits it explicitly
        let out = spec.to_json().to_string();
        assert!(out.contains("\"storage\""), "{out}");
        assert!(out.contains("\"write_bandwidth\""), "{out}");
        // an explicit storage object round-trips verbatim
        let s = sample();
        let re = MachineSpec::from_json(&Json::parse(&s.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(re.storage, s.storage);
        // a partial storage object is a diagnosed error, not a silent default
        let bad = Json::parse(
            r#"{"name": "x", "workers_per_node": 2,
                "peak_flops_per_worker": 1e12, "hbm_per_worker": 1e9,
                "levels": [{"name": "l", "span": 2, "bandwidth": 1e9, "latency": 1e-6}],
                "inter_node": {"bandwidth": 1e9, "latency": 1e-6},
                "storage": {"write_bandwidth": 1e9}}"#,
        )
        .unwrap();
        assert!(MachineSpec::from_json(&bad).is_err());
    }

    #[test]
    fn from_json_reports_missing_fields() {
        for bad in [
            r#"{"workers_per_node": 8}"#,
            r#"{"name": "x"}"#,
            r#"{"name": "x", "workers_per_node": 8, "peak_flops_per_worker": 1e12,
                "hbm_per_worker": 1e9, "levels": []}"#,
            r#"{"name": "x", "workers_per_node": 8, "peak_flops_per_worker": 1e12,
                "hbm_per_worker": 1e9,
                "levels": [{"name": "l", "span": 8, "bandwidth": 1e9}],
                "inter_node": {"bandwidth": 1e9, "latency": 1e-6}}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(MachineSpec::from_json(&j).is_err(), "{bad}");
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("zero_topo_spec_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sample.json");
        let s = sample();
        s.save(&path).unwrap();
        let re = MachineSpec::load(&path).unwrap();
        assert_eq!(s, re);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn resolve_prefers_builtin_then_path() {
        assert_eq!(MachineSpec::resolve("frontier").unwrap().workers_per_node, 8);
        match MachineSpec::resolve("no-such-machine.json") {
            Err(SpecError::Unknown { builtins, .. }) => {
                // the message lists every builtin, sourced from machines.rs
                for n in crate::topology::machines::BUILTIN_NAMES {
                    assert!(builtins.contains(n), "{builtins}");
                }
            }
            other => panic!("expected Unknown, got {other:?}"),
        }
    }
}
