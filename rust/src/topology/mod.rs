//! Hardware topology models — paper Section IV (Tables I & II, Figs 2 & 3),
//! now *data-driven*: a machine is a [`MachineSpec`] (an ordered hierarchy
//! of nested intra-node levels plus an inter-node fabric), JSON-loadable
//! via `util::json`. The old two-variant `NodeKind` enum is gone; Frontier
//! and DGX-A100 are just the first two entries of [`machines`].
//!
//! Frontier compute node (builtin `frontier`): 4× AMD MI250X, each with 2
//! GCDs (8 GCDs/node).
//!   - GCD↔GCD inside one MI250X: 4 Infinity Fabric links, 200 GB/s
//!   - adjacent MI250X pair:      2 IF links, 100 GB/s
//!   - cross-pair MI250X:         1 IF link,   50 GB/s
//!   - inter-node:                4× HPE Slingshot 11, 100 GB/s total
//!
//! DGX-A100 node (builtin `dgx`): 8× A100, NVLink3 600 GB/s all-to-all
//! (NVSwitch), 8× IB HDR = 200 GB/s inter-node.
//!
//! The resolver maps a pair of global ranks to the *link class* their
//! traffic crosses; collectives charge the α–β cost model at the slowest
//! class their device group spans (`comm::cost`). Link classes are level
//! *indices* into the machine's hierarchy, so a never-seen machine JSON
//! resolves with the same generic code paths.

use std::fmt;

pub mod machines;
pub mod spec;

pub use spec::{LinkSpec, MachineLevel, MachineSpec, SpecError, StorageSpec};

/// The link class a pair (or group) of ranks communicates over. Generic
/// over machines: `Intra(k)` is level `k` of the machine's intra-node
/// hierarchy, innermost (fastest) first. The derived `Ord` IS the severity
/// ordering: `Local < Intra(0) < Intra(1) < ... < InterNode`, i.e. outer
/// levels are slower — enforced by [`MachineSpec::validate`].
///
/// On the Frontier builtin: `Intra(0)` = B_GCD (GCD pair), `Intra(1)` =
/// adjacent MI250X, `Intra(2)` = cross MI250X. On DGX: `Intra(0)` = NVLink.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LinkClass {
    /// Same device (no wire) — zero cost.
    Local,
    /// Intra-node hierarchy level `k` (0 = innermost/fastest).
    Intra(u8),
    /// Inter-node fabric (Slingshot-11, InfiniBand, ...).
    InterNode,
}

impl fmt::Display for LinkClass {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // machine-specific names come from `MachineSpec::class_label`; this
        // is the spec-free fallback used by ledgers and traces
        match self {
            LinkClass::Local => f.write_str("local"),
            LinkClass::Intra(k) => write!(f, "B_intra[{k}]"),
            LinkClass::InterNode => f.write_str("B_inter (node-node)"),
        }
    }
}

/// A cluster of identical nodes; ranks are workers (Frontier counts GCDs
/// as GPUs — paper §VI), numbered consecutively within each node.
#[derive(Debug, Clone)]
pub struct Cluster {
    /// The node flavor every node of the cluster shares.
    pub spec: MachineSpec,
    /// Number of nodes.
    pub nodes: usize,
}

impl Cluster {
    /// A cluster of `nodes` identical `spec` nodes.
    pub fn new(spec: MachineSpec, nodes: usize) -> Self {
        // JSON loads always validate; catch hand-built invalid specs early
        debug_assert!(
            spec.validate().is_ok(),
            "invalid machine spec '{}': {:?}",
            spec.name,
            spec.validate().err()
        );
        Cluster { spec, nodes }
    }

    /// Shorthand for `nodes` Frontier-MI250X nodes (the paper's machine).
    pub fn frontier(nodes: usize) -> Self {
        Cluster::new(MachineSpec::frontier_mi250x(), nodes)
    }

    /// Shorthand for `nodes` DGX-A100 nodes.
    pub fn dgx(nodes: usize) -> Self {
        Cluster::new(MachineSpec::dgx_a100(), nodes)
    }

    /// Workers (GCDs / GPUs / tiles) per node.
    pub fn workers_per_node(&self) -> usize {
        self.spec.workers_per_node
    }

    /// Peak dense fp16 FLOP/s per worker.
    pub fn peak_flops_per_worker(&self) -> f64 {
        self.spec.peak_flops_per_worker
    }

    /// HBM bytes per worker.
    pub fn hbm_per_worker(&self) -> f64 {
        self.spec.hbm_per_worker
    }

    /// α–β parameters of a link class on this cluster's machine.
    pub fn link_spec(&self, class: LinkClass) -> LinkSpec {
        self.spec.link_spec(class)
    }

    /// Total worker count (`nodes × workers_per_node`).
    pub fn world_size(&self) -> usize {
        self.nodes * self.spec.workers_per_node
    }

    /// The node a world rank lives on.
    pub fn node_of(&self, rank: usize) -> usize {
        rank / self.spec.workers_per_node
    }

    /// Resolve the link class a pair of ranks communicates over: the
    /// innermost level whose (aligned, nested) block contains both.
    pub fn link_between(&self, a: usize, b: usize) -> LinkClass {
        assert!(a < self.world_size() && b < self.world_size());
        if a == b {
            return LinkClass::Local;
        }
        if self.node_of(a) != self.node_of(b) {
            return LinkClass::InterNode;
        }
        let w = self.spec.workers_per_node;
        let (la, lb) = (a % w, b % w);
        for (k, level) in self.spec.levels.iter().enumerate() {
            if la / level.span == lb / level.span {
                return LinkClass::Intra(k as u8);
            }
        }
        // validated specs never get here (outermost span == workers/node);
        // for an unvalidated one, clamp to the slowest intra level
        LinkClass::Intra((self.spec.levels.len() - 1) as u8)
    }

    /// Slowest link class spanned by a group of ranks — the bandwidth the
    /// paper's Tables VII/VIII attribute to each collective.
    ///
    /// O(n): because levels are nested *aligned* blocks of consecutive
    /// ranks, the worst pair is always (min rank, max rank) — the smallest
    /// block containing both contains every rank in between, and any other
    /// pair shares that block or a smaller one. Equality with the O(n²)
    /// pairwise definition is property-tested below.
    pub fn bottleneck_class(&self, ranks: &[usize]) -> LinkClass {
        let Some(&first) = ranks.first() else { return LinkClass::Local };
        let (mut lo, mut hi) = (first, first);
        for &r in &ranks[1..] {
            lo = lo.min(r);
            hi = hi.max(r);
        }
        self.link_between(lo, hi)
    }

    /// Spec of the bottleneck link for a group.
    pub fn bottleneck_spec(&self, ranks: &[usize]) -> LinkSpec {
        self.spec.link_spec(self.bottleneck_class(ranks))
    }

    /// All ranks grouped by node.
    pub fn ranks_by_node(&self) -> Vec<Vec<usize>> {
        let p = self.spec.workers_per_node;
        (0..self.nodes).map(|n| (n * p..(n + 1) * p).collect()).collect()
    }

    /// The whole group of ranks sharing `rank`'s block at intra level `k`
    /// (includes `rank` itself). Level 0 on Frontier is the GCD pair; on a
    /// machine with a wider innermost level the group is accordingly
    /// larger — no `rank ^ 1` assumption anywhere.
    pub fn level_group(&self, rank: usize, level: usize) -> Vec<usize> {
        assert!(rank < self.world_size());
        let span = self.spec.levels[level].span;
        let base = rank - rank % span;
        (base..base + span).collect()
    }

    /// The innermost-level peer group of a rank (Frontier: its GCD pair) —
    /// the primary weight-partition group of a ZeRO-topo placement.
    pub fn innermost_group(&self, rank: usize) -> Vec<usize> {
        self.level_group(rank, 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::check;

    const GB: f64 = 1e9;

    /// The O(n²) pairwise definition `bottleneck_class` must agree with.
    fn bottleneck_pairwise(c: &Cluster, ranks: &[usize]) -> LinkClass {
        let mut worst = LinkClass::Local;
        for (i, &a) in ranks.iter().enumerate() {
            for &b in &ranks[i + 1..] {
                worst = worst.max(c.link_between(a, b));
            }
        }
        worst
    }

    /// A machine that exists in no builtin: 3 intra tiers over 16 workers.
    fn deep_machine() -> MachineSpec {
        MachineSpec {
            name: "deep-16".into(),
            workers_per_node: 16,
            peak_flops_per_worker: 100e12,
            hbm_per_worker: 16e9,
            levels: vec![
                MachineLevel {
                    name: "l0".into(),
                    span: 2,
                    link: LinkSpec { bandwidth: 500.0 * GB, latency: 1e-6 },
                },
                MachineLevel {
                    name: "l1".into(),
                    span: 4,
                    link: LinkSpec { bandwidth: 200.0 * GB, latency: 2e-6 },
                },
                MachineLevel {
                    name: "l2".into(),
                    span: 16,
                    link: LinkSpec { bandwidth: 80.0 * GB, latency: 3e-6 },
                },
            ],
            inter_node: LinkSpec { bandwidth: 40.0 * GB, latency: 8e-6 },
            storage: StorageSpec::default(),
        }
    }

    fn all_test_machines() -> Vec<MachineSpec> {
        let mut ms = MachineSpec::builtins();
        ms.push(deep_machine());
        ms
    }

    #[test]
    fn frontier_link_resolution() {
        let c = Cluster::frontier(2);
        assert_eq!(c.world_size(), 16);
        assert_eq!(c.link_between(0, 0), LinkClass::Local);
        assert_eq!(c.link_between(0, 1), LinkClass::Intra(0)); // GCD pair
        assert_eq!(c.link_between(0, 2), LinkClass::Intra(1)); // adjacent MI250X
        assert_eq!(c.link_between(0, 3), LinkClass::Intra(1));
        assert_eq!(c.link_between(0, 4), LinkClass::Intra(2)); // cross MI250X
        assert_eq!(c.link_between(1, 7), LinkClass::Intra(2));
        assert_eq!(c.link_between(0, 8), LinkClass::InterNode);
        assert_eq!(c.link_between(7, 15), LinkClass::InterNode);
    }

    #[test]
    fn link_is_symmetric_on_every_machine() {
        for m in all_test_machines() {
            let c = Cluster::new(m, 3);
            for a in 0..c.world_size() {
                for b in 0..c.world_size() {
                    assert_eq!(c.link_between(a, b), c.link_between(b, a), "{}", c.spec.name);
                }
            }
        }
    }

    #[test]
    fn dgx_flat_intra_node() {
        let c = Cluster::dgx(2);
        assert_eq!(c.link_between(0, 1), LinkClass::Intra(0)); // NVLink
        assert_eq!(c.link_between(0, 7), LinkClass::Intra(0));
        assert_eq!(c.link_between(0, 8), LinkClass::InterNode);
    }

    #[test]
    fn paper_bandwidth_numbers() {
        let f = MachineSpec::frontier_mi250x();
        assert_eq!(f.link_spec(LinkClass::Intra(0)).bandwidth, 200.0 * GB);
        assert_eq!(f.link_spec(LinkClass::Intra(1)).bandwidth, 100.0 * GB);
        assert_eq!(f.link_spec(LinkClass::Intra(2)).bandwidth, 50.0 * GB);
        assert_eq!(f.link_spec(LinkClass::InterNode).bandwidth, 100.0 * GB);
        let d = MachineSpec::dgx_a100();
        assert_eq!(d.link_spec(LinkClass::Intra(0)).bandwidth, 600.0 * GB);
        assert_eq!(d.link_spec(LinkClass::InterNode).bandwidth, 200.0 * GB);
    }

    #[test]
    fn bottleneck_of_groups() {
        let c = Cluster::frontier(2);
        assert_eq!(c.bottleneck_class(&[0, 1]), LinkClass::Intra(0));
        assert_eq!(c.bottleneck_class(&[0, 1, 2, 3]), LinkClass::Intra(1));
        assert_eq!(c.bottleneck_class(&[0, 1, 2, 3, 4, 5, 6, 7]), LinkClass::Intra(2));
        assert_eq!(c.bottleneck_class(&(0..16).collect::<Vec<_>>()), LinkClass::InterNode);
        assert_eq!(c.bottleneck_class(&[]), LinkClass::Local);
        assert_eq!(c.bottleneck_class(&[3, 3, 3]), LinkClass::Local);
    }

    #[test]
    fn bottleneck_equals_pairwise_definition() {
        // the O(n) min/max computation == the O(n²) definition, on every
        // builtin + a deep hypothetical machine, over random rank subsets
        let machines = all_test_machines();
        check("bottleneck O(n) == pairwise", 120, |g| {
            let m = g.pick(&machines).clone();
            let nodes = g.usize_in(1, 4);
            let c = Cluster::new(m, nodes);
            let world = c.world_size();
            let len = g.usize_in(1, 12);
            let ranks: Vec<usize> =
                (0..len).map(|_| g.usize_in(0, world - 1)).collect();
            assert_eq!(
                c.bottleneck_class(&ranks),
                bottleneck_pairwise(&c, &ranks),
                "{} nodes={nodes} ranks={ranks:?}",
                c.spec.name
            );
        });
    }

    #[test]
    fn severity_monotone_with_level_distance() {
        // for a <= b <= c, the (a,c) link is at least as severe as (a,b)
        // and (b,c): nested aligned blocks make severity monotone in span
        let machines = all_test_machines();
        check("severity monotone", 120, |g| {
            let m = g.pick(&machines).clone();
            let c = Cluster::new(m, 3);
            let world = c.world_size();
            let mut xs =
                [g.usize_in(0, world - 1), g.usize_in(0, world - 1), g.usize_in(0, world - 1)];
            xs.sort_unstable();
            let [a, b, cc] = xs;
            assert!(c.link_between(a, cc) >= c.link_between(a, b), "{}", c.spec.name);
            assert!(c.link_between(a, cc) >= c.link_between(b, cc), "{}", c.spec.name);
        });
    }

    #[test]
    fn ranks_by_node_partition() {
        let c = Cluster::frontier(3);
        let groups = c.ranks_by_node();
        assert_eq!(groups.len(), 3);
        let all: Vec<usize> = groups.concat();
        assert_eq!(all, (0..24).collect::<Vec<_>>());
        // and on a non-8-worker machine
        let c = Cluster::new(MachineSpec::aurora_pvc(), 2);
        let groups = c.ranks_by_node();
        assert_eq!(groups.len(), 2);
        assert_eq!(groups.concat(), (0..24).collect::<Vec<_>>());
    }

    #[test]
    fn innermost_groups() {
        // Frontier: GCD pairs, derived from the span (not rank ^ 1)
        let c = Cluster::frontier(1);
        assert_eq!(c.innermost_group(0), vec![0, 1]);
        assert_eq!(c.innermost_group(1), vec![0, 1]);
        assert_eq!(c.innermost_group(6), vec![6, 7]);
        for r in 0..8 {
            for &p in &c.innermost_group(r) {
                assert!(c.link_between(r, p) <= LinkClass::Intra(0));
            }
        }
        // DGX: the innermost level IS the whole node (group of 8)
        let d = Cluster::dgx(1);
        assert_eq!(d.innermost_group(3), (0..8).collect::<Vec<_>>());
        // level groups at outer tiers
        assert_eq!(c.level_group(5, 1), vec![4, 5, 6, 7]);
        assert_eq!(c.level_group(5, 2), (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn innermost_groups_partition_every_machine() {
        for m in all_test_machines() {
            let c = Cluster::new(m, 2);
            let mut seen = vec![false; c.world_size()];
            for r in 0..c.world_size() {
                let grp = c.innermost_group(r);
                assert!(grp.contains(&r), "{}", c.spec.name);
                assert_eq!(grp.len(), c.spec.innermost_span());
                for &p in &grp {
                    assert_eq!(c.innermost_group(p), grp, "{}", c.spec.name);
                }
                seen[r] = true;
            }
            assert!(seen.into_iter().all(|s| s));
        }
    }

    #[test]
    fn worker_specs() {
        assert_eq!(MachineSpec::frontier_mi250x().hbm_per_worker, 64e9);
        assert!(
            MachineSpec::dgx_a100().peak_flops_per_worker
                > MachineSpec::frontier_mi250x().peak_flops_per_worker
        );
        let c = Cluster::frontier(1);
        assert_eq!(c.peak_flops_per_worker(), 191.5e12);
        assert_eq!(c.hbm_per_worker(), 64e9);
        assert_eq!(c.workers_per_node(), 8);
    }

    #[test]
    fn severity_ordering_is_derived_ord() {
        assert!(LinkClass::Local < LinkClass::Intra(0));
        assert!(LinkClass::Intra(0) < LinkClass::Intra(1));
        assert!(LinkClass::Intra(1) < LinkClass::Intra(2));
        assert!(LinkClass::Intra(200) < LinkClass::InterNode);
    }
}
