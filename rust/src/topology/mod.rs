//! Hardware topology models — paper Section IV (Tables I & II, Figs 2 & 3).
//!
//! Frontier compute node: 4× AMD MI250X, each with 2 GCDs (8 GCDs/node).
//!   - GCD↔GCD inside one MI250X: 4 Infinity Fabric links, 200 GB/s
//!   - adjacent MI250X pair:      2 IF links, 100 GB/s
//!   - cross-pair MI250X:         1 IF link,   50 GB/s
//!   - inter-node:                4× HPE Slingshot 11, 100 GB/s total
//!
//! DGX-A100 node: 8× A100, NVLink3 600 GB/s all-to-all (NVSwitch), 8× IB
//! HDR = 200 GB/s inter-node.
//!
//! The resolver maps a pair of global ranks to the *link class* their
//! traffic crosses; collectives charge the α–β cost model at the slowest
//! class their device group spans (`comm::cost`).

use std::fmt;

/// Classes of links with distinct bandwidth/latency, ordered fastest→slowest
/// per node kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LinkClass {
    /// Same device (no wire) — zero cost.
    Local,
    /// Frontier: two GCDs inside one MI250X (B_GCD).
    GcdPair,
    /// Frontier: adjacent MI250X pair (2×IF).
    IntraAdjacent,
    /// Frontier: non-adjacent MI250X pair (1×IF).
    IntraCross,
    /// DGX: NVLink/NVSwitch between any two A100s.
    NvLink,
    /// Inter-node fabric (Slingshot-11 or InfiniBand).
    InterNode,
}

impl fmt::Display for LinkClass {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            LinkClass::Local => "local",
            LinkClass::GcdPair => "B_GCD (GCD-GCD)",
            LinkClass::IntraAdjacent => "B_intra (adjacent MI250X)",
            LinkClass::IntraCross => "B_intra (cross MI250X)",
            LinkClass::NvLink => "NVLink",
            LinkClass::InterNode => "B_inter (node-node)",
        };
        f.write_str(s)
    }
}

/// Link parameters for the α–β model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkSpec {
    /// Bandwidth in bytes/second.
    pub bandwidth: f64,
    /// Latency (α) in seconds per message.
    pub latency: f64,
}

const GB: f64 = 1e9;

/// Node flavors from the paper's Section IV.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeKind {
    /// ORNL Frontier: 4× MI250X = 8 GCDs (Table II).
    FrontierMI250X,
    /// NVIDIA DGX-A100: 8× A100 (Table I).
    DgxA100,
}

impl NodeKind {
    pub fn gcds_per_node(&self) -> usize {
        8
    }

    /// Peak dense fp16 FLOP/s per worker (GCD or GPU).
    /// MI250X: 383 TF per GPU → 191.5 TF per GCD. A100: 312 TF.
    pub fn peak_flops_per_worker(&self) -> f64 {
        match self {
            NodeKind::FrontierMI250X => 191.5e12,
            NodeKind::DgxA100 => 312e12,
        }
    }

    /// HBM per worker in bytes (GCD: 64 GB; A100: 80 GB).
    pub fn hbm_per_worker(&self) -> f64 {
        match self {
            NodeKind::FrontierMI250X => 64e9,
            NodeKind::DgxA100 => 80e9,
        }
    }

    /// The paper's bandwidth table (Section IV + Slingshot/NVLink specs).
    pub fn link_spec(&self, class: LinkClass) -> LinkSpec {
        match (self, class) {
            (_, LinkClass::Local) => LinkSpec { bandwidth: f64::INFINITY, latency: 0.0 },
            (NodeKind::FrontierMI250X, LinkClass::GcdPair) => {
                LinkSpec { bandwidth: 200.0 * GB, latency: 2e-6 }
            }
            (NodeKind::FrontierMI250X, LinkClass::IntraAdjacent) => {
                LinkSpec { bandwidth: 100.0 * GB, latency: 3e-6 }
            }
            (NodeKind::FrontierMI250X, LinkClass::IntraCross) => {
                LinkSpec { bandwidth: 50.0 * GB, latency: 3e-6 }
            }
            (NodeKind::FrontierMI250X, LinkClass::InterNode) => {
                // 4× Slingshot-11 ports = 100 GB/s per node.
                LinkSpec { bandwidth: 100.0 * GB, latency: 10e-6 }
            }
            (NodeKind::DgxA100, LinkClass::NvLink) => {
                LinkSpec { bandwidth: 600.0 * GB, latency: 2e-6 }
            }
            (NodeKind::DgxA100, LinkClass::InterNode) => {
                // 8× IB HDR = 200 GB/s per node.
                LinkSpec { bandwidth: 200.0 * GB, latency: 8e-6 }
            }
            // DGX has a flat intra-node fabric: every intra-node class is NVLink.
            (NodeKind::DgxA100, _) => LinkSpec { bandwidth: 600.0 * GB, latency: 2e-6 },
            // Frontier never resolves NvLink; treat as the GCD-pair link.
            (NodeKind::FrontierMI250X, LinkClass::NvLink) => {
                LinkSpec { bandwidth: 200.0 * GB, latency: 2e-6 }
            }
        }
    }
}

/// A cluster of identical nodes; ranks are GCDs (Frontier counts GCDs as
/// GPUs — paper §VI).
#[derive(Debug, Clone)]
pub struct Cluster {
    pub kind: NodeKind,
    pub nodes: usize,
}

impl Cluster {
    pub fn frontier(nodes: usize) -> Self {
        Cluster { kind: NodeKind::FrontierMI250X, nodes }
    }

    pub fn dgx(nodes: usize) -> Self {
        Cluster { kind: NodeKind::DgxA100, nodes }
    }

    pub fn world_size(&self) -> usize {
        self.nodes * self.kind.gcds_per_node()
    }

    pub fn node_of(&self, rank: usize) -> usize {
        rank / self.kind.gcds_per_node()
    }

    /// MI250X index within the node (Frontier: GCD pairs 0-1, 2-3, 4-5, 6-7).
    pub fn gpu_of(&self, rank: usize) -> usize {
        (rank % self.kind.gcds_per_node()) / 2
    }

    /// Resolve the link class a pair of ranks communicates over.
    pub fn link_between(&self, a: usize, b: usize) -> LinkClass {
        assert!(a < self.world_size() && b < self.world_size());
        if a == b {
            return LinkClass::Local;
        }
        if self.node_of(a) != self.node_of(b) {
            return LinkClass::InterNode;
        }
        match self.kind {
            NodeKind::DgxA100 => LinkClass::NvLink,
            NodeKind::FrontierMI250X => {
                let (ga, gb) = (self.gpu_of(a), self.gpu_of(b));
                if ga == gb {
                    LinkClass::GcdPair
                } else if ga / 2 == gb / 2 {
                    // MI250X 0-1 and 2-3 form adjacent pairs (2×IF);
                    // anything else crosses pairs (1×IF).
                    LinkClass::IntraAdjacent
                } else {
                    LinkClass::IntraCross
                }
            }
        }
    }

    /// Slowest link class spanned by a group of ranks — the bandwidth the
    /// paper's Tables VII/VIII attribute to each collective.
    pub fn bottleneck_class(&self, ranks: &[usize]) -> LinkClass {
        let mut worst = LinkClass::Local;
        for (i, &a) in ranks.iter().enumerate() {
            for &b in &ranks[i + 1..] {
                let c = self.link_between(a, b);
                if self.rank_class(c) > self.rank_class(worst) {
                    worst = c;
                }
            }
        }
        worst
    }

    /// Severity ordering of link classes for this node kind (higher = slower).
    fn rank_class(&self, c: LinkClass) -> u8 {
        match c {
            LinkClass::Local => 0,
            LinkClass::GcdPair => 1,
            LinkClass::NvLink => 1,
            LinkClass::IntraAdjacent => 2,
            LinkClass::IntraCross => 3,
            LinkClass::InterNode => 4,
        }
    }

    /// Spec of the bottleneck link for a group.
    pub fn bottleneck_spec(&self, ranks: &[usize]) -> LinkSpec {
        self.kind.link_spec(self.bottleneck_class(ranks))
    }

    /// All ranks grouped by node.
    pub fn ranks_by_node(&self) -> Vec<Vec<usize>> {
        let p = self.kind.gcds_per_node();
        (0..self.nodes).map(|n| (n * p..(n + 1) * p).collect()).collect()
    }

    /// The GCD-pair partner of a rank (Frontier primary-partition peer).
    pub fn gcd_pair_peer(&self, rank: usize) -> usize {
        rank ^ 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frontier_link_resolution() {
        let c = Cluster::frontier(2);
        assert_eq!(c.world_size(), 16);
        assert_eq!(c.link_between(0, 0), LinkClass::Local);
        assert_eq!(c.link_between(0, 1), LinkClass::GcdPair);
        assert_eq!(c.link_between(0, 2), LinkClass::IntraAdjacent);
        assert_eq!(c.link_between(0, 3), LinkClass::IntraAdjacent);
        assert_eq!(c.link_between(0, 4), LinkClass::IntraCross);
        assert_eq!(c.link_between(1, 7), LinkClass::IntraCross);
        assert_eq!(c.link_between(0, 8), LinkClass::InterNode);
        assert_eq!(c.link_between(7, 15), LinkClass::InterNode);
    }

    #[test]
    fn link_is_symmetric() {
        let c = Cluster::frontier(3);
        for a in 0..c.world_size() {
            for b in 0..c.world_size() {
                assert_eq!(c.link_between(a, b), c.link_between(b, a));
            }
        }
    }

    #[test]
    fn dgx_flat_intra_node() {
        let c = Cluster::dgx(2);
        assert_eq!(c.link_between(0, 1), LinkClass::NvLink);
        assert_eq!(c.link_between(0, 7), LinkClass::NvLink);
        assert_eq!(c.link_between(0, 8), LinkClass::InterNode);
    }

    #[test]
    fn paper_bandwidth_numbers() {
        let f = NodeKind::FrontierMI250X;
        assert_eq!(f.link_spec(LinkClass::GcdPair).bandwidth, 200.0 * GB);
        assert_eq!(f.link_spec(LinkClass::IntraAdjacent).bandwidth, 100.0 * GB);
        assert_eq!(f.link_spec(LinkClass::IntraCross).bandwidth, 50.0 * GB);
        assert_eq!(f.link_spec(LinkClass::InterNode).bandwidth, 100.0 * GB);
        let d = NodeKind::DgxA100;
        assert_eq!(d.link_spec(LinkClass::NvLink).bandwidth, 600.0 * GB);
        assert_eq!(d.link_spec(LinkClass::InterNode).bandwidth, 200.0 * GB);
        // paper: NVLink ~3x Infinity Fabric; DGX inter-node 2x Frontier
        assert_eq!(
            d.link_spec(LinkClass::NvLink).bandwidth / f.link_spec(LinkClass::GcdPair).bandwidth,
            3.0
        );
        assert_eq!(
            d.link_spec(LinkClass::InterNode).bandwidth
                / f.link_spec(LinkClass::InterNode).bandwidth,
            2.0
        );
    }

    #[test]
    fn bottleneck_of_groups() {
        let c = Cluster::frontier(2);
        assert_eq!(c.bottleneck_class(&[0, 1]), LinkClass::GcdPair);
        assert_eq!(c.bottleneck_class(&[0, 1, 2, 3]), LinkClass::IntraAdjacent);
        assert_eq!(c.bottleneck_class(&[0, 1, 2, 3, 4, 5, 6, 7]), LinkClass::IntraCross);
        assert_eq!(c.bottleneck_class(&(0..16).collect::<Vec<_>>()), LinkClass::InterNode);
    }

    #[test]
    fn ranks_by_node_partition() {
        let c = Cluster::frontier(3);
        let groups = c.ranks_by_node();
        assert_eq!(groups.len(), 3);
        let all: Vec<usize> = groups.concat();
        assert_eq!(all, (0..24).collect::<Vec<_>>());
    }

    #[test]
    fn gcd_pair_peers() {
        let c = Cluster::frontier(1);
        assert_eq!(c.gcd_pair_peer(0), 1);
        assert_eq!(c.gcd_pair_peer(1), 0);
        assert_eq!(c.gcd_pair_peer(6), 7);
        for r in 0..8 {
            assert_eq!(c.link_between(r, c.gcd_pair_peer(r)), LinkClass::GcdPair);
        }
    }

    #[test]
    fn worker_specs() {
        assert_eq!(NodeKind::FrontierMI250X.hbm_per_worker(), 64e9);
        assert!(NodeKind::DgxA100.peak_flops_per_worker() > NodeKind::FrontierMI250X.peak_flops_per_worker());
    }
}
