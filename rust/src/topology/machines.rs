//! Built-in machine specs — pure data constructors, no behavior.
//!
//! Frontier-MI250X and DGX-A100 carry the paper's Table I/II numbers
//! bit-for-bit (they replaced the old `NodeKind` enum arms). The rest are
//! data-only machines demonstrating that new topologies need no code:
//! Aurora (Intel PVC tiles), El Capitan (MI300A APUs), and a flat-fabric
//! TPU-pod-like spec. JSON twins of the non-paper machines live in
//! `examples/machines/` and are load-tested by `tests/machine_json.rs`.

use super::spec::{LinkSpec, MachineLevel, MachineSpec, StorageSpec};

const GB: f64 = 1e9;

/// Canonical names accepted by [`MachineSpec::builtin`] (aliases exist).
pub const BUILTIN_NAMES: [&str; 5] = ["frontier", "dgx", "aurora", "elcapitan", "tpu-pod"];

fn level(name: &str, span: usize, bandwidth: f64, latency: f64) -> MachineLevel {
    MachineLevel { name: name.into(), span, link: LinkSpec { bandwidth, latency } }
}

impl MachineSpec {
    /// ORNL Frontier: 4× MI250X = 8 GCDs per node (paper Table II, Fig 3).
    /// GCD pair 200 GB/s (4×IF), adjacent MI250X 100 GB/s (2×IF),
    /// cross-pair 50 GB/s (1×IF), 4× Slingshot-11 = 100 GB/s inter-node.
    pub fn frontier_mi250x() -> MachineSpec {
        MachineSpec {
            name: "frontier-mi250x".into(),
            workers_per_node: 8,
            // MI250X: 383 TF per GPU -> 191.5 TF per GCD.
            peak_flops_per_worker: 191.5e12,
            hbm_per_worker: 64e9,
            levels: vec![
                level("B_GCD (GCD-GCD)", 2, 200.0 * GB, 2e-6),
                level("B_intra (adjacent MI250X)", 4, 100.0 * GB, 3e-6),
                level("B_intra (cross MI250X)", 8, 50.0 * GB, 3e-6),
            ],
            inter_node: LinkSpec { bandwidth: 100.0 * GB, latency: 10e-6 },
            // Orion (Lustre): ~5 GB/s sustained write, ~10 GB/s read per
            // node through the burst path, ~1 ms metadata latency.
            storage: StorageSpec {
                write_bandwidth: 5.0 * GB,
                read_bandwidth: 10.0 * GB,
                latency: 1e-3,
            },
        }
    }

    /// NVIDIA DGX-A100: 8× A100, NVSwitch all-to-all (one flat intra
    /// level), 8× IB HDR = 200 GB/s inter-node (paper Table I).
    pub fn dgx_a100() -> MachineSpec {
        MachineSpec {
            name: "dgx-a100".into(),
            workers_per_node: 8,
            peak_flops_per_worker: 312e12,
            hbm_per_worker: 80e9,
            levels: vec![level("NVLink", 8, 600.0 * GB, 2e-6)],
            inter_node: LinkSpec { bandwidth: 200.0 * GB, latency: 8e-6 },
            // local NVMe RAID: ~8 GB/s write, ~16 GB/s read, ~0.1 ms.
            storage: StorageSpec {
                write_bandwidth: 8.0 * GB,
                read_bandwidth: 16.0 * GB,
                latency: 1e-4,
            },
        }
    }

    /// ANL Aurora: 6× Intel Data Center GPU Max (PVC) per node, 2 tiles
    /// each = 12 workers. Tile pairs ride the on-package fabric; GPUs are
    /// Xe-Link connected; 8× Slingshot-11 NICs = 200 GB/s inter-node.
    pub fn aurora_pvc() -> MachineSpec {
        MachineSpec {
            name: "aurora-pvc".into(),
            workers_per_node: 12,
            // ~418 TF fp16 per PVC -> 209 TF per tile.
            peak_flops_per_worker: 209e12,
            hbm_per_worker: 64e9,
            levels: vec![
                level("tile-pair (on-package)", 2, 400.0 * GB, 2e-6),
                level("Xe-Link (node)", 12, 100.0 * GB, 3e-6),
            ],
            inter_node: LinkSpec { bandwidth: 200.0 * GB, latency: 10e-6 },
            // non-paper machines keep the generic default storage path so
            // their committed JSON twins (which predate the field) still
            // parse to identical specs.
            storage: StorageSpec::default(),
        }
    }

    /// LLNL El Capitan: 4× AMD MI300A APUs per node, Infinity Fabric
    /// all-to-all (one flat intra level), 4× Slingshot = 200 GB/s.
    pub fn el_capitan_mi300a() -> MachineSpec {
        MachineSpec {
            name: "elcapitan-mi300a".into(),
            workers_per_node: 4,
            peak_flops_per_worker: 490e12,
            hbm_per_worker: 128e9,
            levels: vec![level("IF (APU-APU)", 4, 256.0 * GB, 2e-6)],
            inter_node: LinkSpec { bandwidth: 200.0 * GB, latency: 10e-6 },
            storage: StorageSpec::default(),
        }
    }

    /// A flat-fabric TPU-pod-like machine: 4 accelerators per "node"
    /// (tray) on fast ICI, modest per-tray external bandwidth. Stresses
    /// the opposite regime from Frontier: one intra level, slow fabric.
    pub fn tpu_pod() -> MachineSpec {
        MachineSpec {
            name: "tpu-pod".into(),
            workers_per_node: 4,
            peak_flops_per_worker: 275e12,
            hbm_per_worker: 32e9,
            levels: vec![level("ICI (tray)", 4, 600.0 * GB, 1e-6)],
            inter_node: LinkSpec { bandwidth: 50.0 * GB, latency: 5e-6 },
            storage: StorageSpec::default(),
        }
    }

    /// Look up a builtin by (case-insensitive) name or alias.
    pub fn builtin(name: &str) -> Option<MachineSpec> {
        match name.to_ascii_lowercase().as_str() {
            "frontier" | "frontier-mi250x" | "mi250x" => Some(Self::frontier_mi250x()),
            "dgx" | "dgx-a100" | "a100" => Some(Self::dgx_a100()),
            "aurora" | "aurora-pvc" | "pvc" => Some(Self::aurora_pvc()),
            "elcapitan" | "el-capitan" | "elcapitan-mi300a" | "mi300a" => {
                Some(Self::el_capitan_mi300a())
            }
            "tpu-pod" | "tpu" | "tpupod" => Some(Self::tpu_pod()),
            _ => None,
        }
    }

    /// Every builtin spec, in a stable order.
    pub fn builtins() -> Vec<MachineSpec> {
        BUILTIN_NAMES.iter().map(|n| Self::builtin(n).expect("builtin")).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn all_builtins_validate() {
        for m in MachineSpec::builtins() {
            m.validate().unwrap_or_else(|e| panic!("{}: {e}", m.name));
        }
    }

    #[test]
    fn frontier_matches_paper_table2() {
        let f = MachineSpec::frontier_mi250x();
        assert_eq!(f.workers_per_node, 8);
        assert_eq!(f.peak_flops_per_worker, 191.5e12);
        assert_eq!(f.hbm_per_worker, 64e9);
        assert_eq!(f.level_spans(), vec![2, 4, 8]);
        assert_eq!(f.levels[0].link.bandwidth, 200.0 * GB);
        assert_eq!(f.levels[1].link.bandwidth, 100.0 * GB);
        assert_eq!(f.levels[2].link.bandwidth, 50.0 * GB);
        assert_eq!(f.inter_node.bandwidth, 100.0 * GB);
    }

    #[test]
    fn dgx_matches_paper_table1() {
        let d = MachineSpec::dgx_a100();
        assert_eq!(d.workers_per_node, 8);
        assert_eq!(d.peak_flops_per_worker, 312e12);
        assert_eq!(d.hbm_per_worker, 80e9);
        assert_eq!(d.level_spans(), vec![8]);
        assert_eq!(d.levels[0].link.bandwidth, 600.0 * GB);
        assert_eq!(d.inter_node.bandwidth, 200.0 * GB);
        // paper §IV: NVLink ~3x Infinity Fabric; DGX inter-node 2x Frontier
        let f = MachineSpec::frontier_mi250x();
        assert_eq!(d.levels[0].link.bandwidth / f.levels[0].link.bandwidth, 3.0);
        assert_eq!(d.inter_node.bandwidth / f.inter_node.bandwidth, 2.0);
    }

    #[test]
    fn storage_paths_match_their_filesystems() {
        // paper machines get realistic checkpoint paths...
        let f = MachineSpec::frontier_mi250x();
        assert_eq!(f.storage.write_bandwidth, 5.0 * GB);
        assert_eq!(f.storage.read_bandwidth, 10.0 * GB);
        let d = MachineSpec::dgx_a100();
        assert_eq!(d.storage.write_bandwidth, 8.0 * GB);
        assert!(d.storage.latency < f.storage.latency); // NVMe vs Lustre
        // ...while the data-only machines keep the default so their
        // committed JSON twins (no "storage" key) parse to equal specs
        for name in ["aurora", "elcapitan", "tpu-pod"] {
            let m = MachineSpec::builtin(name).unwrap();
            assert_eq!(m.storage, StorageSpec::default(), "{name}");
        }
    }

    #[test]
    fn aliases_resolve() {
        assert_eq!(MachineSpec::builtin("FRONTIER").unwrap().name, "frontier-mi250x");
        assert_eq!(MachineSpec::builtin("mi300a").unwrap().name, "elcapitan-mi300a");
        assert_eq!(MachineSpec::builtin("tpu").unwrap().name, "tpu-pod");
        assert!(MachineSpec::builtin("summit").is_none());
    }

    #[test]
    fn builtins_roundtrip_through_json() {
        for m in MachineSpec::builtins() {
            let j = m.to_json().to_string();
            let re = MachineSpec::from_json(&Json::parse(&j).unwrap()).unwrap();
            assert_eq!(m, re, "{}", m.name);
        }
    }
}
