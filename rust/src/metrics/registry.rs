//! A small, dependency-free metrics registry: labeled counters, gauges,
//! and fixed-bucket histograms with deterministic snapshot order and
//! JSON / Prometheus-text export.
//!
//! Everything is plain data — the registry never reads a clock. Values in
//! the *simulated* domain (step seconds, busy seconds, bytes) come from
//! the event clock and the cost ledger; wall-clock self-profiling of the
//! simulator itself lives in `sim::SimProfile` and is exported under
//! explicit `*_wall_*` names so the two time domains can never be
//! confused (DESIGN.md §13).
//!
//! Determinism: metric families and label sets are stored in `BTreeMap`s,
//! so [`Registry::snapshot`], [`Registry::to_json`], and
//! [`Registry::to_prometheus`] emit samples in one canonical order
//! regardless of insertion order.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::util::json::Json;

/// A sorted label set (`key -> value`), the identity of one sample within
/// a metric family.
pub type Labels = BTreeMap<String, String>;

/// Build a [`Labels`] map from `(key, value)` pairs.
pub fn labels(pairs: &[(&str, &str)]) -> Labels {
    pairs.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect()
}

/// A fixed-bucket histogram: explicit finite upper bounds plus the
/// implicit `+Inf` overflow bucket, with running `sum` and `count`.
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    sum: f64,
    count: u64,
}

impl Histogram {
    /// A histogram over strictly increasing finite upper `bounds`.
    pub fn new(bounds: &[f64]) -> Histogram {
        assert!(!bounds.is_empty(), "histogram needs at least one bucket bound");
        assert!(bounds.iter().all(|b| b.is_finite()), "histogram bounds must be finite");
        assert!(bounds.windows(2).all(|w| w[0] < w[1]), "histogram bounds must increase");
        Histogram { bounds: bounds.to_vec(), counts: vec![0; bounds.len() + 1], sum: 0.0, count: 0 }
    }

    /// Record one observation (`v <= bounds[i]` lands in bucket `i`).
    pub fn observe(&mut self, v: f64) {
        assert!(v.is_finite(), "histogram observation must be finite");
        let idx = self.bounds.iter().position(|&b| v <= b).unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.sum += v;
        self.count += 1;
    }

    /// Total observations.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of all observations.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// The finite upper bounds this histogram was built with.
    pub fn bounds(&self) -> &[f64] {
        &self.bounds
    }

    /// Cumulative bucket counts in Prometheus `le` convention: entry `i`
    /// counts observations `<= bounds[i]`; the final entry (`+Inf`) equals
    /// [`Histogram::count`].
    pub fn cumulative(&self) -> Vec<u64> {
        let mut acc = 0u64;
        let mut out = Vec::with_capacity(self.counts.len());
        for &c in &self.counts {
            acc += c;
            out.push(acc);
        }
        out
    }
}

/// One flattened sample of a [`Registry`] snapshot.
#[derive(Debug, Clone, PartialEq)]
pub struct Sample {
    /// Metric family name (e.g. `sim_step_seconds`).
    pub name: String,
    /// Label set identifying the sample within its family. Histogram
    /// bucket samples carry a synthetic `le` label.
    pub labels: Labels,
    /// Sample value (bucket and `_count` samples are exact integers).
    pub value: f64,
}

/// Labeled counters, gauges, and histograms with deterministic export.
#[derive(Debug, Clone, Default)]
pub struct Registry {
    counters: BTreeMap<String, BTreeMap<Labels, f64>>,
    gauges: BTreeMap<String, BTreeMap<Labels, f64>>,
    histograms: BTreeMap<String, BTreeMap<Labels, Histogram>>,
}

impl Registry {
    /// An empty registry.
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Add `delta` (must be finite and `>= 0`) to a counter sample,
    /// creating it at zero first if absent.
    pub fn inc(&mut self, name: &str, label_pairs: &[(&str, &str)], delta: f64) {
        assert!(delta.is_finite() && delta >= 0.0, "counter increments must be finite and >= 0");
        let family = self.counters.entry(name.to_string()).or_default();
        *family.entry(labels(label_pairs)).or_insert(0.0) += delta;
    }

    /// Set a gauge sample to `value` (must be finite).
    pub fn set(&mut self, name: &str, label_pairs: &[(&str, &str)], value: f64) {
        assert!(value.is_finite(), "gauge values must be finite");
        self.gauges.entry(name.to_string()).or_default().insert(labels(label_pairs), value);
    }

    /// Record one histogram observation; the sample's histogram is created
    /// with `bounds` on first use (later calls must pass the same bounds).
    pub fn observe(&mut self, name: &str, label_pairs: &[(&str, &str)], bounds: &[f64], v: f64) {
        let family = self.histograms.entry(name.to_string()).or_default();
        let hist = family.entry(labels(label_pairs)).or_insert_with(|| Histogram::new(bounds));
        assert_eq!(hist.bounds(), bounds, "histogram {name} re-observed with different bounds");
        hist.observe(v);
    }

    /// Current value of a counter sample (0 if never incremented).
    pub fn counter(&self, name: &str, label_pairs: &[(&str, &str)]) -> f64 {
        let key = labels(label_pairs);
        self.counters.get(name).and_then(|m| m.get(&key)).copied().unwrap_or(0.0)
    }

    /// Current value of a gauge sample, if it was ever set.
    pub fn gauge(&self, name: &str, label_pairs: &[(&str, &str)]) -> Option<f64> {
        let key = labels(label_pairs);
        self.gauges.get(name).and_then(|m| m.get(&key)).copied()
    }

    /// The histogram behind a sample, if any observation was recorded.
    pub fn histogram(&self, name: &str, label_pairs: &[(&str, &str)]) -> Option<&Histogram> {
        let key = labels(label_pairs);
        self.histograms.get(name).and_then(|m| m.get(&key))
    }

    /// Flatten every sample into one deterministic, sorted list: counters,
    /// then gauges, then histograms (each histogram expands into
    /// `_bucket{le=...}` samples plus `_sum` and `_count`).
    pub fn snapshot(&self) -> Vec<Sample> {
        let mut out = Vec::new();
        for (name, family) in &self.counters {
            for (ls, v) in family {
                out.push(Sample { name: name.clone(), labels: ls.clone(), value: *v });
            }
        }
        for (name, family) in &self.gauges {
            for (ls, v) in family {
                out.push(Sample { name: name.clone(), labels: ls.clone(), value: *v });
            }
        }
        for (name, family) in &self.histograms {
            for (ls, h) in family {
                for (bound, cum) in hist_buckets(h) {
                    let mut bl = ls.clone();
                    bl.insert("le".to_string(), bound);
                    let name = format!("{name}_bucket");
                    out.push(Sample { name, labels: bl, value: cum as f64 });
                }
                out.push(Sample { name: format!("{name}_sum"), labels: ls.clone(), value: h.sum });
                let count = h.count as f64;
                out.push(Sample { name: format!("{name}_count"), labels: ls.clone(), value: count });
            }
        }
        out
    }

    /// Export the registry as one JSON document (deterministic key order).
    pub fn to_json(&self) -> Json {
        let mut counters = BTreeMap::new();
        for (name, family) in &self.counters {
            counters.insert(name.clone(), scalar_family_json(family));
        }
        let mut gauges = BTreeMap::new();
        for (name, family) in &self.gauges {
            gauges.insert(name.clone(), scalar_family_json(family));
        }
        let mut hists = BTreeMap::new();
        for (name, family) in &self.histograms {
            let mut samples = Vec::new();
            for (ls, h) in family {
                let mut buckets = Vec::new();
                for (bound, cum) in hist_buckets(h) {
                    let b = Json::obj(vec![("le", Json::str(bound)), ("count", Json::from(cum))]);
                    buckets.push(b);
                }
                samples.push(Json::obj(vec![
                    ("labels", labels_json(ls)),
                    ("buckets", Json::arr(buckets)),
                    ("sum", Json::num(h.sum)),
                    ("count", Json::from(h.count)),
                ]));
            }
            hists.insert(name.clone(), Json::arr(samples));
        }
        Json::obj(vec![
            ("counters", Json::Obj(counters)),
            ("gauges", Json::Obj(gauges)),
            ("histograms", Json::Obj(hists)),
        ])
    }

    /// Export the registry in the Prometheus text exposition format
    /// (`# TYPE` headers, `name{labels} value` lines, histogram `le`
    /// buckets), in deterministic order.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        for (name, family) in &self.counters {
            let name = prom_name(name);
            let _ = writeln!(out, "# TYPE {name} counter");
            for (ls, v) in family {
                let _ = writeln!(out, "{name}{} {v}", prom_labels(ls));
            }
        }
        for (name, family) in &self.gauges {
            let name = prom_name(name);
            let _ = writeln!(out, "# TYPE {name} gauge");
            for (ls, v) in family {
                let _ = writeln!(out, "{name}{} {v}", prom_labels(ls));
            }
        }
        for (name, family) in &self.histograms {
            let name = prom_name(name);
            let _ = writeln!(out, "# TYPE {name} histogram");
            for (ls, h) in family {
                for (bound, cum) in hist_buckets(h) {
                    let mut bl = ls.clone();
                    bl.insert("le".to_string(), bound);
                    let _ = writeln!(out, "{name}_bucket{} {cum}", prom_labels(&bl));
                }
                let _ = writeln!(out, "{name}_sum{} {}", prom_labels(ls), h.sum);
                let _ = writeln!(out, "{name}_count{} {}", prom_labels(ls), h.count);
            }
        }
        out
    }
}

/// Histogram buckets as `(le-label, cumulative count)` pairs, ending with
/// the `+Inf` bucket.
fn hist_buckets(h: &Histogram) -> Vec<(String, u64)> {
    let cum = h.cumulative();
    let mut out = Vec::with_capacity(cum.len());
    for (b, c) in h.bounds().iter().zip(&cum) {
        out.push((format!("{b}"), *c));
    }
    out.push(("+Inf".to_string(), *cum.last().expect("histogram has buckets")));
    out
}

fn scalar_family_json(family: &BTreeMap<Labels, f64>) -> Json {
    let mut samples = Vec::new();
    for (ls, v) in family {
        samples.push(Json::obj(vec![("labels", labels_json(ls)), ("value", Json::num(*v))]));
    }
    Json::arr(samples)
}

fn labels_json(ls: &Labels) -> Json {
    Json::Obj(ls.iter().map(|(k, v)| (k.clone(), Json::str(v.clone()))).collect())
}

/// Map a metric name onto the Prometheus grammar
/// (`[a-zA-Z_:][a-zA-Z0-9_:]*`): every other byte becomes `_`.
fn prom_name(name: &str) -> String {
    let ok = |c: char| c.is_ascii_alphanumeric() || c == '_' || c == ':';
    let mut s: String = name.chars().map(|c| if ok(c) { c } else { '_' }).collect();
    if s.chars().next().is_none_or(|c| c.is_ascii_digit()) {
        s.insert(0, '_');
    }
    s
}

/// Render a label set as `{k="v",...}` with Prometheus escaping; empty
/// label sets render as the empty string.
fn prom_labels(ls: &Labels) -> String {
    if ls.is_empty() {
        return String::new();
    }
    let mut body = Vec::with_capacity(ls.len());
    for (k, v) in ls {
        let v = v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n");
        body.push(format!("{}=\"{v}\"", prom_name(k)));
    }
    format!("{{{}}}", body.join(","))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_per_label_set() {
        let mut r = Registry::new();
        r.inc("bytes_total", &[("class", "inter")], 10.0);
        r.inc("bytes_total", &[("class", "inter")], 5.0);
        r.inc("bytes_total", &[("class", "intra0")], 1.0);
        assert_eq!(r.counter("bytes_total", &[("class", "inter")]), 15.0);
        assert_eq!(r.counter("bytes_total", &[("class", "intra0")]), 1.0);
        assert_eq!(r.counter("bytes_total", &[("class", "nope")]), 0.0);
    }

    #[test]
    fn gauges_overwrite() {
        let mut r = Registry::new();
        r.set("step_seconds", &[], 2.0);
        r.set("step_seconds", &[], 3.5);
        assert_eq!(r.gauge("step_seconds", &[]), Some(3.5));
        assert_eq!(r.gauge("missing", &[]), None);
    }

    #[test]
    fn histogram_buckets_and_cumulative_counts() {
        let mut h = Histogram::new(&[0.1, 1.0, 10.0]);
        for v in [0.05, 0.5, 0.5, 5.0, 50.0] {
            h.observe(v);
        }
        assert_eq!(h.count(), 5);
        assert!((h.sum() - 56.05).abs() < 1e-12);
        assert_eq!(h.cumulative(), vec![1, 3, 4, 5]);
    }

    #[test]
    fn snapshot_is_sorted_and_complete() {
        let mut r = Registry::new();
        r.set("z_gauge", &[], 1.0);
        r.inc("a_counter", &[("k", "v")], 2.0);
        r.observe("lat", &[], &[1.0], 0.5);
        let snap = r.snapshot();
        let names: Vec<&str> = snap.iter().map(|s| s.name.as_str()).collect();
        // counters, then gauges, then histogram expansion
        let want = vec!["a_counter", "z_gauge", "lat_bucket", "lat_bucket", "lat_sum", "lat_count"];
        assert_eq!(names, want);
        assert_eq!(snap[2].labels.get("le").map(String::as_str), Some("1"));
        assert_eq!(snap[3].labels.get("le").map(String::as_str), Some("+Inf"));
    }

    #[test]
    fn json_export_parses_and_is_deterministic() {
        let mut r = Registry::new();
        r.inc("steps_total", &[("scheme", "ZeRO-topo")], 3.0);
        r.set("tflops_per_gcd", &[("scheme", "ZeRO-topo")], 71.4);
        r.observe("step_seconds_hist", &[], &[10.0, 20.0], 12.9);
        let a = r.to_json().to_string();
        let b = r.clone().to_json().to_string();
        assert_eq!(a, b);
        let parsed = Json::parse(&a).unwrap();
        let fam = parsed.at(&["counters", "steps_total"]).and_then(|s| s.as_arr()).unwrap();
        assert_eq!(fam[0].get("value").and_then(|v| v.as_f64()), Some(3.0));
        assert_eq!(fam[0].at(&["labels", "scheme"]).and_then(|v| v.as_str()), Some("ZeRO-topo"));
        let hist = parsed.at(&["histograms", "step_seconds_hist"]).unwrap().as_arr().unwrap();
        assert_eq!(hist[0].get("count").and_then(|c| c.as_f64()), Some(1.0));
        assert_eq!(hist[0].get("buckets").and_then(|b| b.as_arr()).map(|b| b.len()), Some(3));
    }

    #[test]
    fn prometheus_text_format() {
        let mut r = Registry::new();
        r.inc("sim_bytes_total", &[("class", "B_inter (node-node)")], 4096.0);
        r.set("sim_step_seconds", &[("scheme", "ZeRO-3")], 33.5);
        r.observe("sim_step_hist", &[], &[10.0, 100.0], 33.5);
        let text = r.to_prometheus();
        assert!(text.contains("# TYPE sim_bytes_total counter\n"));
        assert!(text.contains("sim_bytes_total{class=\"B_inter (node-node)\"} 4096\n"));
        assert!(text.contains("sim_step_seconds{scheme=\"ZeRO-3\"} 33.5\n"));
        assert!(text.contains("sim_step_hist_bucket{le=\"100\"} 1\n"));
        assert!(text.contains("sim_step_hist_bucket{le=\"+Inf\"} 1\n"));
        assert!(text.contains("sim_step_hist_count 1\n"));
        // every non-comment line is `name{...} value` with a sane name
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let name: String = line.chars().take_while(|&c| c != '{' && c != ' ').collect();
            assert!(name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':'));
        }
    }

    #[test]
    fn prom_name_sanitizes() {
        assert_eq!(prom_name("B_inter (node-node)"), "B_inter__node_node_");
        assert_eq!(prom_name("0abc"), "_0abc");
        assert_eq!(prom_name(""), "_");
    }
}
