//! Link shadow prices: ∂step-time/∂knob by re-pricing a plan under a
//! perturbed [`MachineSpec`] (DESIGN.md §14).
//!
//! A knob's **shadow price** is the step-time saving from a one-notch
//! improvement — bandwidth or peak compute doubled, latency halved,
//! prefetch depth +1, layer blocks ×2, the next secondary degree — and,
//! for the continuous machine knobs, the ε-probe derivative
//! `(step(1) - step(1+ε)) / ε`. Ranked descending by saving, the table
//! answers the planner's question directly: *which resource is binding,
//! and what is a unit of it worth?* ("doubling inter-node BW saves
//! 15.4 s for ZeRO-3 and 0.49 s for ZeRO-topo" is the paper's Fig-7
//! story as a first-class artifact — see EXPERIMENTS.md §Bottleneck
//! attribution.)
//!
//! This module owns the machine-knob enumeration and the sweep loop; the
//! simulator evaluator lives in [`crate::sim::shadow_prices`], which also
//! appends the discrete schedule knobs it owns (depth/blocks/sec_degree).

use crate::topology::{LinkClass, MachineSpec};

/// Default relative step for the derivative probe.
pub const DEFAULT_EPSILON: f64 = 0.05;

/// One tunable the sweep perturbs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Knob {
    /// Peak FLOP/s per worker (the compute side of the ledger — without
    /// it a compute-bound step would misattribute its binding resource
    /// to whichever link saves a few milliseconds).
    ComputeRate,
    /// Bandwidth of one link class (±ε per level, one-notch ×2).
    LinkBandwidth(LinkClass),
    /// Latency (α) of one link class (one-notch ÷2).
    LinkLatency(LinkClass),
    /// Prefetch depth +1 (bounded depths only; discrete, evaluator-owned).
    PrefetchDepth,
    /// Layer-granular gather blocks ×2 (discrete, evaluator-owned).
    LayerBlocks,
    /// ZeRO-topo secondary degree bumped to the next level span
    /// (discrete, evaluator-owned).
    SecDegree,
}

impl Knob {
    /// Human-readable row label, resolving link classes against
    /// `machine`'s level names.
    pub fn label(&self, machine: &MachineSpec) -> String {
        match self {
            Knob::ComputeRate => "peak compute (FLOP/s)".into(),
            Knob::LinkBandwidth(c) => format!("BW {}", machine.class_label(*c)),
            Knob::LinkLatency(c) => format!("lat {}", machine.class_label(*c)),
            Knob::PrefetchDepth => "prefetch depth (+1)".into(),
            Knob::LayerBlocks => "layer blocks (x2)".into(),
            Knob::SecDegree => "secondary degree (next span)".into(),
        }
    }

    /// The machine knobs for `machine` in report order: compute rate
    /// first, then bandwidths fastest link first, then latencies. The
    /// discrete schedule knobs are appended by the evaluator that owns
    /// their configuration ([`crate::sim::shadow_prices`]).
    pub fn machine_knobs(machine: &MachineSpec) -> Vec<Knob> {
        let mut knobs = vec![Knob::ComputeRate];
        knobs.extend(machine.classes().into_iter().map(Knob::LinkBandwidth));
        knobs.extend(machine.classes().into_iter().map(Knob::LinkLatency));
        knobs
    }

    /// `machine` with this knob improved by `factor >= 1`: bandwidth and
    /// compute scale up by `factor`, latency scales down by `factor`.
    /// `None` when the knob is not a machine knob, targets a `Local`
    /// link, or the perturbed spec fails validation (an inner level
    /// overtaken by a boosted outer one must be skipped, not priced).
    pub fn improve(&self, machine: &MachineSpec, factor: f64) -> Option<MachineSpec> {
        debug_assert!(factor >= 1.0, "improve() wants a factor >= 1");
        let mut m = machine.clone();
        match *self {
            Knob::ComputeRate => m.peak_flops_per_worker *= factor,
            Knob::LinkBandwidth(LinkClass::Intra(k)) => {
                m.levels.get_mut(k as usize)?.link.bandwidth *= factor;
            }
            Knob::LinkBandwidth(LinkClass::InterNode) => m.inter_node.bandwidth *= factor,
            Knob::LinkLatency(LinkClass::Intra(k)) => {
                m.levels.get_mut(k as usize)?.link.latency /= factor;
            }
            Knob::LinkLatency(LinkClass::InterNode) => m.inter_node.latency /= factor,
            Knob::LinkBandwidth(LinkClass::Local)
            | Knob::LinkLatency(LinkClass::Local)
            | Knob::PrefetchDepth
            | Knob::LayerBlocks
            | Knob::SecDegree => return None,
        }
        m.validate().ok()?;
        Some(m)
    }
}

/// One ranked row of the shadow-price table.
#[derive(Debug, Clone)]
pub struct ShadowPrice {
    /// Which knob was improved.
    pub knob: Knob,
    /// Its display label (resolved against the base machine).
    pub label: String,
    /// Step seconds under the one-notch improvement.
    pub improved_s: f64,
    /// `base_s - improved_s` — the ranking key. Non-negative for pure
    /// bandwidth/compute increases; discrete knobs may price negative
    /// (the current setting is already optimal).
    pub saving: f64,
    /// ε-probe derivative `(base - step(1+ε)) / ε` for continuous
    /// machine knobs; `None` for the discrete ones.
    pub derivative: Option<f64>,
}

/// The ranked shadow-price table for one (plan, machine) pair.
#[derive(Debug, Clone)]
pub struct SensitivityReport {
    /// Unperturbed step seconds.
    pub base_s: f64,
    /// Relative ε used for the derivative probes.
    pub epsilon: f64,
    /// Rows sorted by descending saving (stable: exact ties keep the
    /// [`Knob::machine_knobs`] enumeration order).
    pub prices: Vec<ShadowPrice>,
}

impl SensitivityReport {
    /// The highest-priced knob, if any knob was evaluable.
    pub fn top(&self) -> Option<&ShadowPrice> {
        self.prices.first()
    }

    /// Zero-based rank of the first row matching `pred`.
    pub fn rank_of(&self, pred: impl Fn(&Knob) -> bool) -> Option<usize> {
        self.prices.iter().position(|p| pred(&p.knob))
    }

    /// Insert an evaluator-owned row and restore the ranking order.
    pub fn add(&mut self, price: ShadowPrice) {
        self.prices.push(price);
        sort_prices(&mut self.prices);
    }
}

fn sort_prices(prices: &mut [ShadowPrice]) {
    // stable sort: exact ties (typically 0.0 savings) keep knob order
    prices.sort_by(|a, b| b.saving.partial_cmp(&a.saving).unwrap_or(std::cmp::Ordering::Equal));
}

/// Sweep every machine knob: re-evaluate `eval` under the one-notch
/// (factor 2) improvement and the ε derivative probe. `eval` returns the
/// re-simulated step seconds for a perturbed machine, or `None` to drop
/// the knob (infeasible point). Rows come back ranked by saving.
pub fn sweep(
    machine: &MachineSpec,
    base_s: f64,
    epsilon: f64,
    mut eval: impl FnMut(&MachineSpec) -> Option<f64>,
) -> SensitivityReport {
    assert!(epsilon > 0.0 && epsilon.is_finite(), "epsilon must be a positive relative step");
    let mut prices = Vec::new();
    for knob in Knob::machine_knobs(machine) {
        let Some(doubled) = knob.improve(machine, 2.0) else { continue };
        let Some(improved_s) = eval(&doubled) else { continue };
        let derivative = knob
            .improve(machine, 1.0 + epsilon)
            .and_then(|m| eval(&m))
            .map(|t| (base_s - t) / epsilon);
        prices.push(ShadowPrice {
            knob,
            label: knob.label(machine),
            improved_s,
            saving: base_s - improved_s,
            derivative,
        });
    }
    sort_prices(&mut prices);
    SensitivityReport { base_s, epsilon, prices }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn machine_knobs_enumerate_compute_then_bw_then_lat() {
        let m = MachineSpec::frontier_mi250x();
        let knobs = Knob::machine_knobs(&m);
        assert_eq!(knobs[0], Knob::ComputeRate);
        assert_eq!(knobs[1], Knob::LinkBandwidth(LinkClass::Intra(0)));
        assert_eq!(knobs[4], Knob::LinkBandwidth(LinkClass::InterNode));
        assert_eq!(*knobs.last().unwrap(), Knob::LinkLatency(LinkClass::InterNode));
        assert_eq!(knobs.len(), 1 + 2 * 4);
    }

    #[test]
    fn improve_scales_the_right_field() {
        let m = MachineSpec::frontier_mi250x();
        let b = Knob::LinkBandwidth(LinkClass::InterNode).improve(&m, 2.0).unwrap();
        assert_eq!(b.inter_node.bandwidth, 2.0 * m.inter_node.bandwidth);
        assert_eq!(b.levels, m.levels);
        let l = Knob::LinkLatency(LinkClass::Intra(0)).improve(&m, 2.0).unwrap();
        assert_eq!(l.levels[0].link.latency, m.levels[0].link.latency / 2.0);
        let c = Knob::ComputeRate.improve(&m, 2.0).unwrap();
        assert_eq!(c.peak_flops_per_worker, 2.0 * m.peak_flops_per_worker);
        assert!(Knob::PrefetchDepth.improve(&m, 2.0).is_none());
        assert!(Knob::LinkBandwidth(LinkClass::Local).improve(&m, 2.0).is_none());
    }

    #[test]
    fn improve_rejects_invalid_perturbations() {
        // boosting an outer level 8x overtakes the inner levels: the
        // perturbed spec fails validation and the knob must drop out
        let m = MachineSpec::frontier_mi250x();
        assert!(Knob::LinkBandwidth(LinkClass::Intra(2)).improve(&m, 8.0).is_none());
        assert!(Knob::LinkBandwidth(LinkClass::Intra(2)).improve(&m, 2.0).is_some());
    }

    #[test]
    fn sweep_ranks_by_saving_with_stable_ties() {
        let m = MachineSpec::frontier_mi250x();
        // synthetic evaluator: only inter-node bandwidth matters
        let report = sweep(&m, 10.0, DEFAULT_EPSILON, |spec| {
            let inter = spec.inter_node.bandwidth / MachineSpec::frontier_mi250x().inter_node.bandwidth;
            Some(10.0 - 2.0 * (inter - 1.0))
        });
        assert_eq!(report.base_s, 10.0);
        assert_eq!(report.top().unwrap().knob, Knob::LinkBandwidth(LinkClass::InterNode));
        assert!((report.top().unwrap().saving - 2.0).abs() < 1e-12);
        let d = report.top().unwrap().derivative.unwrap();
        assert!((d - 2.0).abs() < 1e-9, "linear model derivative, got {d}");
        // every other knob saves exactly 0.0 and keeps enumeration order
        assert_eq!(report.prices[1].knob, Knob::ComputeRate);
        assert!(report.prices.iter().skip(1).all(|p| p.saving == 0.0));
    }

    #[test]
    fn add_restores_ranking() {
        let m = MachineSpec::frontier_mi250x();
        let mut report = sweep(&m, 5.0, DEFAULT_EPSILON, |_| Some(5.0));
        report.add(ShadowPrice {
            knob: Knob::SecDegree,
            label: Knob::SecDegree.label(&m),
            improved_s: 4.0,
            saving: 1.0,
            derivative: None,
        });
        assert_eq!(report.top().unwrap().knob, Knob::SecDegree);
    }
}
