//! Training-run metrics: the quantities the paper reports (TFLOPS per GPU,
//! samples/sec, scaling efficiency) computed from simulated step times and
//! the comm ledger, plus the telemetry subsystem (DESIGN.md §13) — a
//! labeled metrics [`registry`] and the per-step JSONL [`telemetry`]
//! stream behind `--telemetry` — and the bottleneck-attribution
//! [`sensitivity`] sweep (link shadow prices, DESIGN.md §14).

pub mod registry;
pub mod sensitivity;
pub mod telemetry;

/// Throughput metrics for one configuration point (one bar of Fig 7/8).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Throughput {
    /// Worker (GCD) count at this point.
    pub gcds: usize,
    /// Simulated seconds per optimizer step.
    pub step_seconds: f64,
    /// Model FLOPs per optimizer step (whole cluster).
    pub flops_per_step: f64,
    /// Sequences per optimizer step (global batch).
    pub sequences_per_step: f64,
}

impl Throughput {
    /// TFLOPS per GPU — the paper's headline metric (GCD == GPU on Frontier).
    /// Degenerate points (zero GCDs or a non-positive step time) report 0.0
    /// rather than NaN/Inf so downstream tables and telemetry stay finite.
    pub fn tflops_per_gpu(&self) -> f64 {
        if self.gcds == 0 || self.step_seconds <= 0.0 {
            return 0.0;
        }
        self.flops_per_step / self.step_seconds / self.gcds as f64 / 1e12
    }

    /// Sequences per second at this point's step time (0.0 when the step
    /// time is degenerate, mirroring [`Throughput::tflops_per_gpu`]).
    pub fn samples_per_second(&self) -> f64 {
        if self.step_seconds <= 0.0 {
            return 0.0;
        }
        self.sequences_per_step / self.step_seconds
    }
}

/// Scaling efficiency of a series of points relative to its first point:
/// `eff_i = (tflops_i / tflops_0)` with per-GPU normalization (weak-scaling
/// style, as the paper's Fig 7/8 efficiency curves). An empty series yields
/// an empty vec; a degenerate base point (zero per-GPU TFLOPS) reports 0.0
/// everywhere instead of dividing by zero.
pub fn scaling_efficiency(points: &[Throughput]) -> Vec<f64> {
    let Some(first) = points.first() else {
        return Vec::new();
    };
    let base = first.tflops_per_gpu();
    if base <= 0.0 {
        return vec![0.0; points.len()];
    }
    points.iter().map(|p| p.tflops_per_gpu() / base).collect()
}

/// Busy-time accounting of one scheduled step's streams for a single rank
/// (produced by `sched::Schedule::utilization`): how much of the event-clock
/// makespan each resource stream actually worked.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepUtilization {
    /// Event-clock step time.
    pub makespan: f64,
    /// Busy seconds of the compute stream.
    pub compute_busy: f64,
    /// Busy seconds of the weight-gather prefetch stream.
    pub prefetch_busy: f64,
    /// Busy seconds of the gradient-sync stream.
    pub grad_sync_busy: f64,
    /// Busy seconds of the pipeline-transfer stream (0 for pure-DP steps).
    pub pipe_busy: f64,
}

impl StepUtilization {
    /// Fraction of the step the compute stream was busy — the scheduler's
    /// analogue of MFU-loss to communication stalls.
    pub fn compute_utilization(&self) -> f64 {
        if self.makespan > 0.0 {
            self.compute_busy / self.makespan
        } else {
            0.0
        }
    }

    /// Compute-stream idle seconds (stall time across all causes).
    pub fn compute_stall(&self) -> f64 {
        (self.makespan - self.compute_busy).max(0.0)
    }
}

/// A recorded loss-curve sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LossPoint {
    /// Optimizer step the sample was taken after.
    pub step: usize,
    /// Cumulative tokens consumed by that step.
    pub tokens: u64,
    /// Training loss value.
    pub loss: f64,
}

/// Running training log for one scheme.
#[derive(Debug, Clone, Default)]
pub struct TrainLog {
    /// Sharding scheme name the run trained under.
    pub scheme: String,
    /// Recorded loss-curve samples, in step order.
    pub losses: Vec<LossPoint>,
    /// Accumulated simulated (event-clock) seconds.
    pub sim_seconds: f64,
    /// Accumulated wall-clock seconds the simulation itself took.
    pub wall_seconds: f64,
}

impl TrainLog {
    /// Loss of the last recorded sample, if any.
    pub fn final_loss(&self) -> Option<f64> {
        self.losses.last().map(|p| p.loss)
    }

    /// Mean loss over the last `k` samples (smoother comparison metric).
    pub fn tail_mean(&self, k: usize) -> Option<f64> {
        if self.losses.is_empty() {
            return None;
        }
        let tail = &self.losses[self.losses.len().saturating_sub(k)..];
        Some(tail.iter().map(|p| p.loss).sum::<f64>() / tail.len() as f64)
    }

    /// Render the loss curve as `step,tokens,loss` CSV.
    pub fn to_csv(&self) -> String {
        let mut s = String::from("step,tokens,loss\n");
        for p in &self.losses {
            s.push_str(&format!("{},{},{:.6}\n", p.step, p.tokens, p.loss));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tflops_math() {
        let t = Throughput {
            gcds: 8,
            step_seconds: 2.0,
            flops_per_step: 8.0 * 2.0 * 100e12,
            sequences_per_step: 64.0,
        };
        assert!((t.tflops_per_gpu() - 100.0).abs() < 1e-9);
        assert!((t.samples_per_second() - 32.0).abs() < 1e-9);
    }

    #[test]
    fn efficiency_starts_at_one() {
        let mk = |gcds, secs| Throughput {
            gcds,
            step_seconds: secs,
            flops_per_step: gcds as f64 * 1e12,
            sequences_per_step: 1.0,
        };
        let pts = vec![mk(8, 1.0), mk(16, 1.05), mk(32, 1.2)];
        let eff = scaling_efficiency(&pts);
        assert!((eff[0] - 1.0).abs() < 1e-12);
        assert!(eff[1] < 1.0 && eff[2] < eff[1]);
    }

    #[test]
    fn efficiency_of_empty_series_is_empty() {
        assert!(scaling_efficiency(&[]).is_empty());
    }

    #[test]
    fn degenerate_points_report_zero_not_nan() {
        let zero_step = Throughput {
            gcds: 8,
            step_seconds: 0.0,
            flops_per_step: 1e15,
            sequences_per_step: 64.0,
        };
        assert_eq!(zero_step.tflops_per_gpu(), 0.0);
        assert_eq!(zero_step.samples_per_second(), 0.0);
        let zero_gcds = Throughput { gcds: 0, step_seconds: 1.0, ..zero_step };
        assert_eq!(zero_gcds.tflops_per_gpu(), 0.0);
        // a degenerate base point zeroes the efficiency series (no NaN)
        let ok = Throughput {
            gcds: 8,
            step_seconds: 2.0,
            flops_per_step: 1e15,
            sequences_per_step: 64.0,
        };
        let eff = scaling_efficiency(&[zero_step, ok]);
        assert_eq!(eff, vec![0.0, 0.0]);
    }

    #[test]
    fn utilization_accounting() {
        let u = StepUtilization {
            makespan: 10.0,
            compute_busy: 7.5,
            prefetch_busy: 4.0,
            grad_sync_busy: 1.5,
            pipe_busy: 0.0,
        };
        assert!((u.compute_utilization() - 0.75).abs() < 1e-12);
        assert!((u.compute_stall() - 2.5).abs() < 1e-12);
        let z = StepUtilization {
            makespan: 0.0,
            compute_busy: 0.0,
            prefetch_busy: 0.0,
            grad_sync_busy: 0.0,
            pipe_busy: 0.0,
        };
        assert_eq!(z.compute_utilization(), 0.0);
    }

    #[test]
    fn train_log_tail() {
        let mut log = TrainLog { scheme: "x".into(), ..Default::default() };
        for i in 0..10 {
            log.losses.push(LossPoint { step: i, tokens: i as u64, loss: 10.0 - i as f64 });
        }
        assert_eq!(log.final_loss(), Some(1.0));
        assert_eq!(log.tail_mean(2), Some(1.5));
        let csv = log.to_csv();
        assert!(csv.starts_with("step,tokens,loss\n"));
        assert_eq!(csv.lines().count(), 11);
    }
}
