//! Per-step JSONL telemetry stream (DESIGN.md §13) behind the CLI's
//! `--telemetry FILE` flag: one self-describing JSON object per optimizer
//! step, carrying the step time, TFLOPS/GCD, samples/s, the comm byte
//! ledger, the per-GCD memory estimate, and the stall + link-utilization
//! breakdowns derived from the executed schedule.
//!
//! Every quantity here is *simulated* (event-clock seconds, modeled
//! bytes); wall-clock self-profiling lives separately in
//! `sim::SimProfile` so the two time bases can never mix. Records are
//! deterministic: map-valued fields use `BTreeMap`, list-valued fields
//! are explicitly sorted, and serialization goes through
//! [`crate::util::json::Json`] (sorted object keys).

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{self, BufWriter, Write};
use std::path::Path;

use crate::comm::cost::CostModel;
use crate::memory::DeviceMemory;
use crate::metrics::registry::Registry;
use crate::metrics::{StepUtilization, Throughput};
use crate::sched::Schedule;
use crate::topology::MachineSpec;
use crate::util::json::Json;

/// Version stamped into every record's `schema` field; bump on any
/// backwards-incompatible change to the record shape (DESIGN.md §13).
pub const SCHEMA_VERSION: u64 = 1;

/// Histogram bucket bounds (seconds) for step-time observations fed into
/// a [`Registry`] by [`register_step`].
pub const STEP_SECONDS_BOUNDS: [f64; 7] = [0.5, 1.0, 2.0, 5.0, 10.0, 30.0, 60.0];

/// Which CLI path produced a record.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepKind {
    /// `simulate` — one priced step per invocation.
    Simulate,
    /// `train` — one record per engine step.
    Train,
    /// `pipeline` — one priced pipeline step per invocation.
    Pipeline,
}

impl StepKind {
    /// The `kind` string written into the record.
    pub fn name(&self) -> &'static str {
        match self {
            StepKind::Simulate => "simulate",
            StepKind::Train => "train",
            StepKind::Pipeline => "pipeline",
        }
    }
}

/// One comm-ledger row: a (collective, link class) cell of the byte
/// ledger, labeled with the machine's link name.
#[derive(Debug, Clone, PartialEq)]
pub struct CommRow {
    /// Collective name (`all-gather`, `reduce-scatter`, ...).
    pub coll: String,
    /// Machine link label (`MachineSpec::class_label`).
    pub link: String,
    /// Number of calls charged.
    pub calls: u64,
    /// Wire bytes moved per rank.
    pub wire_bytes: u64,
    /// Modeled seconds charged to this cell.
    pub seconds: f64,
}

/// One link-utilization row: a link class's busy share of the step.
#[derive(Debug, Clone, PartialEq)]
pub struct UtilRow {
    /// Machine link label (`MachineSpec::class_label`).
    pub link: String,
    /// Distinct `(class, instance)` links that carried traffic.
    pub instances: usize,
    /// Union-of-spans busy seconds across the class's instances.
    pub busy_s: f64,
    /// `busy_s / step_s` (0.0 when the step time is degenerate).
    pub frac_of_step: f64,
    /// Sum of span durations (overlap counted once per task).
    pub task_seconds: f64,
    /// Peak concurrent transfers across the class's instances.
    pub peak_in_flight: usize,
}

/// The critical-path attribution ledger of one step
/// ([`crate::sched::critical::decompose`], DESIGN.md §14): conserved
/// compute / per-link comm / idle seconds summing to the makespan.
#[derive(Debug, Clone, PartialEq)]
pub struct CriticalLedger {
    /// Compute seconds on the critical path.
    pub compute_s: f64,
    /// Idle-gap seconds on the critical path (structurally 0.0 for
    /// simulator-produced schedules).
    pub idle_s: f64,
    /// Per-link comm seconds on the path, fastest class first, labeled
    /// by `MachineSpec::class_label`.
    pub comm_s: Vec<(String, f64)>,
    /// The makespan the ledger partitions (== the record's `step_s` for
    /// single-step records).
    pub makespan_s: f64,
}

impl CriticalLedger {
    /// Sum of every ledger category; equals `makespan_s` to 1e-12.
    pub fn total(&self) -> f64 {
        self.compute_s + self.idle_s + self.comm_s.iter().map(|(_, v)| v).sum::<f64>()
    }
}

/// One telemetry record: everything the paper's observability story needs
/// about a single optimizer step, in simulated units.
#[derive(Debug, Clone, PartialEq)]
pub struct StepRecord {
    /// Step index (0 for one-shot `simulate`/`pipeline` records).
    pub step: usize,
    /// Producing CLI path.
    pub kind: StepKind,
    /// Sharding scheme name.
    pub scheme: String,
    /// Machine name.
    pub machine: String,
    /// Node count.
    pub nodes: usize,
    /// Worker (GCD) count.
    pub gcds: usize,
    /// Event-clock step seconds.
    pub step_s: f64,
    /// TFLOPS per GCD at this step time.
    pub tflops_per_gcd: f64,
    /// Sequences per second at this step time.
    pub samples_per_s: f64,
    /// Wire bytes the step pushed across node boundaries.
    pub inter_node_bytes: u64,
    /// Comm byte ledger, sorted by (collective, link).
    pub comm: Vec<CommRow>,
    /// Per-GCD model-state memory estimate, when priced.
    pub memory: Option<DeviceMemory>,
    /// Compute-stall seconds attributed per link label (rank 0's ledger).
    pub stalls: BTreeMap<String, f64>,
    /// Link busy-time rows, fastest class first.
    pub utilization: Vec<UtilRow>,
    /// Critical-path attribution ledger (set by `with_schedule`).
    pub critical: Option<CriticalLedger>,
    /// Per-stream busy accounting for the modeled rank.
    pub streams: Option<StepUtilization>,
    /// Simulated pipeline bubble fraction (pipeline records only).
    pub bubble_fraction: Option<f64>,
    /// Training loss after this step (train records only).
    pub loss: Option<f64>,
    /// Expected goodput (tokens/s net of checkpoint + failure costs)
    /// under the run's MTBF/interval assumptions (`sim::goodput`).
    pub goodput_tokens_per_s: Option<f64>,
    /// Availability factor `goodput / raw tokens-per-second` in [0, 1].
    pub availability: Option<f64>,
}

impl StepRecord {
    /// A record with the identity + throughput scalars filled in; chain
    /// the `with_*` builders to attach ledger, memory, and schedule views.
    pub fn new(
        step: usize,
        kind: StepKind,
        scheme: &str,
        machine: &str,
        nodes: usize,
        point: &Throughput,
    ) -> StepRecord {
        StepRecord {
            step,
            kind,
            scheme: scheme.to_string(),
            machine: machine.to_string(),
            nodes,
            gcds: point.gcds,
            step_s: point.step_seconds,
            tflops_per_gcd: point.tflops_per_gpu(),
            samples_per_s: point.samples_per_second(),
            inter_node_bytes: 0,
            comm: Vec::new(),
            memory: None,
            stalls: BTreeMap::new(),
            utilization: Vec::new(),
            critical: None,
            streams: None,
            bubble_fraction: None,
            loss: None,
            goodput_tokens_per_s: None,
            availability: None,
        }
    }

    /// Attach the comm byte ledger (and its inter-node byte total), with
    /// link cells labeled by the cost model's machine.
    pub fn with_comm(mut self, cost: &CostModel) -> StepRecord {
        let spec = &cost.cluster.spec;
        let mut rows: Vec<CommRow> = cost
            .entries()
            .map(|((coll, class), e)| CommRow {
                coll: coll.name().to_string(),
                link: spec.class_label(*class),
                calls: e.calls,
                wire_bytes: e.wire_bytes,
                seconds: e.seconds,
            })
            .collect();
        rows.sort_by(|a, b| (&a.coll, &a.link).cmp(&(&b.coll, &b.link)));
        self.comm = rows;
        self.inter_node_bytes = cost.inter_node_bytes();
        self
    }

    /// Attach the per-GCD model-state memory estimate.
    pub fn with_memory(mut self, memory: DeviceMemory) -> StepRecord {
        self.memory = Some(memory);
        self
    }

    /// Attach the schedule-derived views: per-link stall attribution,
    /// link-utilization rows (busy/task seconds, peak in-flight), and the
    /// modeled rank's per-stream busy accounting. Labels come from
    /// `machine` so telemetry, stall table, and trace counters agree.
    pub fn with_schedule(mut self, sched: &Schedule, machine: &MachineSpec) -> StepRecord {
        let rank = sched.ranks().first().copied().unwrap_or(0);
        self.stalls = sched
            .stall_by_class(rank)
            .into_iter()
            .map(|(class, s)| (machine.class_label(class), s))
            .collect();
        let usage = sched.link_usage();
        let busy = sched.class_busy();
        let mut rows = Vec::new();
        for class in sched.link_classes() {
            let mut instances = 0usize;
            let mut task_seconds = 0.0;
            let mut peak = 0usize;
            for ((c, _), u) in &usage {
                if *c == class {
                    instances += 1;
                    task_seconds += u.task_seconds;
                    peak = peak.max(u.peak_in_flight);
                }
            }
            let busy_s = busy.get(&class).copied().unwrap_or(0.0);
            let frac = if self.step_s > 0.0 { busy_s / self.step_s } else { 0.0 };
            rows.push(UtilRow {
                link: machine.class_label(class),
                instances,
                busy_s,
                frac_of_step: frac,
                task_seconds,
                peak_in_flight: peak,
            });
        }
        self.utilization = rows;
        let decomp = crate::sched::critical::decompose(sched);
        let mut comm_s: Vec<(String, f64)> = Vec::new();
        for (class, s) in decomp.comm_s() {
            let label = machine.class_label(*class);
            // distinct classes can share a label on exotic specs; merge them
            match comm_s.iter_mut().find(|(l, _)| *l == label) {
                Some((_, acc)) => *acc += s,
                None => comm_s.push((label, *s)),
            }
        }
        self.critical = Some(CriticalLedger {
            compute_s: decomp.compute_s(),
            idle_s: decomp.idle_s(),
            comm_s,
            makespan_s: decomp.makespan(),
        });
        self.streams = Some(sched.utilization(rank));
        self
    }

    /// Attach the simulated pipeline bubble fraction.
    pub fn with_bubble(mut self, bubble_fraction: f64) -> StepRecord {
        self.bubble_fraction = Some(bubble_fraction);
        self
    }

    /// Attach the post-step training loss.
    pub fn with_loss(mut self, loss: f64) -> StepRecord {
        self.loss = Some(loss);
        self
    }

    /// Attach the goodput view: expected net tokens/s and the
    /// availability factor from a `sim::goodput` analysis.
    pub fn with_goodput(mut self, goodput_tokens_per_s: f64, availability: f64) -> StepRecord {
        self.goodput_tokens_per_s = Some(goodput_tokens_per_s);
        self.availability = Some(availability);
        self
    }

    /// Serialize to the one-object-per-line JSON shape of DESIGN.md §13.
    pub fn to_json(&self) -> Json {
        let mut fields: Vec<(&str, Json)> = vec![
            ("schema", Json::num(SCHEMA_VERSION as f64)),
            ("step", Json::from(self.step)),
            ("kind", Json::str(self.kind.name())),
            ("scheme", Json::str(self.scheme.clone())),
            ("machine", Json::str(self.machine.clone())),
            ("nodes", Json::from(self.nodes)),
            ("gcds", Json::from(self.gcds)),
            ("step_s", Json::num(self.step_s)),
            ("tflops_per_gcd", Json::num(self.tflops_per_gcd)),
            ("samples_per_s", Json::num(self.samples_per_s)),
            ("inter_node_bytes", Json::num(self.inter_node_bytes as f64)),
        ];
        let comm = self.comm.iter().map(|r| {
            Json::obj(vec![
                ("coll", Json::str(r.coll.clone())),
                ("link", Json::str(r.link.clone())),
                ("calls", Json::num(r.calls as f64)),
                ("wire_bytes", Json::num(r.wire_bytes as f64)),
                ("seconds", Json::num(r.seconds)),
            ])
        });
        fields.push(("comm", Json::arr(comm)));
        if let Some(m) = self.memory {
            fields.push((
                "memory_per_gcd",
                Json::obj(vec![
                    ("weights", Json::num(m.weights)),
                    ("secondary", Json::num(m.secondary)),
                    ("grads", Json::num(m.grads)),
                    ("optim", Json::num(m.optim)),
                    ("total", Json::num(m.total())),
                ]),
            ));
        }
        let stalls: BTreeMap<String, Json> =
            self.stalls.iter().map(|(k, v)| (k.clone(), Json::num(*v))).collect();
        fields.push(("stall_s", Json::Obj(stalls)));
        let util = self.utilization.iter().map(|u| {
            Json::obj(vec![
                ("link", Json::str(u.link.clone())),
                ("instances", Json::from(u.instances)),
                ("busy_s", Json::num(u.busy_s)),
                ("frac_of_step", Json::num(u.frac_of_step)),
                ("task_seconds", Json::num(u.task_seconds)),
                ("peak_in_flight", Json::from(u.peak_in_flight)),
            ])
        });
        fields.push(("utilization", Json::arr(util)));
        if let Some(c) = &self.critical {
            let comm =
                c.comm_s.iter().map(|(link, s)| {
                    Json::obj(vec![("link", Json::str(link.clone())), ("seconds", Json::num(*s))])
                });
            fields.push((
                "critical",
                Json::obj(vec![
                    ("compute_s", Json::num(c.compute_s)),
                    ("idle_s", Json::num(c.idle_s)),
                    ("comm", Json::arr(comm)),
                    ("makespan_s", Json::num(c.makespan_s)),
                ]),
            ));
        }
        if let Some(u) = self.streams {
            fields.push((
                "streams",
                Json::obj(vec![
                    ("compute_busy_s", Json::num(u.compute_busy)),
                    ("prefetch_busy_s", Json::num(u.prefetch_busy)),
                    ("grad_sync_busy_s", Json::num(u.grad_sync_busy)),
                    ("pipe_busy_s", Json::num(u.pipe_busy)),
                    ("compute_utilization", Json::num(u.compute_utilization())),
                ]),
            ));
        }
        if let Some(b) = self.bubble_fraction {
            fields.push(("bubble_fraction", Json::num(b)));
        }
        if let Some(l) = self.loss {
            fields.push(("loss", Json::num(l)));
        }
        if let Some(g) = self.goodput_tokens_per_s {
            fields.push(("goodput_tokens_per_s", Json::num(g)));
        }
        if let Some(a) = self.availability {
            fields.push(("availability", Json::num(a)));
        }
        Json::obj(fields)
    }
}

/// Fold a record into a [`Registry`]: step counters, per-scheme step-time
/// totals + histogram, throughput gauges, and per-link busy/stall counters
/// (Prometheus-style naming, see DESIGN.md §13).
pub fn register_step(reg: &mut Registry, rec: &StepRecord) {
    let base = [("machine", rec.machine.as_str()), ("scheme", rec.scheme.as_str())];
    reg.inc("sim_steps_total", &[("kind", rec.kind.name()), ("scheme", &rec.scheme)], 1.0);
    reg.inc("sim_step_seconds_total", &base, rec.step_s);
    reg.inc("sim_inter_node_bytes_total", &base, rec.inter_node_bytes as f64);
    reg.set("sim_tflops_per_gcd", &base, rec.tflops_per_gcd);
    reg.set("sim_samples_per_second", &base, rec.samples_per_s);
    reg.observe("sim_step_seconds", &base, &STEP_SECONDS_BOUNDS, rec.step_s);
    for u in &rec.utilization {
        reg.inc("sim_link_busy_seconds_total", &[("link", &u.link)], u.busy_s);
    }
    for (link, s) in &rec.stalls {
        reg.inc("sim_stall_seconds_total", &[("link", link)], *s);
    }
}

/// Buffered JSONL writer: one [`StepRecord`] object per line.
#[derive(Debug)]
pub struct TelemetryWriter {
    out: BufWriter<File>,
    written: usize,
}

impl TelemetryWriter {
    /// Create (truncate) `path` for writing.
    pub fn create(path: impl AsRef<Path>) -> io::Result<TelemetryWriter> {
        Ok(TelemetryWriter { out: BufWriter::new(File::create(path)?), written: 0 })
    }

    /// Append one record as a single JSON line.
    pub fn write_record(&mut self, rec: &StepRecord) -> io::Result<()> {
        writeln!(self.out, "{}", rec.to_json())?;
        self.written += 1;
        Ok(())
    }

    /// Records written so far.
    pub fn written(&self) -> usize {
        self.written
    }

    /// Flush buffered lines to disk.
    pub fn flush(&mut self) -> io::Result<()> {
        self.out.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{simulate, StreamKind, Task, TaskGraph};
    use crate::topology::LinkClass;

    fn tiny_schedule() -> Schedule {
        let mut g = TaskGraph::new();
        let a = g.add(Task {
            label: "gather".into(),
            rank: 0,
            stream: StreamKind::Prefetch,
            work: 2.0,
            class: Some(LinkClass::InterNode),
            instance: 0,
            deps: vec![],
        });
        g.add(Task {
            label: "fwd".into(),
            rank: 0,
            stream: StreamKind::Compute,
            work: 1.0,
            class: None,
            instance: 0,
            deps: vec![a],
        });
        simulate(g)
    }

    fn tiny_record() -> StepRecord {
        let sched = tiny_schedule();
        let machine = MachineSpec::frontier_mi250x();
        let point = Throughput {
            gcds: 8,
            step_seconds: sched.makespan(),
            flops_per_step: 1e15,
            sequences_per_step: 8.0,
        };
        StepRecord::new(0, StepKind::Simulate, "zero3", &machine.name, 1, &point)
            .with_schedule(&sched, &machine)
    }

    #[test]
    fn record_serializes_with_schema_and_reconciling_views() {
        let rec = tiny_record();
        // the 2s exposed gather both stalls compute and keeps the link busy
        let label = MachineSpec::frontier_mi250x().class_label(LinkClass::InterNode);
        assert_eq!(rec.stalls.get(&label).copied(), Some(2.0));
        assert_eq!(rec.utilization.len(), 1);
        let u = &rec.utilization[0];
        assert_eq!(u.link, label);
        assert_eq!(u.busy_s, 2.0);
        assert!(rec.stalls[&label] <= u.busy_s + 1e-12);
        let j = rec.to_json();
        assert_eq!(j.get("schema").and_then(|v| v.as_usize()), Some(1));
        assert_eq!(j.get("kind").and_then(|v| v.as_str()), Some("simulate"));
        assert_eq!(j.get("step_s").and_then(|v| v.as_f64()), Some(3.0));
        let frac = j
            .at(&["utilization"])
            .and_then(|a| a.as_arr())
            .and_then(|a| a[0].get("frac_of_step"))
            .and_then(|v| v.as_f64())
            .unwrap();
        assert!((frac - 2.0 / 3.0).abs() < 1e-12);
        // the critical ledger reconciles with the step time (2s gather + 1s fwd)
        let ledger = rec.critical.as_ref().expect("with_schedule sets critical");
        assert_eq!(ledger.compute_s, 1.0);
        assert_eq!(ledger.idle_s, 0.0);
        assert_eq!(ledger.comm_s, vec![(label.clone(), 2.0)]);
        assert!((ledger.total() - ledger.makespan_s).abs() <= 1e-12);
        assert_eq!(ledger.makespan_s, rec.step_s);
        let jc = j.get("critical").expect("critical serialized");
        assert_eq!(jc.get("compute_s").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(jc.get("makespan_s").and_then(|v| v.as_f64()), Some(3.0));
        // round-trips through the parser
        let back = Json::parse(&j.to_string()).expect("valid JSON");
        assert_eq!(back, j);
    }

    #[test]
    fn writer_emits_one_parseable_object_per_line() {
        let path = std::env::temp_dir().join("zero_topo_telemetry_writer_test.jsonl");
        {
            let mut w = TelemetryWriter::create(&path).unwrap();
            let rec = tiny_record();
            w.write_record(&rec).unwrap();
            w.write_record(&rec.clone().with_loss(3.5)).unwrap();
            assert_eq!(w.written(), 2);
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in &lines {
            let j = Json::parse(line).expect("each line is a JSON object");
            assert!(j.get("schema").is_some());
        }
        let last = Json::parse(lines[1]).unwrap();
        assert_eq!(last.get("loss").and_then(|v| v.as_f64()), Some(3.5));
    }

    #[test]
    fn goodput_fields_are_optional_and_serialize_together() {
        let rec = tiny_record();
        let j = rec.to_json();
        assert!(j.get("goodput_tokens_per_s").is_none());
        assert!(j.get("availability").is_none());
        let with = rec.with_goodput(1.8e5, 0.989);
        let j = with.to_json();
        assert_eq!(j.get("goodput_tokens_per_s").and_then(|v| v.as_f64()), Some(1.8e5));
        assert_eq!(j.get("availability").and_then(|v| v.as_f64()), Some(0.989));
        let back = Json::parse(&j.to_string()).expect("valid JSON");
        assert_eq!(back, j);
    }

    #[test]
    fn register_step_accumulates_counters_and_histogram() {
        let mut reg = Registry::new();
        let rec = tiny_record();
        register_step(&mut reg, &rec);
        register_step(&mut reg, &rec);
        let kind = [("kind", "simulate"), ("scheme", "zero3")];
        assert_eq!(reg.counter("sim_steps_total", &kind), 2.0);
        let base = [("machine", rec.machine.as_str()), ("scheme", "zero3")];
        assert_eq!(reg.counter("sim_step_seconds_total", &base), 6.0);
        let h = reg.histogram("sim_step_seconds", &base).unwrap();
        assert_eq!(h.count(), 2);
        let label = MachineSpec::frontier_mi250x().class_label(LinkClass::InterNode);
        let link = [("link", label.as_str())];
        assert_eq!(reg.counter("sim_link_busy_seconds_total", &link), 4.0);
    }
}
