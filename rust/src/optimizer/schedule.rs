//! Learning-rate schedules — GPT-NeoX's default regime (linear warmup +
//! cosine decay to a floor), used by the training engine.

/// Warmup + cosine decay (the GPT-NeoX / Megatron default).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WarmupCosine {
    pub base_lr: f32,
    pub warmup_steps: usize,
    pub total_steps: usize,
    /// Final LR as a fraction of base (NeoX default 0.1).
    pub min_ratio: f32,
}

impl WarmupCosine {
    pub fn new(base_lr: f32, warmup_steps: usize, total_steps: usize) -> Self {
        WarmupCosine { base_lr, warmup_steps, total_steps, min_ratio: 0.1 }
    }

    /// LR for optimizer step `step` (0-based).
    pub fn lr(&self, step: usize) -> f32 {
        if self.warmup_steps > 0 && step < self.warmup_steps {
            return self.base_lr * (step + 1) as f32 / self.warmup_steps as f32;
        }
        let total = self.total_steps.max(self.warmup_steps + 1);
        let progress =
            (step - self.warmup_steps) as f32 / (total - self.warmup_steps).max(1) as f32;
        let progress = progress.min(1.0);
        let cosine = 0.5 * (1.0 + (std::f32::consts::PI * progress).cos());
        let min_lr = self.base_lr * self.min_ratio;
        min_lr + (self.base_lr - min_lr) * cosine
    }
}

/// Constant LR (the engine default when no schedule is configured).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Constant(pub f32);

impl Constant {
    pub fn lr(&self, _step: usize) -> f32 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_ramps_linearly() {
        let s = WarmupCosine::new(1.0, 10, 100);
        assert!((s.lr(0) - 0.1).abs() < 1e-6);
        assert!((s.lr(4) - 0.5).abs() < 1e-6);
        assert!((s.lr(9) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn cosine_decays_to_floor() {
        let s = WarmupCosine::new(1.0, 10, 110);
        assert!((s.lr(10) - 1.0).abs() < 1e-2);
        let mid = s.lr(60);
        assert!((0.4..0.7).contains(&mid), "{mid}");
        assert!((s.lr(109) - 0.1).abs() < 0.02);
        // past the end: clamp at the floor
        assert!((s.lr(500) - 0.1).abs() < 1e-6);
    }

    #[test]
    fn monotone_after_warmup() {
        let s = WarmupCosine::new(3e-4, 5, 50);
        let mut prev = f32::MAX;
        for step in 5..50 {
            let lr = s.lr(step);
            assert!(lr <= prev + 1e-9);
            prev = lr;
        }
    }

    #[test]
    fn zero_warmup_starts_at_base() {
        let s = WarmupCosine::new(1.0, 0, 10);
        assert!((s.lr(0) - 1.0).abs() < 1e-6);
    }
}
