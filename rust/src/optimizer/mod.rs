//! Sharded AdamW — the optimizer-state partition of the 3-level design.
//!
//! Each rank owns `1/d_os` of the optimizer states (fp32 master weights,
//! first and second moments — the paper's K = 12 bytes/param regime) and
//! updates only the parameters its shard covers. The fp16 training weights
//! are re-materialized from the fp32 master after each step (mixed
//! precision à la Megatron/DeepSpeed).

pub mod schedule;

/// AdamW hyperparameters (paper stack defaults: GPT-NeoX / DeepSpeed).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdamWConfig {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    /// Global gradient-norm clip (0 disables).
    pub grad_clip: f32,
}

impl Default for AdamWConfig {
    fn default() -> Self {
        AdamWConfig { lr: 1e-3, beta1: 0.9, beta2: 0.95, eps: 1e-8, weight_decay: 0.0, grad_clip: 1.0 }
    }
}

/// The optimizer-state shard owned by one rank.
#[derive(Debug, Clone)]
pub struct AdamWShard {
    pub cfg: AdamWConfig,
    /// fp32 master copy of this shard's parameters.
    pub master: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: u64,
}

impl AdamWShard {
    /// Initialize from the shard's initial parameter values.
    pub fn new(cfg: AdamWConfig, init: &[f32]) -> Self {
        AdamWShard {
            cfg,
            master: init.to_vec(),
            m: vec![0.0; init.len()],
            v: vec![0.0; init.len()],
            step: 0,
        }
    }

    /// Memory footprint in bytes (the K = 12 B/param account).
    pub fn bytes(&self) -> usize {
        12 * self.master.len()
    }

    /// One AdamW step on this shard given its gradient shard. `clip_scale`
    /// is the global-norm clipping factor (must be computed over the FULL
    /// gradient across shards — see [`global_clip_scale`]).
    pub fn step(&mut self, grads: &[f32], clip_scale: f32) {
        assert_eq!(grads.len(), self.master.len());
        self.step += 1;
        let c = self.cfg;
        let t = self.step as f32;
        let bc1 = 1.0 - c.beta1.powf(t);
        let bc2 = 1.0 - c.beta2.powf(t);
        for i in 0..grads.len() {
            let g = grads[i] * clip_scale;
            self.m[i] = c.beta1 * self.m[i] + (1.0 - c.beta1) * g;
            self.v[i] = c.beta2 * self.v[i] + (1.0 - c.beta2) * g * g;
            let mh = self.m[i] / bc1;
            let vh = self.v[i] / bc2;
            // decoupled weight decay (AdamW, Loshchilov & Hutter)
            self.master[i] -= c.lr * (mh / (vh.sqrt() + c.eps) + c.weight_decay * self.master[i]);
        }
    }
}

/// Squared L2 norm of a gradient shard (summed across shards by the caller
/// via an all-reduce to form the global norm).
pub fn local_sq_norm(grads: &[f32]) -> f64 {
    grads.iter().map(|&g| (g as f64) * (g as f64)).sum()
}

/// Clip scale from the global gradient norm: min(1, clip / ||g||).
pub fn global_clip_scale(global_sq_norm: f64, clip: f32) -> f32 {
    if clip <= 0.0 {
        return 1.0;
    }
    let norm = global_sq_norm.sqrt() as f32;
    if norm > clip {
        clip / (norm + 1e-6)
    } else {
        1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quadratic_converges() {
        // minimize f(x) = 0.5*(x - 3)^2; grad = x - 3
        let mut opt = AdamWShard::new(
            AdamWConfig { lr: 0.1, grad_clip: 0.0, ..Default::default() },
            &[0.0],
        );
        for _ in 0..500 {
            let g = opt.master[0] - 3.0;
            opt.step(&[g], 1.0);
        }
        assert!((opt.master[0] - 3.0).abs() < 1e-2, "{}", opt.master[0]);
    }

    #[test]
    fn first_step_is_lr_sized() {
        // With bias correction, |Δx| of step 1 ≈ lr regardless of grad scale.
        for gscale in [1e-3f32, 1.0, 1e3] {
            let mut opt = AdamWShard::new(
                AdamWConfig { lr: 0.01, grad_clip: 0.0, ..Default::default() },
                &[1.0],
            );
            opt.step(&[gscale], 1.0);
            let delta = (1.0 - opt.master[0]).abs();
            assert!((delta - 0.01).abs() < 1e-3, "g={gscale} delta={delta}");
        }
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let mut opt = AdamWShard::new(
            AdamWConfig { lr: 0.1, weight_decay: 0.1, grad_clip: 0.0, ..Default::default() },
            &[5.0],
        );
        for _ in 0..100 {
            opt.step(&[0.0], 1.0); // zero gradient: pure decay
        }
        assert!(opt.master[0] < 5.0 * 0.5, "{}", opt.master[0]);
    }

    #[test]
    fn clip_scale_behaviour() {
        assert_eq!(global_clip_scale(0.25, 1.0), 1.0); // norm 0.5 < clip
        let s = global_clip_scale(100.0, 1.0); // norm 10 -> scale 0.1
        assert!((s - 0.1).abs() < 1e-4);
        assert_eq!(global_clip_scale(1e6, 0.0), 1.0); // disabled
    }

    #[test]
    fn sharded_equals_monolithic() {
        // Running AdamW on two half-shards must equal one full-shard run.
        let init: Vec<f32> = (0..64).map(|i| (i as f32) * 0.1 - 3.0).collect();
        let grads: Vec<f32> = (0..64).map(|i| ((i * 7 % 13) as f32) * 0.01 - 0.05).collect();
        let cfg = AdamWConfig::default();
        let mut full = AdamWShard::new(cfg, &init);
        let mut lo = AdamWShard::new(cfg, &init[..32]);
        let mut hi = AdamWShard::new(cfg, &init[32..]);
        for _ in 0..10 {
            full.step(&grads, 1.0);
            lo.step(&grads[..32], 1.0);
            hi.step(&grads[32..], 1.0);
        }
        assert_eq!(&full.master[..32], &lo.master[..]);
        assert_eq!(&full.master[32..], &hi.master[..]);
    }

    #[test]
    fn bytes_accounting() {
        let opt = AdamWShard::new(AdamWConfig::default(), &vec![0.0; 1000]);
        assert_eq!(opt.bytes(), 12_000);
    }

    #[test]
    fn local_norms_compose() {
        let g: Vec<f32> = (0..100).map(|i| i as f32 * 0.01).collect();
        let whole = local_sq_norm(&g);
        let split = local_sq_norm(&g[..50]) + local_sq_norm(&g[50..]);
        assert!((whole - split).abs() < 1e-9);
    }
}
