//! Per-device memory accounting — paper Tables V & VI, the ZeRO memory
//! formulas of Section III, and the max-model-size capacity claims of
//! Section II (ZeRO-3 ≈ 68B vs ZeRO++ ≈ 55B on two Frontier nodes) and
//! Section VII.B (ZeRO-topo ≈ 36B).
//!
//! Mixed-precision + Adam regime (paper Section III.B): fp16 weights (2
//! bytes/param), fp16 gradients (2), optimizer states K = 12 bytes/param
//! (fp32 master + momentum + variance).

use crate::sharding::{Scheme, ShardingSpec};

/// Bytes per parameter for each state component.
pub const WEIGHT_BYTES: f64 = 2.0; // fp16
pub const GRAD_BYTES: f64 = 2.0; // fp16
pub const OPTIM_BYTES: f64 = 12.0; // Adam: fp32 master + m + v
/// INT8 secondary partition: 1 byte/param + one f32 scale per block.
pub fn int8_bytes(block: usize) -> f64 {
    1.0 + 4.0 / block as f64
}

/// Per-device memory breakdown in bytes for model states.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceMemory {
    pub weights: f64,
    pub secondary: f64,
    pub grads: f64,
    pub optim: f64,
}

impl DeviceMemory {
    pub fn total(&self) -> f64 {
        self.weights + self.secondary + self.grads + self.optim
    }
}

/// The memory model for (scheme, spec, Ψ). `quant_block` only matters for
/// schemes with a quantized secondary partition (ZeRO-topo).
#[derive(Debug, Clone)]
pub struct MemoryModel {
    pub scheme: Scheme,
    pub spec: ShardingSpec,
    pub quant_block: usize,
}

impl MemoryModel {
    pub fn new(scheme: Scheme, spec: ShardingSpec) -> Self {
        MemoryModel { scheme, spec, quant_block: crate::quant::DEFAULT_BLOCK }
    }

    /// Weight memory per device — paper Table V.
    ///
    /// * ZeRO-3:  2Ψ / (N_w · P_w)
    /// * ZeRO++:  2Ψ / (N_w · P_w) + 2Ψ / P        (fp16 secondary in-node)
    /// * Ours:    2Ψ / 2 + Ψ / sec                  (INT8 secondary)
    pub fn weight_bytes_per_device(&self, psi: f64) -> (f64, f64) {
        let primary = WEIGHT_BYTES * psi / self.spec.weights as f64;
        let secondary = match self.scheme {
            Scheme::ZeroPP => WEIGHT_BYTES * psi / self.spec.secondary as f64,
            // resolved degree from the spec (handles `sec_degree: 0` auto)
            Scheme::ZeroTopo { .. } => {
                int8_bytes(self.quant_block) * psi / self.spec.secondary as f64
            }
            _ => 0.0,
        };
        (primary, secondary)
    }

    /// Gradient memory per device — paper Table VI: 2Ψ / d_g.
    pub fn grad_bytes_per_device(&self, psi: f64) -> f64 {
        GRAD_BYTES * psi / self.spec.grads as f64
    }

    /// Optimizer-state memory per device: KΨ / d_os.
    pub fn optim_bytes_per_device(&self, psi: f64) -> f64 {
        OPTIM_BYTES * psi / self.spec.optim as f64
    }

    pub fn per_device(&self, psi: f64) -> DeviceMemory {
        let (weights, secondary) = self.weight_bytes_per_device(psi);
        DeviceMemory {
            weights,
            secondary,
            grads: self.grad_bytes_per_device(psi),
            optim: self.optim_bytes_per_device(psi),
        }
    }

    /// Largest Ψ whose model states fit in `hbm` bytes per device
    /// (excluding activations/buffers, as the paper's Section II estimate).
    /// Memory is linear in Ψ, so the bound is closed-form.
    pub fn max_model_size(&self, hbm: f64) -> f64 {
        let per_psi = self.per_device(1.0).total();
        hbm / per_psi
    }

    /// Capacity when only counting components in the mask (the paper's
    /// §VII.B 36B figure excludes optimizer states, which shrink with N).
    pub fn max_model_size_weights_grads(&self, hbm: f64) -> f64 {
        let m = self.per_device(1.0);
        hbm / (m.weights + m.secondary + m.grads)
    }
}

/// The ZeRO stage memory formulas of Section III (bytes per device for a
/// model of Ψ params over N data-parallel workers) — used as a cross-check
/// oracle against the scheme-derived model.
pub fn zero_stage_total(stage: u8, psi: f64, n: f64) -> f64 {
    match stage {
        0 => (4.0 + 12.0) * psi,                      // plain DP: 4Ψ + KΨ
        1 => 4.0 * psi + OPTIM_BYTES * psi / n,       // 4Ψ + KΨ/N
        2 => 2.0 * psi + (2.0 + OPTIM_BYTES) * psi / n, // 2Ψ + (2+K)Ψ/N
        3 => (4.0 + OPTIM_BYTES) * psi / n,           // (4+K)Ψ/N
        _ => panic!("bad stage"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sharding::Scheme;
    use crate::topology::Cluster;

    fn model(scheme: Scheme, nodes: usize) -> MemoryModel {
        let c = Cluster::frontier(nodes);
        MemoryModel::new(scheme, ShardingSpec::resolve(scheme, &c).unwrap())
    }

    const GB: f64 = 1e9;

    #[test]
    fn table5_weight_memory() {
        let psi = 1e9;
        // ZeRO-3 over 2 nodes (16 GCDs): 2Ψ/16
        let z3 = model(Scheme::Zero3, 2);
        let (p, s) = z3.weight_bytes_per_device(psi);
        assert_eq!(p, 2.0 * psi / 16.0);
        assert_eq!(s, 0.0);
        // ZeRO++: + 2Ψ/8 secondary
        let zpp = model(Scheme::ZeroPP, 2);
        let (p, s) = zpp.weight_bytes_per_device(psi);
        assert_eq!(p, 2.0 * psi / 16.0);
        assert_eq!(s, 2.0 * psi / 8.0);
        // Ours sec=8: 2Ψ/2 + ~Ψ/8 (INT8 + scales)
        let t8 = model(Scheme::ZeroTopo { sec_degree: 8 }, 2);
        let (p, s) = t8.weight_bytes_per_device(psi);
        assert_eq!(p, psi);
        assert!((s - psi / 8.0).abs() / (psi / 8.0) < 0.02, "{s}");
        // Ours sec=2: 2Ψ/2 + ~Ψ/2
        let t2 = model(Scheme::ZeroTopo { sec_degree: 2 }, 2);
        let (_, s2) = t2.weight_bytes_per_device(psi);
        assert!((s2 - psi / 2.0).abs() / (psi / 2.0) < 0.02);
    }

    #[test]
    fn table5_ours_is_worker_count_independent() {
        let psi = 5e9;
        let a = model(Scheme::ZeroTopo { sec_degree: 8 }, 2).weight_bytes_per_device(psi);
        let b = model(Scheme::ZeroTopo { sec_degree: 8 }, 48).weight_bytes_per_device(psi);
        assert_eq!(a, b); // fixed regardless of scale — the paper's point
        let z3a = model(Scheme::Zero3, 2).weight_bytes_per_device(psi).0;
        let z3b = model(Scheme::Zero3, 48).weight_bytes_per_device(psi).0;
        assert!(z3b < z3a); // ZeRO-3 keeps shrinking
    }

    #[test]
    fn table6_gradient_memory() {
        let psi = 1e9;
        assert_eq!(model(Scheme::Zero3, 2).grad_bytes_per_device(psi), 2.0 * psi / 16.0);
        assert_eq!(model(Scheme::ZeroPP, 2).grad_bytes_per_device(psi), 2.0 * psi / 16.0);
        // ours: fixed 2Ψ/8 regardless of node count
        assert_eq!(
            model(Scheme::ZeroTopo { sec_degree: 2 }, 2).grad_bytes_per_device(psi),
            2.0 * psi / 8.0
        );
        assert_eq!(
            model(Scheme::ZeroTopo { sec_degree: 2 }, 48).grad_bytes_per_device(psi),
            2.0 * psi / 8.0
        );
    }

    #[test]
    fn section2_capacity_claims() {
        // Two Frontier nodes, 64 GB per GCD. The paper: ZeRO-3 ≈ 68B,
        // ZeRO++ ≈ 55B. Our accounting reproduces the ratio (~0.81) and
        // the magnitude (±15%).
        let hbm = 64.0 * GB;
        let z3 = model(Scheme::Zero3, 2).max_model_size(hbm);
        let zpp = model(Scheme::ZeroPP, 2).max_model_size(hbm);
        assert!((55e9..75e9).contains(&z3), "{z3}");
        assert!((45e9..62e9).contains(&zpp), "{zpp}");
        let ratio = zpp / z3;
        assert!((0.75..0.88).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn section7b_topo_capacity() {
        // §VII.B: weights must fit two GCDs → ~36B ceiling (weights +
        // secondary + grads accounting).
        let hbm = 64.0 * GB;
        let topo = model(Scheme::ZeroTopo { sec_degree: 2 }, 2);
        let cap = topo.max_model_size_weights_grads(hbm);
        assert!((30e9..42e9).contains(&cap), "{cap}");
    }

    #[test]
    fn zero_stage_formulas() {
        let psi = 1e9;
        let n = 16.0;
        assert_eq!(zero_stage_total(0, psi, n), 16.0 * psi);
        assert_eq!(zero_stage_total(1, psi, n), 4.0 * psi + 12.0 * psi / n);
        assert_eq!(zero_stage_total(2, psi, n), 2.0 * psi + 14.0 * psi / n);
        assert_eq!(zero_stage_total(3, psi, n), psi);
        // monotone: each stage strictly reduces memory for N > 1
        for s in 0..3u8 {
            assert!(zero_stage_total(s, psi, n) > zero_stage_total(s + 1, psi, n));
        }
    }

    #[test]
    fn scheme_totals_match_stage_formulas() {
        // ZeRO-3 via the scheme machinery == the closed-form stage-3 total.
        let psi = 1e9;
        let m = model(Scheme::Zero3, 2);
        let total = m.per_device(psi).total();
        assert!((total - zero_stage_total(3, psi, 16.0)).abs() < 1.0);
    }

    #[test]
    fn topo_trades_memory_for_bandwidth() {
        // ZeRO-topo per-device memory must exceed ZeRO-3's at scale — the
        // documented trade (Section V.A: "we trade memory for communication
        // efficiency").
        let psi = 10e9;
        let z3 = model(Scheme::Zero3, 48).per_device(psi).total();
        let topo = model(Scheme::ZeroTopo { sec_degree: 8 }, 48).per_device(psi).total();
        assert!(topo > z3);
    }
}
