//! Per-device memory accounting — paper Tables V & VI, the ZeRO memory
//! formulas of Section III, and the max-model-size capacity claims of
//! Section II (ZeRO-3 ≈ 68B vs ZeRO++ ≈ 55B on two Frontier nodes) and
//! Section VII.B (ZeRO-topo ≈ 36B).
//!
//! Mixed-precision + Adam regime (paper Section III.B): fp16 weights (2
//! bytes/param), fp16 gradients (2), optimizer states K = 12 bytes/param
//! (fp32 master + momentum + variance).

use crate::model::TransformerSpec;
use crate::sched::pipeline::{in_flight_chunks, split_even};
use crate::sched::plan::gather_window_params;
use crate::sched::Depth;
use crate::sharding::{Scheme, ShardingError, ShardingSpec};
use crate::topology::Cluster;

/// fp16 weight bytes per parameter.
pub const WEIGHT_BYTES: f64 = 2.0;
/// fp16 gradient bytes per parameter.
pub const GRAD_BYTES: f64 = 2.0;
/// Adam optimizer-state bytes per parameter (fp32 master + m + v), the
/// paper's K = 12.
pub const OPTIM_BYTES: f64 = 12.0;
/// INT8 secondary partition: 1 byte/param + one f32 scale per block.
pub fn int8_bytes(block: usize) -> f64 {
    1.0 + 4.0 / block as f64
}

/// Per-device memory breakdown in bytes for model states.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceMemory {
    /// fp16 primary weight shard bytes (Table V).
    pub weights: f64,
    /// Secondary-partition copy bytes (ZeRO++ fp16 / ZeRO-topo INT8).
    pub secondary: f64,
    /// fp16 gradient shard bytes (Table VI).
    pub grads: f64,
    /// Adam optimizer-state shard bytes (K = 12 bytes/param).
    pub optim: f64,
}

impl DeviceMemory {
    /// Sum of all model-state components per device.
    pub fn total(&self) -> f64 {
        self.weights + self.secondary + self.grads + self.optim
    }
}

/// The memory model for (scheme, spec, Ψ). `quant_block` only matters for
/// schemes with a quantized secondary partition (ZeRO-topo).
#[derive(Debug, Clone)]
pub struct MemoryModel {
    /// The ZeRO variant whose partitioning the model prices.
    pub scheme: Scheme,
    /// Resolved partition degrees for weights/grads/optimizer/secondary.
    pub spec: ShardingSpec,
    /// INT8 quantization block size for the secondary partition.
    pub quant_block: usize,
}

impl MemoryModel {
    /// Build a model with the default quantization block
    /// (`quant::DEFAULT_BLOCK`).
    pub fn new(scheme: Scheme, spec: ShardingSpec) -> Self {
        MemoryModel { scheme, spec, quant_block: crate::quant::DEFAULT_BLOCK }
    }

    /// Weight memory per device — paper Table V.
    ///
    /// * ZeRO-3:  2Ψ / (N_w · P_w)
    /// * ZeRO++:  2Ψ / (N_w · P_w) + 2Ψ / P        (fp16 secondary in-node)
    /// * Ours:    2Ψ / 2 + Ψ / sec                  (INT8 secondary)
    pub fn weight_bytes_per_device(&self, psi: f64) -> (f64, f64) {
        let primary = WEIGHT_BYTES * psi / self.spec.weights as f64;
        let secondary = match self.scheme {
            Scheme::ZeroPP => WEIGHT_BYTES * psi / self.spec.secondary as f64,
            // resolved degree from the spec (handles `sec_degree: 0` auto)
            Scheme::ZeroTopo { .. } => {
                int8_bytes(self.quant_block) * psi / self.spec.secondary as f64
            }
            _ => 0.0,
        };
        (primary, secondary)
    }

    /// Gradient memory per device — paper Table VI: 2Ψ / d_g.
    pub fn grad_bytes_per_device(&self, psi: f64) -> f64 {
        GRAD_BYTES * psi / self.spec.grads as f64
    }

    /// Optimizer-state memory per device: KΨ / d_os.
    pub fn optim_bytes_per_device(&self, psi: f64) -> f64 {
        OPTIM_BYTES * psi / self.spec.optim as f64
    }

    /// Full per-device breakdown for a model of Ψ = `psi` parameters.
    pub fn per_device(&self, psi: f64) -> DeviceMemory {
        let (weights, secondary) = self.weight_bytes_per_device(psi);
        DeviceMemory {
            weights,
            secondary,
            grads: self.grad_bytes_per_device(psi),
            optim: self.optim_bytes_per_device(psi),
        }
    }

    /// Largest Ψ whose model states fit in `hbm` bytes per device
    /// (excluding activations/buffers, as the paper's Section II estimate).
    /// Memory is linear in Ψ, so the bound is closed-form.
    pub fn max_model_size(&self, hbm: f64) -> f64 {
        let per_psi = self.per_device(1.0).total();
        hbm / per_psi
    }

    /// Capacity when only counting components in the mask (the paper's
    /// §VII.B 36B figure excludes optimizer states, which shrink with N).
    pub fn max_model_size_weights_grads(&self, hbm: f64) -> f64 {
        let m = self.per_device(1.0);
        hbm / (m.weights + m.secondary + m.grads)
    }
}

/// Schedule knobs that shape the live-memory high-water mark beyond the
/// persistent model states: prefetch window, layer-block split, and the
/// pipeline shape. Mirrors the corresponding `sim::SimConfig` /
/// `config::RunConfig` fields so a run description maps 1:1 onto a fit
/// query (DESIGN.md §15).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FitConfig {
    /// Micro-batch size per GCD (activation payload per layer).
    pub micro_batch: usize,
    /// Quantization block for INT8 secondary partitions.
    pub quant_block: usize,
    /// Prefetch depth gating the gather stream (units = layer blocks
    /// when `layer_blocks > 1`, whole-model gathers otherwise).
    pub prefetch_depth: Depth,
    /// Layer blocks each microbatch gather is split into (1 =
    /// monolithic: the full fp16 model materializes per gather).
    pub layer_blocks: usize,
    /// Pipeline stages `P` (1 = pure data-parallel).
    pub stages: usize,
    /// Pipeline microbatches `M` per step; 0 = unresolved (the 1F1B
    /// in-flight bound then assumes steady state, `M ≥ P`).
    pub microbatches: usize,
    /// Virtual chunks per stage `V`.
    pub interleave: usize,
}

impl Default for FitConfig {
    fn default() -> Self {
        FitConfig {
            micro_batch: 1,
            quant_block: crate::quant::DEFAULT_BLOCK,
            prefetch_depth: Depth::Infinite,
            layer_blocks: 1,
            stages: 1,
            microbatches: 0,
            interleave: 1,
        }
    }
}

/// Why a fit query could not be evaluated (the same legality rules the
/// simulator enforces, surfaced before any pricing).
#[derive(Debug, thiserror::Error)]
pub enum FitError {
    /// The ZeRO scheme could not resolve on the (per-stage) DP group.
    #[error(transparent)]
    Sharding(#[from] ShardingError),
    /// Stages are whole node groups; `P` must divide the node count.
    #[error("{stages} pipeline stages do not divide {nodes} nodes")]
    StagesDontDivideNodes {
        /// Requested stage count `P`.
        stages: usize,
        /// Cluster node count.
        nodes: usize,
    },
}

/// The schedule-aware per-device memory ledger for one `(model, scheme,
/// machine, schedule)` point: persistent model states (Tables V/VI)
/// plus the two live terms the schedule controls — the prefetch gather
/// window and the 1F1B in-flight activations. All byte fields are for
/// the **binding** (max-total) pipeline stage; `P = 1` has exactly one
/// stage. Produced by [`fit_report`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemoryFit {
    /// The scheme the ledger prices.
    pub scheme: Scheme,
    /// Parameters owned by the binding stage (the whole model at `P=1`).
    pub psi: f64,
    /// Index of the binding stage (0 at `P = 1`).
    pub stage: usize,
    /// fp16 primary weight shard bytes (Table V).
    pub weights: f64,
    /// Secondary-partition copy bytes (ZeRO++ fp16 / ZeRO-topo INT8).
    pub secondary: f64,
    /// fp16 gradient shard bytes (Table VI).
    pub grads: f64,
    /// Adam optimizer-state shard bytes (K = 12 bytes/param).
    pub optim: f64,
    /// Live fp16 gathered-weight window: `2 ×` the largest parameter
    /// count the prefetch gate lets onto the gather stream at once
    /// (`sched::plan::gather_window_params`).
    pub gather_window: f64,
    /// Live activation bytes: in-flight microbatch chunks
    /// (`sched::pipeline::in_flight_chunks`) × retained per-layer
    /// hidden states of the stage's layers.
    pub activations: f64,
    /// HBM budget per device the verdict is judged against.
    pub hbm: f64,
}

impl MemoryFit {
    /// Persistent model-state bytes (weights + secondary + grads + optim).
    pub fn state_bytes(&self) -> f64 {
        self.weights + self.secondary + self.grads + self.optim
    }

    /// Total per-device high-water mark: states + gather window +
    /// in-flight activations.
    pub fn total(&self) -> f64 {
        self.state_bytes() + self.gather_window + self.activations
    }

    /// The hard HBM verdict: does the high-water mark fit the budget?
    pub fn fits(&self) -> bool {
        self.total() <= self.hbm
    }

    /// Bytes over budget (0 when the point fits).
    pub fn overage(&self) -> f64 {
        (self.total() - self.hbm).max(0.0)
    }

    /// Bytes under budget (0 when the point is over).
    pub fn headroom(&self) -> f64 {
        (self.hbm - self.total()).max(0.0)
    }

    /// Largest model (total parameters Ψ) this `(scheme, schedule,
    /// machine)` point could hold: states and window scale linearly in
    /// Ψ while the activation term is pinned at this model's shape, so
    /// the bound is closed-form. Returns 0 when activations alone
    /// exceed the budget.
    pub fn max_model_params(&self, total_psi: f64) -> f64 {
        let per_psi = (self.state_bytes() + self.gather_window) / self.psi;
        let budget = self.hbm - self.activations;
        if budget <= 0.0 || per_psi <= 0.0 {
            return 0.0;
        }
        // scale through the binding stage's share of the model
        (budget / per_psi) * (total_psi / self.psi)
    }
}

/// Evaluate the schedule-aware memory ledger for `(model, scheme,
/// cluster)` under the schedule knobs in `cfg`, returning the binding
/// (max-total) stage's [`MemoryFit`]. Pure arithmetic — no simulation,
/// no cost model — so the planner can prune infeasible points before
/// pricing anything (DESIGN.md §15):
///
/// * **states**: Tables V/VI via [`MemoryModel::per_device`] on the
///   stage's parameter share, with the scheme resolved on the stage's
///   `nodes / P` sub-cluster (exactly how `PipelinePlan` resolves it);
/// * **gather window**: `2 ×` [`gather_window_params`] over the layer
///   blocks of the stage (`P = 1`: `model.chunk_params(layer_blocks)`;
///   `P > 1`: the stage's virtual chunks gather monolithically, as the
///   pipeline plan schedules them);
/// * **activations**: [`in_flight_chunks`] × the stage's retained
///   per-layer hidden states (`2 · mbs · seq · d_model` each).
pub fn fit_report(
    model: &TransformerSpec,
    scheme: Scheme,
    cluster: &Cluster,
    cfg: &FitConfig,
) -> Result<MemoryFit, FitError> {
    let p = cfg.stages.max(1);
    if cluster.nodes % p != 0 {
        return Err(FitError::StagesDontDivideNodes { stages: p, nodes: cluster.nodes });
    }
    let v = if p == 1 { 1 } else { cfg.interleave.max(1) };
    let sub = Cluster::new(cluster.spec.clone(), cluster.nodes / p);
    let spec = ShardingSpec::resolve(scheme, &sub)?;
    let mem = MemoryModel { scheme, spec, quant_block: cfg.quant_block.max(1) };

    let chunk_psi = model.chunk_params(p * v);
    let chunk_layers = split_even(model.n_layers, p * v);
    let act_per_layer = model.activation_bytes(cfg.micro_batch.max(1)) as f64;
    let hbm = cluster.hbm_per_worker();

    let mut best: Option<MemoryFit> = None;
    for s in 0..p {
        // stage s owns virtual chunks j = v·P + s (pipeline.rs layout)
        let owned: Vec<usize> = (0..v).map(|c| c * p + s).collect();
        let psi: u64 = owned.iter().map(|&j| chunk_psi[j]).sum();
        let states = mem.per_device(psi as f64);
        let window_elems = if p == 1 {
            // DP: the depth gate runs over the layer-block split
            gather_window_params(
                &model.chunk_params(cfg.layer_blocks.max(1)),
                cfg.prefetch_depth,
            )
        } else {
            // pipeline: each virtual chunk gathers monolithically; the
            // depth gate spans the stage's chunk sequence
            let elems: Vec<u64> = owned.iter().map(|&j| chunk_psi[j]).collect();
            gather_window_params(&elems, cfg.prefetch_depth)
        };
        let max_chunk_layers =
            owned.iter().map(|&j| chunk_layers[j]).max().unwrap_or(0);
        let in_flight = in_flight_chunks(p, cfg.microbatches, v, s);
        let fit = MemoryFit {
            scheme,
            psi: psi as f64,
            stage: s,
            weights: states.weights,
            secondary: states.secondary,
            grads: states.grads,
            optim: states.optim,
            gather_window: WEIGHT_BYTES * window_elems as f64,
            activations: in_flight as f64 * max_chunk_layers as f64 * act_per_layer,
            hbm,
        };
        let binding = match &best {
            None => true,
            Some(b) => fit.total() > b.total(),
        };
        if binding {
            best = Some(fit);
        }
    }
    Ok(best.expect("at least one stage"))
}

/// The ZeRO stage memory formulas of Section III (bytes per device for a
/// model of Ψ params over N data-parallel workers) — used as a cross-check
/// oracle against the scheme-derived model.
pub fn zero_stage_total(stage: u8, psi: f64, n: f64) -> f64 {
    match stage {
        0 => (4.0 + 12.0) * psi,                      // plain DP: 4Ψ + KΨ
        1 => 4.0 * psi + OPTIM_BYTES * psi / n,       // 4Ψ + KΨ/N
        2 => 2.0 * psi + (2.0 + OPTIM_BYTES) * psi / n, // 2Ψ + (2+K)Ψ/N
        3 => (4.0 + OPTIM_BYTES) * psi / n,           // (4+K)Ψ/N
        _ => panic!("bad stage"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sharding::Scheme;
    use crate::topology::Cluster;

    fn model(scheme: Scheme, nodes: usize) -> MemoryModel {
        let c = Cluster::frontier(nodes);
        MemoryModel::new(scheme, ShardingSpec::resolve(scheme, &c).unwrap())
    }

    const GB: f64 = 1e9;

    #[test]
    fn table5_weight_memory() {
        let psi = 1e9;
        // ZeRO-3 over 2 nodes (16 GCDs): 2Ψ/16
        let z3 = model(Scheme::Zero3, 2);
        let (p, s) = z3.weight_bytes_per_device(psi);
        assert_eq!(p, 2.0 * psi / 16.0);
        assert_eq!(s, 0.0);
        // ZeRO++: + 2Ψ/8 secondary
        let zpp = model(Scheme::ZeroPP, 2);
        let (p, s) = zpp.weight_bytes_per_device(psi);
        assert_eq!(p, 2.0 * psi / 16.0);
        assert_eq!(s, 2.0 * psi / 8.0);
        // Ours sec=8: 2Ψ/2 + ~Ψ/8 (INT8 + scales)
        let t8 = model(Scheme::ZeroTopo { sec_degree: 8 }, 2);
        let (p, s) = t8.weight_bytes_per_device(psi);
        assert_eq!(p, psi);
        assert!((s - psi / 8.0).abs() / (psi / 8.0) < 0.02, "{s}");
        // Ours sec=2: 2Ψ/2 + ~Ψ/2
        let t2 = model(Scheme::ZeroTopo { sec_degree: 2 }, 2);
        let (_, s2) = t2.weight_bytes_per_device(psi);
        assert!((s2 - psi / 2.0).abs() / (psi / 2.0) < 0.02);
    }

    #[test]
    fn table5_ours_is_worker_count_independent() {
        let psi = 5e9;
        let a = model(Scheme::ZeroTopo { sec_degree: 8 }, 2).weight_bytes_per_device(psi);
        let b = model(Scheme::ZeroTopo { sec_degree: 8 }, 48).weight_bytes_per_device(psi);
        assert_eq!(a, b); // fixed regardless of scale — the paper's point
        let z3a = model(Scheme::Zero3, 2).weight_bytes_per_device(psi).0;
        let z3b = model(Scheme::Zero3, 48).weight_bytes_per_device(psi).0;
        assert!(z3b < z3a); // ZeRO-3 keeps shrinking
    }

    #[test]
    fn table6_gradient_memory() {
        let psi = 1e9;
        assert_eq!(model(Scheme::Zero3, 2).grad_bytes_per_device(psi), 2.0 * psi / 16.0);
        assert_eq!(model(Scheme::ZeroPP, 2).grad_bytes_per_device(psi), 2.0 * psi / 16.0);
        // ours: fixed 2Ψ/8 regardless of node count
        assert_eq!(
            model(Scheme::ZeroTopo { sec_degree: 2 }, 2).grad_bytes_per_device(psi),
            2.0 * psi / 8.0
        );
        assert_eq!(
            model(Scheme::ZeroTopo { sec_degree: 2 }, 48).grad_bytes_per_device(psi),
            2.0 * psi / 8.0
        );
    }

    #[test]
    fn section2_capacity_claims() {
        // Two Frontier nodes, 64 GB per GCD. The paper: ZeRO-3 ≈ 68B,
        // ZeRO++ ≈ 55B. Our accounting reproduces the ratio (~0.81) and
        // the magnitude (±15%).
        let hbm = 64.0 * GB;
        let z3 = model(Scheme::Zero3, 2).max_model_size(hbm);
        let zpp = model(Scheme::ZeroPP, 2).max_model_size(hbm);
        assert!((55e9..75e9).contains(&z3), "{z3}");
        assert!((45e9..62e9).contains(&zpp), "{zpp}");
        let ratio = zpp / z3;
        assert!((0.75..0.88).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn section7b_topo_capacity() {
        // §VII.B: weights must fit two GCDs → ~36B ceiling (weights +
        // secondary + grads accounting).
        let hbm = 64.0 * GB;
        let topo = model(Scheme::ZeroTopo { sec_degree: 2 }, 2);
        let cap = topo.max_model_size_weights_grads(hbm);
        assert!((30e9..42e9).contains(&cap), "{cap}");
    }

    #[test]
    fn zero_stage_formulas() {
        let psi = 1e9;
        let n = 16.0;
        assert_eq!(zero_stage_total(0, psi, n), 16.0 * psi);
        assert_eq!(zero_stage_total(1, psi, n), 4.0 * psi + 12.0 * psi / n);
        assert_eq!(zero_stage_total(2, psi, n), 2.0 * psi + 14.0 * psi / n);
        assert_eq!(zero_stage_total(3, psi, n), psi);
        // monotone: each stage strictly reduces memory for N > 1
        for s in 0..3u8 {
            assert!(zero_stage_total(s, psi, n) > zero_stage_total(s + 1, psi, n));
        }
    }

    #[test]
    fn scheme_totals_match_stage_formulas() {
        // ZeRO-3 via the scheme machinery == the closed-form stage-3 total.
        let psi = 1e9;
        let m = model(Scheme::Zero3, 2);
        let total = m.per_device(psi).total();
        assert!((total - zero_stage_total(3, psi, 16.0)).abs() < 1.0);
    }

    fn spec20b() -> TransformerSpec {
        TransformerSpec::by_name("20b").unwrap()
    }

    #[test]
    fn fit_report_p1_monolithic_degenerates_to_per_device() {
        // blocks=1 / P=1: states reduce exactly to Tables V/VI and the
        // window to the full 2Ψ fp16 gather
        let c = Cluster::frontier(48);
        let m = spec20b();
        let psi = m.n_params() as f64;
        for scheme in [Scheme::Zero3, Scheme::ZeroPP, Scheme::ZeroTopo { sec_degree: 2 }] {
            let fit = fit_report(&m, scheme, &c, &FitConfig::default()).unwrap();
            let dev = model(scheme, 48).per_device(psi);
            assert!((fit.state_bytes() - dev.total()).abs() < 1.0, "{scheme:?}");
            assert_eq!(fit.stage, 0);
            assert_eq!(fit.psi, psi);
            assert!((fit.gather_window - 2.0 * psi).abs() < 1.0, "{scheme:?}");
            let act = m.n_layers as f64 * m.activation_bytes(1) as f64;
            assert!((fit.activations - act).abs() < 1.0, "{scheme:?}");
            assert_eq!(fit.hbm, 64.0 * GB);
        }
    }

    #[test]
    fn fit_report_window_monotone_in_depth() {
        let c = Cluster::frontier(48);
        let m = spec20b();
        let mut prev = 0.0;
        for d in 0..m.n_layers + 2 {
            let cfg = FitConfig {
                prefetch_depth: Depth::Bounded(d),
                layer_blocks: m.n_layers,
                ..FitConfig::default()
            };
            let f = fit_report(&m, Scheme::ZeroTopo { sec_degree: 2 }, &c, &cfg).unwrap();
            assert!(f.gather_window >= prev, "depth {d}");
            assert!(f.gather_window <= 2.0 * m.n_params() as f64 + 1.0);
            prev = f.gather_window;
        }
        // deep enough == monolithic
        assert!((prev - 2.0 * m.n_params() as f64).abs() < 1.0);
    }

    #[test]
    fn fit_report_pipeline_stage_accounting() {
        // P=4, M=8: stage 0 (embeddings chunk, deepest 1F1B warmup) binds
        let c = Cluster::frontier(48);
        let m = spec20b();
        let cfg = FitConfig { stages: 4, microbatches: 8, ..FitConfig::default() };
        let f = fit_report(&m, Scheme::ZeroTopo { sec_degree: 2 }, &c, &cfg).unwrap();
        assert_eq!(f.stage, 0);
        // 44 layers / 4 stages = 11 per stage, min(P - 0, M) = 4 in flight
        let act1 = m.activation_bytes(1) as f64;
        assert!((f.activations - 4.0 * 11.0 * act1).abs() < 1.0, "{}", f.activations);
        // the stage's chunk gathers monolithically: window = 2 Ψ_stage
        assert!((f.gather_window - 2.0 * f.psi).abs() < 1.0);
        // stage owns about a quarter of the model (plus the embeddings)
        let quarter = m.n_params() as f64 / 4.0;
        assert!(f.psi > quarter && f.psi < 1.1 * quarter, "{}", f.psi);
    }

    #[test]
    fn fit_report_legality_errors() {
        let c = Cluster::frontier(48);
        let m = spec20b();
        let cfg = FitConfig { stages: 5, ..FitConfig::default() };
        match fit_report(&m, Scheme::Zero3, &c, &cfg) {
            Err(FitError::StagesDontDivideNodes { stages: 5, nodes: 48 }) => {}
            other => panic!("want StagesDontDivideNodes, got {other:?}"),
        }
        // sec_degree 3 is not a frontier level span
        let bad = fit_report(&m, Scheme::ZeroTopo { sec_degree: 3 }, &c, &FitConfig::default());
        assert!(matches!(bad, Err(FitError::Sharding(_))));
    }

    #[test]
    fn fit_report_monolithic_topo_overflows_but_layered_window_fits() {
        // the planner's headline disagreement with the hand-tuned config:
        // monolithic ZeRO-topo 20B @ 384 GCDs wants ~2Ψ of live gathered
        // weights on top of ~37 GB of states — over the 64 GB budget —
        // while a depth-2 window over 44 layer blocks fits easily
        let c = Cluster::frontier(48);
        let m = spec20b();
        let scheme = Scheme::ZeroTopo { sec_degree: 2 };
        let mono = fit_report(&m, scheme, &c, &FitConfig::default()).unwrap();
        assert!(!mono.fits());
        assert!(mono.overage() > 10.0 * GB, "{}", mono.overage());
        let layered = fit_report(
            &m,
            scheme,
            &c,
            &FitConfig {
                prefetch_depth: Depth::Bounded(2),
                layer_blocks: m.n_layers,
                ..FitConfig::default()
            },
        )
        .unwrap();
        assert!(layered.fits(), "{}", layered.total());
        assert!(layered.headroom() > 10.0 * GB);
        // ZeRO-3 fits even monolithically: tiny states
        let z3 = fit_report(&m, Scheme::Zero3, &c, &FitConfig::default()).unwrap();
        assert!(z3.fits());
    }

    #[test]
    fn fit_report_max_model_params_inverts_the_ledger() {
        // a model of exactly max_model_params() should sit at the budget
        let c = Cluster::frontier(48);
        let m = spec20b();
        let f = fit_report(&m, Scheme::Zero3, &c, &FitConfig::default()).unwrap();
        let cap = f.max_model_params(m.n_params() as f64);
        // scale the ledger linearly to cap: states+window scale, act fixed
        let scale = cap / m.n_params() as f64;
        let scaled = (f.state_bytes() + f.gather_window) * scale + f.activations;
        assert!((scaled - f.hbm).abs() < 1e-3 * f.hbm, "{scaled}");
    }

    #[test]
    fn topo_trades_memory_for_bandwidth() {
        // ZeRO-topo per-device memory must exceed ZeRO-3's at scale — the
        // documented trade (Section V.A: "we trade memory for communication
        // efficiency").
        let psi = 10e9;
        let z3 = model(Scheme::Zero3, 48).per_device(psi).total();
        let topo = model(Scheme::ZeroTopo { sec_degree: 8 }, 48).per_device(psi).total();
        assert!(topo > z3);
    }
}
