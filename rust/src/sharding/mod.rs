//! Sharding schemes and partition planning — paper Section V (Table IV).
//!
//! A scheme fixes the *sharding factor* of each training-state component:
//! how many workers a full replica of that state is spread across. The
//! paper's dependency rule (from AMSP):
//!
//! ```text
//! N >= N_dp >= N_os >= N_g >= N_w   and   P >= P_dp >= P_os >= P_g >= P_w
//! ```
//!
//! i.e. optimizer states are sharded at least as widely as gradients, which
//! are sharded at least as widely as weights — otherwise a worker holds
//! gradients/optimizer states for parameters it does not own and every step
//! pays redundant traffic.

use crate::topology::Cluster;

/// Which scheme to run. `sec_degree` for ZeroTopo is the secondary-partition
/// sharding degree; it must match one of the machine's intra-node level
/// spans (paper Table V considers Frontier's 2 and 8), and `0` means
/// "auto": the machine's innermost span (Frontier: the GCD pair).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheme {
    /// ZeRO-1: shard optimizer states only.
    Zero1,
    /// ZeRO-2: shard optimizer states + gradients.
    Zero2,
    /// ZeRO-3: shard everything across all workers.
    Zero3,
    /// ZeRO++: ZeRO-3 + quantized collectives + intra-node secondary
    /// weight partitions.
    ZeroPP,
    /// The paper's contribution: weights on a GCD pair, gradients within a
    /// node, optimizer states global; all collectives quantized; secondary
    /// partitions quantized INT8.
    ZeroTopo { sec_degree: usize },
    /// MiCS (Zhang et al., related work Table X): ALL model states sharded
    /// uniformly within a group of `group` workers, replicated across
    /// groups; gradients all-reduced across replicas. No quantization,
    /// no Frontier awareness, no independent per-state factors.
    Mics { group: usize },
    /// PyTorch FSDP hybrid sharding (related work Table X): weights,
    /// gradients and optimizer states sharded within `shard` workers,
    /// replicated beyond; fp16 wire, no quantization.
    FsdpHybrid { shard: usize },
}

impl Scheme {
    pub fn name(&self) -> String {
        match self {
            Scheme::Zero1 => "ZeRO-1".into(),
            Scheme::Zero2 => "ZeRO-2".into(),
            Scheme::Zero3 => "ZeRO-3".into(),
            Scheme::ZeroPP => "ZeRO++".into(),
            Scheme::ZeroTopo { sec_degree: 0 } => "ZeRO-topo".into(),
            Scheme::ZeroTopo { sec_degree } => format!("ZeRO-topo(sec={sec_degree})"),
            Scheme::Mics { group } => format!("MiCS(g={group})"),
            Scheme::FsdpHybrid { shard } => format!("FSDP-hybrid(s={shard})"),
        }
    }

    pub fn parse(s: &str) -> Option<Scheme> {
        match s.to_ascii_lowercase().as_str() {
            "zero1" | "zero-1" => Some(Scheme::Zero1),
            "zero2" | "zero-2" => Some(Scheme::Zero2),
            "zero3" | "zero-3" => Some(Scheme::Zero3),
            "zeropp" | "zero++" | "zero-pp" => Some(Scheme::ZeroPP),
            // auto: secondary rides the machine's innermost level
            "zerotopo" | "zero-topo" | "topo" => Some(Scheme::ZeroTopo { sec_degree: 0 }),
            "mics" => Some(Scheme::Mics { group: 8 }),
            "fsdp" | "fsdp-hybrid" => Some(Scheme::FsdpHybrid { shard: 8 }),
            // generic parameterized forms — any degree a machine's level
            // spans make legal (zerotopo4, zerotopo12, ...), plus the
            // `name()` renderings so configs round-trip through JSON
            other => {
                if let Some(rest) = other
                    .strip_prefix("zero-topo")
                    .or_else(|| other.strip_prefix("zerotopo"))
                {
                    let digits = rest
                        .strip_prefix("(sec=")
                        .and_then(|r| r.strip_suffix(')'))
                        .unwrap_or(rest);
                    return digits
                        .parse::<usize>()
                        .ok()
                        .filter(|&d| d > 0)
                        .map(|d| Scheme::ZeroTopo { sec_degree: d });
                }
                if let Some(rest) =
                    other.strip_prefix("mics(g=").and_then(|r| r.strip_suffix(')'))
                {
                    return rest.parse().ok().filter(|&g| g > 0).map(|g| Scheme::Mics { group: g });
                }
                if let Some(rest) =
                    other.strip_prefix("fsdp-hybrid(s=").and_then(|r| r.strip_suffix(')'))
                {
                    return rest
                        .parse()
                        .ok()
                        .filter(|&s| s > 0)
                        .map(|s| Scheme::FsdpHybrid { shard: s });
                }
                None
            }
        }
    }

    /// Does this scheme quantize collective payloads (ZeRO++ lineage)?
    pub fn quantized(&self) -> bool {
        matches!(self, Scheme::ZeroPP | Scheme::ZeroTopo { .. })
    }

    /// Does this scheme keep a secondary weight partition?
    pub fn has_secondary(&self) -> bool {
        matches!(self, Scheme::ZeroPP | Scheme::ZeroTopo { .. })
    }
}

/// The resolved sharding factors for a (scheme, cluster) pair — the row of
/// the paper's Table IV.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardingSpec {
    /// d_w: workers a full weight replica is split across (primary).
    pub weights: usize,
    /// d_g: workers a full gradient replica is split across.
    pub grads: usize,
    /// d_os: workers the optimizer states are split across.
    pub optim: usize,
    /// Secondary weight partition degree (0 = none).
    pub secondary: usize,
    /// Total workers.
    pub world: usize,
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum ShardingError {
    #[error("dependency rule violated: requires os({optim}) >= grads({grads}) >= weights({weights})")]
    DependencyRule { weights: usize, grads: usize, optim: usize },
    #[error("sharding factor {factor} does not divide world size {world}")]
    NotDivisible { factor: usize, world: usize },
    #[error("ZeRO-topo secondary degree {degree} is not an intra-node level span of '{machine}' (valid: {spans:?})")]
    BadSecondary { degree: usize, machine: String, spans: Vec<usize> },
}

impl ShardingSpec {
    /// Resolve a scheme on a cluster — paper Table IV, generalized to any
    /// machine spec: ZeRO-topo places weights on the machine's innermost
    /// level, gradients on the node, optimizer states on the world.
    pub fn resolve(scheme: Scheme, cluster: &Cluster) -> Result<ShardingSpec, ShardingError> {
        let world = cluster.world_size();
        let p = cluster.workers_per_node();
        let spec = match scheme {
            Scheme::Zero1 => ShardingSpec { weights: 1, grads: 1, optim: world, secondary: 0, world },
            Scheme::Zero2 => ShardingSpec { weights: 1, grads: world, optim: world, secondary: 0, world },
            Scheme::Zero3 => {
                ShardingSpec { weights: world, grads: world, optim: world, secondary: 0, world }
            }
            // ZeRO++: primary = global (like ZeRO-3); secondary replica
            // inside each node (degree P) serves the backward all-gather.
            Scheme::ZeroPP => {
                ShardingSpec { weights: world, grads: world, optim: world, secondary: p, world }
            }
            // Paper: weights over the innermost level (Frontier: the 2
            // GCDs of one MI250X), gradients over the node's P workers,
            // optimizer states global. The secondary degree must map onto
            // a bandwidth tier — i.e. be one of the machine's level spans.
            Scheme::ZeroTopo { sec_degree } => {
                let inner = cluster.spec.innermost_span();
                let sec = if sec_degree == 0 { inner } else { sec_degree };
                if !cluster.spec.levels.iter().any(|l| l.span == sec) {
                    return Err(ShardingError::BadSecondary {
                        degree: sec,
                        machine: cluster.spec.name.clone(),
                        spans: cluster.spec.level_spans(),
                    });
                }
                ShardingSpec { weights: inner, grads: p, optim: world, secondary: sec, world }
            }
            // MiCS: one uniform factor for every state (scale-aware groups)
            Scheme::Mics { group } => {
                let g = group.min(world);
                ShardingSpec { weights: g, grads: g, optim: g, secondary: 0, world }
            }
            // FSDP hybrid: uniform factor, fp16 wire (like MiCS but the
            // FSDP runtime; identical factors at this modeling level)
            Scheme::FsdpHybrid { shard } => {
                let s = shard.min(world);
                ShardingSpec { weights: s, grads: s, optim: s, secondary: 0, world }
            }
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Enforce the dependency rule and divisibility.
    pub fn validate(&self) -> Result<(), ShardingError> {
        if !(self.optim >= self.grads && self.grads >= self.weights) {
            return Err(ShardingError::DependencyRule {
                weights: self.weights,
                grads: self.grads,
                optim: self.optim,
            });
        }
        for f in [self.weights, self.grads, self.optim] {
            if f == 0 || self.world % f != 0 {
                return Err(ShardingError::NotDivisible { factor: f, world: self.world });
            }
        }
        if self.secondary > 0 && self.world % self.secondary != 0 {
            return Err(ShardingError::NotDivisible { factor: self.secondary, world: self.world });
        }
        Ok(())
    }

    /// Number of independent weight-replica groups (data-parallel replicas
    /// at the weight level).
    pub fn weight_groups(&self) -> usize {
        self.world / self.weights
    }

    pub fn grad_groups(&self) -> usize {
        self.world / self.grads
    }
}

/// Maps a rank to its shard (contiguous range) of a flat buffer of `n`
/// elements split across `degree` workers. The flat buffer is padded so
/// every shard has equal length (`shard_len`), mirroring DeepSpeed's
/// flat-partition padding.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PartitionMap {
    pub n: usize,
    pub degree: usize,
    pub shard_len: usize,
}

impl PartitionMap {
    pub fn new(n: usize, degree: usize) -> PartitionMap {
        assert!(degree > 0);
        PartitionMap { n, degree, shard_len: n.div_ceil(degree) }
    }

    /// Padded total length (degree * shard_len).
    pub fn padded_len(&self) -> usize {
        self.shard_len * self.degree
    }

    /// The range of the flat PADDED buffer owned by shard index `i`.
    pub fn range(&self, i: usize) -> std::ops::Range<usize> {
        assert!(i < self.degree);
        i * self.shard_len..(i + 1) * self.shard_len
    }

    /// The unpadded (valid) sub-range of shard `i` within the original
    /// buffer, empty if the shard is pure padding.
    pub fn valid_range(&self, i: usize) -> std::ops::Range<usize> {
        let r = self.range(i);
        r.start.min(self.n)..r.end.min(self.n)
    }

    /// Which shard owns element `e`.
    pub fn owner(&self, e: usize) -> usize {
        assert!(e < self.n);
        e / self.shard_len
    }
}

/// Rank groups for a sharding degree on a cluster: ranks are grouped into
/// consecutive blocks of `degree` (matching how Frontier ranks enumerate
/// GCDs: pairs, then nodes, then the world).
pub fn shard_groups(world: usize, degree: usize) -> Vec<Vec<usize>> {
    assert!(degree > 0 && world % degree == 0);
    (0..world / degree)
        .map(|g| (g * degree..(g + 1) * degree).collect())
        .collect()
}

/// Index of `rank` within its shard group of `degree`.
pub fn index_in_group(rank: usize, degree: usize) -> usize {
    rank % degree
}

/// The group (list of ranks) that `rank` belongs to for `degree`.
pub fn group_of(rank: usize, degree: usize) -> Vec<usize> {
    let g = rank / degree;
    (g * degree..(g + 1) * degree).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::check;

    fn frontier(n: usize) -> Cluster {
        Cluster::frontier(n)
    }

    #[test]
    fn table4_sharding_factors() {
        let c = frontier(4); // 32 GCDs
        let z1 = ShardingSpec::resolve(Scheme::Zero1, &c).unwrap();
        assert_eq!((z1.weights, z1.grads, z1.optim), (1, 1, 32));
        let z2 = ShardingSpec::resolve(Scheme::Zero2, &c).unwrap();
        assert_eq!((z2.weights, z2.grads, z2.optim), (1, 32, 32));
        let z3 = ShardingSpec::resolve(Scheme::Zero3, &c).unwrap();
        assert_eq!((z3.weights, z3.grads, z3.optim), (32, 32, 32));
        let zpp = ShardingSpec::resolve(Scheme::ZeroPP, &c).unwrap();
        assert_eq!((zpp.weights, zpp.secondary), (32, 8));
        let zt = ShardingSpec::resolve(Scheme::ZeroTopo { sec_degree: 2 }, &c).unwrap();
        assert_eq!((zt.weights, zt.grads, zt.optim, zt.secondary), (2, 8, 32, 2));
    }

    #[test]
    fn dependency_rule_enforced() {
        let bad = ShardingSpec { weights: 8, grads: 2, optim: 16, secondary: 0, world: 16 };
        assert!(matches!(bad.validate(), Err(ShardingError::DependencyRule { .. })));
        let bad2 = ShardingSpec { weights: 2, grads: 16, optim: 8, secondary: 0, world: 16 };
        assert!(bad2.validate().is_err());
    }

    #[test]
    fn divisibility_enforced() {
        let bad = ShardingSpec { weights: 3, grads: 8, optim: 16, secondary: 0, world: 16 };
        assert!(matches!(bad.validate(), Err(ShardingError::NotDivisible { .. })));
    }

    #[test]
    fn secondary_degree_legality_follows_level_spans() {
        let c = frontier(1);
        // Frontier's spans are {2, 4, 8}: 3 is illegal, 4 is a real tier
        assert!(matches!(
            ShardingSpec::resolve(Scheme::ZeroTopo { sec_degree: 3 }, &c),
            Err(ShardingError::BadSecondary { degree: 3, .. })
        ));
        let s4 = ShardingSpec::resolve(Scheme::ZeroTopo { sec_degree: 4 }, &c).unwrap();
        assert_eq!(s4.secondary, 4);
        // auto (0) resolves to the innermost span
        let auto = ShardingSpec::resolve(Scheme::ZeroTopo { sec_degree: 0 }, &c).unwrap();
        assert_eq!((auto.weights, auto.secondary), (2, 2));
        // DGX has one flat level of 8: sec 2 is illegal, auto gives 8
        let d = Cluster::dgx(1);
        assert!(ShardingSpec::resolve(Scheme::ZeroTopo { sec_degree: 2 }, &d).is_err());
        let auto_d = ShardingSpec::resolve(Scheme::ZeroTopo { sec_degree: 0 }, &d).unwrap();
        assert_eq!((auto_d.weights, auto_d.grads, auto_d.secondary), (8, 8, 8));
    }

    #[test]
    fn scheme_parsing() {
        assert_eq!(Scheme::parse("zero3"), Some(Scheme::Zero3));
        assert_eq!(Scheme::parse("ZeRO++"), Some(Scheme::ZeroPP));
        // bare "zerotopo" is machine-adaptive (sec = innermost span)
        assert_eq!(Scheme::parse("zero-topo"), Some(Scheme::ZeroTopo { sec_degree: 0 }));
        assert_eq!(Scheme::parse("zerotopo2"), Some(Scheme::ZeroTopo { sec_degree: 2 }));
        assert_eq!(Scheme::parse("zerotopo8"), Some(Scheme::ZeroTopo { sec_degree: 8 }));
        // generic zerotopoN: any span a machine makes legal is expressible
        assert_eq!(Scheme::parse("zerotopo4"), Some(Scheme::ZeroTopo { sec_degree: 4 }));
        assert_eq!(Scheme::parse("zero-topo12"), Some(Scheme::ZeroTopo { sec_degree: 12 }));
        assert_eq!(Scheme::parse("zerotopo16"), Some(Scheme::ZeroTopo { sec_degree: 16 }));
        assert_eq!(Scheme::parse("zerotopo0"), None);
        assert_eq!(Scheme::parse("zerotopox"), None);
        assert_eq!(Scheme::parse("nope"), None);
    }

    #[test]
    fn scheme_names_roundtrip_through_parse() {
        for scheme in [
            Scheme::Zero1,
            Scheme::Zero2,
            Scheme::Zero3,
            Scheme::ZeroPP,
            Scheme::ZeroTopo { sec_degree: 0 },
            Scheme::ZeroTopo { sec_degree: 2 },
            Scheme::ZeroTopo { sec_degree: 12 },
            Scheme::Mics { group: 8 },
            Scheme::FsdpHybrid { shard: 16 },
        ] {
            assert_eq!(Scheme::parse(&scheme.name()), Some(scheme), "{}", scheme.name());
        }
    }

    #[test]
    fn partition_map_covers_everything() {
        check("partition map covers", 80, |g| {
            let n = g.usize_in(1, 10_000);
            let d = g.usize_in(1, 64);
            let pm = PartitionMap::new(n, d);
            // union of valid ranges is exactly [0, n), disjoint
            let mut covered = 0;
            for i in 0..d {
                let r = pm.valid_range(i);
                assert_eq!(r.start, covered.min(n));
                covered = r.end.max(covered);
            }
            assert_eq!(covered, n);
            assert!(pm.padded_len() >= n);
            assert!(pm.padded_len() - n < d.max(1) * pm.shard_len.max(1));
        });
    }

    #[test]
    fn partition_owner_consistent_with_range() {
        check("owner in range", 60, |g| {
            let n = g.usize_in(1, 5_000);
            let d = g.usize_in(1, 16);
            let pm = PartitionMap::new(n, d);
            for _ in 0..20 {
                let e = g.usize_in(0, n - 1);
                let o = pm.owner(e);
                assert!(pm.range(o).contains(&e));
            }
        });
    }

    #[test]
    fn groups_partition_the_world() {
        let groups = shard_groups(16, 4);
        assert_eq!(groups.len(), 4);
        assert_eq!(groups.concat(), (0..16).collect::<Vec<_>>());
        assert_eq!(group_of(5, 4), vec![4, 5, 6, 7]);
        assert_eq!(index_in_group(5, 4), 1);
    }

    #[test]
    fn topo_groups_respect_topology() {
        // weight groups of degree 2 must be GCD pairs; grad groups of 8 a node
        let c = frontier(2);
        let spec = ShardingSpec::resolve(Scheme::ZeroTopo { sec_degree: 2 }, &c).unwrap();
        for g in shard_groups(spec.world, spec.weights) {
            assert_eq!(c.bottleneck_class(&g), crate::topology::LinkClass::Intra(0));
        }
        for g in shard_groups(spec.world, spec.grads) {
            assert!(c.bottleneck_class(&g) < crate::topology::LinkClass::InterNode);
        }
    }
}
