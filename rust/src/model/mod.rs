//! Transformer architecture descriptions: parameter counts, FLOPs, and the
//! GPT-NeoX model family the paper evaluates (10B / 20B) plus the
//! laptop-scale proxies the numerics path actually executes.
//!
//! The analytical simulator (Fig 7/8) only needs Ψ (parameter count), layer
//! geometry and batch shape; the FLOPs model is the standard dense-decoder
//! account (Narayanan et al., Megatron-LM) used by GPT-NeoX's own
//! `flops_calculator`.

/// Architecture + batch geometry of a dense decoder-only transformer.
#[derive(Debug, Clone, PartialEq)]
pub struct TransformerSpec {
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub vocab: usize,
    pub seq: usize,
    /// Untied embedding/LM-head (GPT-NeoX-20B uses untied).
    pub tied_head: bool,
}

impl TransformerSpec {
    /// GPT-NeoX-20B (Black et al. 2022): 44 layers, d=6144, 64 heads,
    /// vocab 50432 (padded), seq 2048.
    pub fn neox20b() -> Self {
        TransformerSpec {
            name: "GPT-NeoX-20B".into(),
            d_model: 6144,
            n_layers: 44,
            n_heads: 64,
            vocab: 50432,
            seq: 2048,
            tied_head: false,
        }
    }

    /// A 10B-class GPT-NeoX configuration (the paper's second model):
    /// 32 layers, d=5120.
    pub fn neox10b() -> Self {
        TransformerSpec {
            name: "GPT-NeoX-10B".into(),
            d_model: 5120,
            n_layers: 32,
            n_heads: 40,
            vocab: 50432,
            seq: 2048,
            tied_head: false,
        }
    }

    /// GPT-style 125M (sanity-scale reference point).
    pub fn gpt125m() -> Self {
        TransformerSpec {
            name: "GPT-125M".into(),
            d_model: 768,
            n_layers: 12,
            n_heads: 12,
            vocab: 50304,
            seq: 2048,
            tied_head: true,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name.to_ascii_lowercase().as_str() {
            "20b" | "neox20b" | "gpt-neox-20b" => Some(Self::neox20b()),
            "10b" | "neox10b" | "gpt-neox-10b" => Some(Self::neox10b()),
            "125m" | "gpt125m" => Some(Self::gpt125m()),
            _ => None,
        }
    }

    /// Parameter count Ψ.
    ///
    /// Per layer: 4 d² (attention qkv+out) + 8 d² (MLP 4×) + 4d (ln scales/
    /// biases) + 13d/... biases are small; we follow the GPT-NeoX counter:
    /// 12 d² + 13d per layer, embeddings vocab·d (+ pos seq·d), final ln 2d,
    /// untied head adds vocab·d.
    pub fn n_params(&self) -> u64 {
        let d = self.d_model as u64;
        let per_layer = 12 * d * d + 13 * d;
        let emb = (self.vocab as u64) * d + (self.seq as u64) * d;
        let head = if self.tied_head { 0 } else { (self.vocab as u64) * d };
        self.n_layers as u64 * per_layer + emb + head + 2 * d
    }

    /// Ψ in bytes for a given element size.
    pub fn param_bytes(&self, elem: usize) -> u64 {
        self.n_params() * elem as u64
    }

    /// Parameter count of each of `chunks` contiguous layer blocks for a
    /// pipeline partition: layers split near-evenly (the first blocks take
    /// the remainder, so layer counts not divisible by the chunk count
    /// still partition), input embeddings (token + position) ride the
    /// first block, the final layer-norm and the (untied) LM head the
    /// last. Sums to exactly [`TransformerSpec::n_params`].
    pub fn chunk_params(&self, chunks: usize) -> Vec<u64> {
        let d = self.d_model as u64;
        let per_layer = 12 * d * d + 13 * d;
        let emb = (self.vocab as u64) * d + (self.seq as u64) * d;
        let head = if self.tied_head { 0 } else { (self.vocab as u64) * d };
        let layers = crate::sched::pipeline::split_even(self.n_layers, chunks);
        let mut out: Vec<u64> = layers.iter().map(|&l| l as u64 * per_layer).collect();
        out[0] += emb;
        *out.last_mut().expect("chunks > 0") += head + 2 * d;
        out
    }

    /// fp16 activation payload one microbatch ships across a pipeline
    /// stage boundary: `mbs · seq · d_model` half-precision elements.
    pub fn activation_bytes(&self, micro_batch: usize) -> u64 {
        2 * (micro_batch * self.seq * self.d_model) as u64
    }

    /// Dense FLOPs for one token, forward pass (2·MAC convention):
    /// 24·d² per layer for the matmuls + 4·d·seq attention score/update +
    /// 2·d·vocab head.
    pub fn flops_per_token_fwd(&self) -> f64 {
        let d = self.d_model as f64;
        let per_layer = 24.0 * d * d + 4.0 * d * self.seq as f64;
        self.n_layers as f64 * per_layer + 2.0 * d * self.vocab as f64
    }

    /// fwd + bwd (bwd ≈ 2× fwd).
    pub fn flops_per_token(&self) -> f64 {
        3.0 * self.flops_per_token_fwd()
    }

    /// FLOPs for one *optimizer step* at a global batch of `tokens`.
    pub fn flops_per_step(&self, tokens: f64) -> f64 {
        self.flops_per_token() * tokens
    }

    /// The classic 6·Ψ approximation (cross-check for the detailed count).
    pub fn flops_per_token_6n(&self) -> f64 {
        6.0 * self.n_params() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn neox20b_parameter_count() {
        let s = TransformerSpec::neox20b();
        let psi = s.n_params() as f64;
        // 20B-class: within 10% of 20.6B (the published size)
        assert!((psi - 20.6e9).abs() / 20.6e9 < 0.10, "{psi}");
    }

    #[test]
    fn neox10b_parameter_count() {
        let s = TransformerSpec::neox10b();
        let psi = s.n_params() as f64;
        assert!((8.5e9..12.5e9).contains(&psi), "{psi}");
    }

    #[test]
    fn gpt125m_parameter_count() {
        let s = TransformerSpec::gpt125m();
        let psi = s.n_params() as f64;
        assert!((100e6..170e6).contains(&psi), "{psi}");
    }

    #[test]
    fn flops_close_to_6n_for_large_models() {
        // For large d, detailed matmul count ≈ 6Ψ (attention adds a bit).
        let s = TransformerSpec::neox20b();
        let detailed = s.flops_per_token();
        let approx = s.flops_per_token_6n();
        let ratio = detailed / approx;
        assert!((0.85..1.30).contains(&ratio), "{ratio}");
    }

    #[test]
    fn fwd_bwd_ratio() {
        let s = TransformerSpec::neox10b();
        assert_eq!(s.flops_per_token(), 3.0 * s.flops_per_token_fwd());
    }

    #[test]
    fn by_name_lookup() {
        assert_eq!(TransformerSpec::by_name("20b").unwrap().name, "GPT-NeoX-20B");
        assert_eq!(TransformerSpec::by_name("10B").unwrap().name, "GPT-NeoX-10B");
        assert!(TransformerSpec::by_name("7b").is_none());
    }

    #[test]
    fn param_bytes_scaling() {
        let s = TransformerSpec::gpt125m();
        assert_eq!(s.param_bytes(2), 2 * s.n_params());
        assert_eq!(s.param_bytes(4), 4 * s.n_params());
    }

    #[test]
    fn chunk_params_sum_to_psi() {
        for spec in [
            TransformerSpec::neox20b(),
            TransformerSpec::neox10b(),
            TransformerSpec::gpt125m(),
        ] {
            for chunks in [1, 2, 3, 4, 7, 8, 16, 64] {
                let cp = spec.chunk_params(chunks);
                assert_eq!(cp.len(), chunks, "{} x{chunks}", spec.name);
                assert_eq!(cp.iter().sum::<u64>(), spec.n_params(), "{} x{chunks}", spec.name);
            }
        }
        // 44 layers over 8 chunks: uneven, no panic, first chunk heaviest
        let cp = TransformerSpec::neox20b().chunk_params(8);
        assert!(cp[0] > cp[4]);
    }

    #[test]
    fn activation_bytes_are_fp16_elements() {
        let s = TransformerSpec::gpt125m();
        assert_eq!(s.activation_bytes(1), 2 * (2048 * 768) as u64);
        assert_eq!(s.activation_bytes(4), 4 * s.activation_bytes(1));
    }

    #[test]
    fn step_flops_linear_in_tokens() {
        let s = TransformerSpec::gpt125m();
        assert_eq!(s.flops_per_step(2048.0), 2.0 * s.flops_per_step(1024.0));
    }
}
