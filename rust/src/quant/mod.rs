//! Block-based symmetric quantization — the native (Rust) port of the L1
//! Pallas kernels in `python/compile/kernels/quant.py`.
//!
//! ZeRO++ (and therefore ZeRO-topo) compresses every collective payload
//! with blockwise quantization [Dettmers et al. 2022]: INT8 for the weight
//! all-gather and the secondary weight partition, INT4 (two nibbles per
//! byte) for the all-to-all gradient reduce-scatter.
//!
//! Contract (identical to the Pallas kernels; cross-checked through PJRT in
//! `rust/tests/pjrt_quant.rs`):
//!   - per-block scale `s = max|x| / Q` (Q = 127 or 7); all-zero block → s = 1
//!   - `q = clip(round_half_to_even(x / s), -Q, Q)`
//!   - dequant `x' = q * s`
//!   - INT4 packing: nibble `n = q + 8 ∈ [1,15]`; byte = `n_even + 16*n_odd`

pub const DEFAULT_BLOCK: usize = 256;

/// An INT8-quantized buffer (1 byte/element + one f32 scale per block).
#[derive(Debug, Clone, PartialEq)]
pub struct QInt8 {
    pub q: Vec<i8>,
    pub scales: Vec<f32>,
    pub block: usize,
}

/// An INT4-quantized buffer (0.5 byte/element + one f32 scale per block).
#[derive(Debug, Clone, PartialEq)]
pub struct QInt4 {
    pub packed: Vec<u8>,
    pub scales: Vec<f32>,
    pub block: usize,
    pub n: usize,
}

/// Round-half-to-even for |y| <= 2^22 via the magic-number trick: adding
/// 1.5*2^23 pushes the value where the f32 ULP is exactly 1, so the
/// IEEE round-to-nearest-even of the ADD performs the integer rounding;
/// the subtraction is exact. ~3x faster than `f32::round_ties_even` on
/// the scalar path and bit-identical on the quantizer's [-127, 127]
/// domain (verified against the original in tests + the Pallas kernels
/// via rust/tests/pjrt_quant.rs). See EXPERIMENTS.md §Perf.
#[inline(always)]
fn round_half_even_small(y: f32) -> f32 {
    const MAGIC: f32 = 12_582_912.0; // 1.5 * 2^23
    (y + MAGIC) - MAGIC
}

#[inline]
fn block_scale(chunk: &[f32], qmax: f32) -> f32 {
    // branchless max in 4 independent lanes so the reduction vectorizes
    // (§Perf: the branchy scalar version stalled on compare-jumps)
    let mut lanes = [0.0f32; 4];
    let mut it = chunk.chunks_exact(4);
    for c in it.by_ref() {
        for (l, v) in lanes.iter_mut().zip(c) {
            *l = l.max(v.abs());
        }
    }
    let mut amax = lanes[0].max(lanes[1]).max(lanes[2]).max(lanes[3]);
    for &v in it.remainder() {
        amax = amax.max(v.abs());
    }
    if amax > 0.0 {
        amax / qmax
    } else {
        1.0
    }
}

impl QInt8 {
    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    /// Wire size in bytes (payload + scales), the quantity the cost model
    /// charges to the interconnect.
    pub fn wire_bytes(&self) -> usize {
        self.q.len() + 4 * self.scales.len()
    }
}

impl QInt4 {
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn wire_bytes(&self) -> usize {
        self.packed.len() + 4 * self.scales.len()
    }
}

/// Blockwise INT8 quantization. `x.len()` must be a multiple of `block`.
pub fn quantize_int8(x: &[f32], block: usize) -> QInt8 {
    assert!(block > 0 && x.len() % block == 0, "len {} % block {block} != 0", x.len());
    let nblocks = x.len() / block;
    let mut q = vec![0i8; x.len()];
    let mut scales = vec![0f32; nblocks];
    for b in 0..nblocks {
        let chunk = &x[b * block..(b + 1) * block];
        let s = block_scale(chunk, 127.0);
        scales[b] = s;
        let inv = 1.0 / s;
        for (o, &v) in q[b * block..(b + 1) * block].iter_mut().zip(chunk) {
            *o = round_half_even_small((v * inv).clamp(-127.0, 127.0)) as i8;
        }
    }
    QInt8 { q, scales, block }
}

/// Dequantize INT8 into a fresh buffer.
pub fn dequantize_int8(q: &QInt8) -> Vec<f32> {
    let mut out = vec![0f32; q.q.len()];
    dequantize_int8_into(q, &mut out);
    out
}

/// Dequantize INT8 into caller storage (hot path — avoids allocation).
pub fn dequantize_int8_into(q: &QInt8, out: &mut [f32]) {
    assert_eq!(out.len(), q.q.len());
    for (b, &s) in q.scales.iter().enumerate() {
        let lo = b * q.block;
        for (o, &v) in out[lo..lo + q.block].iter_mut().zip(&q.q[lo..lo + q.block]) {
            *o = v as f32 * s;
        }
    }
}

/// Blockwise INT4 quantization with nibble packing. `block` must be even.
pub fn quantize_int4(x: &[f32], block: usize) -> QInt4 {
    assert!(block > 0 && block % 2 == 0, "int4 block must be even");
    assert!(x.len() % block == 0, "len {} % block {block} != 0", x.len());
    let nblocks = x.len() / block;
    let mut packed = vec![0u8; x.len() / 2];
    let mut scales = vec![0f32; nblocks];
    for b in 0..nblocks {
        let chunk = &x[b * block..(b + 1) * block];
        let s = block_scale(chunk, 7.0);
        scales[b] = s;
        let inv = 1.0 / s;
        let out = &mut packed[b * block / 2..(b + 1) * block / 2];
        for (i, o) in out.iter_mut().enumerate() {
            let q0 = round_half_even_small((chunk[2 * i] * inv).clamp(-7.0, 7.0)) as i32;
            let q1 = round_half_even_small((chunk[2 * i + 1] * inv).clamp(-7.0, 7.0)) as i32;
            *o = ((q0 + 8) + ((q1 + 8) << 4)) as u8;
        }
    }
    QInt4 { packed, scales, block, n: x.len() }
}

/// Dequantize INT4 into a fresh buffer.
pub fn dequantize_int4(q: &QInt4) -> Vec<f32> {
    let mut out = vec![0f32; q.n];
    dequantize_int4_into(q, &mut out);
    out
}

/// Dequantize INT4 into caller storage.
pub fn dequantize_int4_into(q: &QInt4, out: &mut [f32]) {
    assert_eq!(out.len(), q.n);
    let half = q.block / 2;
    for (b, &s) in q.scales.iter().enumerate() {
        let src = &q.packed[b * half..(b + 1) * half];
        let dst = &mut out[b * q.block..(b + 1) * q.block];
        for (i, &byte) in src.iter().enumerate() {
            let lo = (byte & 0x0F) as i32 - 8;
            let hi = (byte >> 4) as i32 - 8;
            dst[2 * i] = lo as f32 * s;
            dst[2 * i + 1] = hi as f32 * s;
        }
    }
}

/// One quant→dequant round trip (what a single wire hop does to a payload).
pub fn roundtrip_int8(x: &[f32], block: usize) -> Vec<f32> {
    dequantize_int8(&quantize_int8(x, block))
}

/// INT4 round trip.
pub fn roundtrip_int4(x: &[f32], block: usize) -> Vec<f32> {
    dequantize_int4(&quantize_int4(x, block))
}

/// Pad a length up so it is divisible by `block` (callers quantizing
/// arbitrary shard sizes pad with zeros — exact under the contract since a
/// zero tail quantizes to zero).
pub fn padded_len(n: usize, block: usize) -> usize {
    n.div_ceil(block) * block
}

/// Quantization error statistics for reporting (EXPERIMENTS.md).
#[derive(Debug, Clone, Copy)]
pub struct QuantError {
    pub mae: f64,
    pub max_abs: f64,
    pub rel_rms: f64,
}

pub fn error_stats(x: &[f32], xq: &[f32]) -> QuantError {
    assert_eq!(x.len(), xq.len());
    let mut mae = 0.0;
    let mut mx = 0.0f64;
    let (mut se, mut sx) = (0.0f64, 0.0f64);
    for (&a, &b) in x.iter().zip(xq) {
        let e = (a - b) as f64;
        mae += e.abs();
        mx = mx.max(e.abs());
        se += e * e;
        sx += (a as f64) * (a as f64);
    }
    QuantError {
        mae: mae / x.len() as f64,
        max_abs: mx,
        rel_rms: if sx > 0.0 { (se / sx).sqrt() } else { 0.0 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::check;
    use crate::util::rng::Rng;

    fn randn(n: usize, seed: u64, std: f32) -> Vec<f32> {
        let mut r = Rng::new(seed);
        let mut v = vec![0.0; n];
        r.fill_normal(&mut v, std);
        v
    }

    #[test]
    fn int8_error_within_half_step() {
        let x = randn(4096, 1, 1.0);
        let q = quantize_int8(&x, 256);
        let xd = dequantize_int8(&q);
        for (b, &s) in q.scales.iter().enumerate() {
            for i in b * 256..(b + 1) * 256 {
                assert!((x[i] - xd[i]).abs() <= s * 0.5 + 1e-12);
            }
        }
    }

    #[test]
    fn int4_error_within_half_step() {
        let x = randn(2048, 2, 3.0);
        let q = quantize_int4(&x, 128);
        let xd = dequantize_int4(&q);
        for (b, &s) in q.scales.iter().enumerate() {
            for i in b * 128..(b + 1) * 128 {
                assert!((x[i] - xd[i]).abs() <= s * 0.5 + 1e-12);
            }
        }
    }

    #[test]
    fn zero_block_is_exact() {
        let x = vec![0.0f32; 512];
        let q = quantize_int8(&x, 256);
        assert!(q.scales.iter().all(|&s| s == 1.0));
        assert!(dequantize_int8(&q).iter().all(|&v| v == 0.0));
        let q4 = quantize_int4(&x, 256);
        assert!(dequantize_int4(&q4).iter().all(|&v| v == 0.0));
    }

    #[test]
    fn extremes_hit_integer_limits() {
        let mut x = vec![0.0f32; 256];
        x[0] = 10.0;
        x[1] = -10.0;
        let q = quantize_int8(&x, 256);
        assert_eq!(q.q[0], 127);
        assert_eq!(q.q[1], -127);
        let q4 = quantize_int4(&x, 256);
        assert_eq!((q4.packed[0] & 0x0F) as i32 - 8, 7);
        assert_eq!((q4.packed[0] >> 4) as i32 - 8, -7);
    }

    #[test]
    fn int4_nibble_layout_matches_pallas() {
        // q = [7, -7, 0, 1] with scale exactly 1.0
        let x = vec![7.0f32, -7.0, 0.0, 1.0];
        let q = quantize_int4(&x, 4);
        assert_eq!(q.scales[0], 1.0);
        assert_eq!(q.packed[0], ((7 + 8) + ((-7 + 8) << 4)) as u8);
        assert_eq!(q.packed[1], ((0 + 8) + ((1 + 8) << 4)) as u8);
    }

    #[test]
    fn quantization_is_projection() {
        check("q(dq(q(x))) == q(x) int8", 40, |g| {
            let nb = g.usize_in(1, 8);
            let x = g.vec_f32_exact(nb * 64, 2.0);
            let q1 = quantize_int8(&x, 64);
            let q2 = quantize_int8(&dequantize_int8(&q1), 64);
            assert_eq!(q1.q, q2.q);
        });
        check("q(dq(q(x))) == q(x) int4", 40, |g| {
            let nb = g.usize_in(1, 8);
            let x = g.vec_f32_exact(nb * 64, 2.0);
            let q1 = quantize_int4(&x, 64);
            let q2 = quantize_int4(&dequantize_int4(&q1), 64);
            assert_eq!(q1.packed, q2.packed);
        });
    }

    #[test]
    fn prop_error_bound_random_blocks() {
        check("int8 error bound", 60, |g| {
            let nb = g.usize_in(1, 16);
            let block = *g.pick(&[32usize, 64, 256]);
            let std = *g.pick(&[1e-5f32, 1e-2, 1.0, 1e3]);
            let x = g.vec_f32_exact(nb * block, std);
            let q = quantize_int8(&x, block);
            let xd = dequantize_int8(&q);
            for b in 0..nb {
                let s = q.scales[b];
                for i in b * block..(b + 1) * block {
                    assert!((x[i] - xd[i]).abs() <= s * 0.5 + 1e-12);
                }
            }
        });
    }

    #[test]
    fn int4_coarser_than_int8() {
        let x = randn(8192, 5, 1.0);
        let e8 = error_stats(&x, &roundtrip_int8(&x, 256));
        let e4 = error_stats(&x, &roundtrip_int4(&x, 256));
        assert!(e4.mae > e8.mae);
        assert!(e8.rel_rms < 0.01, "{e8:?}");
        assert!(e4.rel_rms < 0.15, "{e4:?}");
    }

    #[test]
    fn wire_bytes_accounting() {
        let x = randn(1024, 6, 1.0);
        assert_eq!(quantize_int8(&x, 256).wire_bytes(), 1024 + 4 * 4);
        assert_eq!(quantize_int4(&x, 256).wire_bytes(), 512 + 4 * 4);
    }

    #[test]
    fn padded_len_math() {
        assert_eq!(padded_len(1, 256), 256);
        assert_eq!(padded_len(256, 256), 256);
        assert_eq!(padded_len(257, 256), 512);
    }

    #[test]
    #[should_panic]
    fn rejects_misaligned() {
        quantize_int8(&[0.0; 100], 256);
    }

    #[test]
    fn magic_round_matches_round_ties_even() {
        // exhaustive on the integer/half grid plus random draws — the
        // §Perf optimization must be bit-identical on the clamped domain
        for i in -254..=254 {
            let y = i as f32 * 0.5; // all integers and halves in [-127,127]
            assert_eq!(round_half_even_small(y), y.round_ties_even(), "{y}");
        }
        let mut r = Rng::new(42);
        for _ in 0..100_000 {
            let y = r.normal_f32(0.0, 40.0).clamp(-127.0, 127.0);
            assert_eq!(round_half_even_small(y), y.round_ties_even(), "{y}");
        }
    }

    #[test]
    fn clamp_then_round_equals_round_then_clamp() {
        for i in -2600..=2600 {
            let y = i as f32 * 0.1;
            let new = round_half_even_small(y.clamp(-127.0, 127.0));
            let old = y.round_ties_even().clamp(-127.0, 127.0);
            assert_eq!(new, old, "{y}");
        }
    }

    #[test]
    fn dequant_into_matches_alloc() {
        let x = randn(512, 7, 1.0);
        let q = quantize_int8(&x, 256);
        let a = dequantize_int8(&q);
        let mut b = vec![0.0; 512];
        dequantize_int8_into(&q, &mut b);
        assert_eq!(a, b);
    }
}
