//! Tiny CLI argument parser (clap is unavailable offline — DESIGN.md §8).
//!
//! Grammar: `prog <subcommand> [--flag] [--key value] [--key=value] [pos...]`.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

#[derive(Debug, thiserror::Error)]
pub enum CliError {
    #[error("missing value for option --{0}")]
    MissingValue(String),
    #[error("invalid value for --{key}: {value} ({why})")]
    BadValue { key: String, value: String, why: String },
    #[error("missing required option --{0}")]
    MissingRequired(String),
}

impl Args {
    /// Parse from an iterator of raw args (no program name).
    /// `known_flags` lists options that take NO value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, known_flags: &[&str]) -> Result<Args, CliError> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&body) {
                    out.flags.push(body.to_string());
                } else {
                    match it.next() {
                        Some(v) => {
                            out.options.insert(body.to_string(), v);
                        }
                        None => return Err(CliError::MissingValue(body.to_string())),
                    }
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// The `i`-th positional argument (0-based, after the subcommand).
    pub fn pos(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn require(&self, key: &str) -> Result<&str, CliError> {
        self.get(key).ok_or_else(|| CliError::MissingRequired(key.to_string()))
    }

    pub fn parse_opt<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, CliError>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e: T::Err| CliError::BadValue {
                key: key.to_string(),
                value: v.to_string(),
                why: e.to_string(),
            }),
        }
    }

    /// Comma-separated list option, e.g. `--gcds 64,128,256`.
    pub fn parse_list<T: std::str::FromStr>(&self, key: &str, default: &[T]) -> Result<Vec<T>, CliError>
    where
        T::Err: std::fmt::Display,
        T: Clone,
    {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim().parse().map_err(|e: T::Err| CliError::BadValue {
                        key: key.to_string(),
                        value: p.to_string(),
                        why: e.to_string(),
                    })
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from), &["verbose", "json"]).unwrap()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = args("simulate --model 20b --gcds=384 --verbose out.csv");
        assert_eq!(a.subcommand.as_deref(), Some("simulate"));
        assert_eq!(a.get("model"), Some("20b"));
        assert_eq!(a.get("gcds"), Some("384"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["out.csv"]);
    }

    #[test]
    fn positional_accessor() {
        let a = args("explain --json a.jsonl b.jsonl");
        assert_eq!(a.pos(0), Some("a.jsonl"));
        assert_eq!(a.pos(1), Some("b.jsonl"));
        assert_eq!(a.pos(2), None);
    }

    #[test]
    fn typed_and_list_options() {
        let a = args("x --steps 50 --scales 8,16,32");
        assert_eq!(a.parse_opt("steps", 0usize).unwrap(), 50);
        assert_eq!(a.parse_opt("missing", 7usize).unwrap(), 7);
        assert_eq!(a.parse_list::<usize>("scales", &[]).unwrap(), vec![8, 16, 32]);
    }

    #[test]
    fn errors() {
        assert!(Args::parse(["--k".to_string()], &[]).is_err());
        let a = args("x --steps abc");
        assert!(a.parse_opt("steps", 0usize).is_err());
        assert!(a.require("nope").is_err());
    }
}
