//! Small statistics helpers for the bench harness (criterion is
//! unavailable offline; rust/benches/harness.rs builds on these).

/// Summary statistics over a sample of measurements.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
}

pub fn summarize(samples: &[f64]) -> Summary {
    assert!(!samples.is_empty());
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        p50: percentile_sorted(&sorted, 0.50),
        p95: percentile_sorted(&sorted, 0.95),
    }
}

/// Linear-interpolated percentile over a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty() && (0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Geometric mean (used for aggregate speedup reporting).
pub fn geomean(samples: &[f64]) -> f64 {
    assert!(!samples.is_empty());
    let s: f64 = samples.iter().map(|x| x.ln()).sum();
    (s / samples.len() as f64).exp()
}

/// Mean absolute error between two equal-length slices.
pub fn mae(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs() as f64).sum::<f64>() / a.len() as f64
}

/// Max absolute error.
pub fn max_abs_err(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs() as f64).fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert_eq!(percentile_sorted(&v, 0.5), 5.0);
        assert_eq!(percentile_sorted(&v, 0.0), 0.0);
        assert_eq!(percentile_sorted(&v, 1.0), 10.0);
    }

    #[test]
    fn geomean_of_powers() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn errors_zero_for_identical() {
        let a = [1.0f32, -2.0, 3.0];
        assert_eq!(mae(&a, &a), 0.0);
        assert_eq!(max_abs_err(&a, &a), 0.0);
    }
}
