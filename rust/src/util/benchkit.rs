//! Tiny benchmarking harness (criterion is unavailable offline —
//! DESIGN.md §8). Used by every target in `rust/benches/`.
//!
//! Measures wall time over warmup + timed iterations and prints a
//! one-line summary compatible with `cargo bench` output conventions.

use std::time::Instant;

use crate::util::stats::{summarize, Summary};

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn time_fn<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    summarize(&samples)
}

/// Print a bench line: name, mean time, throughput if bytes given.
pub fn report(name: &str, s: &Summary, bytes_per_iter: Option<usize>) {
    let mean = s.mean;
    let time_str = if mean < 1e-6 {
        format!("{:.1} ns", mean * 1e9)
    } else if mean < 1e-3 {
        format!("{:.2} us", mean * 1e6)
    } else if mean < 1.0 {
        format!("{:.3} ms", mean * 1e3)
    } else {
        format!("{:.3} s", mean)
    };
    match bytes_per_iter {
        Some(b) => {
            let gbs = b as f64 / mean / 1e9;
            println!("{name:<48} {time_str:>12}  ({gbs:.2} GB/s)  [n={} p95={:.3}ms]", s.n, s.p95 * 1e3);
        }
        None => println!("{name:<48} {time_str:>12}  [n={} p95={:.3}ms]", s.n, s.p95 * 1e3),
    }
}

/// Prevent the optimizer from discarding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_produces_samples() {
        let s = time_fn(1, 5, || {
            black_box((0..1000).sum::<usize>());
        });
        assert_eq!(s.n, 5);
        assert!(s.mean >= 0.0);
        report("test", &s, Some(8000));
        report("test2", &s, None);
    }
}
