//! From-scratch substrates: JSON, CLI parsing, PRNGs, tables, stats.
//!
//! The build environment is fully offline with a restricted crate set (no
//! serde / clap / rand), so these are implemented here (DESIGN.md §8).

pub mod benchkit;
pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;
pub mod table;
