//! ASCII table formatter — renders the paper's tables/figures as aligned
//! text in bench output and `zero-topo report`.

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    align: Vec<Align>,
    rows: Vec<Vec<String>>,
    title: Option<String>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            align: header.iter().map(|_| Align::Right).collect(),
            rows: Vec::new(),
            title: None,
        }
    }

    pub fn title(mut self, t: impl Into<String>) -> Self {
        self.title = Some(t.into());
        self
    }

    pub fn align(mut self, a: &[Align]) -> Self {
        assert_eq!(a.len(), self.header.len());
        self.align = a.to_vec();
        self
    }

    pub fn left_first(mut self) -> Self {
        if !self.align.is_empty() {
            self.align[0] = Align::Left;
        }
        self
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    pub fn rows(&self) -> &[Vec<String>] {
        &self.rows
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(t);
            out.push('\n');
        }
        let sep = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String], widths: &[usize], align: &[Align]| {
            let mut s = String::from("|");
            for i in 0..ncol {
                match align[i] {
                    Align::Left => s.push_str(&format!(" {:<w$} |", cells[i], w = widths[i])),
                    Align::Right => s.push_str(&format!(" {:>w$} |", cells[i], w = widths[i])),
                }
            }
            s
        };
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header, &widths, &vec![Align::Left; ncol]));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &widths, &self.align));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    /// CSV rendering for plotting.
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",");
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Helper: format a float with fixed decimals, trimming noise.
pub fn fnum(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}")
}

/// Helper: human-readable byte count.
pub fn human_bytes(b: f64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = b;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{v:.0} {}", UNITS[u])
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["scheme", "TFLOPS"]).left_first();
        t.row(vec!["ZeRO-3".into(), "12.3".into()]);
        t.row(vec!["ZeRO-topo".into(), "29.5".into()]);
        let s = t.render();
        assert!(s.contains("| ZeRO-3    |"), "{s}");
        assert!(s.contains("|   12.3 |"), "{s}");
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x,y".into(), "q\"z".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"z\""));
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn human_bytes_scales() {
        assert_eq!(human_bytes(512.0), "512 B");
        assert_eq!(human_bytes(2048.0), "2.00 KiB");
        assert_eq!(human_bytes(1.5 * 1024.0 * 1024.0 * 1024.0), "1.50 GiB");
    }
}
