//! Deterministic PRNGs (SplitMix64 seeding + xoshiro256**) and samplers.
//!
//! Everything in the reproduction that needs randomness (synthetic corpus,
//! property tests, workload generators) goes through these so runs are
//! exactly reproducible from a seed.

/// xoshiro256** — fast, high-quality, 256-bit state.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 (recommended by the xoshiro authors: never seed
    /// the state with correlated values).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let out = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        out
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [0, n) without modulo bias (Lemire's method).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= n.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as usize
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Fill a slice with N(0, std) f32 values.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32(0.0, std);
        }
    }

    /// Zipf-distributed index in [0, n) with exponent `s` (inverse-CDF on a
    /// precomputed table is the caller's job at scale; this is the direct
    /// rejection-free inverse via harmonic partial sums, O(n) setup).
    pub fn zipf_table(n: usize, s: f64) -> Vec<f64> {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for v in cdf.iter_mut() {
            *v /= total;
        }
        cdf
    }

    pub fn zipf(&mut self, cdf: &[f64]) -> usize {
        let u = self.f64();
        cdf.partition_point(|&c| c < u).min(cdf.len() - 1)
    }

    /// Independent stream for a worker: deterministic fork.
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(Rng::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(2);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn zipf_is_monotone_decreasing() {
        let cdf = Rng::zipf_table(100, 1.1);
        let mut r = Rng::new(4);
        let mut counts = vec![0usize; 100];
        for _ in 0..50_000 {
            counts[r.zipf(&cdf)] += 1;
        }
        assert!(counts[0] > counts[10] && counts[10] > counts[50]);
    }

    #[test]
    fn fork_streams_differ() {
        let mut r = Rng::new(5);
        let mut a = r.fork(0);
        let mut b = r.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
