//! Minimal, dependency-free JSON: a recursive-descent parser and a
//! serializer. Used for the AOT manifest (`artifacts/manifest.json`),
//! experiment configs, and machine-readable bench output.
//!
//! Supports the full JSON grammar (RFC 8259) minus surrogate-pair escapes
//! (`\uXXXX` outside the BMP), which none of our producers emit.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Numbers are kept as `f64` (adequate: our manifests hold
/// sizes < 2^53) with an integer accessor that checks round-tripping.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path access: `j.at(&["models", "tiny", "n_params"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && n.abs() <= 2f64.powi(53) => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // -- builders ----------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num<T: Into<f64>>(n: T) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}

// ---------------------------------------------------------------- parser

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            out.insert(k, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            out.push(
                                char::from_u32(cp).ok_or_else(|| self.err("surrogate escape"))?,
                            );
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(_) => {
                    // copy one utf-8 code point
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("bad utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

// ------------------------------------------------------------- serializer

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(j.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.at(&["c"]).unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let j = Json::parse(r#""A\t\\ é ü""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "A\t\\ é ü");
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,]", "{\"a\":}", "tru", "1.2.3", "\"\\x\"", "{} extra"] {
            assert!(Json::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"a":[1,2.5,"x"],"b":{"c":true,"d":null},"e":-3}"#;
        let j = Json::parse(src).unwrap();
        let re = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, re);
    }

    #[test]
    fn integer_accessor_checks() {
        assert_eq!(Json::parse("7").unwrap().as_i64(), Some(7));
        assert_eq!(Json::parse("7.5").unwrap().as_i64(), None);
        assert_eq!(Json::parse("-7").unwrap().as_usize(), None);
    }

    #[test]
    fn display_escapes_control_chars() {
        let s = Json::Str("a\u{1}b".into()).to_string();
        assert_eq!(s, "\"a\\u0001b\"");
        assert_eq!(Json::parse(&s).unwrap().as_str().unwrap(), "a\u{1}b");
    }
}
