//! `zero-topo` — CLI for the ZeRO-topo reproduction.
//!
//! Subcommands:
//!   topo      --machine frontier|dgx|...         print node topology (Fig 2/3, Tables I/II)
//!   sharding  --nodes N                          print Table IV sharding factors
//!   memory    --model 20b --nodes N              print Tables V/VI memory breakdown
//!   capacity  --nodes N                          max-model-size claims (Section II / VII.B)
//!   simulate  --model 20b|10b --nodes 8,16,...   Fig 7/8 scaling figures (analytical sim)
//!   scale                                        alias of simulate (scaling sweeps)
//!   train     --model tiny|mini|... --scheme S   real-numerics training via PJRT artifacts
//!   report                                       everything above, in order
//!
//! Every subcommand takes `--machine <name|spec.json>`: a builtin machine
//! (frontier, dgx, aurora, elcapitan, tpu-pod) or a path to a topology
//! spec JSON — machines are data, not code (`topology::spec`).

use zero_topo::config::RunConfig;
use zero_topo::engine::TrainEngine;
use zero_topo::memory::MemoryModel;
use zero_topo::model::TransformerSpec;
use zero_topo::report::{render_scaling_figure, render_stall_table, ScalingSeries};
use zero_topo::runtime::Runtime;
use zero_topo::sched::{trace, Schedule};
use zero_topo::sharding::{Scheme, ShardingSpec};
use zero_topo::sim::{scaling_series, simulate_step_schedule, SimConfig};
use zero_topo::topology::{Cluster, LinkClass, MachineSpec};
use zero_topo::util::cli::Args;
use zero_topo::util::table::{fnum, human_bytes, Table};

const USAGE: &str = "\
zero-topo — ZeRO-topo (3-level low-bandwidth partitioning) reproduction

USAGE: zero-topo <subcommand> [options]

Every subcommand accepts --machine <M> where <M> is a builtin machine
(frontier, dgx, aurora, elcapitan, tpu-pod) or a path to a topology spec
JSON (see examples/machines/). Default: frontier.

  topo      [--machine M]                   node topology (paper Fig 2/3)
  sharding  [--machine M] [--nodes N]       Table IV sharding factors
  memory    [--model 20b] [--nodes N]       Tables V/VI memory per device
  capacity  [--machine M] [--nodes N]       max model size per scheme (Sec II)
  simulate  [--machine M] [--model 20b] [--nodes 8,16,32,48]
            [--schemes zero3,zeropp,zerotopo] [--depth N|inf]
            [--stalls] [--trace out.json]   Fig 7/8 scaling (event-driven sim)
  scale     alias of simulate               cross-scale / cross-machine sweeps
  train     [--machine M] [--model tiny] [--scheme zerotopo] [--nodes 1]
            [--steps 10] [--depth N|inf] [--artifacts DIR] [--csv FILE]
                                            real training via PJRT
  report    [--machine M]                   print all analytical tables
";

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(raw, &["verbose", "json", "help", "stalls"]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    if args.flag("help") || args.subcommand.is_none() {
        println!("{USAGE}");
        return;
    }
    let sub = args.subcommand.clone().unwrap();
    let result = match sub.as_str() {
        "topo" => cmd_topo(&args),
        "sharding" => cmd_sharding(&args),
        "memory" => cmd_memory(&args),
        "capacity" => cmd_capacity(&args),
        "simulate" | "scale" => cmd_simulate(&args),
        "train" => cmd_train(&args),
        "report" => cmd_report(&args),
        other => {
            eprintln!("unknown subcommand '{other}'\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn parse_schemes(args: &Args) -> anyhow::Result<Vec<Scheme>> {
    let raw = args.get_or("schemes", "zero3,zeropp,zerotopo");
    raw.split(',')
        .map(|s| Scheme::parse(s.trim()).ok_or_else(|| anyhow::anyhow!("unknown scheme '{s}'")))
        .collect()
}

/// Resolve `--machine` (builtin name or spec-JSON path); `--node` is kept
/// as a legacy alias for `topo`.
fn resolve_machine(args: &Args) -> anyhow::Result<MachineSpec> {
    let raw = args.get("machine").or_else(|| args.get("node")).unwrap_or("frontier");
    Ok(MachineSpec::resolve(raw)?)
}

fn cmd_topo(args: &Args) -> anyhow::Result<()> {
    let spec = resolve_machine(args)?;
    println!("machine: {}", spec.name);
    println!(
        "workers/node: {}   peak fp16 FLOP/s per worker: {:.1} TF   HBM/worker: {}",
        spec.workers_per_node,
        spec.peak_flops_per_worker / 1e12,
        human_bytes(spec.hbm_per_worker)
    );
    // link-class table straight from the spec's levels — nothing hardcoded
    let mut t = Table::new(&["link class", "span", "bandwidth (GB/s)", "latency (us)"])
        .left_first();
    for class in spec.classes() {
        let s = spec.link_spec(class);
        let span = match class {
            LinkClass::Intra(k) => spec.levels[k as usize].span.to_string(),
            _ => "-".into(),
        };
        t.row(vec![
            spec.class_label(class),
            span,
            fnum(s.bandwidth / 1e9, 0),
            fnum(s.latency * 1e6, 1),
        ]);
    }
    println!("{}", t.render());
    // rank-pair link matrix for one node (digit = intra hierarchy level)
    let cluster = Cluster::new(spec.clone(), 1);
    let w = cluster.workers_per_node();
    println!("intra-node link classes (rank x rank, digit = hierarchy level):");
    for a in 0..w {
        let row: Vec<String> = (0..w)
            .map(|b| match cluster.link_between(a, b) {
                LinkClass::Local => ".".into(),
                LinkClass::Intra(k) => k.to_string(),
                LinkClass::InterNode => "I".into(),
            })
            .collect();
        println!("  {}", row.join(" "));
    }
    for (k, level) in spec.levels.iter().enumerate() {
        println!("  {k}={} ({} GB/s)", level.name, fnum(level.link.bandwidth / 1e9, 0));
    }
    Ok(())
}

/// One ZeRO-topo row per intra-node level span — on Frontier that is
/// sec = 2, 4, 8; on a flat-fabric machine a single row.
fn topo_schemes(cluster: &Cluster) -> Vec<Scheme> {
    cluster
        .spec
        .levels
        .iter()
        .map(|l| Scheme::ZeroTopo { sec_degree: l.span })
        .collect()
}

fn cmd_sharding(args: &Args) -> anyhow::Result<()> {
    let nodes = args.parse_opt("nodes", 2usize)?;
    let cluster = Cluster::new(resolve_machine(args)?, nodes);
    let mut t = Table::new(&["scheme", "weights", "grads", "optim states", "secondary"])
        .title(format!(
            "Table IV — sharding factors ({}, {} nodes, {} workers)",
            cluster.spec.name,
            nodes,
            cluster.world_size()
        ))
        .left_first();
    let mut schemes = vec![Scheme::Zero1, Scheme::Zero2, Scheme::Zero3, Scheme::ZeroPP];
    schemes.extend(topo_schemes(&cluster));
    for scheme in schemes {
        let s = ShardingSpec::resolve(scheme, &cluster)?;
        t.row(vec![
            scheme.name(),
            s.weights.to_string(),
            s.grads.to_string(),
            s.optim.to_string(),
            if s.secondary > 0 { s.secondary.to_string() } else { "-".into() },
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_memory(args: &Args) -> anyhow::Result<()> {
    let model = TransformerSpec::by_name(args.get_or("model", "20b"))
        .ok_or_else(|| anyhow::anyhow!("unknown model (use 10b/20b/125m)"))?;
    let nodes = args.parse_opt("nodes", 2usize)?;
    let cluster = Cluster::new(resolve_machine(args)?, nodes);
    let psi = model.n_params() as f64;
    println!(
        "{} (Ψ = {:.2}B params), {} nodes of {}",
        model.name,
        psi / 1e9,
        nodes,
        cluster.spec.name
    );
    let mut t = Table::new(&["scheme", "weights", "secondary", "grads", "optim", "total"])
        .title("Tables V & VI — per-worker model-state memory".to_string())
        .left_first();
    let mut schemes = vec![Scheme::Zero3, Scheme::ZeroPP];
    schemes.extend(topo_schemes(&cluster).into_iter().rev());
    for scheme in schemes {
        let mm = MemoryModel::new(scheme, ShardingSpec::resolve(scheme, &cluster)?);
        let m = mm.per_device(psi);
        t.row(vec![
            scheme.name(),
            human_bytes(m.weights),
            human_bytes(m.secondary),
            human_bytes(m.grads),
            human_bytes(m.optim),
            human_bytes(m.total()),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_capacity(args: &Args) -> anyhow::Result<()> {
    let nodes = args.parse_opt("nodes", 2usize)?;
    let cluster = Cluster::new(resolve_machine(args)?, nodes);
    let hbm = cluster.hbm_per_worker();
    let mut t = Table::new(&["scheme", "max model (params)", "weights+grads only"])
        .title(format!(
            "Max model size on {nodes} {} nodes ({} workers x {}) — paper Sec II (Frontier): ZeRO-3≈68B, ZeRO++≈55B",
            cluster.spec.name,
            cluster.world_size(),
            human_bytes(hbm)
        ))
        .left_first();
    let mut schemes = vec![Scheme::Zero3, Scheme::ZeroPP];
    schemes.extend(topo_schemes(&cluster).into_iter().rev());
    for scheme in schemes {
        let mm = MemoryModel::new(scheme, ShardingSpec::resolve(scheme, &cluster)?);
        t.row(vec![
            scheme.name(),
            format!("{:.1}B", mm.max_model_size(hbm) / 1e9),
            format!("{:.1}B", mm.max_model_size_weights_grads(hbm) / 1e9),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let model = TransformerSpec::by_name(args.get_or("model", "20b"))
        .ok_or_else(|| anyhow::anyhow!("unknown model (use 10b/20b/125m)"))?;
    let machine = resolve_machine(args)?;
    let node_counts = args.parse_list("nodes", &[8usize, 16, 24, 32, 48])?;
    let schemes = parse_schemes(args)?;
    let mut cfg = SimConfig::default();
    cfg.mfu = args.parse_opt("mfu", cfg.mfu)?;
    cfg.prefetch_depth = args.parse_opt("depth", cfg.prefetch_depth)?;
    let series: Vec<ScalingSeries> = schemes
        .iter()
        .map(|&scheme| ScalingSeries {
            scheme,
            points: scaling_series(&model, scheme, &machine, &node_counts, &cfg),
        })
        .collect();
    let title = format!(
        "Fig 7/8 — TFLOPS per GPU, {} (Ψ={:.1}B) on {}, mfu={} prefetch-depth={}",
        model.name,
        model.n_params() as f64 / 1e9,
        machine.name,
        cfg.mfu,
        cfg.prefetch_depth
    );
    println!("{}", render_scaling_figure(&title, &series));

    // schedule the largest scale once per scheme for the stall breakdown
    // and the optional Chrome-trace export of the stream timelines
    let largest =
        *node_counts.iter().max().ok_or_else(|| anyhow::anyhow!("empty --nodes"))?;
    let want_stalls = args.flag("stalls");
    let trace_path = args.get("trace");
    if want_stalls || trace_path.is_some() {
        let cluster = Cluster::new(machine.clone(), largest);
        let scheds: Vec<(String, Schedule)> = schemes
            .iter()
            .map(|&scheme| {
                let (_, sched) = simulate_step_schedule(&model, scheme, &cluster, &cfg);
                (scheme.name(), sched)
            })
            .collect();
        if want_stalls {
            for (name, sched) in &scheds {
                let title = format!(
                    "{} @ {} {} workers — compute stalls per bandwidth level",
                    name,
                    cluster.world_size(),
                    cluster.spec.name
                );
                println!(
                    "{}",
                    render_stall_table(
                        &title,
                        &sched.stall_by_class(0),
                        &sched.utilization(0),
                        &cluster.spec
                    )
                );
            }
        }
        if let Some(path) = trace_path {
            let named: Vec<(String, &Schedule)> =
                scheds.iter().map(|(n, s)| (n.clone(), s)).collect();
            std::fs::write(path, trace::chrome_trace(&named))?;
            println!("wrote {path} (open in chrome://tracing or ui.perfetto.dev)");
        }
    }
    if let Some(path) = args.get("csv") {
        std::fs::write(path, zero_topo::report::scaling_csv(&series))?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let mut cfg = RunConfig::default();
    cfg.model = args.get_or("model", "tiny").to_string();
    cfg.scheme = Scheme::parse(args.get_or("scheme", "zerotopo"))
        .ok_or_else(|| anyhow::anyhow!("bad --scheme"))?;
    cfg.machine = args.get_or("machine", "frontier").to_string();
    cfg.nodes = args.parse_opt("nodes", 1usize)?;
    cfg.steps = args.parse_opt("steps", 10usize)?;
    cfg.grad_accum = args.parse_opt("grad-accum", 1usize)?;
    cfg.seed = args.parse_opt("seed", 42u64)?;
    cfg.lr = args.parse_opt("lr", 1e-3f32)?;
    cfg.mfu = args.parse_opt("mfu", cfg.mfu)?;
    cfg.prefetch_depth = args.parse_opt("depth", cfg.prefetch_depth)?;
    let dir = args.get_or("artifacts", "artifacts");
    // fail fast on a bad --machine before the (expensive) artifact load
    let machine = MachineSpec::resolve(&cfg.machine)?;

    eprintln!("loading artifacts from {dir} ...");
    let rt = Runtime::load(dir)?;
    let runner = rt.model(&cfg.model)?;
    eprintln!(
        "model {}: {} params, seq {}, mbs {}; scheme {}, {} {} nodes ({} workers)",
        cfg.model,
        runner.manifest.n_params,
        runner.manifest.seq,
        runner.manifest.mbs,
        cfg.scheme.name(),
        cfg.nodes,
        machine.name,
        cfg.nodes * machine.workers_per_node
    );
    let steps = cfg.steps;
    let csv = args.get("csv").map(|s| s.to_string());
    let mut engine = TrainEngine::new(cfg, &runner)?;
    let t0 = std::time::Instant::now();
    for s in 0..steps {
        let loss = engine.step()?;
        println!(
            "step {:>4}  loss {:.4}  step(sim) {:.3}s  comm(sim) {:.3}s  wall {:.1}s",
            s + 1,
            loss,
            engine.sim_seconds(),
            engine.comm_seconds(),
            t0.elapsed().as_secs_f64()
        );
    }
    if let Some(path) = csv {
        std::fs::write(&path, engine.log.to_csv())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_report(args: &Args) -> anyhow::Result<()> {
    cmd_topo(args)?;
    cmd_sharding(args)?;
    cmd_memory(args)?;
    cmd_capacity(args)?;
    cmd_simulate(args)?;
    Ok(())
}
