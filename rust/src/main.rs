//! `zero-topo` — CLI for the ZeRO-topo reproduction.
//!
//! Subcommands:
//!   topo      --machine frontier|dgx|...         print node topology (Fig 2/3, Tables I/II)
//!   sharding  --nodes N                          print Table IV sharding factors
//!   memory    --model 20b --nodes N              print Tables V/VI memory breakdown
//!   capacity  --nodes N                          max-model-size claims (Section II / VII.B)
//!   simulate  --model 20b|10b --nodes 8,16,...   Fig 7/8 scaling figures (analytical sim)
//!   scale                                        alias of simulate (scaling sweeps)
//!   plan      --model 20b --nodes 48             feasibility-aware schedule auto-planner
//!   train     --model tiny|mini|... --scheme S   real-numerics training via PJRT artifacts
//!   report                                       everything above, in order
//!
//! Every subcommand takes `--machine <name|spec.json>`: a builtin machine
//! (frontier, dgx, aurora, elcapitan, tpu-pod) or a path to a topology
//! spec JSON — machines are data, not code (`topology::spec`).

use zero_topo::config::RunConfig;
use zero_topo::engine::TrainEngine;
use zero_topo::memory::MemoryModel;
use zero_topo::metrics::registry::Registry;
use zero_topo::metrics::telemetry::{register_step, StepKind, StepRecord, TelemetryWriter};
use zero_topo::metrics::Throughput;
use zero_topo::model::TransformerSpec;
use zero_topo::metrics::sensitivity::DEFAULT_EPSILON;
use zero_topo::report::{
    capacity_frontier_markdown, category_label, goodput_markdown, render_capacity_frontier,
    render_critical_path, render_decomposition_table, render_goodput_sweep,
    render_goodput_table, render_pipeline_table, render_plan_table, render_rank_table,
    render_scaling_figure, render_shadow_price_table, render_stall_table,
    render_utilization_table, GoodputRow, ScalingSeries,
};
use zero_topo::runtime::Runtime;
use zero_topo::sched::critical::{decompose, Decomposition};
use zero_topo::sched::pipeline::PipeConfig;
use zero_topo::sched::scenario::{RankCount, Scenario};
use zero_topo::sched::{trace, Schedule};
use zero_topo::sharding::{Scheme, ShardingSpec};
use zero_topo::sim::goodput::{
    checkpoint_cost, goodput, optimal_interval, price_timeline, sweep,
};
use zero_topo::sim::par::parallel_map;
use zero_topo::sim::plan::{plan_search_threaded, PlanSpace};
use zero_topo::sim::{
    profile_step, profile_step_pipeline, scaling_series_pipeline_threaded,
    scaling_series_scenario_threaded, scaling_series_threaded, shadow_prices, simulate_step,
    simulate_step_pipeline,
    simulate_step_pipeline_scenario, simulate_step_scenario, simulate_step_schedule,
    simulate_step_telemetry, SimConfig, SimProfile,
};
use zero_topo::topology::{Cluster, LinkClass, MachineSpec};
use zero_topo::util::cli::Args;
use zero_topo::util::json::Json;
use zero_topo::util::table::{fnum, human_bytes, Table};

const USAGE: &str = "\
zero-topo — ZeRO-topo (3-level low-bandwidth partitioning) reproduction

USAGE: zero-topo <subcommand> [options]

Every subcommand accepts --machine <M> where <M> is a builtin machine
(frontier, dgx, aurora, elcapitan, tpu-pod) or a path to a topology spec
JSON (see examples/machines/). Default: frontier.

  topo      [--machine M]                   node topology (paper Fig 2/3)
  sharding  [--machine M] [--nodes N]       Table IV sharding factors
  memory    [--model 20b] [--nodes N]       Tables V/VI memory per device
                                            (static model states only — `plan`
                                            adds the schedule-aware gather
                                            window + activation terms)
  capacity  [--machine M] [--nodes N]       max model size per scheme (Sec II;
                                            states-only bound — `plan` prints
                                            the schedule-aware frontier)
  plan      [--machine M] [--model 20b] [--nodes 48] [--schemes S,...]
            [--depths 1,2,inf] [--blocks 1,44] [--pp 1,2,4,8]
            [--microbatches 0,8,16,32] [--interleave 1,2] [--mfu F]
            [--top K] [--threads T] [--json] [--emit-config FILE] [--md FILE]
            [--objective tflops|goodput] [--mtbf 21600]
                                            feasibility-aware auto-planner
                                            (DESIGN.md Sec 15): sweep scheme x
                                            depth x blocks x P x M x V, prune
                                            anything whose schedule-aware
                                            memory ledger (states + gather
                                            window + in-flight activations)
                                            exceeds HBM *before* pricing, rank
                                            survivors by TFLOPS/GCD;
                                            --emit-config writes the winner as
                                            a RunConfig JSON that
                                            `train --config` runs verbatim;
                                            --md appends the capacity frontier
                                            as markdown; --objective goodput
                                            re-ranks survivors by net tokens/s
                                            under failure (DESIGN.md §17)
  simulate  [--machine M] [--model 20b] [--nodes 8,16,32,48]
            [--schemes zero3,zeropp,zerotopo] [--depth N|inf] [--ranks N|auto]
            [--layer-granular] [--blocks B] [--pp P] [--microbatches M]
            [--interleave V] [--telemetry out.jsonl] [--prom out.prom]
            [--stalls] [--threads T]
            [--trace out.json]              Fig 7/8 scaling (event-driven sim;
                                            --threads T prices scales on T
                                            workers, byte-identical output)
  scale     alias of simulate               cross-scale / cross-machine sweeps
  pipeline  [--machine M] [--model 20b] [--nodes 48] [--schemes S,...]
            [--pp 4] [--microbatches 8] [--interleave 2] [--depth N|inf]
            [--layer-granular] [--straggler R:MULT,...] [--jitter SIGMA]
            [--seed S] [--trace out.json]
            [--telemetry out.jsonl] [--prom out.prom]
                                            1F1B vs interleaved: step time +
                                            bubble fraction per scheme
  scenario  [--machine M] [--model 20b] [--nodes 48] [--schemes S,...]
            [--ranks N|auto] [--straggler R:MULT,...] [--jitter SIGMA]
            [--seed S] [--imbalance R:GA,...] [--depth N|inf]
            [--layer-granular] [--blocks B] [--rank-rows K] [--threads T]
            [--faults STEP:fail|STEP:preempt:GRACE|STEP:resize:NODES,...]
            [--steps 20] [--ckpt-every 5] [--mtbf 21600]
            [--trace out.json]              multi-rank stragglers/jitter study;
                                            --faults walks a priced multi-step
                                            timeline under deterministic node
                                            failures / preemptions / elastic
                                            resizes with checkpoint save +
                                            lost-work + restore accounting
                                            (DESIGN.md §17)
  calibrate [--check] [--write] [--baseline FILE] [--tolerance 0.01]
            [--md FILE]                     perf guardrail vs BENCH_baseline.json
                                            (incl. pinned P=4 pipeline points);
                                            --md appends the drift table as
                                            markdown (CI: $GITHUB_STEP_SUMMARY);
                                            also self-profiles the simulator —
                                            tasks/sec is a gated column under
                                            --check (>3x slowdown vs the
                                            baseline's tasks_per_s fails);
                                            also pins goodput (tok/s) for the
                                            frontier DP points at the default
                                            MTBF when the baseline records it
  goodput   [--machines frontier,dgx | --machine M] [--model 20b] [--nodes 48]
            [--schemes S,...] [--mtbf 21600] [--interval S] [--sweep]
            [--json] [--md FILE]            goodput under failure (DESIGN.md
                                            §17): price checkpoint save/load
                                            against each machine's storage
                                            path, derive the Young/Daly
                                            optimal interval tau*, and report
                                            expected tokens/s net of saves,
                                            lost work, and restarts; --sweep
                                            grids tau* x {1/8..8}; --interval
                                            overrides tau*
  train     [--config FILE] [--machine M] [--model tiny] [--scheme zerotopo]
            [--nodes 1] [--steps 10] [--depth N|inf] [--layer-granular]
            [--blocks B] [--ranks N|auto] [--jitter SIGMA]
            [--straggler R:MULT,...] [--pp P] [--microbatches M]
            [--interleave V] [--artifacts DIR] [--csv FILE]
            [--telemetry out.jsonl] [--prom out.prom]
                                            real training via PJRT; --config
                                            seeds every knob from a RunConfig
                                            JSON (e.g. plan --emit-config
                                            output), explicit flags override
  explain   [--machine M] [--model 20b] [--nodes 48] [--schemes S,...]
            [--pp P] [--microbatches M] [--interleave V] [--depth N|inf]
            [--layer-granular] [--blocks B] [--eps 0.05] [--json]
                                            bottleneck attribution (DESIGN.md
                                            §14): conserved critical-path
                                            decomposition + ranked link
                                            shadow prices per scheme
  explain   --baseline FILE [--tolerance t] [--json]
                                            re-price every pinned BENCH entry;
                                            gate ledger conservation (1e-12)
                                            and step-time drift vs the pin
  explain   --diff A B [--tolerance t] [--json]
                                            attribute the step-time delta
                                            between two telemetry JSONL
                                            streams or two BENCH_*.json
                                            snapshots to ledger categories
                                            (gates drift when --tolerance
                                            is given)
  report    [--machine M]                   print all analytical tables

--depth bounds the prefetch stream: how many gather units may run ahead of
the compute that consumes them (0 = fetch on demand, inf = free-running).
The unit is one whole per-microbatch gather by default; with
--layer-granular (or --blocks B > 1) gathers split per layer block and
--depth counts *layer blocks* ahead — DeepSpeed's parameter-prefetch
window in layers (sched::Depth rustdoc, DESIGN.md §12). --layer-granular
defaults to one block per transformer layer; --blocks overrides the
count. In pipeline runs the blocks are each stage's virtual chunks.

--telemetry streams one self-describing JSON object per priced step
(simulate/pipeline: one per scheme x scale point; train: one per
optimizer step) — schema in DESIGN.md §13. --prom writes a Prometheus
text-format snapshot of the same run's metrics registry. All quantities
are simulated seconds/bytes; only calibrate's tasks/sec is wall time.
";

/// Default cluster-level MTBF for goodput pricing: 6 hours — the right
/// order of magnitude for a ~50-node Frontier-class allocation (per-node
/// MTBF of ~10^6 s divided across the job), and the value the pinned
/// `goodput_tokens_per_s` baseline entries are computed at.
const DEFAULT_MTBF_S: f64 = 21_600.0;

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(
        raw,
        &["verbose", "json", "help", "stalls", "check", "write", "layer-granular", "diff", "sweep"],
    ) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    if args.flag("help") || args.subcommand.is_none() {
        println!("{USAGE}");
        return;
    }
    let sub = args.subcommand.clone().unwrap();
    let result = match sub.as_str() {
        "topo" => cmd_topo(&args),
        "sharding" => cmd_sharding(&args),
        "memory" => cmd_memory(&args),
        "capacity" => cmd_capacity(&args),
        "plan" => cmd_plan(&args),
        "simulate" | "scale" => cmd_simulate(&args),
        "pipeline" => cmd_pipeline(&args),
        "scenario" => cmd_scenario(&args),
        "calibrate" => cmd_calibrate(&args),
        "goodput" => cmd_goodput(&args),
        "explain" => cmd_explain(&args),
        "train" => cmd_train(&args),
        "report" => cmd_report(&args),
        other => {
            eprintln!("unknown subcommand '{other}'\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn parse_schemes(args: &Args) -> anyhow::Result<Vec<Scheme>> {
    let raw = args.get_or("schemes", "zero3,zeropp,zerotopo");
    raw.split(',')
        .map(|s| Scheme::parse(s.trim()).ok_or_else(|| anyhow::anyhow!("unknown scheme '{s}'")))
        .collect()
}

/// Resolve `--machine` (builtin name or spec-JSON path); `--node` is kept
/// as a legacy alias for `topo`.
fn resolve_machine(args: &Args) -> anyhow::Result<MachineSpec> {
    let raw = args.get("machine").or_else(|| args.get("node")).unwrap_or("frontier");
    Ok(MachineSpec::resolve(raw)?)
}

/// Parse `--pp` (pipeline stages), rejecting 0 like the JSON config path
/// does — a typo'd `--pp 0` must not silently run the non-pipeline path.
fn parse_pp(args: &Args) -> anyhow::Result<usize> {
    parse_pp_default(args, 1)
}

fn parse_pp_default(args: &Args, default: usize) -> anyhow::Result<usize> {
    let pp = args.parse_opt("pp", default)?;
    anyhow::ensure!(pp >= 1, "--pp must be >= 1 (1 = no pipeline axis)");
    Ok(pp)
}

/// Resolve the layer-granular prefetch block count: `--blocks B` wins,
/// bare `--layer-granular` defaults to one block per transformer layer,
/// neither keeps the monolithic plan (`1`, bit-for-bit today's schedule).
fn parse_layer_blocks(args: &Args, per_layer_default: usize) -> anyhow::Result<usize> {
    let blocks = match args.get("blocks") {
        Some(_) => args.parse_opt("blocks", 1usize)?,
        None if args.flag("layer-granular") => per_layer_default,
        None => 1,
    };
    anyhow::ensure!(blocks >= 1, "--blocks must be >= 1 (1 = monolithic gathers)");
    Ok(blocks)
}

/// Pipeline runs take their block count from the chunk axis (a stage's
/// blocks are exactly its `--interleave` chunk slice), so an explicit
/// `--blocks` would be silently ignored — reject it instead.
fn ensure_no_blocks_under_pipeline(args: &Args, stages: usize) -> anyhow::Result<()> {
    anyhow::ensure!(
        stages <= 1 || args.get("blocks").is_none(),
        "--blocks does not apply with --pp > 1: a stage's layer blocks are its \
         --interleave chunk slice; use --layer-granular (and --interleave V) instead"
    );
    Ok(())
}

fn cmd_topo(args: &Args) -> anyhow::Result<()> {
    let spec = resolve_machine(args)?;
    println!("machine: {}", spec.name);
    println!(
        "workers/node: {}   peak fp16 FLOP/s per worker: {:.1} TF   HBM/worker: {}",
        spec.workers_per_node,
        spec.peak_flops_per_worker / 1e12,
        human_bytes(spec.hbm_per_worker)
    );
    // link-class table straight from the spec's levels — nothing hardcoded
    let mut t = Table::new(&["link class", "span", "bandwidth (GB/s)", "latency (us)"])
        .left_first();
    for class in spec.classes() {
        let s = spec.link_spec(class);
        let span = match class {
            LinkClass::Intra(k) => spec.levels[k as usize].span.to_string(),
            _ => "-".into(),
        };
        t.row(vec![
            spec.class_label(class),
            span,
            fnum(s.bandwidth / 1e9, 0),
            fnum(s.latency * 1e6, 1),
        ]);
    }
    println!("{}", t.render());
    // rank-pair link matrix for one node (digit = intra hierarchy level)
    let cluster = Cluster::new(spec.clone(), 1);
    let w = cluster.workers_per_node();
    println!("intra-node link classes (rank x rank, digit = hierarchy level):");
    for a in 0..w {
        let row: Vec<String> = (0..w)
            .map(|b| match cluster.link_between(a, b) {
                LinkClass::Local => ".".into(),
                LinkClass::Intra(k) => k.to_string(),
                LinkClass::InterNode => "I".into(),
            })
            .collect();
        println!("  {}", row.join(" "));
    }
    for (k, level) in spec.levels.iter().enumerate() {
        println!("  {k}={} ({} GB/s)", level.name, fnum(level.link.bandwidth / 1e9, 0));
    }
    Ok(())
}

/// One ZeRO-topo row per intra-node level span — on Frontier that is
/// sec = 2, 4, 8; on a flat-fabric machine a single row.
fn topo_schemes(cluster: &Cluster) -> Vec<Scheme> {
    cluster
        .spec
        .levels
        .iter()
        .map(|l| Scheme::ZeroTopo { sec_degree: l.span })
        .collect()
}

fn cmd_sharding(args: &Args) -> anyhow::Result<()> {
    let nodes = args.parse_opt("nodes", 2usize)?;
    let cluster = Cluster::new(resolve_machine(args)?, nodes);
    let mut t = Table::new(&["scheme", "weights", "grads", "optim states", "secondary"])
        .title(format!(
            "Table IV — sharding factors ({}, {} nodes, {} workers)",
            cluster.spec.name,
            nodes,
            cluster.world_size()
        ))
        .left_first();
    let mut schemes = vec![Scheme::Zero1, Scheme::Zero2, Scheme::Zero3, Scheme::ZeroPP];
    schemes.extend(topo_schemes(&cluster));
    for scheme in schemes {
        let s = ShardingSpec::resolve(scheme, &cluster)?;
        t.row(vec![
            scheme.name(),
            s.weights.to_string(),
            s.grads.to_string(),
            s.optim.to_string(),
            if s.secondary > 0 { s.secondary.to_string() } else { "-".into() },
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_memory(args: &Args) -> anyhow::Result<()> {
    let model = TransformerSpec::by_name(args.get_or("model", "20b"))
        .ok_or_else(|| anyhow::anyhow!("unknown model (use 10b/20b/125m)"))?;
    let nodes = args.parse_opt("nodes", 2usize)?;
    let cluster = Cluster::new(resolve_machine(args)?, nodes);
    let psi = model.n_params() as f64;
    println!(
        "{} (Ψ = {:.2}B params), {} nodes of {}",
        model.name,
        psi / 1e9,
        nodes,
        cluster.spec.name
    );
    let mut t = Table::new(&["scheme", "weights", "secondary", "grads", "optim", "total"])
        .title("Tables V & VI — per-worker model-state memory".to_string())
        .left_first();
    let mut schemes = vec![Scheme::Zero3, Scheme::ZeroPP];
    schemes.extend(topo_schemes(&cluster).into_iter().rev());
    for scheme in schemes {
        let mm = MemoryModel::new(scheme, ShardingSpec::resolve(scheme, &cluster)?);
        let m = mm.per_device(psi);
        t.row(vec![
            scheme.name(),
            human_bytes(m.weights),
            human_bytes(m.secondary),
            human_bytes(m.grads),
            human_bytes(m.optim),
            human_bytes(m.total()),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_capacity(args: &Args) -> anyhow::Result<()> {
    let nodes = args.parse_opt("nodes", 2usize)?;
    let cluster = Cluster::new(resolve_machine(args)?, nodes);
    let hbm = cluster.hbm_per_worker();
    let mut t = Table::new(&["scheme", "max model (params)", "weights+grads only"])
        .title(format!(
            "Max model size on {nodes} {} nodes ({} workers x {}) — paper Sec II (Frontier): ZeRO-3≈68B, ZeRO++≈55B",
            cluster.spec.name,
            cluster.world_size(),
            human_bytes(hbm)
        ))
        .left_first();
    let mut schemes = vec![Scheme::Zero3, Scheme::ZeroPP];
    schemes.extend(topo_schemes(&cluster).into_iter().rev());
    for scheme in schemes {
        let mm = MemoryModel::new(scheme, ShardingSpec::resolve(scheme, &cluster)?);
        t.row(vec![
            scheme.name(),
            format!("{:.1}B", mm.max_model_size(hbm) / 1e9),
            format!("{:.1}B", mm.max_model_size_weights_grads(hbm) / 1e9),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

/// `plan` — the feasibility-aware auto-planner (DESIGN.md §15): sweep
/// the joint schedule space under the user's bounds, prune every point
/// whose schedule-aware memory ledger exceeds HBM before pricing, rank
/// the survivors by token-normalized throughput.
fn cmd_plan(args: &Args) -> anyhow::Result<()> {
    let model = TransformerSpec::by_name(args.get_or("model", "20b"))
        .ok_or_else(|| anyhow::anyhow!("unknown model (use 10b/20b/125m)"))?;
    // keep the raw --machine string: the emitted RunConfig must resolve
    // it again on load (builtin name or spec-JSON path, both round-trip)
    let machine_raw = args.get_or("machine", "frontier").to_string();
    let nodes = args.parse_opt("nodes", 48usize)?;
    let cluster = Cluster::new(MachineSpec::resolve(&machine_raw)?, nodes);
    // expand the auto secondary (sec=0) into one candidate per intra-node
    // level span, exactly like the analytical tables do
    let mut schemes: Vec<Scheme> = Vec::new();
    for s in parse_schemes(args)? {
        match s {
            Scheme::ZeroTopo { sec_degree: 0 } => schemes.extend(topo_schemes(&cluster)),
            other => schemes.push(other),
        }
    }
    let mut cfg = SimConfig::default();
    cfg.mfu = args.parse_opt("mfu", cfg.mfu)?;
    let mut space = PlanSpace::default_for(schemes, &model);
    space.depths = args.parse_list("depths", &space.depths)?;
    space.blocks = args.parse_list("blocks", &space.blocks)?;
    space.stages = args.parse_list("pp", &space.stages)?;
    space.microbatches = args.parse_list("microbatches", &space.microbatches)?;
    space.interleaves = args.parse_list("interleave", &space.interleaves)?;
    let top = args.parse_opt("top", 8usize)?;
    let threads = args.parse_opt("threads", 1usize)?;

    let mut out = plan_search_threaded(&model, &cluster, &cfg, &space, threads);

    // --objective goodput: re-rank the feasible points by expected net
    // tokens/s under failure at --mtbf (DESIGN.md §17) instead of raw
    // TFLOPS/GCD. Checkpoint restore cost is scheme-dependent (secondary
    // partitions rematerialize over a full-world quantized all-gather),
    // so the ranking can genuinely flip between schemes.
    let objective = args.get_or("objective", "tflops").to_string();
    match objective.as_str() {
        "tflops" => {}
        "goodput" => {
            let mtbf = args.parse_opt("mtbf", DEFAULT_MTBF_S)?;
            let mut keyed: Vec<(f64, zero_topo::sim::plan::PlanPoint)> =
                Vec::with_capacity(out.ranked.len());
            for p in out.ranked.drain(..) {
                // degenerate goodput inputs rank last instead of aborting
                // the whole plan — a point that cannot even checkpoint is
                // still feasible, just undesirable
                let g = checkpoint_cost(&model, p.scheme, &cluster, &cfg)
                    .and_then(|ck| {
                        let tau = optimal_interval(mtbf, &ck)?;
                        goodput(p.step_s, p.tokens_per_step, &ck, mtbf, tau)
                    })
                    .map(|r| r.goodput_tokens_per_s)
                    .unwrap_or(f64::NEG_INFINITY);
                keyed.push((g, p));
            }
            keyed.sort_by(|a, b| b.0.partial_cmp(&a.0).expect("goodput keys are never NaN"));
            out.ranked = keyed.into_iter().map(|(_, p)| p).collect();
            println!(
                "objective: goodput (MTBF {mtbf:.0}s, interval tau*) — ranking by net tokens/s"
            );
        }
        other => anyhow::bail!("unknown --objective '{other}' (use tflops|goodput)"),
    }

    let world = cluster.world_size();
    let title = format!(
        "Auto-planner — {} on {} x {} nodes ({} workers, {} HBM each)",
        model.name,
        cluster.spec.name,
        nodes,
        world,
        human_bytes(cluster.hbm_per_worker())
    );

    if args.flag("json") {
        let point_json = |p: &zero_topo::sim::plan::PlanPoint| {
            Json::obj(vec![
                ("scheme", Json::str(p.scheme.name())),
                ("depth", Json::str(p.depth.to_string())),
                ("blocks", Json::from(p.blocks)),
                ("stages", Json::from(p.stages)),
                ("microbatches", Json::from(p.microbatches)),
                ("interleave", Json::from(p.interleave)),
                ("step_s", Json::num(p.step_s)),
                ("tokens_per_step", Json::num(p.tokens_per_step)),
                ("tflops_per_gcd", Json::num(p.tflops_per_gcd)),
                ("mem_bytes", Json::num(p.fit.total())),
                ("headroom_bytes", Json::num(p.fit.headroom())),
            ])
        };
        let json = Json::obj(vec![
            ("model", Json::str(model.name.clone())),
            ("machine", Json::str(machine_raw.clone())),
            ("nodes", Json::from(nodes)),
            ("world", Json::from(world)),
            ("feasible", Json::from(out.ranked.len())),
            ("pruned", Json::from(out.pruned.len())),
            ("skipped", Json::from(out.skipped)),
            ("winner", out.winner().map(point_json).unwrap_or(Json::Null)),
            ("ranked", Json::arr(out.ranked.iter().take(top.max(1)).map(point_json))),
            (
                "frontier",
                Json::arr(out.frontier.iter().map(|(s, cap)| {
                    Json::obj(vec![
                        ("scheme", Json::str(s.name())),
                        ("max_model_params", Json::num(*cap)),
                    ])
                })),
            ),
            (
                "smallest_overage_bytes",
                out.smallest_overage()
                    .map(|p| Json::num(p.fit.overage()))
                    .unwrap_or(Json::Null),
            ),
        ]);
        println!("{json}");
    } else {
        println!("{}", render_plan_table(&title, &out, top));
        println!(
            "{}",
            render_capacity_frontier(
                &format!(
                    "Capacity frontier — {} x {} nodes (schedule-aware)",
                    cluster.spec.name, nodes
                ),
                &out
            )
        );
        if let Some(w) = out.winner() {
            println!(
                "winner: {} P={} M={} V={} depth={} blocks={} -> {:.3}s/step, \
                 {:.2} TFLOPS/GCD, {:.2} GiB high-water ({:.2} GiB headroom)",
                w.scheme.name(),
                w.stages,
                w.microbatches,
                w.interleave,
                w.depth,
                w.blocks,
                w.step_s,
                w.tflops_per_gcd,
                w.fit.total() / (1u64 << 30) as f64,
                w.fit.headroom() / (1u64 << 30) as f64,
            );
        }
    }

    if let Some(path) = args.get("emit-config") {
        let w = out.winner().ok_or_else(|| {
            anyhow::anyhow!("nothing fits the HBM budget — no config to emit (see the ledger above)")
        })?;
        let rc = RunConfig {
            model: model.name.clone(),
            scheme: w.scheme,
            machine: machine_raw.clone(),
            nodes,
            micro_batch: cfg.micro_batch,
            // data-parallel winners carry their microbatch count as
            // grad-accum; pipeline winners as M (the same split train uses)
            grad_accum: if w.stages == 1 { w.microbatches } else { 1 },
            quant_block: cfg.quant_block,
            mfu: cfg.mfu,
            prefetch_depth: w.depth,
            layer_blocks: w.blocks,
            pipeline_stages: w.stages,
            microbatches: if w.stages > 1 { w.microbatches } else { 0 },
            interleave: w.interleave,
            ..RunConfig::default()
        };
        rc.save(std::path::Path::new(path))?;
        println!("emitted winner config to {path} (run it: zero-topo train --config {path})");
    }

    if let Some(md_path) = args.get("md") {
        use std::io::Write;
        // append, never truncate: $GITHUB_STEP_SUMMARY is shared by steps
        let md = capacity_frontier_markdown(
            &format!(
                "Capacity frontier — {} on {} x {} nodes (schedule-aware)",
                model.name, cluster.spec.name, nodes
            ),
            &out,
        );
        std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(md_path)?
            .write_all(md.as_bytes())?;
        println!("appended capacity frontier markdown to {md_path}");
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let model = TransformerSpec::by_name(args.get_or("model", "20b"))
        .ok_or_else(|| anyhow::anyhow!("unknown model (use 10b/20b/125m)"))?;
    let machine = resolve_machine(args)?;
    let node_counts = args.parse_list("nodes", &[8usize, 16, 24, 32, 48])?;
    let schemes = parse_schemes(args)?;
    let mut cfg = SimConfig::default();
    cfg.mfu = args.parse_opt("mfu", cfg.mfu)?;
    cfg.prefetch_depth = args.parse_opt("depth", cfg.prefetch_depth)?;
    cfg.layer_blocks = parse_layer_blocks(args, model.n_layers)?;
    // --ranks routes the step clock through the multi-rank builder; with a
    // trivial scenario the congruence collapse makes it bit-identical to
    // the single-rank path, so the figures cannot drift
    let ranks: Option<RankCount> = match args.get("ranks") {
        None => None,
        Some(r) => Some(r.parse().map_err(|e: String| anyhow::anyhow!(e))?),
    };
    let scenario = ranks.map(|r| Scenario { ranks: r, ..Default::default() });
    // --pp routes every point through the pipeline builder instead (P=1
    // would be bit-identical to the plain path; >1 adds the bubble)
    let pipe = PipeConfig {
        stages: parse_pp(args)?,
        microbatches: args.parse_opt("microbatches", 0usize)?,
        interleave: args.parse_opt("interleave", 1usize)?,
    };
    if pipe.stages > 1 && scenario.is_some() {
        anyhow::bail!("--pp composes with --straggler/--jitter via `pipeline`, not --ranks");
    }
    ensure_no_blocks_under_pipeline(args, pipe.stages)?;
    let threads = args.parse_opt("threads", 1usize)?;
    let series: Vec<ScalingSeries> = schemes
        .iter()
        .map(|&scheme| -> anyhow::Result<ScalingSeries> {
            let points = if pipe.stages > 1 {
                scaling_series_pipeline_threaded(
                    &model,
                    scheme,
                    &machine,
                    &node_counts,
                    &cfg,
                    &pipe,
                    threads,
                )?
            } else {
                match &scenario {
                    None => scaling_series_threaded(
                        &model,
                        scheme,
                        &machine,
                        &node_counts,
                        &cfg,
                        threads,
                    ),
                    Some(sc) => scaling_series_scenario_threaded(
                        &model,
                        scheme,
                        &machine,
                        &node_counts,
                        &cfg,
                        sc,
                        threads,
                    ),
                }
            };
            Ok(ScalingSeries { scheme, points })
        })
        .collect::<anyhow::Result<_>>()?;
    let mut pp_note = if pipe.stages > 1 {
        format!(" pp={} interleave={}", pipe.stages, pipe.effective_interleave())
    } else {
        String::new()
    };
    if cfg.layer_blocks > 1 {
        pp_note.push_str(&format!(" layer-blocks={}", cfg.layer_blocks));
    }
    let title = format!(
        "Fig 7/8 — TFLOPS per GPU, {} (Ψ={:.1}B) on {}, mfu={} prefetch-depth={}{}",
        model.name,
        model.n_params() as f64 / 1e9,
        machine.name,
        cfg.mfu,
        cfg.prefetch_depth,
        pp_note
    );
    println!("{}", render_scaling_figure(&title, &series));

    // --telemetry / --prom: one self-describing JSONL record per
    // (scheme, scale) point plus an optional Prometheus snapshot
    // (DESIGN.md §13). Points are re-priced through the exact entry
    // points the figure used, so the streamed numbers cannot diverge.
    let telemetry_path = args.get("telemetry");
    let prom_path = args.get("prom");
    if telemetry_path.is_some() || prom_path.is_some() {
        let mut writer = telemetry_path.map(TelemetryWriter::create).transpose()?;
        let mut reg = Registry::new();
        let mut step = 0usize;
        let psi = model.n_params() as f64;
        for s in &series {
            for (&n, point) in node_counts.iter().zip(&s.points) {
                let cluster = Cluster::new(machine.clone(), n);
                let mem = MemoryModel::new(s.scheme, ShardingSpec::resolve(s.scheme, &cluster)?)
                    .per_device(psi);
                let mut rec = StepRecord::new(
                    step,
                    StepKind::Simulate,
                    &s.scheme.name(),
                    &machine.name,
                    n,
                    point,
                )
                .with_memory(mem);
                if pipe.stages > 1 {
                    let (b, sched, _) =
                        simulate_step_pipeline(&model, s.scheme, &cluster, &cfg, &pipe)?;
                    rec = rec.with_schedule(&sched, &machine).with_bubble(b.bubble_fraction);
                } else {
                    let (_, sched, cost) = simulate_step_telemetry(
                        &model,
                        s.scheme,
                        &cluster,
                        &cfg,
                        scenario.as_ref(),
                    );
                    rec = rec.with_comm(&cost).with_schedule(&sched, &machine);
                }
                register_step(&mut reg, &rec);
                if let Some(w) = writer.as_mut() {
                    w.write_record(&rec)?;
                }
                step += 1;
            }
        }
        if let (Some(w), Some(path)) = (writer.as_mut(), telemetry_path) {
            w.flush()?;
            println!("wrote {} telemetry records to {path}", w.written());
        }
        if let Some(path) = prom_path {
            std::fs::write(path, reg.to_prometheus())?;
            println!("wrote Prometheus snapshot to {path}");
        }
    }

    // schedule the largest scale once per scheme for the stall breakdown
    // and the optional Chrome-trace export of the stream timelines
    let largest =
        *node_counts.iter().max().ok_or_else(|| anyhow::anyhow!("empty --nodes"))?;
    let want_stalls = args.flag("stalls");
    let trace_path = args.get("trace");
    if want_stalls || trace_path.is_some() {
        let cluster = Cluster::new(machine.clone(), largest);
        let scheds: Vec<(String, Schedule)> = schemes
            .iter()
            .map(|&scheme| -> anyhow::Result<(String, Schedule)> {
                let sched = if pipe.stages > 1 {
                    simulate_step_pipeline(&model, scheme, &cluster, &cfg, &pipe)?.1
                } else {
                    match &scenario {
                        None => simulate_step_schedule(&model, scheme, &cluster, &cfg).1,
                        Some(sc) => simulate_step_scenario(&model, scheme, &cluster, &cfg, sc).1,
                    }
                };
                Ok((scheme.name(), sched))
            })
            .collect::<anyhow::Result<_>>()?;
        if want_stalls {
            for (name, sched) in &scheds {
                let title = format!(
                    "{} @ {} {} workers — compute stalls per bandwidth level",
                    name,
                    cluster.world_size(),
                    cluster.spec.name
                );
                println!(
                    "{}",
                    render_stall_table(
                        &title,
                        &sched.stall_by_class(0),
                        &sched.utilization(0),
                        &cluster.spec
                    )
                );
                println!(
                    "{}",
                    render_utilization_table(
                        &format!("{name} — link utilization"),
                        sched,
                        &cluster.spec,
                        0
                    )
                );
            }
        }
        if let Some(path) = trace_path {
            let named: Vec<(String, &Schedule)> =
                scheds.iter().map(|(n, s)| (n.clone(), s)).collect();
            std::fs::write(path, trace::chrome_trace_labeled(&named, Some(&machine)))?;
            println!("wrote {path} (open in chrome://tracing or ui.perfetto.dev)");
        }
    }
    if let Some(path) = args.get("csv") {
        std::fs::write(path, zero_topo::report::scaling_csv(&series))?;
        println!("wrote {path}");
    }
    Ok(())
}

/// Hybrid pipeline-parallel × ZeRO study at one scale: per scheme, the
/// pure-DP baseline vs the 1F1B and interleaved schedules — step time,
/// simulated bubble fraction, and the closed-form bound — plus per-stage
/// accounting and optional straggler/jitter injection onto stages.
fn cmd_pipeline(args: &Args) -> anyhow::Result<()> {
    let model = TransformerSpec::by_name(args.get_or("model", "20b"))
        .ok_or_else(|| anyhow::anyhow!("unknown model (use 10b/20b/125m)"))?;
    let machine = resolve_machine(args)?;
    let nodes = args.parse_opt("nodes", 48usize)?;
    let schemes = parse_schemes(args)?;
    let mut cfg = SimConfig::default();
    cfg.mfu = args.parse_opt("mfu", cfg.mfu)?;
    cfg.prefetch_depth = args.parse_opt("depth", cfg.prefetch_depth)?;
    // pipeline blocks are each stage's chunk slice, so the flag alone
    // turns the layered path on (the count comes from --interleave)
    let pp = parse_pp_default(args, 4)?;
    ensure_no_blocks_under_pipeline(args, pp)?;
    cfg.layer_blocks = parse_layer_blocks(args, model.n_layers)?;
    let microbatches = args.parse_opt("microbatches", 8usize)?;
    let interleave = args.parse_opt("interleave", 2usize)?;
    let scenario = Scenario {
        stragglers: Scenario::parse_stragglers(args.get_or("straggler", ""))
            .map_err(|e| anyhow::anyhow!(e))?,
        jitter_sigma: args.parse_opt("jitter", 0.0f64)?,
        seed: args.parse_opt("seed", 42u64)?,
        ..Default::default()
    };
    let cluster = Cluster::new(machine.clone(), nodes);
    println!(
        "pipeline on {} x{} nodes ({} workers): pp={} microbatches={} interleave={} stragglers={:?} jitter={}",
        machine.name,
        nodes,
        cluster.world_size(),
        pp,
        microbatches,
        interleave,
        scenario.stragglers,
        scenario.jitter_sigma,
    );

    let mut summary = Table::new(&[
        "scheme",
        "schedule",
        "step (s)",
        "thruput vs P=1",
        "bubble",
        "ideal bound",
        "M",
    ])
    .title(format!(
        "Pipeline schedules — {} @ {} workers, P={pp}",
        model.name,
        cluster.world_size()
    ))
    .left_first();
    let telemetry_path = args.get("telemetry");
    let prom_path = args.get("prom");
    let mut writer = telemetry_path.map(TelemetryWriter::create).transpose()?;
    let mut reg = Registry::new();
    let mut telemetry_step = 0usize;
    let mut scheds: Vec<(String, Schedule)> = Vec::new();
    for &scheme in &schemes {
        let base = simulate_step(&model, scheme, &cluster, &cfg);
        // tokens per step differ between the axes (P=1 derives grad-accum
        // from the global batch; the pipeline runs M microbatches on W/P
        // pipelines), so the headline ratio is token-normalized throughput
        let base_rate = (base.grad_accum * cluster.world_size()) as f64 / base.step_s;
        summary.row(vec![
            scheme.name(),
            "P=1 (no pipeline)".into(),
            fnum(base.step_s, 3),
            "1.00x".into(),
            "-".into(),
            "-".into(),
            base.grad_accum.to_string(),
        ]);
        let mut variants = vec![("1F1B", 1usize)];
        if interleave > 1 {
            variants.push(("interleaved", interleave));
        }
        for (label, v) in variants {
            let pipe = PipeConfig { stages: pp, microbatches, interleave: v };
            let (b, sched, plan) = simulate_step_pipeline_scenario(
                &model, scheme, &cluster, &cfg, &pipe, &scenario,
            )?;
            let rate = (b.microbatches * (cluster.world_size() / pp)) as f64 / b.step_s;
            summary.row(vec![
                scheme.name(),
                if v > 1 { format!("{label} V={v}") } else { label.to_string() },
                fnum(b.step_s, 3),
                format!("{:.2}x", rate / base_rate),
                fnum(b.bubble_fraction, 4),
                fnum(b.ideal_bubble, 4),
                b.microbatches.to_string(),
            ]);
            if v == 1 {
                println!(
                    "{}",
                    render_pipeline_table(
                        &format!("{} — 1F1B per-stage accounting", scheme.name()),
                        &plan,
                        &sched,
                        &machine
                    )
                );
                println!(
                    "{}",
                    render_utilization_table(
                        &format!("{} — link utilization", scheme.name()),
                        &sched,
                        &machine,
                        0
                    )
                );
            }
            if writer.is_some() || prom_path.is_some() {
                // token-normalized point: M microbatches on each of the
                // W/P data-parallel pipelines
                let dp = cluster.world_size() / pp;
                let point = Throughput {
                    gcds: cluster.world_size(),
                    step_seconds: b.step_s,
                    flops_per_step: model.flops_per_token()
                        * (cfg.micro_batch * model.seq * b.microbatches * dp) as f64,
                    sequences_per_step: (cfg.micro_batch * b.microbatches * dp) as f64,
                };
                let mem =
                    MemoryModel::new(scheme, ShardingSpec::resolve(scheme, &cluster)?)
                        .per_device(model.n_params() as f64);
                let rec = StepRecord::new(
                    telemetry_step,
                    StepKind::Pipeline,
                    &scheme.name(),
                    &machine.name,
                    nodes,
                    &point,
                )
                .with_memory(mem)
                .with_schedule(&sched, &machine)
                .with_bubble(b.bubble_fraction);
                register_step(&mut reg, &rec);
                if let Some(w) = writer.as_mut() {
                    w.write_record(&rec)?;
                }
                telemetry_step += 1;
            }
            scheds.push((format!("{}/{}", scheme.name(), label), sched));
        }
    }
    println!("{}", summary.render());
    if let (Some(w), Some(path)) = (writer.as_mut(), telemetry_path) {
        w.flush()?;
        println!("wrote {} telemetry records to {path}", w.written());
    }
    if let Some(path) = prom_path {
        std::fs::write(path, reg.to_prometheus())?;
        println!("wrote Prometheus snapshot to {path}");
    }
    if let Some(path) = args.get("trace") {
        let named: Vec<(String, &Schedule)> =
            scheds.iter().map(|(n, s)| (n.clone(), s)).collect();
        std::fs::write(path, trace::chrome_trace_labeled(&named, Some(&machine)))?;
        println!("wrote {path} (open in chrome://tracing or ui.perfetto.dev)");
    }
    Ok(())
}

/// Multi-rank straggler/jitter/imbalance study at one scale: per-scheme
/// baseline-vs-scenario makespans, per-rank stall attribution, and the
/// slowest rank's critical path.
fn cmd_scenario(args: &Args) -> anyhow::Result<()> {
    let model = TransformerSpec::by_name(args.get_or("model", "20b"))
        .ok_or_else(|| anyhow::anyhow!("unknown model (use 10b/20b/125m)"))?;
    let machine = resolve_machine(args)?;
    let nodes = args.parse_opt("nodes", 48usize)?;
    let schemes = parse_schemes(args)?;
    let mut cfg = SimConfig::default();
    cfg.mfu = args.parse_opt("mfu", cfg.mfu)?;
    cfg.prefetch_depth = args.parse_opt("depth", cfg.prefetch_depth)?;
    cfg.layer_blocks = parse_layer_blocks(args, model.n_layers)?;
    let scenario = Scenario {
        ranks: args.parse_opt("ranks", RankCount::Auto)?,
        stragglers: Scenario::parse_stragglers(args.get_or("straggler", ""))
            .map_err(|e| anyhow::anyhow!(e))?,
        jitter_sigma: args.parse_opt("jitter", 0.0f64)?,
        seed: args.parse_opt("seed", 42u64)?,
        imbalance: Scenario::parse_imbalance(args.get_or("imbalance", ""))
            .map_err(|e| anyhow::anyhow!(e))?,
        faults: Scenario::parse_faults(args.get_or("faults", ""))
            .map_err(|e| anyhow::anyhow!(e))?,
    };
    let rank_rows = args.parse_opt("rank-rows", 12usize)?;
    let threads = args.parse_opt("threads", 1usize)?;
    let cluster = Cluster::new(machine.clone(), nodes);
    println!(
        "scenario on {} x{} nodes ({} workers): ranks={} stragglers={:?} jitter={} seed={} imbalance={:?}",
        machine.name,
        nodes,
        cluster.world_size(),
        scenario.ranks,
        scenario.stragglers,
        scenario.jitter_sigma,
        scenario.seed,
        scenario.imbalance,
    );

    let mut summary = Table::new(&[
        "scheme",
        "baseline step (s)",
        "scenario step (s)",
        "slowdown",
        "modeled ranks",
        "slowest rank",
    ])
    .title(format!("Scenario impact — {} @ {} workers", model.name, cluster.world_size()))
    .left_first();
    // each (baseline, scenario) pair is a pure sim — price them on the
    // sweep driver; results come back in scheme order regardless of
    // thread count, so the report is byte-identical at any --threads
    let priced = parallel_map(threads, &schemes, |_, &scheme| {
        let base = simulate_step(&model, scheme, &cluster, &cfg);
        let (b, sched) = simulate_step_scenario(&model, scheme, &cluster, &cfg, &scenario);
        (base, b, sched)
    });
    let mut scheds: Vec<(String, Schedule)> = Vec::new();
    for (&scheme, (base, b, sched)) in schemes.iter().zip(priced) {
        summary.row(vec![
            scheme.name(),
            fnum(base.step_s, 3),
            fnum(b.step_s, 3),
            format!("{:+.2}%", (b.step_s / base.step_s - 1.0) * 100.0),
            sched.ranks().len().to_string(),
            format!("r{}", sched.slowest_rank()),
        ]);
        scheds.push((scheme.name(), sched));
    }
    println!("{}", summary.render());

    // --faults: walk a priced multi-step timeline under the deterministic
    // injectors and account every simulated second (DESIGN.md §17). The
    // per-step clock above is untouched — with no faults the run is
    // bit-identical to before the injectors existed.
    if !scenario.faults.is_empty() {
        let steps = args.parse_opt("steps", 20usize)?;
        let every = args.parse_opt("ckpt-every", 5usize)?;
        let mut tl = Table::new(&[
            "scheme",
            "useful (s)",
            "saves (s)",
            "lost (s)",
            "overhead (s)",
            "total (s)",
            "goodput (tok/s)",
            "tax",
        ])
        .title(format!(
            "Fault timeline — {steps} steps, checkpoint every {every}, {} fault(s)",
            scenario.faults.len()
        ))
        .left_first();
        let mut event_lines = String::new();
        for &scheme in &schemes {
            let tr = price_timeline(
                &model, scheme, &machine, nodes, &cfg, &scenario, None, steps, every,
            )?;
            tl.row(vec![
                scheme.name(),
                fnum(tr.useful_s, 3),
                fnum(tr.save_s_total, 3),
                fnum(tr.lost_work_s_total, 3),
                fnum(tr.overhead_s_total, 3),
                fnum(tr.total_s, 3),
                fnum(tr.goodput_tokens_per_s, 0),
                format!("{:.2}%", (1.0 - tr.goodput_tokens_per_s / tr.tokens_per_s) * 100.0),
            ]);
            for ev in &tr.events {
                event_lines.push_str(&format!(
                    "  {} @ step {}: {} — overhead {:.3}s, lost work {:.3}s\n",
                    scheme.name(),
                    ev.at_step,
                    ev.label,
                    ev.overhead_s,
                    ev.lost_work_s
                ));
            }
            if tr.final_nodes != nodes {
                event_lines.push_str(&format!(
                    "  {} finished on {} nodes (step time {:.3}s after resize)\n",
                    scheme.name(),
                    tr.final_nodes,
                    tr.final_step_s
                ));
            }
        }
        println!("{}", tl.render());
        print!("{event_lines}");
    }

    for (name, sched) in &scheds {
        let title = format!("{name} — per-rank attribution");
        println!("{}", render_rank_table(&title, sched, &machine, rank_rows));
        println!("{}", render_critical_path(sched, rank_rows));
    }
    if let Some(path) = args.get("trace") {
        let named: Vec<(String, &Schedule)> =
            scheds.iter().map(|(n, s)| (n.clone(), s)).collect();
        std::fs::write(path, trace::chrome_trace_labeled(&named, Some(&machine)))?;
        println!("wrote {path} (open in chrome://tracing or ui.perfetto.dev)");
    }
    Ok(())
}

/// Default location of the committed perf baseline: the repo root, one
/// level above the cargo manifest.
fn default_baseline_path() -> String {
    concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_baseline.json").to_string()
}

/// Perf guardrail: recompute the calibrated 20B/384-GCD step times per
/// scheme on the frontier + dgx builtins and compare against the committed
/// `BENCH_baseline.json`. `--check` fails (non-zero exit) on drift beyond
/// the tolerance, so refactors cannot silently move the Fig 7 numbers;
/// `--write` regenerates the baseline after an *intentional* recalibration.
fn cmd_calibrate(args: &Args) -> anyhow::Result<()> {
    let model = TransformerSpec::by_name(args.get_or("model", "20b"))
        .ok_or_else(|| anyhow::anyhow!("unknown model (use 10b/20b/125m)"))?;
    let nodes = args.parse_opt("nodes", 48usize)?;
    let tolerance = args.parse_opt("tolerance", 0.01f64)?;
    let machines: Vec<String> = args
        .get_or("machines", "frontier,dgx")
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();
    let schemes = parse_schemes(args)?;
    let cfg = SimConfig::default();
    let path = args.get_or("baseline", "");
    let path = if path.is_empty() { default_baseline_path() } else { path.to_string() };

    // recompute every (machine, scheme) point; (pp, microbatches) =
    // (1, 0) marks the plain data-parallel entries. Each point carries
    // its wall-clock self-profile (sim::SimProfile) — real time, strictly
    // apart from the simulated step_s it sits next to.
    let mut entries: Vec<(String, String, usize, usize, f64, SimProfile, Option<f64>)> =
        Vec::new();
    for mname in &machines {
        let spec = MachineSpec::resolve(mname)?;
        let cluster = Cluster::new(spec, nodes);
        for &scheme in &schemes {
            let (b, _, prof) = profile_step(&model, scheme, &cluster, &cfg);
            // goodput pin (ISSUE 10): net tokens/s at the Young/Daly
            // optimal interval under the default MTBF — gated like step_s,
            // but only when the committed baseline records the field
            let g = {
                let ck = checkpoint_cost(&model, scheme, &cluster, &cfg)?;
                let tau = optimal_interval(DEFAULT_MTBF_S, &ck)?;
                let tokens =
                    (b.grad_accum * cfg.micro_batch * model.seq * cluster.world_size()) as f64;
                goodput(b.step_s, tokens, &ck, DEFAULT_MTBF_S, tau)?.goodput_tokens_per_s
            };
            entries.push((mname.clone(), scheme.name(), 1, 0, b.step_s, prof, Some(g)));
        }
    }
    // pinned pipeline points (ISSUE 4): ZeRO-topo 1F1B at P=4, M ∈ {8, 32}
    // on the first machine in the list (frontier by default) — the perf
    // guardrail covers the pipeline subsystem from day one
    const PIPELINE_PROBES: [(usize, usize); 2] = [(4, 8), (4, 32)];
    if let Some(mname) = machines.first() {
        let spec = MachineSpec::resolve(mname)?;
        let cluster = Cluster::new(spec, nodes);
        for (pp, mb) in PIPELINE_PROBES {
            if nodes % pp != 0 {
                continue;
            }
            let pipe = PipeConfig { stages: pp, microbatches: mb, interleave: 1 };
            let (b, _, _, prof) = profile_step_pipeline(
                &model,
                Scheme::ZeroTopo { sec_degree: 0 },
                &cluster,
                &cfg,
                &pipe,
            )?;
            // pipeline points carry no goodput pin: the timeline pricer
            // handles pipelines, but the pinned guardrail keeps the DP
            // points as its goodput surface
            entries.push((mname.clone(), "ZeRO-topo".into(), pp, mb, b.step_s, prof, None));
        }
    }

    if args.flag("write") {
        let json = Json::obj(vec![
            ("model", Json::str(args.get_or("model", "20b"))),
            ("nodes", Json::from(nodes)),
            ("tolerance", Json::num(tolerance)),
            (
                "entries",
                Json::arr(entries.iter().map(|(m, s, pp, mb, t, prof, g)| {
                    let mut fields = vec![
                        ("machine", Json::str(m.clone())),
                        ("scheme", Json::str(s.clone())),
                    ];
                    if *pp > 1 {
                        fields.push(("pp", Json::from(*pp)));
                        fields.push(("microbatches", Json::from(*mb)));
                    }
                    fields.push(("step_s", Json::num(*t)));
                    if let Some(g) = g {
                        fields.push(("goodput_tokens_per_s", Json::num(*g)));
                    }
                    // wall-clock self-profile: tasks_per_s is the floor the
                    // --check wall-time gate compares against (>3x under
                    // this recorded rate fails); tasks/wall_s are context
                    fields.push(("tasks", Json::from(prof.tasks)));
                    fields.push(("wall_s", Json::num(prof.total_wall_s())));
                    fields.push(("tasks_per_s", Json::num(prof.tasks_per_sec())));
                    Json::obj(fields)
                })),
            ),
        ]);
        std::fs::write(&path, format!("{json}\n"))?;
        println!("wrote {path} ({} entries)", entries.len());
        return Ok(());
    }

    let text = std::fs::read_to_string(&path).map_err(|e| {
        anyhow::anyhow!("cannot read baseline {path}: {e} (run `calibrate --write`)")
    })?;
    let json = Json::parse(&text).map_err(|e| anyhow::anyhow!("bad baseline {path}: {e}"))?;
    // value: (step_s, optional baseline tasks_per_s, optional goodput pin)
    // — old baselines without the newer fields still parse (the speed
    // column shows — and the goodput gate stays off for that entry)
    type BaselineKey = (String, String, usize, usize);
    let mut baseline: std::collections::BTreeMap<BaselineKey, (f64, Option<f64>, Option<f64>)> =
        std::collections::BTreeMap::new();
    for e in json
        .get("entries")
        .and_then(|e| e.as_arr())
        .ok_or_else(|| anyhow::anyhow!("baseline {path} has no entries array"))?
    {
        let m = e.get("machine").and_then(|v| v.as_str()).unwrap_or_default().to_string();
        let s = e.get("scheme").and_then(|v| v.as_str()).unwrap_or_default().to_string();
        let pp = e.get("pp").and_then(|v| v.as_usize()).unwrap_or(1);
        let mb = e.get("microbatches").and_then(|v| v.as_usize()).unwrap_or(0);
        let t = e
            .get("step_s")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow::anyhow!("baseline entry without step_s"))?;
        let tps = e.get("tasks_per_s").and_then(|v| v.as_f64()).filter(|&v| v > 0.0);
        let gpin = e.get("goodput_tokens_per_s").and_then(|v| v.as_f64()).filter(|&v| v > 0.0);
        baseline.insert((m, s, pp, mb), (t, tps, gpin));
    }
    // precedence: explicit --tolerance > baseline's recorded field > default
    let tol = if args.get("tolerance").is_some() {
        tolerance
    } else {
        json.get("tolerance").and_then(|v| v.as_f64()).unwrap_or(tolerance)
    };

    let mut t =
        Table::new(&["machine", "scheme", "baseline (s)", "now (s)", "drift", "tasks/s"])
            .title(format!(
                "Perf guardrail — {} @ {} nodes (tolerance {:.1}%)",
                model.name,
                nodes,
                tol * 100.0
            ))
            .left_first();
    // --md: the same drift table as GitHub-flavored markdown, appended to
    // FILE (CI points this at $GITHUB_STEP_SUMMARY so guardrail failures
    // are diagnosable from the run page without rerunning locally).
    // tasks/s + speed are the wall-clock self-profile; under --check the
    // speed column is gated (>3x slower than baseline fails, see below).
    let mut md = format!(
        "### Perf guardrail — {} @ {} nodes (tolerance {:.1}%)\n\n\
         | machine | scheme | baseline (s) | now (s) | drift | status | tasks/s | speed |\n\
         |---|---|---|---|---|---|---|---|\n",
        model.name,
        nodes,
        tol * 100.0
    );
    let mut failures = Vec::new();
    let mut slowdowns = Vec::new();
    for (m, s, pp, mb, now, prof, gnow) in &entries {
        let label = if *pp > 1 { format!("{s} [pp{pp} mb{mb}]") } else { s.clone() };
        let now_tps = prof.tasks_per_sec();
        let tps_cell = if now_tps > 0.0 {
            format!("{now_tps:.0}")
        } else {
            "—".to_string()
        };
        match baseline.get(&(m.clone(), s.clone(), *pp, *mb)) {
            Some(&(base, base_tps, base_g)) => {
                let drift = (now - base) / base;
                t.row(vec![
                    m.clone(),
                    label.clone(),
                    format!("{base:.6}"),
                    format!("{now:.6}"),
                    format!("{:+.3}%", drift * 100.0),
                    tps_cell.clone(),
                ]);
                let ok = drift.abs() <= tol;
                let speed = match base_tps {
                    Some(b_tps) if now_tps > 0.0 => format!("{:.2}x", now_tps / b_tps),
                    _ => "—".to_string(),
                };
                md.push_str(&format!(
                    "| {m} | {label} | {base:.6} | {now:.6} | {:+.3}% | {} | {tps_cell} | {speed} |\n",
                    drift * 100.0,
                    if ok { "ok" } else { "**DRIFT**" }
                ));
                if !ok {
                    failures.push(format!(
                        "{m}/{label}: {base:.6}s -> {now:.6}s ({:+.2}%)",
                        drift * 100.0
                    ));
                }
                if let Some(b_tps) = base_tps {
                    if now_tps > 0.0 && now_tps < b_tps / 3.0 {
                        slowdowns.push(format!(
                            "{m}/{label}: {b_tps:.0} -> {now_tps:.0} tasks/s"
                        ));
                    }
                }
                // goodput gate: only when both the baseline pin and the
                // freshly-computed value exist for this entry — the drift
                // tolerance is shared with step_s
                if let (Some(bg), Some(ng)) = (base_g, *gnow) {
                    let gdrift = (ng - bg) / bg;
                    if gdrift.abs() > tol {
                        failures.push(format!(
                            "{m}/{label} goodput: {bg:.6} -> {ng:.6} tok/s ({:+.2}%)",
                            gdrift * 100.0
                        ));
                    }
                }
            }
            None => {
                t.row(vec![
                    m.clone(),
                    label.clone(),
                    "—".into(),
                    format!("{now:.6}"),
                    "—".into(),
                    tps_cell.clone(),
                ]);
                md.push_str(&format!(
                    "| {m} | {label} | — | {now:.6} | — | **MISSING** | {tps_cell} | — |\n"
                ));
                failures.push(format!("{m}/{label}: missing from baseline"));
            }
        }
    }
    println!("{}", t.render());
    // simulator self-profile roll-up (ROADMAP "Simulator raw speed"):
    // real wall time, reported next to — never mixed into — the pins
    let total_tasks: usize = entries.iter().map(|e| e.5.tasks).sum();
    let total_wall: f64 = entries.iter().map(|e| e.5.total_wall_s()).sum();
    let loop_wall: f64 = entries.iter().map(|e| e.5.event_loop_wall_s).sum();
    let agg_tps = if loop_wall > 0.0 {
        total_tasks as f64 / loop_wall
    } else {
        0.0
    };
    println!(
        "self-profile: {total_tasks} tasks in {total_wall:.3}s wall \
         ({agg_tps:.0} tasks/s event loop)"
    );
    // wall-time gate (ISSUE 9): speed regressions fail `--check` like
    // accuracy regressions do. The 3x threshold is deliberately generous —
    // CI-runner speed varies maybe 2x, an accidental O(n^2) in the event
    // loop costs 10-100x on the 384-GCD worlds — so the gate catches
    // algorithmic regressions without flaking on machine noise.
    if !slowdowns.is_empty() {
        let msg = format!(
            "simulator >3x slower than baseline tasks/s:\n  {}\n(if intentional — e.g. a new fidelity feature — regenerate with `calibrate --write`)",
            slowdowns.join("\n  ")
        );
        if args.flag("check") {
            anyhow::bail!("{msg}");
        }
        eprintln!("warning: {msg}");
    }
    if let Some(md_path) = args.get("md") {
        use std::io::Write;
        md.push('\n');
        // append, never truncate: $GITHUB_STEP_SUMMARY is shared by steps
        std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(md_path)?
            .write_all(md.as_bytes())?;
        println!("appended markdown drift table to {md_path}");
    }
    if !failures.is_empty() {
        let msg = format!(
            "calibration drift beyond {:.1}%:\n  {}\n(if intentional, regenerate with `calibrate --write`)",
            tol * 100.0,
            failures.join("\n  ")
        );
        if args.flag("check") {
            anyhow::bail!("{msg}");
        }
        eprintln!("warning: {msg}");
    } else {
        println!("all {} points within {:.1}% of baseline", entries.len(), tol * 100.0);
    }
    Ok(())
}

/// Goodput under failure (DESIGN.md §17): per machine x scheme, price the
/// checkpoint save/restore path against the machine's storage spec, derive
/// the Young/Daly optimal interval tau*, and report expected tokens/s net
/// of saves, lost work, and restarts at the given MTBF.
fn cmd_goodput(args: &Args) -> anyhow::Result<()> {
    let model = TransformerSpec::by_name(args.get_or("model", "20b"))
        .ok_or_else(|| anyhow::anyhow!("unknown model (use 10b/20b/125m)"))?;
    let nodes = args.parse_opt("nodes", 48usize)?;
    let schemes = parse_schemes(args)?;
    let mut cfg = SimConfig::default();
    cfg.mfu = args.parse_opt("mfu", cfg.mfu)?;
    let mtbf = args.parse_opt("mtbf", DEFAULT_MTBF_S)?;
    // --interval overrides the closed-form tau* (e.g. to price a fixed
    // operational cadence); degenerate values come back as diagnosed
    // errors from the goodput layer, not NaN
    let interval: Option<f64> = match args.get("interval") {
        Some(_) => Some(args.parse_opt("interval", 0.0f64)?),
        None => None,
    };
    // --machine (single, accepts spec JSON paths) wins over the
    // calibrate-style --machines comma list
    let machines: Vec<String> = match args.get("machine") {
        Some(m) => vec![m.to_string()],
        None => args
            .get_or("machines", "frontier,dgx")
            .split(',')
            .map(|s| s.trim().to_string())
            .collect(),
    };

    let mut machine_json = Vec::new();
    let mut md_all = String::new();
    for mname in &machines {
        let spec = MachineSpec::resolve(mname)?;
        let cluster = Cluster::new(spec, nodes);
        let world = cluster.world_size();
        let mut rows = Vec::new();
        let mut scheme_json = Vec::new();
        // (scheme name, tau*, interval grid) — rendered after the table
        let mut sweeps: Vec<(
            String,
            f64,
            Vec<(f64, Result<zero_topo::sim::goodput::GoodputReport, zero_topo::sim::goodput::GoodputError>)>,
        )> = Vec::new();
        for &scheme in &schemes {
            let b = simulate_step(&model, scheme, &cluster, &cfg);
            let ck = checkpoint_cost(&model, scheme, &cluster, &cfg)?;
            let tau = optimal_interval(mtbf, &ck)?;
            let tokens = (b.grad_accum * cfg.micro_batch * model.seq * world) as f64;
            let used = interval.unwrap_or(tau);
            let g = goodput(b.step_s, tokens, &ck, mtbf, used)?;
            rows.push(GoodputRow {
                scheme: scheme.name(),
                step_s: b.step_s,
                tokens_per_s: g.tokens_per_s,
                save_s: ck.save_s,
                restore_s: ck.restore_s(),
                tau_opt_s: tau,
                availability: g.availability,
                goodput_tokens_per_s: g.goodput_tokens_per_s,
            });
            let mut fields = vec![
                ("scheme", Json::str(scheme.name())),
                ("step_s", Json::num(b.step_s)),
                ("tokens_per_step", Json::num(tokens)),
                ("save_s", Json::num(ck.save_s)),
                ("load_s", Json::num(ck.load_s)),
                ("remat_s", Json::num(ck.remat_s)),
                ("restore_s", Json::num(ck.restore_s())),
                ("tau_opt_s", Json::num(tau)),
                ("interval_s", Json::num(used)),
                ("availability", Json::num(g.availability)),
                ("tokens_per_s", Json::num(g.tokens_per_s)),
                ("goodput_tokens_per_s", Json::num(g.goodput_tokens_per_s)),
            ];
            if args.flag("sweep") {
                let grid = sweep(b.step_s, tokens, &ck, mtbf)?;
                fields.push((
                    "sweep",
                    Json::arr(grid.iter().map(|(i, r)| match r {
                        Ok(g) => Json::obj(vec![
                            ("interval_s", Json::num(*i)),
                            ("availability", Json::num(g.availability)),
                            ("goodput_tokens_per_s", Json::num(g.goodput_tokens_per_s)),
                        ]),
                        Err(e) => Json::obj(vec![
                            ("interval_s", Json::num(*i)),
                            ("error", Json::str(e.to_string())),
                        ]),
                    })),
                ));
                sweeps.push((scheme.name(), tau, grid));
            }
            scheme_json.push(Json::obj(fields));
        }
        let title = format!(
            "Goodput — {} on {} x{} nodes ({} workers), MTBF {:.0}s, interval {}",
            model.name,
            cluster.spec.name,
            nodes,
            world,
            mtbf,
            interval.map(|i| format!("{i:.0}s")).unwrap_or_else(|| "tau*".into()),
        );
        if args.flag("json") {
            machine_json.push(Json::obj(vec![
                ("machine", Json::str(mname.clone())),
                ("world", Json::from(world)),
                ("schemes", Json::arr(scheme_json.into_iter())),
            ]));
        } else {
            println!("{}", render_goodput_table(&title, mtbf, &rows));
            for (name, tau, grid) in &sweeps {
                println!(
                    "{}",
                    render_goodput_sweep(&format!("{name} — interval sweep"), *tau, grid)
                );
            }
        }
        if args.get("md").is_some() {
            md_all.push_str(&goodput_markdown(&title, mtbf, &rows));
        }
    }
    if args.flag("json") {
        let json = Json::obj(vec![
            ("model", Json::str(model.name.clone())),
            ("nodes", Json::from(nodes)),
            ("mtbf_s", Json::num(mtbf)),
            ("machines", Json::arr(machine_json.into_iter())),
        ]);
        println!("{json}");
    }
    if let Some(md_path) = args.get("md") {
        use std::io::Write;
        // append, never truncate: $GITHUB_STEP_SUMMARY is shared by steps
        std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(md_path)?
            .write_all(md_all.as_bytes())?;
        println!("appended goodput markdown to {md_path}");
    }
    Ok(())
}

/// The decomposition ledger as the JSON shape shared by `explain --json`
/// and the telemetry stream's `critical` object (plus the conservation
/// defect and the binding category, which `explain` gates on).
fn decomposition_json(d: &Decomposition, machine: &MachineSpec) -> Json {
    let comm = d.comm_s().iter().map(|(&c, &s)| {
        Json::obj(vec![
            ("link", Json::str(machine.class_label(c))),
            ("seconds", Json::num(s)),
        ])
    });
    Json::obj(vec![
        ("compute_s", Json::num(d.compute_s())),
        ("idle_s", Json::num(d.idle_s())),
        ("comm", Json::arr(comm)),
        ("makespan_s", Json::num(d.makespan())),
        ("conservation_error", Json::num(d.conservation_error())),
        ("bound_by", Json::str(category_label(d.dominant(), machine))),
    ])
}

/// One priced point for `explain`: step seconds + the executed schedule,
/// through the exact entry points the figures use (pipeline when `pipe`
/// is set).
fn explain_point(
    model: &TransformerSpec,
    scheme: Scheme,
    cluster: &Cluster,
    cfg: &SimConfig,
    pipe: Option<&PipeConfig>,
) -> anyhow::Result<(f64, Schedule)> {
    Ok(match pipe {
        None => {
            let (b, sched) = simulate_step_schedule(model, scheme, cluster, cfg);
            (b.step_s, sched)
        }
        Some(p) => {
            let (b, sched, _) = simulate_step_pipeline(model, scheme, cluster, cfg, p)?;
            (b.step_s, sched)
        }
    })
}

/// `explain` — the bottleneck-attribution engine (DESIGN.md §14).
/// Default: decomposition + shadow prices per scheme; `--baseline FILE`
/// re-prices the pinned BENCH entries and gates conservation + drift;
/// `--diff A B` attributes the step-time delta between two runs.
fn cmd_explain(args: &Args) -> anyhow::Result<()> {
    if args.flag("diff") {
        return cmd_explain_diff(args);
    }
    if let Some(path) = args.get("baseline") {
        return cmd_explain_baseline(args, path);
    }
    let model = TransformerSpec::by_name(args.get_or("model", "20b"))
        .ok_or_else(|| anyhow::anyhow!("unknown model (use 10b/20b/125m)"))?;
    let machine = resolve_machine(args)?;
    let nodes = args.parse_opt("nodes", 48usize)?;
    let schemes = parse_schemes(args)?;
    let mut cfg = SimConfig::default();
    cfg.mfu = args.parse_opt("mfu", cfg.mfu)?;
    cfg.prefetch_depth = args.parse_opt("depth", cfg.prefetch_depth)?;
    let pp = parse_pp(args)?;
    ensure_no_blocks_under_pipeline(args, pp)?;
    cfg.layer_blocks = parse_layer_blocks(args, model.n_layers)?;
    let microbatches = args.parse_opt("microbatches", 0usize)?;
    let interleave = args.parse_opt("interleave", 1usize)?;
    let pipe = (pp > 1).then(|| PipeConfig { stages: pp, microbatches, interleave });
    let eps = args.parse_opt("eps", DEFAULT_EPSILON)?;
    anyhow::ensure!(eps > 0.0, "--eps must be > 0");
    let cluster = Cluster::new(machine.clone(), nodes);
    let mut out = Vec::new();
    for &scheme in &schemes {
        let (step_s, sched) = explain_point(&model, scheme, &cluster, &cfg, pipe.as_ref())?;
        let d = decompose(&sched);
        let prices = shadow_prices(&model, scheme, &cluster, &cfg, pipe.as_ref(), eps)?;
        if args.flag("json") {
            let rows = prices.prices.iter().map(|p| {
                Json::obj(vec![
                    ("knob", Json::str(p.label.clone())),
                    ("saving_s", Json::num(p.saving)),
                    ("improved_s", Json::num(p.improved_s)),
                    (
                        "derivative",
                        p.derivative.map(Json::num).unwrap_or(Json::Null),
                    ),
                ])
            });
            out.push(Json::obj(vec![
                ("scheme", Json::str(scheme.name())),
                ("step_s", Json::num(step_s)),
                ("critical", decomposition_json(&d, &machine)),
                ("shadow_prices", Json::arr(rows)),
            ]));
        } else {
            let at = format!(
                "{} — {} @ {} x{} nodes ({} workers)",
                scheme.name(),
                model.name,
                machine.name,
                nodes,
                cluster.world_size()
            );
            println!(
                "{}",
                render_decomposition_table(
                    &format!("{at} — critical-path decomposition"),
                    &d,
                    &machine
                )
            );
            println!(
                "{}",
                render_shadow_price_table(
                    &format!("{} — link shadow prices", scheme.name()),
                    &prices
                )
            );
        }
    }
    if args.flag("json") {
        let j = Json::obj(vec![
            ("model", Json::str(model.name)),
            ("machine", Json::str(machine.name.clone())),
            ("nodes", Json::from(nodes)),
            ("epsilon", Json::num(eps)),
            ("schemes", Json::arr(out)),
        ]);
        println!("{j}");
    }
    Ok(())
}

/// `explain --baseline FILE`: re-simulate the same probe set `calibrate`
/// pins (machines x schemes, plus the P=4 pipeline probes), decompose
/// each step, and gate (a) ledger conservation at 1e-12 absolute on every
/// entry and (b) step-time drift against the pinned value.
fn cmd_explain_baseline(args: &Args, path: &str) -> anyhow::Result<()> {
    let model = TransformerSpec::by_name(args.get_or("model", "20b"))
        .ok_or_else(|| anyhow::anyhow!("unknown model (use 10b/20b/125m)"))?;
    let nodes = args.parse_opt("nodes", 48usize)?;
    let machines: Vec<String> = args
        .get_or("machines", "frontier,dgx")
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();
    let schemes = parse_schemes(args)?;
    let cfg = SimConfig::default();
    const CONSERVATION_BUDGET: f64 = 1e-12;

    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("cannot read baseline {path}: {e}"))?;
    let json = Json::parse(&text).map_err(|e| anyhow::anyhow!("bad baseline {path}: {e}"))?;
    type PinKey = (String, String, usize, usize);
    let mut pins: std::collections::BTreeMap<PinKey, f64> = std::collections::BTreeMap::new();
    for e in json
        .get("entries")
        .and_then(|e| e.as_arr())
        .ok_or_else(|| anyhow::anyhow!("baseline {path} has no entries array"))?
    {
        let m = e.get("machine").and_then(|v| v.as_str()).unwrap_or_default().to_string();
        let s = e.get("scheme").and_then(|v| v.as_str()).unwrap_or_default().to_string();
        let pp = e.get("pp").and_then(|v| v.as_usize()).unwrap_or(1);
        let mb = e.get("microbatches").and_then(|v| v.as_usize()).unwrap_or(0);
        let t = e
            .get("step_s")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow::anyhow!("baseline entry without step_s"))?;
        pins.insert((m, s, pp, mb), t);
    }
    let tol = if args.get("tolerance").is_some() {
        args.parse_opt("tolerance", 0.01f64)?
    } else {
        json.get("tolerance").and_then(|v| v.as_f64()).unwrap_or(0.01)
    };

    // the exact probe set calibrate pins: (machine x scheme) DP points,
    // then the P=4 ZeRO-topo pipeline probes on the first machine
    let mut probes: Vec<(String, MachineSpec, Scheme, usize, usize)> = Vec::new();
    for mname in &machines {
        let spec = MachineSpec::resolve(mname)?;
        for &scheme in &schemes {
            probes.push((mname.clone(), spec.clone(), scheme, 1, 0));
        }
    }
    const PIPELINE_PROBES: [(usize, usize); 2] = [(4, 8), (4, 32)];
    if let Some(mname) = machines.first() {
        let spec = MachineSpec::resolve(mname)?;
        for (pp, mb) in PIPELINE_PROBES {
            if nodes % pp == 0 {
                probes.push((
                    mname.clone(),
                    spec.clone(),
                    Scheme::ZeroTopo { sec_degree: 0 },
                    pp,
                    mb,
                ));
            }
        }
    }

    let mut t = Table::new(&[
        "machine",
        "scheme",
        "step (s)",
        "pinned (s)",
        "drift",
        "conserve err",
        "bound by",
    ])
    .title(format!(
        "Bottleneck attribution vs {path} — {} @ {nodes} nodes (tolerance {:.1}%)",
        model.name,
        tol * 100.0
    ))
    .left_first();
    let mut failures = Vec::new();
    let mut out = Vec::new();
    let mut matched: std::collections::BTreeSet<PinKey> = std::collections::BTreeSet::new();
    for (mname, spec, scheme, pp, mb) in &probes {
        let cluster = Cluster::new(spec.clone(), nodes);
        let pipe = (*pp > 1)
            .then(|| PipeConfig { stages: *pp, microbatches: *mb, interleave: 1 });
        let (step_s, sched) = explain_point(&model, *scheme, &cluster, &cfg, pipe.as_ref())?;
        let d = decompose(&sched);
        let label = if *pp > 1 {
            format!("{} [pp{pp} mb{mb}]", scheme.name())
        } else {
            scheme.name()
        };
        if d.conservation_error() > CONSERVATION_BUDGET {
            failures.push(format!(
                "{mname}/{label}: ledger conservation error {:.3e} > {CONSERVATION_BUDGET:.0e}",
                d.conservation_error()
            ));
        }
        let key = (mname.clone(), scheme.name(), *pp, *mb);
        let pin = pins.get(&key).copied();
        match pin {
            Some(base) => {
                matched.insert(key);
                let drift = (step_s - base) / base;
                if drift.abs() > tol {
                    failures.push(format!(
                        "{mname}/{label}: {base:.6}s -> {step_s:.6}s ({:+.2}%)",
                        drift * 100.0
                    ));
                }
                t.row(vec![
                    mname.clone(),
                    label.clone(),
                    format!("{step_s:.6}"),
                    format!("{base:.6}"),
                    format!("{:+.3}%", drift * 100.0),
                    format!("{:.1e}", d.conservation_error()),
                    category_label(d.dominant(), spec),
                ]);
            }
            None => {
                failures.push(format!("{mname}/{label}: not pinned in {path}"));
                t.row(vec![
                    mname.clone(),
                    label.clone(),
                    format!("{step_s:.6}"),
                    "—".into(),
                    "—".into(),
                    format!("{:.1e}", d.conservation_error()),
                    category_label(d.dominant(), spec),
                ]);
            }
        }
        let mut fields = vec![
            ("machine", Json::str(mname.clone())),
            ("scheme", Json::str(scheme.name())),
        ];
        if *pp > 1 {
            fields.push(("pp", Json::from(*pp)));
            fields.push(("microbatches", Json::from(*mb)));
        }
        fields.push(("step_s", Json::num(step_s)));
        if let Some(base) = pin {
            fields.push(("pinned_s", Json::num(base)));
            fields.push(("drift", Json::num((step_s - base) / base)));
        }
        fields.push(("critical", decomposition_json(&d, spec)));
        out.push(Json::obj(fields));
    }
    for (key, _) in pins.iter().filter(|&(k, _)| !matched.contains(k)) {
        failures.push(format!(
            "pinned entry {}/{} [pp{} mb{}] not covered by the probe set",
            key.0, key.1, key.2, key.3
        ));
    }
    if args.flag("json") {
        let j = Json::obj(vec![
            ("baseline", Json::str(path)),
            ("model", Json::str(model.name)),
            ("nodes", Json::from(nodes)),
            ("tolerance", Json::num(tol)),
            ("conservation_budget", Json::num(CONSERVATION_BUDGET)),
            ("entries", Json::arr(out)),
            ("ok", Json::Bool(failures.is_empty())),
        ]);
        println!("{j}");
    } else {
        println!("{}", t.render());
    }
    if !failures.is_empty() {
        anyhow::bail!(
            "bottleneck attribution gate failed:\n  {}",
            failures.join("\n  ")
        );
    }
    if !args.flag("json") {
        println!(
            "all {} entries conserved (<= {CONSERVATION_BUDGET:.0e}) and within {:.1}% of the pin",
            probes.len(),
            tol * 100.0
        );
    }
    Ok(())
}

/// One side of an `explain --diff`: mean step seconds and the mean
/// attribution ledger per comparable group.
#[derive(Debug, Clone, Default)]
struct DiffPoint {
    step_s: f64,
    n: usize,
    ledger: std::collections::BTreeMap<String, f64>,
}

/// Load one `--diff` operand: a `BENCH_*.json` snapshot (whole-file JSON
/// with an `entries` array; one point per pinned entry, no ledger) or a
/// telemetry JSONL stream (one record per line; records grouped by
/// (machine, scheme, kind, nodes) and averaged, ledgers included).
fn load_diff_side(path: &str) -> anyhow::Result<std::collections::BTreeMap<String, DiffPoint>> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("cannot read {path}: {e}"))?;
    let mut out: std::collections::BTreeMap<String, DiffPoint> = std::collections::BTreeMap::new();
    // a whole-file parse with an `entries` array is a BENCH snapshot;
    // anything else (including a one-line stream) is telemetry JSONL
    let parsed = Json::parse(&text).ok();
    if let Some(entries) =
        parsed.as_ref().and_then(|j| j.get("entries")).and_then(|e| e.as_arr())
    {
        for e in entries {
            let m = e.get("machine").and_then(|v| v.as_str()).unwrap_or("?");
            let s = e.get("scheme").and_then(|v| v.as_str()).unwrap_or("?");
            let pp = e.get("pp").and_then(|v| v.as_usize()).unwrap_or(1);
            let mb = e.get("microbatches").and_then(|v| v.as_usize()).unwrap_or(0);
            let step_s = e
                .get("step_s")
                .and_then(|v| v.as_f64())
                .ok_or_else(|| anyhow::anyhow!("{path}: entry without step_s"))?;
            let key = if pp > 1 {
                format!("{m}/{s} [pp{pp} mb{mb}]")
            } else {
                format!("{m}/{s}")
            };
            out.insert(key, DiffPoint { step_s, n: 1, ledger: Default::default() });
        }
        return Ok(out);
    }
    // telemetry JSONL: one record per line
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(line)
            .map_err(|e| anyhow::anyhow!("{path}:{}: not a JSON record: {e}", i + 1))?;
        let m = j.get("machine").and_then(|v| v.as_str()).unwrap_or("?");
        let s = j.get("scheme").and_then(|v| v.as_str()).unwrap_or("?");
        let kind = j.get("kind").and_then(|v| v.as_str()).unwrap_or("?");
        let nodes = j.get("nodes").and_then(|v| v.as_usize()).unwrap_or(0);
        let step_s = j
            .get("step_s")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow::anyhow!("{path}:{}: record without step_s", i + 1))?;
        let p = out.entry(format!("{m}/{s} [{kind} n{nodes}]")).or_default();
        p.n += 1;
        p.step_s += step_s;
        if let Some(c) = j.get("critical") {
            let mut add = |cat: String, v: f64| *p.ledger.entry(cat).or_default() += v;
            add("compute".into(), c.get("compute_s").and_then(|v| v.as_f64()).unwrap_or(0.0));
            add("idle".into(), c.get("idle_s").and_then(|v| v.as_f64()).unwrap_or(0.0));
            for row in c.get("comm").and_then(|a| a.as_arr()).unwrap_or(&[]) {
                let link = row.get("link").and_then(|v| v.as_str()).unwrap_or("?");
                let secs = row.get("seconds").and_then(|v| v.as_f64()).unwrap_or(0.0);
                add(format!("comm {link}"), secs);
            }
        }
    }
    anyhow::ensure!(!out.is_empty(), "{path}: no telemetry records");
    for p in out.values_mut() {
        let n = p.n as f64;
        p.step_s /= n;
        for v in p.ledger.values_mut() {
            *v /= n;
        }
    }
    Ok(out)
}

/// `explain --diff A B`: the regression explainer. A is the candidate
/// (new) run, B the reference; the step-time delta of every shared group
/// is attributed to the ledger category that moved the most. With
/// `--tolerance` the diff gates: any shared group drifting beyond it, or
/// any group missing from one side, fails the command.
fn cmd_explain_diff(args: &Args) -> anyhow::Result<()> {
    let (a_path, b_path) = match (args.pos(0), args.pos(1)) {
        (Some(a), Some(b)) => (a.to_string(), b.to_string()),
        _ => anyhow::bail!("--diff needs two files: explain --diff A.jsonl B.jsonl"),
    };
    let a = load_diff_side(&a_path)?;
    let b = load_diff_side(&b_path)?;
    let gate = args.get("tolerance").is_some();
    let tol = args.parse_opt("tolerance", 0.01f64)?;
    let keys: std::collections::BTreeSet<&String> = a.keys().chain(b.keys()).collect();
    let mut t = Table::new(&[
        "group",
        "A step (s)",
        "B step (s)",
        "delta (s)",
        "drift",
        "biggest mover",
    ])
    .title(format!("step-time diff — A={a_path} vs B={b_path}"))
    .left_first();
    let mut rows = Vec::new();
    let mut failures = Vec::new();
    let mut max_drift = 0.0f64;
    for key in keys {
        match (a.get(key), b.get(key)) {
            (Some(pa), Some(pb)) => {
                let delta = pa.step_s - pb.step_s;
                let drift = if pb.step_s != 0.0 { delta / pb.step_s } else { 0.0 };
                max_drift = max_drift.max(drift.abs());
                // the ledger category whose seconds moved the most
                // explains the delta; bench snapshots carry no ledger
                let cats: std::collections::BTreeSet<&String> =
                    pa.ledger.keys().chain(pb.ledger.keys()).collect();
                let mover = cats
                    .into_iter()
                    .map(|c| {
                        let d = pa.ledger.get(c).copied().unwrap_or(0.0)
                            - pb.ledger.get(c).copied().unwrap_or(0.0);
                        (c.clone(), d)
                    })
                    .max_by(|x, y| x.1.abs().partial_cmp(&y.1.abs()).expect("finite ledger"));
                let mover_cell = mover
                    .as_ref()
                    .map(|(c, d)| format!("{c} ({d:+.3}s)"))
                    .unwrap_or_else(|| "- (no ledger)".into());
                t.row(vec![
                    key.clone(),
                    fnum(pa.step_s, 3),
                    fnum(pb.step_s, 3),
                    format!("{delta:+.3}"),
                    format!("{:+.2}%", drift * 100.0),
                    mover_cell,
                ]);
                if gate && drift.abs() > tol {
                    failures.push(format!(
                        "{key}: {:.6}s -> {:.6}s ({:+.2}%)",
                        pb.step_s,
                        pa.step_s,
                        drift * 100.0
                    ));
                }
                let mut fields = vec![
                    ("group", Json::str(key.clone())),
                    ("a_step_s", Json::num(pa.step_s)),
                    ("b_step_s", Json::num(pb.step_s)),
                    ("delta_s", Json::num(delta)),
                    ("drift", Json::num(drift)),
                ];
                if let Some((c, d)) = mover {
                    fields.push(("mover", Json::str(c)));
                    fields.push(("mover_delta_s", Json::num(d)));
                }
                rows.push(Json::obj(fields));
            }
            (pa, pb) => {
                let side = if pa.is_none() { &a_path } else { &b_path };
                t.row(vec![
                    key.clone(),
                    pa.map(|p| fnum(p.step_s, 3)).unwrap_or_else(|| "—".into()),
                    pb.map(|p| fnum(p.step_s, 3)).unwrap_or_else(|| "—".into()),
                    "—".into(),
                    "—".into(),
                    format!("missing from {side}"),
                ]);
                if gate {
                    failures.push(format!("{key}: missing from {side}"));
                }
                rows.push(Json::obj(vec![
                    ("group", Json::str(key.clone())),
                    ("missing_from", Json::str(side.clone())),
                ]));
            }
        }
    }
    if args.flag("json") {
        let j = Json::obj(vec![
            ("a", Json::str(a_path)),
            ("b", Json::str(b_path)),
            ("max_drift", Json::num(max_drift)),
            ("tolerance", if gate { Json::num(tol) } else { Json::Null }),
            ("rows", Json::arr(rows)),
            ("ok", Json::Bool(failures.is_empty())),
        ]);
        println!("{j}");
    } else {
        println!("{}", t.render());
        println!("max |drift| {:.3}%", max_drift * 100.0);
    }
    if !failures.is_empty() {
        anyhow::bail!(
            "step-time drift beyond {:.1}%:\n  {}",
            tol * 100.0,
            failures.join("\n  ")
        );
    }
    Ok(())
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    // --config FILE seeds every knob from a RunConfig JSON (notably the
    // file `plan --emit-config` writes); explicit flags still override.
    let mut cfg = match args.get("config") {
        Some(p) => RunConfig::load(std::path::Path::new(p))
            .map_err(|e| anyhow::anyhow!("cannot load --config {p}: {e}"))?,
        None => RunConfig::default(),
    };
    if let Some(m) = args.get("model") {
        cfg.model = m.to_string();
    }
    if let Some(s) = args.get("scheme") {
        cfg.scheme = Scheme::parse(s).ok_or_else(|| anyhow::anyhow!("bad --scheme"))?;
    }
    if let Some(m) = args.get("machine") {
        cfg.machine = m.to_string();
    }
    cfg.nodes = args.parse_opt("nodes", cfg.nodes)?;
    cfg.steps = args.parse_opt("steps", cfg.steps)?;
    cfg.grad_accum = args.parse_opt("grad-accum", cfg.grad_accum)?;
    cfg.seed = args.parse_opt("seed", cfg.seed)?;
    cfg.lr = args.parse_opt("lr", cfg.lr)?;
    cfg.mfu = args.parse_opt("mfu", cfg.mfu)?;
    cfg.prefetch_depth = args.parse_opt("depth", cfg.prefetch_depth)?;
    cfg.ranks = args.parse_opt("ranks", cfg.ranks)?;
    cfg.jitter_sigma = args.parse_opt("jitter", cfg.jitter_sigma)?;
    if args.get("straggler").is_some() {
        cfg.stragglers = Scenario::parse_stragglers(args.get_or("straggler", ""))
            .map_err(|e| anyhow::anyhow!(e))?;
    }
    if args.get("imbalance").is_some() {
        cfg.imbalance = Scenario::parse_imbalance(args.get_or("imbalance", ""))
            .map_err(|e| anyhow::anyhow!(e))?;
    }
    cfg.pipeline_stages = parse_pp_default(args, cfg.pipeline_stages.max(1))?;
    cfg.microbatches = args.parse_opt("microbatches", cfg.microbatches)?;
    cfg.interleave = args.parse_opt("interleave", cfg.interleave)?;
    if let Some(t) = args.get("telemetry") {
        cfg.telemetry = Some(t.to_string());
    }
    let dir = args.get_or("artifacts", "artifacts");
    // fail fast on a bad --machine before the (expensive) artifact load
    let machine = MachineSpec::resolve(&cfg.machine)?;

    eprintln!("loading artifacts from {dir} ...");
    let rt = Runtime::load(dir)?;
    let runner = rt.model(&cfg.model)?;
    // layer-granular step clock: --layer-granular defaults to one block
    // per manifest layer (the flat parameter count still splits
    // near-evenly — manifests carry no per-layer parameter map)
    ensure_no_blocks_under_pipeline(args, cfg.pipeline_stages)?;
    // only stomp a --config's layer_blocks when a block flag is present
    if args.get("blocks").is_some() || args.flag("layer-granular") {
        cfg.layer_blocks = parse_layer_blocks(args, runner.manifest.n_layers.max(1))?;
    }
    anyhow::ensure!(cfg.layer_blocks >= 1, "layer_blocks must be >= 1");
    eprintln!(
        "model {}: {} params, seq {}, mbs {}; scheme {}, {} {} nodes ({} workers)",
        cfg.model,
        runner.manifest.n_params,
        runner.manifest.seq,
        runner.manifest.mbs,
        cfg.scheme.name(),
        cfg.nodes,
        machine.name,
        cfg.nodes * machine.workers_per_node
    );
    let steps = cfg.steps;
    let csv = args.get("csv").map(|s| s.to_string());
    // capture what the per-step telemetry records need before cfg moves
    // into the engine
    let scheme = cfg.scheme;
    let nodes = cfg.nodes;
    let world = cfg.nodes * machine.workers_per_node;
    let (pp, grad_accum, microbatches) = (cfg.pipeline_stages, cfg.grad_accum, cfg.microbatches);
    let telemetry_path = cfg.telemetry.clone();
    let prom_path = args.get("prom").map(|s| s.to_string());
    let mut writer = telemetry_path.as_deref().map(TelemetryWriter::create).transpose()?;
    let mut reg = Registry::new();
    let cluster = Cluster::new(machine.clone(), nodes);
    let mem = MemoryModel::new(scheme, ShardingSpec::resolve(scheme, &cluster)?)
        .per_device(runner.manifest.n_params as f64);
    // sequences per optimizer step: grad-accum microbatches on every rank
    // (data-parallel), or M microbatches on each of the W/P pipelines
    let seqs_per_step = if pp > 1 {
        let m = if microbatches > 0 { microbatches } else { grad_accum };
        (runner.manifest.mbs * m * (world / pp)) as f64
    } else {
        (runner.manifest.mbs * grad_accum * world) as f64
    };
    // the engine's step clock prices compute with the 6Ψ FLOPs-per-token
    // rule, so telemetry reports the same model FLOPs
    let flops_per_step =
        6.0 * runner.manifest.n_params as f64 * seqs_per_step * runner.manifest.seq as f64;
    let mut engine = TrainEngine::new(cfg, &runner)?;
    let t0 = std::time::Instant::now();
    for s in 0..steps {
        let loss = engine.step()?;
        println!(
            "step {:>4}  loss {:.4}  step(sim) {:.3}s  comm(sim) {:.3}s  wall {:.1}s",
            s + 1,
            loss,
            engine.sim_seconds(),
            engine.comm_seconds(),
            t0.elapsed().as_secs_f64()
        );
        if writer.is_some() || prom_path.is_some() {
            let point = Throughput {
                gcds: world,
                step_seconds: engine.step_sim_seconds(),
                flops_per_step,
                sequences_per_step: seqs_per_step,
            };
            // NB: the train comm ledger is cumulative over the run (a
            // monotonic counter, Prometheus-style) — see DESIGN.md §13
            let mut rec = StepRecord::new(
                s,
                StepKind::Train,
                &scheme.name(),
                &machine.name,
                nodes,
                &point,
            )
            .with_comm(&engine.comm.cost)
            .with_memory(mem)
            .with_loss(loss);
            if let Some(sched) = engine.step_schedule() {
                rec = rec.with_schedule(sched, &machine);
            }
            register_step(&mut reg, &rec);
            if let Some(w) = writer.as_mut() {
                w.write_record(&rec)?;
            }
        }
    }
    if let (Some(w), Some(path)) = (writer.as_mut(), telemetry_path.as_deref()) {
        w.flush()?;
        println!("wrote {} telemetry records to {path}", w.written());
    }
    if let Some(path) = prom_path {
        std::fs::write(&path, reg.to_prometheus())?;
        println!("wrote Prometheus snapshot to {path}");
    }
    if let Some(path) = csv {
        std::fs::write(&path, engine.log.to_csv())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_report(args: &Args) -> anyhow::Result<()> {
    cmd_topo(args)?;
    cmd_sharding(args)?;
    cmd_memory(args)?;
    cmd_capacity(args)?;
    cmd_simulate(args)?;
    Ok(())
}
