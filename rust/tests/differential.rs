//! Differential suite for the fast event loop (DESIGN.md §16): the
//! optimized arena engine (`sched::simulate`) must be bit-for-bit
//! identical to the preserved map-based oracle
//! (`sched::reference::simulate_reference`) — makespans, per-task spans,
//! stall ledgers, link usage, and critical-path decompositions — across
//! hundreds of randomized configurations, every `BENCH_baseline.json`
//! pin, and explicit straggler/jitter/imbalance scenarios. The parallel
//! sweep driver must produce byte-identical reports at any thread count.

use std::path::PathBuf;
use std::process::Command;

use zero_topo::comm::cost::{CommEfficiency, CostModel};
use zero_topo::model::TransformerSpec;
use zero_topo::sched::multi::MultiRankPlan;
use zero_topo::sched::pipeline::PipeConfig;
use zero_topo::sched::plan::StepPlan;
use zero_topo::sched::scenario::{RankCount, Scenario};
use zero_topo::sched::Depth;
use zero_topo::sharding::{Scheme, ShardingSpec};
use zero_topo::sim::{
    scaling_series, scaling_series_threaded, simulate_step_pipeline, simulate_step_schedule,
    SimConfig,
};
use zero_topo::testing::{check, differential};
use zero_topo::topology::{Cluster, MachineSpec};
use zero_topo::util::json::Json;

/// 200 seeded random configurations through both loops: 120 adversarial
/// raw DAGs (ties, zero-work cascades, multi-instance contention,
/// cross-rank dependency webs) + 80 plan-level worlds (scheme × machine
/// × ranks × depth × blocks × P/M/V × scenario). Every observable is
/// compared on `f64::to_bits` terms — see `testing::differential`.
#[test]
fn randomized_graphs_are_bit_identical_across_loops() {
    check("differential: raw DAGs (integration)", 120, |g| {
        differential::simulate_both(differential::random_graph(g));
    });
    check("differential: plan worlds (integration)", 80, |g| {
        differential::simulate_both(differential::random_plan_graph(g));
    });
}

/// Explicit straggler / jitter / imbalance scenarios (not just the
/// randomly-drawn ones): each shape exercises a different multi-rank
/// expansion path, and each must agree bit-for-bit across the loops.
#[test]
fn scenario_shapes_are_bit_identical_across_loops() {
    let cluster = Cluster::frontier(2);
    let cost = CostModel::with_efficiency(cluster.clone(), CommEfficiency::rccl_frontier());
    let shapes: Vec<Scenario> = vec![
        Scenario {
            ranks: RankCount::Count(6),
            stragglers: vec![(3, 1.7), (0, 1.2)],
            ..Default::default()
        },
        Scenario { ranks: RankCount::Count(6), jitter_sigma: 0.08, seed: 7, ..Default::default() },
        Scenario {
            ranks: RankCount::Count(6),
            imbalance: vec![(1, 4), (5, 3)],
            ..Default::default()
        },
        Scenario {
            ranks: RankCount::Auto,
            stragglers: vec![(2, 2.0)],
            jitter_sigma: 0.05,
            imbalance: vec![(0, 3)],
            seed: 99,
            ..Default::default()
        },
    ];
    for scheme in [Scheme::Zero3, Scheme::ZeroPP, Scheme::ZeroTopo { sec_degree: 2 }] {
        let spec = ShardingSpec::resolve(scheme, &cluster).expect("builtin scheme resolves");
        let plan = StepPlan::from_protocol(
            &cost,
            scheme,
            &spec,
            64_000_000,
            256,
            2,
            1.0,
            Depth::Bounded(1),
        );
        for scenario in &shapes {
            differential::simulate_both(MultiRankPlan::new(&plan, &cluster, scenario).build());
        }
    }
}

fn baseline_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../BENCH_baseline.json")
}

/// Every `BENCH_baseline.json` pin must reproduce at exactly 0.0 drift
/// through the optimized loop (`to_bits` equality, far stronger than the
/// calibrate tolerance), and each pinned world's task graph must agree
/// bit-for-bit between the two loops on all observables.
#[test]
fn bench_pins_reproduce_exactly_through_the_optimized_loop() {
    let text = std::fs::read_to_string(baseline_path()).expect("BENCH_baseline.json committed");
    let json = Json::parse(&text).expect("valid baseline JSON");
    let nodes = json.get("nodes").and_then(|n| n.as_usize()).expect("nodes");
    let model = TransformerSpec::by_name(
        json.get("model").and_then(|m| m.as_str()).expect("model"),
    )
    .expect("known model");
    let entries = json.get("entries").and_then(|e| e.as_arr()).expect("entries");
    assert!(entries.len() >= 8, "all 8 pins present");

    let cfg = SimConfig::default();
    for e in entries {
        let mname = e.get("machine").and_then(|m| m.as_str()).expect("machine");
        let sname = e.get("scheme").and_then(|s| s.as_str()).expect("scheme");
        let pp = e.get("pp").and_then(|v| v.as_usize()).unwrap_or(1);
        let mb = e.get("microbatches").and_then(|v| v.as_usize()).unwrap_or(0);
        let pin = e.get("step_s").and_then(|s| s.as_f64()).expect("step_s");
        let scheme = Scheme::parse(sname).unwrap_or_else(|| panic!("unknown scheme {sname}"));
        let cluster = Cluster::new(MachineSpec::resolve(mname).expect("machine"), nodes);
        let sched = if pp > 1 {
            let pipe = PipeConfig { stages: pp, microbatches: mb, interleave: 1 };
            simulate_step_pipeline(&model, scheme, &cluster, &cfg, &pipe)
                .expect("pinned pipeline point prices")
                .1
        } else {
            simulate_step_schedule(&model, scheme, &cluster, &cfg).1
        };
        assert_eq!(
            sched.makespan().to_bits(),
            pin.to_bits(),
            "{mname}/{sname} pp{pp} mb{mb}: optimized loop moved the pin \
             ({pin:?} -> {:?})",
            sched.makespan()
        );
        // the pinned world itself must agree across both loops
        let optimized = differential::simulate_both(sched.graph().clone());
        assert_eq!(optimized.makespan().to_bits(), pin.to_bits());
    }
}

/// The threaded scaling sweep returns bitwise the same series as the
/// serial one at any thread count (one pure sim per point, results in
/// node-count order).
#[test]
fn threaded_scaling_series_is_deterministic() {
    let model = TransformerSpec::by_name("20b").unwrap();
    let machine = MachineSpec::resolve("frontier").unwrap();
    let node_counts = [4usize, 8, 12, 16];
    let cfg = SimConfig::default();
    let serial = scaling_series(&model, Scheme::Zero3, &machine, &node_counts, &cfg);
    for threads in [2usize, 4, 16] {
        let par =
            scaling_series_threaded(&model, Scheme::Zero3, &machine, &node_counts, &cfg, threads);
        assert_eq!(serial.len(), par.len());
        for (s, p) in serial.iter().zip(&par) {
            assert_eq!(s.gcds, p.gcds, "threads={threads}");
            assert_eq!(
                s.step_seconds.to_bits(),
                p.step_seconds.to_bits(),
                "threads={threads}"
            );
            assert_eq!(
                s.flops_per_step.to_bits(),
                p.flops_per_step.to_bits(),
                "threads={threads}"
            );
            assert_eq!(
                s.sequences_per_step.to_bits(),
                p.sequences_per_step.to_bits(),
                "threads={threads}"
            );
        }
    }
}

fn run_bin(args: &[&str]) -> Vec<u8> {
    let out = Command::new(env!("CARGO_BIN_EXE_zero-topo"))
        .args(args)
        .output()
        .expect("zero-topo binary runs");
    assert!(
        out.status.success(),
        "zero-topo {args:?} failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    out.stdout
}

/// End-to-end determinism of the CLI sweep drivers: `plan --json` and
/// `scale` must emit byte-identical reports (ranking, tie-breaks,
/// ledgers, rendered tables) at --threads 1 vs N.
#[test]
fn cli_reports_are_byte_identical_across_thread_counts() {
    let plan_args = [
        "plan",
        "--model",
        "20b",
        "--nodes",
        "8",
        "--schemes",
        "zero3,zerotopo",
        "--depths",
        "1,inf",
        "--blocks",
        "1",
        "--pp",
        "1,2",
        "--microbatches",
        "8",
        "--interleave",
        "1",
        "--json",
    ];
    let plan_serial = run_bin(&[&plan_args[..], &["--threads", "1"]].concat());
    for t in ["4", "13"] {
        let plan_par = run_bin(&[&plan_args[..], &["--threads", t]].concat());
        assert_eq!(plan_serial, plan_par, "plan --json diverged at --threads {t}");
    }

    let scale_args =
        ["scale", "--model", "20b", "--nodes", "4,8,12", "--schemes", "zero3,zerotopo"];
    let scale_serial = run_bin(&[&scale_args[..], &["--threads", "1"]].concat());
    let scale_par = run_bin(&[&scale_args[..], &["--threads", "8"]].concat());
    assert_eq!(scale_serial, scale_par, "scale output diverged at --threads 8");
}
