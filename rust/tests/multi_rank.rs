//! Multi-rank determinism gates: a 1-rank `MultiRankPlan` with zero jitter
//! must reproduce the single-rank `StepPlan` makespan bit-for-bit across
//! random (scheme, scale, depth, grad-accum) points, seeded jitter must be
//! reproducible across two simulations, and the acceptance scenario (one
//! rank at 1.2x compute at 20B/384 GCDs) must stretch the makespan and
//! show up in the per-rank attribution.

use zero_topo::comm::cost::{CommEfficiency, CostModel};
use zero_topo::model::TransformerSpec;
use zero_topo::sched::multi::MultiRankPlan;
use zero_topo::sched::plan::StepPlan;
use zero_topo::sched::scenario::{RankCount, Scenario};
use zero_topo::sched::Depth;
use zero_topo::sharding::{Scheme, ShardingSpec};
use zero_topo::sim::{simulate_step, simulate_step_scenario, SimConfig};
use zero_topo::testing::check;
use zero_topo::topology::Cluster;

fn plan_for(scheme: Scheme, nodes: usize, ga: usize, depth: Depth) -> (StepPlan, Cluster) {
    let cluster = Cluster::frontier(nodes);
    let cost = CostModel::with_efficiency(cluster.clone(), CommEfficiency::rccl_frontier());
    let spec = ShardingSpec::resolve(scheme, &cluster).unwrap();
    let plan =
        StepPlan::from_protocol(&cost, scheme, &spec, 2_000_000_000, 256, ga, 3.0, depth);
    (plan, cluster)
}

#[test]
fn one_rank_multirank_reproduces_stepplan_bit_for_bit() {
    let schemes = [
        Scheme::Zero3,
        Scheme::ZeroPP,
        Scheme::ZeroTopo { sec_degree: 2 },
        Scheme::ZeroTopo { sec_degree: 8 },
        Scheme::Zero1,
        Scheme::Mics { group: 8 },
    ];
    let depths = [Depth::Bounded(0), Depth::Bounded(1), Depth::Bounded(3), Depth::Infinite];
    check("1-rank MultiRankPlan == StepPlan", 60, |g| {
        let scheme = *g.pick(&schemes);
        let nodes = g.usize_in(1, 6);
        let ga = g.usize_in(1, 6);
        let depth = *g.pick(&depths);
        let (plan, cluster) = plan_for(scheme, nodes, ga, depth);
        let single = plan.simulate();
        let sc = Scenario { ranks: RankCount::Count(1), ..Default::default() };
        let multi = MultiRankPlan::new(&plan, &cluster, &sc);
        assert_eq!(multi.modeled_ranks(), &[0]);
        let m = multi.simulate();
        // bit-for-bit: same task count, same spans, same makespan
        assert_eq!(single.makespan(), m.makespan(), "{scheme:?} n={nodes} ga={ga} {depth:?}");
        assert_eq!(single.spans().len(), m.spans().len());
        for (a, b) in single.spans().iter().zip(m.spans()) {
            assert_eq!(a.start, b.start, "{scheme:?} n={nodes} ga={ga} {depth:?}");
            assert_eq!(a.end, b.end, "{scheme:?} n={nodes} ga={ga} {depth:?}");
        }
    });
}

#[test]
fn congruent_explicit_ranks_keep_the_makespan() {
    // modeling more congruent ranks never changes the step time: shared
    // collectives + per-instance contention reproduce the calibrated clock
    check("congruent ranks keep makespan", 40, |g| {
        let schemes =
            [Scheme::Zero3, Scheme::ZeroPP, Scheme::ZeroTopo { sec_degree: 2 }];
        let scheme = *g.pick(&schemes);
        let nodes = g.usize_in(1, 4);
        let (plan, cluster) = plan_for(scheme, nodes, 4, Depth::Infinite);
        let single = plan.simulate().makespan();
        let n = g.usize_in(1, cluster.world_size());
        let sc = Scenario { ranks: RankCount::Count(n), ..Default::default() };
        let mk = MultiRankPlan::new(&plan, &cluster, &sc).simulate().makespan();
        assert!(
            (mk - single).abs() <= 1e-12 * single.max(1.0),
            "{scheme:?} nodes={nodes} ranks={n}: {mk} vs {single}"
        );
    });
}

#[test]
fn seeded_jitter_is_reproducible_across_simulations() {
    check("seeded jitter reproducible", 30, |g| {
        let nodes = g.usize_in(2, 6);
        let seed = g.i64_in(0, 1 << 40) as u64;
        let sigma = 0.01 + 0.2 * g.f64_unit();
        let (plan, cluster) =
            plan_for(Scheme::ZeroTopo { sec_degree: 2 }, nodes, 4, Depth::Infinite);
        let sc = Scenario { jitter_sigma: sigma, seed, ..Default::default() };
        let a = MultiRankPlan::new(&plan, &cluster, &sc).simulate();
        let b = MultiRankPlan::new(&plan, &cluster, &sc).simulate();
        assert_eq!(a.makespan(), b.makespan(), "seed={seed} sigma={sigma}");
        assert_eq!(a.spans().len(), b.spans().len());
        for (x, y) in a.spans().iter().zip(b.spans()) {
            assert_eq!(x.start, y.start);
            assert_eq!(x.end, y.end);
        }
        // jitter simulates at one modeled rank per node
        assert_eq!(a.ranks().len(), nodes);
    });
}

#[test]
fn acceptance_straggler_at_20b_384_gcds() {
    // ISSUE acceptance: `--ranks 1` matches the single-rank step within
    // 0.1% while a 1.2x straggler measurably stretches the makespan and
    // shows up in the per-rank stall attribution
    let model = TransformerSpec::neox20b();
    let cfg = SimConfig::default();
    let cluster = Cluster::frontier(48);
    for scheme in [Scheme::Zero3, Scheme::ZeroPP, Scheme::ZeroTopo { sec_degree: 2 }] {
        let base = simulate_step(&model, scheme, &cluster, &cfg);
        let one = Scenario { ranks: RankCount::Count(1), ..Default::default() };
        let (b1, _) = simulate_step_scenario(&model, scheme, &cluster, &cfg, &one);
        assert!(
            (b1.step_s - base.step_s).abs() <= 1e-3 * base.step_s,
            "{scheme:?}: ranks=1 {} vs single {}",
            b1.step_s,
            base.step_s
        );
        let sc = Scenario { stragglers: vec![(5, 1.2)], ..Default::default() };
        let (bs, sched) = simulate_step_scenario(&model, scheme, &cluster, &cfg, &sc);
        assert!(bs.step_s > base.step_s, "{scheme:?}");
        assert_eq!(sched.slowest_rank(), 5, "{scheme:?}");
        let victim = *sched.ranks().iter().find(|&&r| r != 5).unwrap();
        let victim_wait =
            sched.skew_wait(victim) + sched.stall_by_class(victim).values().sum::<f64>();
        let straggler_wait =
            sched.skew_wait(5) + sched.stall_by_class(5).values().sum::<f64>();
        assert!(
            victim_wait > straggler_wait,
            "{scheme:?}: victim {victim_wait} vs straggler {straggler_wait}"
        );
    }
}

#[test]
fn imbalanced_grad_groups_shift_the_critical_path() {
    let (plan, cluster) = plan_for(Scheme::ZeroTopo { sec_degree: 2 }, 2, 4, Depth::Infinite);
    let base = plan.simulate().makespan();
    let sc = Scenario { imbalance: vec![(9, 6)], ..Default::default() };
    let sched = MultiRankPlan::new(&plan, &cluster, &sc).simulate();
    assert!(sched.makespan() > base);
    assert_eq!(sched.slowest_rank(), 9);
    // the slowest chain runs through rank 9's extra microbatches
    let path = sched.critical_path();
    assert!(path.iter().any(|&id| {
        let t = sched.graph().task(id);
        t.rank == 9 && t.label.contains("[5]")
    }));
}
