//! Telemetry must observe without perturbing: every committed
//! `BENCH_baseline.json` step time must reproduce at exactly 0.0 drift
//! when priced through the telemetry-bearing entry points, and the
//! link-utilization view must reconcile with the stall-attribution
//! ledger — a stall charged to a link class can never exceed that
//! class's busy time, which can never exceed the step (ISSUE 6
//! acceptance criteria).

use std::path::PathBuf;

use zero_topo::metrics::telemetry::{StepKind, StepRecord, TelemetryWriter, SCHEMA_VERSION};
use zero_topo::metrics::Throughput;
use zero_topo::model::TransformerSpec;
use zero_topo::sched::pipeline::PipeConfig;
use zero_topo::sched::Schedule;
use zero_topo::sharding::Scheme;
use zero_topo::sim::{
    profile_step, profile_step_pipeline, simulate_step, simulate_step_pipeline,
    simulate_step_telemetry, SimConfig,
};
use zero_topo::topology::{Cluster, MachineSpec};
use zero_topo::util::json::Json;

fn baseline_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../BENCH_baseline.json")
}

/// Absolute slack for reconciliation sums (interval unions accumulate
/// float error; the quantities compared are tens of seconds).
const EPS: f64 = 1e-9;

/// The reconciliation invariant between the utilization report and the
/// stall-attribution ledger, checked on every rank and link class.
fn reconcile(sched: &Schedule, ctx: &str) {
    let busy = sched.class_busy();
    let makespan = sched.makespan();
    for (&class, &b) in &busy {
        assert!(
            b <= makespan + EPS,
            "{ctx}: {class:?} busy {b}s exceeds makespan {makespan}s"
        );
    }
    for rank in sched.ranks() {
        for (class, stall) in sched.stall_by_class(rank) {
            let b = busy.get(&class).copied().unwrap_or(0.0);
            assert!(
                stall <= b + EPS,
                "{ctx}: rank {rank} stall {stall}s on {class:?} exceeds class busy {b}s"
            );
        }
    }
}

#[test]
fn baseline_reproduces_at_zero_drift_with_telemetry() {
    let text = std::fs::read_to_string(baseline_path()).expect("BENCH_baseline.json committed");
    let json = Json::parse(&text).expect("valid baseline JSON");
    let nodes = json.get("nodes").and_then(|n| n.as_usize()).expect("nodes");
    let model = TransformerSpec::by_name(
        json.get("model").and_then(|m| m.as_str()).expect("model"),
    )
    .expect("known model");
    let entries = json.get("entries").and_then(|e| e.as_arr()).expect("entries");
    assert!(entries.len() >= 8, "expected frontier+dgx x 3 schemes + 2 pipeline points");

    let cfg = SimConfig::default();
    let tmp = std::env::temp_dir().join("zero_topo_telemetry_baseline_test.jsonl");
    let mut writer = TelemetryWriter::create(&tmp).expect("temp telemetry file");

    for (i, e) in entries.iter().enumerate() {
        let mname = e.get("machine").and_then(|m| m.as_str()).expect("machine");
        let sname = e.get("scheme").and_then(|s| s.as_str()).expect("scheme");
        let pp = e.get("pp").and_then(|v| v.as_usize()).unwrap_or(1);
        let mb = e.get("microbatches").and_then(|v| v.as_usize()).unwrap_or(0);
        let base = e.get("step_s").and_then(|s| s.as_f64()).expect("step_s");
        let scheme = Scheme::parse(sname).unwrap_or_else(|| panic!("unknown scheme {sname}"));
        let spec = MachineSpec::resolve(mname).expect("known machine");
        let cluster = Cluster::new(spec.clone(), nodes);
        let ctx = format!("{mname}/{sname} pp{pp} mb{mb}");

        // price through the telemetry-bearing path AND the plain path:
        // both must land on the committed pin exactly — telemetry is
        // span-derived after the fact and cannot move the event clock
        let (step_s, sched, rec) = if pp > 1 {
            let pipe = PipeConfig { stages: pp, microbatches: mb, interleave: 1 };
            let plain = simulate_step_pipeline(&model, scheme, &cluster, &cfg, &pipe)
                .expect("pipeline point prices")
                .0
                .step_s;
            let (b, sched, _, prof) =
                profile_step_pipeline(&model, scheme, &cluster, &cfg, &pipe)
                    .expect("pipeline point profiles");
            assert_eq!(b.step_s, plain, "{ctx}: profiling changed the pipeline clock");
            assert_eq!(prof.tasks, sched.spans().len(), "{ctx}: profile task count");
            let point = Throughput {
                gcds: cluster.world_size(),
                step_seconds: b.step_s,
                flops_per_step: 1.0,
                sequences_per_step: 1.0,
            };
            let rec = StepRecord::new(i, StepKind::Pipeline, sname, mname, nodes, &point)
                .with_schedule(&sched, &spec)
                .with_bubble(b.bubble_fraction);
            (b.step_s, sched, rec)
        } else {
            let plain = simulate_step(&model, scheme, &cluster, &cfg).step_s;
            let (b, sched, cost) =
                simulate_step_telemetry(&model, scheme, &cluster, &cfg, None);
            assert_eq!(b.step_s, plain, "{ctx}: telemetry changed the step clock");
            let (pb, psched, prof) = profile_step(&model, scheme, &cluster, &cfg);
            assert_eq!(pb.step_s, plain, "{ctx}: wall-clock profiling moved the clock");
            assert_eq!(prof.tasks, psched.spans().len(), "{ctx}: profile task count");
            let point = Throughput {
                gcds: cluster.world_size(),
                step_seconds: b.step_s,
                flops_per_step: 1.0,
                sequences_per_step: 1.0,
            };
            let rec = StepRecord::new(i, StepKind::Simulate, sname, mname, nodes, &point)
                .with_comm(&cost)
                .with_schedule(&sched, &spec);
            (b.step_s, sched, rec)
        };

        // the hard pin: exactly the committed value, 0.0 drift
        assert_eq!(
            step_s, base,
            "{ctx}: telemetry-path step_s {step_s} != pinned {base} (drift must be 0.0)"
        );

        // busy/stall reconciliation on the real schedule
        reconcile(&sched, &ctx);

        // the serialized record agrees with the schedule it came from
        assert_eq!(rec.step_s, step_s);
        let busy = sched.class_busy();
        for row in &rec.utilization {
            let class = *busy
                .keys()
                .find(|&&c| spec.class_label(c) == row.link)
                .unwrap_or_else(|| panic!("{ctx}: unknown link label {}", row.link));
            let b = busy[&class];
            assert!((row.busy_s - b).abs() <= EPS, "{ctx}: busy mismatch on {}", row.link);
            assert!(row.busy_s <= step_s + EPS, "{ctx}: {} busy exceeds step", row.link);
        }
        for (link, stall) in &rec.stalls {
            let row = rec.utilization.iter().find(|u| &u.link == link);
            if let Some(u) = row {
                assert!(
                    *stall <= u.busy_s + EPS,
                    "{ctx}: serialized stall {stall}s on {link} exceeds busy {}s",
                    u.busy_s
                );
            }
        }
        writer.write_record(&rec).expect("record writes");
    }

    // the JSONL stream round-trips: one self-describing object per line
    writer.flush().expect("flush");
    let text = std::fs::read_to_string(&tmp).expect("telemetry file readable");
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), entries.len());
    for (i, line) in lines.iter().enumerate() {
        let j = Json::parse(line).unwrap_or_else(|e| panic!("line {i} not JSON: {e}"));
        assert_eq!(j.get("schema").and_then(|v| v.as_i64()), Some(SCHEMA_VERSION as i64));
        assert_eq!(j.get("step").and_then(|v| v.as_usize()), Some(i));
        for key in ["kind", "scheme", "machine", "nodes", "step_s", "stall_s", "utilization"] {
            assert!(j.get(key).is_some(), "line {i} missing key {key}");
        }
        let pinned = entries[i].get("step_s").and_then(|v| v.as_f64()).unwrap();
        assert_eq!(
            j.get("step_s").and_then(|v| v.as_f64()),
            Some(pinned),
            "line {i}: step_s must round-trip the pinned value exactly"
        );
    }
    std::fs::remove_file(&tmp).ok();
}

/// Reconciliation must also hold under asymmetric multi-rank scenarios,
/// where stalls and skew interact — not just the collapsed fast path.
#[test]
fn reconciliation_holds_under_stragglers() {
    let model = TransformerSpec::by_name("20b").expect("known model");
    let cluster = Cluster::new(MachineSpec::resolve("frontier").expect("frontier"), 8);
    let cfg = SimConfig::default();
    let scenario = zero_topo::sched::scenario::Scenario {
        stragglers: vec![(3, 1.5)],
        ..Default::default()
    };
    for scheme in [
        Scheme::Zero3,
        Scheme::ZeroPP,
        Scheme::ZeroTopo { sec_degree: 0 },
    ] {
        let (_, sched, _) =
            simulate_step_telemetry(&model, scheme, &cluster, &cfg, Some(&scenario));
        reconcile(&sched, &format!("straggler scenario, {}", scheme.name()));
    }
}
