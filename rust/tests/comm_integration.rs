//! Integration: collectives + sharding + quantization composed the way
//! the engine composes them, on multi-node simulated clusters. No PJRT
//! needed — pure L3.

use zero_topo::comm::{CommWorld, Wire};
use zero_topo::quant;
use zero_topo::sharding::{shard_groups, PartitionMap, Scheme, ShardingSpec};
use zero_topo::testing::check;
use zero_topo::topology::Cluster;
use zero_topo::util::rng::Rng;
use zero_topo::util::stats::{mae, max_abs_err};

fn randv(n: usize, seed: u64) -> Vec<f32> {
    let mut r = Rng::new(seed);
    let mut v = vec![0.0; n];
    r.fill_normal(&mut v, 1.0);
    v
}

/// The paper's full gradient path for ZeRO-topo on a 2-node cluster,
/// assembled by hand: INT4 a2a within each node, fp16 all-reduce across
/// nodes — final result must equal the exact mean within quantization
/// error bounds.
#[test]
fn topo_gradient_path_approximates_exact_mean() {
    let cluster = Cluster::frontier(2);
    let world = cluster.world_size();
    let n = 4096;
    let grads: Vec<Vec<f32>> = (0..world).map(|r| randv(n, 100 + r as u64)).collect();
    let mut exact = vec![0f32; n];
    for g in &grads {
        for (e, &v) in exact.iter_mut().zip(g) {
            *e += v;
        }
    }

    let mut w = CommWorld::new(cluster.clone());
    let p = 8;
    // phase 1: per node
    let mut node_sums = Vec::new();
    for node in 0..2 {
        let group: Vec<usize> = (node * p..(node + 1) * p).collect();
        let contrib: Vec<&[f32]> = group.iter().map(|&r| grads[r].as_slice()).collect();
        node_sums.push(w.reduce_scatter_a2a(&group, &contrib, Wire::Int4 { block: 64 }));
    }
    // phase 2: cross-node all-reduce per local shard
    let mut result = vec![0f32; n];
    let shard = n / p;
    for local in 0..p {
        let group = [local, p + local];
        let contrib = [node_sums[0][local].as_slice(), node_sums[1][local].as_slice()];
        let summed = w.all_reduce(&group, &contrib, Wire::F16);
        result[local * shard..(local + 1) * shard].copy_from_slice(&summed);
    }

    // INT4 error per element is bounded by (ranks-per-node) * scale/2;
    // statistically the MAE stays well below the signal (|sum of 16
    // unit-normal grads| ~ sqrt(2/pi)*4 ≈ 3.2)
    let err = mae(&exact, &result);
    assert!(err < 0.5, "topo grad path MAE {err}");
    let signal = exact.iter().map(|v| v.abs() as f64).sum::<f64>() / n as f64;
    assert!(err / signal < 0.15, "rel err {}", err / signal);
}

#[test]
fn zero3_fp16_path_is_much_more_precise_than_int4() {
    let cluster = Cluster::frontier(1);
    let n = 2048;
    let world = 8;
    let grads: Vec<Vec<f32>> = (0..world).map(|r| randv(n, 7 + r as u64)).collect();
    let views: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
    let group: Vec<usize> = (0..world).collect();
    let mut exact = vec![0f32; n];
    for g in &grads {
        for (e, &v) in exact.iter_mut().zip(g) {
            *e += v;
        }
    }
    let fp16 = CommWorld::new(cluster.clone())
        .reduce_scatter_ring(&group, &views, Wire::F16)
        .concat();
    let int4 = CommWorld::new(cluster)
        .reduce_scatter_a2a(&group, &views, Wire::Int4 { block: 256 })
        .concat();
    assert!(mae(&exact, &fp16) < mae(&exact, &int4) / 5.0);
}

#[test]
fn weight_gather_roundtrip_across_primary_partitions() {
    // shard weights across a GCD pair, gather with INT8 wire, compare
    check("primary partition gather", 25, |g| {
        let n = g.usize_in(1, 20) * 512;
        let w = g.vec_f32_exact(n, 0.05); // weight-scale values
        let pm = PartitionMap::new(n, 2);
        let mut padded = w.clone();
        padded.resize(pm.padded_len(), 0.0);
        let shards: Vec<&[f32]> = (0..2).map(|i| &padded[pm.range(i)]).collect();
        let mut world = CommWorld::new(Cluster::frontier(1));
        let mut gathered = world.all_gather(&[0, 1], &shards, Wire::Int8 { block: 256 });
        gathered.truncate(n);
        let err = max_abs_err(&w, &gathered);
        // int8 contract: error ≤ amax/254 per block (amax of the worst block)
        let amax = w.iter().fold(0f32, |m, v| m.max(v.abs())) as f64;
        assert!(err <= amax / 254.0 * 1.01 + 1e-9, "err {err} amax {amax}");
    });
}

#[test]
fn secondary_partition_quantization_is_stable_across_steps() {
    // re-quantizing an already-quantized secondary partition must be a
    // fixed point (no error drift over repeated steps)
    let w = randv(4096, 42);
    let q1 = quant::roundtrip_int8(&w, 256);
    let q2 = quant::roundtrip_int8(&q1, 256);
    let q3 = quant::roundtrip_int8(&q2, 256);
    assert_eq!(q1, q2);
    assert_eq!(q2, q3);
}

#[test]
fn sharding_specs_compose_with_collectives_on_any_cluster() {
    check("spec/collective composition", 20, |g| {
        let nodes = *g.pick(&[1usize, 2, 3, 6]);
        let cluster = Cluster::frontier(nodes);
        for scheme in [Scheme::Zero3, Scheme::ZeroPP, Scheme::ZeroTopo { sec_degree: 8 }] {
            let spec = ShardingSpec::resolve(scheme, &cluster).unwrap();
            // every group list tiles the world
            for degree in [spec.weights, spec.grads, spec.optim] {
                let groups = shard_groups(spec.world, degree);
                let mut all: Vec<usize> = groups.concat();
                all.sort();
                assert_eq!(all, (0..spec.world).collect::<Vec<_>>());
            }
        }
    });
}

#[test]
fn cost_model_monotone_in_scale_for_world_collectives() {
    // inter-node all-gather of the same payload gets slower as the world
    // grows (group-size penalty + NIC sharing) — the degradation that
    // motivates the paper
    let bytes = 1_000_000_000u64;
    let mut last = 0.0;
    for nodes in [2usize, 8, 24, 48] {
        let cluster = Cluster::frontier(nodes);
        let mut cm = zero_topo::comm::CostModel::with_efficiency(
            cluster.clone(),
            zero_topo::comm::cost::CommEfficiency::rccl_frontier(),
        );
        let group: Vec<usize> = (0..cluster.world_size()).collect();
        let t = cm.all_gather(&group, bytes);
        assert!(t > last, "nodes={nodes}: {t} vs {last}");
        last = t;
    }
}

#[test]
fn all_reduce_wire_dtype_error_ordering() {
    // f32 < f16 < int8 wire error, all bounded
    let world = 4;
    let n = 1024;
    let grads: Vec<Vec<f32>> = (0..world).map(|r| randv(n, 300 + r as u64)).collect();
    let views: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
    let group: Vec<usize> = (0..world).collect();
    let mut exact = vec![0f32; n];
    for g in &grads {
        for (e, &v) in exact.iter_mut().zip(g) {
            *e += v;
        }
    }
    let run = |wire| CommWorld::new(Cluster::frontier(1)).all_reduce(&group, &views, wire);
    let e32 = mae(&exact, &run(Wire::F32));
    let e16 = mae(&exact, &run(Wire::F16));
    let e8 = mae(&exact, &run(Wire::Int8 { block: 256 }));
    assert!(e32 <= e16 && e16 < e8, "{e32} {e16} {e8}");
    assert!(e8 < 0.3);
}
