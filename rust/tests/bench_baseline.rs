//! Perf guardrail twin of `zero-topo calibrate --check`: the committed
//! `BENCH_baseline.json` (20B @ 48 nodes, frontier + dgx builtins, plus
//! the pinned P=4 pipeline points) must stay within its tolerance of
//! what the simulator computes today, so a refactor cannot silently move
//! the calibrated Fig 7 numbers or the pipeline step times.

use std::path::PathBuf;

use zero_topo::model::TransformerSpec;
use zero_topo::sched::pipeline::PipeConfig;
use zero_topo::sharding::Scheme;
use zero_topo::sim::goodput::{checkpoint_cost, goodput, optimal_interval};
use zero_topo::sim::{simulate_step, simulate_step_pipeline, SimConfig};
use zero_topo::topology::{Cluster, MachineSpec};
use zero_topo::util::json::Json;

fn baseline_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../BENCH_baseline.json")
}

#[test]
fn committed_baseline_matches_simulator() {
    let text = std::fs::read_to_string(baseline_path()).expect("BENCH_baseline.json committed");
    let json = Json::parse(&text).expect("valid baseline JSON");
    let nodes = json.get("nodes").and_then(|n| n.as_usize()).expect("nodes");
    let tol = json.get("tolerance").and_then(|t| t.as_f64()).expect("tolerance");
    let model = TransformerSpec::by_name(
        json.get("model").and_then(|m| m.as_str()).expect("model"),
    )
    .expect("known model");
    let entries = json.get("entries").and_then(|e| e.as_arr()).expect("entries");
    assert!(entries.len() >= 8, "expected frontier+dgx x 3 schemes + 2 pipeline points");

    let cfg = SimConfig::default();
    let mut pipeline_entries = 0usize;
    for e in entries {
        let mname = e.get("machine").and_then(|m| m.as_str()).expect("machine");
        let sname = e.get("scheme").and_then(|s| s.as_str()).expect("scheme");
        let pp = e.get("pp").and_then(|v| v.as_usize()).unwrap_or(1);
        let mb = e.get("microbatches").and_then(|v| v.as_usize()).unwrap_or(0);
        let base = e.get("step_s").and_then(|s| s.as_f64()).expect("step_s");
        let scheme = Scheme::parse(sname).unwrap_or_else(|| panic!("unknown scheme {sname}"));
        let spec = MachineSpec::resolve(mname).expect("known machine");
        let cluster = Cluster::new(spec, nodes);
        let step_s = if pp > 1 {
            pipeline_entries += 1;
            let pipe = PipeConfig { stages: pp, microbatches: mb, interleave: 1 };
            simulate_step_pipeline(&model, scheme, &cluster, &cfg, &pipe)
                .expect("pipeline point prices")
                .0
                .step_s
        } else {
            simulate_step(&model, scheme, &cluster, &cfg).step_s
        };
        let drift = (step_s - base) / base;
        assert!(
            drift.abs() <= tol,
            "{mname}/{sname} pp{pp} mb{mb}: {base}s -> {step_s}s ({:+.3}% > {:.1}%) — \
             if intentional, regenerate with `cargo run -- calibrate --write`",
            drift * 100.0,
            tol * 100.0
        );
        // goodput pin (ISSUE 10): the DP entries also record net tokens/s
        // at the Young/Daly optimal interval under the default 6h MTBF —
        // gated with the same tolerance as step_s
        if let Some(gbase) = e.get("goodput_tokens_per_s").and_then(|v| v.as_f64()) {
            assert_eq!(pp, 1, "goodput pins cover the data-parallel entries");
            let cluster = Cluster::new(MachineSpec::resolve(mname).unwrap(), nodes);
            let b = simulate_step(&model, scheme, &cluster, &cfg);
            let ck = checkpoint_cost(&model, scheme, &cluster, &cfg).expect("ckpt prices");
            let mtbf = 21_600.0;
            let tau = optimal_interval(mtbf, &ck).expect("tau* exists");
            let tokens =
                (b.grad_accum * cfg.micro_batch * model.seq * cluster.world_size()) as f64;
            let g = goodput(b.step_s, tokens, &ck, mtbf, tau).expect("goodput prices");
            let gdrift = (g.goodput_tokens_per_s - gbase) / gbase;
            assert!(
                gdrift.abs() <= tol,
                "{mname}/{sname} goodput: {gbase} -> {} tok/s ({:+.3}% > {:.1}%)",
                g.goodput_tokens_per_s,
                gdrift * 100.0,
                tol * 100.0
            );
        }
    }
    assert_eq!(pipeline_entries, 2, "the two pinned P=4 pipeline points must be present");
}
