//! Perf guardrail twin of `zero-topo calibrate --check`: the committed
//! `BENCH_baseline.json` (20B @ 48 nodes, frontier + dgx builtins) must
//! stay within its tolerance of what the simulator computes today, so a
//! refactor cannot silently move the calibrated Fig 7 numbers.

use std::path::PathBuf;

use zero_topo::model::TransformerSpec;
use zero_topo::sharding::Scheme;
use zero_topo::sim::{simulate_step, SimConfig};
use zero_topo::topology::{Cluster, MachineSpec};
use zero_topo::util::json::Json;

fn baseline_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../BENCH_baseline.json")
}

#[test]
fn committed_baseline_matches_simulator() {
    let text = std::fs::read_to_string(baseline_path()).expect("BENCH_baseline.json committed");
    let json = Json::parse(&text).expect("valid baseline JSON");
    let nodes = json.get("nodes").and_then(|n| n.as_usize()).expect("nodes");
    let tol = json.get("tolerance").and_then(|t| t.as_f64()).expect("tolerance");
    let model = TransformerSpec::by_name(
        json.get("model").and_then(|m| m.as_str()).expect("model"),
    )
    .expect("known model");
    let entries = json.get("entries").and_then(|e| e.as_arr()).expect("entries");
    assert!(entries.len() >= 6, "expected frontier+dgx x 3 schemes");

    let cfg = SimConfig::default();
    for e in entries {
        let mname = e.get("machine").and_then(|m| m.as_str()).expect("machine");
        let sname = e.get("scheme").and_then(|s| s.as_str()).expect("scheme");
        let base = e.get("step_s").and_then(|s| s.as_f64()).expect("step_s");
        let scheme = Scheme::parse(sname).unwrap_or_else(|| panic!("unknown scheme {sname}"));
        let spec = MachineSpec::resolve(mname).expect("known machine");
        let b = simulate_step(&model, scheme, &Cluster::new(spec, nodes), &cfg);
        let drift = (b.step_s - base) / base;
        assert!(
            drift.abs() <= tol,
            "{mname}/{sname}: {base}s -> {}s ({:+.3}% > {:.1}%) — \
             if intentional, regenerate with `cargo run -- calibrate --write`",
            b.step_s,
            drift * 100.0,
            tol * 100.0
        );
    }
}
