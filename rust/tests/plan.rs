//! Integration tests for the feasibility-aware auto-planner
//! (DESIGN.md §15): the schedule-aware memory ledger (`fit_report`)
//! must be monotone in the obvious directions, the `plan_search` winner
//! must be feasible and beat every hand-picked pinned baseline that
//! fits, and the ledger must degenerate to the static Tables V/VI
//! accounting when the schedule terms are trivial.

use zero_topo::memory::{fit_report, FitConfig, MemoryModel};
use zero_topo::model::TransformerSpec;
use zero_topo::sched::pipeline::PipeConfig;
use zero_topo::sched::Depth;
use zero_topo::sharding::{Scheme, ShardingSpec};
use zero_topo::sim::plan::{plan_search, PlanSpace};
use zero_topo::sim::{simulate_step, simulate_step_pipeline, SimConfig};
use zero_topo::topology::{Cluster, MachineSpec};

const GIB: f64 = 1024.0 * 1024.0 * 1024.0;

fn schemes() -> Vec<Scheme> {
    vec![Scheme::Zero3, Scheme::ZeroPP, Scheme::ZeroTopo { sec_degree: 2 }]
}

/// The trimmed sweep used by the 20B acceptance tests: covers every
/// pinned BENCH_baseline.json shape (monolithic ∞-depth DP, pp4 mb8,
/// pp4 mb32) plus the layered bounded-depth DP points that make
/// ZeRO-topo feasible.
fn acceptance_space(model: &TransformerSpec) -> PlanSpace {
    PlanSpace {
        schemes: schemes(),
        depths: vec![Depth::Bounded(2), Depth::Infinite],
        blocks: vec![1, model.n_layers.max(1)],
        stages: vec![1, 4],
        microbatches: vec![0, 8, 32],
        interleaves: vec![1],
    }
}

/// Growing the HBM budget can only grow the feasible set: every point
/// that fits on stock Frontier must still fit (with identical ledger
/// bytes) on a Frontier with twice the HBM per GCD.
#[test]
fn more_hbm_never_shrinks_the_feasible_set() {
    let model = TransformerSpec::by_name("20b").unwrap();
    let small = MachineSpec::resolve("frontier").unwrap();
    let mut big = small.clone();
    big.hbm_per_worker *= 2.0;
    let small = Cluster::new(small, 48);
    let big = Cluster::new(big, 48);

    let mut feasible_small = 0usize;
    let mut feasible_big = 0usize;
    for scheme in schemes() {
        for &stages in &[1usize, 4] {
            for &depth in &[Depth::Bounded(1), Depth::Bounded(2), Depth::Infinite] {
                for &blocks in &[1usize, 44] {
                    let cfg = FitConfig {
                        prefetch_depth: depth,
                        layer_blocks: blocks,
                        stages,
                        microbatches: 8,
                        ..FitConfig::default()
                    };
                    let a = fit_report(&model, scheme, &small, &cfg).unwrap();
                    let b = fit_report(&model, scheme, &big, &cfg).unwrap();
                    // the ledger is budget-independent; only the verdict moves
                    assert!((a.total() - b.total()).abs() < 1e-6);
                    if a.fits() {
                        assert!(b.fits(), "{} fits 64G but not 128G?!", scheme.name());
                    }
                    feasible_small += a.fits() as usize;
                    feasible_big += b.fits() as usize;
                }
            }
        }
    }
    assert!(feasible_big >= feasible_small);
    // 2x HBM must actually unlock something on 20B (the monolithic
    // ZeRO-topo window, for one)
    assert!(feasible_big > feasible_small);
}

/// A deeper prefetch window can only grow the gather-window term — and
/// with it the ledger total. Monotone non-decreasing in depth.
#[test]
fn deeper_window_never_shrinks_the_ledger() {
    let model = TransformerSpec::by_name("20b").unwrap();
    let cluster = Cluster::frontier(48);
    for scheme in schemes() {
        let mut prev = 0.0f64;
        for d in 0..=44usize {
            let cfg = FitConfig {
                prefetch_depth: Depth::Bounded(d),
                layer_blocks: 44,
                ..FitConfig::default()
            };
            let fit = fit_report(&model, scheme, &cluster, &cfg).unwrap();
            assert!(
                fit.total() >= prev - 1e-9,
                "{} depth {d} shrank the ledger",
                scheme.name()
            );
            prev = fit.total();
        }
        // Bounded(>= blocks-1) saturates at the Infinite-depth ledger
        let inf = FitConfig {
            prefetch_depth: Depth::Infinite,
            layer_blocks: 44,
            ..FitConfig::default()
        };
        let inf = fit_report(&model, scheme, &cluster, &inf).unwrap();
        assert!((inf.total() - prev).abs() < 1e-6);
    }
}

/// The winner of a small exhaustive grid is feasible, is ranked first,
/// and re-simulating it independently reproduces its quoted step time
/// bit-for-bit (the CI smoke gate relies on this).
#[test]
fn winner_is_feasible_and_re_simulates_exactly() {
    let model = TransformerSpec::by_name("125m").unwrap();
    let cluster = Cluster::frontier(2);
    let cfg = SimConfig { global_batch_tokens: (1u64 << 15) as f64, ..SimConfig::default() };
    let space = PlanSpace {
        schemes: schemes(),
        depths: vec![Depth::Bounded(1), Depth::Infinite],
        blocks: vec![1, 12],
        stages: vec![1, 2],
        microbatches: vec![0, 4],
        interleaves: vec![1, 2],
    };
    let out = plan_search(&model, &cluster, &cfg, &space);
    let w = out.winner().expect("125m fits a 2-node frontier");
    assert!(w.fit.fits());
    for p in &out.ranked {
        assert!(p.tflops_per_gcd <= w.tflops_per_gcd + 1e-12);
    }
    // independent re-simulation of the winner: 0.0 drift
    let mut re_cfg = cfg.clone();
    re_cfg.prefetch_depth = w.depth;
    re_cfg.layer_blocks = if w.stages == 1 { w.blocks } else { 1 };
    let step_s = if w.stages == 1 {
        simulate_step(&model, w.scheme, &cluster, &re_cfg).step_s
    } else {
        let pipe = PipeConfig {
            stages: w.stages,
            microbatches: w.microbatches,
            interleave: w.interleave,
        };
        simulate_step_pipeline(&model, w.scheme, &cluster, &re_cfg, &pipe).unwrap().0.step_s
    };
    assert_eq!(step_s, w.step_s, "winner must re-simulate bit-for-bit");
}

/// The 20B @ 48-node Frontier acceptance claim (ISSUE 8): the planner's
/// winner is at least as fast (token-normalized) as every hand-picked
/// pinned BENCH_baseline.json configuration **that fits** the
/// schedule-aware ledger — and the one pinned config that does *not*
/// fit (monolithic free-running ZeRO-topo DP) is provably over budget.
#[test]
fn planner_beats_every_fitting_pinned_baseline_20b_frontier() {
    let model = TransformerSpec::by_name("20b").unwrap();
    let cluster = Cluster::frontier(48);
    let world = cluster.world_size() as f64;
    let cfg = SimConfig::default();
    let out = plan_search(&model, &cluster, &cfg, &acceptance_space(&model));
    let w = out.winner().expect("something must fit 20B on 384 GCDs");
    assert!(w.fit.fits());
    // the winner restores the paper's ZeRO-topo operating point under the
    // ledger: layer-granular gathers with a depth-2 window make the DP
    // schedule fit (≈38 GiB high-water) at full DP throughput, where the
    // monolithic free-running pin (pruned below) would not
    assert_eq!(w.scheme, Scheme::ZeroTopo { sec_degree: 2 });
    assert_eq!(w.stages, 1);
    assert_eq!(w.blocks, 44);
    assert_eq!(w.depth, Depth::Bounded(2));
    assert!(w.step_s > 12.0 && w.step_s < 14.0, "winner step {}", w.step_s);

    // the pinned DP entries: monolithic, free-running prefetch
    for scheme in schemes() {
        let fit =
            fit_report(&model, scheme, &cluster, &FitConfig::default()).unwrap();
        if !fit.fits() {
            // documented planner-vs-paper disagreement: the monolithic
            // ZeRO-topo DP pin keeps the full fp16 model live on top of
            // its secondary copy — over budget on a 64 GB MI250X GCD
            assert_eq!(scheme, Scheme::ZeroTopo { sec_degree: 2 });
            assert!(fit.overage() > 10.0 * GIB);
            continue;
        }
        let b = simulate_step(&model, scheme, &cluster, &cfg);
        let tokens = b.grad_accum as f64 * model.seq as f64 * world;
        let tflops = model.flops_per_token() * tokens / b.step_s / world / 1e12;
        assert!(
            w.tflops_per_gcd >= tflops - 1e-9,
            "winner ({:.2}) slower than pinned {} DP ({:.2})",
            w.tflops_per_gcd,
            scheme.name(),
            tflops
        );
    }

    // the pinned pipeline entries: ZeRO-topo pp4, mb 8 and 32
    for mb in [8usize, 32] {
        let scheme = Scheme::ZeroTopo { sec_degree: 2 };
        let fit_cfg = FitConfig { stages: 4, microbatches: mb, ..FitConfig::default() };
        let fit = fit_report(&model, scheme, &cluster, &fit_cfg).unwrap();
        assert!(fit.fits(), "pinned pp4 mb{mb} should fit");
        let pipe = PipeConfig { stages: 4, microbatches: mb, interleave: 1 };
        let b = simulate_step_pipeline(&model, scheme, &cluster, &cfg, &pipe).unwrap().0;
        let tokens = mb as f64 * model.seq as f64 * (world / 4.0);
        let tflops = model.flops_per_token() * tokens / b.step_s / world / 1e12;
        assert!(
            w.tflops_per_gcd >= tflops - 1e-9,
            "winner ({:.2}) slower than pinned pp4 mb{mb} ({:.2})",
            w.tflops_per_gcd,
            tflops
        );
    }

    // every pruned point is provably over budget, per its own ledger
    for p in &out.pruned {
        assert!(p.fit.overage() > 0.0);
        assert!(p.fit.total() > p.fit.hbm);
    }
}

/// With trivial schedule terms (P = 1, one block, depth ∞) the ledger's
/// state bytes are exactly the static Tables V/VI accounting, the
/// window is the full fp16 model, and activations are one microbatch
/// through every layer.
#[test]
fn fit_report_degenerates_to_static_accounting() {
    let model = TransformerSpec::by_name("20b").unwrap();
    let cluster = Cluster::frontier(48);
    let psi = model.n_params() as f64;
    for scheme in schemes() {
        let fit =
            fit_report(&model, scheme, &cluster, &FitConfig::default()).unwrap();
        let mm = MemoryModel::new(scheme, ShardingSpec::resolve(scheme, &cluster).unwrap());
        let st = mm.per_device(psi);
        assert!((fit.weights - st.weights).abs() < 1e-6);
        assert!((fit.secondary - st.secondary).abs() < 1e-6);
        assert!((fit.grads - st.grads).abs() < 1e-6);
        assert!((fit.optim - st.optim).abs() < 1e-6);
        assert!((fit.state_bytes() - st.total()).abs() < 1e-6);
        // monolithic free-running window: the whole fp16 model, live
        assert!((fit.gather_window - 2.0 * psi).abs() < 1e-6);
        let act = model.n_layers as f64 * model.activation_bytes(1) as f64;
        assert!((fit.activations - act).abs() < 1e-6);
    }
}
