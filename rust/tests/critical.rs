//! Bottleneck-attribution engine guarantees (DESIGN.md §14):
//!
//! 1. **Conservation**: the critical-path ledger (`sched::critical`)
//!    tiles the makespan — `compute + idle + Σ comm == makespan` to
//!    1e-12 absolute — on every schedule the simulator can produce
//!    (random scheme x machine x depth x blocks x multi-rank x pipeline
//!    graphs), and on every pinned `BENCH_baseline.json` entry.
//! 2. **Shadow-price sanity**: a pure bandwidth (or compute) increase
//!    can never slow the modeled step, so those savings are >= 0.
//! 3. **The paper's attribution story** (Fig 7 at 20B / 384 GCDs on
//!    frontier): ZeRO-3 is priced inter-node-bound — doubling B_inter
//!    tops the table and the path is comm-dominated — while ZeRO-topo
//!    is compute-bound: peak compute tops its table and B_inter drops
//!    out of first place.

use std::path::PathBuf;

use zero_topo::metrics::sensitivity::{Knob, DEFAULT_EPSILON};
use zero_topo::model::TransformerSpec;
use zero_topo::sched::critical::{decompose, Category};
use zero_topo::sched::pipeline::PipeConfig;
use zero_topo::sched::scenario::{RankCount, Scenario};
use zero_topo::sched::Depth;
use zero_topo::sharding::Scheme;
use zero_topo::sim::{
    shadow_prices, simulate_step_pipeline, simulate_step_scenario, simulate_step_schedule,
    SimConfig,
};
use zero_topo::testing::check;
use zero_topo::topology::{Cluster, LinkClass, MachineSpec};
use zero_topo::util::json::Json;

const CONSERVATION_BUDGET: f64 = 1e-12;

#[test]
fn ledger_conserves_on_random_simulator_graphs() {
    let machines = ["frontier", "dgx", "aurora"];
    let schemes = [
        Scheme::Zero3,
        Scheme::ZeroPP,
        Scheme::ZeroTopo { sec_degree: 0 },
    ];
    let depths = [Depth::Infinite, Depth::Bounded(0), Depth::Bounded(2)];
    let model = TransformerSpec::by_name("125m").expect("125m model");
    check("critical-path ledger conserves", 48, |g| {
        let spec = MachineSpec::resolve(g.pick(&machines)).unwrap();
        let scheme = *g.pick(&schemes);
        let mut cfg = SimConfig::default();
        cfg.prefetch_depth = *g.pick(&depths);
        let sched = match g.usize_in(0, 2) {
            // pipeline graphs: 1F1B and interleaved
            0 => {
                let cluster = Cluster::new(spec, 4);
                let stages = *g.pick(&[2usize, 4]);
                let pipe = PipeConfig {
                    stages,
                    // a multiple of stages keeps interleave=2 legal
                    microbatches: stages * g.usize_in(1, 3),
                    interleave: *g.pick(&[1usize, 2]),
                };
                simulate_step_pipeline(&model, scheme, &cluster, &cfg, &pipe)
                    .expect("pipeline simulates")
                    .1
            }
            // multi-rank graphs: stragglers + jitter break congruence
            1 => {
                let cluster = Cluster::new(spec, g.usize_in(1, 3));
                let scenario = Scenario {
                    ranks: RankCount::Count(g.usize_in(2, 6)),
                    stragglers: vec![(1, 1.0 + g.f64_unit())],
                    jitter_sigma: 0.1 * g.f64_unit(),
                    seed: g.case as u64,
                    ..Default::default()
                };
                simulate_step_scenario(&model, scheme, &cluster, &cfg, &scenario).1
            }
            // plain single-rank graphs, optionally layer-granular
            _ => {
                cfg.layer_blocks = *g.pick(&[1usize, 2, 4]);
                let cluster = Cluster::new(spec, g.usize_in(1, 4));
                simulate_step_schedule(&model, scheme, &cluster, &cfg).1
            }
        };
        let d = decompose(&sched);
        assert!(
            d.conservation_error() <= CONSERVATION_BUDGET,
            "conservation error {:.3e} (makespan {})",
            d.conservation_error(),
            d.makespan()
        );
        assert_eq!(d.makespan(), sched.makespan());
        assert!(d.compute_s() >= 0.0 && d.idle_s() >= 0.0);
        assert!(d.comm_s().values().all(|&v| v >= 0.0));
    });
}

#[test]
fn bandwidth_and_compute_shadow_prices_are_nonnegative() {
    let model = TransformerSpec::by_name("125m").expect("125m model");
    let cfg = SimConfig::default();
    for mname in ["frontier", "dgx"] {
        let cluster = Cluster::new(MachineSpec::resolve(mname).unwrap(), 2);
        for scheme in [Scheme::Zero3, Scheme::ZeroPP, Scheme::ZeroTopo { sec_degree: 0 }] {
            let report =
                shadow_prices(&model, scheme, &cluster, &cfg, None, DEFAULT_EPSILON).unwrap();
            assert!(!report.prices.is_empty());
            for p in &report.prices {
                if matches!(p.knob, Knob::LinkBandwidth(_) | Knob::ComputeRate) {
                    assert!(
                        p.saving >= -1e-9,
                        "{mname}/{scheme:?}: {} priced negative ({})",
                        p.label,
                        p.saving
                    );
                }
            }
        }
    }
}

/// The acceptance pin: at 20B / 48 nodes (384 GCDs) on frontier, the
/// engine attributes ZeRO-3 to the inter-node link and ZeRO-topo to
/// compute — the paper's Fig 7 claim as a machine-checked fact.
#[test]
fn frontier_20b_attribution_story() {
    let model = TransformerSpec::by_name("20b").expect("20b model");
    let cfg = SimConfig::default();
    let cluster = Cluster::new(MachineSpec::resolve("frontier").unwrap(), 48);
    let inter_bw = |k: &Knob| matches!(k, Knob::LinkBandwidth(LinkClass::InterNode));
    let inter_any = |k: &Knob| {
        matches!(
            k,
            Knob::LinkBandwidth(LinkClass::InterNode) | Knob::LinkLatency(LinkClass::InterNode)
        )
    };

    // ZeRO-3: inter-node bound — B_inter tops the shadow prices and the
    // critical path is dominated by inter-node comm
    let z3 = shadow_prices(&model, Scheme::Zero3, &cluster, &cfg, None, DEFAULT_EPSILON).unwrap();
    assert_eq!(z3.rank_of(inter_bw), Some(0), "ZeRO-3 must rank BW B_inter first");
    let top = z3.top().unwrap();
    assert!(top.saving > 0.0 && top.derivative.unwrap() > 0.0);
    let (_, sched) = simulate_step_schedule(&model, Scheme::Zero3, &cluster, &cfg);
    let d3 = decompose(&sched);
    assert_eq!(d3.dominant(), Category::Comm(LinkClass::InterNode));
    assert!(d3.conservation_error() <= CONSERVATION_BUDGET);

    // ZeRO-topo: compute bound — peak compute tops the table, no
    // inter-node knob is first, and the path is compute-dominated
    let scheme = Scheme::ZeroTopo { sec_degree: 0 };
    let zt = shadow_prices(&model, scheme, &cluster, &cfg, None, DEFAULT_EPSILON).unwrap();
    assert_eq!(zt.top().unwrap().knob, Knob::ComputeRate, "ZeRO-topo must be compute-bound");
    assert!(!inter_any(&zt.top().unwrap().knob));
    assert!(zt.rank_of(inter_bw).unwrap() > 0, "B_inter must NOT rank first for ZeRO-topo");
    let (_, sched) = simulate_step_schedule(&model, scheme, &cluster, &cfg);
    let dt = decompose(&sched);
    assert_eq!(dt.dominant(), Category::Compute);
    assert!(dt.conservation_error() <= CONSERVATION_BUDGET);

    // the ranking key is consistent: rows sorted by descending saving
    for r in [&z3, &zt] {
        assert!(r.prices.windows(2).all(|w| w[0].saving >= w[1].saving));
    }
}

#[test]
fn committed_baseline_entries_all_conserve() {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../BENCH_baseline.json");
    let text = std::fs::read_to_string(path).expect("BENCH_baseline.json committed");
    let json = Json::parse(&text).expect("valid baseline JSON");
    let nodes = json.get("nodes").and_then(|n| n.as_usize()).expect("nodes");
    let model = TransformerSpec::by_name(
        json.get("model").and_then(|m| m.as_str()).expect("model"),
    )
    .expect("known model");
    let entries = json.get("entries").and_then(|e| e.as_arr()).expect("entries");
    assert!(entries.len() >= 8, "expected the 8 pinned entries");
    let cfg = SimConfig::default();
    for e in entries {
        let mname = e.get("machine").and_then(|m| m.as_str()).expect("machine");
        let sname = e.get("scheme").and_then(|s| s.as_str()).expect("scheme");
        let pp = e.get("pp").and_then(|v| v.as_usize()).unwrap_or(1);
        let mb = e.get("microbatches").and_then(|v| v.as_usize()).unwrap_or(0);
        let scheme = Scheme::parse(sname).unwrap_or_else(|| panic!("unknown scheme {sname}"));
        let cluster = Cluster::new(MachineSpec::resolve(mname).unwrap(), nodes);
        let sched = if pp > 1 {
            let pipe = PipeConfig { stages: pp, microbatches: mb, interleave: 1 };
            simulate_step_pipeline(&model, scheme, &cluster, &cfg, &pipe)
                .expect("pipeline simulates")
                .1
        } else {
            simulate_step_schedule(&model, scheme, &cluster, &cfg).1
        };
        let d = decompose(&sched);
        assert!(
            d.conservation_error() <= CONSERVATION_BUDGET,
            "{mname}/{sname} pp{pp} mb{mb}: conservation error {:.3e}",
            d.conservation_error()
        );
        // the ledger's makespan is the pinned step time's schedule
        assert_eq!(d.makespan(), sched.makespan());
    }
}
