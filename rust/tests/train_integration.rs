//! Integration: the full training engine over the PJRT runtime, on the
//! `tiny` artifact. Exercises every layer at once: manifest → HLO compile
//! → init → sharded training with quantized collectives → AdamW.
//!
//! Requires `make artifacts`.

use zero_topo::config::RunConfig;
use zero_topo::engine::TrainEngine;
use zero_topo::runtime::{ModelRunner, Runtime};
use zero_topo::sharding::Scheme;

struct Ctx {
    _rt: Runtime,
    tiny: ModelRunner,
}

// PjRtClient is Rc-based (not Send): per-thread context.
thread_local! {
    static CTX: Ctx = {
        let rt = Runtime::load("artifacts").expect("run `make artifacts` first");
        let tiny = rt.model("tiny").expect("tiny artifact");
        Ctx { _rt: rt, tiny }
    };
}

fn cfg(scheme: Scheme, steps: usize, seed: u64) -> RunConfig {
    RunConfig { model: "tiny".into(), scheme, nodes: 1, steps, seed, ..Default::default() }
}

#[test]
fn init_params_deterministic_and_sized() {
    CTX.with(|ctx| {
    let a = ctx.tiny.init_params(5).unwrap();
    let b = ctx.tiny.init_params(5).unwrap();
    let c = ctx.tiny.init_params(6).unwrap();
    assert_eq!(a.len(), ctx.tiny.manifest.n_params);
    assert_eq!(a, b);
    assert_ne!(a, c);
    assert!(a.iter().all(|v| v.is_finite()));
    });
}

#[test]
fn train_step_shapes_and_finiteness() {
    CTX.with(|ctx| {
    let m = &ctx.tiny.manifest;
    let flat = ctx.tiny.init_params(1).unwrap();
    let tokens = vec![3i32; m.mbs * m.seq];
    let (loss, grads) = ctx.tiny.train_step(&flat, &tokens, &tokens).unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    assert_eq!(grads.len(), m.n_params);
    assert!(grads.iter().all(|g| g.is_finite()));
    // eval on the same batch gives the same loss as fwd of train_step
    let eval = ctx.tiny.eval_loss(&flat, &tokens, &tokens).unwrap();
    assert!((eval - loss).abs() < 1e-4, "{eval} vs {loss}");
    });
}

#[test]
fn loss_decreases_under_all_schemes() {
    CTX.with(|ctx| {
    for scheme in [Scheme::Zero3, Scheme::ZeroPP, Scheme::ZeroTopo { sec_degree: 2 }] {
        let mut e = TrainEngine::new(cfg(scheme, 8, 42), &ctx.tiny).unwrap();
        let mut losses = Vec::new();
        for _ in 0..8 {
            losses.push(e.step().unwrap());
        }
        assert!(
            losses.last().unwrap() < losses.first().unwrap(),
            "{}: {losses:?}",
            scheme.name()
        );
    }
    });
}

#[test]
fn schemes_agree_at_step_one_and_stay_close() {
    // identical data + init: the only difference is the wire format, so
    // step-1 losses must be nearly identical and curves must stay close —
    // the paper's Fig 9/10 claim in miniature.
    CTX.with(|ctx| {
    let mut z3 = TrainEngine::new(cfg(Scheme::Zero3, 6, 7), &ctx.tiny).unwrap();
    let mut topo =
        TrainEngine::new(cfg(Scheme::ZeroTopo { sec_degree: 2 }, 6, 7), &ctx.tiny).unwrap();
    let mut l3 = Vec::new();
    let mut lt = Vec::new();
    for _ in 0..6 {
        l3.push(z3.step().unwrap());
        lt.push(topo.step().unwrap());
    }
    assert!((l3[0] - lt[0]).abs() / l3[0] < 0.01, "step1: {} vs {}", l3[0], lt[0]);
    let rel = (l3.last().unwrap() - lt.last().unwrap()).abs() / l3.last().unwrap();
    assert!(rel < 0.05, "curves diverged: {l3:?} vs {lt:?}");
    });
}

#[test]
fn training_is_deterministic() {
    CTX.with(|ctx| {
    let run = || {
        let mut e =
            TrainEngine::new(cfg(Scheme::ZeroTopo { sec_degree: 2 }, 3, 99), &ctx.tiny).unwrap();
        let mut l = Vec::new();
        for _ in 0..3 {
            l.push(e.step().unwrap());
        }
        l
    };
    assert_eq!(run(), run());
    });
}

#[test]
fn ledger_matches_scheme_topology() {
    use zero_topo::comm::Coll;
    use zero_topo::topology::LinkClass;
    CTX.with(|ctx| {
    // ZeRO-topo on one node: weight gathers on the GCD pair, NO inter-node
    let mut topo =
        TrainEngine::new(cfg(Scheme::ZeroTopo { sec_degree: 2 }, 2, 1), &ctx.tiny).unwrap();
    topo.step().unwrap();
    assert_eq!(topo.comm.cost.inter_node_bytes(), 0);
    let pair = topo.comm.cost.entry(Coll::AllGather, LinkClass::Intra(0));
    assert!(pair.calls > 0 && pair.wire_bytes > 0);
    let a2a = topo.comm.cost.entry(Coll::AllToAll, LinkClass::Intra(2));
    assert!(a2a.calls > 0, "grad sync must run intra-node a2a");
    // ZeRO-3's gathers span the whole node (IntraCross bottleneck)
    let mut z3 = TrainEngine::new(cfg(Scheme::Zero3, 2, 1), &ctx.tiny).unwrap();
    z3.step().unwrap();
    let z3g = z3.comm.cost.entry(Coll::AllGather, LinkClass::Intra(2));
    assert!(z3g.calls > 0);
    // The paper's claim is about LATENCY, not aggregate bytes: topo's
    // per-gather time (2 GCDs @ 200 GB/s, INT8) must beat ZeRO-3's
    // (8 GCDs @ 50 GB/s bottleneck, fp16).
    let topo_per_call = pair.seconds / pair.calls as f64;
    let z3_per_call = z3g.seconds / z3g.calls as f64;
    assert!(
        topo_per_call < z3_per_call / 4.0,
        "topo {topo_per_call:.3e}s vs z3 {z3_per_call:.3e}s per gather"
    );
    });
}

#[test]
fn multi_node_topo_keeps_weight_traffic_on_node() {
    use zero_topo::comm::Coll;
    use zero_topo::topology::LinkClass;
    CTX.with(|ctx| {
    // grad_accum=4 exposes the paper's advantage: ZeRO-3 pays inter-node
    // weight gathers per MICROBATCH while topo's inter-node traffic
    // (update gather + cross-node grad all-reduce) is per-STEP.
    let mut c = cfg(Scheme::ZeroTopo { sec_degree: 2 }, 1, 3);
    c.nodes = 2; // 16 simulated GCDs
    c.grad_accum = 4;
    let mut e = TrainEngine::new(c, &ctx.tiny).unwrap();
    e.step().unwrap();
    // the quantized gradient all-to-all never crosses nodes
    let inter_a2a = e.comm.cost.entry(Coll::AllToAll, LinkClass::InterNode);
    assert_eq!(inter_a2a.calls, 0);
    // per-microbatch weight gathers stay on GCD pairs
    let pair_ag = e.comm.cost.entry(Coll::AllGather, LinkClass::Intra(0));
    assert!(pair_ag.calls >= 4 * 8, "fwd gathers per micro per pair group: {pair_ag:?}");

    let mut c3 = cfg(Scheme::Zero3, 1, 3);
    c3.nodes = 2;
    c3.grad_accum = 4;
    let mut z3 = TrainEngine::new(c3, &ctx.tiny).unwrap();
    z3.step().unwrap();
    assert!(
        e.comm.cost.inter_node_bytes() < z3.comm.cost.inter_node_bytes(),
        "topo inter {} vs z3 inter {}",
        e.comm.cost.inter_node_bytes(),
        z3.comm.cost.inter_node_bytes()
    );
    });
}

#[test]
fn related_work_baselines_train() {
    // Table X rows we implement: MiCS and FSDP-hybrid must also learn
    CTX.with(|ctx| {
        for scheme in [Scheme::Mics { group: 8 }, Scheme::FsdpHybrid { shard: 8 }] {
            let mut e = TrainEngine::new(cfg(scheme, 4, 11), &ctx.tiny).unwrap();
            let mut losses = Vec::new();
            for _ in 0..4 {
                losses.push(e.step().unwrap());
            }
            assert!(
                losses.last().unwrap() < losses.first().unwrap(),
                "{}: {losses:?}",
                scheme.name()
            );
        }
    });
}

#[test]
fn mics_matches_zero3_numerics() {
    // MiCS with a full-world group is ZeRO-3 with a different transport —
    // same data, same init, fp16 wire both: curves must be very close.
    CTX.with(|ctx| {
        let mut a = TrainEngine::new(cfg(Scheme::Zero3, 3, 31), &ctx.tiny).unwrap();
        let mut b = TrainEngine::new(cfg(Scheme::Mics { group: 8 }, 3, 31), &ctx.tiny).unwrap();
        for _ in 0..3 {
            let la = a.step().unwrap();
            let lb = b.step().unwrap();
            assert!((la - lb).abs() / la < 0.01, "{la} vs {lb}");
        }
    });
}

#[test]
fn checkpoint_roundtrip_resumes_identically() {
    CTX.with(|ctx| {
        let scheme = Scheme::ZeroTopo { sec_degree: 2 };
        // run 4 steps straight
        let mut full = TrainEngine::new(cfg(scheme, 4, 77), &ctx.tiny).unwrap();
        let mut straight = Vec::new();
        for _ in 0..4 {
            straight.push(full.step().unwrap());
        }
        // run 2 steps, checkpoint, restore into a FRESH engine, run 2 more
        let mut first = TrainEngine::new(cfg(scheme, 4, 77), &ctx.tiny).unwrap();
        first.step().unwrap();
        first.step().unwrap();
        let ck = first.checkpoint();
        let bytes = ck.serialize();
        let ck2 = zero_topo::engine::checkpoint::Checkpoint::deserialize(&bytes).unwrap();
        let mut resumed = TrainEngine::new(cfg(scheme, 4, 77), &ctx.tiny).unwrap();
        resumed.restore(&ck2).unwrap();
        let l3 = resumed.step().unwrap();
        let l4 = resumed.step().unwrap();
        assert_eq!(l3, straight[2], "step 3 after resume must be bit-identical");
        assert_eq!(l4, straight[3], "step 4 after resume must be bit-identical");
        // scheme mismatch is rejected
        let mut other = TrainEngine::new(cfg(Scheme::Zero3, 1, 77), &ctx.tiny).unwrap();
        assert!(other.restore(&ck2).is_err());
    });
}

#[test]
fn failure_recovery_via_priced_checkpoint_matches_uninterrupted_run() {
    // save -> node failure (the engine is dropped) -> restore into a fresh
    // engine via the *priced* paths: the resumed losses must match the
    // uninterrupted run bit-for-bit, and both legs must charge simulated
    // seconds against the machine's storage path (DESIGN.md §17)
    CTX.with(|ctx| {
        let scheme = Scheme::ZeroTopo { sec_degree: 2 };
        let mut full = TrainEngine::new(cfg(scheme, 4, 91), &ctx.tiny).unwrap();
        let mut straight = Vec::new();
        for _ in 0..4 {
            straight.push(full.step().unwrap());
        }
        let mut first = TrainEngine::new(cfg(scheme, 4, 91), &ctx.tiny).unwrap();
        first.step().unwrap();
        first.step().unwrap();
        let (ck, save_s) = first.checkpoint_priced();
        assert!(save_s > 0.0, "save must cost simulated time, got {save_s}");
        drop(first); // the failure: that engine and its state are gone
        let mut resumed = TrainEngine::new(cfg(scheme, 4, 91), &ctx.tiny).unwrap();
        let restore_s = resumed.restore_priced(&ck).unwrap();
        assert!(restore_s > 0.0, "restore must cost simulated time, got {restore_s}");
        assert_eq!(resumed.step().unwrap(), straight[2], "step 3 must be bit-identical");
        assert_eq!(resumed.step().unwrap(), straight[3], "step 4 must be bit-identical");
    });
}

#[test]
fn grad_accumulation_equals_bigger_batch_direction() {
    // 2 accumulation steps halve per-micro noise; loss after N optimizer
    // steps should still decrease and stay finite
    CTX.with(|ctx| {
    let mut c = cfg(Scheme::ZeroTopo { sec_degree: 2 }, 3, 21);
    c.grad_accum = 2;
    let mut e = TrainEngine::new(c, &ctx.tiny).unwrap();
    let mut losses = Vec::new();
    for _ in 0..3 {
        losses.push(e.step().unwrap());
    }
    assert!(losses.iter().all(|l| l.is_finite()));
    assert!(losses[2] < losses[0]);
    });
}
