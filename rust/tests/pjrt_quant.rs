//! L1 ↔ L3 cross-check through the real artifact path: the Pallas
//! block-quantization kernels (lowered to HLO, compiled by PJRT) must be
//! bit-exact with the native Rust port in `zero_topo::quant` — the
//! contract that lets the engine's comm path use the fast native code
//! while staying faithful to the paper's GPU kernels.
//!
//! Requires `make artifacts`.

use zero_topo::quant;
use zero_topo::runtime::Runtime;
use zero_topo::util::rng::Rng;

// PjRtClient is Rc-based (not Send), so cache it per test thread.
thread_local! {
    static RT: Runtime = Runtime::load("artifacts").expect("run `make artifacts` first");
}

fn rand_input(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut v = vec![0.0f32; n];
    rng.fill_normal(&mut v, 1.5);
    v
}

#[test]
fn pallas_roundtrip_int8_matches_native() {
    RT.with(|rt| {
    let n = rt.manifest.quant_n;
    let block = rt.manifest.quant_block;
    let exe = rt.quant_executable("roundtrip_int8").unwrap();
    let x = rand_input(n, 11);
    let out = exe.execute::<xla::Literal>(&[xla::Literal::vec1(&x)]).unwrap()[0][0]
        .to_literal_sync()
        .unwrap();
    let pallas: Vec<f32> = out.to_tuple1().unwrap().to_vec::<f32>().unwrap();
    let native = quant::roundtrip_int8(&x, block);
    let err = zero_topo::util::stats::max_abs_err(&pallas, &native);
    assert!(err <= 1e-6, "pallas vs native int8 roundtrip max err {err}");
    });
}

#[test]
fn pallas_roundtrip_int4_matches_native() {
    RT.with(|rt| {
    let n = rt.manifest.quant_n;
    let block = rt.manifest.quant_block;
    let exe = rt.quant_executable("roundtrip_int4").unwrap();
    let x = rand_input(n, 13);
    let out = exe.execute::<xla::Literal>(&[xla::Literal::vec1(&x)]).unwrap()[0][0]
        .to_literal_sync()
        .unwrap();
    let pallas: Vec<f32> = out.to_tuple1().unwrap().to_vec::<f32>().unwrap();
    let native = quant::roundtrip_int4(&x, block);
    let err = zero_topo::util::stats::max_abs_err(&pallas, &native);
    assert!(err <= 1e-6, "pallas vs native int4 roundtrip max err {err}");
    });
}

#[test]
fn pallas_quantize_int8_bits_match_native() {
    RT.with(|rt| {
    let n = rt.manifest.quant_n;
    let block = rt.manifest.quant_block;
    let exe = rt.quant_executable("quant_int8").unwrap();
    let x = rand_input(n, 17);
    let out = exe.execute::<xla::Literal>(&[xla::Literal::vec1(&x)]).unwrap()[0][0]
        .to_literal_sync()
        .unwrap();
    let parts = out.to_tuple().unwrap();
    let q_pallas: Vec<i8> = parts[0].to_vec::<i8>().unwrap();
    let s_pallas: Vec<f32> = parts[1].to_vec::<f32>().unwrap();
    let native = quant::quantize_int8(&x, block);
    assert_eq!(q_pallas, native.q, "int8 integer outputs must be IDENTICAL");
    for (a, b) in s_pallas.iter().zip(&native.scales) {
        assert!((a - b).abs() <= a.abs() * 1e-6, "{a} vs {b}");
    }
    });
}

#[test]
fn pallas_quantize_int4_bits_match_native() {
    RT.with(|rt| {
    let n = rt.manifest.quant_n;
    let block = rt.manifest.quant_block;
    let exe = rt.quant_executable("quant_int4").unwrap();
    let x = rand_input(n, 19);
    let out = exe.execute::<xla::Literal>(&[xla::Literal::vec1(&x)]).unwrap()[0][0]
        .to_literal_sync()
        .unwrap();
    let parts = out.to_tuple().unwrap();
    let p_pallas: Vec<u8> = parts[0].to_vec::<u8>().unwrap();
    let native = quant::quantize_int4(&x, block);
    assert_eq!(p_pallas, native.packed, "int4 packed bytes must be IDENTICAL");
    });
}

#[test]
fn adversarial_inputs_still_match() {
    // zeros, constants, huge dynamic range, f16-boundary values
    RT.with(|rt| {
    let n = rt.manifest.quant_n;
    let block = rt.manifest.quant_block;
    let exe = rt.quant_executable("roundtrip_int8").unwrap();
    let mut x = vec![0.0f32; n];
    for (i, v) in x.iter_mut().enumerate() {
        *v = match i % 5 {
            0 => 0.0,
            1 => 65504.0,
            2 => -1e-7,
            3 => (i as f32) * 1e-3,
            _ => -3.14159,
        };
    }
    let out = exe.execute::<xla::Literal>(&[xla::Literal::vec1(&x)]).unwrap()[0][0]
        .to_literal_sync()
        .unwrap();
    let pallas: Vec<f32> = out.to_tuple1().unwrap().to_vec::<f32>().unwrap();
    let native = quant::roundtrip_int8(&x, block);
    let err = zero_topo::util::stats::max_abs_err(&pallas, &native);
    assert!(err <= 1e-3, "adversarial max err {err}");
    });
}
