//! Every committed machine spec JSON (`examples/machines/*.json`) must
//! load, validate, round-trip, and drive a simulation end-to-end — the CI
//! gate guaranteeing machines stay *data*, not code. Also pins the
//! builtin specs to their JSON twins so the two never drift.

use std::path::PathBuf;

use zero_topo::model::TransformerSpec;
use zero_topo::sched::Depth;
use zero_topo::sharding::Scheme;
use zero_topo::sim::{scaling_series, simulate_step, simulate_step_schedule, SimConfig};
use zero_topo::topology::{Cluster, LinkClass, MachineSpec};
use zero_topo::util::json::Json;

fn machine_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../examples/machines")
}

fn committed_specs() -> Vec<(PathBuf, MachineSpec)> {
    let mut out = Vec::new();
    for entry in std::fs::read_dir(machine_dir()).expect("examples/machines/ exists") {
        let p = entry.unwrap().path();
        if p.extension().map(|e| e == "json").unwrap_or(false) {
            let spec = MachineSpec::load(&p)
                .unwrap_or_else(|e| panic!("{}: {e}", p.display()));
            out.push((p, spec));
        }
    }
    out.sort_by(|a, b| a.0.cmp(&b.0));
    assert!(out.len() >= 4, "expected the committed sample machine specs");
    out
}

#[test]
fn committed_machines_validate_and_roundtrip() {
    for (p, spec) in committed_specs() {
        spec.validate().unwrap_or_else(|e| panic!("{}: {e}", p.display()));
        let re = MachineSpec::from_json(&Json::parse(&spec.to_json().to_string()).unwrap())
            .unwrap_or_else(|e| panic!("{}: {e}", p.display()));
        assert_eq!(spec, re, "{}", p.display());
    }
}

#[test]
fn committed_machines_match_builtin_twins() {
    // JSON files that share a name with a builtin must be byte-equivalent
    // specs — the JSONs are the builtins' source of truth for users
    let mut matched = 0;
    for (p, spec) in committed_specs() {
        if let Some(builtin) = MachineSpec::builtin(&spec.name) {
            assert_eq!(spec, builtin, "{} drifted from the builtin", p.display());
            matched += 1;
        }
    }
    assert!(matched >= 3, "expected JSON twins for the data-only builtins");
}

#[test]
fn committed_machines_simulate_one_node() {
    // the `--machine file.json` CI sanity: every committed spec runs a
    // 1-node simulate under each default scheme
    let model = TransformerSpec::gpt125m();
    let cfg = SimConfig::default();
    for (p, spec) in committed_specs() {
        for scheme in [Scheme::Zero3, Scheme::ZeroPP, Scheme::ZeroTopo { sec_degree: 0 }] {
            let b = simulate_step(&model, scheme, &Cluster::new(spec.clone(), 1), &cfg);
            assert!(
                b.step_s.is_finite() && b.step_s > 0.0,
                "{} {scheme:?}: step_s = {}",
                p.display(),
                b.step_s
            );
        }
    }
}

#[test]
fn json_only_machine_runs_simulate_scale_and_stalls() {
    // the acceptance path: a machine that exists ONLY as JSON (no Rust
    // changes) flows CLI-shaped end-to-end — scaling sweep + stall table
    let spec = MachineSpec::load(machine_dir().join("hypothetical_quadlevel.json")).unwrap();
    assert!(MachineSpec::builtin(&spec.name).is_none(), "must not be a builtin");
    let model = TransformerSpec::neox10b();
    let mut cfg = SimConfig::default();

    // `scale`: multi-node sweep
    let pts = scaling_series(
        &model,
        Scheme::ZeroTopo { sec_degree: 0 },
        &spec,
        &[1, 2, 4],
        &cfg,
    );
    assert_eq!(pts.len(), 3);
    assert!(pts.iter().all(|p| p.step_seconds > 0.0 && p.step_seconds.is_finite()));

    // `--stalls`: schedule + per-class stall attribution at depth 0
    cfg.prefetch_depth = Depth::Bounded(0);
    let cluster = Cluster::new(spec.clone(), 4);
    let (b, sched) = simulate_step_schedule(&model, Scheme::Zero3, &cluster, &cfg);
    let stalls = sched.stall_by_class(0);
    let total: f64 = stalls.values().sum();
    assert!(total > 0.0 && total.is_finite());
    // ZeRO-3 gathers span the world -> stalls land on the inter-node class
    assert!(stalls.contains_key(&LinkClass::InterNode), "{stalls:?}");
    assert!(b.step_s >= b.compute_s);

    // machine-named labels resolve for every stalled class
    for class in stalls.keys() {
        let label = spec.class_label(*class);
        assert!(!label.is_empty());
    }
}

#[test]
fn builtins_roundtrip_and_save_load() {
    let dir = std::env::temp_dir().join("zero_topo_machine_json_test");
    std::fs::create_dir_all(&dir).unwrap();
    for m in MachineSpec::builtins() {
        let path = dir.join(format!("{}.json", m.name));
        m.save(&path).unwrap();
        let re = MachineSpec::load(&path).unwrap();
        assert_eq!(m, re, "{}", m.name);
        let _ = std::fs::remove_file(&path);
    }
}

#[test]
fn frontier_json_reproduces_calibrated_step_time() {
    // acceptance criterion: the Frontier spec reproduces the calibrated
    // 20B/384-GCD ZeRO-topo step time within 0.1%. The pinned value is
    // the pre-refactor (NodeKind-enum) simulator output — the machine
    // spec must not perturb the calibration.
    const CALIBRATED_20B_384_TOPO_STEP_S: f64 = 12.972582660171392;
    let frontier = MachineSpec::frontier_mi250x();
    let rejson =
        MachineSpec::from_json(&Json::parse(&frontier.to_json().to_string()).unwrap()).unwrap();
    let model = TransformerSpec::neox20b();
    let cfg = SimConfig::default();
    let scheme = Scheme::ZeroTopo { sec_degree: 2 };
    let a = simulate_step(&model, scheme, &Cluster::new(frontier, 48), &cfg);
    assert!(
        (a.step_s - CALIBRATED_20B_384_TOPO_STEP_S).abs()
            <= 1e-3 * CALIBRATED_20B_384_TOPO_STEP_S,
        "step_s {} drifted from the calibrated {CALIBRATED_20B_384_TOPO_STEP_S}",
        a.step_s
    );
    // and the JSON round-trip of the spec prices identically, bit-for-bit
    let b = simulate_step(&model, scheme, &Cluster::new(rejson, 48), &cfg);
    assert_eq!(a.step_s, b.step_s);
    assert_eq!(a.inter_node_bytes, b.inter_node_bytes);
}
