//! Integration: the goodput-under-failure layer (DESIGN.md §17) against
//! the real machine specs and simulated step times — closed-form
//! Young/Daly optimum vs numeric argmax, storage-path orderings between
//! machines and schemes, the sweep grid contract, and the diagnosed-error
//! surface for degenerate inputs.

use zero_topo::model::TransformerSpec;
use zero_topo::sharding::Scheme;
use zero_topo::sim::goodput::{
    checkpoint_cost, goodput, optimal_interval, sweep, CheckpointCost, GoodputError,
    SWEEP_FACTORS,
};
use zero_topo::sim::{simulate_step, SimConfig};
use zero_topo::topology::{Cluster, MachineSpec};

const MTBF: f64 = 21_600.0;

fn frontier_point(scheme: Scheme) -> (f64, f64, CheckpointCost) {
    let model = TransformerSpec::neox20b();
    let cluster = Cluster::frontier(48);
    let cfg = SimConfig::default();
    let b = simulate_step(&model, scheme, &cluster, &cfg);
    let tokens =
        (b.grad_accum * cfg.micro_batch * model.seq * cluster.world_size()) as f64;
    let ck = checkpoint_cost(&model, scheme, &cluster, &cfg).unwrap();
    (b.step_s, tokens, ck)
}

#[test]
fn closed_form_optimum_matches_numeric_argmax_within_5_percent() {
    // the ISSUE 10 acceptance bound: where the Young/Daly assumptions
    // hold (interval well below MTBF), the closed-form tau* must sit
    // within 5% of the brute-force availability argmax
    for scheme in [Scheme::Zero3, Scheme::ZeroTopo { sec_degree: 0 }] {
        let (step_s, tokens, ck) = frontier_point(scheme);
        let tau = optimal_interval(MTBF, &ck).unwrap();
        let mut best = (f64::NEG_INFINITY, 0.0);
        // fine grid around the optimum: 0.05 tau .. 20 tau in 0.5% steps
        let mut interval = 0.05 * tau;
        while interval < 20.0 * tau {
            if let Ok(r) = goodput(step_s, tokens, &ck, MTBF, interval) {
                if r.goodput_tokens_per_s > best.0 {
                    best = (r.goodput_tokens_per_s, interval);
                }
            }
            interval *= 1.005;
        }
        let rel = (best.1 - tau).abs() / tau;
        assert!(
            rel < 0.05,
            "{}: numeric argmax {:.1}s vs closed-form {:.1}s ({:.2}% off)",
            scheme.name(),
            best.1,
            tau,
            rel * 100.0
        );
    }
}

#[test]
fn dgx_nvme_saves_faster_than_frontier_lustre() {
    // same world, same per-rank bytes: the checkpoint time ordering is
    // purely the storage path — DGX's node-local NVMe beats Lustre
    let model = TransformerSpec::neox20b();
    let cfg = SimConfig::default();
    let frontier = Cluster::frontier(48);
    let dgx = Cluster::new(MachineSpec::resolve("dgx").unwrap(), 48);
    let a = checkpoint_cost(&model, Scheme::Zero3, &frontier, &cfg).unwrap();
    let b = checkpoint_cost(&model, Scheme::Zero3, &dgx, &cfg).unwrap();
    assert_eq!(a.bytes_per_rank, b.bytes_per_rank, "state bytes are storage-independent");
    assert!(b.save_s < a.save_s, "dgx {} vs frontier {}", b.save_s, a.save_s);
    assert!(b.load_s < a.load_s);
}

#[test]
fn secondary_partitions_pay_rematerialization_on_restore() {
    // ZeRO-3 restores straight from storage; ZeRO++/ZeRO-topo must also
    // rebuild the quantized secondary copies via a full-world gather
    let (_, _, z3) = frontier_point(Scheme::Zero3);
    let (_, _, zpp) = frontier_point(Scheme::ZeroPP);
    let (_, _, zt) = frontier_point(Scheme::ZeroTopo { sec_degree: 0 });
    assert_eq!(z3.remat_s, 0.0);
    assert!(zpp.remat_s > 0.0);
    assert!(zt.remat_s > 0.0);
    assert!(zpp.restore_s() > z3.restore_s());
    // identical persisted bytes per rank: the sharded state is
    // scheme-independent (14 psi / W), only the remat differs
    assert_eq!(z3.bytes_per_rank, zt.bytes_per_rank);
}

#[test]
fn sweep_covers_the_factor_grid_and_flags_degenerates_inline() {
    let (step_s, tokens, ck) = frontier_point(Scheme::ZeroTopo { sec_degree: 0 });
    let tau = optimal_interval(MTBF, &ck).unwrap();
    let grid = sweep(step_s, tokens, &ck, MTBF).unwrap();
    assert_eq!(grid.len(), SWEEP_FACTORS.len());
    for ((interval, r), f) in grid.iter().zip(SWEEP_FACTORS) {
        assert!((interval - f * tau).abs() < 1e-9);
        // on this machine every grid point is valid; the optimum wins
        let report = r.as_ref().expect("frontier grid point prices");
        assert!(report.goodput_tokens_per_s > 0.0);
    }
    let at_tau = grid[3].1.as_ref().unwrap().goodput_tokens_per_s;
    for (i, (_, r)) in grid.iter().enumerate() {
        if i != 3 {
            assert!(r.as_ref().unwrap().goodput_tokens_per_s <= at_tau);
        }
    }
}

#[test]
fn degenerate_inputs_come_back_as_diagnosed_errors_not_nan() {
    let (step_s, tokens, ck) = frontier_point(Scheme::Zero3);
    // mtbf = 0 / negative / NaN
    assert!(matches!(
        goodput(step_s, tokens, &ck, 0.0, 100.0),
        Err(GoodputError::BadMtbf(_))
    ));
    assert!(matches!(
        goodput(step_s, tokens, &ck, f64::NAN, 100.0),
        Err(GoodputError::BadMtbf(_))
    ));
    assert!(matches!(optimal_interval(-1.0, &ck), Err(GoodputError::BadMtbf(_))));
    // interval at/above the MTBF: no checkpoint ever completes usefully
    assert!(matches!(
        goodput(step_s, tokens, &ck, 3600.0, 3600.0),
        Err(GoodputError::BadInterval { .. })
    ));
    // interval shorter than the save itself: the job only checkpoints
    assert!(matches!(
        goodput(step_s, tokens, &ck, MTBF, ck.save_s * 0.5),
        Err(GoodputError::IntervalBelowSave { .. })
    ));
    // every error renders a human-readable diagnosis, never NaN
    let e = goodput(step_s, tokens, &ck, 3600.0, 3600.0).unwrap_err();
    let msg = e.to_string();
    assert!(!msg.contains("NaN"), "diagnosis should explain, got: {msg}");
    assert!(!msg.is_empty());
}
