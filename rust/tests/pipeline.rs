//! Pipeline determinism and bubble-prediction gates (ISSUE 4): `P = 1`
//! reproduces the single-axis step bit-for-bit, the simulated bubble of a
//! communication-free equal-stage 1F1B plan matches the closed-form
//! `(P-1)/(M+P-1)` bound across random grids, `M = 1` hits the worst
//! case, interleaving tightens the bound to `(P-1)/(V·M+P-1)`, and
//! uneven layer counts partition without panicking.

use zero_topo::model::TransformerSpec;
use zero_topo::sched::pipeline::{even_chunk_params, split_even, PipeConfig, PipelinePlan};
use zero_topo::sched::Depth;
use zero_topo::sharding::Scheme;
use zero_topo::sim::{simulate_step, simulate_step_pipeline, SimConfig};
use zero_topo::testing::check;
use zero_topo::topology::Cluster;

#[test]
fn p1_reproduces_single_axis_step_bit_for_bit() {
    let cfg = SimConfig::default();
    let schemes =
        [Scheme::Zero3, Scheme::ZeroPP, Scheme::ZeroTopo { sec_degree: 2 }, Scheme::Zero1];
    check("pipeline P=1 == simulate_step", 24, |g| {
        let scheme = *g.pick(&schemes);
        let model =
            if g.bool() { TransformerSpec::neox20b() } else { TransformerSpec::neox10b() };
        let nodes = *g.pick(&[1usize, 2, 4, 8, 48]);
        let c = Cluster::frontier(nodes);
        let base = simulate_step(&model, scheme, &c, &cfg);
        let pipe = PipeConfig { stages: 1, microbatches: 0, interleave: 1 };
        let (b, _, _) = simulate_step_pipeline(&model, scheme, &c, &cfg, &pipe).unwrap();
        assert_eq!(base.step_s, b.step_s, "{scheme:?} nodes={nodes}");
        assert_eq!(base.grad_accum, b.microbatches, "{scheme:?} nodes={nodes}");
    });
}

#[test]
fn bubble_matches_closed_form_on_random_grids() {
    check("1F1B bubble == (P-1)/(M+P-1)", 60, |g| {
        let p = g.usize_in(1, 8);
        let m = g.usize_in(1, 16);
        let tf = 0.5 + g.f64_unit();
        let tb = 2.0 * tf;
        let plan = PipelinePlan::synthetic(p, m, 1, tf, tb, Depth::Infinite);
        let sched = plan.simulate();
        let bubble = plan.bubble_fraction(&sched);
        let bound = PipelinePlan::ideal_bubble(p, m, 1);
        assert!((bubble - bound).abs() < 1e-9, "p={p} m={m}: {bubble} vs {bound}");
        // and the compute-only makespan is exactly (M + P - 1) (tf + tb)
        let want = (m + p - 1) as f64 * (tf + tb);
        assert!(
            (sched.makespan() - want).abs() < 1e-9 * want,
            "p={p} m={m}: {} vs {want}",
            sched.makespan()
        );
    });
}

#[test]
fn single_microbatch_hits_the_worst_case_bubble() {
    for p in [2usize, 3, 4, 8] {
        let plan = PipelinePlan::synthetic(p, 1, 1, 1.0, 2.0, Depth::Infinite);
        let bubble = plan.bubble_fraction(&plan.simulate());
        let worst = (p - 1) as f64 / p as f64;
        assert!((bubble - worst).abs() < 1e-9, "p={p}: {bubble} vs {worst}");
    }
}

#[test]
fn interleaving_matches_its_bound_and_wins() {
    check("interleaved bubble == (P-1)/(VM+P-1)", 40, |g| {
        let p = g.usize_in(2, 6);
        let m = g.usize_in(1, 4) * p;
        let v = g.usize_in(2, 4);
        let plan = PipelinePlan::synthetic(p, m, v, 1.0, 2.0, Depth::Infinite);
        let bubble = plan.bubble_fraction(&plan.simulate());
        let bound = PipelinePlan::ideal_bubble(p, m, v);
        assert!((bubble - bound).abs() < 1e-9, "p={p} m={m} v={v}: {bubble} vs {bound}");
        let plain = PipelinePlan::synthetic(p, m, 1, 1.0, 2.0, Depth::Infinite);
        assert!(bubble < plain.bubble_fraction(&plain.simulate()), "p={p} m={m} v={v}");
    });
}

#[test]
fn uneven_layer_counts_partition_cleanly() {
    check("layer split covers", 60, |g| {
        let layers = g.usize_in(1, 96);
        let chunks = g.usize_in(1, 32);
        let split = split_even(layers, chunks);
        assert_eq!(split.len(), chunks);
        assert_eq!(split.iter().sum::<usize>(), layers);
        assert!(split.iter().max().unwrap() - split.iter().min().unwrap() <= 1);
        let total = g.i64_in(1, 1 << 40) as u64;
        let cp = even_chunk_params(total, chunks);
        assert_eq!(cp.iter().sum::<u64>(), total);
    });
}

#[test]
fn indivisible_layer_counts_simulate_end_to_end() {
    // 44 NeoX-20B layers over P=8 stages (not divisible) must price and
    // schedule without panicking, on frontier and dgx
    let model = TransformerSpec::neox20b();
    let cfg = SimConfig::default();
    for nodes in [8usize, 48] {
        let c = Cluster::frontier(nodes);
        let pipe = PipeConfig { stages: 8, microbatches: 8, interleave: 1 };
        let (b, _, _) =
            simulate_step_pipeline(&model, Scheme::ZeroTopo { sec_degree: 2 }, &c, &cfg, &pipe)
                .unwrap();
        assert!(b.step_s.is_finite() && b.step_s > 0.0, "nodes={nodes}");
        assert!(b.bubble_fraction >= 0.0 && b.bubble_fraction < 1.0, "nodes={nodes}");
    }
}

#[test]
fn acceptance_pipeline_20b_384_gcds() {
    // ISSUE acceptance: step time + bubble fraction for 1F1B and
    // interleaved at 20B / 384 GCDs, P=4
    let model = TransformerSpec::neox20b();
    let cfg = SimConfig::default();
    let c = Cluster::frontier(48);
    let scheme = Scheme::ZeroTopo { sec_degree: 2 };
    let pipe = |mb: usize, v: usize| PipeConfig { stages: 4, microbatches: mb, interleave: v };
    let (f1b, _, _) = simulate_step_pipeline(&model, scheme, &c, &cfg, &pipe(8, 1)).unwrap();
    assert!(f1b.bubble_fraction > 0.0 && f1b.bubble_fraction < 1.0, "{f1b:?}");
    assert!((f1b.ideal_bubble - 3.0 / 11.0).abs() < 1e-12);
    let (inter, _, _) = simulate_step_pipeline(&model, scheme, &c, &cfg, &pipe(8, 2)).unwrap();
    assert!(inter.ideal_bubble < f1b.ideal_bubble);
    // more microbatches amortize the fill/drain: smaller bubble
    let (m32, _, _) = simulate_step_pipeline(&model, scheme, &c, &cfg, &pipe(32, 1)).unwrap();
    assert!(m32.bubble_fraction < f1b.bubble_fraction, "{} vs {}", m32.bubble_fraction, f1b.bubble_fraction);
}
