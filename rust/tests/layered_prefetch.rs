//! Gather-splitting invariants for layer-granular prefetch (ISSUE 5):
//! per-block gather seconds must sum to the monolithic
//! `t_gather_fwd`/`t_gather_bwd` (and `prefetchable_s`/`serialized_s`
//! must be preserved) for arbitrary chunk counts; a single block must
//! reproduce today's `StepPlan` schedule bit-for-bit; depth-in-layers
//! must be monotone; and at the calibrated 20B/384-GCD points the
//! layered depth=∞ step must track the monolithic one — never slower,
//! at most one microbatch's compute faster (the shrunken step tail),
//! and within 1% for the compute-bound ZeRO-topo headline.

use zero_topo::comm::cost::{CommEfficiency, CostModel};
use zero_topo::model::TransformerSpec;
use zero_topo::sched::pipeline::even_chunk_params;
use zero_topo::sched::plan::StepPlan;
use zero_topo::sched::{Depth, StreamKind};
use zero_topo::sharding::{Scheme, ShardingSpec};
use zero_topo::sim::{simulate_step, SimConfig};
use zero_topo::testing::check;
use zero_topo::topology::Cluster;

const SCHEMES: [Scheme; 5] = [
    Scheme::Zero3,
    Scheme::ZeroPP,
    Scheme::ZeroTopo { sec_degree: 2 },
    Scheme::ZeroTopo { sec_degree: 8 },
    Scheme::Zero1,
];

fn plans(
    scheme: Scheme,
    nodes: usize,
    ga: usize,
    depth: Depth,
    psi: u64,
    blocks: usize,
) -> (StepPlan, StepPlan) {
    let cluster = Cluster::frontier(nodes);
    let cost = CostModel::with_efficiency(cluster.clone(), CommEfficiency::rccl_frontier());
    let spec = ShardingSpec::resolve(scheme, &cluster).unwrap();
    let mono =
        StepPlan::from_protocol(&cost, scheme, &spec, psi as usize, 256, ga, 3.0, depth);
    let elems = even_chunk_params(psi, blocks);
    let layered =
        StepPlan::from_protocol_layered(&cost, scheme, &spec, &elems, 256, ga, 3.0, depth);
    (mono, layered)
}

#[test]
fn per_block_gathers_sum_to_monolithic_for_arbitrary_chunk_counts() {
    check("block gather sums == monolithic", 60, |g| {
        let scheme = *g.pick(&SCHEMES);
        let nodes = g.usize_in(1, 6);
        let ga = g.usize_in(1, 6);
        let blocks = g.usize_in(2, 64);
        let psi = g.i64_in(1_000, 4_000_000_000) as u64;
        let (mono, lay) = plans(scheme, nodes, ga, Depth::Infinite, psi, blocks);
        assert_eq!(lay.blocks.len(), blocks);
        let ctx = format!("{scheme:?} nodes={nodes} ga={ga} blocks={blocks} psi={psi}");
        let f: f64 = lay.blocks.iter().map(|b| b.t_gather_fwd).sum();
        let b: f64 = lay.blocks.iter().map(|b| b.t_gather_bwd).sum();
        let c: f64 = lay.blocks.iter().map(|b| b.compute_frac).sum();
        assert!((f - mono.t_gather_fwd).abs() <= 1e-9 * mono.t_gather_fwd.max(1e-12), "{ctx}");
        assert!((b - mono.t_gather_bwd).abs() <= 1e-9 * mono.t_gather_bwd.max(1e-12), "{ctx}");
        assert!((c - 1.0).abs() < 1e-9, "{ctx}: fracs sum to {c}");
        // the derived totals every consumer reads are preserved exactly
        assert_eq!(lay.t_gather_fwd, mono.t_gather_fwd, "{ctx}");
        assert_eq!(lay.t_gather_bwd, mono.t_gather_bwd, "{ctx}");
        assert_eq!(lay.prefetchable_s(), mono.prefetchable_s(), "{ctx}");
        assert_eq!(lay.serialized_s(), mono.serialized_s(), "{ctx}");
        // and the scheduled prefetch stream does the same total work (only
        // asserted without a §V.D update gather, whose processor sharing
        // with same-class block gathers legitimately stretches spans)
        if mono.t_update == 0.0 {
            let sched = lay.simulate();
            let busy = sched.stream_busy(0, StreamKind::Prefetch);
            let want = ga as f64 * (mono.t_gather_fwd + mono.t_gather_bwd);
            assert!((busy - want).abs() <= 1e-6 * want.max(1e-12), "{ctx}: {busy} vs {want}");
        }
    });
}

#[test]
fn single_block_reproduces_todays_schedule_bit_for_bit() {
    let depths = [Depth::Bounded(0), Depth::Bounded(1), Depth::Bounded(3), Depth::Infinite];
    check("blocks=1 == StepPlan", 40, |g| {
        let scheme = *g.pick(&SCHEMES);
        let nodes = g.usize_in(1, 6);
        let ga = g.usize_in(1, 6);
        let depth = *g.pick(&depths);
        let psi = g.i64_in(1_000, 4_000_000_000) as u64;
        let (mono, lay) = plans(scheme, nodes, ga, depth, psi, 1);
        assert!(lay.blocks.is_empty());
        let (a, b) = (mono.simulate(), lay.simulate());
        let ctx = format!("{scheme:?} nodes={nodes} ga={ga} {depth:?}");
        assert_eq!(a.makespan(), b.makespan(), "{ctx}");
        assert_eq!(a.spans().len(), b.spans().len(), "{ctx}");
        for (x, y) in a.spans().iter().zip(b.spans()) {
            assert_eq!((x.start, x.end), (y.start, y.end), "{ctx}");
        }
    });
}

#[test]
fn depth_in_layers_is_monotone_non_increasing() {
    // update-free schemes: without the §V.D refresh no two comm tasks can
    // share a contention domain in a single-rank plan, so weakening the
    // gate can only move start times earlier — monotone rigorously
    let schemes = [Scheme::Zero3, Scheme::ZeroPP, Scheme::Zero1];
    check("depth-in-layers monotone", 30, |g| {
        let scheme = *g.pick(&schemes);
        let nodes = g.usize_in(1, 4);
        let blocks = g.usize_in(2, 24);
        let psi = g.i64_in(1_000_000, 4_000_000_000) as u64;
        let mut last = f64::INFINITY;
        for depth in [
            Depth::Bounded(0),
            Depth::Bounded(1),
            Depth::Bounded(2),
            Depth::Bounded(blocks),
            Depth::Infinite,
        ] {
            let (_, lay) = plans(scheme, nodes, 4, depth, psi, blocks);
            let mk = lay.simulate().makespan();
            assert!(
                mk <= last + 1e-9 * last.max(1.0),
                "{scheme:?} nodes={nodes} blocks={blocks} {depth:?}: {mk} > {last}"
            );
            last = mk;
        }
    });
}

#[test]
fn acceptance_layered_inf_tracks_monolithic_inf() {
    // ISSUE acceptance at the calibrated 20B/384-GCD points, frontier and
    // dgx: blocks=1 reproduces the BENCH_baseline entries at 0 drift; at
    // depth=inf the layered step is never slower than the monolithic one
    // and gains at most one microbatch's compute (the step tail after the
    // last gather shrinks from a whole backward to one block); for the
    // compute-bound calibrated scheme (ZeRO-topo, the Fig 7 headline) the
    // two agree within 1%.
    let model = TransformerSpec::neox20b();
    for machine in ["frontier", "dgx"] {
        let spec = zero_topo::topology::MachineSpec::resolve(machine).unwrap();
        let cluster = Cluster::new(spec, 48);
        for scheme in [Scheme::Zero3, Scheme::ZeroPP, Scheme::ZeroTopo { sec_degree: 2 }] {
            let mono = simulate_step(&model, scheme, &cluster, &SimConfig::default());
            let mut cfg = SimConfig::default();
            cfg.layer_blocks = 1;
            let one = simulate_step(&model, scheme, &cluster, &cfg);
            assert_eq!(mono.step_s, one.step_s, "{machine}/{scheme:?}: blocks=1 drifted");
            cfg.layer_blocks = model.n_layers;
            let lay = simulate_step(&model, scheme, &cluster, &cfg);
            let micro_compute = mono.compute_s / mono.grad_accum as f64;
            assert!(
                lay.step_s <= mono.step_s + 1e-9 * mono.step_s,
                "{machine}/{scheme:?}: layered inf {} slower than monolithic {}",
                lay.step_s,
                mono.step_s
            );
            assert!(
                lay.step_s >= mono.step_s - micro_compute - 1e-9 * mono.step_s,
                "{machine}/{scheme:?}: layered inf {} gained more than one \
                 microbatch compute over {}",
                lay.step_s,
                mono.step_s
            );
            if matches!(scheme, Scheme::ZeroTopo { .. }) {
                assert!(
                    (lay.step_s - mono.step_s).abs() <= 0.01 * mono.step_s,
                    "{machine}: ZeRO-topo layered inf {} vs monolithic inf {}",
                    lay.step_s,
                    mono.step_s
                );
            }
            // totals conserved through the sim path too
            assert!((lay.prefetchable_s - mono.prefetchable_s).abs() < 1e-9);
            assert!((lay.grad_sync_s - mono.grad_sync_s).abs() < 1e-9);
        }
    }
}

#[test]
fn depth_zero_in_layers_still_serializes_exactly() {
    // the split is conservative, so fetch-on-demand degenerates to the
    // same serialized reference as the monolithic plan (ZeRO-3: no
    // update gather to overlap)
    let (mono, lay) = plans(Scheme::Zero3, 4, 4, Depth::Bounded(0), 2_000_000_000, 16);
    let a = mono.simulate().makespan();
    let b = lay.simulate().makespan();
    assert!((a - b).abs() <= 1e-9 * a, "{a} vs {b}");
    assert!((b - lay.serialized_s()).abs() <= 1e-9 * b);
}
